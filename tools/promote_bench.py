#!/usr/bin/env python3
"""Promote a CI-measured decode-throughput record to the committed baseline.

Every CI run uploads a fresh ``BENCH_hotpath`` artifact produced by a real
``cargo bench --bench bench_hotpath`` execution (``provenance: "measured"``).
The committed repo-root ``BENCH_hotpath.json`` arms the >20% regression gate
(``tools/bench_gate.py``) — but only a genuinely measured record may land
there, never a hand-edited one. This tool is the only supported way to
advance the baseline:

    python3 tools/promote_bench.py --fresh path/to/downloaded/BENCH_hotpath.json
    git add BENCH_hotpath.json && git commit

It refuses records that are not ``provenance: "measured"``, that carry no
decode work, or whose schema drifted from the committed file (so gate keys
never silently vanish).
"""

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO_ROOT, "BENCH_hotpath.json")

# Keys the regression gate and the PR-4/PR-6 evidence trail rely on.
REQUIRED_POSITIVE = [
    "decode_tokens",
    "samples",
    "fast_tokens_per_s",
    "fast_ns_per_token",
    "pool_threads",
]


def fail(msg):
    print(f"REFUSED: {msg}", file=sys.stderr)
    return 1


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument(
        "--fresh",
        required=True,
        help="BENCH_hotpath.json downloaded from a CI run's BENCH_hotpath artifact",
    )
    p.add_argument(
        "--baseline",
        default=BASELINE,
        help=f"committed baseline to replace (default: {BASELINE})",
    )
    args = p.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    if fresh.get("provenance") != "measured":
        return fail(
            f"fresh record has provenance={fresh.get('provenance')!r}; only a real "
            "bench run's output (provenance='measured') may become the baseline"
        )
    for key in REQUIRED_POSITIVE:
        if not float(fresh.get(key) or 0.0) > 0.0:
            return fail(f"fresh record's {key!r} is missing or non-positive")

    missing = sorted(set(base) - set(fresh) - {"note"})
    if missing:
        return fail(
            "fresh record dropped baseline keys the gate/evidence trail uses: "
            + ", ".join(missing)
        )

    if fresh.get("smoke"):
        print(
            "note: promoting a smoke-profile record (CI default). Fine for the "
            "gate — both sides of the comparison run the same profile."
        )
    prev = float(base.get("fast_tokens_per_s") or 0.0)
    now = float(fresh["fast_tokens_per_s"])
    if prev > 0.0:
        print(f"baseline fast-path: {prev:.1f} -> {now:.1f} tok/s ({now / prev - 1:+.1%})")
    else:
        print(f"arming the gate: fast-path {now:.1f} tok/s (previous baseline was a seed)")

    with open(args.baseline, "w") as f:
        json.dump(fresh, f, indent=2)
        f.write("\n")
    print(f"wrote {args.baseline} — commit it to advance the regression baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
