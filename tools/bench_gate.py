#!/usr/bin/env python3
"""Soft regression gate over the decode-throughput record.

Compares a freshly produced ``BENCH_hotpath.json`` against the committed
baseline and fails (exit 1) when the fast-path decode tokens/sec dropped by
more than ``--max-regression`` (default 20%).

Bootstrap mode: a committed baseline whose ``provenance`` is not
``"measured"`` (or that lacks a positive ``fast_tokens_per_s``) cannot be
compared — the gate prints the fresh numbers and passes, so the very first
measured CI artifact can be committed to arm the gate.
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", required=True, help="committed BENCH_hotpath.json")
    p.add_argument("--fresh", required=True, help="BENCH_hotpath.json from this run")
    p.add_argument(
        "--max-regression",
        type=float,
        default=0.20,
        help="maximum tolerated fractional drop in fast_tokens_per_s (default 0.20)",
    )
    args = p.parse_args()

    base = load(args.baseline)
    fresh = load(args.fresh)
    key = "fast_tokens_per_s"
    b = float(base.get(key) or 0.0)
    f = float(fresh.get(key) or 0.0)

    print(f"baseline: {b:.1f} tok/s  (provenance: {base.get('provenance', 'unknown')}, "
          f"smoke: {base.get('smoke')})")
    print(f"fresh   : {f:.1f} tok/s  (provenance: {fresh.get('provenance', 'unknown')}, "
          f"smoke: {fresh.get('smoke')})")

    if base.get("provenance") != "measured" or b <= 0.0:
        # GitHub Actions warning annotation: keep the unarmed gate loud on
        # every run page until a measured baseline lands.
        print("::warning title=bench gate unarmed::committed BENCH_hotpath.json is a "
              "seed record — commit this run's BENCH_hotpath artifact to the repo "
              "root to arm the regression gate")
        print("baseline is a seed record without measured numbers — gate passes in "
              "bootstrap mode. Commit this run's artifact as BENCH_hotpath.json to arm it.")
        return 0
    if f <= 0.0:
        print("FAIL: fresh record lacks a fast-path throughput number")
        return 1
    if base.get("smoke") != fresh.get("smoke"):
        print("note: smoke flags differ between baseline and fresh run; "
              "comparison is indicative only")

    ratio = f / b
    floor = 1.0 - args.max_regression
    print(f"fresh/baseline = {ratio:.3f} (floor {floor:.2f})")
    if ratio < floor:
        print(f"FAIL: fast-path decode regressed more than "
              f"{args.max_regression:.0%} vs the committed baseline")
        return 1
    print("OK: fast-path decode within the regression budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
