#!/usr/bin/env python3
"""Structural validator for exported Chrome trace-event JSON.

CI runs this over every ``*.trace.json`` the scenario suite writes (see
``leap scenario --trace-dir``); it is the independent check that the
hand-rolled exporter emits documents Perfetto/chrome://tracing will
actually load. Checks, per file:

- top level is an object with a non-empty ``traceEvents`` array;
- every record carries ``ph``, ``ts``, ``pid``, ``tid``, ``name``;
- per track (``tid``), timestamps are monotone non-decreasing;
- per track, ``B``/``E`` records balance as a stack and every ``E``
  names the span it closes (Perfetto rejects mismatches);
- every track that carries timeline records has ``thread_name``
  metadata;
- at least one per-session track exists (tid in [1000, 2000) — the
  exporter's session-track band).

Exit status: 0 if every file passes, 1 otherwise (with one line per
violation). Usage: ``validate_trace.py TRACE.json [TRACE.json ...]``.
"""

import json
import sys

SESSION_TID_LO = 1000
SESSION_TID_HI = 2000
KNOWN_PHASES = {"B", "E", "i", "C", "M"}


def validate(path):
    """Return a list of violation strings for one trace file."""
    errors = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: missing or empty traceEvents array"]

    named_tids = set()
    used_tids = set()
    stacks = {}  # tid -> [open span names]
    last_ts = {}  # tid -> last timestamp seen
    for i, ev in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        missing = [k for k in ("ph", "ts", "pid", "tid", "name") if k not in ev]
        if missing:
            errors.append(f"{where}: missing fields {missing}")
            continue
        ph, tid, ts, name = ev["ph"], ev["tid"], ev["ts"], ev["name"]
        if ph not in KNOWN_PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph == "M":
            if name == "thread_name":
                named_tids.add(tid)
            continue
        used_tids.add(tid)
        if ts < last_ts.get(tid, float("-inf")):
            errors.append(
                f"{where}: tid {tid} timestamp went backwards "
                f"({last_ts[tid]} -> {ts})"
            )
        last_ts[tid] = ts
        if ph == "B":
            stacks.setdefault(tid, []).append(name)
        elif ph == "E":
            stack = stacks.setdefault(tid, [])
            if not stack:
                errors.append(f"{where}: tid {tid} E {name!r} with no open span")
            elif stack[-1] != name:
                errors.append(
                    f"{where}: tid {tid} E {name!r} closes open span "
                    f"{stack[-1]!r}"
                )
                stack.pop()
            else:
                stack.pop()

    for tid, stack in sorted(stacks.items()):
        if stack:
            errors.append(f"{path}: tid {tid} ends with unclosed spans {stack}")
    for tid in sorted(used_tids - named_tids):
        errors.append(f"{path}: tid {tid} has records but no thread_name metadata")
    if not any(SESSION_TID_LO <= t < SESSION_TID_HI for t in used_tids):
        errors.append(f"{path}: no per-session track (tid in [1000, 2000))")
    return errors


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    failed = False
    for path in argv[1:]:
        errors = validate(path)
        if errors:
            failed = True
            for e in errors:
                print(f"FAIL {e}")
        else:
            print(f"OK   {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
