//! Scaling study: models × context windows × architecture knobs.
//!
//! Regenerates the Fig. 10 throughput matrix and the Fig. 12 packet-width /
//! IRCU-parallelism frontier in one run, plus the §VI-D sublinear-scaling
//! observation (throughput vs model size vs critical-path growth).
//!
//! Run: `cargo run --release --example scaling_sweep`

use leap::arch::HwParams;
use leap::model::ModelPreset;
use leap::sim::AnalyticalSim;

fn main() {
    println!("== Fig. 10: throughput across models and context windows ==\n");
    println!(
        "{:<14} {:>6} {:>6} {:>13} {:>12} {:>12}",
        "model", "in", "out", "prefill t/s", "decode t/s", "total t/s"
    );
    for preset in [ModelPreset::Llama1B, ModelPreset::Llama8B, ModelPreset::Llama13B] {
        let sim = AnalyticalSim::new(preset, HwParams::default());
        for (inp, out) in [(128, 128), (512, 512), (1024, 1024), (2048, 2048)] {
            let r = sim.run(inp, out);
            println!(
                "{:<14} {:>6} {:>6} {:>13.1} {:>12.2} {:>12.2}",
                preset.shape().name,
                inp,
                out,
                r.prefill.tokens_per_s,
                r.decode.tokens_per_s,
                r.total_tokens_per_s
            );
        }
        println!();
    }

    println!("== §VI-D: sublinear throughput drop vs model growth ==\n");
    let r1 = AnalyticalSim::new(ModelPreset::Llama1B, HwParams::default()).run(1024, 1024);
    let r8 = AnalyticalSim::new(ModelPreset::Llama8B, HwParams::default()).run(1024, 1024);
    let size_ratio = ModelPreset::Llama8B.shape().mapped_params() as f64
        / ModelPreset::Llama1B.shape().mapped_params() as f64;
    let thr_ratio = r1.total_tokens_per_s / r8.total_tokens_per_s;
    println!("1B → 8B: parameters ×{size_ratio:.1}, throughput ÷{thr_ratio:.2} (sublinear ✓)");
    println!("(critical path scales with s_e·s_l, not s_e·s_h·s_l — row/col partitioning)\n");

    println!("== Fig. 12: packet width × IRCU parallelism (Llama 3.2-1B, 1024+1024) ==\n");
    print!("{:>10}", "pkt\\MACs");
    let mac_sweep = [4usize, 8, 16, 32, 64];
    for m in mac_sweep {
        print!("{m:>10}");
    }
    println!();
    for packet_bits in [16u32, 32, 64, 128, 256] {
        print!("{packet_bits:>10}");
        for macs in mac_sweep {
            let mut hw = HwParams::default();
            hw.packet_bits = packet_bits;
            hw.ircu_macs = macs;
            let r = AnalyticalSim::new(ModelPreset::Llama1B, hw).run(1024, 1024);
            print!("{:>10.0}", r.total_tokens_per_s);
        }
        println!();
    }
    println!("\n(Table I point: 64-bit packets, 16 MACs — near the frontier knee)");
}
