//! Quickstart: the five-minute tour of the LEAP library.
//!
//! Compiles Llama 3.2-1B for the PIM-NoC, runs the spatial-mapping DSE,
//! simulates a full inference, and prints the headline numbers alongside
//! the A100 baseline.
//!
//! Run: `cargo run --release --example quickstart`

use leap::arch::HwParams;
use leap::baselines::GpuModel;
use leap::compiler::Compiler;
use leap::mapping::explore;
use leap::model::ModelPreset;
use leap::sim::AnalyticalSim;

fn main() -> anyhow::Result<()> {
    println!("== LEAP quickstart ==\n");

    // 1. Hardware: Table I defaults (128×128 crossbars, 64-bit packets,
    //    16-MAC IRCUs, 1 GHz).
    let hw = HwParams::default();
    println!(
        "hardware: {}×{} crossbars, {}-bit packets, {} MACs/IRCU, {} GHz",
        hw.xb, hw.xb, hw.packet_bits, hw.ircu_macs, hw.freq_ghz
    );

    // 2. Compile the model: partition weights, build the Fig. 3(b) DAG,
    //    pick the spatial mapping.
    let preset = ModelPreset::Llama1B;
    let compiled = Compiler { hw: hw.clone(), run_dse: false }.compile(preset)?;
    println!(
        "\ncompiled {}: tile {}×{} macros, DAG {} nodes / {} edges",
        compiled.shape.name,
        2 * compiled.geom.dc,
        2 * compiled.geom.dc,
        compiled.dag.nodes.len(),
        compiled.dag.edges.len()
    );

    // 3. Mapping DSE (Fig. 8): the Fig. 4 layout is near-optimal.
    let dse = explore(compiled.geom.dc, hw.xb, hw.packet_bits);
    println!(
        "mapping DSE: {} candidates in {:.2}s — paper layout at p{:.1} of the cost distribution",
        dse.costs.len(),
        dse.elapsed_s,
        dse.paper_percentile()
    );

    // 4. Simulate a full inference (1024 in + 1024 out).
    let sim = AnalyticalSim::new(preset, hw);
    let r = sim.run(1024, 1024);
    println!("\ninference (1024 in + 1024 out):");
    println!("  prefill  {:>10.1} tok/s", r.prefill.tokens_per_s);
    println!("  decode   {:>10.1} tok/s", r.decode.tokens_per_s);
    println!("  total    {:>10.1} tok/s at {:.2} W → {:.1} tok/J", r.total_tokens_per_s, r.avg_power_w, r.tokens_per_j);

    // 5. Compare with an A100 running the same workload.
    let a100 = GpuModel::a100().run(&compiled.shape, 1024, 1024);
    println!("\nvs A100: {:.1} tok/s at {:.0} W → {:.3} tok/J", a100.total_tokens_per_s, a100.power_w, a100.tokens_per_j);
    println!(
        "LEAP advantage: {:.2}× throughput, {:.1}× energy efficiency",
        r.total_tokens_per_s / a100.total_tokens_per_s,
        r.tokens_per_j / a100.tokens_per_j
    );
    Ok(())
}
