//! END-TO-END DRIVER: serve a real (tiny) Llama-style model through the
//! full three-layer stack and report throughput/latency/energy.
//!
//! This is the composition proof required by DESIGN.md §6: quantised
//! `leapbin` weights → functional numerics backend (pure-Rust reference f32
//! by default; PJRT when built with `--features xla` and real artifacts) →
//! serving coordinator + instruction-level/analytical simulators (L3).
//!
//! The generated tokens are REAL model outputs (greedy decode with the
//! quantised weights), self-checked against the golden continuation
//! recorded by the python oracle at fixture-generation time
//! (`python -m compile.gen_ref_fixture`). Timing and energy come from the
//! cycle simulator for the same shapes.
//!
//! Runs offline out of the box against the checked-in fixture:
//!   cargo run --release --example e2e_serve
//!
//! The results are recorded in EXPERIMENTS.md §End-to-end.

use leap::arch::HwParams;
use leap::coordinator::{BatchPolicy, EngineConfig, Numerics, ServingEngine};
use leap::kvcache::KvCacheConfig;
use leap::model::ModelPreset;
use leap::runtime::{leapbin, KernelMode, ReferenceBackend};
use leap::scenario::{chunk_ab_json, Scenario};

fn main() -> anyhow::Result<()> {
    // Pin the checked-in fixture: its golden comes from gen_ref_fixture.py,
    // which asserts a top-2 argmax margin, so the exact-match check below is
    // sound. (An aot.py artifacts/ golden is produced by the Pallas-lowered
    // path with no margin guarantee — it is exercised by the `xla`-gated
    // tests instead.)
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tiny_ref");

    println!("== LEAP end-to-end serving (tiny-llama, reference backend) ==\n");
    let backend = ReferenceBackend::load(&dir)?;
    println!(
        "loaded artifacts from {}: vocab={} d_model={} layers={} (backend: reference-f32)",
        dir.display(),
        backend.meta().vocab,
        backend.meta().d_model,
        backend.meta().n_layers,
    );

    // --- golden continuation recorded by the python oracle ----------------
    let golden_prompt = leapbin::load(dir.join("golden/prompt.bin"))?.as_i32()?;
    let golden_tokens = leapbin::load(dir.join("golden/greedy_tokens.bin"))?.as_i32()?;

    let wall0 = std::time::Instant::now();
    let mut engine = ServingEngine::new(EngineConfig {
        preset: ModelPreset::Tiny,
        hw: HwParams::default(),
        policy: BatchPolicy::default(),
        numerics: Numerics::Backend(Box::new(backend)),
    })?;

    // request 0: the golden prompt (checked); requests 1..4: variations
    let golden_id = engine.submit(golden_prompt.clone(), golden_tokens.len())?;
    let mut other_ids = Vec::new();
    for i in 1..4 {
        let prompt: Vec<i32> = golden_prompt.iter().map(|&t| (t + i) % 512).collect();
        other_ids.push(engine.submit(prompt, 8)?);
    }
    engine.run_until_idle()?;
    let wall = wall0.elapsed();

    let got = engine.take_completion(golden_id).expect("golden request done");
    println!("\ngolden prompt   : {golden_prompt:?}");
    println!("generated       : {:?}", got.tokens);
    println!("expected        : {golden_tokens:?}");
    anyhow::ensure!(
        got.tokens == golden_tokens,
        "generated tokens diverge from the python golden run!"
    );
    println!("✓ rust reference generation matches the python golden continuation exactly");

    for id in other_ids {
        let c = engine.take_completion(id).expect("request done");
        println!("request {} → {:?}", c.id, c.tokens);
    }

    // --- serving metrics (simulated timing/energy + host overhead) -------
    let m = &engine.metrics;
    let (lp50, lp99) = m.latency_p50_p99();
    println!("\n-- serving metrics (simulated hardware clock) --");
    println!("requests        : {} done, {} failed", m.requests_done, m.requests_failed);
    println!("tokens          : {} prefill + {} decode", m.prefill_tokens, m.decode_tokens);
    println!("sim time        : {:.3} ms", m.sim_time_ns as f64 * 1e-6);
    println!("throughput      : {:.1} tok/s total, {:.1} tok/s decode", m.total_tokens_per_s(), m.decode_tokens_per_s());
    println!("energy          : {:.6} J → {:.1} tok/J", m.energy_j, m.tokens_per_j());
    println!("latency p50/p99 : {:.3} / {:.3} ms", lp50 as f64 * 1e-6, lp99 as f64 * 1e-6);
    println!("npm bank swaps  : {}", m.npm_swaps);
    println!("\n-- host (L3) overhead --");
    println!("wall time       : {:.1} ms (includes the f32 forward passes)", wall.as_secs_f64() * 1e3);
    println!("host/sim ratio  : {:.2}", m.host_overhead());

    high_concurrency_scenario()?;
    chunked_prefill_scenario()?;

    println!("\nAll layers composed: leapbin weights → reference numerics → coordinator ✓");
    Ok(())
}

/// ISSUE 6 tentpole: the declarative scenario harness — run the
/// mixed-length stress script (one 96-token prompt ahead of short sampled
/// requests) with its scripted chunk size and with chunking off, and show
/// the short-request TTFT win. Tokens must be identical in both runs:
/// chunking is a scheduling change, never a numerics change.
fn chunked_prefill_scenario() -> anyhow::Result<()> {
    println!("\n== chunked prefill A/B (scenario harness) ==\n");
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let sc = Scenario::load(root.join("scenarios/mixed_length.scn"))?;
    let (on, off) = sc.run_chunk_ab(Some(&root.join("tests/fixtures/tiny_ref")))?;
    anyhow::ensure!(on.passed() && off.passed(), "scenario expectations failed");

    println!(
        "scenario        : {} ({} sessions, chunk={})",
        on.scenario,
        on.sessions.len(),
        sc.chunk.unwrap_or(0)
    );
    println!(
        "{:<8} {:>13} {:>12} {:>13} {:>9}",
        "session", "prompt_tokens", "ttft_on_ns", "ttft_off_ns", "improved"
    );
    let fmt = |t: Option<u64>| t.map(|v| v.to_string()).unwrap_or_else(|| "-".into());
    for (a, b) in on.sessions.iter().zip(&off.sessions) {
        anyhow::ensure!(a.output == b.output, "session {}: chunking changed tokens", a.index);
        let improved = matches!((a.ttft_ns, b.ttft_ns), (Some(x), Some(y)) if x < y);
        println!(
            "{:<8} {:>13} {:>12} {:>13} {:>9}",
            a.index,
            a.prompt_tokens,
            fmt(a.ttft_ns),
            fmt(b.ttft_ns),
            improved
        );
    }

    let out_dir = root.join("target/scenarios");
    std::fs::create_dir_all(&out_dir)?;
    let out = out_dir.join("mixed_length_ab.json");
    std::fs::write(&out, chunk_ab_json(&on, &off))?;
    println!("✓ identical tokens, chunk-on/off A/B recorded at {}", out.display());
    Ok(())
}

/// ISSUE 4 satellite: more concurrent requests than flat per-session KV
/// could ever hold, served through the paged pool — a shared system-prompt
/// prefix maps every session onto the same physical blocks, and when
/// decode growth still outruns the pool the engine preempts + re-prefills
/// instead of failing.
fn high_concurrency_scenario() -> anyhow::Result<()> {
    println!("\n== high concurrency through the paged KV pool ==\n");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tiny_ref");

    // 16 blocks × 4 tokens = 64 KV positions. Flat per-session KV would
    // fit 64 / 17 = 3 concurrent requests; we serve 12 at once.
    const BLOCKS: usize = 16;
    const BS: usize = 4;
    const REQUESTS: usize = 12;
    const GEN: usize = 6;
    let cfg = KvCacheConfig { block_size: BS, n_blocks: BLOCKS, prefix_sharing: true };
    let backend = ReferenceBackend::load_with_opts(&dir, KernelMode::Fast, Some(cfg))?;

    let mut engine = ServingEngine::new(EngineConfig {
        preset: ModelPreset::Tiny,
        hw: HwParams::default(),
        policy: BatchPolicy { max_batch: REQUESTS, max_total_ctx: 100_000 },
        numerics: Numerics::Backend(Box::new(backend)),
    })?;

    // shared 8-token system prompt + 4 distinct user tokens per request
    let system: Vec<i32> = (0..8).map(|i| (i * 29 + 3) % 512).collect();
    let mut ids = Vec::new();
    for r in 0..REQUESTS as i32 {
        let mut prompt = system.clone();
        prompt.extend((0..4).map(|k| (r * 67 + k * 13 + 40) % 512));
        ids.push(engine.submit(prompt, GEN)?);
    }
    engine.run_until_idle()?;

    let m = &engine.metrics;
    let ctx = 12 + GEN - 1; // cached positions per request
    let private_blocks = REQUESTS * ctx.div_ceil(BS);
    println!("pool            : {BLOCKS} blocks × {BS} tokens (flat KV fits 3 sessions)");
    println!("requests        : {REQUESTS} submitted, {} done, {} failed", m.requests_done, m.requests_failed);
    println!("peak occupancy  : {}/{BLOCKS} blocks (private copies would need {private_blocks})", m.kv_peak_blocks_used);
    println!(
        "prefix sharing  : {:.1}% hit rate ({}/{} probes), {} CoW copies",
        100.0 * m.kv_prefix_hit_rate(),
        m.kv_prefix_hits,
        m.kv_prefix_lookups,
        m.kv_cow_copies
    );
    println!("preemptions     : {} (release → requeue → re-prefill)", m.preemptions);

    anyhow::ensure!(m.requests_done == REQUESTS as u64, "every request must complete");
    anyhow::ensure!(m.kv_prefix_hits > 0, "the shared system prompt must hit the prefix cache");
    anyhow::ensure!(
        m.kv_peak_blocks_used <= BLOCKS,
        "peak occupancy exceeded the pool"
    );
    for id in ids {
        let c = engine.take_completion(id).expect("request done");
        anyhow::ensure!(c.tokens.len() == GEN, "request {} truncated", c.id);
    }
    println!("✓ {REQUESTS} concurrent sessions served through {BLOCKS} pooled blocks");
    Ok(())
}
