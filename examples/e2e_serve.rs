//! END-TO-END DRIVER: serve a real (tiny) Llama-style model through the
//! full three-layer stack and report throughput/latency/energy.
//!
//! This is the composition proof required by DESIGN.md §6:
//!   Pallas kernels (L1, int8 crossbar MVM + context-window-tiled flash
//!   attention) → JAX decoder (L2) → AOT HLO text → Rust PJRT runtime →
//!   serving coordinator + instruction-level/analytical simulators (L3).
//!
//! The generated tokens are REAL model outputs (greedy decode of the AOT
//! artifacts with the quantised weights), self-checked against the golden
//! continuation recorded by python at export time. Timing and energy come
//! from the cycle simulator for the same shapes.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_serve
//!
//! The results are recorded in EXPERIMENTS.md §End-to-end.

use leap::arch::HwParams;
use leap::coordinator::{BatchPolicy, EngineConfig, Numerics, ServingEngine};
use leap::model::ModelPreset;
use leap::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("meta.txt").exists(),
        "artifacts not found — run `make artifacts` first"
    );

    println!("== LEAP end-to-end serving (tiny-llama via PJRT) ==\n");
    let pjrt = Engine::load(&dir)?;
    println!(
        "loaded artifacts: vocab={} d_model={} layers={} (platform: {})",
        pjrt.meta.vocab,
        pjrt.meta.d_model,
        pjrt.meta.n_layers,
        pjrt.platform()
    );

    // --- self-check against the python golden run ------------------------
    let (prompt_t, _, golden_t) = pjrt.golden()?;
    let golden_prompt = prompt_t.as_i32()?;
    let golden_tokens = golden_t.as_i32()?;

    let wall0 = std::time::Instant::now();
    let mut engine = ServingEngine::new(EngineConfig {
        preset: ModelPreset::Tiny,
        hw: HwParams::default(),
        policy: BatchPolicy::default(),
        numerics: Numerics::Pjrt(Box::new(pjrt)),
    })?;

    // request 0: the golden prompt (checked); requests 1..4: variations
    let golden_id = engine.submit(golden_prompt.clone(), golden_tokens.len());
    let mut other_ids = Vec::new();
    for i in 1..4 {
        let prompt: Vec<i32> = golden_prompt.iter().map(|&t| (t + i) % 512).collect();
        other_ids.push(engine.submit(prompt, 8));
    }
    engine.run_until_idle()?;
    let wall = wall0.elapsed();

    let got = engine.take_completion(golden_id).expect("golden request done");
    println!("\ngolden prompt   : {golden_prompt:?}");
    println!("generated       : {:?}", got.tokens);
    println!("expected        : {golden_tokens:?}");
    anyhow::ensure!(
        got.tokens == golden_tokens,
        "generated tokens diverge from the python golden run!"
    );
    println!("✓ rust PJRT generation matches the python golden continuation exactly");

    for id in other_ids {
        let c = engine.take_completion(id).expect("request done");
        println!("request {} → {:?}", c.id, c.tokens);
    }

    // --- serving metrics (simulated timing/energy + host overhead) -------
    let m = &engine.metrics;
    let (lp50, lp99) = m.latency_p50_p99();
    println!("\n-- serving metrics (simulated hardware clock) --");
    println!("requests        : {} done, {} failed", m.requests_done, m.requests_failed);
    println!("tokens          : {} prefill + {} decode", m.prefill_tokens, m.decode_tokens);
    println!("sim time        : {:.3} ms", m.sim_time_ns as f64 * 1e-6);
    println!("throughput      : {:.1} tok/s total, {:.1} tok/s decode", m.total_tokens_per_s(), m.decode_tokens_per_s());
    println!("energy          : {:.6} J → {:.1} tok/J", m.energy_j, m.tokens_per_j());
    println!("latency p50/p99 : {:.3} / {:.3} ms", lp50 as f64 * 1e-6, lp99 as f64 * 1e-6);
    println!("npm bank swaps  : {}", m.npm_swaps);
    println!("\n-- host (L3) overhead --");
    println!("wall time       : {:.1} ms (includes PJRT execution)", wall.as_secs_f64() * 1e3);
    println!("host/sim ratio  : {:.2}", m.host_overhead());
    println!("\nAll three layers composed: Pallas kernel → JAX model → HLO text → PJRT → coordinator ✓");
    Ok(())
}
