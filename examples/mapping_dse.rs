//! Spatial-mapping design-space exploration (paper §III-B + Fig. 8).
//!
//! Enumerates every heuristic-constrained mapping of an attention layer of
//! Llama 3.2-1B onto 1024 macros, scores each by X-Y communication cost,
//! prints the cost histogram, and reports where the paper's Fig. 4 layout
//! falls. Also demonstrates the search-space-reduction arithmetic.
//!
//! Run: `cargo run --release --example mapping_dse`

use leap::mapping::{candidates, explore};

fn main() {
    println!("== spatial mapping DSE (Llama 3.2-1B attention layer, 1024 macros) ==\n");

    // Search-space reduction (§III-B): unconstrained 64P64 for a single
    // 1024×1024 weight vs the constrained candidate count.
    let lg_unconstrained = candidates::log10_unconstrained(64);
    println!("unconstrained mappings of one 1024×1024 weight: 64! ≈ 1e{lg_unconstrained:.1}");

    let res = explore(16, 128, 64);
    let reduction = lg_unconstrained - (res.costs.len() as f64).log10();
    println!("constrained candidates: {} → reduction ≈ 1e{reduction:.0}×", res.costs.len());
    println!("exploration time: {:.2}s (paper budget: 20 s)\n", res.elapsed_s);

    println!("communication-cost distribution (Fig. 8):");
    println!("{}", leap::bench_util::ascii_histogram(&res.histogram(28), 50));

    println!("\nbest cost            : {:>12.0}", res.best_cost());
    println!("paper Fig. 4 mapping : {:>12.0}  (p{:.1} — near-optimal, not absolute min:", res.paper_cost(), res.paper_percentile());
    println!("                        the DSE cost is the coarse X-Y estimate, which ignores");
    println!("                        the fine-grained temporal overlap — exactly the paper's caveat)");

    // Show the winning candidate's structure.
    let best = &res.candidates[res.best];
    println!("\nDSE-optimal candidate: {:?}", best.family);
    for ch in leap::arch::ChannelKind::ALL {
        let l = best.layout(ch);
        println!(
            "  {} channel: origin ({:>2},{:>2}) {}×{} {:?}",
            ch.name(),
            l.region.x0,
            l.region.y0,
            l.region.w,
            l.region.h,
            l.order
        );
    }
}
