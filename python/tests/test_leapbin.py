"""leapbin round-trip + format stability (mirrored by rust runtime tests)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import leapbin


@settings(max_examples=25, deadline=None)
@given(
    ndim=st.integers(1, 4),
    dtype=st.sampled_from([np.float32, np.int8, np.int32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip(tmp_path_factory, ndim, dtype, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 6, size=ndim))
    if dtype == np.float32:
        arr = rng.standard_normal(shape).astype(dtype)
    else:
        arr = rng.integers(-100, 100, size=shape).astype(dtype)
    path = tmp_path_factory.mktemp("bin") / "t.bin"
    leapbin.write(str(path), arr)
    back = leapbin.read(str(path))
    assert back.dtype == arr.dtype and back.shape == arr.shape
    np.testing.assert_array_equal(back, arr)


def test_header_layout(tmp_path):
    arr = np.arange(6, dtype=np.float32).reshape(2, 3)
    p = tmp_path / "h.bin"
    leapbin.write(str(p), arr)
    blob = p.read_bytes()
    assert blob[:4] == b"LEAP"
    assert blob[4] == 1            # version
    assert blob[5] == 0            # f32
    assert blob[6] == 2            # ndim
    assert int.from_bytes(blob[8:12], "little") == 2
    assert int.from_bytes(blob[12:16], "little") == 3
    assert len(blob) == 16 + 6 * 4


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"XXXX" + b"\0" * 16)
    with pytest.raises(AssertionError):
        leapbin.read(str(p))
