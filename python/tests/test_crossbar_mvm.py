"""L1 correctness: crossbar_mvm Pallas kernel vs pure-jnp oracle.

Hypothesis sweeps shapes (including non-multiples of the crossbar size) and
asserts allclose; plus directed edge cases for quantisation behaviour.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import crossbar_mvm as cm
from compile.kernels import ref

ATOL = 1e-4


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 9),
    k=st.integers(1, 300),
    n=st.integers(1, 300),
    xb=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_oracle(m, k, n, xb, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    w_q, scales = cm.quantize_weights(w, xb)
    got = cm.crossbar_matmul(x, w_q, scales, xb)
    want = ref.ref_crossbar_matmul(x, w_q, scales, xb)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 260), n=st.integers(1, 260), seed=st.integers(0, 999))
def test_quantisation_error_bounded(k, n, seed):
    """8-bit cells: dequantised product within ~1% of full precision."""
    x = _rand(seed, (4, k))
    w = _rand(seed + 7, (k, n))
    y = cm.crossbar_linear(x, w)
    yf = x @ w
    scale = float(jnp.max(jnp.abs(yf))) + 1e-6
    assert float(jnp.max(jnp.abs(y - yf))) / scale < 0.02


def test_quantize_shapes_padded():
    w = jnp.ones((200, 300))
    w_q, s = cm.quantize_weights(w, 128)
    assert w_q.shape == (256, 384)
    assert s.shape == (2, 3)
    assert w_q.dtype == jnp.int8


def test_quantize_zero_matrix_safe():
    w = jnp.zeros((128, 128))
    w_q, s = cm.quantize_weights(w, 128)
    assert np.all(np.asarray(w_q) == 0)
    assert np.all(np.asarray(s) == 1.0)  # guard against div-by-zero scales
    x = jnp.ones((2, 128))
    y = cm.crossbar_matmul(x, w_q, s, 128)
    assert np.all(np.asarray(y) == 0)


def test_quantize_per_tile_scales_independent():
    """A huge value in one tile must not destroy precision in another."""
    w = np.zeros((256, 128), np.float32)
    w[:128] = 1000.0   # tile (0,0): large magnitude
    w[128:] = 0.001    # tile (1,0): small magnitude
    w_q, s = cm.quantize_weights(jnp.asarray(w), 128)
    s = np.asarray(s)
    assert s[0, 0] > 1.0 and s[1, 0] < 1.0
    x = jnp.ones((1, 256))
    y = np.asarray(cm.crossbar_matmul(x, w_q, s, 128))
    expect = 128 * 1000.0 + 128 * 0.001
    assert abs(y[0, 0] - expect) / expect < 0.01


def test_identity_roundtrip():
    w = jnp.eye(128)
    x = _rand(3, (5, 128))
    y = cm.crossbar_linear(x, w)
    np.testing.assert_allclose(y, x, atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("xb", [32, 64, 128])
def test_xb_sizes(xb):
    x = _rand(11, (3, xb * 2))
    w = _rand(12, (xb * 2, xb * 3))
    w_q, s = cm.quantize_weights(w, xb)
    got = cm.crossbar_matmul(x, w_q, s, xb)
    want = ref.ref_crossbar_matmul(x, w_q, s, xb)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-4)
