"""L1 correctness: context-window-tiled attention kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_shard as fs
from compile.kernels import ref

ATOL = 2e-5


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


@settings(max_examples=20, deadline=None)
@given(
    nq=st.integers(1, 4),
    nkv=st.integers(1, 8),
    dh=st.sampled_from([16, 32, 64]),
    shard=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_matches_oracle(nq, nkv, dh, shard, seed):
    nkv = max(nkv, nq)  # keys must cover the queries causally
    sq, skv = nq * shard, nkv * shard
    q = _rand(seed, (sq, dh))
    k = _rand(seed + 1, (skv, dh))
    v = _rand(seed + 2, (skv, dh))
    off = jnp.array([0], jnp.int32)
    got = fs.flash_shard_attention(q, k, v, off, shard=shard)
    want = ref.ref_attention(q, k, v, 0)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    pos=st.integers(0, 63),
    dh=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_matches_oracle(pos, dh, seed):
    """Single-Q decode at arbitrary position; cache beyond pos is garbage."""
    shard, skv = 16, 64
    q = _rand(seed, (shard, dh))  # only row 0 meaningful (pipeline padding)
    k = _rand(seed + 1, (skv, dh), scale=3.0)
    v = _rand(seed + 2, (skv, dh), scale=3.0)
    got = fs.flash_shard_attention(q, k, v, jnp.array([pos], jnp.int32),
                                   shard=shard)
    want = ref.ref_attention(q[:1], k, v, pos)
    np.testing.assert_allclose(got[0], want[0], atol=ATOL, rtol=1e-4)


def test_causality_strict():
    """Perturbing future keys/values must not change earlier outputs."""
    shard = 16
    q = _rand(0, (32, 32))
    k = _rand(1, (32, 32))
    v = _rand(2, (32, 32))
    off = jnp.array([0], jnp.int32)
    base = fs.flash_shard_attention(q, k, v, off, shard=shard)
    k2 = k.at[20:].set(99.0)
    v2 = v.at[20:].set(-99.0)
    pert = fs.flash_shard_attention(q, k2, v2, off, shard=shard)
    np.testing.assert_allclose(base[:20], pert[:20], atol=1e-6)
    assert not np.allclose(base[20:], pert[20:])


def test_noncausal_mode():
    q = _rand(5, (16, 32))
    k = _rand(6, (32, 32))
    v = _rand(7, (32, 32))
    off = jnp.array([0], jnp.int32)
    got = fs.flash_shard_attention(q, k, v, off, shard=16, causal=False)
    want = ref.ref_attention(q, k, v, 0, causal=False)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-4)


def test_numerical_stability_large_scores():
    """Online softmax must survive score magnitudes that overflow naive exp."""
    q = jnp.full((16, 32), 30.0)
    k = jnp.full((32, 32), 30.0)
    v = _rand(8, (32, 32))
    off = jnp.array([0], jnp.int32)
    got = fs.flash_shard_attention(q, k, v, off, shard=16)
    assert np.all(np.isfinite(np.asarray(got)))
    want = ref.ref_attention(q, k, v, 0)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_mha_vmap_consistency():
    qh = _rand(0, (4, 32, 64))
    kh = _rand(1, (4, 32, 64))
    vh = _rand(2, (4, 32, 64))
    off = jnp.array([0], jnp.int32)
    got = fs.mha_flash(qh, kh, vh, off)
    for h in range(4):
        want = ref.ref_attention(qh[h], kh[h], vh[h], 0)
        np.testing.assert_allclose(got[h], want, atol=ATOL, rtol=1e-4)


def test_gqa_by_duplication():
    """Paper: GQA degrades to MHA by duplicating K/V matrices."""
    n_heads, n_kv, dh = 8, 2, 32
    qh = _rand(0, (n_heads, 16, dh))
    kkv = _rand(1, (n_kv, 16, dh))
    vkv = _rand(2, (n_kv, 16, dh))
    rep = n_heads // n_kv
    kh = jnp.repeat(kkv, rep, axis=0)
    vh = jnp.repeat(vkv, rep, axis=0)
    off = jnp.array([0], jnp.int32)
    got = fs.mha_flash(qh, kh, vh, off)
    for h in range(n_heads):
        want = ref.ref_attention(qh[h], kkv[h // rep], vkv[h // rep], 0)
        np.testing.assert_allclose(got[h], want, atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("shard", [8, 16, 32])
def test_shard_size_invariance(shard):
    """Output must be independent of the tiling factor C_S."""
    q = _rand(3, (64, 32))
    k = _rand(4, (64, 32))
    v = _rand(5, (64, 32))
    off = jnp.array([0], jnp.int32)
    got = fs.flash_shard_attention(q, k, v, off, shard=shard)
    want = ref.ref_attention(q, k, v, 0)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-4)
