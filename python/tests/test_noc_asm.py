"""NoC-ISA python assembler: wire-format pinned against the Rust encoder."""

from compile import noc_asm
from compile.noc_asm import Op, Program, Sel


def test_golden_bytes_match_rust():
    """These constants are asserted identically in
    rust/src/isa/encode.rs::tests::golden_hex_stable — a change on either
    side must update both."""
    hexes = [l for l in noc_asm.demo_program().assemble().splitlines()
             if not l.startswith(";")]
    assert hexes[0] == "10000000040000000000000000000000"
    assert hexes[1] == "02010a00200004000000020002000400"
    assert len(hexes) == 5  # 4 + HALT


def test_instruction_size():
    p = Program().uni(Op.NOP, 0, 1, Sel.all())
    assert len(p.instrs[0].encode()) == noc_asm.INSTR_BYTES


def test_sealed_idempotent():
    p = Program().uni(Op.MAC, 0, 3, Sel.rows(0, 2)).sealed().sealed()
    assert len(p.instrs) == 2
    assert p.instrs[-1].cmd1[0] == Op.HALT


def test_sel_encodings_distinct():
    encs = set()
    for sel in [Sel.all(), Sel.rows(0, 1), Sel.cols(0, 1), Sel.rect(0, 1, 0, 1),
                Sel.split_rows(0, 1, 1, 2)]:
        p = Program().uni(Op.NOP, 0, 1, sel)
        encs.add(p.instrs[0].encode())
    assert len(encs) == 5


def test_opcode_values_stable():
    assert Op.NOP == 0x00
    assert Op.MAC == 0x0A
    assert Op.HALT == 0x12
    assert len(Op) == 19
