"""L2 correctness: quantised-kernel model vs pure-jnp float oracle, and
prefill/decode consistency (the property the Rust serving loop relies on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.TINY


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(CFG, seed=0)


@pytest.fixture(scope="module")
def params(weights):
    return M.params_as_tuple(M.quantize_model(weights, CFG))


def test_prefill_matches_float_oracle(weights, params):
    toks = jnp.arange(32, dtype=jnp.int32) % CFG.vocab
    logits, _, _ = M.prefill(toks, *params, cfg=CFG)
    want = M.ref_forward(toks, weights, CFG)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(logits / scale, want / scale, atol=2e-5)


def test_prefill_then_decode_consistent(params):
    """decode_step(pos=n) after prefill(n tokens) must equal prefill(n+1)."""
    toks = (jnp.arange(32, dtype=jnp.int32) * 7 + 3) % CFG.vocab
    logits_a, kc, vc = M.prefill(toks, *params, cfg=CFG)

    # prefill the first 16 tokens only (pad the rest), then decode token 16.
    toks_b = toks.at[16:].set(0)
    _, kcb, vcb = M.prefill(toks_b, *params, cfg=CFG)
    lg, _, _ = M.decode_step(toks[16:17], jnp.int32(16), kcb, vcb, *params,
                             cfg=CFG)
    # logits for position 16 from the full prefill vs the decode path:
    np.testing.assert_allclose(lg[0], logits_a[16], atol=3e-4, rtol=1e-3)


def test_decode_updates_cache_in_place(params):
    toks = jnp.zeros(32, jnp.int32)
    _, kc, vc = M.prefill(toks, *params, cfg=CFG)
    _, kc2, vc2 = M.decode_step(jnp.array([5], jnp.int32), jnp.int32(32),
                                kc, vc, *params, cfg=CFG)
    # only row 32 of each layer's cache may change
    k_old, k_new = np.asarray(kc), np.asarray(kc2)
    changed = np.any(k_old != k_new, axis=2)  # [L, S_max]
    assert changed[:, 32].all()
    assert not changed[:, :32].any()
    assert not changed[:, 33:].any()


def test_causal_prefill_prefix_stability(params):
    """Changing later prompt tokens must not change earlier logits."""
    t1 = jnp.arange(32, dtype=jnp.int32) % CFG.vocab
    t2 = t1.at[20:].set(99)
    l1, _, _ = M.prefill(t1, *params, cfg=CFG)
    l2, _, _ = M.prefill(t2, *params, cfg=CFG)
    np.testing.assert_allclose(l1[:20], l2[:20], atol=1e-5)
    assert not np.allclose(l1[20:], l2[20:])


def test_logits_finite(params):
    toks = jnp.full((32,), CFG.vocab - 1, jnp.int32)
    logits, kc, vc = M.prefill(toks, *params, cfg=CFG)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert np.all(np.isfinite(np.asarray(kc)))


def test_param_order_stable():
    """The Rust runtime hard-codes this calling convention."""
    assert M.PARAM_ORDER == ("embed", "attn_q", "attn_s", "gu_q", "gu_s",
                             "down_q", "down_s", "norms", "final_norm")
