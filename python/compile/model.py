"""L2: Llama-style decoder model in JAX, built on the L1 kernels.

Build-time only. `aot.py` lowers `prefill` and `decode_step` once to HLO text;
the Rust runtime (rust/src/runtime) loads and executes them on the request
path, so Python never serves a request.

All projection / MLP matmuls go through the PIM crossbar kernel
(`crossbar_matmul`, int8 cells + per-tile scales — the DSMM path mapped to
PEs); attention score/context matmuls go through the context-window-tiled
flash kernel (the DDMM path mapped to IRCUs). This mirrors the paper's
static-vs-dynamic split exactly.

The tiny config used for the end-to-end artifacts keeps shapes small enough
that interpret-mode Pallas lowers and compiles in seconds, while exercising
the same code paths as the Llama presets in rust/src/model.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import crossbar_mvm as cm
from .kernels import flash_shard as fs
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Shape configuration (mirrors rust/src/model/presets.rs)."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 4          # tiny model is MHA; GQA duplicates K/V
    d_ff: int = 512
    xb: int = 128                # crossbar array size (Table I)
    shard: int = 16              # context-window shard rows C_S
    rope_theta: float = 10000.0
    eps: float = 1e-5

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


TINY = ModelConfig()


# ---------------------------------------------------------------------------
# Weight construction + quantisation (build-time)
# ---------------------------------------------------------------------------

def init_weights(cfg: ModelConfig, seed: int = 0):
    """Seeded float weights as a dict of stacked per-layer arrays."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    d, h, l, v = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    scale = d ** -0.5
    return {
        "embed": jax.random.normal(ks[0], (v, d), jnp.float32) * scale,
        # Wq, Wk, Wv, Wo stacked: [L, 4, D, D]
        "attn": jax.random.normal(ks[1], (l, 4, d, d), jnp.float32) * scale,
        # gate, up: [L, 2, D, H]
        "gu": jax.random.normal(ks[2], (l, 2, d, h), jnp.float32) * scale,
        # down: [L, H, D]
        "down": jax.random.normal(ks[3], (l, h, d), jnp.float32) * (h ** -0.5),
        # attn-norm, mlp-norm gains: [L, 2, D]
        "norms": jnp.ones((l, 2, d), jnp.float32),
        "final_norm": jnp.ones((d,), jnp.float32),
    }


def quantize_model(w: dict, cfg: ModelConfig):
    """Quantise every static projection into 8-bit crossbar tiles.

    Returns the runtime parameter dict passed (from Rust) to prefill/decode:
    int8 cell tensors + f32 per-tile scales, plus the f32 non-PIM params.
    """
    xb = cfg.xb

    def qstack(ws):  # ws: [..., K, N] stacked weights
        flat = ws.reshape((-1,) + ws.shape[-2:])
        qs, ss = [], []
        for i in range(flat.shape[0]):
            q, s = cm.quantize_weights(flat[i], xb)
            qs.append(q)
            ss.append(s)
        q = jnp.stack(qs).reshape(ws.shape[:-2] + qs[0].shape)
        s = jnp.stack(ss).reshape(ws.shape[:-2] + ss[0].shape)
        return q, s

    attn_q, attn_s = qstack(w["attn"])
    gu_q, gu_s = qstack(w["gu"])
    down_q, down_s = qstack(w["down"])
    return {
        "embed": w["embed"],
        "attn_q": attn_q, "attn_s": attn_s,
        "gu_q": gu_q, "gu_s": gu_s,
        "down_q": down_q, "down_s": down_s,
        "norms": w["norms"], "final_norm": w["final_norm"],
    }


# Ordered parameter list = the Rust runtime's calling convention.
PARAM_ORDER = ("embed", "attn_q", "attn_s", "gu_q", "gu_s", "down_q",
               "down_s", "norms", "final_norm")


def params_as_tuple(p: dict):
    return tuple(p[k] for k in PARAM_ORDER)


# ---------------------------------------------------------------------------
# Layer computation
# ---------------------------------------------------------------------------

def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    s, d = x.shape
    return x.reshape(s, n_heads, d // n_heads).transpose(1, 0, 2)  # [H, S, dh]


def _merge_heads(x: jax.Array) -> jax.Array:
    h, s, dh = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * dh)


def _proj(x: jax.Array, w_q: jax.Array, s: jax.Array, cfg: ModelConfig,
          n_out: int) -> jax.Array:
    """DSMM on the PIM path: x [S, K] -> [S, n_out]."""
    return cm.crossbar_matmul(x, w_q, s, cfg.xb)[:, :n_out]


def attention_block(x, layer_attn_q, layer_attn_s, norm_g, kcache, vcache,
                    pos0, cfg: ModelConfig, causal_offset):
    """One attention sub-layer over `x` [S, D] with KV written at pos0..pos0+S.

    Returns (out [S, D], kcache', vcache'). Caches are [S_max, D].
    """
    d = cfg.d_model
    xn = ref.ref_rmsnorm(x, norm_g, cfg.eps)
    q = _proj(xn, layer_attn_q[0], layer_attn_s[0], cfg, d)
    k = _proj(xn, layer_attn_q[1], layer_attn_s[1], cfg, d)
    v = _proj(xn, layer_attn_q[2], layer_attn_s[2], cfg, d)

    s = x.shape[0]
    positions = pos0 + jnp.arange(s, dtype=jnp.int32)
    qh = ref.ref_rope(_split_heads(q, cfg.n_heads), positions, cfg.rope_theta)
    kh = ref.ref_rope(_split_heads(k, cfg.n_heads), positions, cfg.rope_theta)
    vh = _split_heads(v, cfg.n_heads)

    kcache = jax.lax.dynamic_update_slice(kcache, _merge_heads(kh), (pos0, 0))
    vcache = jax.lax.dynamic_update_slice(vcache, _merge_heads(vh), (pos0, 0))

    kall = _split_heads(kcache, cfg.n_heads)  # [H, S_max, dh]
    vall = _split_heads(vcache, cfg.n_heads)
    # DDMM on the IRCU path: context-window-tiled attention (Fig. 5 dataflow).
    # Decode feeds a single Q row; pad it to a whole shard (the idle rows are
    # exactly the underutilised Q-channel pipeline slots of section IV-C) and
    # discard the padding after the kernel.
    s_pad = (-s) % cfg.shard
    qh_p = jnp.pad(qh, ((0, 0), (0, s_pad), (0, 0))) if s_pad else qh
    oh = fs.mha_flash(qh_p, kall, vall, causal_offset, shard=cfg.shard)
    o = _merge_heads(oh[:, :s])
    out = _proj(o, layer_attn_q[3], layer_attn_s[3], cfg, d)
    return x + out, kcache, vcache


def mlp_block(x, gu_q, gu_s, down_q, down_s, norm_g, cfg: ModelConfig):
    """SwiGLU MLP, all three matmuls on the PIM path."""
    xn = ref.ref_rmsnorm(x, norm_g, cfg.eps)
    gate = _proj(xn, gu_q[0], gu_s[0], cfg, cfg.d_ff)
    up = _proj(xn, gu_q[1], gu_s[1], cfg, cfg.d_ff)
    h = jax.nn.silu(gate) * up
    return x + _proj(h, down_q, down_s, cfg, cfg.d_model)


def _forward(tokens, params, kcache, vcache, pos0, cfg: ModelConfig,
             causal_offset):
    """Shared prefill/decode body. tokens [S] int32; caches [L, S_max, D]."""
    (embed, attn_q, attn_s, gu_q, gu_s, down_q, down_s, norms,
     final_norm) = params
    x = embed[tokens]  # [S, D]

    new_k, new_v = [], []
    for layer in range(cfg.n_layers):
        x, kc, vc = attention_block(
            x, attn_q[layer], attn_s[layer], norms[layer, 0],
            kcache[layer], vcache[layer], pos0, cfg, causal_offset)
        x = mlp_block(x, gu_q[layer], gu_s[layer], down_q[layer],
                      down_s[layer], norms[layer, 1], cfg)
        new_k.append(kc)
        new_v.append(vc)

    x = ref.ref_rmsnorm(x, final_norm, cfg.eps)
    logits = x @ embed.T  # tied LM head (digital, not PIM: dynamic @ static^T
    # of the embedding — the paper keeps the sampling head off-chip)
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def prefill(tokens, *params, cfg: ModelConfig = TINY, s_max: int = 128):
    """Prefill S tokens from scratch. Returns (logits [S, V], k/v caches)."""
    l, d = cfg.n_layers, cfg.d_model
    kc = jnp.zeros((l, s_max, d), jnp.float32)
    vc = jnp.zeros((l, s_max, d), jnp.float32)
    off = jnp.array([0], jnp.int32)
    return _forward(tokens, params, kc, vc, 0, cfg, off)


def decode_step(token, pos, kcache, vcache, *params, cfg: ModelConfig = TINY):
    """One decode step. token [1] int32, pos [] int32, caches [L, S_max, D].

    Returns (logits [1, V], kcache', vcache').
    """
    off = pos.reshape(1).astype(jnp.int32)
    return _forward(token, params, kcache, vcache, pos, cfg, off)


# ---------------------------------------------------------------------------
# Pure-jnp golden model (oracle for tests: no pallas, no quantisation split)
# ---------------------------------------------------------------------------

def ref_forward(tokens, w: dict, cfg: ModelConfig, s_max: int = 128):
    """Float-weight oracle of prefill (quantisation applied via dequant so the
    kernel path and the oracle share the same effective weights)."""
    p = quantize_model(w, cfg)

    def deq(qs, ss, k_logical, n_logical):
        return ref.ref_dequant(qs, ss, cfg.xb)[:k_logical, :n_logical]

    d, h = cfg.d_model, cfg.d_ff
    x = p["embed"][tokens]
    s = tokens.shape[0]
    positions = jnp.arange(s, dtype=jnp.int32)
    for layer in range(cfg.n_layers):
        xn = ref.ref_rmsnorm(x, p["norms"][layer, 0], cfg.eps)
        wq = [deq(p["attn_q"][layer, i], p["attn_s"][layer, i], d, d)
              for i in range(4)]
        q = ref.ref_rope(_split_heads(xn @ wq[0], cfg.n_heads), positions,
                         cfg.rope_theta)
        k = ref.ref_rope(_split_heads(xn @ wq[1], cfg.n_heads), positions,
                         cfg.rope_theta)
        v = _split_heads(xn @ wq[2], cfg.n_heads)
        o = ref.ref_mha(q, k, v, 0)
        x = x + _merge_heads(o) @ wq[3]
        xn = ref.ref_rmsnorm(x, p["norms"][layer, 1], cfg.eps)
        gate = xn @ deq(p["gu_q"][layer, 0], p["gu_s"][layer, 0], d, h)
        up = xn @ deq(p["gu_q"][layer, 1], p["gu_s"][layer, 1], d, h)
        x = x + (jax.nn.silu(gate) * up) @ deq(p["down_q"][layer],
                                               p["down_s"][layer], h, d)
    x = ref.ref_rmsnorm(x, p["final_norm"], cfg.eps)
    return x @ p["embed"].T
