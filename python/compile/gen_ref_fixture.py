"""Generate the checked-in reference-numerics fixture for the Rust tests.

Produces, under --out (default rust/tests/fixtures/tiny_ref):
  meta.txt               same key=value format as aot.py emits
  weights/<name>.bin     quantised Tiny weights in model.PARAM_ORDER (leapbin)
  golden/prompt.bin      the golden prompt token ids (i32)
  golden/prefill_logits.bin  last-row prefill logits from the jnp float
                         oracle (model.ref_forward, built on kernels/ref.py)
  golden/greedy_tokens.bin   greedy continuation of the prompt (i32)

The Rust `runtime::reference` backend loads the same weights and must
reproduce prefill_logits within 1e-4 and the greedy continuation exactly
(tests/integration_reference.rs). Unlike aot.py this needs no Pallas
lowering and no PJRT — it is pure jnp, so it runs anywhere JAX does.

Run from python/:  python -m compile.gen_ref_fixture
"""

from __future__ import annotations

import argparse
import os

import jax.numpy as jnp
import numpy as np

from . import leapbin
from . import model as M

GOLDEN_PROMPT = [5, 17, 3, 101, 42, 7, 250, 11]
GOLDEN_STEPS = 8
S_PRE = 32
S_MAX = 128


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../rust/tests/fixtures/tiny_ref")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = args.out
    os.makedirs(f"{out}/weights", exist_ok=True)
    os.makedirs(f"{out}/golden", exist_ok=True)

    cfg = M.TINY
    w = M.init_weights(cfg, seed=args.seed)
    params = M.quantize_model(w, cfg)

    for name in M.PARAM_ORDER:
        leapbin.write(f"{out}/weights/{name}.bin", np.asarray(params[name]))
    print(f"wrote {len(M.PARAM_ORDER)} weight tensors")

    # Greedy continuation by full re-forward: for causal attention the last
    # row of prefill(prompt + generated) equals the incremental decode step,
    # so the oracle needs no KV cache.
    prompt = list(GOLDEN_PROMPT)
    seq = list(prompt)
    logits = M.ref_forward(jnp.asarray(seq, jnp.int32), w, cfg)
    leapbin.write(f"{out}/golden/prompt.bin", np.asarray(prompt, np.int32))
    leapbin.write(f"{out}/golden/prefill_logits.bin",
                  np.asarray(logits[len(prompt) - 1], np.float32))

    gen = []
    margins = []
    for _ in range(GOLDEN_STEPS):
        row = np.asarray(logits[-1], np.float64)
        order = np.argsort(row)
        margins.append(float(row[order[-1]] - row[order[-2]]))
        nxt = int(order[-1])
        gen.append(nxt)
        seq.append(nxt)
        logits = M.ref_forward(jnp.asarray(seq, jnp.int32), w, cfg)
    leapbin.write(f"{out}/golden/greedy_tokens.bin", np.asarray(gen, np.int32))
    print(f"golden greedy continuation: {gen}")
    print(f"top-2 logit margins per step: {[round(m, 4) for m in margins]}")
    assert min(margins) > 1e-3, (
        f"argmax margin {min(margins)} too small for a stable cross-impl "
        "golden; regenerate with a different --seed")

    with open(f"{out}/meta.txt", "w") as f:
        f.write(f"vocab={cfg.vocab}\nd_model={cfg.d_model}\n")
        f.write(f"n_layers={cfg.n_layers}\nn_heads={cfg.n_heads}\n")
        f.write(f"n_kv_heads={cfg.n_kv_heads}\nd_ff={cfg.d_ff}\n")
        f.write(f"xb={cfg.xb}\nshard={cfg.shard}\n")
        f.write(f"s_prefill={S_PRE}\ns_max={S_MAX}\n")
        f.write(f"golden_prompt_len={len(prompt)}\ngolden_steps={GOLDEN_STEPS}\n")
        f.write("param_order=" + ",".join(M.PARAM_ORDER) + "\n")
    print("wrote meta.txt")


if __name__ == "__main__":
    main()
