"""AOT export: lower the L2 model (embedding the L1 Pallas kernels) to HLO
text artifacts that the Rust runtime loads via PJRT.

HLO *text* (never `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Emitted into --out (default ../artifacts):
  tiny_prefill.hlo.txt   prefill(tokens[S_PRE], 9 params) -> (logits, K, V)
  tiny_decode.hlo.txt    decode(token[1], pos, K, V, 9 params) -> (logits, K, V)
  xbar_demo.hlo.txt      standalone crossbar_matmul (runtime smoke test)
  weights/<name>.bin     leapbin tensors in model.PARAM_ORDER
  golden/*.bin           prompt, expected prefill logits, greedy continuation
  meta.txt               key=value shape metadata consumed by rust/src/runtime

Python runs ONCE at build time (make artifacts); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import leapbin
from . import model as M

S_PRE = 32     # fixed prefill window of the tiny artifact
S_MAX = 128    # KV-cache capacity
GOLDEN_PROMPT = [5, 17, 3, 101, 42, 7, 250, 11]  # len 8, padded to S_PRE
GOLDEN_STEPS = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _prefill_fn(tokens, *params):
    return M.prefill(tokens, *params, cfg=M.TINY, s_max=S_MAX)


def _decode_fn(token, pos, kc, vc, *params):
    return M.decode_step(token, pos, kc, vc, *params, cfg=M.TINY)


def _xbar_demo_fn(x, w_q, scales):
    from .kernels import crossbar_mvm as cm

    return (cm.crossbar_matmul(x, w_q, scales, cm.DEFAULT_XB),)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    os.makedirs(f"{out}/weights", exist_ok=True)
    os.makedirs(f"{out}/golden", exist_ok=True)

    cfg = M.TINY
    w = M.init_weights(cfg, seed=args.seed)
    params = M.quantize_model(w, cfg)
    pt = M.params_as_tuple(params)

    # ---- lower the two model entry points --------------------------------
    tok_spec = jax.ShapeDtypeStruct((S_PRE,), jnp.int32)
    p_specs = tuple(jax.ShapeDtypeStruct(p.shape, p.dtype) for p in pt)
    lowered_pre = jax.jit(_prefill_fn).lower(tok_spec, *p_specs)
    with open(f"{out}/tiny_prefill.hlo.txt", "w") as f:
        f.write(to_hlo_text(lowered_pre))
    print("wrote tiny_prefill.hlo.txt")

    tok1 = jax.ShapeDtypeStruct((1,), jnp.int32)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    cache = jax.ShapeDtypeStruct((cfg.n_layers, S_MAX, cfg.d_model), jnp.float32)
    lowered_dec = jax.jit(_decode_fn).lower(tok1, pos_s, cache, cache, *p_specs)
    with open(f"{out}/tiny_decode.hlo.txt", "w") as f:
        f.write(to_hlo_text(lowered_dec))
    print("wrote tiny_decode.hlo.txt")

    # ---- standalone kernel demo (runtime smoke test) ---------------------
    from .kernels import crossbar_mvm as cm

    xd = jax.ShapeDtypeStruct((8, 256), jnp.float32)
    wd = jax.ShapeDtypeStruct((256, 256), jnp.int8)
    sd = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered_xb = jax.jit(_xbar_demo_fn).lower(xd, wd, sd)
    with open(f"{out}/xbar_demo.hlo.txt", "w") as f:
        f.write(to_hlo_text(lowered_xb))
    print("wrote xbar_demo.hlo.txt")

    # ---- weights ----------------------------------------------------------
    for name in M.PARAM_ORDER:
        leapbin.write(f"{out}/weights/{name}.bin", np.asarray(params[name]))
    print(f"wrote {len(M.PARAM_ORDER)} weight tensors")

    # ---- golden run (computed with the exact lowered functions) ----------
    prompt = np.array(GOLDEN_PROMPT, np.int32)
    plen = len(prompt)
    toks = np.zeros(S_PRE, np.int32)
    toks[:plen] = prompt
    logits, kc, vc = jax.jit(_prefill_fn)(jnp.asarray(toks), *pt)
    leapbin.write(f"{out}/golden/prompt.bin", prompt)
    leapbin.write(f"{out}/golden/prefill_logits.bin",
                  np.asarray(logits[plen - 1]))

    dec = jax.jit(_decode_fn)
    cur = int(jnp.argmax(logits[plen - 1]))
    pos = plen
    gen = [cur]
    for _ in range(GOLDEN_STEPS - 1):
        lg, kc, vc = dec(jnp.array([cur], jnp.int32), jnp.int32(pos), kc, vc, *pt)
        cur = int(jnp.argmax(lg[0]))
        gen.append(cur)
        pos += 1
    leapbin.write(f"{out}/golden/greedy_tokens.bin", np.array(gen, np.int32))
    print(f"golden greedy continuation: {gen}")

    # ---- metadata ----------------------------------------------------------
    with open(f"{out}/meta.txt", "w") as f:
        f.write(f"vocab={cfg.vocab}\nd_model={cfg.d_model}\n")
        f.write(f"n_layers={cfg.n_layers}\nn_heads={cfg.n_heads}\n")
        f.write(f"n_kv_heads={cfg.n_kv_heads}\nd_ff={cfg.d_ff}\n")
        f.write(f"xb={cfg.xb}\nshard={cfg.shard}\n")
        f.write(f"s_prefill={S_PRE}\ns_max={S_MAX}\n")
        f.write(f"golden_prompt_len={plen}\ngolden_steps={GOLDEN_STEPS}\n")
        f.write("param_order=" + ",".join(M.PARAM_ORDER) + "\n")
    print("wrote meta.txt")


if __name__ == "__main__":
    main()
