"""L1 Pallas kernel: context-window-tiled attention (LEAP Fig. 5).

LEAP adopts FlashAttention's nested-loop structure with three distinctions
(paper section IV-A):

  (i)  Q/K/V are partitioned into *shards* of C_S rows (C_S = 2*N_r =
       ceil(D/C)); each shard's rows are distributed across the routers of an
       RPU group — here a shard is one BlockSpec block and the scratchpad
       layout of Fig. 5(c) is the HBM->VMEM schedule.
  (ii) the inner (Q) loop is spatially unrolled across RPUs — here it is the
       parallel grid dimension;
  (iii) the outer (K/V) loop is a rotational broadcast across the RG — here
       it is the sequential fori_loop inside the kernel, which consumes one
       K/V shard per iteration exactly as one rotation step delivers it.

Online softmax state (running row-max m, normaliser l, accumulator O) is the
same intermediate set the paper holds in the O-channel scratchpad.
interpret=True: real-TPU lowering emits Mosaic custom-calls the CPU PJRT
plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default shard height: C_S = ceil(D/C) = 16 for Llama 3.2-1B (Table I).
DEFAULT_SHARD = 16
_NEG_INF = -1e30


def _attn_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, *, shard: int,
                 sm_scale: float, causal: bool):
    """One Q shard (grid dim 0) against all K/V shards (rotational loop)."""
    qi = pl.program_id(0)
    q = q_ref[...]  # [shard, dh]
    skv = k_ref.shape[0]
    n_kv = skv // shard
    offset = off_ref[0]

    # Global row index of each Q row: prefill uses offset=0; decode passes
    # offset=pos so the single query row attends to cache slots 0..pos.
    rows = qi * shard + jax.lax.broadcasted_iota(jnp.int32, (shard, 1), 0) + offset

    def body(s, carry):
        m_i, l_i, acc = carry
        k_blk = pl.load(k_ref, (pl.ds(s * shard, shard), slice(None)))
        v_blk = pl.load(v_ref, (pl.ds(s * shard, shard), slice(None)))
        scores = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        scores = scores * sm_scale
        cols = s * shard + jax.lax.broadcasted_iota(jnp.int32, (1, shard), 1)
        if causal:
            mask = cols <= rows
            scores = jnp.where(mask, scores, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(scores, axis=1, keepdims=True))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v_blk, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    dh = q_ref.shape[1]
    m0 = jnp.full((shard, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((shard, 1), jnp.float32)
    a0 = jnp.zeros((shard, dh), jnp.float32)
    m_f, l_f, acc_f = jax.lax.fori_loop(0, n_kv, body, (m0, l0, a0))
    # Rows that saw no unmasked key (padding rows ahead of `offset` in a
    # padded prefill) keep l == 0 after the exp(-inf) underflow; emit zeros.
    safe_l = jnp.where(l_f > 0, l_f, 1.0)
    o_ref[...] = (acc_f / safe_l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("shard", "sm_scale", "causal"))
def flash_shard_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                          offset: jax.Array, shard: int = DEFAULT_SHARD,
                          sm_scale: float | None = None,
                          causal: bool = True) -> jax.Array:
    """Single-head tiled attention. q: [Sq, dh]; k, v: [Skv, dh].

    `offset` is a [1] int32 array: global position of q row 0 (0 for prefill;
    the current decode position for a 1-row q). Sq and Skv must be multiples
    of `shard` — the model layer pads, matching the paper's requirement that
    the context window is a whole number of shards per scratchpad column.
    """
    sq, dh = q.shape
    skv = k.shape[0]
    assert sq % shard == 0 and skv % shard == 0, (sq, skv, shard)
    if sm_scale is None:
        sm_scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_attn_kernel, shard=shard,
                               sm_scale=float(sm_scale), causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(sq // shard,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),          # offset scalar
            pl.BlockSpec((shard, dh), lambda i: (i, 0)),
            pl.BlockSpec((skv, dh), lambda i: (0, 0)),
            pl.BlockSpec((skv, dh), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((shard, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, dh), jnp.float32),
        interpret=True,
    )(offset, q, k, v)


def mha_flash(q: jax.Array, k: jax.Array, v: jax.Array, offset: jax.Array,
              shard: int = DEFAULT_SHARD, causal: bool = True) -> jax.Array:
    """Multi-head wrapper: q/k/v [H, S, dh] -> [H, Sq, dh] via vmap.

    GQA callers duplicate K/V heads first (the paper: "GQA can degrade to
    this scheme by matrix duplication").
    """
    fn = functools.partial(flash_shard_attention, shard=shard, causal=causal)
    return jax.vmap(fn, in_axes=(0, 0, 0, None))(q, k, v, offset)
