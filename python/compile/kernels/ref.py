"""Pure-jnp oracles for the L1 kernels — the build-time correctness signal.

Every Pallas kernel is asserted allclose against these references by
python/tests (hypothesis sweeps over shapes/dtypes). No pallas imports here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def ref_dequant(w_q: jax.Array, scales: jax.Array, xb: int) -> jax.Array:
    """Expand per-tile scales and dequantise the int8 crossbar cells."""
    kp, np_ = w_q.shape
    kt, nt = kp // xb, np_ // xb
    s_full = jnp.repeat(jnp.repeat(scales, xb, axis=0), xb, axis=1)
    assert s_full.shape == (kp, np_), (s_full.shape, w_q.shape, (kt, nt))
    return w_q.astype(jnp.float32) * s_full


def ref_crossbar_matmul(x: jax.Array, w_q: jax.Array, scales: jax.Array,
                        xb: int) -> jax.Array:
    """y = x_padded @ dequant(w_q) — the whole-matrix view of the tile sum."""
    kp = w_q.shape[0]
    if x.shape[1] < kp:
        x = jnp.pad(x, ((0, 0), (0, kp - x.shape[1])))
    return x @ ref_dequant(w_q, scales, xb)


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array, offset: int,
                  sm_scale: float | None = None,
                  causal: bool = True) -> jax.Array:
    """Vanilla materialised-S softmax attention (single head).

    q: [Sq, dh], k/v: [Skv, dh]; q row i has global position i + offset.
    """
    sq, dh = q.shape
    skv = k.shape[0]
    if sm_scale is None:
        sm_scale = 1.0 / (dh ** 0.5)
    scores = (q @ k.T) * sm_scale
    if causal:
        rows = jnp.arange(sq)[:, None] + offset
        cols = jnp.arange(skv)[None, :]
        scores = jnp.where(cols <= rows, scores, _NEG_INF)
    # Guard fully-masked rows (padding): emit zeros like the kernel.
    m = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    out = (p @ v) / jnp.where(l > 0, l, 1.0)
    any_valid = (jnp.max(scores, axis=1, keepdims=True) > _NEG_INF / 2)
    return jnp.where(any_valid, out, 0.0)


def ref_mha(q: jax.Array, k: jax.Array, v: jax.Array, offset: int,
            causal: bool = True) -> jax.Array:
    return jax.vmap(
        lambda qq, kk, vv: ref_attention(qq, kk, vv, offset, causal=causal)
    )(q, k, v)


def ref_rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def ref_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: [H, S, dh] (dh even), positions: [S] int32."""
    h, s, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def ref_swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
