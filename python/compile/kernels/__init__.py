"""LEAP L1 Pallas kernels (build-time only; never on the request path)."""

from .crossbar_mvm import (  # noqa: F401
    DEFAULT_XB,
    crossbar_linear,
    crossbar_matmul,
    quantize_weights,
)
from .flash_shard import (  # noqa: F401
    DEFAULT_SHARD,
    flash_shard_attention,
    mha_flash,
)
