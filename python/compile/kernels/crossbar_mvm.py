"""L1 Pallas kernel: PIM crossbar DSMM (dynamic activation x static weight).

Models LEAP's PIM processing elements: the static weight matrix is
partitioned into C x C crossbar tiles (C = 128 in the paper, Table I), each
tile's weights are quantised to 8-bit cells with a per-tile symmetric scale
(the analog array computes with integer conductance levels; the ADC output is
rescaled digitally), and the per-tile partial results are aggregated across
the K dimension exactly as Reduction 1 aggregates partial sums across an RPU
group.

Hardware adaptation (DESIGN.md section Hardware-Adaptation): one crossbar tile
= one BlockSpec block; the grid's k dimension plays the role of the RG
reduction; the MXU-shaped (C x C) `dot` stands in for the crossbar's analog
MVM. interpret=True everywhere — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Crossbar array width/height (Table I: "XB size 128x128").
DEFAULT_XB = 128
# 8-bit cell -> symmetric int8 levels.
CELL_LEVELS = 127.0


def pad_to_multiple(a: jax.Array, mult: int, axes: tuple[int, ...]) -> jax.Array:
    """Zero-pad `a` so the given axes are multiples of `mult`."""
    pads = [(0, 0)] * a.ndim
    for ax in axes:
        rem = (-a.shape[ax]) % mult
        pads[ax] = (0, rem)
    if all(p == (0, 0) for p in pads):
        return a
    return jnp.pad(a, pads)


def quantize_weights(w: jax.Array, xb: int = DEFAULT_XB):
    """Quantise a static weight matrix into 8-bit crossbar tiles.

    Returns (w_q int8 [Kp, Np], scales f32 [Kp//xb, Np//xb]) where Kp/Np are
    K/N padded up to multiples of the crossbar size. Each xb x xb tile has a
    symmetric per-tile scale (max-abs / 127), mirroring per-array conductance
    programming.
    """
    assert w.ndim == 2, f"expected 2-D weight, got {w.shape}"
    w = pad_to_multiple(w.astype(jnp.float32), xb, (0, 1))
    kp, np_ = w.shape
    kt, nt = kp // xb, np_ // xb
    tiles = w.reshape(kt, xb, nt, xb).transpose(0, 2, 1, 3)  # [kt, nt, xb, xb]
    maxabs = jnp.max(jnp.abs(tiles), axis=(2, 3))
    scales = jnp.where(maxabs > 0, maxabs / CELL_LEVELS, 1.0)
    w_q = jnp.round(tiles / scales[:, :, None, None])
    w_q = jnp.clip(w_q, -CELL_LEVELS, CELL_LEVELS).astype(jnp.int8)
    w_q = w_q.transpose(0, 2, 1, 3).reshape(kp, np_)
    return w_q, scales.astype(jnp.float32)


def _mvm_kernel(x_ref, w_ref, s_ref, o_ref):
    """Grid = (n_tile, k_tile). Accumulates one crossbar tile's partial MVM.

    The int8 tile is multiplied in integer-ish domain (cast to f32 for the
    MXU dot) and the partial product is rescaled by the tile's ADC scale
    before accumulation — the same partial-sum-then-aggregate order as
    Reduction 1 across an RPU group.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_blk = x_ref[...]
    w_blk = w_ref[...].astype(jnp.float32)
    partial = jnp.dot(x_blk, w_blk, preferred_element_type=jnp.float32)
    o_ref[...] += partial * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("xb",))
def crossbar_matmul(x: jax.Array, w_q: jax.Array, scales: jax.Array,
                    xb: int = DEFAULT_XB) -> jax.Array:
    """y = x @ dequant(w_q) computed tile-by-tile as the PIM array would.

    x: [M, K] f32 (dynamic activations, fed from the channel's west edge)
    w_q: [Kp, Np] int8 (static 8-bit cells), scales: [Kp//xb, Np//xb] f32.
    Returns [M, Np] f32; callers slice off padding columns.
    """
    m, k = x.shape
    kp, np_ = w_q.shape
    assert kp % xb == 0 and np_ % xb == 0, (kp, np_, xb)
    x = pad_to_multiple(x, xb, (1,))
    assert x.shape[1] == kp, f"x K={k} (padded {x.shape[1]}) vs w K={kp}"
    kt, nt = kp // xb, np_ // xb

    out = pl.pallas_call(
        _mvm_kernel,
        grid=(nt, kt),
        in_specs=[
            pl.BlockSpec((m, xb), lambda n, k_: (0, k_)),
            pl.BlockSpec((xb, xb), lambda n, k_: (k_, n)),
            pl.BlockSpec((1, 1), lambda n, k_: (k_, n)),
        ],
        out_specs=pl.BlockSpec((m, xb), lambda n, k_: (0, n)),
        out_shape=jax.ShapeDtypeStruct((m, np_), jnp.float32),
        interpret=True,
    )(x, w_q, scales)
    return out


def crossbar_linear(x: jax.Array, w: jax.Array, xb: int = DEFAULT_XB) -> jax.Array:
    """Convenience: quantise-then-multiply in one call (build/test path only).

    The serving path pre-quantises once (weights are static) and calls
    crossbar_matmul; this helper exists for oracles and tests.
    """
    w_q, scales = quantize_weights(w, xb)
    y = crossbar_matmul(x, w_q, scales, xb)
    return y[:, : w.shape[1]]
