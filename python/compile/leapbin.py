"""`leapbin` — the tiny tensor interchange format between aot.py and Rust.

Layout (little-endian):
  magic   4 bytes  b"LEAP"
  version u8       1
  dtype   u8       0 = f32, 1 = i8, 2 = i32
  ndim    u8
  pad     u8       0
  dims    ndim * u32
  data    raw array bytes, C order

Mirrored by rust/src/runtime/leapbin.rs — keep in sync.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"LEAP"
_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2}
_RDTYPES = {0: np.float32, 1: np.int8, 2: np.int32}


def write(path: str, arr: np.ndarray) -> None:
    arr = np.ascontiguousarray(arr)
    code = _DTYPES[arr.dtype]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<BBBB", 1, code, arr.ndim, 0))
        f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        f.write(arr.tobytes())


def read(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        blob = f.read()
    assert blob[:4] == MAGIC, f"bad magic in {path}"
    ver, code, ndim, _ = struct.unpack("<BBBB", blob[4:8])
    assert ver == 1
    dims = struct.unpack(f"<{ndim}I", blob[8 : 8 + 4 * ndim])
    data = np.frombuffer(blob[8 + 4 * ndim :], dtype=_RDTYPES[code])
    return data.reshape(dims)
