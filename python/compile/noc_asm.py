"""Python authoring API for the LEAP NoC instruction set (paper §V-A).

The paper provides "a Python API ... to facilitate programming the LLM
inference dataflow to the 2D mesh NoC; the compiler then translates the
user's Python code into a corresponding hex file that can be loaded into the
NPM". This module is that API; the binary format is pinned against the Rust
assembler (`rust/src/isa/encode.rs`) by golden-byte tests on both sides.

Wire layout (16 bytes/instruction, little-endian):
  [0] cmd1 opcode  [1] cmd1 arg  [2] cmd2 opcode  [3] cmd2 arg
  [4:6] CMD_rep u16  [6] sel kind  [7] reserved
  [8:16] four u16 sel operands
"""

from __future__ import annotations

import dataclasses
import enum
import struct

INSTR_BYTES = 16


class Op(enum.IntEnum):
    """Opcodes — keep byte-for-byte in sync with rust isa::Opcode."""

    NOP = 0x00
    ROUTE_N = 0x01
    ROUTE_E = 0x02
    ROUTE_S = 0x03
    ROUTE_W = 0x04
    ROUTE_PE = 0x05
    BCAST_ROW = 0x06
    BCAST_COL = 0x07
    REDUCE_E = 0x08
    REDUCE_S = 0x09
    MAC = 0x0A
    ADD = 0x0B
    MUL = 0x0C
    EXPMAX = 0x0D
    SPAD_RD = 0x0E
    SPAD_WR = 0x0F
    PE_MVM = 0x10
    SYNC = 0x11
    HALT = 0x12


# selection kinds
SEL_ALL, SEL_ROWS, SEL_COLS, SEL_RECT, SEL_SPLIT_ROWS = range(5)


@dataclasses.dataclass(frozen=True)
class Sel:
    kind: int
    ops: tuple[int, int, int, int] = (0, 0, 0, 0)

    @staticmethod
    def all() -> "Sel":
        return Sel(SEL_ALL)

    @staticmethod
    def rows(lo: int, hi: int) -> "Sel":
        return Sel(SEL_ROWS, (lo, hi, 0, 0))

    @staticmethod
    def cols(lo: int, hi: int) -> "Sel":
        return Sel(SEL_COLS, (lo, hi, 0, 0))

    @staticmethod
    def rect(rlo: int, rhi: int, clo: int, chi: int) -> "Sel":
        return Sel(SEL_RECT, (rlo, rhi, clo, chi))

    @staticmethod
    def split_rows(lo: int, hi: int, lo2: int, hi2: int) -> "Sel":
        return Sel(SEL_SPLIT_ROWS, (lo, hi, lo2, hi2))


@dataclasses.dataclass(frozen=True)
class Instr:
    cmd1: tuple[Op, int]
    cmd2: tuple[Op, int]
    rep: int
    sel: Sel

    def encode(self) -> bytes:
        (o1, a1), (o2, a2) = self.cmd1, self.cmd2
        head = struct.pack("<BBBBHBB", o1, a1, o2, a2, self.rep, self.sel.kind, 0)
        return head + struct.pack("<4H", *self.sel.ops)


class Program:
    """Builder for an NPM program."""

    def __init__(self, label: str = "prog"):
        self.label = label
        self.instrs: list[Instr] = []

    def uni(self, op: Op, arg: int, rep: int, sel: Sel) -> "Program":
        self.instrs.append(Instr((op, arg), (Op.NOP, 0), rep, sel))
        return self

    def dual(self, cmd1: tuple[Op, int], cmd2: tuple[Op, int], rep: int, sel: Sel) -> "Program":
        self.instrs.append(Instr(cmd1, cmd2, rep, sel))
        return self

    def sealed(self) -> "Program":
        if not self.instrs or self.instrs[-1].cmd1[0] != Op.HALT:
            self.uni(Op.HALT, 0, 1, Sel.all())
        return self

    def assemble(self) -> str:
        """Emit the NPM hex file (one 32-hex-char line per instruction)."""
        lines = [f"; {self.label}"]
        for i in self.instrs:
            lines.append(i.encode().hex())
        return "\n".join(lines) + "\n"


def demo_program() -> Program:
    """The cross-language golden program — byte-identical to the Rust
    `isa::encode::tests::demo_program()`."""
    p = Program("demo")
    p.uni(Op.PE_MVM, 0, 4, Sel.all())
    p.dual((Op.ROUTE_E, 1), (Op.MAC, 0), 32, Sel.split_rows(0, 2, 2, 4))
    p.uni(Op.REDUCE_S, 0, 16, Sel.rect(0, 4, 2, 4))
    p.uni(Op.SPAD_WR, 2, 8, Sel.cols(1, 3))
    return p.sealed()
