//! Table III regeneration: LEAP vs A100 vs H100 on Llama 3-8B and
//! Llama 2-13B, full 2048-token context window (1024 in + 1024 out).
//!
//! Absolute numbers come from our simulator + datasheet rooflines, not the
//! authors' testbed; the *shape* to check (EXPERIMENTS.md records both):
//!  * LEAP beats the A100 on throughput by a small multiple (paper ~2.55×);
//!  * H100 wins raw throughput;
//!  * LEAP wins energy efficiency by 1–2 orders of magnitude
//!    (paper ~71.9× vs A100, ~24.2× vs H100) at ~10.5 W.
//!
//! Run: `cargo bench --bench bench_table3_gpu`

use leap::arch::HwParams;
use leap::baselines::GpuModel;
use leap::model::ModelPreset;
use leap::sim::AnalyticalSim;

fn main() {
    let (inp, out) = (1024usize, 1024usize);
    println!("=== Table III: comparison to GPU platforms ({inp} in + {out} out) ===\n");
    println!(
        "{:<14} {:>10} {:>12} {:>12} {:>12}",
        "", "", "Ours", "A100", "H100"
    );
    println!("{:<14} {:>10} {:>12} {:>12} {:>12}", "Frequency", "(GHz)", 1.0, 1.4, 1.7);

    let mut ours_rows = Vec::new();
    for preset in [ModelPreset::Llama8B, ModelPreset::Llama13B] {
        let shape = preset.shape();
        let ours = AnalyticalSim::new(preset, HwParams::default()).run(inp, out);
        let a100 = GpuModel::a100().run(&shape, inp, out);
        let h100 = GpuModel::h100().run(&shape, inp, out);
        println!(
            "{:<14} {:>10} {:>12.2} {:>12.2} {:>12.2}",
            "Throughput*", shape.name, ours.gen_tokens_per_s, a100.gen_tokens_per_s, h100.gen_tokens_per_s
        );
        ours_rows.push((shape.name, ours, a100, h100));
    }
    let (o8, a8, h8) = (&ours_rows[0].1, &ours_rows[0].2, &ours_rows[0].3);
    println!(
        "{:<14} {:>10} {:>12.2} {:>12} {:>12}",
        "Power", "(W)", o8.avg_power_w, "~300", "~350"
    );
    for (name, ours, a100, h100) in &ours_rows {
        println!(
            "{:<14} {:>10} {:>12.2} {:>12.4} {:>12.4}",
            "Energy eff.", name, ours.tokens_per_j, a100.tokens_per_j, h100.tokens_per_j
        );
    }
    println!("\n* generation throughput (out tokens / total time); paper rows for reference:");
    println!("  ours 202.25 / 120.62 tok/s; A100 78.36 / 47.86; H100 274.26 / 167.51");
    println!("  ours 19.21 / 11.45 tok/J;  A100 0.2612 / 0.1628; H100 0.7836 / 0.4786");

    println!("\n=== gain factors (ours vs A100 / H100) ===");
    for (name, ours, a100, h100) in &ours_rows {
        println!(
            "{name:<14} throughput ×{:.2} vs A100 (paper ~2.55×); eff ×{:.1} vs A100 (paper ~71.9×), ×{:.1} vs H100 (paper ~24.2×)",
            ours.gen_tokens_per_s / a100.gen_tokens_per_s,
            ours.tokens_per_j / a100.tokens_per_j,
            ours.tokens_per_j / h100.tokens_per_j
        );
    }
    let _ = (a8, h8);

    println!("\n=== ablation: duplicated-KV (paper) vs GQA-aware streaming ===");
    for preset in [ModelPreset::Llama1B, ModelPreset::Llama8B, ModelPreset::Llama13B] {
        let dup = AnalyticalSim::new(preset, HwParams::default()).run(inp, out);
        let gqa = AnalyticalSim::gqa_aware(preset, HwParams::default()).run(inp, out);
        println!(
            "{:<14} duplicated {:>8.2} tok/s  |  GQA-aware {:>8.2} tok/s  (×{:.2})",
            preset.shape().name,
            dup.gen_tokens_per_s,
            gqa.gen_tokens_per_s,
            gqa.gen_tokens_per_s / dup.gen_tokens_per_s
        );
    }
    println!("(the paper's 8B figure, 202.25 tok/s, falls between the two variants —");
    println!(" its simulator sits partway between full duplication and GQA-aware reads)");
}
