//! Fig. 12 regeneration: throughput trend under increasing packet bit-width
//! and IRCU parallelism, demonstrating the bandwidth/compute trade-off and
//! that the Table I configuration (64-bit, 16 MACs) sits near the frontier
//! knee without excessive resource overhead.
//!
//! Run: `cargo bench --bench bench_fig12_sweep`

use leap::arch::HwParams;
use leap::model::ModelPreset;
use leap::sim::AnalyticalSim;

fn run(packet_bits: u32, macs: usize) -> f64 {
    let mut hw = HwParams::default();
    hw.packet_bits = packet_bits;
    hw.ircu_macs = macs;
    AnalyticalSim::new(ModelPreset::Llama1B, hw).run(1024, 1024).total_tokens_per_s
}

fn main() {
    println!("=== Fig. 12: packet width × IRCU parallelism sweep (Llama 3.2-1B) ===\n");
    let packet_sweep = [16u32, 32, 64, 128, 256];
    let mac_sweep = [4usize, 8, 16, 32, 64];

    print!("{:>10}", "pkt\\MACs");
    for m in mac_sweep {
        print!("{m:>10}");
    }
    println!("   (total tok/s)");
    let mut grid = Vec::new();
    for pb in packet_sweep {
        print!("{pb:>10}");
        let mut row = Vec::new();
        for m in mac_sweep {
            let t = run(pb, m);
            print!("{t:>10.0}");
            row.push(t);
        }
        grid.push(row);
        println!();
    }

    // Frontier analysis: marginal gain per doubling at the Table I point.
    let t_table1 = grid[2][2]; // 64-bit, 16 MACs
    println!("\nTable I point (64 b, 16 MACs): {t_table1:.0} tok/s");
    println!("marginal gains from the Table I point:");
    println!("  2× packet width : +{:.1}%", (grid[3][2] / t_table1 - 1.0) * 100.0);
    println!("  2× IRCU MACs    : +{:.1}%", (grid[2][3] / t_table1 - 1.0) * 100.0);
    println!("  ½× packet width : {:.1}%", (grid[1][2] / t_table1 - 1.0) * 100.0);
    println!("  ½× IRCU MACs    : {:.1}%", (grid[2][1] / t_table1 - 1.0) * 100.0);
    println!("\nroofline reading: losses from halving exceed gains from doubling →");
    println!("the Table I configuration is at the knee (the paper's 'near-optimal");
    println!("throughput at the performance frontier without excessive overhead').");

    // resource-normalised view: throughput per (packet-bit × MAC) unit
    println!("\nthroughput per resource unit (tok/s ÷ (pkt_bits/64 × macs/16)):");
    print!("{:>10}", "pkt\\MACs");
    for m in mac_sweep {
        print!("{m:>10}");
    }
    println!();
    for (i, pb) in packet_sweep.iter().enumerate() {
        print!("{pb:>10}");
        for (j, m) in mac_sweep.iter().enumerate() {
            let norm = grid[i][j] / ((*pb as f64 / 64.0) * (*m as f64 / 16.0));
            print!("{norm:>10.0}");
        }
        println!();
    }
}
