//! Table II + Fig. 9 regeneration: macro-level power and area breakdown at
//! the 7 nm-scaled node, and the system-level totals of Table I.
//!
//! Run: `cargo bench --bench bench_table2_breakdown`

use leap::arch::HwParams;
use leap::energy::{table2, AreaBreakdown, MacroArea, RouterDetail, ScratchpadModel};

fn main() {
    println!("=== Table II: macro-level power and area breakdown (7 nm) ===\n");
    let m = MacroArea::default();
    println!(
        "{:<12} {:>12} {:>10} {:>12} {:>10}",
        "component", "power (µW)", "share", "area (mm²)", "share"
    );
    let rows = [
        ("PIM PE", m.pe_uw, m.pe_mm2),
        ("Scratchpad", m.spad_uw, m.spad_mm2),
        ("Router", m.router_uw, m.router_mm2),
    ];
    for (name, uw, mm2) in rows {
        println!(
            "{:<12} {:>12.2} {:>9.2}% {:>12.4} {:>9.2}%",
            name,
            uw,
            uw / m.total_uw() * 100.0,
            mm2,
            mm2 / table2::MACRO_MM2_PAPER * 100.0
        );
    }
    println!(
        "{:<12} {:>12.2} {:>10} {:>12.4} {:>10}",
        "Total", m.total_uw(), "100%", m.total_mm2(), "100%"
    );
    println!(
        "\npaper rows: PE 32.37 µW / 0.0864 mm², spad 37.80 / 0.0125, router 90.48 / 0.021"
    );
    println!(
        "NOTE: the paper's printed area total (0.1181 mm²) is 1.5% below its own\n\
         component sum (0.1199 mm²) — documented erratum; we report the sum."
    );

    println!("\n=== Fig. 9 headline: router share ===");
    let shares = m.shares();
    println!("router: {:.2}% of power but {:.2}% of area (paper: 56.32% / 17.78%)",
        shares[2].0, m.router_mm2 / table2::MACRO_MM2_PAPER * 100.0);

    println!("\n=== Fig. 9 (right): router-level sub-block breakdown ===");
    let rd = RouterDetail::for_hw(&HwParams::default());
    for blk in &rd.blocks {
        println!(
            "{:<24} {:>8.2} µW ({:>5.1}%)   {:>8.5} mm² ({:>5.1}%)",
            blk.name,
            blk.power_uw,
            blk.power_uw / rd.total_power_uw() * 100.0,
            blk.area_mm2,
            blk.area_mm2 / rd.total_area_mm2() * 100.0
        );
    }

    println!("\n=== Table I system (64 tiles × 1024 macros) ===");
    let sys = AreaBreakdown::new(64 * 1024);
    println!("peak power : {:>8.2} W   (Table III 'Ours' power: 10.53 W)", sys.peak_power_w());
    println!("total area : {:>8.1} mm²", sys.total_area_mm2());

    println!("\n=== CACTI-style scratchpad scaling (energy/access model) ===");
    println!("{:>10} {:>14} {:>12} {:>14}", "capacity", "power (µW)", "area (mm²)", "pJ/access");
    for kb in [8usize, 16, 32, 64, 128] {
        let s = ScratchpadModel::new(kb * 1024, 16);
        println!(
            "{:>7} KB {:>14.2} {:>12.4} {:>14.3}",
            kb,
            s.active_power_uw(),
            s.area_mm2(),
            s.access_pj()
        );
    }

    // shares must be scale-invariant (§VI-C)
    println!("\nscale invariance: shares identical at 1k and 1M macros: {}",
        AreaBreakdown::new(1024).per_macro.shares() == AreaBreakdown::new(1 << 20).per_macro.shares());
}
