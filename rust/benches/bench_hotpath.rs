//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf).
//!
//! Measures the L3 components that sit on the serving path:
//!  * analytical simulation of a full inference (dominates `simulate`);
//!  * phase-plan construction (called per program compile);
//!  * program lowering + hex assembly (per NPM load);
//!  * mesh-executor cycle rate (instruction-level sim throughput);
//!  * serving-engine decode-round rate (coordinator overhead);
//!  * mapping cost evaluation (DSE inner loop).
//!
//! Run: `cargo bench --bench bench_hotpath`

use leap::arch::{Coord, HwParams, TileGeometry};
use leap::compiler::{lower_phases, Compiler};
use leap::coordinator::{BatchPolicy, EngineConfig, Numerics, ServingEngine};
use leap::isa::assemble;
use leap::mapping::{paper_mapping, CostModel};
use leap::model::ModelPreset;
use leap::noc::MeshSim;
use leap::schedule::{decode_phases, prefill_phases};
use leap::sim::AnalyticalSim;
use leap::bench_util::bench;

fn main() {
    println!("=== L3 hot-path microbenchmarks ===\n");
    let hw = HwParams::default();

    // analytical end-to-end (Fig. 10/Table III inner loop)
    let sim8 = AnalyticalSim::new(ModelPreset::Llama8B, hw.clone());
    bench("analytical run 8B (1024+1024)", 3, 30, || sim8.run(1024, 1024).total_tokens_per_s);
    let sim13 = AnalyticalSim::new(ModelPreset::Llama13B, hw.clone());
    bench("analytical run 13B (2048+2048)", 3, 30, || sim13.run(2048, 2048).total_tokens_per_s);

    // phase-plan construction
    let shape = ModelPreset::Llama1B.shape();
    let geom = TileGeometry::for_model(shape.d_model, &hw);
    bench("prefill_phases 1B S=1024", 10, 200, || prefill_phases(&shape, &geom, &hw, 1024).total_cycles());
    bench("decode_phases 1B ctx=2048", 10, 200, || decode_phases(&shape, &geom, &hw, 2048).total_cycles());

    // lowering + assembly
    let lp = prefill_phases(&shape, &geom, &hw, 1024);
    bench("lower_phases 1B prefill", 10, 200, || lower_phases("b", &lp, &geom).len());
    let prog = lower_phases("b", &lp, &geom);
    bench("assemble program to hex", 10, 200, || assemble(&prog).len());

    // instruction-level executor: simulated cycles per wall second
    let tshape = ModelPreset::Tiny.shape();
    let tgeom = TileGeometry::for_model(tshape.d_model, &hw);
    let tlp = prefill_phases(&tshape, &tgeom, &hw, 32);
    let tprog = lower_phases("mesh", &tlp, &tgeom);
    let side = (2 * tgeom.dc) as u16;
    let stats = bench("mesh executor: tiny prefill program", 2, 20, || {
        let mut sim = MeshSim::new(side, side, hw.clone());
        for y in 0..side {
            for x in 0..side {
                sim.preload_spad(Coord::new(x, y), 4096);
            }
        }
        sim.run(&tprog).unwrap()
    });
    let cycles = {
        let mut sim = MeshSim::new(side, side, hw.clone());
        sim.run(&tprog).unwrap()
    };
    let rate = cycles as f64 / (stats.mean_ns * 1e-9);
    println!("    → {:.2} M simulated mesh-cycles/s ({} routers)", rate / 1e6, side as u64 * side as u64);

    // a larger mesh for router-scaling
    let stats32 = bench("mesh executor: 32×32 mesh, same program", 1, 5, || {
        let mut sim = MeshSim::new(32, 32, hw.clone());
        for y in 0..32 {
            for x in 0..32 {
                sim.preload_spad(Coord::new(x, y), 4096);
            }
        }
        sim.run(&tprog).unwrap()
    });
    let rate32 = cycles as f64 / (stats32.mean_ns * 1e-9);
    println!("    → {:.2} M simulated mesh-cycles/s (1024 routers)", rate32 / 1e6);

    // coordinator decode rounds (synthetic numerics → pure L3 cost)
    bench("serving engine: 8 reqs × 16 tokens (1B)", 1, 10, || {
        let mut e = ServingEngine::new(EngineConfig {
            preset: ModelPreset::Llama1B,
            hw: HwParams::default(),
            policy: BatchPolicy::default(),
            numerics: Numerics::Synthetic { vocab: 1000 },
        })
        .unwrap();
        for _ in 0..8 {
            e.submit(vec![1; 64], 16);
        }
        e.run_until_idle().unwrap();
        e.metrics.requests_done
    });

    // compile cache effectiveness
    bench("compiler: decode program (cached)", 2, 50, || {
        let mut cm = Compiler::default().compile(ModelPreset::Llama1B).unwrap();
        cm.decode_program(1024).len()
    });

    // mapping DSE inner loop
    let model = CostModel::new(16, 128, 64);
    let cand = paper_mapping(16);
    bench("mapping cost evaluation (dc=16)", 10, 300, || model.evaluate(&cand));
}
