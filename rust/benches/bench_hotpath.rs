//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf).
//!
//! Two halves:
//!
//! 1. **Decode throughput** (always runs) — tokens/sec and ns/token of the
//!    reference backend on the `tiny_ref` fixture, fast kernels vs the
//!    retained pre-optimisation naive path, plus the batched
//!    weight-stationary decode cost for 1 vs 8 sessions. Results are
//!    written to `BENCH_hotpath.json` (machine-readable; override the path
//!    with `BENCH_HOTPATH_JSON`) so CI tracks the perf trajectory.
//! 2. **L3 component microbenches** (skipped in smoke mode) — analytical
//!    simulation, phase-plan construction, lowering/assembly, the mesh
//!    executor, the serving coordinator, and the mapping cost model.
//!
//! Run: `cargo bench --bench bench_hotpath`
//! Smoke (CI): `BENCH_SMOKE=1 cargo bench --bench bench_hotpath`

use std::time::Instant;

use leap::arch::{Coord, HwParams, TileGeometry};
use leap::bench_util::{bench, Stats};
use leap::compiler::{lower_phases, Compiler};
use leap::coordinator::{BatchPolicy, EngineConfig, Metrics, Numerics, ServingEngine};
use leap::isa::assemble;
use leap::kvcache::{KvCacheConfig, KvDtype};
use leap::mapping::{paper_mapping, CostModel};
use leap::model::ModelPreset;
use leap::noc::MeshSim;
use leap::obs::{Tracer, DEFAULT_RING_CAPACITY};
use leap::runtime::{argmax_row, KernelMode, NumericsBackend, ReferenceBackend, WorkerPool};
use leap::schedule::{decode_phases, prefill_phases};
use leap::sim::AnalyticalSim;

fn fixture_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tiny_ref")
}

fn fixture_prompt(session: u64) -> Vec<i32> {
    (0..8).map(|i| ((session as i32 * 97) + i * 37 + 11) % 512).collect()
}

/// Best-of-`samples` single-session decode cost in ns/token.
fn decode_ns_per_token(mode: KernelMode, tokens: usize, samples: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let mut b = ReferenceBackend::load_with_mode(fixture_dir(), mode).expect("fixture loads");
        b.prefill(1, &fixture_prompt(1)).expect("prefill");
        let mut tok = 3i32;
        let t0 = Instant::now();
        for _ in 0..tokens {
            let out = b.decode_step(1, tok).expect("decode");
            tok = argmax_row(&out.logits, 0, b.vocab()) as i32;
        }
        best = best.min(t0.elapsed().as_nanos() as f64 / tokens as f64);
    }
    best
}

/// Best-of-`samples` single-session fast decode with the KV pool stored at
/// `dtype` (the f32 case re-measures the plain fast path through the typed
/// read-side, so the three numbers are apples-to-apples).
fn decode_ns_per_token_dtype(dtype: KvDtype, tokens: usize, samples: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let mut b = ReferenceBackend::load_with_kv_dtype(fixture_dir(), KernelMode::Fast, dtype)
            .expect("fixture loads");
        b.prefill(1, &fixture_prompt(1)).expect("prefill");
        let mut tok = 3i32;
        let t0 = Instant::now();
        for _ in 0..tokens {
            let out = b.decode_step(1, tok).expect("decode");
            tok = argmax_row(&out.logits, 0, b.vocab()) as i32;
        }
        best = best.min(t0.elapsed().as_nanos() as f64 / tokens as f64);
    }
    best
}

/// Byte budget for the KV-dtype capacity sweep: 1 MiB holds 32 f32 blocks
/// of the tiny model at block_size 4, so the sweep's session counts leave
/// room to show the ~2×/~4× capacity gain at f16/q8.
const KV_SWEEP_POOL_BYTES: usize = 1 << 20;

/// Size a pool to `pool_bytes` at `dtype` and admit 24-token sessions until
/// the allocator refuses. Returns `(bytes_per_token, sessions_admitted)` —
/// the capacity half of the ISSUE 7 acceptance evidence.
fn kv_capacity_probe(dtype: KvDtype, pool_bytes: usize) -> (usize, usize) {
    let probe =
        ReferenceBackend::load_with_mode(fixture_dir(), KernelMode::Fast).expect("fixture loads");
    let meta = probe.meta();
    let mut cfg = KvCacheConfig::for_model(meta.d_model, meta.s_max);
    cfg.block_size = 4;
    cfg.dtype = dtype;
    cfg.prefix_sharing = false;
    cfg.n_blocks = cfg.blocks_for_bytes(pool_bytes, meta.n_layers, meta.d_model);
    let bytes_per_token = cfg.bytes_per_token(meta.n_layers, meta.d_model);
    let mut b = ReferenceBackend::load_with_opts(fixture_dir(), KernelMode::Fast, Some(cfg))
        .expect("fixture loads");
    let mut admitted = 0usize;
    for s in 0..4096u64 {
        let prompt: Vec<i32> =
            (0..24).map(|i| ((s as i32 * 97) + i * 37 + 11) % 512).collect();
        if b.prefill(s, &prompt).is_err() {
            break;
        }
        admitted += 1;
    }
    (bytes_per_token, admitted)
}

/// Best-of-`samples` single-session fast-path decode through an explicitly
/// sized worker pool (`None` = the backend default: LEAP_THREADS /
/// available_parallelism). Returns `(ns_per_token, pool_dispatches_per_token)`
/// of the best sample — the dispatch counter is the witness that all
/// parallelism flows through the resident pool (zero spawns after load).
fn decode_ns_per_token_pooled(threads: Option<usize>, tokens: usize, samples: usize) -> (f64, f64) {
    let mut best = f64::INFINITY;
    let mut best_disp = 0f64;
    for _ in 0..samples {
        let mut b = match threads {
            Some(t) => ReferenceBackend::load_with_pool(
                fixture_dir(),
                KernelMode::Fast,
                None,
                WorkerPool::with_threads(t),
            )
            .expect("fixture loads"),
            None => {
                ReferenceBackend::load_with_mode(fixture_dir(), KernelMode::Fast)
                    .expect("fixture loads")
            }
        };
        b.prefill(1, &fixture_prompt(1)).expect("prefill");
        let d0 = b.worker_pool_stats().map_or(0, |s| s.dispatches);
        let mut tok = 3i32;
        let t0 = Instant::now();
        for _ in 0..tokens {
            let out = b.decode_step(1, tok).expect("decode");
            tok = argmax_row(&out.logits, 0, b.vocab()) as i32;
        }
        let ns = t0.elapsed().as_nanos() as f64 / tokens as f64;
        if ns < best {
            best = ns;
            let d1 = b.worker_pool_stats().map_or(0, |s| s.dispatches);
            best_disp = d1.saturating_sub(d0) as f64 / tokens as f64;
        }
    }
    (best, best_disp)
}

/// Best-of-`samples` cost of one `decode_batch` round over `nsessions`
/// live sessions, in ns/round.
fn batch_ns_per_round(nsessions: usize, rounds: usize, samples: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let mut b = ReferenceBackend::load_with_mode(fixture_dir(), KernelMode::Fast)
            .expect("fixture loads");
        for s in 0..nsessions as u64 {
            b.prefill(s, &fixture_prompt(s)).expect("prefill");
        }
        let mut toks = vec![3i32; nsessions];
        let vocab = b.vocab();
        let t0 = Instant::now();
        for _ in 0..rounds {
            let steps: Vec<(u64, i32)> =
                toks.iter().enumerate().map(|(s, &t)| (s as u64, t)).collect();
            let outs = b.decode_batch(&steps).expect("decode_batch");
            for (s, res) in outs.into_iter().enumerate() {
                toks[s] = argmax_row(&res.expect("step ok").logits, 0, vocab) as i32;
            }
        }
        best = best.min(t0.elapsed().as_nanos() as f64 / rounds as f64);
    }
    best
}

/// Best-of-`samples` wall ns per generated token of a full engine serve
/// over the reference fixture, with structured tracing off or on. Tracing
/// is bitwise-invisible to results (same tokens, same sim clock); this A/B
/// measures the residual host-side wall cost of the ring-buffer emits.
fn engine_serve_ns_per_token(trace: bool, requests: usize, gen: usize, samples: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let backend = ReferenceBackend::load_with_mode(fixture_dir(), KernelMode::Fast)
            .expect("fixture loads");
        let mut e = ServingEngine::new(EngineConfig {
            preset: ModelPreset::Tiny,
            hw: HwParams::default(),
            policy: BatchPolicy::default(),
            numerics: Numerics::Backend(Box::new(backend)),
        })
        .expect("engine");
        if trace {
            e.tracer = Tracer::enabled(DEFAULT_RING_CAPACITY);
        }
        for s in 0..requests as u64 {
            e.submit(fixture_prompt(s), gen).expect("submit");
        }
        let t0 = Instant::now();
        e.run_until_idle().expect("serve");
        let tokens = e.metrics.decode_tokens.max(1);
        best = best.min(t0.elapsed().as_nanos() as f64 / tokens as f64);
    }
    best
}

/// Serve a shared-prefix workload through a deliberately tight KV pool and
/// report the pool gauges (ISSUE 4 satellite): blocks used/free at peak,
/// prefix-share hit rate, CoW copies, and the preemption count. Returns
/// the engine metrics for the JSON record.
fn kv_pool_pressure_report(smoke: bool) -> Metrics {
    let (requests, gen) = if smoke { (6, 4) } else { (10, 8) };
    let cfg =
        KvCacheConfig { block_size: 4, n_blocks: 14, prefix_sharing: true, dtype: KvDtype::F32 };
    let (bs, n_blocks) = (cfg.block_size, cfg.n_blocks);
    let backend = ReferenceBackend::load_with_opts(fixture_dir(), KernelMode::Fast, Some(cfg))
        .expect("fixture loads");
    let mut e = ServingEngine::new(EngineConfig {
        preset: ModelPreset::Tiny,
        hw: HwParams::default(),
        policy: BatchPolicy { max_batch: 16, max_total_ctx: 100_000 },
        numerics: Numerics::Backend(Box::new(backend)),
    })
    .expect("engine");
    for s in 0..requests as i32 {
        // shared 8-token system prefix + 2 distinct user tokens
        let mut p: Vec<i32> = (0..8).map(|i| (i * 29 + 3) % 512).collect();
        p.extend([(s * 67 + 40) % 512, (s * 31 + 77) % 512]);
        e.submit(p, gen).expect("submit");
    }
    e.run_until_idle().expect("serve");
    let m = e.metrics.clone();
    println!(
        "=== paged KV pool under pressure ({requests} reqs, {n_blocks} blocks × {bs} tok) ===\n"
    );
    println!(
        "requests                {} done / {} failed   preemptions {}",
        m.requests_done, m.requests_failed, m.preemptions
    );
    println!(
        "pool occupancy          peak {}/{} blocks   shared-at-last-obs {}",
        m.kv_peak_blocks_used, m.kv_blocks_total, m.kv_shared_blocks
    );
    println!(
        "prefix sharing          {:.1}% hit rate ({}/{} probes)   CoW copies {}",
        100.0 * m.kv_prefix_hit_rate(),
        m.kv_prefix_hits,
        m.kv_prefix_lookups,
        m.kv_cow_copies
    );
    println!(
        "worker pool             {} lanes, {} dispatches ({} parks / {} wakes)\n",
        m.pool_threads, m.pool_dispatches, m.pool_parks, m.pool_wakes
    );
    m
}

/// Decode-throughput mode: fast vs naive kernels, batched vs sequential,
/// machine-readable JSON out.
fn decode_throughput_report(smoke: bool) {
    println!("=== reference-backend decode throughput (tiny_ref) ===\n");
    // Smoke keeps 3 best-of samples (not 2): the smoke numbers feed the
    // CI regression gate across heterogeneous shared runners, so the
    // best-of estimate needs some noise rejection.
    let (tokens, rounds, samples) = if smoke { (24, 16, 3) } else { (96, 64, 5) };

    let naive_ns = decode_ns_per_token(KernelMode::Naive, tokens, samples);
    let (fast_ns, disp_per_tok) = decode_ns_per_token_pooled(None, tokens, samples);
    // Single-lane pool: the fused pipeline with all parallelism off. A
    // conservative stand-in for the pre-PR scoped-thread baseline — on
    // this model the old per-call threshold (1 << 21 MACs) never spawned,
    // so pre-PR fast was single-threaded AND unfused, i.e. no faster than
    // this.
    let (serial_ns, _) = decode_ns_per_token_pooled(Some(1), tokens, samples);
    // SIMD vs forced-scalar A/B on the identical fused pipeline: the
    // dispatch is bitwise-invisible (same fixed-order reduction), so this
    // isolates the vectorisation win alone.
    leap::runtime::simd::force_scalar(true);
    let (fast_scalar_ns, _) = decode_ns_per_token_pooled(None, tokens, samples);
    leap::runtime::simd::force_scalar(false);
    let simd_level = leap::runtime::simd::probed_level().as_str();
    let simd_speedup = fast_scalar_ns / fast_ns;
    let speedup = naive_ns / fast_ns;
    let pool_speedup = serial_ns / fast_ns;
    let pool_threads = WorkerPool::default_threads();
    println!(
        "single-session decode   naive {:>10}/tok ({:>9.0} tok/s)",
        Stats::fmt_ns(naive_ns),
        1e9 / naive_ns
    );
    println!(
        "single-session decode   fast  {:>10}/tok ({:>9.0} tok/s)   speedup {speedup:.2}x",
        Stats::fmt_ns(fast_ns),
        1e9 / fast_ns
    );
    println!(
        "worker pool             {pool_threads} lanes, {disp_per_tok:.1} dispatches/token \
         (0 thread spawns after load)"
    );
    println!(
        "pool vs single lane     1-lane fused {:>10}/tok → pooled speedup {pool_speedup:.2}x",
        Stats::fmt_ns(serial_ns)
    );
    println!(
        "simd dispatch           {simd_level}; forced-scalar {:>10}/tok → simd speedup {simd_speedup:.2}x",
        Stats::fmt_ns(fast_scalar_ns)
    );

    let b1_ns = batch_ns_per_round(1, rounds, samples);
    let b8_ns = batch_ns_per_round(8, rounds, samples);
    let sublin = b8_ns / b1_ns;
    println!(
        "batched decode round    B=1   {:>10}/round        B=8 {:>10}/round",
        Stats::fmt_ns(b1_ns),
        Stats::fmt_ns(b8_ns)
    );
    println!(
        "                        8-session round costs {sublin:.2}x a 1-session round \
         ({:.0} tok/s aggregate)\n",
        8.0 * 1e9 / b8_ns
    );

    // KV dtype sweep: per-token bytes, capacity on a fixed byte budget,
    // and decode cost with the quantized read-side in the attention walk.
    println!(
        "=== KV dtype sweep ({} KiB pool, 24-token sessions) ===\n",
        KV_SWEEP_POOL_BYTES >> 10
    );
    let mut sweep = Vec::new();
    for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Q8] {
        let (bpt, sessions) = kv_capacity_probe(dtype, KV_SWEEP_POOL_BYTES);
        let ns = decode_ns_per_token_dtype(dtype, tokens, samples);
        println!(
            "{:<4}  {bpt:>6} B/token   {sessions:>3} sessions admitted   decode {:>10}/tok",
            dtype.as_str(),
            Stats::fmt_ns(ns)
        );
        sweep.push((dtype, bpt, sessions, ns));
    }
    println!();
    let (f32_bpt, f32_sessions, f32_ns) = (sweep[0].1, sweep[0].2, sweep[0].3);
    let (f16_bpt, f16_sessions, f16_ns) = (sweep[1].1, sweep[1].2, sweep[1].3);
    let (q8_bpt, q8_sessions, q8_ns) = (sweep[2].1, sweep[2].2, sweep[2].3);

    let kv = kv_pool_pressure_report(smoke);

    // Trace-on/off A/B on a full engine serve: the observability layer's
    // wall-cost witness (its result-invisibility is a unit-test concern).
    let (ab_requests, ab_gen) = if smoke { (4, 6) } else { (8, 12) };
    let ab_samples = samples.min(3);
    let trace_off_ns = engine_serve_ns_per_token(false, ab_requests, ab_gen, ab_samples);
    let trace_on_ns = engine_serve_ns_per_token(true, ab_requests, ab_gen, ab_samples);
    let trace_ratio = trace_on_ns / trace_off_ns;
    println!("=== engine trace overhead A/B ({ab_requests} reqs × {ab_gen} tokens) ===\n");
    println!(
        "traced serve            off {:>10}/tok   on {:>10}/tok   overhead {trace_ratio:.3}x\n",
        Stats::fmt_ns(trace_off_ns),
        Stats::fmt_ns(trace_on_ns)
    );

    let json = format!(
        "{{\n  \"bench\": \"hotpath_decode\",\n  \"fixture\": \"tiny_ref\",\n  \
         \"provenance\": \"measured\",\n  \
         \"smoke\": {smoke},\n  \"decode_tokens\": {tokens},\n  \"samples\": {samples},\n  \
         \"naive_baseline\": \"retained pre-optimisation scalar path (in-place paged reads)\",\n  \
         \"serial_baseline\": \"single-lane pool: fused pipeline, parallelism off — an upper \
         bound on the pre-PR scoped-thread fast path, which was single-threaded AND unfused \
         on this model\",\n  \
         \"naive_ns_per_token\": {naive_ns:.1},\n  \"naive_tokens_per_s\": {:.1},\n  \
         \"fast_ns_per_token\": {fast_ns:.1},\n  \"fast_tokens_per_s\": {:.1},\n  \
         \"speedup_fast_over_naive\": {speedup:.3},\n  \
         \"simd_level\": \"{simd_level}\",\n  \
         \"fast_scalar_ns_per_token\": {fast_scalar_ns:.1},\n  \
         \"speedup_simd_over_scalar\": {simd_speedup:.3},\n  \
         \"serial_lane_ns_per_token\": {serial_ns:.1},\n  \
         \"speedup_pool_over_single_lane\": {pool_speedup:.3},\n  \
         \"pool_threads\": {pool_threads},\n  \
         \"pool_dispatches_per_token\": {disp_per_tok:.1},\n  \
         \"batch1_ns_per_round\": {b1_ns:.1},\n  \"batch8_ns_per_round\": {b8_ns:.1},\n  \
         \"batch8_over_batch1\": {sublin:.3},\n  \"batch8_tokens_per_s\": {:.1},\n  \
         \"kv_block_size\": {},\n  \"kv_blocks_total\": {},\n  \
         \"kv_peak_blocks_used\": {},\n  \"kv_prefix_hit_rate\": {:.3},\n  \
         \"kv_prefix_lookups\": {},\n  \"kv_prefix_hits\": {},\n  \
         \"kv_cow_copies\": {},\n  \"kv_preemptions\": {},\n  \
         \"kv_sweep_pool_bytes\": {KV_SWEEP_POOL_BYTES},\n  \
         \"kv_f32_bytes_per_token\": {f32_bpt},\n  \
         \"kv_f32_max_sessions\": {f32_sessions},\n  \
         \"kv_f32_decode_ns_per_token\": {f32_ns:.1},\n  \
         \"kv_f16_bytes_per_token\": {f16_bpt},\n  \
         \"kv_f16_max_sessions\": {f16_sessions},\n  \
         \"kv_f16_decode_ns_per_token\": {f16_ns:.1},\n  \
         \"kv_q8_bytes_per_token\": {q8_bpt},\n  \
         \"kv_q8_max_sessions\": {q8_sessions},\n  \
         \"kv_q8_decode_ns_per_token\": {q8_ns:.1},\n  \
         \"trace_off_ns_per_token\": {trace_off_ns:.1},\n  \
         \"trace_on_ns_per_token\": {trace_on_ns:.1},\n  \
         \"trace_overhead_ratio\": {trace_ratio:.3},\n  \
         \"engine_pool_dispatches\": {},\n  \"engine_pool_parks\": {},\n  \
         \"engine_pool_wakes\": {}\n}}\n",
        1e9 / naive_ns,
        1e9 / fast_ns,
        8.0 * 1e9 / b8_ns,
        kv.kv_block_size,
        kv.kv_blocks_total,
        kv.kv_peak_blocks_used,
        kv.kv_prefix_hit_rate(),
        kv.kv_prefix_lookups,
        kv.kv_prefix_hits,
        kv.kv_cow_copies,
        kv.preemptions,
        kv.pool_dispatches,
        kv.pool_parks,
        kv.pool_wakes,
    );
    // Written to the crate dir (gitignored) or an explicit override —
    // never to the repo root: the root BENCH_hotpath.json is the
    // *committed* regression-gate baseline, advanced only by an explicit
    // copy (see README), so a local bench run can never silently clobber
    // it into the next commit.
    let path = std::env::var("BENCH_HOTPATH_JSON")
        .ok()
        .unwrap_or_else(|| "BENCH_hotpath.json".to_string());
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(err) => eprintln!("could not write {path}: {err}"),
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0");

    decode_throughput_report(smoke);
    if smoke {
        println!("(BENCH_SMOKE set: skipping L3 component microbenches)");
        return;
    }

    println!("\n=== L3 hot-path microbenchmarks ===\n");
    let hw = HwParams::default();

    // analytical end-to-end (Fig. 10/Table III inner loop)
    let sim8 = AnalyticalSim::new(ModelPreset::Llama8B, hw.clone());
    bench("analytical run 8B (1024+1024)", 3, 30, || sim8.run(1024, 1024).total_tokens_per_s);
    let sim13 = AnalyticalSim::new(ModelPreset::Llama13B, hw.clone());
    bench("analytical run 13B (2048+2048)", 3, 30, || sim13.run(2048, 2048).total_tokens_per_s);

    // phase-plan construction
    let shape = ModelPreset::Llama1B.shape();
    let geom = TileGeometry::for_model(shape.d_model, &hw);
    bench("prefill_phases 1B S=1024", 10, 200, || prefill_phases(&shape, &geom, &hw, 1024).total_cycles());
    bench("decode_phases 1B ctx=2048", 10, 200, || decode_phases(&shape, &geom, &hw, 2048).total_cycles());

    // lowering + assembly
    let lp = prefill_phases(&shape, &geom, &hw, 1024);
    bench("lower_phases 1B prefill", 10, 200, || lower_phases("b", &lp, &geom).len());
    let prog = lower_phases("b", &lp, &geom);
    bench("assemble program to hex", 10, 200, || assemble(&prog).len());

    // instruction-level executor: simulated cycles per wall second
    let tshape = ModelPreset::Tiny.shape();
    let tgeom = TileGeometry::for_model(tshape.d_model, &hw);
    let tlp = prefill_phases(&tshape, &tgeom, &hw, 32);
    let tprog = lower_phases("mesh", &tlp, &tgeom);
    let side = (2 * tgeom.dc) as u16;
    let stats = bench("mesh executor: tiny prefill program", 2, 20, || {
        let mut sim = MeshSim::new(side, side, hw.clone());
        for y in 0..side {
            for x in 0..side {
                sim.preload_spad(Coord::new(x, y), 4096);
            }
        }
        sim.run(&tprog).unwrap()
    });
    let cycles = {
        let mut sim = MeshSim::new(side, side, hw.clone());
        sim.run(&tprog).unwrap()
    };
    let rate = cycles as f64 / (stats.mean_ns * 1e-9);
    println!("    → {:.2} M simulated mesh-cycles/s ({} routers)", rate / 1e6, side as u64 * side as u64);

    // a larger mesh for router-scaling
    let stats32 = bench("mesh executor: 32×32 mesh, same program", 1, 5, || {
        let mut sim = MeshSim::new(32, 32, hw.clone());
        for y in 0..32 {
            for x in 0..32 {
                sim.preload_spad(Coord::new(x, y), 4096);
            }
        }
        sim.run(&tprog).unwrap()
    });
    let rate32 = cycles as f64 / (stats32.mean_ns * 1e-9);
    println!("    → {:.2} M simulated mesh-cycles/s (1024 routers)", rate32 / 1e6);

    // coordinator decode rounds (synthetic numerics → pure L3 cost)
    bench("serving engine: 8 reqs × 16 tokens (1B)", 1, 10, || {
        let mut e = ServingEngine::new(EngineConfig {
            preset: ModelPreset::Llama1B,
            hw: HwParams::default(),
            policy: BatchPolicy::default(),
            numerics: Numerics::Synthetic { vocab: 1000 },
        })
        .unwrap();
        for _ in 0..8 {
            e.submit(vec![1; 64], 16).expect("submit");
        }
        e.run_until_idle().unwrap();
        e.metrics.requests_done
    });

    // compile cache effectiveness
    bench("compiler: decode program (cached)", 2, 50, || {
        let mut cm = Compiler::default().compile(ModelPreset::Llama1B).unwrap();
        cm.decode_program(1024).len()
    });

    // mapping DSE inner loop
    let model = CostModel::new(16, 128, 64);
    let cand = paper_mapping(16);
    bench("mapping cost evaluation (dc=16)", 10, 300, || model.evaluate(&cand));
}
