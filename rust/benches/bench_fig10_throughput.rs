//! Fig. 10 regeneration: end-to-end throughput across models and
//! input/output sequence lengths, with the prefill/decode breakdown.
//!
//! Paper claims checked: decode throughput 4–6× below prefill; throughput
//! drops sublinearly with model size.
//!
//! Run: `cargo bench --bench bench_fig10_throughput`

use leap::arch::HwParams;
use leap::model::ModelPreset;
use leap::sim::AnalyticalSim;

fn main() {
    println!("=== Fig. 10: throughput vs models and sequence lengths ===\n");
    println!(
        "{:<14} {:>6} {:>6} {:>13} {:>12} {:>12} {:>16}",
        "model", "in", "out", "prefill t/s", "decode t/s", "total t/s", "prefill/decode*"
    );
    let mut per_model_total = Vec::new();
    for preset in [ModelPreset::Llama1B, ModelPreset::Llama8B, ModelPreset::Llama13B] {
        let sim = AnalyticalSim::new(preset, HwParams::default());
        for (inp, out) in [(128, 128), (256, 256), (512, 512), (1024, 1024), (2048, 2048)] {
            let r = sim.run(inp, out);
            let ratio = r.prefill.tokens_per_s / r.decode.tokens_per_s;
            println!(
                "{:<14} {:>6} {:>6} {:>13.1} {:>12.2} {:>12.2} {:>15.1}×",
                preset.shape().name,
                inp,
                out,
                r.prefill.tokens_per_s,
                r.decode.tokens_per_s,
                r.total_tokens_per_s,
                ratio
            );
            if inp == 1024 {
                per_model_total.push((preset.shape().name, r.total_tokens_per_s));
            }
        }
        println!();
    }
    println!("* per-stage token rate; paper: decode 4–6× below prefill");

    println!("\n=== sublinear scaling check (at 1024+1024) ===");
    for w in per_model_total.windows(2) {
        let (n0, t0) = w[0];
        let (n1, t1) = w[1];
        println!("{n0} → {n1}: throughput ÷{:.2}", t0 / t1);
    }
    let p1 = ModelPreset::Llama1B.shape().mapped_params() as f64;
    let p8 = ModelPreset::Llama8B.shape().mapped_params() as f64;
    println!("(parameter growth 1B→8B: ×{:.1} — throughput drop must be smaller)", p8 / p1);
}
