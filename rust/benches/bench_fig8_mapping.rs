//! Fig. 8 regeneration: spatial-mapping DSE cost distribution for an
//! attention layer of Llama 3.2-1B mapped onto 1024 macros.
//!
//! Paper claims reproduced here:
//!  * a few thousand heuristic-constrained candidates (paper: 2,592
//!    evaluated / 1,440 "valid"; ours: 3,456 — family set documented in
//!    mapping/candidates.rs);
//!  * exploration completes well inside the 20 s budget;
//!  * the selected (Fig. 4) mapping sits in the lowest tail of the
//!    distribution but is not the absolute minimum under the coarse X-Y
//!    cost.
//!
//! Run: `cargo bench --bench bench_fig8_mapping`

use leap::bench_util::{ascii_histogram, bench};
use leap::mapping::{explore, CostModel, paper_mapping};

fn main() {
    println!("=== Fig. 8: mapping-DSE communication-cost distribution ===\n");
    let res = explore(16, 128, 64);
    println!("candidates evaluated : {}", res.costs.len());
    println!("exploration time     : {:.3} s  (paper budget 20 s)", res.elapsed_s);
    println!("best cost            : {:.0}", res.best_cost());
    println!(
        "paper Fig. 4 mapping : {:.0}  → percentile p{:.2}",
        res.paper_cost(),
        res.paper_percentile()
    );
    println!("\nhistogram (cost → #candidates):");
    println!("{}\n", ascii_histogram(&res.histogram(24), 48));

    // hot-path timing: single-candidate evaluation (drives DSE latency)
    let model = CostModel::new(16, 128, 64);
    let cand = paper_mapping(16);
    bench("cost-model single evaluation (dc=16)", 10, 200, || model.evaluate(&cand));
    bench("full DSE (3456 candidates, dc=16)", 1, 5, || explore(16, 128, 64).best);

    // smaller/larger tiles for scaling context
    for dc in [4usize, 8, 32] {
        let r = explore(dc, 128, 64);
        println!(
            "dc={dc:<3} candidates={:<6} best={:<12.0} paper=p{:.1}",
            r.costs.len(),
            r.best_cost(),
            r.paper_percentile()
        );
    }
}
