//! Fig. 11 regeneration: breakdown of clock cycles on the critical path by
//! instruction class, for an attention layer + its MLP in Llama 3.2-1B,
//! prefill vs decode.
//!
//! Paper claims checked: PIM operations rarely on the critical path;
//! latency dominated by data movement (send) and IRCU DDMM compute
//! (mul/add). Both the analytical attribution and the instruction-level
//! mesh executor's per-class accounting are reported.
//!
//! Run: `cargo bench --bench bench_fig11_cycles`

use leap::arch::{Coord, HwParams, TileGeometry};
use leap::compiler::lower_phases;
use leap::model::ModelPreset;
use leap::noc::MeshSim;
use leap::schedule::prefill_phases;
use leap::sim::class_breakdown;

fn main() {
    let hw = HwParams::default();
    let shape = ModelPreset::Llama1B.shape();
    let geom = TileGeometry::for_model(shape.d_model, &hw);
    let s = 1024;

    println!("=== Fig. 11: critical-path cycles by instruction class ===");
    println!("(Llama 3.2-1B, attention layer + MLP, S = {s})\n");
    let (pre, dec) = class_breakdown(&shape, &geom, &hw, s);
    println!(
        "{:<8} {:>16} {:>8} {:>16} {:>8}",
        "class", "prefill cycles", "share", "decode cycles", "share"
    );
    for c in ["send", "mul", "add", "spad", "pim", "ctrl"] {
        println!(
            "{:<8} {:>16} {:>7.1}% {:>16} {:>7.1}%",
            c,
            pre.cycles.get(c).unwrap_or(&0),
            pre.share(c) * 100.0,
            dec.cycles.get(c).unwrap_or(&0),
            dec.share(c) * 100.0
        );
    }
    println!("{:<8} {:>16} {:>8} {:>16}", "total", pre.total(), "", dec.total());
    println!("\npaper claims: send+IRCU dominate; PIM rarely critical —");
    println!(
        "here: prefill send+mul+add = {:.0}%, pim = {:.1}%",
        (pre.share("send") + pre.share("mul") + pre.share("add")) * 100.0,
        pre.share("pim") * 100.0
    );

    // Cross-check: execute the compiled tiny-model program on the mesh and
    // show its per-class cycle mix agrees in ordering.
    println!("\n=== instruction-level cross-check (tiny model on a real mesh) ===");
    let tshape = ModelPreset::Tiny.shape();
    let tgeom = TileGeometry::for_model(tshape.d_model, &hw);
    let lp = prefill_phases(&tshape, &tgeom, &hw, 32);
    let prog = lower_phases("fig11-xcheck", &lp, &tgeom);
    let mut sim = MeshSim::new((2 * tgeom.dc) as u16, (2 * tgeom.dc) as u16, hw);
    for y in 0..sim.mesh.height {
        for x in 0..sim.mesh.width {
            sim.preload_spad(Coord::new(x, y), 4096);
        }
    }
    sim.run(&prog).unwrap();
    let total: u64 = sim.stats.class_cycles.values().sum();
    for (class, cycles) in &sim.stats.class_cycles {
        println!("{class:<8} {cycles:>12} cycles ({:>5.1}%)", *cycles as f64 / total as f64 * 100.0);
    }
    println!("hops={} stalls={} energy={:.3} µJ", sim.stats.hops, sim.stats.stalls, sim.ledger.dynamic_pj * 1e-6);
}
