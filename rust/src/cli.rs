//! Hand-rolled CLI (clap is unavailable offline).
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!   serve         — run the serving coordinator on a synthetic workload
//!   simulate      — end-to-end throughput/energy for one model+context
//!   map-explore   — spatial-mapping DSE (Fig. 8)
//!   compare-gpu   — LEAP vs A100/H100 (Table III)
//!   throughput    — model × context sweep (Fig. 10)
//!   breakdown     — per-instruction-class cycles (Fig. 11) + Table II
//!   sweep         — packet width × IRCU parallelism (Fig. 12)
//!   isa-demo      — assemble/disassemble a sample NPM program

use std::collections::HashMap;

use crate::arch::{HwParams, TileGeometry};
use crate::baselines::GpuModel;
use crate::coordinator::generation::DEFAULT_PRIORITY;
use crate::coordinator::{BatchPolicy, EngineConfig, GenerationConfig, Numerics, ServingEngine};
use crate::energy::{AreaBreakdown, MacroArea};
use crate::mapping::explore;
use crate::model::ModelPreset;
use crate::sim::{class_breakdown, AnalyticalSim};

/// Parsed command-line arguments: positional subcommand + `--key value`
/// options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub options: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut args = Args::default();
        let mut it = argv.iter();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it.next().cloned().unwrap_or_else(|| "true".into());
                args.options.insert(key.to_string(), val);
            }
        }
        args
    }

    pub fn get(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.options.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.options.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.options.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn model(&self) -> anyhow::Result<ModelPreset> {
        let name = self.get("model", "1b");
        ModelPreset::parse(&name).ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))
    }
}

pub const USAGE: &str = "\
leap — LLM inference on a scalable PIM-NoC architecture (paper reproduction)

USAGE: leap <command> [--key value ...]

COMMANDS
  serve        --model 1b --requests 8 --prompt 64 --gen 32
               [--numerics ref|synthetic|xla] [--artifacts DIR]
               [--chunk N] (chunked prefill; omit = monolithic)
               [--kv-dtype f32|f16|q8] (KV-cache storage; ref numerics only.
                f16 halves and q8 roughly quarters KV bytes/token, so the
                same pool byte budget admits more concurrent sessions)
               [--temp F --top-k N --top-p F --rep F --seed N]
               (sampling; --temp 0 = greedy. tiny model defaults to the
                pure-Rust reference backend; xla requires building with
                `--features xla`)
               [--trace true] [--trace-ring N] (structured tracing; any
                trace output flag below also enables it)
               [--trace-out FILE]   Chrome trace-event JSON (Perfetto/
                                    chrome://tracing loadable)
               [--events-out FILE]  JSONL event log, one event per line
               [--metrics-out FILE] Prometheus text exposition of the
                                    run's final metrics
               [--journal DIR] (crash-safe session journal: every submit/
                admit/token/preempt/finish is appended to DIR and
                periodically compacted into a checkpoint; replay with
                `leap recover`) [--checkpoint-every N] [--fsync always|
                never] (journal durability; default never)
               [--spill DIR|true] (spill preempted sessions' KV blocks to
                disk and restore them at readmission instead of
                re-prefilling — oversubscription mode; bare --spill uses
                <journal>/spill; enables spill-aware admission)
               [--fault-plan SPEC] (deterministic fault injection, e.g.
                'seed=7; site=journal_write at=3 mode=transient times=2';
                sites: journal_write spill_write spill_read lane_panic
                lane_stall block_alloc — see README 'Failure semantics')
               [--ttft-deadline-ns N] [--total-deadline-ns N] (per-request
                SLO deadlines on the simulated clock; an elapsed deadline
                aborts the request with a typed timeout, never a hang)
               [--priority N] (0-255 shedding class, default 100; under
                overload lower classes are shed first)
               [--max-waiting N] (overload cap on the wait queue; excess
                requests are shed lowest-priority-first, typed outcome)
  recover      --journal DIR [--model tiny --numerics ref|synthetic
               --artifacts DIR --kv-dtype ... --chunk N  (match the
                crashed run's engine flags)]
               (rebuild sessions from checkpoint + journal tail, print
                finished streams, continue unfinished ones — with the
                reference backend bitwise-identically to the lost run —
                and re-journal the continuation into DIR. A missing DIR
                is a typed error; an empty or torn-tail-only journal
                prints 'nothing to recover' and exits 0)
  scenario     --script FILE.scn | --suite DIR
               [--json-dir DIR] [--artifacts DIR] [--ab-chunk true]
               [--trace true] (force tracing even if the script omits
                `trace on`) [--trace-dir DIR] (write {scenario}.trace.json
                + {scenario}.events.jsonl per traced scenario; implies
                --trace)
               (declarative e2e traffic scripts — see rust/scenarios/;
                --ab-chunk also runs each scenario with chunking off and
                reports the per-session TTFT comparison)
  simulate     --model 8b --in 1024 --out 1024
  map-explore  [--dc 16]                         (Fig. 8)
  compare-gpu  [--in 1024 --out 1024]            (Table III)
  throughput   [--models 1b,8b,13b]              (Fig. 10)
  breakdown    --model 1b [--seq 1024]           (Fig. 11 + Table II)
  sweep        --model 1b [--in 1024 --out 1024] (Fig. 12)
  trace        [--dc 16]  per-router traffic heat map of the Fig. 4 mapping
  isa-demo
";

/// Entry point used by main.rs; returns the process exit code.
pub fn run(argv: &[String]) -> anyhow::Result<i32> {
    let args = Args::parse(argv);
    match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "recover" => cmd_recover(&args),
        "scenario" => cmd_scenario(&args),
        "simulate" => cmd_simulate(&args),
        "map-explore" => cmd_map_explore(&args),
        "compare-gpu" => cmd_compare_gpu(&args),
        "throughput" => cmd_throughput(&args),
        "breakdown" => cmd_breakdown(&args),
        "sweep" => cmd_sweep(&args),
        "trace" => cmd_trace(&args),
        "isa-demo" => cmd_isa_demo(),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            Ok(2)
        }
    }
}

/// Build a serving engine from the shared engine knobs (--model,
/// --numerics, --artifacts, --kv-dtype, --chunk) — `serve` and `recover`
/// must agree on these for recovery to continue the same numerics.
fn build_engine(args: &Args) -> anyhow::Result<ServingEngine> {
    let preset = args.model()?;
    let default_numerics = if preset == ModelPreset::Tiny { "ref" } else { "synthetic" };
    let which = args.get("numerics", default_numerics);
    let artifacts = || -> anyhow::Result<std::path::PathBuf> {
        anyhow::ensure!(
            preset == ModelPreset::Tiny,
            "functional numerics only exist for the tiny artifact model (got {preset})"
        );
        let explicit = args.options.get("artifacts").map(String::as_str);
        crate::runtime::default_artifacts_dir(explicit).ok_or_else(|| match explicit {
            Some(d) => anyhow::anyhow!("--artifacts {d}: no meta.txt there"),
            None => anyhow::anyhow!("no artifact directory with meta.txt found"),
        })
    };
    let kv_dtype = match args.options.get("kv-dtype") {
        None => None,
        Some(v) => Some(
            crate::kvcache::KvDtype::parse(v)
                .ok_or_else(|| anyhow::anyhow!("--kv-dtype {v}: expected f32, f16, or q8"))?,
        ),
    };
    let numerics = match which.as_str() {
        "synthetic" => Numerics::synthetic(preset.shape().vocab),
        "ref" | "reference" => match kv_dtype {
            None => Numerics::reference(artifacts()?)?,
            Some(dt) => Numerics::Backend(Box::new(
                crate::runtime::ReferenceBackend::load_with_kv_dtype(
                    artifacts()?,
                    crate::runtime::KernelMode::Fast,
                    dt,
                )?,
            )),
        },
        #[cfg(feature = "xla")]
        "xla" | "pjrt" => Numerics::pjrt(artifacts()?)?,
        #[cfg(not(feature = "xla"))]
        "xla" | "pjrt" => {
            anyhow::bail!("this binary was built without the `xla` feature")
        }
        other => anyhow::bail!("unknown numerics backend '{other}'"),
    };
    println!("numerics backend: {}", numerics.name());
    let mut engine = ServingEngine::new(EngineConfig {
        preset,
        hw: HwParams::default(),
        policy: BatchPolicy::default(),
        numerics,
    })?;
    // chunked prefill (omit = monolithic)
    engine.prefill_chunk = args.options.get("chunk").and_then(|v| v.parse().ok());
    Ok(engine)
}

/// Wire the durability flags (--journal, --checkpoint-every, --fsync,
/// --spill) into an engine. When recovering, call
/// [`crate::persist::reconstruct`] *before* this: `Journal::create`
/// truncates the directory's previous journal.
fn attach_durability(engine: &mut ServingEngine, args: &Args) -> anyhow::Result<()> {
    use crate::persist::{FsyncPolicy, Journal, SpillStore, DEFAULT_CHECKPOINT_EVERY};
    let journal_dir = args.options.get("journal").map(std::path::PathBuf::from);
    if let Some(dir) = &journal_dir {
        let fsync_arg = args.get("fsync", "never");
        let fsync = FsyncPolicy::parse(&fsync_arg)
            .ok_or_else(|| anyhow::anyhow!("--fsync {fsync_arg}: expected always or never"))?;
        let every = args.get_u64("checkpoint-every", DEFAULT_CHECKPOINT_EVERY);
        engine.journal = Some(Journal::create(dir, fsync, every)?);
    }
    if let Some(spec) = args.options.get("spill") {
        let dir = if spec == "true" {
            journal_dir.as_ref().map(|d| d.join("spill")).ok_or_else(|| {
                anyhow::anyhow!("bare --spill needs --journal DIR (or pass --spill DIR)")
            })?
        } else {
            std::path::PathBuf::from(spec)
        };
        engine.spill = Some(SpillStore::create(&dir)?);
        engine.admission.spill_aware = true;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<i32> {
    let preset = args.model()?;
    let n_requests = args.get_usize("requests", 8);
    let prompt_len = args.get_usize("prompt", 64);
    let gen = args.get_usize("gen", 32);
    let mut engine = build_engine(args)?;
    attach_durability(&mut engine, args)?;
    if let Some(spec) = args.options.get("fault-plan") {
        engine.faults = crate::faults::FaultPlan::parse(spec)?;
    }
    if let Some(cap) = args.options.get("max-waiting") {
        let cap = cap
            .parse()
            .map_err(|_| anyhow::anyhow!("--max-waiting {cap}: expected a queue depth"))?;
        engine.overload.max_waiting = Some(cap);
    }
    // Any trace output path implies tracing; --trace true enables it on
    // its own (counters still print even with nowhere to export).
    let trace_out = args.options.get("trace-out").map(std::path::PathBuf::from);
    let events_out = args.options.get("events-out").map(std::path::PathBuf::from);
    let metrics_out = args.options.get("metrics-out").map(std::path::PathBuf::from);
    let trace_on = args.get("trace", "false") == "true"
        || trace_out.is_some()
        || events_out.is_some();
    if trace_on {
        let ring = args.get_usize("trace-ring", crate::obs::DEFAULT_RING_CAPACITY);
        engine.tracer = crate::obs::Tracer::enabled(ring);
    }
    let gen_cfg = GenerationConfig {
        max_new_tokens: gen,
        temperature: args.get_f32("temp", 0.0),
        top_k: args.get_usize("top-k", 0),
        top_p: args.get_f32("top-p", 1.0),
        repetition_penalty: args.get_f32("rep", 1.0),
        stop: Vec::new(),
        seed: args.get_u64("seed", 0),
        ttft_deadline_ns: args.options.get("ttft-deadline-ns").and_then(|v| v.parse().ok()),
        total_deadline_ns: args.options.get("total-deadline-ns").and_then(|v| v.parse().ok()),
        priority: args.get_usize("priority", DEFAULT_PRIORITY as usize) as u8,
    };
    for i in 0..n_requests {
        let prompt: Vec<i32> =
            (0..prompt_len).map(|k| ((i * 31 + k * 7) % preset.shape().vocab) as i32).collect();
        // a typed rejection drops this request only; the run keeps serving
        // (the engine counts it in the `rejected` summary line)
        if let Err(err) = engine.submit_with(prompt, gen_cfg.clone()) {
            eprintln!("request {i} rejected: {err}");
        }
    }
    engine.run_until_idle()?;
    let m = &engine.metrics;
    let (lp50, lp99) = m.latency_p50_p99();
    let (tp50, tp99) = m.ttft_p50_p99();
    println!("model           : {preset}");
    println!(
        "requests done   : {} (failed {}, rejected {})",
        m.requests_done, m.requests_failed, m.requests_rejected
    );
    if m.requests_timeout > 0 || m.requests_shed > 0 {
        println!(
            "slo             : {} timed out, {} shed under overload",
            m.requests_timeout, m.requests_shed
        );
    }
    if m.faults_injected > 0 {
        println!(
            "faults injected : {} ({} persist retries, {} lane deaths)",
            m.faults_injected, m.persist_retries, m.pool_lane_deaths
        );
    }
    println!("prefill tokens  : {} ({} chunks)", m.prefill_tokens, m.prefill_chunks);
    println!("decode tokens   : {}", m.decode_tokens);
    println!("sim time        : {:.3} s", m.sim_time_ns as f64 * 1e-9);
    println!("throughput      : {:.2} tok/s (decode {:.2})", m.total_tokens_per_s(), m.decode_tokens_per_s());
    println!("energy          : {:.3} J ({:.2} tok/J)", m.energy_j, m.tokens_per_j());
    println!("latency p50/p99 : {:.2} / {:.2} ms", lp50 as f64 * 1e-6, lp99 as f64 * 1e-6);
    println!("ttft    p50/p99 : {:.2} / {:.2} ms", tp50 as f64 * 1e-6, tp99 as f64 * 1e-6);
    println!("npm swaps       : {}", m.npm_swaps);
    println!("host overhead   : {:.4}×", m.host_overhead());
    println!("simd kernels    : {}", crate::runtime::simd::level().as_str());
    if m.kv_blocks_total > 0 {
        println!(
            "kv pool         : {} blocks × {} tokens, peak {} used ({:.1}%)",
            m.kv_blocks_total,
            m.kv_block_size,
            m.kv_peak_blocks_used,
            100.0 * m.kv_peak_blocks_used as f64 / m.kv_blocks_total as f64
        );
        println!(
            "kv storage      : {} ({} B/token across both arenas, all layers)",
            m.kv_dtype.as_str(),
            m.kv_bytes_per_token
        );
        println!(
            "kv sharing      : prefix hit {:.1}% ({}/{} probes), {} CoW copies, \
             {} preemptions",
            100.0 * m.kv_prefix_hit_rate(),
            m.kv_prefix_hits,
            m.kv_prefix_lookups,
            m.kv_cow_copies,
            m.preemptions
        );
    }
    if m.kv_spills > 0 || m.sessions_recovered > 0 {
        println!(
            "kv spill        : {} spills / {} blocks ({} B written, {} B read), \
             {} sessions recovered",
            m.kv_spills,
            m.kv_spilled_blocks,
            m.spill_bytes_written,
            m.spill_bytes_read,
            m.sessions_recovered
        );
    }
    // Naive-mode (and LEAP_THREADS=1) backends hold a lane-less stub pool
    // that never dispatches — only report a pool that can actually engage.
    if m.pool_threads > 1 || m.pool_dispatches > 0 {
        println!(
            "worker pool     : {} lanes, {} tile dispatches ({} parks / {} wakes; \
             0 spawns after load)",
            m.pool_threads, m.pool_dispatches, m.pool_parks, m.pool_wakes
        );
    }
    if engine.tracer.is_enabled() {
        println!(
            "trace           : {} events recorded, {} dropped (ring full)",
            engine.tracer.recorded(),
            engine.tracer.dropped()
        );
    }
    if let Some(p) = &trace_out {
        std::fs::write(p, crate::obs::chrome_trace_json(&engine.tracer))
            .map_err(|e| anyhow::anyhow!("--trace-out {}: {e}", p.display()))?;
        println!("trace-out       : {}", p.display());
    }
    if let Some(p) = &events_out {
        std::fs::write(p, crate::obs::events_jsonl(&engine.tracer))
            .map_err(|e| anyhow::anyhow!("--events-out {}: {e}", p.display()))?;
        println!("events-out      : {}", p.display());
    }
    if let Some(p) = &metrics_out {
        std::fs::write(p, crate::obs::prometheus_text(&engine.metrics))
            .map_err(|e| anyhow::anyhow!("--metrics-out {}: {e}", p.display()))?;
        println!("metrics-out     : {}", p.display());
    }
    Ok(0)
}

fn cmd_recover(args: &Args) -> anyhow::Result<i32> {
    let dir = std::path::PathBuf::from(
        args.options
            .get("journal")
            .ok_or_else(|| anyhow::anyhow!("recover needs --journal DIR"))?,
    );
    // Typed pre-flight: a missing/non-directory path is a clear error
    // before any replay machinery runs.
    crate::persist::check_journal_dir(&dir)?;
    let state = crate::persist::reconstruct(&dir)?;
    if state.sessions.is_empty() {
        // An empty journal (or one holding only a torn tail from a crash
        // mid-first-write) is a clean no-op, not a failure.
        let torn = if state.torn_tail {
            " (torn tail only — crash before the first complete record)"
        } else {
            ""
        };
        println!("nothing to recover: journal at {} holds no sessions{torn}", dir.display());
        return Ok(0);
    }
    println!(
        "journal         : {} sessions ({} unfinished), checkpoint covers {}, \
         {} tail records{}",
        state.sessions.len(),
        state.unfinished().count(),
        state.checkpoint_covers,
        state.replay_events,
        if state.torn_tail { ", torn tail (crash mid-write)" } else { "" }
    );
    let mut engine = build_engine(args)?;
    // re-journal the continuation into the same directory — safe only
    // because reconstruct() above already read the crashed history
    attach_durability(&mut engine, args)?;
    engine.metrics.recovery_replay_events = state.replay_events;
    let mut resumed = Vec::new();
    for s in &state.sessions {
        if s.finished {
            let status = if s.failed { "failed, journaled" } else { "done, journaled" };
            println!("session {:>4}    : [{status}] {}", s.id, join_tokens(&s.output));
        } else {
            match engine.resubmit_recovered(s.prompt.clone(), s.gen.clone(), s.output.clone()) {
                Ok(id) => resumed.push((s.id, id)),
                Err(err) => println!("session {:>4}    : resubmit rejected: {err}", s.id),
            }
        }
    }
    engine.run_until_idle()?;
    for (orig, id) in resumed {
        match engine.take_finished_request(id) {
            Some(r) => {
                let status = if r.state == crate::coordinator::RequestState::Done {
                    "recovered"
                } else {
                    "failed"
                };
                println!("session {orig:>4}    : [{status}] {}", join_tokens(&r.output));
            }
            None => println!("session {orig:>4}    : lost after resubmit"),
        }
    }
    let m = &engine.metrics;
    println!(
        "recovered       : {} sessions continued, {} replay records, {} decode tokens",
        m.sessions_recovered, m.recovery_replay_events, m.decode_tokens
    );
    Ok(0)
}

fn join_tokens(tokens: &[i32]) -> String {
    tokens.iter().map(i32::to_string).collect::<Vec<_>>().join(",")
}

fn cmd_scenario(args: &Args) -> anyhow::Result<i32> {
    use crate::scenario::{chunk_ab_json, Scenario};
    // collect scripts: one --script, or every *.scn under --suite (sorted)
    let mut scripts: Vec<std::path::PathBuf> = Vec::new();
    if let Some(s) = args.options.get("script") {
        scripts.push(s.into());
    } else if let Some(dir) = args.options.get("suite") {
        for entry in std::fs::read_dir(dir)
            .map_err(|e| anyhow::anyhow!("--suite {dir}: {e}"))?
        {
            let path = entry?.path();
            if path.extension().is_some_and(|x| x == "scn") {
                scripts.push(path);
            }
        }
        scripts.sort();
        anyhow::ensure!(!scripts.is_empty(), "--suite {dir}: no .scn scripts there");
    } else {
        anyhow::bail!("scenario needs --script FILE.scn or --suite DIR");
    }
    let artifacts = args.options.get("artifacts").map(std::path::PathBuf::from);
    let ab = args.get("ab-chunk", "false") == "true";
    let json_dir = args.options.get("json-dir").map(std::path::PathBuf::from);
    if let Some(d) = &json_dir {
        std::fs::create_dir_all(d)?;
    }
    // --trace-dir implies tracing; --trace true forces it for scripts
    // that omit `trace on` (tracing is bitwise-invisible, so forcing it
    // cannot change any expectation verdict)
    let trace_dir = args.options.get("trace-dir").map(std::path::PathBuf::from);
    if let Some(d) = &trace_dir {
        std::fs::create_dir_all(d)?;
    }
    let force_trace = args.get("trace", "false") == "true" || trace_dir.is_some();

    let mut all_passed = true;
    for path in &scripts {
        let mut sc = Scenario::load(path)?;
        sc.trace |= force_trace;
        let (report, json, passed) = if ab && sc.chunk.is_some() {
            let (on, off) = sc.run_chunk_ab(artifacts.as_deref())?;
            let json = chunk_ab_json(&on, &off);
            let passed = on.passed() && off.passed();
            (on, json, passed)
        } else {
            let report = sc.run(artifacts.as_deref())?;
            let json = report.to_json();
            let passed = report.passed();
            (report, json, passed)
        };
        let verdict = if passed { "PASS" } else { "FAIL" };
        println!(
            "{verdict} {:<16} sessions {:>2}  done {:>2}  rejected {} preempt {} \
             prefix-hits {} ttft-p50 {:.2} ms",
            report.scenario,
            report.sessions.len(),
            report.metrics.requests_done,
            report.metrics.requests_rejected,
            report.metrics.preemptions,
            report.metrics.kv_prefix_hits,
            report.metrics.ttft_p50_p99().0 as f64 * 1e-6,
        );
        for f in &report.expect_failures {
            println!("     ! {f}");
        }
        if let Some(d) = &json_dir {
            let suffix = if ab && sc.chunk.is_some() { "_ab" } else { "" };
            let out = d.join(format!("{}{suffix}.json", report.scenario));
            std::fs::write(&out, &json)?;
            println!("     → {}", out.display());
        }
        if let (Some(d), Some(trace)) = (&trace_dir, &report.trace) {
            let chrome = d.join(format!("{}.trace.json", report.scenario));
            std::fs::write(&chrome, &trace.chrome_json)?;
            let jsonl = d.join(format!("{}.events.jsonl", report.scenario));
            std::fs::write(&jsonl, &trace.jsonl)?;
            println!(
                "     → {} + {} ({} events, {} dropped)",
                chrome.display(),
                jsonl.display(),
                trace.recorded,
                trace.dropped
            );
        }
        all_passed &= passed;
    }
    Ok(if all_passed { 0 } else { 1 })
}

fn cmd_simulate(args: &Args) -> anyhow::Result<i32> {
    let preset = args.model()?;
    let inp = args.get_usize("in", 1024);
    let out = args.get_usize("out", 1024);
    let r = AnalyticalSim::new(preset, HwParams::default()).run(inp, out);
    println!("model             : {}", r.model);
    println!("workload          : {} in + {} out tokens", r.in_tokens, r.out_tokens);
    println!("mapped macros     : {} ({} tiles)", r.mapped_macros, r.mapped_macros / 1024);
    println!("prefill           : {:.3} s ({:.1} tok/s)", r.prefill.seconds, r.prefill.tokens_per_s);
    println!("decode            : {:.3} s ({:.1} tok/s)", r.decode.seconds, r.decode.tokens_per_s);
    println!("total throughput  : {:.2} tok/s (gen {:.2})", r.total_tokens_per_s, r.gen_tokens_per_s);
    println!("energy            : {:.3} J", r.total_energy_j);
    println!("energy efficiency : {:.2} tok/J", r.tokens_per_j);
    println!("avg power         : {:.2} W", r.avg_power_w);
    Ok(0)
}

fn cmd_map_explore(args: &Args) -> anyhow::Result<i32> {
    let dc = args.get_usize("dc", 16);
    let res = explore(dc, 128, 64);
    println!("candidates evaluated : {}", res.costs.len());
    println!("explore time         : {:.2} s (paper budget: 20 s)", res.elapsed_s);
    println!("best cost            : {:.0}", res.best_cost());
    println!("paper mapping cost   : {:.0} (p{:.1})", res.paper_cost(), res.paper_percentile());
    println!("\ncommunication-cost distribution (Fig. 8):");
    println!("{}", crate::bench_util::ascii_histogram(&res.histogram(24), 48));
    Ok(0)
}

fn cmd_compare_gpu(args: &Args) -> anyhow::Result<i32> {
    let inp = args.get_usize("in", 1024);
    let out = args.get_usize("out", 1024);
    println!("Table III — LEAP vs GPUs ({inp} in + {out} out)\n");
    println!("{:<14} {:>12} {:>12} {:>12} {:>10}", "model", "ours tok/s", "A100 tok/s", "H100 tok/s", "ours W");
    for preset in [ModelPreset::Llama8B, ModelPreset::Llama13B] {
        let shape = preset.shape();
        let ours = AnalyticalSim::new(preset, HwParams::default()).run(inp, out);
        let a100 = GpuModel::a100().run(&shape, inp, out);
        let h100 = GpuModel::h100().run(&shape, inp, out);
        println!(
            "{:<14} {:>12.2} {:>12.2} {:>12.2} {:>10.2}",
            shape.name, ours.gen_tokens_per_s, a100.gen_tokens_per_s, h100.gen_tokens_per_s, ours.avg_power_w
        );
        println!(
            "{:<14} {:>12.2} {:>12.4} {:>12.4}   (tok/J)",
            "", ours.tokens_per_j, a100.tokens_per_j, h100.tokens_per_j
        );
    }
    Ok(0)
}

fn cmd_throughput(args: &Args) -> anyhow::Result<i32> {
    let models = args.get("models", "1b,8b,13b");
    println!("Fig. 10 — throughput across models and context windows\n");
    println!("{:<14} {:>8} {:>8} {:>12} {:>12} {:>12}", "model", "in", "out", "prefill t/s", "decode t/s", "total t/s");
    for name in models.split(',') {
        let preset = ModelPreset::parse(name.trim())
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
        let sim = AnalyticalSim::new(preset, HwParams::default());
        for (inp, out) in [(128, 128), (512, 512), (1024, 1024), (2048, 2048)] {
            let r = sim.run(inp, out);
            println!(
                "{:<14} {:>8} {:>8} {:>12.1} {:>12.2} {:>12.2}",
                preset.shape().name, inp, out, r.prefill.tokens_per_s, r.decode.tokens_per_s, r.total_tokens_per_s
            );
        }
    }
    Ok(0)
}

fn cmd_breakdown(args: &Args) -> anyhow::Result<i32> {
    let preset = args.model()?;
    let s = args.get_usize("seq", 1024);
    let hw = HwParams::default();
    let shape = preset.shape();
    let geom = TileGeometry::for_model(shape.d_model, &hw);
    let (pre, dec) = class_breakdown(&shape, &geom, &hw, s);
    println!("Fig. 11 — cycle breakdown by instruction class ({}, S={s})\n", shape.name);
    println!("{:<8} {:>14} {:>8} {:>14} {:>8}", "class", "prefill cyc", "%", "decode cyc", "%");
    for c in ["send", "mul", "add", "spad", "pim", "ctrl"] {
        println!(
            "{:<8} {:>14} {:>7.1}% {:>14} {:>7.1}%",
            c,
            pre.cycles.get(c).unwrap_or(&0),
            pre.share(c) * 100.0,
            dec.cycles.get(c).unwrap_or(&0),
            dec.share(c) * 100.0
        );
    }
    println!("\nTable II — macro power & area breakdown (7 nm)\n");
    let m = MacroArea::default();
    let shares = m.shares();
    for (i, comp) in ["PIM PE", "Scratchpad", "Router"].iter().enumerate() {
        println!("{comp:<12} power {:>6.1}%   area {:>6.1}%", shares[i].0, shares[i].1);
    }
    let sys = AreaBreakdown::new(64 * 1024);
    println!("\nTable I system: {:.2} W peak, {:.1} mm² total", sys.peak_power_w(), sys.total_area_mm2());
    Ok(0)
}

fn cmd_sweep(args: &Args) -> anyhow::Result<i32> {
    let preset = args.model()?;
    let inp = args.get_usize("in", 1024);
    let out = args.get_usize("out", 1024);
    println!("Fig. 12 — packet width × IRCU parallelism ({preset})\n");
    println!("{:>10} {:>8} {:>14}", "packet b", "MACs", "total tok/s");
    for packet_bits in [16u32, 32, 64, 128, 256] {
        for macs in [4usize, 8, 16, 32, 64] {
            let mut hw = HwParams::default();
            hw.packet_bits = packet_bits;
            hw.ircu_macs = macs;
            let r = AnalyticalSim::new(preset, hw).run(inp, out);
            println!("{packet_bits:>10} {macs:>8} {:>14.2}", r.total_tokens_per_s);
        }
    }
    Ok(0)
}

fn cmd_trace(args: &Args) -> anyhow::Result<i32> {
    use crate::mapping::paper_mapping;
    use crate::sim::TrafficMatrix;
    let dc = args.get_usize("dc", 16);
    let tm = TrafficMatrix::from_mapping(&paper_mapping(dc), dc);
    println!("per-router X-Y traffic of the Fig. 4 mapping (dc={dc}; 0-9 heat scale):\n");
    println!("{}", tm.heatmap());
    println!("mean load   : {:.1} routes/router", tm.mean());
    println!("peak load   : {} routes", tm.max());
    println!("peak/mean   : {:.2} (1.0 = perfectly balanced)", tm.imbalance());
    println!("coeff. var. : {:.2}", tm.cv());
    Ok(0)
}

fn cmd_isa_demo() -> anyhow::Result<i32> {
    use crate::isa::{assemble, disassemble, Cmd, Instruction, Opcode, Program, SelBits};
    let mut p = Program::new("demo: one projection + reduce step");
    p.push(Instruction::uni(Cmd::new(Opcode::PeMvm, 0), 4, SelBits::All));
    p.push(Instruction::dual(
        Cmd::new(Opcode::RouteE, 1),
        Cmd::new(Opcode::Mac, 0),
        32,
        SelBits::SplitRows { lo: 0, hi: 16, lo2: 16, hi2: 32 },
    ));
    p.push(Instruction::uni(Cmd::new(Opcode::ReduceS, 0), 16, SelBits::Cols { lo: 8, hi: 16 }));
    let p = p.sealed();
    let hex = assemble(&p);
    println!("— program —\n{p}");
    println!("— NPM hex —\n{hex}");
    let q = disassemble(&hex)?;
    println!("— disassembled roundtrip: {} instructions, label '{}' —", q.len(), q.label);
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parse_options() {
        let a = Args::parse(&argv("simulate --model 8b --in 512"));
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get("model", "1b"), "8b");
        assert_eq!(a.get_usize("in", 0), 512);
        assert_eq!(a.get_usize("out", 7), 7);
        assert_eq!(a.model().unwrap(), ModelPreset::Llama8B);
    }

    #[test]
    fn unknown_command_exit_code() {
        assert_eq!(run(&argv("bogus")).unwrap(), 2);
        assert_eq!(run(&argv("help")).unwrap(), 0);
    }

    #[test]
    fn fast_commands_run() {
        assert_eq!(run(&argv("breakdown --model 1b --seq 256")).unwrap(), 0);
        assert_eq!(run(&argv("trace --dc 4")).unwrap(), 0);
        assert_eq!(run(&argv("isa-demo")).unwrap(), 0);
        assert_eq!(run(&argv("simulate --model tiny --in 32 --out 8")).unwrap(), 0);
    }

    #[test]
    fn bad_model_errors() {
        assert!(run(&argv("simulate --model 70b")).is_err());
    }

    #[test]
    fn scenario_command_runs_synthetic_script() {
        let dir = std::env::temp_dir().join("leap_cli_scn_test");
        std::fs::create_dir_all(&dir).unwrap();
        let script = dir.join("demo.scn");
        std::fs::write(
            &script,
            "scenario demo\nnumerics synthetic\nchunk 16\n\
             session prompt=rand:40:1 gen=4\nsession prompt=rand:8:2 gen=2\n",
        )
        .unwrap();
        let cmd = format!(
            "scenario --script {} --json-dir {} --ab-chunk true",
            script.display(),
            dir.display()
        );
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let json = std::fs::read_to_string(dir.join("demo_ab.json")).unwrap();
        assert!(json.contains("\"chunk_on\""), "A/B artifact must embed both runs");
        // a missing script is an error, not a crash
        assert!(run(&argv("scenario --script /nonexistent.scn")).is_err());
        // an expectation failure exits nonzero
        std::fs::write(&script, "scenario bad\nnumerics synthetic\nsession prompt=rand:8:3 gen=2 expect=rejected\n")
            .unwrap();
        let cmd = format!("scenario --script {}", script.display());
        assert_eq!(run(&argv(&cmd)).unwrap(), 1);
    }

    #[test]
    fn serve_writes_trace_and_metrics_files() {
        let dir = std::env::temp_dir().join("leap_cli_serve_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("serve.trace.json");
        let events = dir.join("serve.events.jsonl");
        let metrics = dir.join("serve.prom");
        let cmd = format!(
            "serve --model 1b --numerics synthetic --requests 2 --prompt 8 \
             --gen 4 --trace-out {} --events-out {} --metrics-out {}",
            trace.display(),
            events.display(),
            metrics.display()
        );
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let chrome = std::fs::read_to_string(&trace).unwrap();
        assert!(chrome.contains("\"traceEvents\""), "Chrome trace envelope");
        assert!(chrome.contains("\"finish\""), "lifecycle spans exported");
        let jsonl = std::fs::read_to_string(&events).unwrap();
        assert!(jsonl.lines().count() > 0, "JSONL log is non-empty");
        assert!(jsonl.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
        let prom = std::fs::read_to_string(&metrics).unwrap();
        assert!(prom.contains("leap_requests_done_total 2"), "prom counters:\n{prom}");
    }

    #[test]
    fn serve_journal_then_recover_reports_finished_streams() {
        let dir = std::env::temp_dir().join("leap_cli_recover_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let jdir = dir.join("journal");
        let cmd = format!(
            "serve --model 1b --numerics synthetic --requests 3 --prompt 8 --gen 4 \
             --journal {} --checkpoint-every 5 --fsync always",
            jdir.display()
        );
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let state = crate::persist::reconstruct(&jdir).unwrap();
        assert_eq!(state.sessions.len(), 3, "every request journaled");
        assert!(state.sessions.iter().all(|s| s.finished), "clean run journals all finishes");
        assert!(state.checkpoint_covers >= 5, "--checkpoint-every 5 compacted");
        // recover replays the journal and reports the finished streams
        let cmd = format!("recover --journal {} --model 1b --numerics synthetic", jdir.display());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        // a bogus fsync policy is a typed error
        let cmd = format!("serve --model 1b --numerics synthetic --requests 1 --prompt 4 \
             --gen 2 --journal {} --fsync sometimes", jdir.display());
        assert!(run(&argv(&cmd)).is_err());
        // bare --spill without --journal is a typed error too
        assert!(run(&argv("serve --model 1b --numerics synthetic --requests 1 \
             --prompt 4 --gen 2 --spill")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_preflight_missing_dir_typed_empty_dir_clean_exit() {
        let dir = std::env::temp_dir().join("leap_cli_recover_preflight_test");
        let _ = std::fs::remove_dir_all(&dir);
        // missing directory → typed error naming the path
        let cmd = format!("recover --journal {} --model 1b --numerics synthetic", dir.display());
        let err = run(&argv(&cmd)).unwrap_err();
        assert!(err.to_string().contains("does not exist"), "{err}");
        // empty directory → "nothing to recover", exit 0
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        // torn-tail-only journal (crash before the first complete record)
        // → still nothing to recover, exit 0
        std::fs::write(dir.join(crate::persist::JOURNAL_FILE), [1u8, 2, 3]).unwrap();
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_fault_plan_and_slo_flags_wire_through() {
        // permanent block_alloc fault: every admission fails typed, the
        // queue drains, and the run still exits 0 (typed, not a crash)
        let cmd = "serve --model 1b --numerics synthetic --requests 2 --prompt 8 \
                   --gen 4 --fault-plan site=block_alloc --max-waiting 8";
        assert_eq!(run(&argv(cmd)).unwrap(), 0);
        // a malformed plan is a typed error at startup
        assert!(run(&argv(
            "serve --model 1b --numerics synthetic --requests 1 --fault-plan site=warp_core"
        ))
        .is_err());
        // an immediate TTFT deadline times every request out, typed
        let cmd = "serve --model 1b --numerics synthetic --requests 2 --prompt 8 \
                   --gen 4 --ttft-deadline-ns 0 --priority 5";
        assert_eq!(run(&argv(cmd)).unwrap(), 0);
        // a bogus overload cap is a typed error
        assert!(run(&argv(
            "serve --model 1b --numerics synthetic --requests 1 --max-waiting lots"
        ))
        .is_err());
    }

    #[test]
    fn scenario_trace_dir_forces_tracing_and_writes_artifacts() {
        let dir = std::env::temp_dir().join("leap_cli_scn_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let script = dir.join("quiet.scn");
        // no `trace on` in the script — --trace-dir must force it
        std::fs::write(
            &script,
            "scenario quiet\nnumerics synthetic\nsession prompt=rand:8:5 gen=3\n",
        )
        .unwrap();
        let cmd =
            format!("scenario --script {} --trace-dir {}", script.display(), dir.display());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let chrome = std::fs::read_to_string(dir.join("quiet.trace.json")).unwrap();
        assert!(chrome.contains("\"traceEvents\""));
        let jsonl = std::fs::read_to_string(dir.join("quiet.events.jsonl")).unwrap();
        assert!(jsonl.lines().count() > 0);
    }
}
