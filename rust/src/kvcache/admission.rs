//! Preemption-aware admission policy: admit/queue/reject against actual
//! free blocks (not session slots). The serving engine combines this with
//! a preemption loop — an admitted request that later starves the pool is
//! preempted (blocks released, re-queued) and re-prefilled on readmission.

/// What to do with the request at the head of the wait queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Enough free blocks right now: admit and prefill.
    Admit,
    /// Not now — wait for running requests to finish or be preempted.
    Queue,
    /// Can never run in this pool (needs more blocks than exist).
    Reject,
}

/// Block-granular admission knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionPolicy {
    /// Judge admission against the full `prompt + max_new` context
    /// (conservative: far fewer preemptions, lower occupancy). The
    /// reservation is evaluated at the admission *decision* only — blocks
    /// are physically claimed as the context grows, so concurrent
    /// admissions across later rounds can still oversubscribe the pool
    /// and preempt; it is a strong bias, not a hard guarantee. The
    /// default judges the prefill only and relies on preemption when
    /// decode growth outruns the pool — higher occupancy, the vLLM
    /// discipline.
    pub reserve_output: bool,
    /// Keep at least this many blocks free after admitting (headroom so
    /// one decode round of boundary crossings doesn't immediately preempt).
    pub watermark_blocks: usize,
    /// Preempted KV spills to disk instead of being recomputed. Preemption
    /// then costs one disk round-trip, not a re-prefill, so the watermark
    /// headroom is waived and the pool runs oversubscribed at full
    /// occupancy — the point of the spill store.
    pub spill_aware: bool,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self { reserve_output: false, watermark_blocks: 1, spill_aware: false }
    }
}

impl AdmissionPolicy {
    /// KV positions to reserve at admission for a request that will
    /// prefill `prefill_tokens` and may generate `max_new` more. (The last
    /// generated token never enters the cache, hence `max_new - 1`.)
    pub fn reserve_tokens(&self, prefill_tokens: usize, max_new: usize) -> usize {
        if self.reserve_output {
            prefill_tokens + max_new.saturating_sub(1)
        } else {
            prefill_tokens
        }
    }

    /// Decide for a request needing `need_blocks` (worst case, ignoring
    /// prefix sharing) against a pool of `total` blocks with `free` free.
    ///
    /// The watermark is headroom against immediate re-preemption, so a
    /// fully idle pool (`free == total`) admits even a request that needs
    /// every block — otherwise a request sized at exactly the pool could
    /// queue forever behind its own watermark.
    pub fn decide(&self, need_blocks: usize, free: usize, total: usize) -> AdmissionDecision {
        let watermark = if self.spill_aware { 0 } else { self.watermark_blocks };
        if need_blocks > total {
            AdmissionDecision::Reject
        } else if need_blocks + watermark <= free || (free == total && need_blocks <= free) {
            AdmissionDecision::Admit
        } else {
            AdmissionDecision::Queue
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decide_three_ways() {
        let p = AdmissionPolicy { reserve_output: false, watermark_blocks: 1, spill_aware: false };
        assert_eq!(p.decide(4, 8, 16), AdmissionDecision::Admit);
        assert_eq!(p.decide(8, 8, 16), AdmissionDecision::Queue); // watermark
        assert_eq!(p.decide(17, 16, 16), AdmissionDecision::Reject);
        // an idle pool admits a pool-sized request despite the watermark
        assert_eq!(p.decide(16, 16, 16), AdmissionDecision::Admit);
        assert_eq!(p.decide(16, 15, 16), AdmissionDecision::Queue);
    }

    #[test]
    fn reserve_modes() {
        let optimistic = AdmissionPolicy::default();
        assert_eq!(optimistic.reserve_tokens(10, 5), 10);
        let conservative =
            AdmissionPolicy { reserve_output: true, watermark_blocks: 0, spill_aware: false };
        assert_eq!(conservative.reserve_tokens(10, 5), 14);
        assert_eq!(conservative.reserve_tokens(10, 0), 10);
    }

    #[test]
    fn spill_aware_waives_the_watermark() {
        let p = AdmissionPolicy { spill_aware: true, ..AdmissionPolicy::default() };
        // watermark_blocks = 1, but spilling makes preemption cheap:
        // a request that exactly fills the free blocks is admitted
        assert_eq!(p.decide(8, 8, 16), AdmissionDecision::Admit);
        assert_eq!(p.decide(9, 8, 16), AdmissionDecision::Queue);
        assert_eq!(p.decide(17, 16, 16), AdmissionDecision::Reject);
    }
}
