//! Pooled KV storage: fixed-size f32 blocks shared by every session.
//!
//! A [`KvStore`] owns two flat arenas (K and V) of
//! `n_blocks × n_layers × block_size × d_model` words plus a
//! [`BlockLedger`]; each session holds a [`BlockTable`] mapping its token
//! positions to physical blocks (`position p` lives in table block
//! `p / block_size`, row `p % block_size`). Blocks are the unit of
//! admission, sharing, and preemption:
//!
//! - **Prefix sharing.** [`KvStore::build_prefill`] walks the prompt in
//!   block-size chunks through the ledger's exact prefix cache; matching
//!   chunks (including a matching partial tail) map to the *same* physical
//!   block with a refcount, so N sessions with a common system prompt
//!   consume far fewer than `N × ceil(s/block_size)` blocks. After the
//!   forward pass fills the fresh blocks, [`KvStore::seal_prefill`]
//!   registers them for future prompts.
//! - **Copy-on-write.** Appending into a shared tail block copies the
//!   filled rows into a private block first ([`KvStore::grow`]), so no
//!   physical block ever has two writers.
//! - **Preemption.** Releasing a table returns its blocks to the pool;
//!   the coordinator re-prefills the session's tokens on readmission.
//!
//! The block size defaults to one tile row group
//! ([`crate::arch::TileGeometry::shard_rows`]) — the granularity at which
//! the simulated hardware shards the KV cache across routers (§IV-C).

use anyhow::Context;

use crate::arch::{HwParams, TileGeometry};

use super::ledger::{BlockId, BlockLedger, PoolStats, PrefixKey};

/// Pool-shape knobs for a [`KvStore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheConfig {
    /// Tokens per block (one tile row group by default).
    pub block_size: usize,
    /// Physical blocks in the pool.
    pub n_blocks: usize,
    /// Enable prompt-prefix sharing (identical prefixes map to the same
    /// physical blocks). Disable for strictly private sessions.
    pub prefix_sharing: bool,
}

impl KvCacheConfig {
    /// Default pool for a model: block size = the tile row group of the
    /// model's geometry, pool sized for a healthy running batch
    /// (32 full-window sessions).
    pub fn for_model(d_model: usize, s_max: usize) -> Self {
        let geom = TileGeometry::for_model(d_model, &HwParams::default());
        let block_size = geom.shard_rows.max(1);
        let blocks_per_session = s_max.div_ceil(block_size).max(1);
        Self { block_size, n_blocks: 32 * blocks_per_session, prefix_sharing: true }
    }

    /// Worst-case blocks a session of `tokens` KV positions needs
    /// (ignoring any prefix sharing).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }
}

/// One session's block mapping: physical block ids in position order.
#[derive(Debug, Default, Clone)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
    /// Token positions this table covers (`blocks.len() == ceil(len/bs)`).
    len: usize,
    /// Positions `[0, shared_prefix)` were resolved from the prefix cache
    /// at prefill: their KV rows already exist and must not be rewritten.
    shared_prefix: usize,
}

impl BlockTable {
    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// KV positions covered.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Prompt positions mapped from the prefix cache at prefill.
    pub fn shared_prefix(&self) -> usize {
        self.shared_prefix
    }
}

/// The pooled KV cache: block arenas + ledger. All sessions of one backend
/// share one store.
pub struct KvStore {
    cfg: KvCacheConfig,
    ledger: BlockLedger,
    n_layers: usize,
    d: usize,
    /// K arena, `[n_blocks][n_layers][block_size][d]` row-major.
    k: Vec<f32>,
    /// V arena, same layout.
    v: Vec<f32>,
}

impl KvStore {
    pub fn new(cfg: KvCacheConfig, n_layers: usize, d: usize) -> Self {
        assert!(cfg.block_size > 0 && cfg.n_blocks > 0, "degenerate KV pool config");
        let words = cfg.n_blocks * n_layers * cfg.block_size * d;
        Self {
            cfg,
            ledger: BlockLedger::new(cfg.n_blocks),
            n_layers,
            d,
            k: vec![0f32; words],
            v: vec![0f32; words],
        }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    pub fn ledger(&self) -> &BlockLedger {
        &self.ledger
    }

    pub fn free_blocks(&self) -> usize {
        self.ledger.free_blocks()
    }

    /// Occupancy/sharing snapshot with `block_size` filled in.
    pub fn stats(&self) -> PoolStats {
        PoolStats { block_size: self.cfg.block_size, ..self.ledger.stats() }
    }

    /// Arena offset of `(block, layer)` — identical for the K and V arenas.
    #[inline]
    fn off(&self, b: BlockId, layer: usize) -> usize {
        (b as usize * self.n_layers + layer) * self.cfg.block_size * self.d
    }

    /// The whole K arena. Paged kernels index it directly with the offsets
    /// produced by [`Self::append_starts`].
    pub fn k_arena(&self) -> &[f32] {
        &self.k
    }

    /// The whole V arena (same layout as [`Self::k_arena`]).
    pub fn v_arena(&self) -> &[f32] {
        &self.v
    }

    /// The `[block_size, d]` K slice of one block at one layer.
    pub fn k_block(&self, b: BlockId, layer: usize) -> &[f32] {
        let o = self.off(b, layer);
        &self.k[o..o + self.cfg.block_size * self.d]
    }

    /// The `[block_size, d]` V slice of one block at one layer.
    pub fn v_block(&self, b: BlockId, layer: usize) -> &[f32] {
        let o = self.off(b, layer);
        &self.v[o..o + self.cfg.block_size * self.d]
    }

    /// Append the arena offsets of `table`'s blocks at `layer` to `starts`
    /// (valid for both arenas — kernels add `row * d` per position). One
    /// flat buffer carries every session of a batched forward pass, each
    /// session recording its own offset run; callers clear between layers.
    pub fn append_starts(&self, table: &BlockTable, layer: usize, starts: &mut Vec<usize>) {
        starts.extend(table.blocks.iter().map(|&b| self.off(b, layer)));
    }

    /// Write one position's K/V rows into `(block, layer, row)`. The block
    /// must be privately held — shared blocks are copied first by
    /// [`Self::grow`].
    pub fn write_row(&mut self, b: BlockId, layer: usize, row: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert!(!self.ledger.is_shared(b), "write into a shared KV block (missing CoW)");
        debug_assert!(row < self.cfg.block_size);
        let o = self.off(b, layer) + row * self.d;
        self.k[o..o + self.d].copy_from_slice(krow);
        self.v[o..o + self.d].copy_from_slice(vrow);
    }

    /// Worst-case free blocks [`Self::grow`] would claim to extend `table`
    /// by `new_positions` tokens (boundary blocks + a possible
    /// copy-on-write of a shared tail).
    pub fn grow_demand(&self, table: &BlockTable, new_positions: usize) -> usize {
        if new_positions == 0 {
            return 0;
        }
        let bs = self.cfg.block_size;
        let mut demand = (table.len + new_positions).div_ceil(bs) - table.blocks.len();
        if table.len % bs != 0 && self.ledger.is_shared(table.blocks[table.len / bs]) {
            demand += 1; // CoW of the shared tail before the first write
        }
        demand
    }

    /// Reserve `new_positions` more token positions in `table`: allocate
    /// boundary blocks, copy-on-write a shared tail, and unseal a sealed
    /// private tail whose content is about to diverge. Callers that need
    /// all-or-nothing semantics check [`Self::grow_demand`] against
    /// [`Self::free_blocks`] first — with enough free blocks this cannot
    /// fail.
    pub fn grow(&mut self, table: &mut BlockTable, new_positions: usize) -> anyhow::Result<()> {
        if new_positions == 0 {
            return Ok(());
        }
        let bs = self.cfg.block_size;
        if table.len % bs != 0 {
            // The first new position lands mid-block: the tail must be
            // privately writable.
            let bi = table.len / bs;
            let b = table.blocks[bi];
            if self.ledger.is_shared(b) {
                let nb = self.ledger.alloc().context("KV block pool exhausted (CoW)")?;
                let rows = table.len % bs;
                for layer in 0..self.n_layers {
                    let src = self.off(b, layer);
                    let dst = self.off(nb, layer);
                    let n = rows * self.d;
                    self.k.copy_within(src..src + n, dst);
                    self.v.copy_within(src..src + n, dst);
                }
                self.ledger.release(b);
                table.blocks[bi] = nb;
                self.ledger.note_cow();
            } else if self.ledger.is_sealed(b) {
                self.ledger.unseal(b);
            }
        }
        let need = (table.len + new_positions).div_ceil(bs) - table.blocks.len();
        for _ in 0..need {
            table.blocks.push(self.ledger.alloc().context("KV block pool exhausted")?);
        }
        table.len += new_positions;
        Ok(())
    }

    /// Start a session table for `tokens`, resolving as much of the prompt
    /// as possible from the prefix cache. The returned table covers only
    /// the shared prefix (`len == shared_prefix`); the forward pass grows
    /// it over the remaining positions and writes their KV rows.
    pub fn build_prefill(&mut self, tokens: &[i32]) -> BlockTable {
        let mut table = BlockTable::default();
        if !self.cfg.prefix_sharing {
            return table;
        }
        let mut parent = None;
        for chunk in tokens.chunks(self.cfg.block_size) {
            let key = PrefixKey { parent, tokens: chunk.to_vec() };
            let Some(b) = self.ledger.lookup_retain(&key) else { break };
            table.blocks.push(b);
            table.len += chunk.len();
            table.shared_prefix += chunk.len();
            parent = Some(b);
        }
        table
    }

    /// Register the fresh prompt blocks of a completed prefill in the
    /// prefix cache so future identical prefixes share them. Both full
    /// chunks and the partial tail are sealed (the key carries the exact
    /// chunk, so fills of different lengths never alias).
    pub fn seal_prefill(&mut self, table: &BlockTable, tokens: &[i32]) {
        if !self.cfg.prefix_sharing {
            return;
        }
        let mut parent = None;
        for (i, chunk) in tokens.chunks(self.cfg.block_size).enumerate() {
            let b = table.blocks[i];
            if i * self.cfg.block_size >= table.shared_prefix {
                self.ledger.seal(b, PrefixKey { parent, tokens: chunk.to_vec() });
            }
            parent = Some(b);
        }
    }

    /// Release every block a table holds (refcount-decrement; physical
    /// blocks free when the last sharer releases).
    pub fn release_table(&mut self, table: BlockTable) {
        for b in table.blocks {
            self.ledger.release(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(bs: usize, n_blocks: usize) -> KvStore {
        KvStore::new(
            KvCacheConfig { block_size: bs, n_blocks, prefix_sharing: true },
            2, // layers
            4, // d
        )
    }

    /// Grow a fresh table over `tokens` and write distinct rows, sealing at
    /// the end — a miniature prefill without the model forward.
    fn prefill(s: &mut KvStore, tokens: &[i32], salt: f32) -> BlockTable {
        let mut t = s.build_prefill(tokens);
        let new = tokens.len() - t.len();
        s.grow(&mut t, new).unwrap();
        for pos in t.shared_prefix()..tokens.len() {
            let b = t.blocks()[pos / s.cfg.block_size];
            for layer in 0..2 {
                let row = vec![salt + pos as f32 + layer as f32 * 0.5; 4];
                s.write_row(b, layer, pos % s.cfg.block_size, &row, &row);
            }
        }
        s.seal_prefill(&t, tokens);
        t
    }

    #[test]
    fn identical_prompts_share_all_blocks() {
        let mut s = store(2, 16);
        let a = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        let used_after_a = s.ledger().used_blocks();
        let b = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        assert_eq!(a.blocks(), b.blocks(), "identical prompt must map to the same blocks");
        assert_eq!(b.shared_prefix(), 4);
        assert_eq!(s.ledger().used_blocks(), used_after_a, "no new physical blocks");
        s.release_table(a);
        assert_eq!(s.ledger().used_blocks(), used_after_a, "b still holds them");
        s.release_table(b);
        assert_eq!(s.ledger().used_blocks(), 0);
    }

    #[test]
    fn shared_prefix_diverging_suffix() {
        let mut s = store(2, 16);
        let a = prefill(&mut s, &[1, 2, 3, 4, 5, 6], 0.0);
        let b = prefill(&mut s, &[1, 2, 3, 4, 9, 9], 0.0);
        assert_eq!(b.shared_prefix(), 4);
        assert_eq!(&a.blocks()[..2], &b.blocks()[..2]);
        assert_ne!(a.blocks()[2], b.blocks()[2]);
        // 3 blocks for a + 1 private block for b
        assert_eq!(s.ledger().used_blocks(), 4);
        s.release_table(a);
        s.release_table(b);
    }

    #[test]
    fn partial_tail_shares_and_cow_on_append() {
        let mut s = store(4, 16);
        // 6 tokens = 1 full block + a partial tail of 2 — both sealed
        let a = prefill(&mut s, &[1, 2, 3, 4, 5, 6], 1.0);
        let mut b = prefill(&mut s, &[1, 2, 3, 4, 5, 6], 0.0);
        assert_eq!(b.shared_prefix(), 6, "partial tail chunk must share too");
        assert_eq!(s.ledger().used_blocks(), 2);

        // b appends into the shared tail → CoW: one fresh private block,
        // a's view untouched
        let tail_before = b.blocks()[1];
        assert_eq!(s.grow_demand(&b, 1), 1);
        s.grow(&mut b, 1).unwrap();
        let tail_after = b.blocks()[1];
        assert_ne!(tail_before, tail_after, "CoW must swap the tail block");
        assert_eq!(a.blocks()[1], tail_before);
        assert_eq!(s.ledger().refcount(tail_before), 1);
        assert_eq!(s.stats().cow_copies, 1);
        // the copied rows carry a's values (salt 1.0 from the first fill)
        assert_eq!(s.k_block(tail_after, 0)[0], 1.0 + 4.0);
        s.write_row(tail_after, 0, 2, &[9.0; 4], &[9.0; 4]);
        s.release_table(a);
        s.release_table(b);
        assert_eq!(s.ledger().used_blocks(), 0);
    }

    #[test]
    fn sole_owner_append_unseals_instead_of_copying() {
        let mut s = store(4, 8);
        let mut a = prefill(&mut s, &[1, 2, 3, 4, 5], 0.0);
        assert_eq!(s.ledger().cached_prefix_blocks(), 2);
        assert_eq!(s.grow_demand(&a, 1), 0);
        s.grow(&mut a, 1).unwrap();
        // the partial tail's cache entry is gone (content diverged) but no
        // copy happened
        assert_eq!(s.ledger().cached_prefix_blocks(), 1);
        assert_eq!(s.stats().cow_copies, 0);
        s.release_table(a);
    }

    #[test]
    fn grow_demand_counts_boundary_blocks() {
        let mut s = store(4, 8);
        let a = prefill(&mut s, &[1, 2, 3], 0.0);
        assert_eq!(s.grow_demand(&a, 1), 0); // fills the tail
        assert_eq!(s.grow_demand(&a, 2), 1); // crosses one boundary
        assert_eq!(s.grow_demand(&a, 6), 2);
        s.release_table(a);
    }

    #[test]
    fn pool_exhaustion_is_an_error() {
        let mut s = store(2, 2);
        let mut a = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        assert!(s.grow(&mut a, 1).is_err());
        s.release_table(a);
    }

    #[test]
    fn sharing_disabled_allocates_privately() {
        let mut s = KvStore::new(
            KvCacheConfig { block_size: 2, n_blocks: 8, prefix_sharing: false },
            1,
            4,
        );
        let a = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        let b = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        assert_eq!(b.shared_prefix(), 0);
        assert_ne!(a.blocks()[0], b.blocks()[0]);
        assert_eq!(s.ledger().used_blocks(), 4);
        s.release_table(a);
        s.release_table(b);
    }

    #[test]
    fn default_config_aligns_with_tile_geometry() {
        let cfg = KvCacheConfig::for_model(256, 128);
        assert_eq!(cfg.block_size, 2, "tiny model: shard_rows = 2");
        assert_eq!(cfg.n_blocks, 32 * 64);
        assert!(cfg.prefix_sharing);
        assert_eq!(cfg.blocks_for(5), 3);
        let cfg1b = KvCacheConfig::for_model(2048, 4096);
        assert_eq!(cfg1b.block_size, 16, "Table I: C_S = 16 rows");
    }
}
