//! Pooled KV storage: fixed-size typed blocks shared by every session.
//!
//! A [`KvStore`] owns two flat arenas (K and V) of
//! `n_blocks × n_layers × block_size × d_model` elements plus a
//! [`BlockLedger`]; each session holds a [`BlockTable`] mapping its token
//! positions to physical blocks (`position p` lives in table block
//! `p / block_size`, row `p % block_size`). Arenas are stored at a
//! configurable [`KvDtype`] — full f32, IEEE half (f16, 2× residency),
//! or symmetric per-row int8 (q8, ~4× residency; one f32 scale per
//! `d_model`-wide row, quantized at [`KvStore::write_row`] and consumed
//! in place by the paged attention readers). Blocks are the unit of
//! admission, sharing, and preemption:
//!
//! - **Prefix sharing.** [`KvStore::build_prefill`] walks the prompt in
//!   block-size chunks through the ledger's exact prefix cache; matching
//!   chunks (including a matching partial tail) map to the *same* physical
//!   block with a refcount, so N sessions with a common system prompt
//!   consume far fewer than `N × ceil(s/block_size)` blocks. After the
//!   forward pass fills the fresh blocks, [`KvStore::seal_prefill`]
//!   registers them for future prompts.
//! - **Copy-on-write.** Appending into a shared tail block copies the
//!   filled rows into a private block first ([`KvStore::grow`]), so no
//!   physical block ever has two writers.
//! - **Preemption.** Releasing a table returns its blocks to the pool;
//!   the coordinator re-prefills the session's tokens on readmission.
//!
//! The block size defaults to one tile row group
//! ([`crate::arch::TileGeometry::shard_rows`]) — the granularity at which
//! the simulated hardware shards the KV cache across routers (§IV-C).

use anyhow::{ensure, Context};

use crate::arch::{HwParams, TileGeometry};

use super::ledger::{BlockId, BlockLedger, PoolStats, PrefixKey};

/// Storage dtype of the pooled KV arenas.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// Full-precision rows; the bitwise-exact baseline.
    #[default]
    F32,
    /// IEEE binary16 rows (round-to-nearest-even on write), 2× residency.
    F16,
    /// Symmetric int8 rows with one f32 scale per `d`-wide row,
    /// ~4× residency; attention scores run `dot_q8` on the stored cells.
    Q8,
}

impl KvDtype {
    /// Parse a CLI/scenario spelling. Case-insensitive.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(Self::F32),
            "f16" | "fp16" | "half" => Some(Self::F16),
            "q8" | "i8" | "int8" => Some(Self::Q8),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F16 => "f16",
            Self::Q8 => "q8",
        }
    }

    /// Bytes one `d`-wide KV row occupies in an arena (including the q8
    /// per-row scale).
    pub fn row_bytes(self, d: usize) -> usize {
        match self {
            Self::F32 => 4 * d,
            Self::F16 => 2 * d,
            Self::Q8 => d + 4,
        }
    }
}

/// Convert f32 → IEEE binary16 bits with round-to-nearest-even.
/// Handles normals, subnormals, overflow-to-inf, and NaN payloads.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf stays inf; NaN keeps a quiet bit plus the top payload bits.
        let payload = if man == 0 { 0 } else { 0x0200 | ((man >> 13) as u16 & 0x03ff) | 1 };
        return sign | 0x7c00 | payload;
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if unbiased >= -14 {
        // Normal half: drop 13 mantissa bits, round to nearest even. A
        // round-up can carry into the exponent; 0x7c00 (inf) is then the
        // correct saturation.
        let mut h = ((unbiased + 15) as u32) << 10 | (man >> 13);
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && h & 1 != 0) {
            h += 1;
        }
        return sign | h as u16;
    }
    if unbiased >= -25 {
        // Subnormal half: value = m * 2^-24 for m in [0, 1024).
        let man_full = man | 0x0080_0000;
        let shift = (-unbiased - 1) as u32; // 14..=24
        let h = man_full >> shift;
        let rem = man_full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let h = if rem > half || (rem == half && h & 1 != 0) { h + 1 } else { h };
        // h == 1024 after round-up is exactly the smallest normal (0x0400).
        return sign | h as u16;
    }
    sign // underflows to ±0
}

/// Convert IEEE binary16 bits → f32. Exact (every half is an f32).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x03ff) as u32;
    match (exp, man) {
        (0, 0) => f32::from_bits(sign),
        (0, _) => {
            // Subnormal: m * 2^-24, both factors exact in f32.
            let v = man as f32 * f32::from_bits(0x3380_0000);
            if sign != 0 {
                -v
            } else {
                v
            }
        }
        (0x1f, 0) => f32::from_bits(sign | 0x7f80_0000),
        (0x1f, _) => f32::from_bits(sign | 0x7f80_0000 | (man << 13)),
        _ => f32::from_bits(sign | ((exp as u32 + 112) << 23) | (man << 13)),
    }
}

/// Pool-shape knobs for a [`KvStore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheConfig {
    /// Tokens per block (one tile row group by default).
    pub block_size: usize,
    /// Physical blocks in the pool.
    pub n_blocks: usize,
    /// Enable prompt-prefix sharing (identical prefixes map to the same
    /// physical blocks). Disable for strictly private sessions.
    pub prefix_sharing: bool,
    /// Storage dtype of the K/V arenas.
    pub dtype: KvDtype,
}

impl KvCacheConfig {
    /// Default pool for a model: block size = the tile row group of the
    /// model's geometry, pool sized for a healthy running batch
    /// (32 full-window sessions).
    pub fn for_model(d_model: usize, s_max: usize) -> Self {
        let geom = TileGeometry::for_model(d_model, &HwParams::default());
        let block_size = geom.shard_rows.max(1);
        let blocks_per_session = s_max.div_ceil(block_size).max(1);
        Self {
            block_size,
            n_blocks: 32 * blocks_per_session,
            prefix_sharing: true,
            dtype: KvDtype::F32,
        }
    }

    /// Worst-case blocks a session of `tokens` KV positions needs
    /// (ignoring any prefix sharing).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Bytes one token position occupies across both arenas and all layers.
    pub fn bytes_per_token(&self, n_layers: usize, d: usize) -> usize {
        2 * n_layers * self.dtype.row_bytes(d)
    }

    /// Bytes one physical block occupies across both arenas and all layers.
    pub fn bytes_per_block(&self, n_layers: usize, d: usize) -> usize {
        self.block_size * self.bytes_per_token(n_layers, d)
    }

    /// Largest pool (block count) that fits a byte budget at this dtype;
    /// at least one block.
    pub fn blocks_for_bytes(&self, bytes: usize, n_layers: usize, d: usize) -> usize {
        (bytes / self.bytes_per_block(n_layers, d)).max(1)
    }
}

/// A borrowed, dtype-tagged arena the paged attention kernels read in
/// place. Offsets from [`KvStore::append_starts`] are *element* offsets,
/// valid for every variant; q8 carries the per-row scale plane
/// (`scale index = row_element_offset / d`).
#[derive(Clone, Copy)]
pub enum KvView<'a> {
    F32(&'a [f32]),
    F16(&'a [u16]),
    Q8 { q: &'a [i8], s: &'a [f32] },
}

impl KvView<'_> {
    /// Dequantize `out.len()` elements starting `base` into a row whose
    /// first element sits at element offset `row_start` (`row_start` must
    /// be row-aligned: divisible by `d`). Used by the naive readers and
    /// tests; the fused kernels consume the variants directly.
    pub fn read_into(&self, row_start: usize, d: usize, base: usize, out: &mut [f32]) {
        debug_assert_eq!(row_start % d, 0, "row_start must be row-aligned");
        debug_assert!(base + out.len() <= d);
        let at = row_start + base;
        match *self {
            KvView::F32(a) => out.copy_from_slice(&a[at..at + out.len()]),
            KvView::F16(a) => {
                for (x, &hb) in out.iter_mut().zip(&a[at..at + out.len()]) {
                    *x = f16_to_f32(hb);
                }
            }
            KvView::Q8 { q, s } => {
                let scale = s[row_start / d];
                for (x, &qv) in out.iter_mut().zip(&q[at..at + out.len()]) {
                    *x = scale * qv as f32;
                }
            }
        }
    }
}

/// A dtype-preserving snapshot of one session's KV rows, `[pos][layer][d]`
/// row-major. `k`/`v` hold the *stored* representation as little-endian
/// element bytes (f32 words, f16 halfwords, or raw q8 cells); q8 also
/// carries one scale per `(pos, layer)` row so a restore never re-rounds.
/// This is what the spill store serializes on preemption and what
/// [`KvStore::write_raw_rows`] replays back into the pool bit-exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct SpillImage {
    pub dtype: KvDtype,
    pub n_layers: usize,
    pub d: usize,
    /// Token positions captured.
    pub rows: usize,
    /// K rows, `rows × n_layers × d` elements as stored bytes.
    pub k: Vec<u8>,
    /// V rows, same layout.
    pub v: Vec<u8>,
    /// q8 per-row scales (`rows × n_layers`), empty for f32/f16.
    pub k_scales: Vec<f32>,
    pub v_scales: Vec<f32>,
}

impl SpillImage {
    /// Stored bytes per element for `dtype` (scales excluded).
    pub fn elem_bytes(dtype: KvDtype) -> usize {
        match dtype {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
            KvDtype::Q8 => 1,
        }
    }

    /// Check the byte/scale array lengths against the declared shape.
    pub fn validate(&self) -> anyhow::Result<()> {
        let want = self.rows * self.n_layers * self.d * Self::elem_bytes(self.dtype);
        ensure!(
            self.k.len() == want && self.v.len() == want,
            "spill image arrays ({}K/{}V bytes) don't match shape ({} rows × {} layers × d={} {:?} = {want})",
            self.k.len(),
            self.v.len(),
            self.rows,
            self.n_layers,
            self.d,
            self.dtype,
        );
        let scales = if self.dtype == KvDtype::Q8 { self.rows * self.n_layers } else { 0 };
        ensure!(
            self.k_scales.len() == scales && self.v_scales.len() == scales,
            "spill image scales ({}K/{}V) don't match {:?} expectation ({scales})",
            self.k_scales.len(),
            self.v_scales.len(),
            self.dtype,
        );
        Ok(())
    }
}

/// Owned, dtype-tagged arena storage. Quantization happens once at
/// [`KvArena::write_row`]; copy-on-write moves the stored representation
/// (and q8 scales) verbatim, so a CoW never re-rounds values.
enum KvArena {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Q8 { q: Vec<i8>, s: Vec<f32> },
}

impl KvArena {
    fn new(dtype: KvDtype, elems: usize, rows: usize) -> Self {
        match dtype {
            KvDtype::F32 => Self::F32(vec![0f32; elems]),
            KvDtype::F16 => Self::F16(vec![0u16; elems]),
            KvDtype::Q8 => Self::Q8 { q: vec![0i8; elems], s: vec![0f32; rows] },
        }
    }

    fn view(&self) -> KvView<'_> {
        match self {
            Self::F32(a) => KvView::F32(a),
            Self::F16(a) => KvView::F16(a),
            Self::Q8 { q, s } => KvView::Q8 { q, s },
        }
    }

    fn as_f32(&self) -> &[f32] {
        match self {
            Self::F32(a) => a,
            _ => panic!("f32 arena accessor used on a quantized KV pool; use the view API"),
        }
    }

    /// Store one `d`-wide row at element offset `o` (row-aligned).
    fn write_row(&mut self, o: usize, src: &[f32]) {
        let d = src.len();
        match self {
            Self::F32(a) => a[o..o + d].copy_from_slice(src),
            Self::F16(a) => {
                for (hb, &x) in a[o..o + d].iter_mut().zip(src) {
                    *hb = f32_to_f16(x);
                }
            }
            Self::Q8 { q, s } => {
                let amax = src.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let scale = if amax > 0.0 { amax / 127.0 } else { 0.0 };
                s[o / d] = scale;
                let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
                for (qc, &x) in q[o..o + d].iter_mut().zip(src) {
                    *qc = (x * inv).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
    }

    /// Append the stored `d`-wide row at element offset `o` to `bytes`
    /// (little-endian element bytes); q8 also pushes the row scale.
    fn export_row(&self, o: usize, d: usize, bytes: &mut Vec<u8>, scales: &mut Vec<f32>) {
        match self {
            Self::F32(a) => {
                for &x in &a[o..o + d] {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
            Self::F16(a) => {
                for &h in &a[o..o + d] {
                    bytes.extend_from_slice(&h.to_le_bytes());
                }
            }
            Self::Q8 { q, s } => {
                bytes.extend(q[o..o + d].iter().map(|&c| c as u8));
                scales.push(s[o / d]);
            }
        }
    }

    /// Write one exported row back verbatim at element offset `o` —
    /// the exact inverse of [`Self::export_row`], no re-quantization.
    fn import_row(&mut self, o: usize, d: usize, bytes: &[u8], scale: f32) {
        match self {
            Self::F32(a) => {
                for (x, w) in a[o..o + d].iter_mut().zip(bytes.chunks_exact(4)) {
                    *x = f32::from_le_bytes(w.try_into().unwrap());
                }
            }
            Self::F16(a) => {
                for (h, w) in a[o..o + d].iter_mut().zip(bytes.chunks_exact(2)) {
                    *h = u16::from_le_bytes(w.try_into().unwrap());
                }
            }
            Self::Q8 { q, s } => {
                for (c, &b) in q[o..o + d].iter_mut().zip(bytes) {
                    *c = b as i8;
                }
                s[o / d] = scale;
            }
        }
    }

    /// Copy `src..src + n` to `dst` (all row-aligned multiples of `d`),
    /// moving q8 scales alongside the cells.
    fn copy_rows_within(&mut self, src: usize, n: usize, dst: usize, d: usize) {
        debug_assert!(src % d == 0 && n % d == 0 && dst % d == 0);
        match self {
            Self::F32(a) => a.copy_within(src..src + n, dst),
            Self::F16(a) => a.copy_within(src..src + n, dst),
            Self::Q8 { q, s } => {
                q.copy_within(src..src + n, dst);
                s.copy_within(src / d..(src + n) / d, dst / d);
            }
        }
    }
}

/// One session's block mapping: physical block ids in position order.
#[derive(Debug, Default, Clone)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
    /// Token positions this table covers (`blocks.len() == ceil(len/bs)`).
    len: usize,
    /// Positions `[0, shared_prefix)` were resolved from the prefix cache
    /// at prefill: their KV rows already exist and must not be rewritten.
    shared_prefix: usize,
}

impl BlockTable {
    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// KV positions covered.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Prompt positions mapped from the prefix cache at prefill.
    pub fn shared_prefix(&self) -> usize {
        self.shared_prefix
    }
}

/// The pooled KV cache: block arenas + ledger. All sessions of one backend
/// share one store.
pub struct KvStore {
    cfg: KvCacheConfig,
    ledger: BlockLedger,
    n_layers: usize,
    d: usize,
    /// K arena, `[n_blocks][n_layers][block_size][d]` row-major.
    k: KvArena,
    /// V arena, same layout.
    v: KvArena,
}

impl KvStore {
    pub fn new(cfg: KvCacheConfig, n_layers: usize, d: usize) -> Self {
        assert!(cfg.block_size > 0 && cfg.n_blocks > 0, "degenerate KV pool config");
        let rows = cfg.n_blocks * n_layers * cfg.block_size;
        let elems = rows * d;
        Self {
            cfg,
            ledger: BlockLedger::new(cfg.n_blocks),
            n_layers,
            d,
            k: KvArena::new(cfg.dtype, elems, rows),
            v: KvArena::new(cfg.dtype, elems, rows),
        }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    pub fn ledger(&self) -> &BlockLedger {
        &self.ledger
    }

    pub fn free_blocks(&self) -> usize {
        self.ledger.free_blocks()
    }

    /// Spill-gauge passthrough: `blocks` worth of content just left the
    /// pool for a spill file (see [`super::ledger::BlockLedger::note_spill`]).
    pub fn note_spilled(&mut self, blocks: usize) {
        self.ledger.note_spill(blocks);
    }

    /// Spill-gauge passthrough: `blocks` worth of spilled content was
    /// restored into (or abandoned to) the pool.
    pub fn note_restored(&mut self, blocks: usize) {
        self.ledger.note_restore(blocks);
    }

    /// Bytes one token position occupies across both arenas and all layers.
    pub fn bytes_per_token(&self) -> usize {
        self.cfg.bytes_per_token(self.n_layers, self.d)
    }

    /// Occupancy/sharing snapshot with the pool shape (`block_size`,
    /// `dtype`, `bytes_per_token`) filled in over the ledger's counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            block_size: self.cfg.block_size,
            dtype: self.cfg.dtype,
            bytes_per_token: self.bytes_per_token(),
            ..self.ledger.stats()
        }
    }

    /// Arena offset of `(block, layer)` — identical for the K and V arenas.
    #[inline]
    fn off(&self, b: BlockId, layer: usize) -> usize {
        (b as usize * self.n_layers + layer) * self.cfg.block_size * self.d
    }

    /// The whole K arena as f32. Paged kernels index it directly with the
    /// offsets produced by [`Self::append_starts`]. Panics unless the pool
    /// dtype is [`KvDtype::F32`] — quantized pools go through
    /// [`Self::k_view`].
    pub fn k_arena(&self) -> &[f32] {
        self.k.as_f32()
    }

    /// The whole V arena (same layout as [`Self::k_arena`]).
    pub fn v_arena(&self) -> &[f32] {
        self.v.as_f32()
    }

    /// Dtype-tagged view of the K arena, valid for every pool dtype.
    pub fn k_view(&self) -> KvView<'_> {
        self.k.view()
    }

    /// Dtype-tagged view of the V arena.
    pub fn v_view(&self) -> KvView<'_> {
        self.v.view()
    }

    /// The `[block_size, d]` K slice of one block at one layer (f32 pools
    /// only; see [`Self::k_view`] + [`KvView::read_into`] otherwise).
    pub fn k_block(&self, b: BlockId, layer: usize) -> &[f32] {
        let o = self.off(b, layer);
        &self.k.as_f32()[o..o + self.cfg.block_size * self.d]
    }

    /// The `[block_size, d]` V slice of one block at one layer.
    pub fn v_block(&self, b: BlockId, layer: usize) -> &[f32] {
        let o = self.off(b, layer);
        &self.v.as_f32()[o..o + self.cfg.block_size * self.d]
    }

    /// Element offset of `(block, layer, row)` — the `row_start` argument
    /// of [`KvView::read_into`].
    #[inline]
    pub fn row_start(&self, b: BlockId, layer: usize, row: usize) -> usize {
        debug_assert!(row < self.cfg.block_size);
        self.off(b, layer) + row * self.d
    }

    /// Append the arena offsets of `table`'s blocks at `layer` to `starts`
    /// (valid for both arenas — kernels add `row * d` per position). One
    /// flat buffer carries every session of a batched forward pass, each
    /// session recording its own offset run; callers clear between layers.
    pub fn append_starts(&self, table: &BlockTable, layer: usize, starts: &mut Vec<usize>) {
        starts.extend(table.blocks.iter().map(|&b| self.off(b, layer)));
    }

    /// Write one position's K/V rows into `(block, layer, row)`,
    /// quantizing to the pool dtype. The block must be privately held —
    /// shared blocks are copied first by [`Self::grow`].
    pub fn write_row(&mut self, b: BlockId, layer: usize, row: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert!(!self.ledger.is_shared(b), "write into a shared KV block (missing CoW)");
        debug_assert!(row < self.cfg.block_size);
        debug_assert!(krow.len() == self.d && vrow.len() == self.d);
        let o = self.off(b, layer) + row * self.d;
        self.k.write_row(o, krow);
        self.v.write_row(o, vrow);
    }

    /// Worst-case free blocks [`Self::grow`] would claim to extend `table`
    /// by `new_positions` tokens (boundary blocks + a possible
    /// copy-on-write of a shared tail).
    pub fn grow_demand(&self, table: &BlockTable, new_positions: usize) -> usize {
        if new_positions == 0 {
            return 0;
        }
        let bs = self.cfg.block_size;
        let mut demand = (table.len + new_positions).div_ceil(bs) - table.blocks.len();
        if table.len % bs != 0 && self.ledger.is_shared(table.blocks[table.len / bs]) {
            demand += 1; // CoW of the shared tail before the first write
        }
        demand
    }

    /// Reserve `new_positions` more token positions in `table`: allocate
    /// boundary blocks, copy-on-write a shared tail, and unseal a sealed
    /// private tail whose content is about to diverge. Callers that need
    /// all-or-nothing semantics check [`Self::grow_demand`] against
    /// [`Self::free_blocks`] first — with enough free blocks this cannot
    /// fail.
    pub fn grow(&mut self, table: &mut BlockTable, new_positions: usize) -> anyhow::Result<()> {
        if new_positions == 0 {
            return Ok(());
        }
        let bs = self.cfg.block_size;
        if table.len % bs != 0 {
            // The first new position lands mid-block: the tail must be
            // privately writable.
            let bi = table.len / bs;
            let b = table.blocks[bi];
            if self.ledger.is_shared(b) {
                let nb = self.ledger.alloc().context("KV block pool exhausted (CoW)")?;
                let rows = table.len % bs;
                for layer in 0..self.n_layers {
                    let src = self.off(b, layer);
                    let dst = self.off(nb, layer);
                    let n = rows * self.d;
                    self.k.copy_rows_within(src, n, dst, self.d);
                    self.v.copy_rows_within(src, n, dst, self.d);
                }
                self.ledger.release(b);
                table.blocks[bi] = nb;
                self.ledger.note_cow();
            } else if self.ledger.is_sealed(b) {
                self.ledger.unseal(b);
            }
        }
        let need = (table.len + new_positions).div_ceil(bs) - table.blocks.len();
        for _ in 0..need {
            table.blocks.push(self.ledger.alloc().context("KV block pool exhausted")?);
        }
        table.len += new_positions;
        Ok(())
    }

    /// Start a session table for `tokens`, resolving as much of the prompt
    /// as possible from the prefix cache. The returned table covers only
    /// the shared prefix (`len == shared_prefix`); the forward pass grows
    /// it over the remaining positions and writes their KV rows.
    pub fn build_prefill(&mut self, tokens: &[i32]) -> BlockTable {
        let mut table = BlockTable::default();
        if !self.cfg.prefix_sharing {
            return table;
        }
        let mut parent = None;
        for chunk in tokens.chunks(self.cfg.block_size) {
            let key = PrefixKey { parent, tokens: chunk.to_vec() };
            let Some(b) = self.ledger.lookup_retain(&key) else { break };
            table.blocks.push(b);
            table.len += chunk.len();
            table.shared_prefix += chunk.len();
            parent = Some(b);
        }
        table
    }

    /// Register the fresh prompt blocks of a completed prefill in the
    /// prefix cache so future identical prefixes share them. Both full
    /// chunks and the partial tail are sealed (the key carries the exact
    /// chunk, so fills of different lengths never alias).
    pub fn seal_prefill(&mut self, table: &BlockTable, tokens: &[i32]) {
        if !self.cfg.prefix_sharing {
            return;
        }
        let mut parent = None;
        for (i, chunk) in tokens.chunks(self.cfg.block_size).enumerate() {
            let b = table.blocks[i];
            if i * self.cfg.block_size >= table.shared_prefix {
                self.ledger.seal(b, PrefixKey { parent, tokens: chunk.to_vec() });
            }
            parent = Some(b);
        }
    }

    /// Snapshot the first `rows` token positions of `table` into a
    /// dtype-preserving [`SpillImage`]. Reads shared blocks too (safe —
    /// read-only), so the image always covers the full position range and
    /// restores bit-exactly regardless of how a later table re-shares.
    pub fn extract_rows(&self, table: &BlockTable, rows: usize) -> SpillImage {
        assert!(rows <= table.len, "extract_rows past table end ({rows} > {})", table.len);
        let cap = rows * self.n_layers * self.d * SpillImage::elem_bytes(self.cfg.dtype);
        let mut img = SpillImage {
            dtype: self.cfg.dtype,
            n_layers: self.n_layers,
            d: self.d,
            rows,
            k: Vec::with_capacity(cap),
            v: Vec::with_capacity(cap),
            k_scales: Vec::new(),
            v_scales: Vec::new(),
        };
        for pos in 0..rows {
            let b = table.blocks[pos / self.cfg.block_size];
            let row = pos % self.cfg.block_size;
            for layer in 0..self.n_layers {
                let o = self.off(b, layer) + row * self.d;
                self.k.export_row(o, self.d, &mut img.k, &mut img.k_scales);
                self.v.export_row(o, self.d, &mut img.v, &mut img.v_scales);
            }
        }
        img
    }

    /// Replay a [`SpillImage`] into `table` verbatim. Positions the prefix
    /// cache already resolved (`[0, shared_prefix)`) are skipped — those
    /// blocks are live shared state and hold identical bytes anyway; the
    /// rest must sit in privately-owned blocks (freshly grown, or CoW'd by
    /// [`Self::grow`]). `table` must cover at least `image.rows` positions.
    pub fn write_raw_rows(&mut self, table: &BlockTable, image: &SpillImage) -> anyhow::Result<()> {
        image.validate()?;
        ensure!(
            image.dtype == self.cfg.dtype && image.n_layers == self.n_layers && image.d == self.d,
            "spill image shape ({:?}, {} layers, d={}) doesn't match pool ({:?}, {} layers, d={})",
            image.dtype,
            image.n_layers,
            image.d,
            self.cfg.dtype,
            self.n_layers,
            self.d,
        );
        ensure!(
            image.rows <= table.len,
            "spill image covers {} rows but the table holds {}",
            image.rows,
            table.len,
        );
        let rb = self.d * SpillImage::elem_bytes(image.dtype);
        for pos in table.shared_prefix..image.rows {
            let b = table.blocks[pos / self.cfg.block_size];
            debug_assert!(!self.ledger.is_shared(b), "restore into a shared KV block");
            let row = pos % self.cfg.block_size;
            for layer in 0..self.n_layers {
                let o = self.off(b, layer) + row * self.d;
                let idx = pos * self.n_layers + layer;
                let ks = image.k_scales.get(idx).copied().unwrap_or(0.0);
                let vs = image.v_scales.get(idx).copied().unwrap_or(0.0);
                self.k.import_row(o, self.d, &image.k[idx * rb..(idx + 1) * rb], ks);
                self.v.import_row(o, self.d, &image.v[idx * rb..(idx + 1) * rb], vs);
            }
        }
        Ok(())
    }

    /// Release every block a table holds (refcount-decrement; physical
    /// blocks free when the last sharer releases).
    pub fn release_table(&mut self, table: BlockTable) {
        for b in table.blocks {
            self.ledger.release(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(bs: usize, n_blocks: usize) -> KvStore {
        store_with_dtype(bs, n_blocks, KvDtype::F32)
    }

    fn store_with_dtype(bs: usize, n_blocks: usize, dtype: KvDtype) -> KvStore {
        KvStore::new(
            KvCacheConfig { block_size: bs, n_blocks, prefix_sharing: true, dtype },
            2, // layers
            4, // d
        )
    }

    /// Grow a fresh table over `tokens` and write distinct rows, sealing at
    /// the end — a miniature prefill without the model forward.
    fn prefill(s: &mut KvStore, tokens: &[i32], salt: f32) -> BlockTable {
        let mut t = s.build_prefill(tokens);
        let new = tokens.len() - t.len();
        s.grow(&mut t, new).unwrap();
        for pos in t.shared_prefix()..tokens.len() {
            let b = t.blocks()[pos / s.cfg.block_size];
            for layer in 0..2 {
                let row = vec![salt + pos as f32 + layer as f32 * 0.5; 4];
                s.write_row(b, layer, pos % s.cfg.block_size, &row, &row);
            }
        }
        s.seal_prefill(&t, tokens);
        t
    }

    #[test]
    fn identical_prompts_share_all_blocks() {
        let mut s = store(2, 16);
        let a = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        let used_after_a = s.ledger().used_blocks();
        let b = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        assert_eq!(a.blocks(), b.blocks(), "identical prompt must map to the same blocks");
        assert_eq!(b.shared_prefix(), 4);
        assert_eq!(s.ledger().used_blocks(), used_after_a, "no new physical blocks");
        s.release_table(a);
        assert_eq!(s.ledger().used_blocks(), used_after_a, "b still holds them");
        s.release_table(b);
        assert_eq!(s.ledger().used_blocks(), 0);
    }

    #[test]
    fn shared_prefix_diverging_suffix() {
        let mut s = store(2, 16);
        let a = prefill(&mut s, &[1, 2, 3, 4, 5, 6], 0.0);
        let b = prefill(&mut s, &[1, 2, 3, 4, 9, 9], 0.0);
        assert_eq!(b.shared_prefix(), 4);
        assert_eq!(&a.blocks()[..2], &b.blocks()[..2]);
        assert_ne!(a.blocks()[2], b.blocks()[2]);
        // 3 blocks for a + 1 private block for b
        assert_eq!(s.ledger().used_blocks(), 4);
        s.release_table(a);
        s.release_table(b);
    }

    #[test]
    fn partial_tail_shares_and_cow_on_append() {
        let mut s = store(4, 16);
        // 6 tokens = 1 full block + a partial tail of 2 — both sealed
        let a = prefill(&mut s, &[1, 2, 3, 4, 5, 6], 1.0);
        let mut b = prefill(&mut s, &[1, 2, 3, 4, 5, 6], 0.0);
        assert_eq!(b.shared_prefix(), 6, "partial tail chunk must share too");
        assert_eq!(s.ledger().used_blocks(), 2);

        // b appends into the shared tail → CoW: one fresh private block,
        // a's view untouched
        let tail_before = b.blocks()[1];
        assert_eq!(s.grow_demand(&b, 1), 1);
        s.grow(&mut b, 1).unwrap();
        let tail_after = b.blocks()[1];
        assert_ne!(tail_before, tail_after, "CoW must swap the tail block");
        assert_eq!(a.blocks()[1], tail_before);
        assert_eq!(s.ledger().refcount(tail_before), 1);
        assert_eq!(s.stats().cow_copies, 1);
        // the copied rows carry a's values (salt 1.0 from the first fill)
        assert_eq!(s.k_block(tail_after, 0)[0], 1.0 + 4.0);
        s.write_row(tail_after, 0, 2, &[9.0; 4], &[9.0; 4]);
        s.release_table(a);
        s.release_table(b);
        assert_eq!(s.ledger().used_blocks(), 0);
    }

    #[test]
    fn sole_owner_append_unseals_instead_of_copying() {
        let mut s = store(4, 8);
        let mut a = prefill(&mut s, &[1, 2, 3, 4, 5], 0.0);
        assert_eq!(s.ledger().cached_prefix_blocks(), 2);
        assert_eq!(s.grow_demand(&a, 1), 0);
        s.grow(&mut a, 1).unwrap();
        // the partial tail's cache entry is gone (content diverged) but no
        // copy happened
        assert_eq!(s.ledger().cached_prefix_blocks(), 1);
        assert_eq!(s.stats().cow_copies, 0);
        s.release_table(a);
    }

    #[test]
    fn grow_demand_counts_boundary_blocks() {
        let mut s = store(4, 8);
        let a = prefill(&mut s, &[1, 2, 3], 0.0);
        assert_eq!(s.grow_demand(&a, 1), 0); // fills the tail
        assert_eq!(s.grow_demand(&a, 2), 1); // crosses one boundary
        assert_eq!(s.grow_demand(&a, 6), 2);
        s.release_table(a);
    }

    #[test]
    fn pool_exhaustion_is_an_error() {
        let mut s = store(2, 2);
        let mut a = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        assert!(s.grow(&mut a, 1).is_err());
        s.release_table(a);
    }

    #[test]
    fn sharing_disabled_allocates_privately() {
        let mut s = KvStore::new(
            KvCacheConfig {
                block_size: 2,
                n_blocks: 8,
                prefix_sharing: false,
                dtype: KvDtype::F32,
            },
            1,
            4,
        );
        let a = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        let b = prefill(&mut s, &[1, 2, 3, 4], 0.0);
        assert_eq!(b.shared_prefix(), 0);
        assert_ne!(a.blocks()[0], b.blocks()[0]);
        assert_eq!(s.ledger().used_blocks(), 4);
        s.release_table(a);
        s.release_table(b);
    }

    #[test]
    fn default_config_aligns_with_tile_geometry() {
        let cfg = KvCacheConfig::for_model(256, 128);
        assert_eq!(cfg.block_size, 2, "tiny model: shard_rows = 2");
        assert_eq!(cfg.n_blocks, 32 * 64);
        assert!(cfg.prefix_sharing);
        assert_eq!(cfg.dtype, KvDtype::F32);
        assert_eq!(cfg.blocks_for(5), 3);
        let cfg1b = KvCacheConfig::for_model(2048, 4096);
        assert_eq!(cfg1b.block_size, 16, "Table I: C_S = 16 rows");
    }

    #[test]
    fn dtype_byte_accounting() {
        let mut cfg = KvCacheConfig::for_model(256, 128);
        let f32_tok = cfg.bytes_per_token(4, 256);
        assert_eq!(f32_tok, 2 * 4 * 4 * 256);
        cfg.dtype = KvDtype::F16;
        assert_eq!(cfg.bytes_per_token(4, 256) * 2, f32_tok, "f16 halves residency");
        cfg.dtype = KvDtype::Q8;
        let q8_tok = cfg.bytes_per_token(4, 256);
        assert!(
            q8_tok * 3 < f32_tok,
            "q8 ({q8_tok}B) must be well under a third of f32 ({f32_tok}B)"
        );
        // Same byte budget → proportionally more blocks.
        let budget = cfg.bytes_per_block(4, 256) * 10;
        assert_eq!(cfg.blocks_for_bytes(budget, 4, 256), 10);
        cfg.dtype = KvDtype::F32;
        assert!(cfg.blocks_for_bytes(budget, 4, 256) < 4);
    }

    #[test]
    fn f16_round_trip_is_exact_for_halves_and_bounded_otherwise() {
        // Every exactly-representable half survives the round trip.
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 1.5, 65504.0, -65504.0, 6.104e-5] {
            let back = f16_to_f32(f32_to_f16(x));
            assert!(
                (back - x).abs() <= x.abs() * 1e-3,
                "f16 round trip drifted: {x} -> {back}"
            );
        }
        assert_eq!(f16_to_f32(f32_to_f16(1.0)), 1.0);
        assert_eq!(f16_to_f32(f32_to_f16(-2.5)), -2.5);
        // Overflow saturates to inf, NaN stays NaN, subnormals survive.
        assert!(f16_to_f32(f32_to_f16(1e6)).is_infinite());
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        let sub = f16_to_f32(f32_to_f16(3.0e-6));
        assert!((sub - 3.0e-6).abs() < 6.0e-8, "subnormal half drifted: {sub}");
        // Relative error ≤ 2^-11 across the normal range.
        let mut x = 6.2e-5f32;
        while x < 6.0e4 {
            let back = f16_to_f32(f32_to_f16(x));
            assert!((back - x).abs() <= x * (1.0 / 2048.0) * 1.0001, "{x} -> {back}");
            x *= 1.37;
        }
    }

    #[test]
    fn quantized_write_read_round_trip_bounds() {
        for dtype in [KvDtype::F16, KvDtype::Q8] {
            let mut s = store_with_dtype(4, 4, dtype);
            let b = s.ledger.alloc().unwrap();
            let krow = [1.0f32, -0.5, 0.25, 0.9375];
            let vrow = [-2.0f32, 0.0, 127.0, 1.0];
            s.write_row(b, 1, 2, &krow, &vrow);
            let mut kout = [0f32; 4];
            let mut vout = [0f32; 4];
            let rs = s.row_start(b, 1, 2);
            s.k_view().read_into(rs, 4, 0, &mut kout);
            s.v_view().read_into(rs, 4, 0, &mut vout);
            for i in 0..4 {
                // q8 bound: half a step of amax/127; f16 is far tighter.
                let kbound = krow.iter().fold(0f32, |m, &x| m.max(x.abs())) / 127.0 * 0.5 + 1e-4;
                let vbound = vrow.iter().fold(0f32, |m, &x| m.max(x.abs())) / 127.0 * 0.5 + 1e-2;
                assert!(
                    (kout[i] - krow[i]).abs() <= kbound,
                    "{dtype:?} K[{i}]: {} vs {}",
                    kout[i],
                    krow[i]
                );
                assert!(
                    (vout[i] - vrow[i]).abs() <= vbound,
                    "{dtype:?} V[{i}]: {} vs {}",
                    vout[i],
                    vrow[i]
                );
            }
            // Sub-row (head-sliced) reads use the same per-row scale.
            let mut half = [0f32; 2];
            s.k_view().read_into(rs, 4, 2, &mut half);
            assert_eq!(half, [kout[2], kout[3]]);
        }
    }

    #[test]
    fn spill_extract_restore_roundtrip_bitwise_all_dtypes() {
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Q8] {
            let mut s = store_with_dtype(4, 16, dtype);
            let tokens = [1, 2, 3, 4, 5, 6, 7];
            let t = prefill(&mut s, &tokens, 0.25);
            let img = s.extract_rows(&t, tokens.len());
            img.validate().unwrap();
            assert_eq!(img.rows, 7);
            s.release_table(t);
            // sole owner released → prefix cache purged → fully private
            let mut t2 = s.build_prefill(&tokens);
            assert_eq!(t2.shared_prefix(), 0);
            s.grow(&mut t2, tokens.len()).unwrap();
            s.write_raw_rows(&t2, &img).unwrap();
            let img2 = s.extract_rows(&t2, tokens.len());
            assert_eq!(img, img2, "{dtype:?} restore must be bitwise");
            s.release_table(t2);
        }
    }

    #[test]
    fn restore_skips_live_shared_prefix_rows() {
        let mut s = store(2, 16);
        let a = prefill(&mut s, &[1, 2, 3, 4, 5], 1.0);
        let img = s.extract_rows(&a, 5);
        // a stays live; a restore of the same prompt re-shares every chunk
        let mut b = s.build_prefill(&[1, 2, 3, 4, 5]);
        assert_eq!(b.shared_prefix(), 5);
        s.write_raw_rows(&b, &img).unwrap();
        // shared bytes untouched and already identical to the image
        assert_eq!(s.extract_rows(&b, 5), img);
        // a partially-shared restore fills only the private tail
        s.grow(&mut b, 1).unwrap();
        s.release_table(a);
        s.release_table(b);
    }

    #[test]
    fn mismatched_spill_image_is_rejected() {
        let mut s = store_with_dtype(2, 8, KvDtype::F16);
        let t = prefill(&mut s, &[1, 2, 3], 0.0);
        let mut img = s.extract_rows(&t, 3);
        // dtype mismatch against an f32 pool
        let mut f32_pool = store(2, 8);
        let t32 = prefill(&mut f32_pool, &[1, 2, 3], 0.0);
        assert!(f32_pool.write_raw_rows(&t32, &img).is_err());
        f32_pool.release_table(t32);
        // truncated byte array fails validate()
        img.k.pop();
        assert!(img.validate().is_err());
        assert!(s.write_raw_rows(&t, &img).is_err());
        s.release_table(t);
    }

    #[test]
    fn cow_preserves_quantized_tail_rows_bitwise() {
        let mut s = store_with_dtype(4, 16, KvDtype::Q8);
        let a = prefill(&mut s, &[1, 2, 3, 4, 5, 6], 1.0);
        let mut b = prefill(&mut s, &[1, 2, 3, 4, 5, 6], 0.0);
        assert_eq!(b.shared_prefix(), 6);
        let tail_before = b.blocks()[1];
        let mut orig = [0f32; 4];
        s.k_view().read_into(s.row_start(tail_before, 0, 1), 4, 0, &mut orig);
        s.grow(&mut b, 1).unwrap();
        let tail_after = b.blocks()[1];
        assert_ne!(tail_before, tail_after);
        let mut copied = [0f32; 4];
        s.k_view().read_into(s.row_start(tail_after, 0, 1), 4, 0, &mut copied);
        assert_eq!(orig, copied, "CoW must move q8 cells and scales verbatim");
        assert_eq!(s.stats().dtype, KvDtype::Q8);
        assert_eq!(s.stats().bytes_per_token, 2 * 2 * (4 + 4));
        s.release_table(a);
        s.release_table(b);
    }
}
