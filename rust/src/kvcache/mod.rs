//! Paged KV-cache subsystem: block-pooled, prefix-shared KV storage with
//! preemption-aware admission (the PagedAttention discipline, sized to
//! this architecture's tile row groups).
//!
//! LEAP's serving capacity is bounded by how the dynamic KV tensors are
//! packed into distributed tile-local memory, not by compute. This module
//! replaces per-session flat `[s_max, d]` KV buffers with a shared pool of
//! fixed-size blocks:
//!
//! - [`ledger`] — [`BlockLedger`]: refcounted block accounting + an
//!   exact-match prefix cache. Also used storage-free by the coordinator's
//!   simulated-scratchpad capacity manager.
//! - [`store`] — [`KvStore`]/[`BlockTable`]: the typed block arenas
//!   ([`KvDtype`]: f32 / f16 / per-row-scaled q8) behind the reference
//!   backend, with copy-on-write prefix sharing.
//! - [`admission`] — [`AdmissionPolicy`]: admit/queue/reject against
//!   actual free blocks; the engine preempts (release + re-queue +
//!   re-prefill) when decode growth outruns the pool. With spill-aware
//!   admission ([`crate::persist::SpillStore`]), preempted KV rows move
//!   to disk ([`SpillImage`]) instead of being recomputed.

pub mod admission;
pub mod ledger;
pub mod store;

pub use admission::{AdmissionDecision, AdmissionPolicy};
pub use ledger::{BlockId, BlockLedger, PoolStats, PrefixKey};
pub use store::{BlockTable, KvCacheConfig, KvDtype, KvStore, KvView, SpillImage};
