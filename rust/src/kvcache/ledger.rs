//! Refcounted block accounting: the allocator half of the paged KV cache.
//!
//! A [`BlockLedger`] owns no tensor storage — it tracks which fixed-size
//! blocks are free, how many holders reference each live block, and an
//! exact-match prefix cache (chain key → block) that lets identical prompt
//! prefixes map to the same physical block. The same type backs both the
//! functional pool in [`crate::kvcache::KvStore`] (real f32 storage) and
//! the coordinator's simulated-scratchpad capacity accounting
//! ([`crate::coordinator::KvManager`]).
//!
//! Prefix-cache keys are *exact*: a key is the parent block id plus the
//! owned token chunk, so a cache hit proves the chunk chain matches
//! bit-for-bit — there is no hash-collision soundness hazard. When a block
//! is freed, its own key and any child keys chained off it are purged, so
//! a recycled block id can never satisfy a stale lookup.

use std::collections::HashMap;

use super::store::KvDtype;

/// Physical block identifier within one pool.
pub type BlockId = u32;

/// Exact prefix-cache key: the parent block in the chain (`None` for the
/// first chunk of a prompt) plus the token chunk this block holds.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PrefixKey {
    pub parent: Option<BlockId>,
    pub tokens: Vec<i32>,
}

/// Snapshot of pool occupancy and sharing counters. `block_size`,
/// `dtype`, and `bytes_per_token` are filled in by the pool that owns the
/// ledger (the ledger itself is size- and dtype-blind).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PoolStats {
    /// Tokens per block.
    pub block_size: usize,
    /// Storage dtype of the owning pool's arenas.
    pub dtype: KvDtype,
    /// Bytes one token position occupies (both arenas, all layers).
    pub bytes_per_token: usize,
    pub blocks_total: usize,
    pub blocks_free: usize,
    pub blocks_used: usize,
    /// High-water mark of `blocks_used` over the ledger's lifetime.
    pub peak_blocks_used: usize,
    /// Live blocks currently referenced by more than one holder.
    pub shared_blocks: usize,
    /// Prefix-cache probes (one per prompt chunk walked).
    pub prefix_lookups: u64,
    /// Prefix-cache hits (chunks resolved to an existing block).
    pub prefix_hits: u64,
    /// Copy-on-write block copies performed.
    pub cow_copies: u64,
    /// Blocks whose content currently lives in spill files instead of the
    /// pool (oversubscription beyond `blocks_total`).
    pub spilled_blocks: usize,
}

impl PoolStats {
    /// Fraction of prefix-cache probes that hit (0 when never probed).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }
}

/// Refcounted fixed-population block allocator with an exact prefix cache.
#[derive(Debug)]
pub struct BlockLedger {
    /// Holder count per block; 0 = free.
    refcount: Vec<u32>,
    /// Free-list stack (top = next allocation).
    free: Vec<BlockId>,
    /// The prefix-cache key a block was sealed with, if any.
    sealed: Vec<Option<PrefixKey>>,
    by_key: HashMap<PrefixKey, BlockId>,
    /// Live cache entries whose key's parent is this block. Lets
    /// [`Self::release`] skip the orphan scan for the common case (a
    /// freed block that parents nothing), keeping frees O(1).
    child_entries: Vec<u32>,
    peak_used: usize,
    prefix_lookups: u64,
    prefix_hits: u64,
    cow_copies: u64,
    /// Blocks whose content is parked in spill files right now. Pure
    /// accounting — the blocks themselves were released back to the free
    /// list when their session was preempted.
    spilled_blocks: usize,
}

impl BlockLedger {
    pub fn new(n_blocks: usize) -> Self {
        Self {
            refcount: vec![0; n_blocks],
            // Pop order is ascending ids — deterministic, test-friendly.
            free: (0..n_blocks as BlockId).rev().collect(),
            sealed: vec![None; n_blocks],
            by_key: HashMap::new(),
            child_entries: vec![0; n_blocks],
            peak_used: 0,
            prefix_lookups: 0,
            prefix_hits: 0,
            cow_copies: 0,
            spilled_blocks: 0,
        }
    }

    pub fn total(&self) -> usize {
        self.refcount.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total() - self.free.len()
    }

    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refcount[b as usize]
    }

    /// Is this live block held by more than one holder? (A shared block
    /// must never be written; writers copy-on-write first.)
    pub fn is_shared(&self, b: BlockId) -> bool {
        self.refcount[b as usize] > 1
    }

    pub fn is_sealed(&self, b: BlockId) -> bool {
        self.sealed[b as usize].is_some()
    }

    /// Blocks currently registered in the prefix cache.
    pub fn cached_prefix_blocks(&self) -> usize {
        self.by_key.len()
    }

    /// Claim a free block (refcount 1). `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refcount[b as usize], 0);
        debug_assert!(self.sealed[b as usize].is_none());
        self.refcount[b as usize] = 1;
        self.peak_used = self.peak_used.max(self.used_blocks());
        Some(b)
    }

    /// Add one holder to a live block.
    pub fn retain(&mut self, b: BlockId) {
        debug_assert!(self.refcount[b as usize] > 0, "retain of a free block");
        self.refcount[b as usize] += 1;
    }

    /// Remove one cache entry, keeping the parent's child count in sync.
    fn drop_key(&mut self, key: PrefixKey) {
        let parent = key.parent;
        self.by_key.remove(&key);
        if let Some(p) = parent {
            self.child_entries[p as usize] -= 1;
        }
    }

    /// Drop one holder; returns `true` when this freed the block. Freeing
    /// purges the block's own prefix-cache key and every child key chained
    /// off it (a recycled id must never satisfy a stale lookup). Purged
    /// children also drop their `sealed` back-pointer — leaving it would
    /// let the child's own later release evict an unrelated entry that
    /// re-used the recycled parent id. The orphan scan only runs when the
    /// freed block actually parents cache entries, so common frees
    /// (decode tails, unshared blocks) stay O(1).
    pub fn release(&mut self, b: BlockId) -> bool {
        let rc = &mut self.refcount[b as usize];
        debug_assert!(*rc > 0, "release of a free block");
        *rc -= 1;
        if *rc > 0 {
            return false;
        }
        if let Some(key) = self.sealed[b as usize].take() {
            self.drop_key(key);
        }
        if self.child_entries[b as usize] > 0 {
            let orphans: Vec<BlockId> = self
                .by_key
                .iter()
                .filter(|(k, _)| k.parent == Some(b))
                .map(|(_, &child)| child)
                .collect();
            for child in orphans {
                if let Some(key) = self.sealed[child as usize].take() {
                    self.drop_key(key);
                }
            }
            debug_assert_eq!(self.child_entries[b as usize], 0, "orphan purge must drain");
        }
        self.free.push(b);
        true
    }

    /// Register a freshly filled block in the prefix cache. First writer
    /// wins: if an identical chain entry already exists the block is left
    /// unsealed (future prompts will share the existing one).
    pub fn seal(&mut self, b: BlockId, key: PrefixKey) {
        debug_assert!(self.refcount[b as usize] > 0, "seal of a free block");
        if self.by_key.contains_key(&key) || self.sealed[b as usize].is_some() {
            return;
        }
        if let Some(p) = key.parent {
            self.child_entries[p as usize] += 1;
        }
        self.by_key.insert(key.clone(), b);
        self.sealed[b as usize] = Some(key);
    }

    /// Remove a block's prefix-cache entry (its content is about to
    /// diverge from the sealed chunk — e.g. a sole owner appending into a
    /// sealed partial block).
    pub fn unseal(&mut self, b: BlockId) {
        if let Some(key) = self.sealed[b as usize].take() {
            self.drop_key(key);
        }
    }

    /// Probe the prefix cache; on a hit the block gains a holder and is
    /// returned. Counts lookups/hits for the hit-rate gauge.
    pub fn lookup_retain(&mut self, key: &PrefixKey) -> Option<BlockId> {
        self.prefix_lookups += 1;
        let b = *self.by_key.get(key)?;
        self.prefix_hits += 1;
        self.retain(b);
        Some(b)
    }

    /// Count one copy-on-write block copy (performed by the storage owner).
    pub fn note_cow(&mut self) {
        self.cow_copies += 1;
    }

    /// Record that `n` blocks' worth of KV rows moved to spill files
    /// (their pool blocks are free again; the state lives on disk).
    pub fn note_spill(&mut self, n: usize) {
        self.spilled_blocks += n;
    }

    /// Record that `n` spilled blocks' rows were restored into the pool
    /// (or their session finished while spilled and the file was dropped).
    pub fn note_restore(&mut self, n: usize) {
        self.spilled_blocks = self.spilled_blocks.saturating_sub(n);
    }

    /// Blocks currently parked in spill files.
    pub fn spilled_blocks(&self) -> usize {
        self.spilled_blocks
    }

    /// Occupancy/sharing snapshot (`block_size`/`dtype`/`bytes_per_token`
    /// left at defaults — the owning pool fills them in).
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            block_size: 0,
            dtype: KvDtype::default(),
            bytes_per_token: 0,
            blocks_total: self.total(),
            blocks_free: self.free_blocks(),
            blocks_used: self.used_blocks(),
            peak_blocks_used: self.peak_used,
            shared_blocks: self.refcount.iter().filter(|&&rc| rc > 1).count(),
            prefix_lookups: self.prefix_lookups,
            prefix_hits: self.prefix_hits,
            cow_copies: self.cow_copies,
            spilled_blocks: self.spilled_blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(parent: Option<BlockId>, toks: &[i32]) -> PrefixKey {
        PrefixKey { parent, tokens: toks.to_vec() }
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut l = BlockLedger::new(3);
        assert_eq!(l.free_blocks(), 3);
        let a = l.alloc().unwrap();
        let b = l.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(l.used_blocks(), 2);
        assert!(l.release(a));
        assert_eq!(l.free_blocks(), 2);
        assert!(l.release(b));
        assert_eq!(l.free_blocks(), 3);
        assert_eq!(l.peak_used(), 2);
    }

    #[test]
    fn pool_exhaustion_returns_none() {
        let mut l = BlockLedger::new(1);
        let a = l.alloc().unwrap();
        assert_eq!(l.alloc(), None);
        l.release(a);
        assert!(l.alloc().is_some());
    }

    #[test]
    fn refcounts_free_exactly_at_zero() {
        let mut l = BlockLedger::new(2);
        let a = l.alloc().unwrap();
        l.retain(a);
        l.retain(a);
        assert_eq!(l.refcount(a), 3);
        assert!(l.is_shared(a));
        assert!(!l.release(a));
        assert!(!l.release(a));
        assert!(!l.is_shared(a));
        assert_eq!(l.used_blocks(), 1);
        assert!(l.release(a));
        assert_eq!(l.used_blocks(), 0);
    }

    #[test]
    fn prefix_cache_hits_and_misses() {
        let mut l = BlockLedger::new(4);
        let a = l.alloc().unwrap();
        l.seal(a, key(None, &[1, 2]));
        assert_eq!(l.lookup_retain(&key(None, &[1, 2])), Some(a));
        assert_eq!(l.refcount(a), 2);
        assert_eq!(l.lookup_retain(&key(None, &[9, 9])), None);
        let s = l.stats();
        assert_eq!((s.prefix_lookups, s.prefix_hits), (2, 1));
        assert!((s.prefix_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn free_purges_own_and_child_keys() {
        let mut l = BlockLedger::new(4);
        let a = l.alloc().unwrap();
        let b = l.alloc().unwrap();
        l.seal(a, key(None, &[1]));
        l.seal(b, key(Some(a), &[2]));
        // free `a` (sole holder): its key AND the child key through it die
        assert!(l.release(a));
        assert_eq!(l.lookup_retain(&key(None, &[1])), None);
        assert_eq!(l.lookup_retain(&key(Some(a), &[2])), None);
        assert_eq!(l.cached_prefix_blocks(), 0);
        // b itself is still live, just no longer reachable via the cache —
        // and its sealed back-pointer is gone with its entry
        assert_eq!(l.refcount(b), 1);
        assert!(!l.is_sealed(b), "purged child must not keep a dangling seal");
    }

    #[test]
    fn purged_child_release_cannot_evict_recycled_key() {
        let mut l = BlockLedger::new(4);
        let a = l.alloc().unwrap();
        let b = l.alloc().unwrap();
        l.seal(a, key(None, &[1]));
        l.seal(b, key(Some(a), &[2]));
        l.release(a); // purges b's entry AND its back-pointer
        // recycle a's id for a fresh chain that re-uses the same key shape
        let a2 = l.alloc().unwrap();
        assert_eq!(a2, a, "free-list must hand the id back for this test");
        let c = l.alloc().unwrap();
        l.seal(a2, key(None, &[9]));
        l.seal(c, key(Some(a2), &[2]));
        // b's release must NOT evict c's legitimate {parent: a2, [2]} entry
        assert!(l.release(b));
        assert_eq!(l.lookup_retain(&key(Some(a2), &[2])), Some(c));
    }

    #[test]
    fn spill_accounting_is_a_pure_gauge() {
        let mut l = BlockLedger::new(4);
        let a = l.alloc().unwrap();
        l.note_spill(3);
        assert_eq!(l.spilled_blocks(), 3);
        assert_eq!(l.stats().spilled_blocks, 3);
        l.note_restore(2);
        assert_eq!(l.spilled_blocks(), 1);
        // over-restore saturates instead of wrapping
        l.note_restore(5);
        assert_eq!(l.spilled_blocks(), 0);
        // the gauge never touches block occupancy
        assert_eq!(l.used_blocks(), 1);
        l.release(a);
    }

    #[test]
    fn unseal_removes_cache_entry_only() {
        let mut l = BlockLedger::new(2);
        let a = l.alloc().unwrap();
        l.seal(a, key(None, &[7]));
        assert!(l.is_sealed(a));
        l.unseal(a);
        assert!(!l.is_sealed(a));
        assert_eq!(l.lookup_retain(&key(None, &[7])), None);
        assert_eq!(l.refcount(a), 1);
    }

    #[test]
    fn seal_first_writer_wins() {
        let mut l = BlockLedger::new(3);
        let a = l.alloc().unwrap();
        let b = l.alloc().unwrap();
        l.seal(a, key(None, &[5]));
        l.seal(b, key(None, &[5])); // duplicate chain: no-op
        assert!(!l.is_sealed(b));
        assert_eq!(l.lookup_retain(&key(None, &[5])), Some(a));
    }
}
