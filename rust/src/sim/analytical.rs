//! Analytical end-to-end inference simulation.
//!
//! Sums the per-layer phase plans of `schedule::dataflow` over all layers
//! and all decode steps, converts cycles to seconds at the configured
//! frequency, and charges the energy ledger from the phase event counts.
//! Produces tokens/s and tokens/J — the Table III / Fig. 10 quantities.

use crate::arch::{HwParams, TileGeometry};
use crate::energy::{EnergyLedger, EventEnergy, EventKind};
use crate::model::{ModelPreset, ModelShape};
use crate::schedule::{decode_phases_opts, prefill_phases_opts, LayerPhases};

/// Per-stage (prefill or decode) results.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageReport {
    pub tokens: usize,
    pub cycles: u64,
    pub seconds: f64,
    /// Stage throughput in tokens/s.
    pub tokens_per_s: f64,
    pub energy_j: f64,
}

/// End-to-end inference results for one (model, in, out) workload.
#[derive(Debug, Clone, PartialEq)]
pub struct InferenceReport {
    pub model: String,
    pub in_tokens: usize,
    pub out_tokens: usize,
    pub prefill: StageReport,
    pub decode: StageReport,
    /// Overall throughput: (in + out) tokens / total time — the Table III
    /// convention (full 2048-token context window processed).
    pub total_tokens_per_s: f64,
    /// Generation-only throughput: out / total time.
    pub gen_tokens_per_s: f64,
    pub total_energy_j: f64,
    /// tokens/J over the full window (Table III energy efficiency).
    pub tokens_per_j: f64,
    /// Average power draw, W.
    pub avg_power_w: f64,
    /// Macros mapped for this model (leakage base).
    pub mapped_macros: usize,
}

/// Active-wavefront size: 64 tiles × 1024 macros (Table I system).
pub const WAVEFRONT_MACROS: usize = 64 * 1024;

/// Analytical simulator for one model on given hardware.
#[derive(Debug, Clone)]
pub struct AnalyticalSim {
    pub shape: ModelShape,
    pub geom: TileGeometry,
    pub hw: HwParams,
    /// Stream duplicated (MHA-degraded) K/V shards, the paper's choice.
    /// `false` = GQA-aware ablation (EXPERIMENTS.md §Table III).
    pub kv_duplication: bool,
    energy: EventEnergy,
}

impl AnalyticalSim {
    pub fn new(preset: ModelPreset, hw: HwParams) -> Self {
        let shape = preset.shape();
        let geom = TileGeometry::for_model(shape.d_model, &hw);
        Self { shape, geom, hw, kv_duplication: true, energy: EventEnergy::default() }
    }

    /// The GQA-aware ablation variant (streams n_kv_heads-wide caches).
    pub fn gqa_aware(preset: ModelPreset, hw: HwParams) -> Self {
        let mut s = Self::new(preset, hw);
        s.kv_duplication = false;
        s
    }

    /// Macros required to map the whole model: the attention tile plus the
    /// MLP tiles, per layer (Table I: 64 tiles for Llama 3.2-1B).
    pub fn mapped_macros(&self) -> usize {
        let attn = self.geom.macros_per_tile();
        // MLP weights: 3·D·F cells → tiles of the same 2dc×2dc size.
        let mlp_xbars = 3 * self.shape.d_model.div_ceil(self.hw.xb)
            * self.shape.d_ff.div_ceil(self.hw.xb);
        let mlp_tiles = mlp_xbars.div_ceil(self.geom.macros_per_tile());
        self.shape.n_layers * (attn + mlp_tiles * self.geom.macros_per_tile())
    }

    /// Tiles required (the Table I "Tile #" figure).
    pub fn mapped_tiles(&self) -> usize {
        self.mapped_macros() / self.geom.macros_per_tile()
    }

    fn charge(&self, ledger: &mut EnergyLedger, lp: &LayerPhases) {
        for p in &lp.phases {
            ledger.add(&self.energy, EventKind::RouterHop, p.hop_events);
            ledger.add(&self.energy, EventKind::IrcuCycle, p.ircu_events);
            ledger.add(&self.energy, EventKind::SpadRead, p.spad_events / 2);
            ledger.add(&self.energy, EventKind::SpadWrite, p.spad_events.div_ceil(2));
            ledger.add(&self.energy, EventKind::PeMvm, p.pe_events);
        }
    }

    /// Macros in the active execution wavefront. The paper reports a single
    /// 10.53 W "Ours" power for 8B and 13B alike — exactly 65,536 macros
    /// (the Table I 64-tile system) at Table II's 160.65 µW. We model the
    /// same: the pipeline wavefront keeps ~64 tiles un-gated regardless of
    /// how many tiles the full model maps to; everything else is
    /// power-gated (non-volatile weights retain state).
    pub fn wavefront_macros(&self) -> usize {
        self.mapped_macros().min(WAVEFRONT_MACROS)
    }

    /// Cycles for one full-model prefill of `s` tokens.
    pub fn prefill_cycles(&self, s: usize) -> u64 {
        let lp =
            prefill_phases_opts(&self.shape, &self.geom, &self.hw, s, self.kv_duplication);
        lp.total_cycles() * self.shape.n_layers as u64
    }

    /// Cycles for one decode step at context length `ctx`.
    pub fn decode_cycles(&self, ctx: usize) -> u64 {
        let lp =
            decode_phases_opts(&self.shape, &self.geom, &self.hw, ctx, self.kv_duplication);
        lp.total_cycles() * self.shape.n_layers as u64
    }

    /// Simulate a full inference: prefill `in_tokens`, then generate
    /// `out_tokens` autoregressively (context grows each step).
    pub fn run(&self, in_tokens: usize, out_tokens: usize) -> InferenceReport {
        let layers = self.shape.n_layers as u64;

        // Prefill.
        let mut ledger_p = EnergyLedger::new();
        let lp =
            prefill_phases_opts(&self.shape, &self.geom, &self.hw, in_tokens, self.kv_duplication);
        self.charge(&mut ledger_p, &lp);
        // per-layer events × layers: merge layers-1 more copies cheaply
        let prefill_cycles = lp.total_cycles() * layers;
        scale_ledger(&mut ledger_p, layers);
        let prefill_s = self.hw.seconds(prefill_cycles);
        let wavefront_w =
            self.wavefront_macros() as f64 * crate::energy::table2::MACRO_UW * 1e-6;
        let prefill_j = ledger_p.total_j(&self.energy, self.mapped_macros(), prefill_s)
            + wavefront_w * prefill_s;

        // Decode: sample the growing context at a coarse stride for speed,
        // integrating cycles/energy piecewise (exact at stride 1).
        let mut decode_cycles = 0u64;
        let mut ledger_d = EnergyLedger::new();
        let stride = (out_tokens / 64).max(1);
        let mut t = 0usize;
        while t < out_tokens {
            let span = stride.min(out_tokens - t);
            let ctx = in_tokens + t + span / 2;
            let lp =
                decode_phases_opts(&self.shape, &self.geom, &self.hw, ctx, self.kv_duplication);
            decode_cycles += lp.total_cycles() * layers * span as u64;
            let mut one = EnergyLedger::new();
            self.charge(&mut one, &lp);
            scale_ledger(&mut one, layers * span as u64);
            ledger_d.merge(&one);
            t += span;
        }
        let decode_s = self.hw.seconds(decode_cycles);
        let decode_j = ledger_d.total_j(&self.energy, self.mapped_macros(), decode_s)
            + wavefront_w * decode_s;

        let total_s = prefill_s + decode_s;
        let total_j = prefill_j + decode_j;
        let total_tokens = (in_tokens + out_tokens) as f64;

        InferenceReport {
            model: self.shape.name.to_string(),
            in_tokens,
            out_tokens,
            prefill: StageReport {
                tokens: in_tokens,
                cycles: prefill_cycles,
                seconds: prefill_s,
                tokens_per_s: in_tokens as f64 / prefill_s.max(1e-12),
                energy_j: prefill_j,
            },
            decode: StageReport {
                tokens: out_tokens,
                cycles: decode_cycles,
                seconds: decode_s,
                tokens_per_s: out_tokens as f64 / decode_s.max(1e-12),
                energy_j: decode_j,
            },
            total_tokens_per_s: total_tokens / total_s.max(1e-12),
            gen_tokens_per_s: out_tokens as f64 / total_s.max(1e-12),
            total_energy_j: total_j,
            tokens_per_j: total_tokens / total_j.max(1e-12),
            avg_power_w: total_j / total_s.max(1e-12),
            mapped_macros: self.mapped_macros(),
        }
    }
}

fn scale_ledger(l: &mut EnergyLedger, k: u64) {
    for v in l.counts.values_mut() {
        *v *= k;
    }
    l.dynamic_pj *= k as f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(p: ModelPreset) -> AnalyticalSim {
        AnalyticalSim::new(p, HwParams::default())
    }

    #[test]
    fn table1_tile_count_for_1b() {
        // Table I: 64 tiles for Llama 3.2-1B (16 layers × (1 attn + 3 MLP)).
        let s = sim(ModelPreset::Llama1B);
        assert_eq!(s.geom.macros_per_tile(), 1024);
        assert_eq!(s.mapped_tiles(), 64);
        assert_eq!(s.mapped_macros(), 64 * 1024);
    }

    #[test]
    fn report_structure_sane() {
        let r = sim(ModelPreset::Llama1B).run(256, 256);
        assert!(r.prefill.seconds > 0.0 && r.decode.seconds > 0.0);
        assert!(r.prefill.tokens_per_s > r.decode.tokens_per_s, "prefill faster per token");
        assert!(r.total_tokens_per_s > 0.0);
        assert!(r.tokens_per_j > 0.0);
        assert!(r.avg_power_w > 0.0);
    }

    #[test]
    fn decode_dominates_long_generations() {
        let r = sim(ModelPreset::Llama1B).run(1024, 1024);
        assert!(r.decode.seconds > r.prefill.seconds);
    }

    #[test]
    fn throughput_drops_sublinearly_with_model_size() {
        // §VI-D: 1B → 8B is ~8× parameters but throughput drops ≪ 8×.
        let r1 = sim(ModelPreset::Llama1B).run(1024, 1024);
        let r8 = sim(ModelPreset::Llama8B).run(1024, 1024);
        let drop = r1.total_tokens_per_s / r8.total_tokens_per_s;
        assert!(drop > 1.2, "8B must be slower ({drop:.2}×)");
        assert!(drop < 8.0, "drop must be sublinear in the 8× size ({drop:.2}×)");
    }

    #[test]
    fn throughput_ordering_1b_8b_13b() {
        let t: Vec<f64> = [ModelPreset::Llama1B, ModelPreset::Llama8B, ModelPreset::Llama13B]
            .iter()
            .map(|&p| sim(p).run(512, 512).total_tokens_per_s)
            .collect();
        assert!(t[0] > t[1] && t[1] > t[2], "{t:?}");
    }

    #[test]
    fn power_in_plausible_envelope() {
        // The Table III system average is ~10.5 W; accept a broad band
        // (2–60 W) — EXPERIMENTS.md records the exact measured value.
        let r = sim(ModelPreset::Llama8B).run(1024, 1024);
        assert!((2.0..60.0).contains(&r.avg_power_w), "power {}", r.avg_power_w);
    }

    #[test]
    fn longer_context_lowers_decode_rate() {
        let s = sim(ModelPreset::Llama1B);
        let short = s.run(128, 128);
        let long = s.run(2048, 2048);
        assert!(short.decode.tokens_per_s > long.decode.tokens_per_s);
    }

    #[test]
    fn gqa_aware_ablation_brackets_paper() {
        // 8B: duplicated-KV (paper-faithful) is slower, GQA-aware faster;
        // the two bracket the paper's reported 202 tok/s (EXPERIMENTS.md).
        let dup = sim(ModelPreset::Llama8B).run(1024, 1024).gen_tokens_per_s;
        let gqa = AnalyticalSim::gqa_aware(ModelPreset::Llama8B, HwParams::default())
            .run(1024, 1024)
            .gen_tokens_per_s;
        assert!(gqa > dup);
        assert!(dup < 202.25 && 202.25 < gqa, "bracket failed: {dup} .. {gqa}");
    }

    #[test]
    fn stride_sampling_close_to_exact() {
        // The piecewise integration must track the exact sum closely.
        let s = sim(ModelPreset::Tiny);
        let exact: u64 = (0..64u64)
            .map(|t| s.decode_cycles(32 + t as usize))
            .sum();
        let r = s.run(32, 64);
        let rel = (r.decode.cycles as f64 - exact as f64).abs() / exact as f64;
        assert!(rel < 0.05, "stride integration error {rel}");
    }
}
