//! Traffic tracing: per-link utilisation and traffic matrices from either
//! the mapping cost model (static, X-Y estimate) or the mesh executor
//! (dynamic, measured hops). Backs the "balanced NoC traffic" claim of the
//! paper's contribution list with inspectable numbers (`leap trace`).

use crate::arch::{ChannelKind, Coord, Mesh};
use crate::mapping::Candidate;
use crate::noc::MeshSim;

/// Per-link traffic summary over a mesh.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficMatrix {
    pub width: u16,
    pub height: u16,
    /// Packets forwarded per router (any direction).
    pub per_router: Vec<u64>,
}

impl TrafficMatrix {
    /// Collect from a finished mesh simulation.
    pub fn from_mesh(sim: &MeshSim) -> Self {
        Self {
            width: sim.mesh.width,
            height: sim.mesh.height,
            per_router: sim.routers.iter().map(|r| r.counters.hops).collect(),
        }
    }

    /// Static estimate for a spatial-mapping candidate: X-Y route loads for
    /// the attention collectives (the same model the DSE cost uses).
    pub fn from_mapping(cand: &Candidate, dc: usize) -> Self {
        let side = (2 * dc) as u16;
        let mesh = Mesh::new(side, side);
        let mut per_router = vec![0u64; mesh.len()];
        let mut route = |src: Coord, dst: Coord| {
            for hop in mesh.xy_route(src, dst) {
                per_router[mesh.index(hop)] += 1;
            }
        };
        // Broadcast 1 + Reduction 1 + Unicast 1 (the dominant collectives)
        for ch in [ChannelKind::Q, ChannelKind::K, ChannelKind::V] {
            for i in 0..dc as u16 {
                for j in 0..dc as u16 {
                    let dst = cand.submatrix_coord(ch, i, j, dc);
                    route(Coord::new(0, dst.y), dst);
                    if i > 0 {
                        let prev = cand.submatrix_coord(ch, i - 1, j, dc);
                        route(prev, dst);
                    }
                }
            }
        }
        for j in 0..dc as u16 {
            let k_tail = cand.submatrix_coord(ChannelKind::K, dc as u16 - 1, j, dc);
            let q_tail = cand.submatrix_coord(ChannelKind::Q, dc as u16 - 1, j, dc);
            route(k_tail, q_tail);
        }
        Self { width: side, height: side, per_router }
    }

    pub fn max(&self) -> u64 {
        self.per_router.iter().copied().max().unwrap_or(0)
    }

    pub fn mean(&self) -> f64 {
        if self.per_router.is_empty() {
            return 0.0;
        }
        self.per_router.iter().sum::<u64>() as f64 / self.per_router.len() as f64
    }

    /// Peak-to-mean ratio — the balance metric (1.0 = perfectly even).
    pub fn imbalance(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            return 0.0;
        }
        self.max() as f64 / m
    }

    /// Coefficient of variation of per-router load.
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            return 0.0;
        }
        let var = self
            .per_router
            .iter()
            .map(|&x| (x as f64 - m).powi(2))
            .sum::<f64>()
            / self.per_router.len() as f64;
        var.sqrt() / m
    }

    /// ASCII heat map (one char per router, 0-9 scaled to the max load).
    pub fn heatmap(&self) -> String {
        let max = self.max().max(1);
        let mut out = String::new();
        for y in 0..self.height {
            for x in 0..self.width {
                let v = self.per_router[y as usize * self.width as usize + x as usize];
                let level = (v * 9).div_ceil(max).min(9);
                out.push(char::from_digit(level as u32, 10).unwrap());
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::paper_mapping;

    #[test]
    fn mapping_traffic_reasonably_balanced() {
        // The Fig. 4 layout's claim: regular horizontal/vertical dataflow
        // keeps traffic balanced. Peak/mean stays moderate.
        let tm = TrafficMatrix::from_mapping(&paper_mapping(16), 16);
        assert!(tm.max() > 0);
        assert!(tm.imbalance() < 20.0, "peak/mean {}", tm.imbalance());
        assert!(tm.cv() < 3.0, "cv {}", tm.cv());
    }

    #[test]
    fn heatmap_dimensions() {
        let tm = TrafficMatrix::from_mapping(&paper_mapping(4), 4);
        let map = tm.heatmap();
        assert_eq!(map.lines().count(), 8);
        assert!(map.lines().all(|l| l.len() == 8));
    }

    #[test]
    fn from_mesh_collects_hops() {
        use crate::arch::{Dir, HwParams};
        use crate::isa::{Cmd, Instruction, Opcode, Program, SelBits};
        let mut sim = MeshSim::new(4, 4, HwParams::default());
        sim.routers[0].accept(Dir::West, 1);
        sim.stats.packets_created += 1;
        let mut p = Program::new("t");
        p.push(Instruction::uni(Cmd::new(Opcode::RouteE, 4), 1, SelBits::All));
        sim.run(&p.sealed()).unwrap();
        let tm = TrafficMatrix::from_mesh(&sim);
        assert_eq!(tm.per_router.iter().sum::<u64>(), 1);
    }

    #[test]
    fn empty_matrix_degenerate_metrics() {
        let tm = TrafficMatrix { width: 2, height: 2, per_router: vec![0; 4] };
        assert_eq!(tm.imbalance(), 0.0);
        assert_eq!(tm.cv(), 0.0);
    }
}
