//! Instruction-level and analytical simulation.
//!
//! Two fidelity levels, cross-validated in `tests/integration_sim.rs`:
//!  * [`analytical`] — closed-form phase sums (from `schedule::dataflow`)
//!    used for the end-to-end studies (Table III, Figs. 10/12). Fast enough
//!    to sweep full Llama-13B contexts in microseconds.
//!  * the detailed mesh executor in [`crate::noc`] — packet-level execution
//!    of compiled NPM programs, used for small configs and property tests.
//!
//! [`breakdown`] produces the per-instruction-class critical-path cycle
//! split of Fig. 11 from either level.

pub mod analytical;
pub mod breakdown;
pub mod trace;

pub use analytical::{AnalyticalSim, InferenceReport, StageReport};
pub use breakdown::{class_breakdown, ClassBreakdown};
pub use trace::TrafficMatrix;
