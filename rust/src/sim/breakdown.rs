//! Per-instruction-class critical-path cycle breakdown (Fig. 11).
//!
//! Maps the analytical phase plan onto the Fig. 11 legend classes (send /
//! mul / add / spad / pim / ctrl) for an attention layer and its subsequent
//! MLP, for both prefill and decode.

use std::collections::BTreeMap;

use crate::arch::{HwParams, TileGeometry};
use crate::model::ModelShape;
use crate::schedule::{decode_phases, prefill_phases, LayerPhases, PhaseKind};

/// Cycle share per instruction class.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClassBreakdown {
    pub cycles: BTreeMap<&'static str, u64>,
}

impl ClassBreakdown {
    pub fn total(&self) -> u64 {
        self.cycles.values().sum()
    }

    pub fn share(&self, class: &str) -> f64 {
        *self.cycles.get(class).unwrap_or(&0) as f64 / self.total().max(1) as f64
    }
}

/// Attribute each phase's critical-path cycles to its dominant class.
///
/// The attribution mirrors what the NMC observes: a phase bottlenecked on
/// streaming charges `send`; DDMM phases charge the IRCU `mul`; reductions
/// and softmax charge `add`; scratchpad-bound phases charge `spad`; the
/// in-crossbar projections charge `pim`.
fn attribute(lp: &LayerPhases) -> ClassBreakdown {
    let mut b = ClassBreakdown::default();
    for p in &lp.phases {
        let class = match p.kind {
            PhaseKind::InputBroadcast | PhaseKind::KShardRotate => "send",
            PhaseKind::Projection => "pim",
            PhaseKind::ProjReduce | PhaseKind::ScoreReduce | PhaseKind::OutputReduce => "add",
            PhaseKind::ScoreDdmm | PhaseKind::ContextDdmm => "mul",
            PhaseKind::Softmax => "add",
            PhaseKind::Mlp => "send", // MLP critical path is the F-wide stream
        };
        *b.cycles.entry(class).or_insert(0) += p.cycles;
        // scratchpad side-channel: charge the access cycles that exceed the
        // overlap window as spad
        let spad_extra = p.spad_events.saturating_sub(p.cycles) / 8;
        if spad_extra > 0 {
            *b.cycles.entry("spad").or_insert(0) += spad_extra.min(p.cycles / 4);
        }
    }
    *b.cycles.entry("ctrl").or_insert(0) += lp.phases.len() as u64; // issue cycles
    b
}

/// Fig. 11 data: (prefill breakdown, decode breakdown) for one layer+MLP.
pub fn class_breakdown(
    shape: &ModelShape,
    geom: &TileGeometry,
    hw: &HwParams,
    s: usize,
) -> (ClassBreakdown, ClassBreakdown) {
    let pre = attribute(&prefill_phases(shape, geom, hw, s));
    let dec = attribute(&decode_phases(shape, geom, hw, s));
    (pre, dec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelPreset;

    fn setup() -> (ModelShape, TileGeometry, HwParams) {
        let hw = HwParams::default();
        let shape = ModelPreset::Llama1B.shape();
        let geom = TileGeometry::for_model(shape.d_model, &hw);
        (shape, geom, hw)
    }

    #[test]
    fn movement_and_ircu_dominate() {
        // Fig. 11's headline: latency is bottlenecked by data movement and
        // IRCU DDMMs, not PIM.
        let (shape, geom, hw) = setup();
        let (pre, dec) = class_breakdown(&shape, &geom, &hw, 1024);
        for b in [&pre, &dec] {
            let comm_compute = b.share("send") + b.share("mul") + b.share("add");
            assert!(comm_compute > 0.7, "send+mul+add = {comm_compute}");
            assert!(b.share("pim") < 0.15, "pim share {}", b.share("pim"));
        }
    }

    #[test]
    fn all_classes_present_in_prefill() {
        let (shape, geom, hw) = setup();
        let (pre, _) = class_breakdown(&shape, &geom, &hw, 1024);
        for c in ["send", "mul", "add", "pim", "ctrl"] {
            assert!(pre.cycles.contains_key(c), "missing {c}");
        }
    }

    #[test]
    fn totals_match_phase_sums() {
        let (shape, geom, hw) = setup();
        let lp = prefill_phases(&shape, &geom, &hw, 512);
        let b = attribute(&lp);
        // breakdown ≥ phase cycles (ctrl + spad extras are additive)
        assert!(b.total() >= lp.total_cycles());
        assert!(b.total() < lp.total_cycles() * 2);
    }

    #[test]
    fn shares_sum_to_one() {
        let (shape, geom, hw) = setup();
        let (pre, dec) = class_breakdown(&shape, &geom, &hw, 256);
        for b in [pre, dec] {
            let sum: f64 = ["send", "mul", "add", "spad", "pim", "ctrl"]
                .iter()
                .map(|c| b.share(c))
                .sum();
            assert!((sum - 1.0).abs() < 1e-9, "shares sum {sum}");
        }
    }
}
