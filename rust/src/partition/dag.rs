//! The partitioned attention-layer DAG of Fig. 3(b).
//!
//! Nodes are operations: PIM DSMMs (projections, orange in the figure),
//! IRCU DDMMs (QKᵀ and S·V), in-router adds/muls (reductions, softmax
//! pieces). Edges carry the collective-communication kind the scheduler
//! must realise: Broadcast 1/2, Reduction 1/2/3, Unicast 1/2.

use std::collections::HashMap;

use crate::arch::ChannelKind;

/// Node identifier (index into [`AttentionDag::nodes`]).
pub type NodeId = usize;

/// Operation kind a DAG node performs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dynamic·static matmul on a PIM crossbar (projection sub-matrix).
    Dsmm { channel: ChannelKind },
    /// Dynamic·dynamic matmul on an IRCU (QKᵀ or S·V shard product).
    Ddmm { score: bool },
    /// Partial-result addition in a router ("R-Add").
    RAdd,
    /// Element-wise multiply in a router ("R-Mul", softmax rescale).
    RMul,
    /// Softmax pieces (row-max, exp, normalise) on the IRCU.
    Softmax,
    /// Tensor source (input activations, KV cache reads).
    Source,
    /// Tensor sink (layer output).
    Sink,
}

/// Collective-communication kind annotating an edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommKind {
    /// Broadcast 1: input activations into Q/K/V channels.
    Broadcast1,
    /// Broadcast 2: O shards across the O-channel RG.
    Broadcast2,
    /// Reduction 1: DSMM partial sums within an RG.
    Reduction1,
    /// Reduction 2: partial attention scores across Q-channel RGs.
    Reduction2,
    /// Reduction 3: final output reduction in the O channel.
    Reduction3,
    /// Unicast 1: K shards K-channel → Q-channel (same row).
    Unicast1,
    /// Unicast 2: V-channel partials → O-channel scratchpad.
    Unicast2,
    /// Plain local dependency (same macro, no NoC traffic).
    Local,
}

/// A DAG node: operation + the sub-matrix / shard coordinates it touches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DagNode {
    pub op: OpKind,
    /// Sub-matrix grid coordinates for DSMMs, shard coordinates for DDMMs.
    pub coords: (u16, u16),
    pub label: String,
}

/// A directed edge with its communication kind and payload element count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DagEdge {
    pub src: NodeId,
    pub dst: NodeId,
    pub comm: CommKind,
    /// Number of 16-bit elements moved along this edge per shard pass.
    pub elems: u32,
}

/// The partitioned attention layer as a DAG (Fig. 3(b)).
#[derive(Debug, Clone, Default)]
pub struct AttentionDag {
    pub nodes: Vec<DagNode>,
    pub edges: Vec<DagEdge>,
}

impl AttentionDag {
    /// Build the DAG for embedding dim `d_model` partitioned on `xb`-sized
    /// crossbars: dc² DSMM nodes per projection channel, dc DDMM score
    /// nodes, dc DDMM context nodes, with the seven collective edges.
    pub fn build(d_model: usize, xb: usize) -> Self {
        let dc = d_model.div_ceil(xb);
        let elems_vec = xb as u32; // one sub-vector of C elements
        let mut dag = AttentionDag::default();

        let input = dag.push(OpKind::Source, (0, 0), "x".into());

        // Projection DSMMs + Reduction 1 per output column of each channel.
        let mut proj_out: HashMap<(ChannelKind, u16), NodeId> = HashMap::new();
        for ch in [ChannelKind::Q, ChannelKind::K, ChannelKind::V] {
            for col in 0..dc as u16 {
                let red = dag.push(OpKind::RAdd, (0, col), format!("red1-{}{col}", ch.name()));
                proj_out.insert((ch, col), red);
                for row in 0..dc as u16 {
                    let m = dag.push(
                        OpKind::Dsmm { channel: ch },
                        (row, col),
                        format!("{}[{row},{col}]", ch.name()),
                    );
                    dag.connect(input, m, CommKind::Broadcast1, elems_vec);
                    dag.connect(m, red, CommKind::Reduction1, elems_vec);
                }
            }
        }

        // Score DDMMs: Q-channel RPUs consume K shards (Unicast 1), reduce
        // partial scores across RGs (Reduction 2), then softmax.
        let mut softmaxed = Vec::with_capacity(dc);
        for col in 0..dc as u16 {
            let qk = dag.push(OpKind::Ddmm { score: true }, (0, col), format!("QK[{col}]"));
            dag.connect(proj_out[&(ChannelKind::Q, col)], qk, CommKind::Local, elems_vec);
            dag.connect(proj_out[&(ChannelKind::K, col)], qk, CommKind::Unicast1, elems_vec);
            let red2 = dag.push(OpKind::RAdd, (1, col), format!("red2[{col}]"));
            dag.connect(qk, red2, CommKind::Reduction2, elems_vec);
            let sm = dag.push(OpKind::Softmax, (0, col), format!("softmax[{col}]"));
            dag.connect(red2, sm, CommKind::Local, elems_vec);
            softmaxed.push(sm);
        }

        // Context DDMMs: softmaxed scores meet V partials; rescale (R-Mul),
        // accumulate into the O channel (Unicast 2), broadcast the finished
        // shard across the O-channel RG (Broadcast 2), reduce (Reduction 3).
        let sink = dag.push(OpKind::Sink, (0, 0), "out".into());
        for col in 0..dc as u16 {
            let sv = dag.push(OpKind::Ddmm { score: false }, (1, col), format!("SV[{col}]"));
            dag.connect(softmaxed[col as usize], sv, CommKind::Local, elems_vec);
            dag.connect(proj_out[&(ChannelKind::V, col)], sv, CommKind::Unicast2, elems_vec);
            let rescale = dag.push(OpKind::RMul, (1, col), format!("rescale[{col}]"));
            dag.connect(sv, rescale, CommKind::Local, elems_vec);
            // O projection DSMMs (row-major mapped W_O) + final reduction.
            let red3 = dag.push(OpKind::RAdd, (2, col), format!("red3[{col}]"));
            for row in 0..dc as u16 {
                let m = dag.push(
                    OpKind::Dsmm { channel: ChannelKind::O },
                    (row, col),
                    format!("O[{row},{col}]"),
                );
                dag.connect(rescale, m, CommKind::Broadcast2, elems_vec);
                dag.connect(m, red3, CommKind::Reduction3, elems_vec);
            }
            dag.connect(red3, sink, CommKind::Local, elems_vec);
        }
        dag
    }

    fn push(&mut self, op: OpKind, coords: (u16, u16), label: String) -> NodeId {
        self.nodes.push(DagNode { op, coords, label });
        self.nodes.len() - 1
    }

    fn connect(&mut self, src: NodeId, dst: NodeId, comm: CommKind, elems: u32) {
        self.edges.push(DagEdge { src, dst, comm, elems });
    }

    /// Nodes of a given operation kind.
    pub fn count_op(&self, pred: impl Fn(&OpKind) -> bool) -> usize {
        self.nodes.iter().filter(|n| pred(&n.op)).count()
    }

    /// Kahn topological order; `None` if a cycle exists (it never should).
    pub fn topo_order(&self) -> Option<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for e in &self.edges {
            indeg[e.dst] += 1;
            adj[e.src].push(e.dst);
        }
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop() {
            order.push(u);
            for &v in &adj[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// Sum of payload elements per communication kind — the traffic matrix
    /// the mapper's cost function weighs.
    pub fn traffic_by_comm(&self) -> HashMap<CommKind, u64> {
        let mut m = HashMap::new();
        for e in &self.edges {
            *m.entry(e.comm).or_insert(0u64) += e.elems as u64;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_counts_match_partitioning() {
        // D=2048, C=128 → dc=16: 3 input channels × 16² DSMMs + 16² for O.
        let dag = AttentionDag::build(2048, 128);
        let dsmm = dag.count_op(|o| matches!(o, OpKind::Dsmm { .. }));
        assert_eq!(dsmm, 4 * 16 * 16);
        let ddmm = dag.count_op(|o| matches!(o, OpKind::Ddmm { .. }));
        assert_eq!(ddmm, 2 * 16);
        let sm = dag.count_op(|o| matches!(o, OpKind::Softmax));
        assert_eq!(sm, 16);
    }

    #[test]
    fn dag_is_acyclic() {
        let dag = AttentionDag::build(1024, 128);
        let order = dag.topo_order().expect("must be a DAG");
        assert_eq!(order.len(), dag.nodes.len());
        // every edge goes forward in the order
        let pos: HashMap<_, _> = order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
        for e in &dag.edges {
            assert!(pos[&e.src] < pos[&e.dst], "{} -> {}", e.src, e.dst);
        }
    }

    #[test]
    fn all_seven_collectives_present() {
        let dag = AttentionDag::build(1024, 128);
        let traffic = dag.traffic_by_comm();
        for k in [
            CommKind::Broadcast1,
            CommKind::Broadcast2,
            CommKind::Reduction1,
            CommKind::Reduction2,
            CommKind::Reduction3,
            CommKind::Unicast1,
            CommKind::Unicast2,
        ] {
            assert!(traffic.contains_key(&k), "missing {k:?}");
        }
    }

    #[test]
    fn broadcast1_feeds_every_input_dsmm() {
        let dag = AttentionDag::build(512, 128);
        let b1 = dag.edges.iter().filter(|e| e.comm == CommKind::Broadcast1).count();
        assert_eq!(b1, 3 * 4 * 4); // Q/K/V channels × dc² sub-matrices
    }

    #[test]
    fn tiny_model_dag_small_but_complete() {
        let dag = AttentionDag::build(256, 128); // dc = 2
        assert!(dag.topo_order().is_some());
        assert_eq!(dag.count_op(|o| matches!(o, OpKind::Dsmm { .. })), 16);
    }
}
