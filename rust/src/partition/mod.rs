//! Model partitioning (paper §III-A): splitting the static projection
//! weights into crossbar-sized sub-matrices and building the DAG of
//! Fig. 3(b) that the mapper and scheduler consume.

pub mod dag;
pub mod weights;

pub use dag::{AttentionDag, CommKind, DagEdge, DagNode, NodeId, OpKind};
pub use weights::{SubMatrix, WeightPartition};
