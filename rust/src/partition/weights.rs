//! Weight-matrix partitioning along rows and columns to fit the crossbar
//! arrays (Fig. 3(a)). A D×D projection matrix becomes a ceil(D/C)² grid of
//! C×C sub-matrices; edge tiles are zero-padded (the spare cells idle).

use crate::arch::HwParams;

/// One crossbar-sized sub-matrix of a partitioned weight.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SubMatrix {
    /// Row index in the sub-matrix grid (input/K dimension).
    pub row: u16,
    /// Column index in the sub-matrix grid (output/N dimension).
    pub col: u16,
    /// Logical rows actually occupied (≤ C at the bottom edge).
    pub used_rows: u16,
    /// Logical cols actually occupied (≤ C at the right edge).
    pub used_cols: u16,
}

/// Partitioning of one K×N weight matrix into crossbar tiles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WeightPartition {
    pub k: usize,
    pub n: usize,
    pub xb: usize,
    /// Grid dimensions: rows = ceil(K/C), cols = ceil(N/C).
    pub grid_rows: usize,
    pub grid_cols: usize,
}

impl WeightPartition {
    pub fn new(k: usize, n: usize, hw: &HwParams) -> Self {
        Self {
            k,
            n,
            xb: hw.xb,
            grid_rows: k.div_ceil(hw.xb),
            grid_cols: n.div_ceil(hw.xb),
        }
    }

    /// Total crossbars required — the paper's ceil(D/C)² for square weights.
    pub fn num_xbars(&self) -> usize {
        self.grid_rows * self.grid_cols
    }

    /// Iterate all sub-matrices with their edge-occupancy.
    pub fn submatrices(&self) -> impl Iterator<Item = SubMatrix> + '_ {
        let (gr, gc, xb) = (self.grid_rows, self.grid_cols, self.xb);
        let (k, n) = (self.k, self.n);
        (0..gr).flat_map(move |r| {
            (0..gc).map(move |c| SubMatrix {
                row: r as u16,
                col: c as u16,
                used_rows: (k - r * xb).min(xb) as u16,
                used_cols: (n - c * xb).min(xb) as u16,
            })
        })
    }

    /// Cell-utilisation: occupied cells / (num_xbars · C²).
    pub fn utilization(&self) -> f64 {
        (self.k * self.n) as f64 / (self.num_xbars() * self.xb * self.xb) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_2048_gives_256_xbars() {
        // Paper §III-B: a 1024×1024 matrix on 128² arrays → 64 sub-matrices;
        // D=2048 → 16² = 256.
        let hw = HwParams::default();
        assert_eq!(WeightPartition::new(1024, 1024, &hw).num_xbars(), 64);
        assert_eq!(WeightPartition::new(2048, 2048, &hw).num_xbars(), 256);
    }

    #[test]
    fn ragged_edges_padded() {
        let hw = HwParams::default();
        let p = WeightPartition::new(200, 300, &hw);
        assert_eq!((p.grid_rows, p.grid_cols), (2, 3));
        let subs: Vec<_> = p.submatrices().collect();
        assert_eq!(subs.len(), 6);
        // bottom-right tile occupancy
        let br = subs.last().unwrap();
        assert_eq!((br.used_rows, br.used_cols), (72, 44));
        assert!(p.utilization() < 1.0);
    }

    #[test]
    fn exact_fit_full_utilization() {
        let hw = HwParams::default();
        let p = WeightPartition::new(256, 384, &hw);
        assert!((p.utilization() - 1.0).abs() < 1e-12);
        assert!(p.submatrices().all(|s| s.used_rows == 128 && s.used_cols == 128));
    }

    #[test]
    fn submatrix_count_matches_grid() {
        let hw = HwParams::default();
        let p = WeightPartition::new(5120, 13824, &hw);
        assert_eq!(p.submatrices().count(), p.num_xbars());
        assert_eq!(p.grid_rows, 40);
        assert_eq!(p.grid_cols, 108);
    }
}
