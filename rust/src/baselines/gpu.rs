//! Roofline models of A100/H100 for decoder-only LLM inference.
//!
//! Prefill is compute-bound (dense-FP16 tensor-core throughput at a batch-1
//! utilisation factor); decode is memory-bandwidth-bound (every generated
//! token re-reads the weights + the KV cache from HBM). These two rules
//! reproduce the published single-GPU serving figures well enough for the
//! Table III comparison — the paper's A100/H100 numbers fall out of the
//! same datasheet constants (ours differ <2×, shape preserved).

use crate::model::ModelShape;

/// Which GPU to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuKind {
    A100,
    H100,
}

/// Datasheet-level GPU description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuModel {
    pub kind: GpuKind,
    /// Dense FP16/BF16 tensor TFLOP/s (no sparsity).
    pub tflops: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbs: f64,
    /// Board power, W (the paper's ~300 / ~350 figures).
    pub power_w: f64,
    /// Sustained efficiency factors for batch-1 serving (empirical).
    pub prefill_util: f64,
    pub decode_util: f64,
    pub freq_ghz: f64,
    /// Weight bytes per parameter: 2.0 for FP16 (A100); 1.0 for FP8 on the
    /// H100 transformer engine (how it reaches the paper's 274 tok/s).
    pub weight_bytes_per_param: f64,
}

impl GpuModel {
    pub fn a100() -> Self {
        Self {
            kind: GpuKind::A100,
            tflops: 312.0,
            hbm_gbs: 2039.0,
            power_w: 300.0,
            prefill_util: 0.45,
            decode_util: 0.55,
            freq_ghz: 1.4,
            weight_bytes_per_param: 2.0, // FP16
        }
    }

    pub fn h100() -> Self {
        Self {
            kind: GpuKind::H100,
            tflops: 989.0,
            hbm_gbs: 3350.0,
            power_w: 350.0,
            prefill_util: 0.45,
            decode_util: 0.62,
            freq_ghz: 1.7,
            weight_bytes_per_param: 1.0, // FP8 transformer engine
        }
    }

    /// FLOPs for one token through the model (2 × parameters, plus
    /// attention's 2·ctx·D per layer).
    fn flops_per_token(&self, m: &ModelShape, ctx: usize) -> f64 {
        let params = m.checkpoint_params() as f64;
        let attn = (2 * m.n_layers * 2 * ctx * m.d_model) as f64;
        2.0 * params + attn
    }

    /// Bytes read from HBM per generated token: weights + KV cache.
    fn bytes_per_token(&self, m: &ModelShape, ctx: usize) -> f64 {
        let weight_bytes = m.checkpoint_params() as f64 * self.weight_bytes_per_param;
        let kv_dim = m.d_model * m.n_kv_heads / m.n_heads;
        let kv_bytes = (2 * m.n_layers * ctx * kv_dim) as f64 * 2.0;
        weight_bytes + kv_bytes
    }

    /// Prefill time for `s` tokens (compute-bound batch matmuls).
    pub fn prefill_seconds(&self, m: &ModelShape, s: usize) -> f64 {
        let flops = self.flops_per_token(m, s / 2) * s as f64;
        flops / (self.tflops * 1e12 * self.prefill_util)
    }

    /// One decode step at context `ctx` (bandwidth-bound).
    pub fn decode_step_seconds(&self, m: &ModelShape, ctx: usize) -> f64 {
        self.bytes_per_token(m, ctx) / (self.hbm_gbs * 1e9 * self.decode_util)
    }

    /// Full run: prefill `inp` then generate `out` tokens.
    pub fn run(&self, m: &ModelShape, inp: usize, out: usize) -> GpuReport {
        let prefill_s = self.prefill_seconds(m, inp);
        let mut decode_s = 0.0;
        for t in 0..out {
            decode_s += self.decode_step_seconds(m, inp + t);
        }
        let total_s = prefill_s + decode_s;
        let total_tokens = (inp + out) as f64;
        GpuReport {
            kind: self.kind,
            prefill_s,
            decode_s,
            total_tokens_per_s: total_tokens / total_s,
            gen_tokens_per_s: out as f64 / total_s,
            tokens_per_j: total_tokens / (total_s * self.power_w),
            power_w: self.power_w,
        }
    }
}

/// GPU baseline results for one workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuReport {
    pub kind: GpuKind,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub total_tokens_per_s: f64,
    pub gen_tokens_per_s: f64,
    pub tokens_per_j: f64,
    pub power_w: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelPreset;

    #[test]
    fn a100_8b_near_paper_figure() {
        // Paper Table III: A100 78.36 tok/s on Llama 3-8B (1024 in + 1024
        // out). Our roofline should land within ~2×.
        let m = ModelPreset::Llama8B.shape();
        let r = GpuModel::a100().run(&m, 1024, 1024);
        assert!(
            (40.0..160.0).contains(&r.gen_tokens_per_s)
                || (40.0..160.0).contains(&r.total_tokens_per_s),
            "A100 8B = {:.1}/{:.1} tok/s",
            r.gen_tokens_per_s,
            r.total_tokens_per_s
        );
    }

    #[test]
    fn h100_faster_than_a100() {
        let m = ModelPreset::Llama8B.shape();
        let a = GpuModel::a100().run(&m, 1024, 1024);
        let h = GpuModel::h100().run(&m, 1024, 1024);
        assert!(h.total_tokens_per_s > a.total_tokens_per_s);
        assert!(h.tokens_per_j > a.tokens_per_j);
    }

    #[test]
    fn bigger_model_slower() {
        let g = GpuModel::a100();
        let r8 = g.run(&ModelPreset::Llama8B.shape(), 512, 512);
        let r13 = g.run(&ModelPreset::Llama13B.shape(), 512, 512);
        assert!(r8.total_tokens_per_s > r13.total_tokens_per_s);
    }

    #[test]
    fn decode_bandwidth_bound_grows_with_ctx() {
        let g = GpuModel::a100();
        let m = ModelPreset::Llama8B.shape();
        assert!(g.decode_step_seconds(&m, 4096) > g.decode_step_seconds(&m, 256));
    }

    #[test]
    fn energy_efficiency_magnitude() {
        // Paper: A100 ≈ 0.26 tok/J on 8B. Accept 0.1–1.0.
        let m = ModelPreset::Llama8B.shape();
        let r = GpuModel::a100().run(&m, 1024, 1024);
        assert!((0.1..1.0).contains(&r.tokens_per_j), "{}", r.tokens_per_j);
    }
}
