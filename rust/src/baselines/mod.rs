//! Comparison baselines (paper Table III): analytical roofline models of the
//! A100 and H100 GPUs running Llama-family inference.

pub mod gpu;

pub use gpu::{GpuKind, GpuModel, GpuReport};
