//! Test utilities: deterministic PRNG and a mini property-testing harness.
//!
//! The image's crate registry is offline, so `proptest`/`quickcheck` are
//! unavailable; this module provides the subset we need: a SplitMix64 PRNG
//! (stable across platforms), value generators, a `forall` driver that
//! reports the failing seed + case for reproduction, and a strict JSON
//! reader ([`json::Json`]) for checking the hand-rolled writers.

pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use prop::{forall, Config};
pub use rng::SplitMix64;

/// Scatter a contiguous `[ctx, d]` K/V cache into out-of-order blocks of a
/// larger arena (reverse block order, one unused gap block, `NaN` filler so
/// any out-of-bounds read poisons the result). Returns
/// `(karena, varena, starts)` in the layout `attention_rows_paged` reads:
/// position `j` lives at `starts[j / bs] + (j % bs) * d`. Shared by the
/// kernel unit tests and the integration parity props so the block-layout
/// convention is encoded in exactly one place.
pub fn scatter_blocks(
    kcache: &[f32],
    vcache: &[f32],
    ctx: usize,
    d: usize,
    bs: usize,
) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
    let n_blocks = ctx.div_ceil(bs);
    let mut karena = vec![f32::NAN; (n_blocks + 1) * bs * d];
    let mut varena = vec![f32::NAN; (n_blocks + 1) * bs * d];
    let starts: Vec<usize> = (0..n_blocks).map(|b| (n_blocks - b) * bs * d).collect();
    for j in 0..ctx {
        let at = starts[j / bs] + (j % bs) * d;
        karena[at..at + d].copy_from_slice(&kcache[j * d..(j + 1) * d]);
        varena[at..at + d].copy_from_slice(&vcache[j * d..(j + 1) * d]);
    }
    (karena, varena, starts)
}
