//! Test utilities: deterministic PRNG and a mini property-testing harness.
//!
//! The image's crate registry is offline, so `proptest`/`quickcheck` are
//! unavailable; this module provides the subset we need: a SplitMix64 PRNG
//! (stable across platforms), value generators, and a `forall` driver that
//! reports the failing seed + case for reproduction.

pub mod prop;
pub mod rng;

pub use prop::{forall, Config};
pub use rng::SplitMix64;
