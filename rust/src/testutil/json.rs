//! Minimal JSON parser for tests (serde is unavailable offline).
//!
//! The runtime emits all of its machine-readable output — scenario
//! reports, Chrome traces, JSONL event logs — through hand-rolled
//! writers. The tests that check those documents need an independent
//! reader so a writer bug cannot validate itself; this is that reader.
//! It is a strict recursive-descent parser over the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, literals) that
//! rejects trailing garbage. Not a performance path: test-only.

use std::collections::BTreeMap;

/// A parsed JSON value. Objects use a `BTreeMap` so iteration order is
/// deterministic in assertions; duplicate keys keep the last value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document; any trailing non-whitespace is an
    /// error (catches truncated writers).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), at: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.at != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.at));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup (`None` for non-arrays or out of range).
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric field as u64 (exact integers only — rejects fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    at: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.at < self.b.len() && matches!(self.b[self.at], b' ' | b'\t' | b'\n' | b'\r') {
            self.at += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.at).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.at += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.at))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.at..].starts_with(word.as_bytes()) {
            self.at += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.at))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.at)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.at += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            m.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.at += 1,
                Some(b'}') => {
                    self.at += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.at)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.at += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.at += 1,
                Some(b']') => {
                    self.at += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.at)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.at += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or("unterminated escape")?;
                    self.at += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            // surrogate pair: a second \uXXXX must follow
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| format!("bad codepoint {cp:#x}"))?,
                            );
                        }
                        _ => return Err(format!("bad escape at byte {}", self.at)),
                    }
                }
                _ => {
                    // re-walk from the byte: multi-byte UTF-8 passes through
                    let start = self.at - 1;
                    let n = utf8_len(c);
                    self.at = start + n;
                    let chunk = self
                        .b
                        .get(start..start + n)
                        .ok_or("truncated UTF-8 sequence")?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|e| format!("bad UTF-8: {e}"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let chunk = self
            .b
            .get(self.at..self.at + 4)
            .ok_or("truncated \\u escape")?;
        self.at += 4;
        let text = std::str::from_utf8(chunk).map_err(|_| "bad \\u escape")?;
        u32::from_str_radix(text, 16).map_err(|_| "bad \\u escape".to_string())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.at;
        if self.peek() == Some(b'-') {
            self.at += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.at += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.at]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let j = Json::parse(
            r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\nyA"}, "t": true, "n": null}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").unwrap().idx(0).unwrap().as_u64(), Some(1));
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str(), Some("x\nyA"));
        assert_eq!(j.get("t").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("n"), Some(&Json::Null));
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{\"a\": 1} extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("01x").is_err());
    }

    #[test]
    fn surrogate_pairs_and_unicode_pass_through() {
        let j = Json::parse("\"\\uD83D\\uDE00 π\"").unwrap();
        assert_eq!(j.as_str(), Some("😀 π"));
    }

    #[test]
    fn fractional_numbers_are_not_u64() {
        let j = Json::parse("1.5").unwrap();
        assert_eq!(j.as_u64(), None);
        assert_eq!(j.as_f64(), Some(1.5));
    }
}
