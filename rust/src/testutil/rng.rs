//! SplitMix64 — tiny, fast, deterministic PRNG for tests and workload
//! generation. Reference: Steele, Lea, Flood — "Fast splittable pseudorandom
//! number generators" (the standard splitmix64 finaliser).

/// Deterministic 64-bit PRNG. Same seed → same stream on every platform.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire-style rejection-free reduction is fine for tests.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (two uniforms per call; unmemoised).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Random f32 vector with standard-normal entries.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_first_value() {
        // splitmix64(0) first output — pins the algorithm.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn below_in_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = SplitMix64::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = SplitMix64::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
