//! Mini property-testing harness (offline stand-in for proptest).
//!
//! `forall(cfg, |rng| -> Result<(), String>)` runs the closure over many
//! deterministically-seeded PRNGs; on failure it reports the seed so the
//! case can be replayed with `forall_seed`.

use super::rng::SplitMix64;

/// Property-test configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u64,
    /// Base seed; case i runs with seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, base_seed: 0xD1CE }
    }
}

impl Config {
    pub fn cases(n: u64) -> Self {
        Self { cases: n, ..Self::default() }
    }
}

/// Run `prop` on `cfg.cases` deterministic PRNGs; panic with the failing
/// seed + message on the first failure.
pub fn forall<F>(cfg: Config, mut prop: F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i);
        let mut rng = SplitMix64::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (seed={seed:#x}, case {i}/{}): {msg}", cfg.cases);
        }
    }
}

/// Replay a single failing case.
pub fn forall_seed<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    let mut rng = SplitMix64::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed (seed={seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(Config::cases(10), |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(Config::cases(10), |rng| {
            if rng.below(4) == 3 {
                Err("hit".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<u64> = vec![];
        forall(Config::cases(5), |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        forall(Config::cases(5), |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
