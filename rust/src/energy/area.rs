//! Macro- and system-level area/power breakdown (Table II, Fig. 9).

use super::table2;

/// Per-component share of a macro.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MacroArea {
    pub pe_mm2: f64,
    pub spad_mm2: f64,
    pub router_mm2: f64,
    pub pe_uw: f64,
    pub spad_uw: f64,
    pub router_uw: f64,
}

impl Default for MacroArea {
    fn default() -> Self {
        Self {
            pe_mm2: table2::PE_MM2,
            spad_mm2: table2::SPAD_MM2,
            router_mm2: table2::ROUTER_MM2,
            pe_uw: table2::PE_UW,
            spad_uw: table2::SPAD_UW,
            router_uw: table2::ROUTER_UW,
        }
    }
}

impl MacroArea {
    pub fn total_mm2(&self) -> f64 {
        self.pe_mm2 + self.spad_mm2 + self.router_mm2
    }

    pub fn total_uw(&self) -> f64 {
        self.pe_uw + self.spad_uw + self.router_uw
    }

    /// (power %, area %) shares per component, in PE/scratchpad/router order.
    pub fn shares(&self) -> [(f64, f64); 3] {
        let (tp, ta) = (self.total_uw(), self.total_mm2());
        [
            (self.pe_uw / tp * 100.0, self.pe_mm2 / ta * 100.0),
            (self.spad_uw / tp * 100.0, self.spad_mm2 / ta * 100.0),
            (self.router_uw / tp * 100.0, self.router_mm2 / ta * 100.0),
        ]
    }
}

/// System-level breakdown for `n_macros` (the "consistent as the system
/// scales" property of §VI-C — shares are macro-count invariant).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaBreakdown {
    pub n_macros: usize,
    pub per_macro: MacroArea,
}

impl AreaBreakdown {
    pub fn new(n_macros: usize) -> Self {
        Self { n_macros, per_macro: MacroArea::default() }
    }

    pub fn total_area_mm2(&self) -> f64 {
        self.per_macro.total_mm2() * self.n_macros as f64
    }

    /// Peak (all-active) power in watts — the upper bound the paper's
    /// 10.53 W average sits under because only the critical-path region is
    /// active at a time.
    pub fn peak_power_w(&self) -> f64 {
        self.per_macro.total_uw() * 1e-6 * self.n_macros as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_macro_totals() {
        let m = MacroArea::default();
        assert!((m.total_uw() - 160.65).abs() < 0.01);
        // component sum (the paper's printed 0.1181 total is 1.5% low).
        assert!((m.total_mm2() - 0.1199).abs() < 1e-4);
    }

    #[test]
    fn fig9_router_dominates_power_not_area() {
        let m = MacroArea::default();
        let [_pe, _spad, router] = m.shares();
        assert!(router.0 > 50.0, "router power share {}", router.0);
        assert!(router.1 < 20.0, "router area share {}", router.1);
    }

    #[test]
    fn table1_system_peak_power() {
        // 64 tiles × 1024 macros × 160.65 µW ≈ 10.53 W — the Table III
        // power figure corresponds to the whole Table I system active.
        let b = AreaBreakdown::new(64 * 1024);
        assert!((b.peak_power_w() - 10.53).abs() < 0.01, "{}", b.peak_power_w());
    }

    #[test]
    fn shares_scale_invariant() {
        let small = AreaBreakdown::new(1024);
        let large = AreaBreakdown::new(1 << 20);
        assert_eq!(small.per_macro.shares(), large.per_macro.shares());
    }
}
