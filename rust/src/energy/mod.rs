//! Energy, power, and area models (paper Table II / Fig. 9).
//!
//! Per-macro constants at the 7 nm-scaled node: PIM PE 32.37 µW / 0.0864 mm²
//! (from [15]), scratchpad 37.80 µW / 0.0125 mm² (CACTI-style model), router
//! 90.48 µW / 0.021 mm² (synthesised at 45 nm, scaled). The simulator
//! charges *event* energies derived from these powers at 1 GHz (power ×
//! 1 ns = energy per active cycle); idle macros are power-gated
//! (non-volatile RRAM retains state), which is how the system sustains
//! ~10.5 W while mapping far more macros than are simultaneously active.

pub mod area;
pub mod events;
pub mod router_detail;
pub mod scratchpad;

pub use area::{AreaBreakdown, MacroArea};
pub use events::{EnergyLedger, EventEnergy, EventKind};
pub use router_detail::{RouterDetail, SubBlock};
pub use scratchpad::ScratchpadModel;

/// Table II per-component active power (µW) at the 7 nm-scaled node.
pub mod table2 {
    /// PIM PE active power, µW (from [15]).
    pub const PE_UW: f64 = 32.37;
    /// Scratchpad active power, µW.
    pub const SPAD_UW: f64 = 37.80;
    /// Router (incl. IRCU + crossbar + FIFOs) active power, µW.
    pub const ROUTER_UW: f64 = 90.48;
    /// Total macro active power, µW.
    pub const MACRO_UW: f64 = 160.65;

    /// PIM PE area, mm².
    pub const PE_MM2: f64 = 0.0864;
    /// Scratchpad area, mm².
    pub const SPAD_MM2: f64 = 0.0125;
    /// Router area, mm².
    pub const ROUTER_MM2: f64 = 0.021;
    /// Total macro area, mm². NOTE: the paper prints 0.1181, but its own
    /// components sum to 0.1199 — Table II is internally inconsistent by
    /// 1.5%. We keep the component values authoritative and document the
    /// discrepancy in EXPERIMENTS.md.
    pub const MACRO_MM2: f64 = PE_MM2 + SPAD_MM2 + ROUTER_MM2;
    /// The (inconsistent) total the paper prints.
    pub const MACRO_MM2_PAPER: f64 = 0.1181;
}

/// Linear-ish technology scaling from 45 nm synthesis results to 7 nm
/// (Dennard-inspired: area ∝ (7/45)², power via capacitance + voltage).
/// The paper reports post-scaling numbers; this helper documents the rule
/// used to regenerate them from raw 45 nm synthesis data.
pub fn scale_45nm_to_7nm(power_uw_45: f64, area_mm2_45: f64) -> (f64, f64) {
    let lin = 7.0 / 45.0;
    // Area scales quadratically; power scales ~linearly with feature size
    // at iso-frequency (capacitance ↓ linear, V² ↓ modestly at these nodes).
    (power_uw_45 * lin * 1.45, area_mm2_45 * lin * lin * 2.4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_totals_consistent() {
        let p = table2::PE_UW + table2::SPAD_UW + table2::ROUTER_UW;
        assert!((p - table2::MACRO_UW).abs() < 0.01, "power sum {p}");
        let a = table2::PE_MM2 + table2::SPAD_MM2 + table2::ROUTER_MM2;
        assert!((a - table2::MACRO_MM2).abs() < 1e-12, "area sum {a}");
        // Paper's printed total is 1.5% low — a documented erratum.
        assert!((a - table2::MACRO_MM2_PAPER).abs() < 2e-3);
    }

    #[test]
    fn table2_breakdown_percentages() {
        // Paper: router = 56.32% of power, 17.78% of area.
        let rp = table2::ROUTER_UW / table2::MACRO_UW * 100.0;
        assert!((rp - 56.32).abs() < 0.1, "router power share {rp}");
        // The paper computed area shares against its (low) printed total of
        // 0.1181 mm²; reproduce its arithmetic exactly.
        let ra = table2::ROUTER_MM2 / table2::MACRO_MM2_PAPER * 100.0;
        assert!((ra - 17.78).abs() < 0.1, "router area share {ra}");
        let pa = table2::PE_MM2 / table2::MACRO_MM2_PAPER * 100.0;
        assert!((pa - 73.16).abs() < 0.1, "PE area share {pa}");
    }

    #[test]
    fn scaling_direction_sane() {
        let (p7, a7) = scale_45nm_to_7nm(400.0, 0.5);
        assert!(p7 < 400.0 && a7 < 0.5);
        assert!(p7 > 0.0 && a7 > 0.0);
    }
}
