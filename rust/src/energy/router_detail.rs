//! Router-level area/power sub-breakdown (the right-hand pie of Fig. 9).
//!
//! The paper's router integrates five input FIFOs, the IRCU (16-MAC array +
//! softmax support), the 4-in/5-out output crossbar, and control. Fig. 9
//! shows the IRCU dominating router energy (it is the in-router *compute*)
//! while buffers dominate router area. We derive the sub-block split from
//! the Table I sizing (FIFO bits, MAC count, crossbar ports) with standard
//! per-bit/per-port cost ratios, normalised to the Table II router totals,
//! so the sub-blocks always sum to 90.48 µW / 0.021 mm² exactly.

use crate::arch::HwParams;

use super::table2;

/// One router sub-block's share.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubBlock {
    pub name: &'static str,
    pub power_uw: f64,
    pub area_mm2: f64,
}

/// Router sub-block breakdown normalised to Table II.
#[derive(Debug, Clone, PartialEq)]
pub struct RouterDetail {
    pub blocks: Vec<SubBlock>,
}

impl RouterDetail {
    /// Derive from the hardware configuration.
    pub fn for_hw(hw: &HwParams) -> Self {
        // Relative cost weights (arbitrary units, normalised below):
        //  - FIFOs: storage-dominated; area ∝ total buffered bits, moderate
        //    dynamic power (one push/pop per cycle).
        let fifo_bits = (5 * hw.rbuf_bytes * 8) as f64;
        let fifo_area_w = fifo_bits * 1.0;
        let fifo_power_w = fifo_bits * 0.45;
        //  - IRCU: MAC array dominates dynamic power (switching multipliers
        //    every cycle), modest area per MAC.
        let macs = hw.ircu_macs as f64;
        let ircu_area_w = macs * 220.0;
        let ircu_power_w = macs * 330.0;
        //  - Output crossbar: 4×5 ports × packet width; wiring-dominated.
        let xbar_w = (4.0 * 5.0) * hw.packet_bits as f64;
        let xbar_area_w = xbar_w * 0.9;
        let xbar_power_w = xbar_w * 0.8;
        //  - Control (command registers, repeat counter, decode).
        let ctrl_area_w = 600.0;
        let ctrl_power_w = 450.0;

        let area_total = fifo_area_w + ircu_area_w + xbar_area_w + ctrl_area_w;
        let power_total = fifo_power_w + ircu_power_w + xbar_power_w + ctrl_power_w;
        let mk = |name, pw: f64, aw: f64| SubBlock {
            name,
            power_uw: table2::ROUTER_UW * pw / power_total,
            area_mm2: table2::ROUTER_MM2 * aw / area_total,
        };
        Self {
            blocks: vec![
                mk("input FIFOs", fifo_power_w, fifo_area_w),
                mk("IRCU (MACs + softmax)", ircu_power_w, ircu_area_w),
                mk("output crossbar", xbar_power_w, xbar_area_w),
                mk("control", ctrl_power_w, ctrl_area_w),
            ],
        }
    }

    pub fn total_power_uw(&self) -> f64 {
        self.blocks.iter().map(|b| b.power_uw).sum()
    }

    pub fn total_area_mm2(&self) -> f64 {
        self.blocks.iter().map(|b| b.area_mm2).sum()
    }

    pub fn block(&self, name: &str) -> Option<&SubBlock> {
        self.blocks.iter().find(|b| b.name.contains(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_match_table2_router_row() {
        let d = RouterDetail::for_hw(&HwParams::default());
        assert!((d.total_power_uw() - table2::ROUTER_UW).abs() < 1e-9);
        assert!((d.total_area_mm2() - table2::ROUTER_MM2).abs() < 1e-9);
    }

    #[test]
    fn ircu_dominates_power_fifos_dominate_area() {
        // The Fig. 9 qualitative shape.
        let d = RouterDetail::for_hw(&HwParams::default());
        let ircu = d.block("IRCU").unwrap();
        let fifo = d.block("FIFO").unwrap();
        for b in &d.blocks {
            assert!(ircu.power_uw >= b.power_uw, "IRCU must lead power ({:?})", b.name);
        }
        assert!(fifo.area_mm2 > ircu.area_mm2, "buffers out-area the MAC array");
    }

    #[test]
    fn more_macs_shift_power_share() {
        let hw16 = HwParams::default();
        let mut hw64 = HwParams::default();
        hw64.ircu_macs = 64;
        let s16 = RouterDetail::for_hw(&hw16).block("IRCU").unwrap().power_uw;
        let s64 = RouterDetail::for_hw(&hw64).block("IRCU").unwrap().power_uw;
        // normalised to the same router total, the IRCU share grows
        assert!(s64 > s16);
    }

    #[test]
    fn four_blocks_positive() {
        let d = RouterDetail::for_hw(&HwParams::default());
        assert_eq!(d.blocks.len(), 4);
        assert!(d.blocks.iter().all(|b| b.power_uw > 0.0 && b.area_mm2 > 0.0));
    }
}
