//! Event-based energy accounting.
//!
//! Every simulated hardware event (a link hop, an IRCU MAC burst, a
//! scratchpad burst, a crossbar MVM) deposits energy into an
//! [`EnergyLedger`]. Average power = total energy / elapsed time; idle
//! macros are power-gated and contribute only a small leakage share.

use std::collections::BTreeMap;

use super::table2;

/// Energy-bearing event kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EventKind {
    /// One packet traversing one router (crossbar + FIFO + link).
    RouterHop,
    /// One IRCU MAC-array cycle (up to `ircu_macs` MACs).
    IrcuCycle,
    /// One scratchpad word read.
    SpadRead,
    /// One scratchpad word write.
    SpadWrite,
    /// One crossbar in-place MVM (whole-array analog dot).
    PeMvm,
    /// One crossbar programming pass (deployment only).
    PeProgram,
    /// Controller fetch/decode of one instruction.
    CtrlIssue,
    /// One router-cycle of an *active* (un-gated) macro: clock tree, FIFO
    /// standby, sequencing — drawn whether or not a packet moves. This is
    /// what makes the active region's draw approach Table II's 160.65 µW
    /// per macro and the system average land near the paper's 10.53 W.
    ActiveCycle,
}

impl EventKind {
    pub const ALL: [EventKind; 8] = [
        EventKind::RouterHop,
        EventKind::IrcuCycle,
        EventKind::SpadRead,
        EventKind::SpadWrite,
        EventKind::PeMvm,
        EventKind::PeProgram,
        EventKind::CtrlIssue,
        EventKind::ActiveCycle,
    ];
}

/// Per-event energies in picojoules.
///
/// Derived from Table II powers at 1 GHz: a component drawing P µW while
/// active consumes P fJ per active nanosecond; an event occupying the
/// component for k cycles costs k·P fJ = k·P·1e-3 pJ. The defaults bake in
/// the occupancy factors of each event kind.
#[derive(Debug, Clone, PartialEq)]
pub struct EventEnergy {
    pub pj: BTreeMap<EventKind, f64>,
    /// Leakage power per *mapped* (idle, power-gated) macro, µW.
    pub idle_leak_uw: f64,
}

impl Default for EventEnergy {
    fn default() -> Self {
        let mut pj = BTreeMap::new();
        // Router active power 90.48 µW → 0.09048 pJ/cycle; a hop keeps the
        // input FIFO + crossbar + output driver busy ~1 cycle.
        pj.insert(EventKind::RouterHop, table2::ROUTER_UW * 1e-3);
        // The IRCU MAC array is the dominant router sub-block (Fig. 9):
        // charge ~60% of router power per compute cycle.
        pj.insert(EventKind::IrcuCycle, table2::ROUTER_UW * 0.6 * 1e-3);
        // Scratchpad 37.8 µW across a 16-bit word interface.
        pj.insert(EventKind::SpadRead, table2::SPAD_UW * 0.5 * 1e-3);
        pj.insert(EventKind::SpadWrite, table2::SPAD_UW * 0.6 * 1e-3);
        // PE MVM: whole-array analog dot, 32.37 µW over pe_mvm_cycles ≈ 4.
        pj.insert(EventKind::PeMvm, table2::PE_UW * 4.0 * 1e-3);
        // Programming: ~1e4 × an MVM (write-verify row passes).
        pj.insert(EventKind::PeProgram, table2::PE_UW * 4.0 * 1e-3 * 1e4);
        // Controller issue: decode + crossbar broadcast, ≈ one router cycle.
        pj.insert(EventKind::CtrlIssue, table2::ROUTER_UW * 1e-3);
        // Active-macro baseline: ~70% of the macro's Table II draw is
        // clock/sequencing that burns whenever the region is un-gated.
        pj.insert(EventKind::ActiveCycle, table2::MACRO_UW * 0.7 * 1e-3);
        Self { pj, idle_leak_uw: 0.15 }
    }
}

impl EventEnergy {
    pub fn energy_pj(&self, kind: EventKind) -> f64 {
        self.pj[&kind]
    }
}

/// Accumulates event counts + energy over a simulation.
#[derive(Debug, Clone, Default)]
pub struct EnergyLedger {
    pub counts: BTreeMap<EventKind, u64>,
    pub dynamic_pj: f64,
}

impl EnergyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` events of `kind`.
    pub fn add(&mut self, model: &EventEnergy, kind: EventKind, n: u64) {
        *self.counts.entry(kind).or_insert(0) += n;
        self.dynamic_pj += model.energy_pj(kind) * n as f64;
    }

    pub fn merge(&mut self, other: &EnergyLedger) {
        for (k, v) in &other.counts {
            *self.counts.entry(*k).or_insert(0) += v;
        }
        self.dynamic_pj += other.dynamic_pj;
    }

    /// Total energy in joules including idle leakage of `mapped_macros`
    /// over `seconds`.
    pub fn total_j(&self, model: &EventEnergy, mapped_macros: usize, seconds: f64) -> f64 {
        let leak_w = model.idle_leak_uw * 1e-6 * mapped_macros as f64;
        self.dynamic_pj * 1e-12 + leak_w * seconds
    }

    /// Average power in watts over `seconds`.
    pub fn avg_power_w(&self, model: &EventEnergy, mapped_macros: usize, seconds: f64) -> f64 {
        if seconds <= 0.0 {
            return 0.0;
        }
        self.total_j(model, mapped_macros, seconds) / seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_energy_positive_and_ordered() {
        let m = EventEnergy::default();
        for k in EventKind::ALL {
            assert!(m.energy_pj(k) > 0.0, "{k:?}");
        }
        // programming must dwarf everything else
        assert!(m.energy_pj(EventKind::PeProgram) > 1e3 * m.energy_pj(EventKind::PeMvm));
        // a hop costs more than a scratchpad word access (Table II ordering)
        assert!(m.energy_pj(EventKind::RouterHop) > m.energy_pj(EventKind::SpadRead));
    }

    #[test]
    fn ledger_accumulates() {
        let m = EventEnergy::default();
        let mut l = EnergyLedger::new();
        l.add(&m, EventKind::RouterHop, 1000);
        l.add(&m, EventKind::IrcuCycle, 500);
        assert_eq!(l.counts[&EventKind::RouterHop], 1000);
        let expect = 1000.0 * m.energy_pj(EventKind::RouterHop)
            + 500.0 * m.energy_pj(EventKind::IrcuCycle);
        assert!((l.dynamic_pj - expect).abs() < 1e-9);
    }

    #[test]
    fn merge_is_additive() {
        let m = EventEnergy::default();
        let mut a = EnergyLedger::new();
        a.add(&m, EventKind::SpadRead, 10);
        let mut b = EnergyLedger::new();
        b.add(&m, EventKind::SpadRead, 5);
        b.add(&m, EventKind::PeMvm, 2);
        a.merge(&b);
        assert_eq!(a.counts[&EventKind::SpadRead], 15);
        assert_eq!(a.counts[&EventKind::PeMvm], 2);
    }

    #[test]
    fn avg_power_includes_leakage() {
        let m = EventEnergy::default();
        let l = EnergyLedger::new();
        // no events: power = leakage only = 0.15 µW × 1e6 macros = 0.15 W
        let p = l.avg_power_w(&m, 1_000_000, 1.0);
        assert!((p - 0.15).abs() < 1e-9, "{p}");
    }

    #[test]
    fn busy_router_power_matches_table2() {
        // A router hopping every cycle for 1 s at 1 GHz should draw ~90 µW.
        let m = EventEnergy::default();
        let mut l = EnergyLedger::new();
        l.add(&m, EventKind::RouterHop, 1_000_000_000);
        let p = l.avg_power_w(&m, 0, 1.0);
        assert!((p - 90.48e-6).abs() / 90.48e-6 < 1e-6, "{p}");
    }
}
