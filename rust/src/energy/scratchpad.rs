//! CACTI-style analytical scratchpad model.
//!
//! The paper estimates scratchpad area/power with CACTI [20]; we fit a
//! simple capacity/width law anchored at Table II's 32 KB / 16-bit point
//! (37.80 µW, 0.0125 mm²) so alternative configurations (swept in design
//! studies) scale plausibly: energy/access grows ~sqrt(capacity), area
//! grows ~linearly with capacity.

use super::table2;

/// Analytical SRAM scratchpad model anchored at the Table II point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScratchpadModel {
    pub capacity_bytes: usize,
    pub word_bits: u32,
}

/// The Table II anchor configuration.
const ANCHOR_BYTES: f64 = 32.0 * 1024.0;

impl ScratchpadModel {
    pub fn new(capacity_bytes: usize, word_bits: u32) -> Self {
        Self { capacity_bytes, word_bits }
    }

    /// Table I default: 32 KB, 16-bit words.
    pub fn table1() -> Self {
        Self::new(32 * 1024, 16)
    }

    /// Active power, µW (bitline/wordline energy ∝ sqrt(capacity); word
    /// width scales the sense-amp count linearly).
    pub fn active_power_uw(&self) -> f64 {
        let cap_scale = (self.capacity_bytes as f64 / ANCHOR_BYTES).sqrt();
        let width_scale = self.word_bits as f64 / 16.0;
        table2::SPAD_UW * cap_scale * width_scale
    }

    /// Area, mm² (cell array dominates: ~linear in capacity).
    pub fn area_mm2(&self) -> f64 {
        table2::SPAD_MM2 * (self.capacity_bytes as f64 / ANCHOR_BYTES)
    }

    /// Depth in words.
    pub fn words(&self) -> usize {
        self.capacity_bytes / (self.word_bits as usize / 8)
    }

    /// Energy per word access, pJ (active power over one 1 GHz cycle).
    pub fn access_pj(&self) -> f64 {
        self.active_power_uw() * 1e-3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_reproduces_table2() {
        let m = ScratchpadModel::table1();
        assert!((m.active_power_uw() - table2::SPAD_UW).abs() < 1e-9);
        assert!((m.area_mm2() - table2::SPAD_MM2).abs() < 1e-9);
        assert_eq!(m.words(), 16 * 1024);
    }

    #[test]
    fn scaling_monotone() {
        let small = ScratchpadModel::new(8 * 1024, 16);
        let big = ScratchpadModel::new(128 * 1024, 16);
        assert!(small.active_power_uw() < big.active_power_uw());
        assert!(small.area_mm2() < big.area_mm2());
        // area linear, power sub-linear in capacity
        let area_ratio = big.area_mm2() / small.area_mm2();
        let pow_ratio = big.active_power_uw() / small.active_power_uw();
        assert!((area_ratio - 16.0).abs() < 1e-9);
        assert!((pow_ratio - 4.0).abs() < 1e-9);
    }

    #[test]
    fn wider_words_cost_power() {
        let narrow = ScratchpadModel::new(32 * 1024, 16);
        let wide = ScratchpadModel::new(32 * 1024, 64);
        assert!((wide.active_power_uw() / narrow.active_power_uw() - 4.0).abs() < 1e-9);
        assert_eq!(wide.words(), narrow.words() / 4);
    }
}
