//! NoC hardware model (paper §V-B): 5-port routers with per-port input
//! FIFOs, an in-router compute unit (IRCU) with a MAC array, a 4-input
//! 5-output crossbar with multicast, and the mesh-level packet simulator
//! that executes NPM instructions cycle by cycle.
//!
//! The simulator is *functional at packet granularity*: payloads are opaque
//! token counts (the numerics live in the PJRT-executed artifacts), but
//! movement, buffering, and bandwidth are modelled per cycle, so FIFO
//! overflow, link contention, and conservation can be property-tested.

pub mod mesh;
pub mod router;

pub use mesh::{MeshSim, SimStats};
pub use router::{Router, RouterConfig};
