//! Single-router model: five input FIFOs (N/E/S/W/PE), a local egress
//! staging queue fed by scratchpad reads, the IRCU, and event counters.

use std::collections::VecDeque;

use crate::arch::Dir;

/// Static router configuration derived from `HwParams`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouterConfig {
    /// Input FIFO capacity in packets (rbuf_bytes / packet bytes).
    pub fifo_packets: usize,
    /// Scratchpad capacity in 16-bit words.
    pub spad_words: usize,
    /// MACs in the IRCU.
    pub macs: usize,
}

impl RouterConfig {
    pub fn from_hw(hw: &crate::arch::HwParams) -> Self {
        Self {
            fifo_packets: (hw.rbuf_bytes / (hw.packet_bits as usize / 8)).max(1),
            spad_words: hw.scratchpad_words(),
            macs: hw.ircu_macs,
        }
    }
}

/// Per-router counters the energy ledger consumes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterCounters {
    pub hops: u64,
    pub ircu_cycles: u64,
    pub spad_reads: u64,
    pub spad_writes: u64,
    pub stalls: u64,
    pub drops: u64,
}

/// One router's dynamic state. A "packet" is an opaque payload id — the
/// simulator tracks movement and occupancy, not numerics.
#[derive(Debug, Clone)]
pub struct Router {
    pub cfg: RouterConfig,
    /// Input FIFOs indexed by [`port_index`] (N, E, S, W, PE).
    pub fifos: [VecDeque<u64>; 5],
    /// Egress staging queue (fed by SpadRd, drained by Route*/Bcast*).
    pub egress: VecDeque<u64>,
    /// Scratchpad occupancy in words (contents abstracted).
    pub spad_used: usize,
    pub counters: RouterCounters,
}

/// FIFO index for a port direction.
pub fn port_index(d: Dir) -> usize {
    match d {
        Dir::North => 0,
        Dir::East => 1,
        Dir::South => 2,
        Dir::West => 3,
        Dir::Pe => 4,
    }
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        Self {
            cfg,
            fifos: Default::default(),
            egress: VecDeque::new(),
            spad_used: 0,
            counters: RouterCounters::default(),
        }
    }

    /// Total packets buffered anywhere in this router.
    pub fn buffered(&self) -> usize {
        self.fifos.iter().map(|f| f.len()).sum::<usize>() + self.egress.len()
    }

    /// Try to accept a packet into the `from` input FIFO. Returns false on
    /// backpressure (FIFO full) — the sender must retry (stall).
    pub fn accept(&mut self, from: Dir, payload: u64) -> bool {
        let f = &mut self.fifos[port_index(from)];
        if f.len() >= self.cfg.fifo_packets {
            self.counters.stalls += 1;
            return false;
        }
        f.push_back(payload);
        true
    }

    /// Pop a packet from the source encoded in a command arg:
    /// 0 = egress (local), 1..=4 = N/E/S/W input FIFO, 5 = PE FIFO.
    pub fn pop_source(&mut self, arg: u8) -> Option<u64> {
        match arg {
            0 => self.egress.pop_front(),
            1 => self.fifos[0].pop_front(),
            2 => self.fifos[1].pop_front(),
            3 => self.fifos[2].pop_front(),
            4 => self.fifos[3].pop_front(),
            5 => self.fifos[4].pop_front(),
            _ => None,
        }
    }

    /// Undo a pop (packet could not be delivered this cycle).
    pub fn unpop_source(&mut self, arg: u8, payload: u64) {
        match arg {
            0 => self.egress.push_front(payload),
            1 => self.fifos[0].push_front(payload),
            2 => self.fifos[1].push_front(payload),
            3 => self.fifos[2].push_front(payload),
            4 => self.fifos[3].push_front(payload),
            5 => self.fifos[4].push_front(payload),
            _ => {}
        }
    }

    /// Scratchpad read of one word burst → one packet into egress.
    /// Returns false if nothing to read or egress is saturated.
    pub fn spad_read(&mut self) -> bool {
        if self.spad_used == 0 || self.egress.len() >= self.cfg.fifo_packets * 2 {
            return false;
        }
        self.counters.spad_reads += 1;
        self.egress.push_back(0xC0FFEE);
        true
    }

    /// Scratchpad write of one packet popped from `arg`'s source.
    pub fn spad_write(&mut self, arg: u8) -> bool {
        if self.spad_used >= self.cfg.spad_words {
            self.counters.drops += 1;
            return false;
        }
        if self.pop_source(arg).is_some() {
            self.counters.spad_writes += 1;
            self.spad_used += 1;
            true
        } else {
            false
        }
    }

    /// One IRCU cycle consuming (up to) one operand packet from `arg`.
    /// Compute results stay local (they surface later via SpadRd).
    pub fn ircu_op(&mut self, arg: u8) -> bool {
        self.counters.ircu_cycles += 1;
        if let Some(_p) = self.pop_source(arg) {
            // operand consumed into the accumulator file
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwParams;

    fn router() -> Router {
        Router::new(RouterConfig::from_hw(&HwParams::default()))
    }

    #[test]
    fn config_from_table1() {
        let cfg = RouterConfig::from_hw(&HwParams::default());
        assert_eq!(cfg.fifo_packets, 32); // 256 B / 8 B packets
        assert_eq!(cfg.spad_words, 16 * 1024);
        assert_eq!(cfg.macs, 16);
    }

    #[test]
    fn fifo_backpressure() {
        let mut r = router();
        for i in 0..32 {
            assert!(r.accept(Dir::West, i));
        }
        assert!(!r.accept(Dir::West, 99), "33rd packet must stall");
        assert_eq!(r.counters.stalls, 1);
        assert_eq!(r.buffered(), 32);
    }

    #[test]
    fn pop_unpop_roundtrip() {
        let mut r = router();
        r.accept(Dir::North, 7);
        let p = r.pop_source(1).unwrap();
        assert_eq!(p, 7);
        r.unpop_source(1, p);
        assert_eq!(r.fifos[0].front(), Some(&7));
    }

    #[test]
    fn spad_write_then_read() {
        let mut r = router();
        r.accept(Dir::Pe, 1);
        assert!(r.spad_write(5));
        assert_eq!(r.spad_used, 1);
        assert!(r.spad_read());
        assert_eq!(r.egress.len(), 1);
        assert_eq!(r.counters.spad_reads, 1);
    }

    #[test]
    fn spad_capacity_enforced() {
        let mut r = router();
        r.cfg.spad_words = 2;
        r.accept(Dir::West, 1);
        r.accept(Dir::West, 2);
        r.accept(Dir::West, 3);
        assert!(r.spad_write(4));
        assert!(r.spad_write(4));
        assert!(!r.spad_write(4), "third write exceeds capacity");
        assert_eq!(r.counters.drops, 1);
    }

    #[test]
    fn ircu_counts_even_when_starved() {
        let mut r = router();
        assert!(!r.ircu_op(1), "no operand");
        assert_eq!(r.counters.ircu_cycles, 1);
    }
}
