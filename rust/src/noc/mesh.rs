//! Mesh-level instruction executor: runs an NPM [`Program`] on a grid of
//! [`Router`]s + PIM PEs, cycle by cycle, with the NMC semantics of §V-A
//! (one instruction at a time, each repeated `CMD_rep` cycles; CMD1/CMD2
//! dispatched through the command crossbar to the selected routers).

use crate::arch::{Coord, Dir, HwParams, Mesh};
use crate::energy::{EnergyLedger, EventEnergy, EventKind};
use crate::isa::{Instruction, Opcode, Program};
use crate::pim::PimPe;

use super::router::{Router, RouterConfig};

/// Aggregate statistics of one simulated program.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimStats {
    /// Total elapsed cycles (issue + repeats).
    pub cycles: u64,
    /// Cycles attributed per opcode class (Fig. 11 breakdown).
    pub class_cycles: std::collections::BTreeMap<&'static str, u64>,
    /// Total packets created / delivered-to-scratchpad or consumed.
    pub packets_created: u64,
    pub packets_consumed: u64,
    /// Total hop events.
    pub hops: u64,
    /// Stall events (backpressure).
    pub stalls: u64,
}

/// Instruction-level mesh simulator.
pub struct MeshSim {
    pub mesh: Mesh,
    pub hw: HwParams,
    pub routers: Vec<Router>,
    pub pes: Vec<PimPe>,
    /// Pending PE output packets: (router index, remaining packets).
    pe_out_pending: Vec<u64>,
    /// Router indices with non-zero PE backlog (drain worklist).
    pe_drain_list: Vec<usize>,
    /// Reused per-step delivery buffer (perf: avoids per-cycle allocation).
    deliveries: Vec<(usize, Dir, u64, u8)>,
    pub ledger: EnergyLedger,
    energy: EventEnergy,
    pub stats: SimStats,
}

impl MeshSim {
    pub fn new(width: u16, height: u16, hw: HwParams) -> Self {
        let mesh = Mesh::new(width, height);
        let cfg = RouterConfig::from_hw(&hw);
        let n = mesh.len();
        let mut pes: Vec<PimPe> = (0..n).map(|_| PimPe::default()).collect();
        // Crossbars come up programmed (deployment happens before serving).
        for (i, pe) in pes.iter_mut().enumerate() {
            pe.program(i as u32);
        }
        Self {
            mesh,
            hw,
            routers: (0..n).map(|_| Router::new(cfg)).collect(),
            pes,
            pe_out_pending: vec![0; n],
            pe_drain_list: Vec::new(),
            deliveries: Vec::new(),
            ledger: EnergyLedger::new(),
            energy: EventEnergy::default(),
            stats: SimStats::default(),
        }
    }

    /// Pre-load `words` of scratchpad data into router (x, y) — models
    /// prior-phase results already resident (e.g. the KV cache).
    pub fn preload_spad(&mut self, c: Coord, words: usize) {
        let idx = self.mesh.index(c);
        let r = &mut self.routers[idx];
        r.spad_used = (r.spad_used + words).min(r.cfg.spad_words);
    }

    /// Run a complete program; returns the cycles it took.
    pub fn run(&mut self, prog: &Program) -> anyhow::Result<u64> {
        let start_cycles = self.stats.cycles;
        // Reused scratch for the per-instruction router selection — the
        // command crossbar configuration is fixed for all CMD_rep repeats,
        // so it is resolved once per instruction, not per cycle (perf pass
        // §Perf change 2: ~20× on large meshes).
        let mut selected: Vec<(usize, crate::isa::Cmd)> = Vec::new();
        for instr in &prog.instrs {
            // one issue cycle for fetch/decode through the command crossbar
            self.stats.cycles += 1;
            *self.stats.class_cycles.entry("ctrl").or_insert(0) += 1;
            self.ledger.add(&self.energy, EventKind::CtrlIssue, 1);
            if instr.cmd1.op == Opcode::Halt {
                break;
            }
            selected.clear();
            for y in 0..self.mesh.height {
                for x in 0..self.mesh.width {
                    match instr.sel.command_for(x, y) {
                        Some(1) => selected.push((self.mesh.index(Coord::new(x, y)), instr.cmd1)),
                        Some(2) => selected.push((self.mesh.index(Coord::new(x, y)), instr.cmd2)),
                        _ => {}
                    }
                }
            }
            for _ in 0..instr.rep.max(1) {
                self.step(instr, &selected)?;
            }
        }
        Ok(self.stats.cycles - start_cycles)
    }

    /// Execute one repeat-cycle of an instruction across the pre-resolved
    /// selected routers. Two sweep phases (collect sends, then deliver)
    /// keep the cycle semantics order-independent.
    fn step(&mut self, instr: &Instruction, selected: &[(usize, crate::isa::Cmd)]) -> anyhow::Result<()> {
        self.stats.cycles += 1;
        // Charge the cycle to the dominant (CMD1) class.
        *self.stats.class_cycles.entry(instr.cmd1.op.class()).or_insert(0) += 1;

        // (router index, destination dir, payload, source arg)
        let mut deliveries = std::mem::take(&mut self.deliveries);
        deliveries.clear();
        // Per-step event tallies, flushed to the ledger once per cycle —
        // avoids O(selected routers) BTreeMap lookups per cycle (perf pass
        // §Perf change 4, the dominant mesh-executor cost).
        let (mut n_hops, mut n_ircu, mut n_sprd, mut n_spwr, mut n_mvm) =
            (0u64, 0u64, 0u64, 0u64, 0u64);

        {
            for &(idx, cmd) in selected {
                match cmd.op {
                    Opcode::Nop | Opcode::Sync | Opcode::Halt => {}
                    Opcode::RouteN | Opcode::RouteE | Opcode::RouteS | Opcode::RouteW
                    | Opcode::RoutePe | Opcode::ReduceE | Opcode::ReduceS | Opcode::BcastRow
                    | Opcode::BcastCol => {
                        let dir = match cmd.op {
                            Opcode::RouteN => Dir::North,
                            Opcode::RouteE | Opcode::ReduceE | Opcode::BcastRow => Dir::East,
                            Opcode::RouteS | Opcode::ReduceS | Opcode::BcastCol => Dir::South,
                            Opcode::RouteW => Dir::West,
                            _ => Dir::Pe,
                        };
                        if let Some(p) = self.routers[idx].pop_source(cmd.arg) {
                            deliveries.push((idx, dir, p, cmd.arg));
                        }
                        if cmd.op == Opcode::ReduceE || cmd.op == Opcode::ReduceS {
                            // the add half of a pipelined reduction
                            self.routers[idx].counters.ircu_cycles += 1;
                            n_ircu += 1;
                        }
                        if cmd.op == Opcode::BcastRow || cmd.op == Opcode::BcastCol {
                            // multicast also deposits a copy locally
                            self.routers[idx].counters.spad_writes += 1;
                            n_spwr += 1;
                        }
                    }
                    Opcode::Mac | Opcode::Add | Opcode::Mul | Opcode::ExpMax => {
                        // only consume a packet if an operand was available
                        if self.routers[idx].ircu_op(cmd.arg) {
                            self.stats.packets_consumed += 1;
                        }
                        n_ircu += 1;
                    }
                    Opcode::SpadRd => {
                        if self.routers[idx].spad_read() {
                            n_sprd += 1;
                            self.stats.packets_created += 1;
                        }
                    }
                    Opcode::SpadWr => {
                        if self.routers[idx].spad_write(cmd.arg) {
                            n_spwr += 1;
                            self.stats.packets_consumed += 1;
                        }
                    }
                    Opcode::PeMvm => {
                        self.pes[idx].mvm()?;
                        n_mvm += 1;
                        // results drain into the PE port over following cycles
                        if self.pe_out_pending[idx] == 0 {
                            self.pe_drain_list.push(idx);
                        }
                        self.pe_out_pending[idx] +=
                            (self.hw.xb as u64).div_ceil(self.hw.elems_per_packet() as u64);
                    }
                }
            }
        }

        // PE output drain: one packet per cycle into the local PE FIFO.
        // Only routers with a non-zero backlog are visited (perf pass
        // §Perf change 3 — avoids an O(mesh) scan on every cycle).
        let mut drain = std::mem::take(&mut self.pe_drain_list);
        drain.retain(|&idx| {
            debug_assert!(self.pe_out_pending[idx] > 0);
            if self.routers[idx].accept(Dir::Pe, 0xBEEF) {
                self.pe_out_pending[idx] -= 1;
                self.stats.packets_created += 1;
            }
            self.pe_out_pending[idx] > 0
        });
        self.pe_drain_list = drain;

        // Delivery phase: move packets to neighbour FIFOs with backpressure.
        for (idx, dir, payload, src_arg) in deliveries.drain(..) {
            let from = self.mesh.coord(idx);
            match dir {
                Dir::Pe => {
                    // deliver to the local PE (input staging) — consumed.
                    self.stats.packets_consumed += 1;
                    self.stats.hops += 1;
                    n_hops += 1;
                }
                d => {
                    if let Some(to) = self.mesh.neighbor(from, d) {
                        let tidx = self.mesh.index(to);
                        let back = d.opposite().expect("mesh dir");
                        if self.routers[tidx].accept(back, payload) {
                            self.stats.hops += 1;
                            self.routers[idx].counters.hops += 1;
                            n_hops += 1;
                        } else {
                            // backpressure: restore to the source queue
                            self.routers[idx].unpop_source(src_arg, payload);
                            self.stats.stalls += 1;
                        }
                    } else {
                        // edge exit: counts as delivered off-tile (to the
                        // neighbouring tile or the I/O ring)
                        self.stats.hops += 1;
                        self.stats.packets_consumed += 1;
                        n_hops += 1;
                    }
                }
            }
        }
        // flush the per-step tallies
        if n_hops > 0 {
            self.ledger.add(&self.energy, EventKind::RouterHop, n_hops);
        }
        if n_ircu > 0 {
            self.ledger.add(&self.energy, EventKind::IrcuCycle, n_ircu);
        }
        if n_sprd > 0 {
            self.ledger.add(&self.energy, EventKind::SpadRead, n_sprd);
        }
        if n_spwr > 0 {
            self.ledger.add(&self.energy, EventKind::SpadWrite, n_spwr);
        }
        if n_mvm > 0 {
            self.ledger.add(&self.energy, EventKind::PeMvm, n_mvm);
        }
        self.deliveries = deliveries;
        Ok(())
    }

    /// Packets currently buffered across the whole mesh. PE output backlog
    /// (`pe_out_pending`) is *not* included: those results have not been
    /// materialised into packets yet (creation is counted at FIFO entry).
    pub fn in_flight(&self) -> u64 {
        self.routers.iter().map(|r| r.buffered() as u64).sum::<u64>()
    }

    /// Crossbar results awaiting drain into PE FIFOs.
    pub fn pe_backlog(&self) -> u64 {
        self.pe_out_pending.iter().sum()
    }

    /// Conservation check: created = consumed + in flight (hops move, never
    /// create or destroy).
    pub fn conservation_ok(&self) -> bool {
        self.stats.packets_created == self.stats.packets_consumed + self.in_flight()
    }
}

#[cfg(test)]
mod tests {
    use super::super::router::port_index;
    use super::*;
    use crate::isa::{Cmd, SelBits};

    fn sim4() -> MeshSim {
        MeshSim::new(4, 4, HwParams::default())
    }

    fn uni(op: Opcode, arg: u8, rep: u16, sel: SelBits) -> Instruction {
        Instruction::uni(Cmd::new(op, arg), rep, sel)
    }

    #[test]
    fn pe_mvm_creates_packets() {
        let mut sim = sim4();
        let mut p = Program::new("mvm");
        p.push(uni(Opcode::PeMvm, 0, 1, SelBits::Rect { rlo: 0, rhi: 1, clo: 0, chi: 1 }));
        // drain cycles: 128/4 = 32 packets at 1/cycle
        p.push(uni(Opcode::Nop, 0, 40, SelBits::All));
        let p = p.sealed();
        sim.run(&p).unwrap();
        assert_eq!(sim.stats.packets_created, 32);
        assert!(sim.conservation_ok());
    }

    #[test]
    fn route_east_moves_packet() {
        let mut sim = sim4();
        // seed a packet into router (0,0)'s west FIFO
        sim.routers[0].accept(Dir::West, 42);
        sim.stats.packets_created += 1;
        let mut p = Program::new("route");
        p.push(uni(Opcode::RouteE, 4, 1, SelBits::Rect { rlo: 0, rhi: 1, clo: 0, chi: 1 }));
        sim.run(&p.sealed()).unwrap();
        // packet now in router (1,0)'s west FIFO
        let r1 = &sim.routers[1];
        assert_eq!(r1.fifos[port_index(Dir::West)].front(), Some(&42));
        assert_eq!(sim.stats.hops, 1);
        assert!(sim.conservation_ok());
    }

    #[test]
    fn edge_exit_consumes() {
        let mut sim = sim4();
        sim.routers[3].accept(Dir::West, 9); // router (3,0), east edge
        sim.stats.packets_created += 1;
        let mut p = Program::new("exit");
        p.push(uni(Opcode::RouteE, 4, 1, SelBits::Rect { rlo: 0, rhi: 1, clo: 3, chi: 4 }));
        sim.run(&p.sealed()).unwrap();
        assert_eq!(sim.stats.packets_consumed, 1);
        assert!(sim.conservation_ok());
    }

    #[test]
    fn backpressure_stalls_not_drops() {
        let mut sim = sim4();
        // fill router (1,0)'s west FIFO
        for i in 0..32 {
            sim.routers[1].accept(Dir::West, i);
            sim.stats.packets_created += 1;
        }
        sim.routers[0].accept(Dir::West, 99);
        sim.stats.packets_created += 1;
        let mut p = Program::new("bp");
        p.push(uni(Opcode::RouteE, 4, 3, SelBits::Rect { rlo: 0, rhi: 1, clo: 0, chi: 1 }));
        sim.run(&p.sealed()).unwrap();
        assert!(sim.stats.stalls >= 3, "every attempt must stall");
        // the packet is still buffered at (0,0)
        assert_eq!(sim.routers[0].buffered(), 1);
        assert!(sim.conservation_ok());
    }

    #[test]
    fn spad_pipeline_read_route_write() {
        let mut sim = sim4();
        sim.preload_spad(Coord::new(0, 0), 100);
        let mut p = Program::new("pipe");
        // (0,0): read spad into egress; route east; (1,0): write to spad
        p.push(uni(Opcode::SpadRd, 0, 8, SelBits::Rect { rlo: 0, rhi: 1, clo: 0, chi: 1 }));
        p.push(uni(Opcode::RouteE, 0, 8, SelBits::Rect { rlo: 0, rhi: 1, clo: 0, chi: 1 }));
        p.push(uni(Opcode::SpadWr, 4, 8, SelBits::Rect { rlo: 0, rhi: 1, clo: 1, chi: 2 }));
        sim.run(&p.sealed()).unwrap();
        assert_eq!(sim.routers[1].spad_used, 8);
        assert_eq!(sim.stats.packets_created, 8);
        assert_eq!(sim.stats.packets_consumed, 8);
        assert!(sim.conservation_ok());
    }

    #[test]
    fn class_cycles_accumulate() {
        let mut sim = sim4();
        let mut p = Program::new("cls");
        p.push(uni(Opcode::Mac, 0, 10, SelBits::All));
        p.push(uni(Opcode::RouteE, 0, 5, SelBits::All));
        sim.run(&p.sealed()).unwrap();
        assert_eq!(sim.stats.class_cycles["mul"], 10);
        assert_eq!(sim.stats.class_cycles["send"], 5);
        assert!(sim.stats.class_cycles["ctrl"] >= 3);
    }

    #[test]
    fn energy_ledger_populates() {
        let mut sim = sim4();
        let mut p = Program::new("energy");
        p.push(uni(Opcode::PeMvm, 0, 1, SelBits::All));
        p.push(uni(Opcode::Mac, 0, 4, SelBits::All));
        sim.run(&p.sealed()).unwrap();
        assert!(sim.ledger.dynamic_pj > 0.0);
        assert!(sim.ledger.counts[&EventKind::PeMvm] == 16);
    }
}
