//! Data-stationarity algebra — paper Eqs. (1)–(3).
//!
//! Static data (pre-trained weights) per attention layer: 4·D².
//! Dynamic data (runtime tensors Q/K/V/S/O + input) per layer: 5·S·D + S².
//! The static/dynamic ratio collapses as S grows, which is the paper's
//! motivating Challenge 1 and drives the PIM (DSMM) vs NoC (DDMM) split.

/// Static/dynamic data accounting for one attention layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stationarity {
    /// Embedding dimension D.
    pub d_model: usize,
    /// Sequence length S.
    pub seq_len: usize,
}

impl Stationarity {
    pub fn new(d_model: usize, seq_len: usize) -> Self {
        Self { d_model, seq_len }
    }

    /// Eq. (1): DA_static = 4·D².
    pub fn static_data(&self) -> u64 {
        4 * (self.d_model as u64) * (self.d_model as u64)
    }

    /// Eq. (2): DA_dynamic = 5·S·D + S².
    pub fn dynamic_data(&self) -> u64 {
        let (s, d) = (self.seq_len as u64, self.d_model as u64);
        5 * s * d + s * s
    }

    /// Eq. (3): the static : dynamic ratio.
    pub fn ratio(&self) -> f64 {
        self.static_data() as f64 / self.dynamic_data() as f64
    }

    /// Fraction of attention-layer *multiplications* that are DDMMs
    /// (QKᵀ + S·V = 2·S²·D of 2·S²·D + 4·S·D² total MACs).
    pub fn ddmm_fraction(&self) -> f64 {
        let (s, d) = (self.seq_len as f64, self.d_model as f64);
        let ddmm = 2.0 * s * s * d;
        let dsmm = 4.0 * s * d * d;
        ddmm / (ddmm + dsmm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Eq. (3)'s worked case: S = D gives ratio 4D² / 6D² = 2/3.
    #[test]
    fn ratio_at_s_equals_d() {
        let st = Stationarity::new(1024, 1024);
        assert!((st.ratio() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn dynamic_dominates_at_long_context() {
        let short = Stationarity::new(2048, 128);
        let long = Stationarity::new(2048, 65_536);
        assert!(short.ratio() > 1.0);
        assert!(long.ratio() < 0.1);
        assert!(long.dynamic_data() > long.static_data());
    }

    #[test]
    fn ratio_monotonically_decreasing_in_s() {
        let mut prev = f64::INFINITY;
        for s in [64, 256, 1024, 4096, 16_384] {
            let r = Stationarity::new(2048, s).ratio();
            assert!(r < prev, "ratio must fall with S");
            prev = r;
        }
    }

    #[test]
    fn ddmm_fraction_grows_with_s() {
        let a = Stationarity::new(2048, 256).ddmm_fraction();
        let b = Stationarity::new(2048, 8192).ddmm_fraction();
        assert!(a < b);
        // At S = 2D the DDMM share is 2·(2D)²·D / (2·(2D)²·D + 4·2D·D²) = 1/2.
        let c = Stationarity::new(1024, 2048).ddmm_fraction();
        assert!((c - 0.5).abs() < 1e-12);
    }

    #[test]
    fn exact_small_numbers() {
        let st = Stationarity::new(2, 3);
        assert_eq!(st.static_data(), 16);
        assert_eq!(st.dynamic_data(), 5 * 3 * 2 + 9);
    }
}
