//! Llama-family architecture shape presets (public model cards) plus the
//! tiny configuration matching the AOT artifacts built by python/compile.

use std::fmt;

/// Model shape parameters relevant to mapping and cycle simulation.
/// Weight *values* are irrelevant to the simulator — only shapes matter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelShape {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// KV heads (GQA); equals `n_heads` for MHA. The paper degrades GQA to
    /// the MHA mapping by K/V duplication, which we mirror.
    pub n_kv_heads: usize,
    pub d_ff: usize,
}

/// Named presets used throughout the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelPreset {
    /// Llama 3.2-1B — Table I's reference configuration.
    Llama1B,
    /// Llama 3-8B — Table III row 1.
    Llama8B,
    /// Llama 2-13B — Table III row 2.
    Llama13B,
    /// The tiny model whose artifacts `make artifacts` builds (D=256, L=4).
    Tiny,
}

impl ModelPreset {
    pub const ALL: [ModelPreset; 4] =
        [ModelPreset::Llama1B, ModelPreset::Llama8B, ModelPreset::Llama13B, ModelPreset::Tiny];

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "1b" | "llama1b" | "llama-3.2-1b" => Some(Self::Llama1B),
            "8b" | "llama8b" | "llama-3-8b" => Some(Self::Llama8B),
            "13b" | "llama13b" | "llama-2-13b" => Some(Self::Llama13B),
            "tiny" => Some(Self::Tiny),
            _ => None,
        }
    }

    pub fn shape(self) -> ModelShape {
        match self {
            // Llama 3.2-1B: 16 layers, D=2048, 32 heads / 8 KV, FFN 8192.
            ModelPreset::Llama1B => ModelShape {
                name: "Llama 3.2-1B",
                vocab: 128_256,
                d_model: 2048,
                n_layers: 16,
                n_heads: 32,
                n_kv_heads: 8,
                d_ff: 8192,
            },
            // Llama 3-8B: 32 layers, D=4096, 32 heads / 8 KV, FFN 14336.
            ModelPreset::Llama8B => ModelShape {
                name: "Llama 3-8B",
                vocab: 128_256,
                d_model: 4096,
                n_layers: 32,
                n_heads: 32,
                n_kv_heads: 8,
                d_ff: 14336,
            },
            // Llama 2-13B: 40 layers, D=5120, 40 heads MHA, FFN 13824.
            ModelPreset::Llama13B => ModelShape {
                name: "Llama 2-13B",
                vocab: 32_000,
                d_model: 5120,
                n_layers: 40,
                n_heads: 40,
                n_kv_heads: 40,
                d_ff: 13824,
            },
            // Must match python/compile/model.py::TINY.
            ModelPreset::Tiny => ModelShape {
                name: "tiny-llama",
                vocab: 512,
                d_model: 256,
                n_layers: 4,
                n_heads: 4,
                n_kv_heads: 4,
                d_ff: 512,
            },
        }
    }
}

impl fmt::Display for ModelPreset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.shape().name)
    }
}

impl ModelShape {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Attention parameters per layer: 4·D² for MHA; GQA shrinks K/V but the
    /// paper duplicates them back to the MHA mapping, so the *mapped* count
    /// stays 4·D² (Eq. 1) while the *stored checkpoint* count is smaller.
    pub fn attn_params_mapped(&self) -> usize {
        4 * self.d_model * self.d_model
    }

    /// MLP parameters per layer (SwiGLU: gate + up + down).
    pub fn mlp_params(&self) -> usize {
        3 * self.d_model * self.d_ff
    }

    /// Total mapped parameters (excluding embeddings — kept off-chip).
    pub fn mapped_params(&self) -> usize {
        self.n_layers * (self.attn_params_mapped() + self.mlp_params())
    }

    /// Approximate checkpoint parameter count (with GQA-reduced K/V and
    /// embedding), used only for reporting.
    pub fn checkpoint_params(&self) -> usize {
        let kv = self.d_model * self.d_model * self.n_kv_heads / self.n_heads;
        let attn = 2 * self.d_model * self.d_model + 2 * kv;
        self.n_layers * (attn + self.mlp_params()) + self.vocab * self.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(ModelPreset::parse("8b"), Some(ModelPreset::Llama8B));
        assert_eq!(ModelPreset::parse("Llama-2-13b"), Some(ModelPreset::Llama13B));
        assert_eq!(ModelPreset::parse("TINY"), Some(ModelPreset::Tiny));
        assert_eq!(ModelPreset::parse("70b"), None);
    }

    #[test]
    fn checkpoint_param_counts_plausible() {
        // ±25% of the nominal sizes is fine — we exclude norms/rope tables.
        let b1 = ModelPreset::Llama1B.shape().checkpoint_params() as f64;
        assert!((0.75e9..1.6e9).contains(&b1), "1B params = {b1}");
        let b8 = ModelPreset::Llama8B.shape().checkpoint_params() as f64;
        assert!((6e9..9e9).contains(&b8), "8B params = {b8}");
        let b13 = ModelPreset::Llama13B.shape().checkpoint_params() as f64;
        assert!((11e9..15e9).contains(&b13), "13B params = {b13}");
    }

    #[test]
    fn paper_scaling_example() {
        // §VI-D: 1B → 8B has s_e = 2, s_h = 1.75, s_l = 2.
        let a = ModelPreset::Llama1B.shape();
        let b = ModelPreset::Llama8B.shape();
        assert_eq!(b.d_model / a.d_model, 2);
        assert!((b.d_ff as f64 / a.d_ff as f64 - 1.75).abs() < 1e-9);
        assert_eq!(b.n_layers / a.n_layers, 2);
    }

    #[test]
    fn tiny_matches_python_config() {
        let t = ModelPreset::Tiny.shape();
        assert_eq!((t.vocab, t.d_model, t.n_layers, t.n_heads, t.d_ff), (512, 256, 4, 4, 512));
    }

    #[test]
    fn d_head_divides() {
        for p in ModelPreset::ALL {
            let s = p.shape();
            assert_eq!(s.d_head() * s.n_heads, s.d_model, "{}", s.name);
        }
    }
}
