//! LLM model descriptions: Llama-family shape presets, per-layer operation
//! shapes, and the static/dynamic data-stationarity algebra of paper
//! Eqs. (1)–(3).

pub mod presets;
pub mod stationarity;

pub use presets::{ModelPreset, ModelShape};
pub use stationarity::Stationarity;
