//! Pure-Rust reference numerics backend: an f32 Llama-style forward pass
//! (embed → per-layer RMSNorm/attention/SwiGLU with KV cache → tied LM
//! head) mirroring the jnp oracles in `python/compile/kernels/ref.py` and
//! `model.ref_forward`.
//!
//! It loads the same quantised `leapbin` weight artifacts as the PJRT path
//! (int8 crossbar cells + per-tile scales, dequantised once at load), so
//! generated tokens are real model outputs with zero non-std dependencies —
//! the default functional backend of the serving engine. Golden parity with
//! the python oracle is pinned by `tests/integration_reference.rs` against
//! the checked-in fixture (`tests/fixtures/tiny_ref`, regenerate with
//! `python -m compile.gen_ref_fixture`).
//!
//! The hot path runs through [`super::kernels`]: prefill processes the
//! whole prompt as an `[s, d]` activation matrix, and
//! [`NumericsBackend::decode_batch`] stacks one row per live session so a
//! single weight-stationary pass over each matrix serves every session —
//! the software analogue of LEAP's PIM dataflow. Both are the *same*
//! multi-row forward ([`ReferenceModel::forward_rows`]); a single
//! `decode_step` is a batch of one, which is what makes batched and
//! sequential decode bit-identical (property-tested in
//! `tests/prop_backend.rs`).
//!
//! **Persistent worker pool.** Each backend spawns one
//! [`WorkerPool`](super::pool::WorkerPool) at load time; every parallel
//! kernel dispatches fixed-ownership tile bands onto it, so **zero OS
//! threads are spawned on the request path** after load (the pool's
//! dispatch counter is the observable witness). The per-layer pipeline is
//! fused — residual-add folded into each RMSNorm sweep, Q/K/V as one
//! dispatch, SwiGLU gate·up as one dispatch, and a flash-style
//! online-softmax attention over all `(row, head)` tiles at once — so a
//! decode layer costs a handful of pool barriers instead of a dozen
//! fork-joins.
//!
//! **Paged KV.** Sessions no longer own flat `[s_max, d]` buffers: all KV
//! lives in one [`KvStore`] block pool (block size = one tile row group),
//! each session holding a [`BlockTable`]. Prompt prefixes that match an
//! earlier live session's chain map to the *same* physical blocks
//! (refcounted, copy-on-write on divergence), so concurrency is bounded by
//! actual KV residency rather than session count. Both kernel paths read
//! the cache **in place** through the block tables — the fast path via
//! [`kernels::attention_rows_paged`], the retained [`KernelMode::Naive`]
//! scalar path by walking blocks inside its original per-head loops — so
//! neither ever materialises a gathered K/V copy.
//! `tests/integration_reference.rs` pins paged ≡ flat by comparing a paged
//! pool against a one-block-per-session (flat-equivalent) pool.

use std::collections::HashMap;
use std::collections::HashSet;
use std::path::Path;

use anyhow::{ensure, Context};

use crate::kvcache::{BlockTable, KvCacheConfig, KvDtype, KvStore, PoolStats, SpillImage};

use super::backend::{ArtifactMeta, BatchResults, NumericsBackend, SessionId, StepOutput};
use super::kernels::{
    self, add_residual_rmsnorm, attention_rows_paged_kv, gemm_q8, gemm_q8_qkv, gemm_q8_swiglu,
    gemm_t, rmsnorm_into, QMat, RopeTable, Scratch,
};
use super::leapbin::{self, DType, Tensor};
use super::pool::{WorkerPool, WorkerPoolStats};

/// Which kernel path the backend runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// The `runtime::kernels` fast path (default).
    #[default]
    Fast,
    /// The retained pre-optimisation scalar path: parity oracle and the
    /// baseline for `benches/bench_hotpath.rs`.
    Naive,
}

/// Fast-path weights for one decoder layer: the int8 crossbar cells in
/// transposed [`QMat`] form — streamed directly by `kernels::gemm_q8`
/// with the per-tile scale folded in, so a decode step moves 4× fewer
/// weight bytes than a dequantised-f32 walk would.
struct QLayer {
    wq: QMat,
    wk: QMat,
    wv: QMat,
    wo: QMat,
    w_gate: QMat,
    w_up: QMat,
    w_down: QMat,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// Naive-path weights for one decoder layer: dense dequantised f32 in the
/// original row-major `[k, n]` layout (what `kernels::naive::matvec`
/// walks — the pre-optimisation representation, retained for parity tests
/// and the bench baseline).
struct DenseLayer {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    w_gate: Vec<f32>,
    w_up: Vec<f32>,
    w_down: Vec<f32>,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// The loaded model: metadata plus per-mode weights (exactly one of
/// `qlayers` / `dlayers` is populated).
pub struct ReferenceModel {
    pub meta: ArtifactMeta,
    mode: KernelMode,
    /// Token embeddings, row-major `[vocab, d_model]` (also the tied head;
    /// this layout is simultaneously the transposed head matrix).
    embed: Vec<f32>,
    qlayers: Vec<QLayer>,
    dlayers: Vec<DenseLayer>,
    final_norm: Vec<f32>,
    rope: RopeTable,
}

/// Per-request decode state: a block table into the shared [`KvStore`]
/// pool plus the count of positions actually forwarded. Invariant between
/// operations: `pos == table.len()` (positions are reserved exactly when
/// their rows are computed; a prefix-shared prefill starts with
/// `table.len() == shared_prefix` and skips rewriting those rows).
struct RefSession {
    table: BlockTable,
    pos: usize,
    /// Prompt tokens accumulated across [`NumericsBackend::prefill_chunk`]
    /// calls, so the final chunk can seal the prefix cache with the full
    /// prompt (exactly what monolithic prefill seals). Empty outside a
    /// chunked prefill.
    prompt: Vec<i32>,
}

/// The reference backend: a [`ReferenceModel`], the pooled KV store shared
/// by all sessions, per-session block tables, the shared scratch arena
/// (sessions are stepped one batch at a time, so one arena serves them
/// all), and the resident worker pool every fast kernel dispatches onto —
/// spawned once here, never on the request path.
pub struct ReferenceBackend {
    model: ReferenceModel,
    sessions: HashMap<SessionId, RefSession>,
    scratch: Scratch,
    kv: KvStore,
    pool: WorkerPool,
}

/// Dequantise one `[kp, np]` int8 tile matrix with `[kt, nt]` per-tile
/// scales into a dense f32 matrix (`w[k][n] = q[k][n] * s[k/xb][n/xb]`).
fn dequant(q: &[u8], s: &[f32], kp: usize, np: usize, nt: usize, xb: usize) -> Vec<f32> {
    let mut w = vec![0f32; kp * np];
    for k in 0..kp {
        let srow = &s[(k / xb) * nt..(k / xb) * nt + nt];
        for n in 0..np {
            w[k * np + n] = (q[k * np + n] as i8) as f32 * srow[n / xb];
        }
    }
    w
}

impl ReferenceModel {
    /// Load `meta.txt` + `weights/*.bin` from an artifact directory
    /// (fast-kernel layout).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        Self::load_with_mode(dir, KernelMode::Fast)
    }

    /// Load with an explicit kernel mode (`Naive` retains the
    /// pre-optimisation scalar path for parity tests and benchmarks).
    pub fn load_with_mode(dir: impl AsRef<Path>, mode: KernelMode) -> anyhow::Result<Self> {
        let dir = dir.as_ref();
        let meta_text = std::fs::read_to_string(dir.join("meta.txt"))
            .with_context(|| format!("{}/meta.txt (no artifacts built?)", dir.display()))?;
        let meta = ArtifactMeta::parse(&meta_text)?;
        let tensor = |name: &str| -> anyhow::Result<Tensor> {
            ensure!(
                meta.param_order.iter().any(|p| p == name),
                "param_order lacks required tensor '{name}'"
            );
            leapbin::load(dir.join("weights").join(format!("{name}.bin")))
        };

        let (l, d, ff, v, xb) = (meta.n_layers, meta.d_model, meta.d_ff, meta.vocab, meta.xb);
        ensure!(xb > 0 && d % xb == 0 && ff % xb == 0, "dims must be multiples of xb={xb}");
        ensure!(meta.s_max > 0, "meta s_max must be positive");

        let embed_t = tensor("embed")?;
        ensure!(embed_t.dtype == DType::F32 && embed_t.dims == [v, d], "embed shape");
        let embed = embed_t.as_f32()?;

        let attn_q = tensor("attn_q")?;
        let attn_s = tensor("attn_s")?;
        let gu_q = tensor("gu_q")?;
        let gu_s = tensor("gu_s")?;
        let down_q = tensor("down_q")?;
        let down_s = tensor("down_s")?;
        let norms_t = tensor("norms")?;
        let final_t = tensor("final_norm")?;
        for (name, t) in [("attn_q", &attn_q), ("gu_q", &gu_q), ("down_q", &down_q)] {
            ensure!(t.dtype == DType::I8, "{name} must be int8 cells, got {:?}", t.dtype);
        }
        ensure!(attn_q.dims == [l, 4, d, d], "attn_q dims {:?}", attn_q.dims);
        ensure!(attn_s.dims == [l, 4, d / xb, d / xb], "attn_s dims {:?}", attn_s.dims);
        ensure!(gu_q.dims == [l, 2, d, ff], "gu_q dims {:?}", gu_q.dims);
        ensure!(gu_s.dims == [l, 2, d / xb, ff / xb], "gu_s dims {:?}", gu_s.dims);
        ensure!(down_q.dims == [l, ff, d], "down_q dims {:?}", down_q.dims);
        ensure!(down_s.dims == [l, ff / xb, d / xb], "down_s dims {:?}", down_s.dims);
        ensure!(norms_t.dims == [l, 2, d], "norms dims {:?}", norms_t.dims);
        ensure!(final_t.dims == [d], "final_norm dims {:?}", final_t.dims);
        let attn_sv = attn_s.as_f32()?;
        let gu_sv = gu_s.as_f32()?;
        let down_sv = down_s.as_f32()?;
        let norms = norms_t.as_f32()?;
        let final_norm = final_t.as_f32()?;

        let mut qlayers = Vec::new();
        let mut dlayers = Vec::new();
        for li in 0..l {
            let attn_norm = norms[(li * 2) * d..(li * 2 + 1) * d].to_vec();
            let mlp_norm = norms[(li * 2 + 1) * d..(li * 2 + 2) * d].to_vec();
            let aqo = |i: usize| (li * 4 + i) * d * d;
            let aso = |i: usize| (li * 4 + i) * (d / xb) * (d / xb);
            let gqo = |i: usize| (li * 2 + i) * d * ff;
            let gso = |i: usize| (li * 2 + i) * (d / xb) * (ff / xb);
            let dqo = li * ff * d;
            let dso = li * (ff / xb) * (d / xb);
            match mode {
                KernelMode::Fast => {
                    // No dequantised copy: the kernels stream the int8
                    // cells (transposed) with the scales folded in.
                    let aq = |i: usize| {
                        QMat::from_cells(
                            &attn_q.data[aqo(i)..aqo(i) + d * d],
                            &attn_sv[aso(i)..aso(i) + (d / xb) * (d / xb)],
                            d,
                            d,
                            xb,
                        )
                    };
                    let gq = |i: usize| {
                        QMat::from_cells(
                            &gu_q.data[gqo(i)..gqo(i) + d * ff],
                            &gu_sv[gso(i)..gso(i) + (d / xb) * (ff / xb)],
                            d,
                            ff,
                            xb,
                        )
                    };
                    qlayers.push(QLayer {
                        wq: aq(0),
                        wk: aq(1),
                        wv: aq(2),
                        wo: aq(3),
                        w_gate: gq(0),
                        w_up: gq(1),
                        w_down: QMat::from_cells(
                            &down_q.data[dqo..dqo + ff * d],
                            &down_sv[dso..dso + (ff / xb) * (d / xb)],
                            ff,
                            d,
                            xb,
                        ),
                        attn_norm,
                        mlp_norm,
                    });
                }
                KernelMode::Naive => {
                    let aq = |i: usize| {
                        let cells = &attn_q.data[aqo(i)..aqo(i) + d * d];
                        dequant(cells, &attn_sv[aso(i)..], d, d, d / xb, xb)
                    };
                    let gq = |i: usize| {
                        let cells = &gu_q.data[gqo(i)..gqo(i) + d * ff];
                        dequant(cells, &gu_sv[gso(i)..], d, ff, ff / xb, xb)
                    };
                    dlayers.push(DenseLayer {
                        wq: aq(0),
                        wk: aq(1),
                        wv: aq(2),
                        wo: aq(3),
                        w_gate: gq(0),
                        w_up: gq(1),
                        w_down: dequant(
                            &down_q.data[dqo..dqo + ff * d],
                            &down_sv[dso..],
                            ff,
                            d,
                            d / xb,
                            xb,
                        ),
                        attn_norm,
                        mlp_norm,
                    });
                }
            }
        }
        let rope = RopeTable::new(meta.s_max, meta.d_head(), kernels::ROPE_THETA);
        Ok(Self { meta, mode, embed, qlayers, dlayers, final_norm, rope })
    }

    /// The kernel path this model was loaded for.
    pub fn mode(&self) -> KernelMode {
        self.mode
    }

    /// Multi-row forward through the fused fast-kernel pipeline: each
    /// entry of `rows` is `(session index, token)`; row `i` appends one KV
    /// position to `sessions[rows[i].0]`. A prefill is `s` rows of one
    /// session; a batched decode is one row each of `B` sessions — either
    /// way each weight matrix is streamed once for the whole batch, and
    /// every parallel kernel dispatches onto the resident `pool` (no
    /// thread spawns).
    ///
    /// Per layer the pipeline is: fused residual+RMSNorm sweep → one
    /// fused Q/K/V dispatch → rope + in-place KV block writes → one
    /// flash-attention dispatch over all `(row, head)` tiles → output
    /// projection → fused residual+RMSNorm → one fused SwiGLU dispatch →
    /// down projection, whose residual stays pending for the next norm.
    ///
    /// Returns row-major `[rows.len(), vocab]` logits. Row `i` is
    /// bit-identical to what a batch containing only row `i` (with the
    /// same per-session cache state) would produce: every per-row op —
    /// norm, projection dot, rope, attention, residual — touches only that
    /// row's data in a fixed order.
    ///
    /// KV positions live in the shared block pool: the needed blocks
    /// (boundary growth + copy-on-write of shared tails) are reserved up
    /// front, rows whose position falls inside a prefix-shared block skip
    /// the (bit-identical) rewrite, and attention walks the blocks in
    /// place via [`attention_rows_paged_kv`] — no gathered copy, reading
    /// the pool's storage dtype (f32 bitwise; f16/q8 dequantized
    /// per-row in-register).
    ///
    /// Validates every token, session capacity, and the pool's free-block
    /// demand *before* mutating any session, so an error leaves all
    /// sessions untouched.
    fn forward_rows(
        &self,
        pool: &WorkerPool,
        kv: &mut KvStore,
        sessions: &mut [RefSession],
        rows: &[(usize, i32)],
        scratch: &mut Scratch,
    ) -> anyhow::Result<Vec<f32>> {
        // Hard error, not debug-only: on a Naive-mode model the fast layer
        // stack is empty and the loop would silently skip every layer.
        ensure!(self.mode == KernelMode::Fast, "forward_rows requires a Fast-mode model");
        let m = &self.meta;
        let (d, ff, heads, s_max) = (m.d_model, m.d_ff, m.n_heads, m.s_max);
        let dh = m.d_head();
        let r = rows.len();
        ensure!(r > 0, "empty row batch");
        let bs = kv.config().block_size;

        // -- validate everything up front ---------------------------------
        let mut extra = vec![0usize; sessions.len()];
        for &(si, token) in rows {
            ensure!(si < sessions.len(), "row references session index {si} out of range");
            ensure!(
                (0..m.vocab as i32).contains(&token),
                "token {token} outside vocab 0..{}",
                m.vocab
            );
            extra[si] += 1;
        }
        let mut demand = 0usize;
        for (si, (sess, &n)) in sessions.iter().zip(&extra).enumerate() {
            ensure!(
                sess.pos + n <= s_max,
                "session slot {si}: context {} + {n} new tokens exceeds the \
                 model window s_max={s_max}",
                sess.pos
            );
            let new_positions = (sess.pos + n).saturating_sub(sess.table.len());
            demand += kv.grow_demand(&sess.table, new_positions);
        }
        ensure!(
            demand <= kv.free_blocks(),
            "KV block pool exhausted: step needs {demand} free blocks, {} available",
            kv.free_blocks()
        );

        // -- reserve block capacity (cannot fail after the demand check) --
        for (sess, &n) in sessions.iter_mut().zip(&extra) {
            let new_positions = (sess.pos + n).saturating_sub(sess.table.len());
            kv.grow(&mut sess.table, new_positions)?;
        }

        // -- assign cache positions and gather embeddings -----------------
        scratch.ensure(r, d, ff);
        for (i, &(si, token)) in rows.iter().enumerate() {
            scratch.pos[i] = sessions[si].pos;
            sessions[si].pos += 1;
            let erow = &self.embed[token as usize * d..(token as usize + 1) * d];
            scratch.x[i * d..(i + 1) * d].copy_from_slice(erow);
        }

        // Attention dispatch metadata is layer-invariant (every session
        // contributes exactly `table.blocks().len()` entries to the flat
        // start buffer at every layer): build the per-session offsets and
        // per-row `(offset, ctx)` once; only the offsets' *values*
        // (`block_starts`) are refilled per layer below.
        scratch.sess_starts.clear();
        let mut start_acc = 0usize;
        for sess in sessions.iter() {
            scratch.sess_starts.push(start_acc);
            start_acc += sess.table.blocks().len();
        }
        scratch.attn_rows.clear();
        for (i, &(si, _)) in rows.iter().enumerate() {
            scratch.attn_rows.push((scratch.sess_starts[si], scratch.pos[i] + 1));
        }

        for (li, lw) in self.qlayers.iter().enumerate() {
            // -- attention sub-layer --------------------------------------
            // Fold the previous layer's down-projection residual (pending
            // in `proj`) into this norm's sweep; layer 0 norms the raw
            // embeddings (no residual pending yet).
            if li == 0 {
                for (xrow, xnrow) in scratch.x[..r * d]
                    .chunks_exact(d)
                    .zip(scratch.xn[..r * d].chunks_exact_mut(d))
                {
                    rmsnorm_into(xrow, &lw.attn_norm, xnrow);
                }
            } else {
                for ((xrow, prow), xnrow) in scratch.x[..r * d]
                    .chunks_exact_mut(d)
                    .zip(scratch.proj[..r * d].chunks_exact(d))
                    .zip(scratch.xn[..r * d].chunks_exact_mut(d))
                {
                    add_residual_rmsnorm(xrow, prow, &lw.attn_norm, xnrow);
                }
            }
            gemm_q8_qkv(
                pool,
                &scratch.xn[..r * d],
                &lw.wq,
                &lw.wk,
                &lw.wv,
                r,
                &mut scratch.q[..r * d],
                &mut scratch.k[..r * d],
                &mut scratch.v[..r * d],
            );

            for (i, &(si, _)) in rows.iter().enumerate() {
                let pos = scratch.pos[i];
                self.rope.apply(&mut scratch.q[i * d..(i + 1) * d], pos, heads, dh);
                self.rope.apply(&mut scratch.k[i * d..(i + 1) * d], pos, heads, dh);
                let sess = &sessions[si];
                // Positions inside the prefix-shared region already hold
                // these exact rows (same tokens, same kernels), and shared
                // blocks must never be rewritten — skip, don't copy.
                if pos >= sess.table.shared_prefix() {
                    kv.write_row(
                        sess.table.blocks()[pos / bs],
                        li,
                        pos % bs,
                        &scratch.k[i * d..(i + 1) * d],
                        &scratch.v[i * d..(i + 1) * d],
                    );
                }
            }

            // Causal attention: the KV rows for every position of this
            // step are already present (written above or shared), and row
            // i only reads positions 0..=pos[i] of its own session. ONE
            // dispatch covers every (row, head) tile of the batch: each
            // session's block-start run goes into the flat buffer at the
            // layer-invariant offset computed above.
            scratch.block_starts.clear();
            for sess in sessions.iter() {
                kv.append_starts(&sess.table, li, &mut scratch.block_starts);
            }
            attention_rows_paged_kv(
                pool,
                &scratch.q[..r * d],
                kv.k_view(),
                kv.v_view(),
                &scratch.block_starts,
                &scratch.attn_rows,
                bs,
                heads,
                dh,
                d,
                &mut scratch.o[..r * d],
            );
            gemm_q8(pool, &scratch.o[..r * d], &lw.wo, r, &mut scratch.proj[..r * d]);

            // -- SwiGLU MLP sub-layer (attention residual folded in) ------
            for ((xrow, prow), xnrow) in scratch.x[..r * d]
                .chunks_exact_mut(d)
                .zip(scratch.proj[..r * d].chunks_exact(d))
                .zip(scratch.xn[..r * d].chunks_exact_mut(d))
            {
                add_residual_rmsnorm(xrow, prow, &lw.mlp_norm, xnrow);
            }
            gemm_q8_swiglu(
                pool,
                &scratch.xn[..r * d],
                &lw.w_gate,
                &lw.w_up,
                r,
                &mut scratch.gate[..r * ff],
            );
            gemm_q8(pool, &scratch.gate[..r * ff], &lw.w_down, r, &mut scratch.proj[..r * d]);
            // The down-projection residual stays pending in `proj`; the
            // next layer's attention norm (or the final norm) folds it in.
        }

        // -- tied LM head (last residual folded into the final norm) ------
        for ((xrow, prow), xnrow) in scratch.x[..r * d]
            .chunks_exact_mut(d)
            .zip(scratch.proj[..r * d].chunks_exact(d))
            .zip(scratch.xn[..r * d].chunks_exact_mut(d))
        {
            add_residual_rmsnorm(xrow, prow, &self.final_norm, xnrow);
        }
        let mut logits = vec![0f32; r * m.vocab];
        gemm_t(pool, &scratch.xn[..r * d], &self.embed, r, d, m.vocab, &mut logits);
        Ok(logits)
    }

    /// One causal step through the retained naive scalar path (the exact
    /// pre-optimisation algorithm: per-call `Vec`s, zero-skip axpy matvec
    /// over `[k, n]` weights, per-token trig). Attention walks the paged
    /// cache **in place** through the block table — the per-position
    /// arithmetic and order are exactly the old gathered loop's, so the
    /// logits are bit-identical to the gather-era path while the per-call
    /// `O(ctx·d)` K/V copies are gone (the score/output `Vec`s remain:
    /// this path allocates per call by design). Parity oracle + bench
    /// baseline; only valid on a `KernelMode::Naive` model.
    fn step_one_naive(
        &self,
        kv: &mut KvStore,
        sess: &mut RefSession,
        token: i32,
    ) -> anyhow::Result<Vec<f32>> {
        use kernels::naive::{matvec, rmsnorm, rope};
        ensure!(self.mode == KernelMode::Naive, "step_one_naive requires a Naive-mode model");
        let m = &self.meta;
        let (d, ff, heads, _s_max) = (m.d_model, m.d_ff, m.n_heads, m.s_max);
        let dh = m.d_head();
        m.check_step(sess.pos, token)?;
        let pos = sess.pos;
        let bs = kv.config().block_size;

        // Reserve the position's block up front; an exhausted pool fails
        // before any state changes.
        let new_positions = (pos + 1).saturating_sub(sess.table.len());
        ensure!(
            kv.grow_demand(&sess.table, new_positions) <= kv.free_blocks(),
            "KV block pool exhausted: step needs {} free blocks, {} available",
            kv.grow_demand(&sess.table, new_positions),
            kv.free_blocks()
        );
        kv.grow(&mut sess.table, new_positions)?;

        let mut x = self.embed[token as usize * d..(token as usize + 1) * d].to_vec();

        for (li, lw) in self.dlayers.iter().enumerate() {
            // -- attention sub-layer --------------------------------------
            let xn = rmsnorm(&x, &lw.attn_norm);
            let mut q = matvec(&xn, &lw.wq, d, d);
            let mut k = matvec(&xn, &lw.wk, d, d);
            let v = matvec(&xn, &lw.wv, d, d);
            rope(&mut q, pos, heads, dh);
            rope(&mut k, pos, heads, dh);
            if pos >= sess.table.shared_prefix() {
                kv.write_row(sess.table.blocks()[pos / bs], li, pos % bs, &k, &v);
            }

            let ctx = pos + 1;
            // Walk the paged cache in place: position j is row j % bs of
            // block j / bs. Rows are read through the dtype-tagged view
            // (an f32 pool's copy is bit-identical to the old direct
            // slice walk; f16/q8 dequantize one head slice at a time).
            let scale = 1.0 / (dh as f32).sqrt();
            let mut o = vec![0f32; d];
            let mut scores = vec![0f32; ctx];
            let mut kbuf = vec![0f32; dh];
            let mut vbuf = vec![0f32; dh];
            for h in 0..heads {
                let base = h * dh;
                let qh = &q[base..base + dh];
                let mut max = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate() {
                    let blk = sess.table.blocks()[j / bs];
                    kv.k_view().read_into(kv.row_start(blk, li, j % bs), d, base, &mut kbuf);
                    let mut dot = 0f32;
                    for (a, b) in qh.iter().zip(&kbuf) {
                        dot += a * b;
                    }
                    *sc = dot * scale;
                    max = max.max(*sc);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                }
                let oh = &mut o[base..base + dh];
                for (j, &p) in scores.iter().enumerate() {
                    let blk = sess.table.blocks()[j / bs];
                    kv.v_view().read_into(kv.row_start(blk, li, j % bs), d, base, &mut vbuf);
                    for (ov, &vv) in oh.iter_mut().zip(&vbuf) {
                        *ov += p * vv;
                    }
                }
                for ov in oh.iter_mut() {
                    *ov /= denom;
                }
            }
            let attn_out = matvec(&o, &lw.wo, d, d);
            for (xv, av) in x.iter_mut().zip(&attn_out) {
                *xv += av;
            }

            // -- SwiGLU MLP sub-layer -------------------------------------
            let xn = rmsnorm(&x, &lw.mlp_norm);
            let gate = matvec(&xn, &lw.w_gate, d, ff);
            let up = matvec(&xn, &lw.w_up, d, ff);
            let h: Vec<f32> =
                gate.iter().zip(&up).map(|(&g, &u)| g / (1.0 + (-g).exp()) * u).collect();
            let down = matvec(&h, &lw.w_down, ff, d);
            for (xv, dv) in x.iter_mut().zip(&down) {
                *xv += dv;
            }
        }

        let xf = rmsnorm(&x, &self.final_norm);
        let mut logits = vec![0f32; m.vocab];
        for (t, lv) in logits.iter_mut().enumerate() {
            let erow = &self.embed[t * d..(t + 1) * d];
            let mut dot = 0f32;
            for (a, b) in xf.iter().zip(erow) {
                dot += a * b;
            }
            *lv = dot;
        }
        sess.pos += 1;
        Ok(logits)
    }
}

impl ReferenceBackend {
    /// Load the model from an artifact/fixture directory (fast kernels,
    /// default pool sizing).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        Self::load_with_mode(dir, KernelMode::Fast)
    }

    /// Load with an explicit kernel mode ([`KernelMode::Naive`] retains the
    /// pre-optimisation scalar path for parity tests and the bench
    /// baseline).
    pub fn load_with_mode(dir: impl AsRef<Path>, mode: KernelMode) -> anyhow::Result<Self> {
        Self::load_with_opts(dir, mode, None)
    }

    /// Load with an explicit KV pool configuration (`None` = the model's
    /// default: block size = one tile row group, pool sized for 32
    /// full-window sessions, capped at [`Self::DEFAULT_POOL_BYTES`] across
    /// both arenas so big artifacts don't eagerly allocate tens of GB —
    /// the arenas are allocated up front, unlike the old lazy per-session
    /// buffers). Small pools exercise admission/preemption;
    /// `block_size = s_max` + sharing off reproduces the pre-pool flat-KV
    /// layout.
    pub fn load_with_opts(
        dir: impl AsRef<Path>,
        mode: KernelMode,
        kv_cfg: Option<KvCacheConfig>,
    ) -> anyhow::Result<Self> {
        // The worker pool is spawned HERE, once — the decode hot path only
        // ever dispatches onto it. The naive mode never dispatches, so it
        // gets a lane-less pool instead of idle threads.
        let pool = match mode {
            KernelMode::Fast => WorkerPool::new(),
            KernelMode::Naive => WorkerPool::with_threads(1),
        };
        Self::load_with_pool(dir, mode, kv_cfg, pool)
    }

    /// Load with the default pool shape at an explicit KV storage dtype.
    /// The byte budget is unchanged, so quantized dtypes fit
    /// proportionally more blocks when the budget (not the 32-session
    /// sizing) is the binding cap — the capacity win `leap serve
    /// --kv-dtype q8` exposes.
    pub fn load_with_kv_dtype(
        dir: impl AsRef<Path>,
        mode: KernelMode,
        dtype: KvDtype,
    ) -> anyhow::Result<Self> {
        let pool = match mode {
            KernelMode::Fast => WorkerPool::new(),
            KernelMode::Naive => WorkerPool::with_threads(1),
        };
        let model = ReferenceModel::load_with_mode(dir, mode)?;
        let cfg = Self::default_kv_config_with_dtype(&model.meta, dtype);
        Ok(Self::assemble(model, cfg, pool))
    }

    /// Load with an explicit worker pool (tests pin pool sizes 1/2/max for
    /// the determinism props; the bench measures pool-off vs pool-on).
    pub fn load_with_pool(
        dir: impl AsRef<Path>,
        mode: KernelMode,
        kv_cfg: Option<KvCacheConfig>,
        pool: WorkerPool,
    ) -> anyhow::Result<Self> {
        let model = ReferenceModel::load_with_mode(dir, mode)?;
        let cfg = kv_cfg.unwrap_or_else(|| Self::default_kv_config(&model.meta));
        Ok(Self::assemble(model, cfg, pool))
    }

    fn assemble(model: ReferenceModel, cfg: KvCacheConfig, pool: WorkerPool) -> Self {
        let kv = KvStore::new(cfg, model.meta.n_layers, model.meta.d_model);
        Self { model, sessions: HashMap::new(), scratch: Scratch::new(), kv, pool }
    }

    /// Eager-arena byte budget for the *default* pool across both arenas
    /// (512 MiB — the same envelope as the old 64 Mi-f32-words-per-arena
    /// budget; quantized dtypes fit 2–4× more blocks inside it). Explicit
    /// [`KvCacheConfig`]s are taken verbatim.
    pub const DEFAULT_POOL_BYTES: usize = 512 << 20;

    /// The default pool for an artifact: 32 full-window sessions, capped
    /// at the byte budget but never below one full-window session (a
    /// single max-length request must always be serveable).
    fn default_kv_config(meta: &ArtifactMeta) -> KvCacheConfig {
        Self::default_kv_config_with_dtype(meta, KvDtype::F32)
    }

    fn default_kv_config_with_dtype(meta: &ArtifactMeta, dtype: KvDtype) -> KvCacheConfig {
        let mut cfg = KvCacheConfig::for_model(meta.d_model, meta.s_max);
        cfg.dtype = dtype;
        let budget_blocks = cfg
            .blocks_for_bytes(Self::DEFAULT_POOL_BYTES, meta.n_layers, meta.d_model)
            .max(cfg.blocks_for(meta.s_max));
        cfg.n_blocks = cfg.n_blocks.min(budget_blocks);
        cfg
    }

    pub fn model(&self) -> &ReferenceModel {
        &self.model
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.model.meta
    }

    /// The shared KV block pool (tests, benches, gauges).
    pub fn kv(&self) -> &KvStore {
        &self.kv
    }

    /// The resident worker pool (tests, benches, gauges).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Live session count (tests: release bookkeeping).
    pub fn live_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// One session's block table (tests: the chunked-vs-monolithic parity
    /// check reads KV block contents through it).
    pub fn session_table(&self, session: SessionId) -> Option<&BlockTable> {
        self.sessions.get(&session).map(|s| &s.table)
    }
}

impl NumericsBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        match self.model.mode {
            KernelMode::Fast => "reference-f32",
            KernelMode::Naive => "reference-f32-naive",
        }
    }

    fn vocab(&self) -> usize {
        self.model.meta.vocab
    }

    fn prefill(&mut self, session: SessionId, tokens: &[i32]) -> anyhow::Result<StepOutput> {
        ensure!(!tokens.is_empty(), "empty prompt");
        let m = &self.model.meta;
        // No silent truncation (same contract as the PJRT backend): a
        // prompt the KV window cannot hold in full is rejected.
        ensure!(
            tokens.len() <= m.s_max,
            "prompt of {} tokens exceeds the model window s_max={}",
            tokens.len(),
            m.s_max
        );
        // A resubmitted session id restarts from scratch — return its old
        // blocks to the pool first.
        if let Some(old) = self.sessions.remove(&session) {
            self.kv.release_table(old.table);
        }
        let Self { model, sessions, scratch, kv, pool } = self;
        // Resolve as much of the prompt as possible from the prefix cache;
        // the forward pass below computes every row (full logits, same
        // bits) but only writes KV for the unshared positions.
        let table = kv.build_prefill(tokens);
        let mut sess = RefSession { table, pos: 0, prompt: Vec::new() };
        let result = match model.mode {
            KernelMode::Fast => {
                let rows: Vec<(usize, i32)> = tokens.iter().map(|&t| (0usize, t)).collect();
                model.forward_rows(pool, kv, std::slice::from_mut(&mut sess), &rows, scratch)
            }
            KernelMode::Naive => {
                let mut logits = Vec::with_capacity(tokens.len() * model.meta.vocab);
                let mut err = None;
                for &t in tokens {
                    match model.step_one_naive(kv, &mut sess, t) {
                        Ok(row) => logits.extend(row),
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    }
                }
                match err {
                    None => Ok(logits),
                    Some(e) => Err(e),
                }
            }
        };
        match result {
            Ok(logits) => {
                kv.seal_prefill(&sess.table, tokens);
                sessions.insert(session, sess);
                Ok(StepOutput { logits, rows: tokens.len() })
            }
            Err(e) => {
                // release whatever the partial prefill held (shared prefix
                // refcounts included) — a failed prefill leaks nothing
                kv.release_table(sess.table);
                Err(e)
            }
        }
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    /// Incremental prefill: one contiguous prompt slice per call. The
    /// first chunk (`start == 0`) creates the session and resolves the
    /// prefix cache against that chunk alone (sharing a *shorter* prefix
    /// than monolithic prefill might — an efficiency difference only: the
    /// recomputed rows are bit-identical, see `forward_rows`). Mid-prefill
    /// blocks stay unsealed, so concurrent sessions cannot share a
    /// half-written chain; the last chunk seals the prefix cache with the
    /// full accumulated prompt — exactly what monolithic
    /// [`Self::prefill`] seals, making the post-prefill ledger state and
    /// KV bytes identical for any chunking. A failed chunk releases the
    /// whole session (nothing leaks; the engine re-prefills on retry).
    fn prefill_chunk(
        &mut self,
        session: SessionId,
        chunk: &[i32],
        start: usize,
        last: bool,
    ) -> anyhow::Result<StepOutput> {
        ensure!(!chunk.is_empty(), "empty prefill chunk");
        let m = &self.model.meta;
        // Same no-silent-truncation contract as monolithic prefill, applied
        // to the running total.
        ensure!(
            start + chunk.len() <= m.s_max,
            "prompt of {} tokens exceeds the model window s_max={}",
            start + chunk.len(),
            m.s_max
        );
        if start == 0 {
            // first chunk (re)creates the session from scratch
            if let Some(old) = self.sessions.remove(&session) {
                self.kv.release_table(old.table);
            }
            let table = self.kv.build_prefill(chunk);
            self.sessions.insert(session, RefSession { table, pos: 0, prompt: Vec::new() });
        }
        let Self { model, sessions, scratch, kv, pool } = self;
        let sess = sessions.get_mut(&session).ok_or_else(|| {
            anyhow::anyhow!("unknown session {session} (chunked prefill must start at 0)")
        })?;
        ensure!(
            sess.pos == start,
            "prefill chunk starts at {start} but session {session} is at position {}",
            sess.pos
        );
        let result = match model.mode {
            KernelMode::Fast => {
                let rows: Vec<(usize, i32)> = chunk.iter().map(|&t| (0usize, t)).collect();
                model.forward_rows(pool, kv, std::slice::from_mut(sess), &rows, scratch)
            }
            KernelMode::Naive => {
                let mut logits = Vec::with_capacity(chunk.len() * model.meta.vocab);
                let mut err = None;
                for &t in chunk {
                    match model.step_one_naive(kv, sess, t) {
                        Ok(row) => logits.extend(row),
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    }
                }
                match err {
                    None => Ok(logits),
                    Some(e) => Err(e),
                }
            }
        };
        match result {
            Ok(logits) => {
                sess.prompt.extend_from_slice(chunk);
                if last {
                    kv.seal_prefill(&sess.table, &sess.prompt);
                    sess.prompt = Vec::new();
                }
                Ok(StepOutput { logits, rows: chunk.len() })
            }
            Err(e) => {
                // a failed chunk drops the whole partial session — the
                // engine treats it like a failed prefill
                let sess = sessions.remove(&session).expect("session present");
                kv.release_table(sess.table);
                Err(e)
            }
        }
    }

    fn decode_step(&mut self, session: SessionId, token: i32) -> anyhow::Result<StepOutput> {
        let Self { model, sessions, scratch, kv, pool } = self;
        let sess = sessions
            .get_mut(&session)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session} (prefill first)"))?;
        model.meta.check_step(sess.pos, token)?;
        let logits = match model.mode {
            KernelMode::Fast => {
                model.forward_rows(pool, kv, std::slice::from_mut(sess), &[(0, token)], scratch)?
            }
            KernelMode::Naive => model.step_one_naive(kv, sess, token)?,
        };
        Ok(StepOutput { logits, rows: 1 })
    }

    /// Weight-stationary batched decode: every valid step becomes one
    /// activation row of a single [`ReferenceModel::forward_rows`] batch,
    /// so each weight matrix is streamed once per round instead of once
    /// per session. Bit-identical to sequential [`Self::decode_step`]
    /// calls in the same order (each row's arithmetic touches only its own
    /// data); a per-session failure (unknown session, bad token, exhausted
    /// window, starved block pool) occupies its slot as an `Err` without
    /// disturbing the rest of the round. Pool-exhaustion slot failures are
    /// conservative (worst-case demand, see the inline comment), unlike
    /// the window/vocab checks which match sequential behaviour exactly.
    fn decode_batch(&mut self, steps: &[(SessionId, i32)]) -> anyhow::Result<BatchResults> {
        // The naive path has no batched kernel; duplicate session ids need
        // earlier steps visible to later ones. Both fall back to the
        // sequential loop (= the trait's default behaviour).
        let mut seen = HashSet::new();
        let has_dup = steps.iter().any(|&(sid, _)| !seen.insert(sid));
        if self.model.mode == KernelMode::Naive || has_dup {
            return Ok(steps.iter().map(|&(sid, t)| self.decode_step(sid, t)).collect());
        }

        let Self { model, sessions, scratch, kv, pool } = self;
        let vocab = model.meta.vocab;
        let mut results: Vec<Option<anyhow::Result<StepOutput>>> =
            steps.iter().map(|_| None).collect();
        // Move each valid session out of the map for the batch (restored
        // below); invalid steps record their error and stay put. The
        // window/vocab checks (and error text) are exactly decode_step's,
        // so batched and sequential rounds fail identically on those. The
        // per-slot pool check is *conservative*: each slot is charged its
        // worst-case demand in step order, and two sharers of one tail
        // block both count a CoW even though the first copy makes the
        // second unnecessary — so under extreme pressure a slot may fail
        // here that a sequential round would have served. The engine
        // preempts using the same conservative sum before every round, so
        // engine-driven batches never reach this backstop.
        let mut free = kv.free_blocks();
        let mut batch_sessions: Vec<RefSession> = Vec::with_capacity(steps.len());
        let mut batch_slots: Vec<(usize, SessionId)> = Vec::with_capacity(steps.len());
        let mut rows: Vec<(usize, i32)> = Vec::with_capacity(steps.len());
        for (i, &(sid, token)) in steps.iter().enumerate() {
            let Some(sess) = sessions.remove(&sid) else {
                results[i] = Some(Err(anyhow::anyhow!("unknown session {sid} (prefill first)")));
                continue;
            };
            if let Err(err) = model.meta.check_step(sess.pos, token) {
                results[i] = Some(Err(err));
                sessions.insert(sid, sess);
                continue;
            }
            let need = kv.grow_demand(&sess.table, (sess.pos + 1).saturating_sub(sess.table.len()));
            if need > free {
                results[i] = Some(Err(anyhow::anyhow!(
                    "KV block pool exhausted: session {sid} needs {need} free blocks"
                )));
                sessions.insert(sid, sess);
                continue;
            }
            free -= need;
            rows.push((batch_sessions.len(), token));
            batch_sessions.push(sess);
            batch_slots.push((i, sid));
        }

        if !rows.is_empty() {
            let forward = model.forward_rows(pool, kv, &mut batch_sessions, &rows, scratch);
            // Restore sessions whatever happened (validation precedes any
            // mutation inside forward_rows, so an error leaves them
            // unchanged).
            for ((_, sid), sess) in batch_slots.iter().zip(batch_sessions) {
                sessions.insert(*sid, sess);
            }
            let logits = forward?;
            for (bi, &(slot, _)) in batch_slots.iter().enumerate() {
                let row = logits[bi * vocab..(bi + 1) * vocab].to_vec();
                results[slot] = Some(Ok(StepOutput { logits: row, rows: 1 }));
            }
        }

        Ok(results.into_iter().map(|r| r.expect("every step slot filled")).collect())
    }

    fn release(&mut self, session: SessionId) {
        if let Some(sess) = self.sessions.remove(&session) {
            self.kv.release_table(sess.table);
        }
    }

    fn context_window(&self) -> Option<usize> {
        Some(self.model.meta.s_max)
    }

    fn kv_pool_stats(&self) -> Option<PoolStats> {
        Some(self.kv.stats())
    }

    fn kv_append_demand(&self, session: SessionId) -> usize {
        self.sessions.get(&session).map_or(0, |s| {
            self.kv.grow_demand(&s.table, (s.pos + 1).saturating_sub(s.table.len()))
        })
    }

    fn kv_admit_demand(&self, tokens: usize) -> Option<usize> {
        Some(self.kv.config().blocks_for(tokens))
    }

    /// Snapshot the session's cached rows (all `pos` forwarded positions,
    /// shared-prefix blocks included — reading them is refcount-safe) in
    /// the pool's stored representation. The session itself is untouched;
    /// the engine calls [`Self::release`] right after.
    fn kv_spill(&mut self, session: SessionId) -> Option<SpillImage> {
        let sess = self.sessions.get(&session)?;
        if sess.pos == 0 {
            return None;
        }
        let img = self.kv.extract_rows(&sess.table, sess.pos);
        let blocks = self.kv.config().blocks_for(img.rows);
        self.kv.note_spilled(blocks);
        Some(img)
    }

    /// Rebuild `session` from a spill image without running the model:
    /// re-resolve the prefix cache over `tokens` (restored sessions
    /// re-share exactly like a real prefill), replay the image's bytes
    /// into the private blocks, and seal — leaving KV state bitwise
    /// identical to a prefill of `tokens`. On any failure the partial
    /// table is released and the backend holds no trace of the session.
    fn kv_restore(
        &mut self,
        session: SessionId,
        tokens: &[i32],
        image: &SpillImage,
    ) -> anyhow::Result<()> {
        ensure!(!tokens.is_empty(), "empty restore token stream");
        ensure!(
            image.rows == tokens.len(),
            "spill image covers {} rows but the resume stream has {} tokens",
            image.rows,
            tokens.len()
        );
        ensure!(
            tokens.len() <= self.model.meta.s_max,
            "restore of {} tokens exceeds the model window s_max={}",
            tokens.len(),
            self.model.meta.s_max
        );
        if let Some(old) = self.sessions.remove(&session) {
            self.kv.release_table(old.table);
        }
        let mut table = self.kv.build_prefill(tokens);
        let new = tokens.len() - table.len();
        let restore = (|| {
            let demand = self.kv.grow_demand(&table, new);
            ensure!(
                demand <= self.kv.free_blocks(),
                "KV block pool exhausted: restore needs {demand} free blocks, {} available",
                self.kv.free_blocks()
            );
            self.kv.grow(&mut table, new)?;
            self.kv.write_raw_rows(&table, image)
        })();
        match restore {
            Ok(()) => {
                self.kv.seal_prefill(&table, tokens);
                self.sessions
                    .insert(session, RefSession { table, pos: tokens.len(), prompt: Vec::new() });
                let blocks = self.kv.config().blocks_for(tokens.len());
                self.kv.note_restored(blocks);
                Ok(())
            }
            Err(e) => {
                self.kv.release_table(table);
                Err(e)
            }
        }
    }

    fn worker_pool_stats(&self) -> Option<WorkerPoolStats> {
        Some(self.pool.stats())
    }

    fn worker_pool_lane_dispatches(&self) -> Option<[u64; 64]> {
        Some(self.pool.lane_dispatches())
    }

    fn inject_lane_fault(&mut self, lane: usize, fault: crate::runtime::pool::LaneFault) {
        self.pool.inject_lane_fault(lane, fault);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dequant_per_tile_scales() {
        // 2×2 tiles of xb=1: w[k][n] = q[k][n] * s[k][n]
        let q: Vec<u8> = vec![1, 2, 3u8, 0x80]; // 0x80 = -128
        let s = vec![1.0f32, 10.0, 100.0, 0.5];
        let w = dequant(&q, &s, 2, 2, 2, 1);
        assert_eq!(w, vec![1.0, 20.0, 300.0, -64.0]);
    }

    #[test]
    fn session_kv_is_block_pooled() {
        // the session layout is a block table, not a flat [s_max, d] buffer
        let cfg = KvCacheConfig {
            block_size: 4,
            n_blocks: 8,
            prefix_sharing: true,
            dtype: KvDtype::F32,
        };
        let mut kv = KvStore::new(cfg, 3, 8);
        let mut t = kv.build_prefill(&[1, 2, 3, 4, 5]);
        assert_eq!(t.len(), 0, "cold cache: nothing shared");
        kv.grow(&mut t, 5).unwrap();
        assert_eq!(t.len(), 5);
        assert_eq!(t.blocks().len(), 2, "5 tokens at bs=4 span 2 blocks");
        assert_eq!(kv.free_blocks(), 6);
        kv.release_table(t);
        assert_eq!(kv.free_blocks(), 8);
    }

    #[test]
    fn kernel_mode_default_is_fast() {
        assert_eq!(KernelMode::default(), KernelMode::Fast);
    }
}
