//! Pure-Rust reference numerics backend: a naive f32 Llama-style forward
//! pass (embed → per-layer RMSNorm/attention/SwiGLU with KV cache → tied
//! LM head) mirroring the jnp oracles in `python/compile/kernels/ref.py`
//! and `model.ref_forward`.
//!
//! It loads the same quantised `leapbin` weight artifacts as the PJRT path
//! (int8 crossbar cells + per-tile scales, dequantised once at load), so
//! generated tokens are real model outputs with zero non-std dependencies —
//! the default functional backend of the serving engine. Golden parity with
//! the python oracle is pinned by `tests/integration_reference.rs` against
//! the checked-in fixture (`tests/fixtures/tiny_ref`, regenerate with
//! `python -m compile.gen_ref_fixture`).
//!
//! Prefill is computed token-by-token (each prompt token is one causal
//! decode step), which makes prefill-vs-decode consistency exact by
//! construction — the property `tests/prop_backend.rs` checks.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context};

use super::backend::{ArtifactMeta, NumericsBackend, SessionId, StepOutput};
use super::leapbin::{self, DType, Tensor};

/// Dequantised weights for one decoder layer (row-major `[K, N]`).
struct LayerWeights {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    w_gate: Vec<f32>,
    w_up: Vec<f32>,
    w_down: Vec<f32>,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// The loaded model: metadata plus dequantised f32 weights.
pub struct ReferenceModel {
    pub meta: ArtifactMeta,
    /// Token embeddings, row-major `[vocab, d_model]` (also the tied head).
    embed: Vec<f32>,
    layers: Vec<LayerWeights>,
    final_norm: Vec<f32>,
}

/// Per-request decode state: per-layer KV rows, row-major `[pos, d_model]`.
struct RefSession {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    pos: usize,
}

/// The reference backend: a [`ReferenceModel`] plus per-session KV caches.
pub struct ReferenceBackend {
    model: ReferenceModel,
    sessions: HashMap<SessionId, RefSession>,
}

const EPS: f32 = 1e-5;
const ROPE_THETA: f64 = 10000.0;

/// Dequantise one `[kp, np]` int8 tile matrix with `[kt, nt]` per-tile
/// scales into a dense f32 matrix (`w[k][n] = q[k][n] * s[k/xb][n/xb]`).
fn dequant(q: &[u8], s: &[f32], kp: usize, np: usize, nt: usize, xb: usize) -> Vec<f32> {
    let mut w = vec![0f32; kp * np];
    for k in 0..kp {
        let srow = &s[(k / xb) * nt..(k / xb) * nt + nt];
        for n in 0..np {
            w[k * np + n] = (q[k * np + n] as i8) as f32 * srow[n / xb];
        }
    }
    w
}

/// `y = x @ W` for one activation row: `x: [k]`, `w: [k, n]` row-major.
fn matvec(x: &[f32], w: &[f32], k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), k * n);
    let mut y = vec![0f32; n];
    for (ki, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &w[ki * n..(ki + 1) * n];
        for (yv, &wv) in y.iter_mut().zip(row) {
            *yv += xv * wv;
        }
    }
    y
}

fn rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
    let mut sq = 0f32;
    for &v in x {
        sq += v * v;
    }
    let inv = 1.0 / (sq / x.len() as f32 + EPS).sqrt();
    x.iter().zip(g).map(|(&v, &gv)| v * inv * gv).collect()
}

/// In-place rotary embedding at `pos` over merged heads (half-split
/// rotation per head, matching `ref.ref_rope`).
fn rope(x: &mut [f32], pos: usize, n_heads: usize, d_head: usize) {
    let half = d_head / 2;
    for h in 0..n_heads {
        let base = h * d_head;
        for j in 0..half {
            let freq = (1.0 / ROPE_THETA.powf(j as f64 / half as f64)) as f32;
            let ang = pos as f32 * freq;
            let (sin, cos) = (ang.sin(), ang.cos());
            let (x1, x2) = (x[base + j], x[base + half + j]);
            x[base + j] = x1 * cos - x2 * sin;
            x[base + half + j] = x1 * sin + x2 * cos;
        }
    }
}

impl ReferenceModel {
    /// Load `meta.txt` + `weights/*.bin` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref();
        let meta_text = std::fs::read_to_string(dir.join("meta.txt"))
            .with_context(|| format!("{}/meta.txt (no artifacts built?)", dir.display()))?;
        let meta = ArtifactMeta::parse(&meta_text)?;
        let tensor = |name: &str| -> anyhow::Result<Tensor> {
            ensure!(
                meta.param_order.iter().any(|p| p == name),
                "param_order lacks required tensor '{name}'"
            );
            leapbin::load(dir.join("weights").join(format!("{name}.bin")))
        };

        let (l, d, ff, v, xb) = (meta.n_layers, meta.d_model, meta.d_ff, meta.vocab, meta.xb);
        ensure!(xb > 0 && d % xb == 0 && ff % xb == 0, "dims must be multiples of xb={xb}");

        let embed_t = tensor("embed")?;
        ensure!(embed_t.dtype == DType::F32 && embed_t.dims == [v, d], "embed shape");
        let embed = embed_t.as_f32()?;

        let attn_q = tensor("attn_q")?;
        let attn_s = tensor("attn_s")?;
        let gu_q = tensor("gu_q")?;
        let gu_s = tensor("gu_s")?;
        let down_q = tensor("down_q")?;
        let down_s = tensor("down_s")?;
        let norms_t = tensor("norms")?;
        let final_t = tensor("final_norm")?;
        for (name, t) in [("attn_q", &attn_q), ("gu_q", &gu_q), ("down_q", &down_q)] {
            ensure!(t.dtype == DType::I8, "{name} must be int8 cells, got {:?}", t.dtype);
        }
        ensure!(attn_q.dims == [l, 4, d, d], "attn_q dims {:?}", attn_q.dims);
        ensure!(attn_s.dims == [l, 4, d / xb, d / xb], "attn_s dims {:?}", attn_s.dims);
        ensure!(gu_q.dims == [l, 2, d, ff], "gu_q dims {:?}", gu_q.dims);
        ensure!(gu_s.dims == [l, 2, d / xb, ff / xb], "gu_s dims {:?}", gu_s.dims);
        ensure!(down_q.dims == [l, ff, d], "down_q dims {:?}", down_q.dims);
        ensure!(down_s.dims == [l, ff / xb, d / xb], "down_s dims {:?}", down_s.dims);
        ensure!(norms_t.dims == [l, 2, d], "norms dims {:?}", norms_t.dims);
        ensure!(final_t.dims == [d], "final_norm dims {:?}", final_t.dims);
        let attn_sv = attn_s.as_f32()?;
        let gu_sv = gu_s.as_f32()?;
        let down_sv = down_s.as_f32()?;
        let norms = norms_t.as_f32()?;
        let final_norm = final_t.as_f32()?;

        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let aq = |i: usize| -> Vec<f32> {
                let qo = (li * 4 + i) * d * d;
                let so = (li * 4 + i) * (d / xb) * (d / xb);
                dequant(&attn_q.data[qo..qo + d * d], &attn_sv[so..], d, d, d / xb, xb)
            };
            let gq = |i: usize| -> Vec<f32> {
                let qo = (li * 2 + i) * d * ff;
                let so = (li * 2 + i) * (d / xb) * (ff / xb);
                dequant(&gu_q.data[qo..qo + d * ff], &gu_sv[so..], d, ff, ff / xb, xb)
            };
            let dqo = li * ff * d;
            let dso = li * (ff / xb) * (d / xb);
            layers.push(LayerWeights {
                wq: aq(0),
                wk: aq(1),
                wv: aq(2),
                wo: aq(3),
                w_gate: gq(0),
                w_up: gq(1),
                w_down: dequant(&down_q.data[dqo..dqo + ff * d], &down_sv[dso..], ff, d, d / xb, xb),
                attn_norm: norms[(li * 2) * d..(li * 2 + 1) * d].to_vec(),
                mlp_norm: norms[(li * 2 + 1) * d..(li * 2 + 2) * d].to_vec(),
            });
        }
        Ok(Self { meta, embed, layers, final_norm })
    }

    /// One causal step: append `token` at `sess.pos`, return its logits row.
    fn step_one(&self, sess: &mut RefSession, token: i32) -> anyhow::Result<Vec<f32>> {
        let m = &self.meta;
        let (d, ff, heads) = (m.d_model, m.d_ff, m.n_heads);
        let dh = m.d_head();
        ensure!(
            (0..m.vocab as i32).contains(&token),
            "token {token} outside vocab 0..{}",
            m.vocab
        );
        let pos = sess.pos;
        let mut x = self.embed[token as usize * d..(token as usize + 1) * d].to_vec();

        for (li, lw) in self.layers.iter().enumerate() {
            // -- attention sub-layer ---------------------------------------
            let xn = rmsnorm(&x, &lw.attn_norm);
            let mut q = matvec(&xn, &lw.wq, d, d);
            let mut k = matvec(&xn, &lw.wk, d, d);
            let v = matvec(&xn, &lw.wv, d, d);
            rope(&mut q, pos, heads, dh);
            rope(&mut k, pos, heads, dh);
            sess.k[li].extend_from_slice(&k);
            sess.v[li].extend_from_slice(&v);

            let ctx = pos + 1;
            let kcache = &sess.k[li];
            let vcache = &sess.v[li];
            let scale = 1.0 / (dh as f32).sqrt();
            let mut o = vec![0f32; d];
            let mut scores = vec![0f32; ctx];
            for h in 0..heads {
                let base = h * dh;
                let qh = &q[base..base + dh];
                let mut max = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate() {
                    let krow = &kcache[j * d + base..j * d + base + dh];
                    let mut dot = 0f32;
                    for (a, b) in qh.iter().zip(krow) {
                        dot += a * b;
                    }
                    *sc = dot * scale;
                    max = max.max(*sc);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                }
                let oh = &mut o[base..base + dh];
                for (j, &p) in scores.iter().enumerate() {
                    let vrow = &vcache[j * d + base..j * d + base + dh];
                    for (ov, &vv) in oh.iter_mut().zip(vrow) {
                        *ov += p * vv;
                    }
                }
                for ov in oh.iter_mut() {
                    *ov /= denom;
                }
            }
            let attn_out = matvec(&o, &lw.wo, d, d);
            for (xv, av) in x.iter_mut().zip(&attn_out) {
                *xv += av;
            }

            // -- SwiGLU MLP sub-layer --------------------------------------
            let xn = rmsnorm(&x, &lw.mlp_norm);
            let gate = matvec(&xn, &lw.w_gate, d, ff);
            let up = matvec(&xn, &lw.w_up, d, ff);
            let h: Vec<f32> = gate
                .iter()
                .zip(&up)
                .map(|(&g, &u)| g / (1.0 + (-g).exp()) * u)
                .collect();
            let down = matvec(&h, &lw.w_down, ff, d);
            for (xv, dv) in x.iter_mut().zip(&down) {
                *xv += dv;
            }
        }

        let xf = rmsnorm(&x, &self.final_norm);
        let mut logits = vec![0f32; m.vocab];
        for (t, lv) in logits.iter_mut().enumerate() {
            let erow = &self.embed[t * d..(t + 1) * d];
            let mut dot = 0f32;
            for (a, b) in xf.iter().zip(erow) {
                dot += a * b;
            }
            *lv = dot;
        }
        sess.pos += 1;
        Ok(logits)
    }
}

impl ReferenceBackend {
    /// Load the model from an artifact/fixture directory.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        Ok(Self { model: ReferenceModel::load(dir)?, sessions: HashMap::new() })
    }

    pub fn model(&self) -> &ReferenceModel {
        &self.model
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.model.meta
    }

    /// Live session count (tests: release bookkeeping).
    pub fn live_sessions(&self) -> usize {
        self.sessions.len()
    }
}

impl NumericsBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference-f32"
    }

    fn vocab(&self) -> usize {
        self.model.meta.vocab
    }

    fn prefill(&mut self, session: SessionId, tokens: &[i32]) -> anyhow::Result<StepOutput> {
        ensure!(!tokens.is_empty(), "empty prompt");
        let l = self.model.meta.n_layers;
        let mut sess = RefSession { k: vec![Vec::new(); l], v: vec![Vec::new(); l], pos: 0 };
        let mut logits = Vec::with_capacity(tokens.len() * self.model.meta.vocab);
        for &t in tokens {
            logits.extend(self.model.step_one(&mut sess, t)?);
        }
        // A resubmitted session id restarts from scratch.
        self.sessions.insert(session, sess);
        Ok(StepOutput { logits, rows: tokens.len() })
    }

    fn decode_step(&mut self, session: SessionId, token: i32) -> anyhow::Result<StepOutput> {
        let sess = self
            .sessions
            .get_mut(&session)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session} (prefill first)"))?;
        let logits = self.model.step_one(sess, token)?;
        Ok(StepOutput { logits, rows: 1 })
    }

    fn release(&mut self, session: SessionId) {
        self.sessions.remove(&session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dequant_per_tile_scales() {
        // 2×2 tiles of xb=1: w[k][n] = q[k][n] * s[k][n]
        let q: Vec<u8> = vec![1, 2, 3u8, 0x80]; // 0x80 = -128
        let s = vec![1.0f32, 10.0, 100.0, 0.5];
        let w = dequant(&q, &s, 2, 2, 2, 1);
        assert_eq!(w, vec![1.0, 20.0, 300.0, -64.0]);
    }

    #[test]
    fn matvec_row_major() {
        // x [2] @ w [2,3]
        let w = vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0];
        assert_eq!(matvec(&[1.0, 2.0], &w, 2, 3), vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn rmsnorm_unit_gain() {
        let y = rmsnorm(&[3.0, 4.0], &[1.0, 1.0]);
        // rms = sqrt(12.5); y ≈ x / rms
        let rms = 12.5f32.sqrt();
        assert!((y[0] - 3.0 / rms).abs() < 1e-4);
        assert!((y[1] - 4.0 / rms).abs() < 1e-4);
    }

    #[test]
    fn rope_at_pos_zero_is_identity() {
        let orig = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut x = orig.clone();
        rope(&mut x, 0, 1, 4);
        assert_eq!(x, orig);
    }

    #[test]
    fn rope_rotates_pairs() {
        // one head, d_head=2: (x1, x2) rotated by ang = pos * 1.0
        let mut x = vec![1.0f32, 0.0];
        rope(&mut x, 1, 1, 2);
        assert!((x[0] - 1f32.cos()).abs() < 1e-6);
        assert!((x[1] - 1f32.sin()).abs() < 1e-6);
    }
}
