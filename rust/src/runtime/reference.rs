//! Pure-Rust reference numerics backend: an f32 Llama-style forward pass
//! (embed → per-layer RMSNorm/attention/SwiGLU with KV cache → tied LM
//! head) mirroring the jnp oracles in `python/compile/kernels/ref.py` and
//! `model.ref_forward`.
//!
//! It loads the same quantised `leapbin` weight artifacts as the PJRT path
//! (int8 crossbar cells + per-tile scales, dequantised once at load), so
//! generated tokens are real model outputs with zero non-std dependencies —
//! the default functional backend of the serving engine. Golden parity with
//! the python oracle is pinned by `tests/integration_reference.rs` against
//! the checked-in fixture (`tests/fixtures/tiny_ref`, regenerate with
//! `python -m compile.gen_ref_fixture`).
//!
//! The hot path runs through [`super::kernels`]: prefill processes the
//! whole prompt as an `[s, d]` activation matrix, and
//! [`NumericsBackend::decode_batch`] stacks one row per live session so a
//! single weight-stationary pass over each matrix serves every session —
//! the software analogue of LEAP's PIM dataflow. Both are the *same*
//! multi-row forward ([`ReferenceModel::forward_rows`]); a single
//! `decode_step` is a batch of one, which is what makes batched and
//! sequential decode bit-identical (property-tested in
//! `tests/prop_backend.rs`). Per-session KV caches are flat preallocated
//! `[s_max, d]` buffers and all tensor intermediates live in a grow-only
//! [`Scratch`] arena, so the steady-state decode loop performs no
//! per-token tensor allocations — only the returned logits buffer and a
//! few words of per-round bookkeeping.
//!
//! [`KernelMode::Naive`] retains the pre-optimisation scalar path
//! (token-at-a-time prefill, per-call allocations, per-token trig) as the
//! parity oracle and the bench baseline.

use std::collections::HashMap;
use std::collections::HashSet;
use std::path::Path;

use anyhow::{ensure, Context};

use super::backend::{ArtifactMeta, BatchResults, NumericsBackend, SessionId, StepOutput};
use super::kernels::{
    self, attention_row, gemm_q8, gemm_t, rmsnorm_into, silu_mul, QMat, RopeTable, Scratch,
};
use super::leapbin::{self, DType, Tensor};

/// Which kernel path the backend runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// The `runtime::kernels` fast path (default).
    #[default]
    Fast,
    /// The retained pre-optimisation scalar path: parity oracle and the
    /// baseline for `benches/bench_hotpath.rs`.
    Naive,
}

/// Fast-path weights for one decoder layer: the int8 crossbar cells in
/// transposed [`QMat`] form — streamed directly by `kernels::gemm_q8`
/// with the per-tile scale folded in, so a decode step moves 4× fewer
/// weight bytes than a dequantised-f32 walk would.
struct QLayer {
    wq: QMat,
    wk: QMat,
    wv: QMat,
    wo: QMat,
    w_gate: QMat,
    w_up: QMat,
    w_down: QMat,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// Naive-path weights for one decoder layer: dense dequantised f32 in the
/// original row-major `[k, n]` layout (what `kernels::naive::matvec`
/// walks — the pre-optimisation representation, retained for parity tests
/// and the bench baseline).
struct DenseLayer {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    w_gate: Vec<f32>,
    w_up: Vec<f32>,
    w_down: Vec<f32>,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// The loaded model: metadata plus per-mode weights (exactly one of
/// `qlayers` / `dlayers` is populated).
pub struct ReferenceModel {
    pub meta: ArtifactMeta,
    mode: KernelMode,
    /// Token embeddings, row-major `[vocab, d_model]` (also the tied head;
    /// this layout is simultaneously the transposed head matrix).
    embed: Vec<f32>,
    qlayers: Vec<QLayer>,
    dlayers: Vec<DenseLayer>,
    final_norm: Vec<f32>,
    rope: RopeTable,
}

/// Per-request decode state: flat preallocated KV caches, one
/// `[s_max, d_model]` row-major block per layer (layer `l` starts at
/// `l * s_max * d_model`), filled through `pos`.
struct RefSession {
    k: Vec<f32>,
    v: Vec<f32>,
    pos: usize,
}

impl RefSession {
    fn new(n_layers: usize, s_max: usize, d: usize) -> Self {
        Self { k: vec![0f32; n_layers * s_max * d], v: vec![0f32; n_layers * s_max * d], pos: 0 }
    }
}

/// The reference backend: a [`ReferenceModel`], per-session KV caches, and
/// the shared scratch arena (sessions are stepped one batch at a time, so
/// one arena serves them all).
pub struct ReferenceBackend {
    model: ReferenceModel,
    sessions: HashMap<SessionId, RefSession>,
    scratch: Scratch,
}

/// Dequantise one `[kp, np]` int8 tile matrix with `[kt, nt]` per-tile
/// scales into a dense f32 matrix (`w[k][n] = q[k][n] * s[k/xb][n/xb]`).
fn dequant(q: &[u8], s: &[f32], kp: usize, np: usize, nt: usize, xb: usize) -> Vec<f32> {
    let mut w = vec![0f32; kp * np];
    for k in 0..kp {
        let srow = &s[(k / xb) * nt..(k / xb) * nt + nt];
        for n in 0..np {
            w[k * np + n] = (q[k * np + n] as i8) as f32 * srow[n / xb];
        }
    }
    w
}

impl ReferenceModel {
    /// Load `meta.txt` + `weights/*.bin` from an artifact directory
    /// (fast-kernel layout).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        Self::load_with_mode(dir, KernelMode::Fast)
    }

    /// Load with an explicit kernel mode (`Naive` retains the
    /// pre-optimisation scalar path for parity tests and benchmarks).
    pub fn load_with_mode(dir: impl AsRef<Path>, mode: KernelMode) -> anyhow::Result<Self> {
        let dir = dir.as_ref();
        let meta_text = std::fs::read_to_string(dir.join("meta.txt"))
            .with_context(|| format!("{}/meta.txt (no artifacts built?)", dir.display()))?;
        let meta = ArtifactMeta::parse(&meta_text)?;
        let tensor = |name: &str| -> anyhow::Result<Tensor> {
            ensure!(
                meta.param_order.iter().any(|p| p == name),
                "param_order lacks required tensor '{name}'"
            );
            leapbin::load(dir.join("weights").join(format!("{name}.bin")))
        };

        let (l, d, ff, v, xb) = (meta.n_layers, meta.d_model, meta.d_ff, meta.vocab, meta.xb);
        ensure!(xb > 0 && d % xb == 0 && ff % xb == 0, "dims must be multiples of xb={xb}");
        ensure!(meta.s_max > 0, "meta s_max must be positive");

        let embed_t = tensor("embed")?;
        ensure!(embed_t.dtype == DType::F32 && embed_t.dims == [v, d], "embed shape");
        let embed = embed_t.as_f32()?;

        let attn_q = tensor("attn_q")?;
        let attn_s = tensor("attn_s")?;
        let gu_q = tensor("gu_q")?;
        let gu_s = tensor("gu_s")?;
        let down_q = tensor("down_q")?;
        let down_s = tensor("down_s")?;
        let norms_t = tensor("norms")?;
        let final_t = tensor("final_norm")?;
        for (name, t) in [("attn_q", &attn_q), ("gu_q", &gu_q), ("down_q", &down_q)] {
            ensure!(t.dtype == DType::I8, "{name} must be int8 cells, got {:?}", t.dtype);
        }
        ensure!(attn_q.dims == [l, 4, d, d], "attn_q dims {:?}", attn_q.dims);
        ensure!(attn_s.dims == [l, 4, d / xb, d / xb], "attn_s dims {:?}", attn_s.dims);
        ensure!(gu_q.dims == [l, 2, d, ff], "gu_q dims {:?}", gu_q.dims);
        ensure!(gu_s.dims == [l, 2, d / xb, ff / xb], "gu_s dims {:?}", gu_s.dims);
        ensure!(down_q.dims == [l, ff, d], "down_q dims {:?}", down_q.dims);
        ensure!(down_s.dims == [l, ff / xb, d / xb], "down_s dims {:?}", down_s.dims);
        ensure!(norms_t.dims == [l, 2, d], "norms dims {:?}", norms_t.dims);
        ensure!(final_t.dims == [d], "final_norm dims {:?}", final_t.dims);
        let attn_sv = attn_s.as_f32()?;
        let gu_sv = gu_s.as_f32()?;
        let down_sv = down_s.as_f32()?;
        let norms = norms_t.as_f32()?;
        let final_norm = final_t.as_f32()?;

        let mut qlayers = Vec::new();
        let mut dlayers = Vec::new();
        for li in 0..l {
            let attn_norm = norms[(li * 2) * d..(li * 2 + 1) * d].to_vec();
            let mlp_norm = norms[(li * 2 + 1) * d..(li * 2 + 2) * d].to_vec();
            let aqo = |i: usize| (li * 4 + i) * d * d;
            let aso = |i: usize| (li * 4 + i) * (d / xb) * (d / xb);
            let gqo = |i: usize| (li * 2 + i) * d * ff;
            let gso = |i: usize| (li * 2 + i) * (d / xb) * (ff / xb);
            let dqo = li * ff * d;
            let dso = li * (ff / xb) * (d / xb);
            match mode {
                KernelMode::Fast => {
                    // No dequantised copy: the kernels stream the int8
                    // cells (transposed) with the scales folded in.
                    let aq = |i: usize| {
                        QMat::from_cells(
                            &attn_q.data[aqo(i)..aqo(i) + d * d],
                            &attn_sv[aso(i)..aso(i) + (d / xb) * (d / xb)],
                            d,
                            d,
                            xb,
                        )
                    };
                    let gq = |i: usize| {
                        QMat::from_cells(
                            &gu_q.data[gqo(i)..gqo(i) + d * ff],
                            &gu_sv[gso(i)..gso(i) + (d / xb) * (ff / xb)],
                            d,
                            ff,
                            xb,
                        )
                    };
                    qlayers.push(QLayer {
                        wq: aq(0),
                        wk: aq(1),
                        wv: aq(2),
                        wo: aq(3),
                        w_gate: gq(0),
                        w_up: gq(1),
                        w_down: QMat::from_cells(
                            &down_q.data[dqo..dqo + ff * d],
                            &down_sv[dso..dso + (ff / xb) * (d / xb)],
                            ff,
                            d,
                            xb,
                        ),
                        attn_norm,
                        mlp_norm,
                    });
                }
                KernelMode::Naive => {
                    let aq = |i: usize| {
                        let cells = &attn_q.data[aqo(i)..aqo(i) + d * d];
                        dequant(cells, &attn_sv[aso(i)..], d, d, d / xb, xb)
                    };
                    let gq = |i: usize| {
                        let cells = &gu_q.data[gqo(i)..gqo(i) + d * ff];
                        dequant(cells, &gu_sv[gso(i)..], d, ff, ff / xb, xb)
                    };
                    dlayers.push(DenseLayer {
                        wq: aq(0),
                        wk: aq(1),
                        wv: aq(2),
                        wo: aq(3),
                        w_gate: gq(0),
                        w_up: gq(1),
                        w_down: dequant(
                            &down_q.data[dqo..dqo + ff * d],
                            &down_sv[dso..],
                            ff,
                            d,
                            d / xb,
                            xb,
                        ),
                        attn_norm,
                        mlp_norm,
                    });
                }
            }
        }
        let rope = RopeTable::new(meta.s_max, meta.d_head(), kernels::ROPE_THETA);
        Ok(Self { meta, mode, embed, qlayers, dlayers, final_norm, rope })
    }

    /// The kernel path this model was loaded for.
    pub fn mode(&self) -> KernelMode {
        self.mode
    }

    /// Multi-row forward through the fast kernels: each entry of `rows` is
    /// `(session index, token)`; row `i` appends one KV position to
    /// `sessions[rows[i].0]`. A prefill is `s` rows of one session; a
    /// batched decode is one row each of `B` sessions — either way each
    /// weight matrix is streamed once for the whole batch.
    ///
    /// Returns row-major `[rows.len(), vocab]` logits. Row `i` is
    /// bit-identical to what a batch containing only row `i` (with the
    /// same per-session cache state) would produce: every per-row op —
    /// norm, projection dot, rope, attention, residual — touches only that
    /// row's data in a fixed order.
    ///
    /// Validates every token and session capacity *before* mutating any
    /// session, so an error leaves all sessions untouched.
    fn forward_rows(
        &self,
        sessions: &mut [RefSession],
        rows: &[(usize, i32)],
        scratch: &mut Scratch,
    ) -> anyhow::Result<Vec<f32>> {
        // Hard error, not debug-only: on a Naive-mode model the fast layer
        // stack is empty and the loop would silently skip every layer.
        ensure!(self.mode == KernelMode::Fast, "forward_rows requires a Fast-mode model");
        let m = &self.meta;
        let (d, ff, heads, s_max) = (m.d_model, m.d_ff, m.n_heads, m.s_max);
        let dh = m.d_head();
        let r = rows.len();
        ensure!(r > 0, "empty row batch");

        // -- validate everything up front ---------------------------------
        let mut extra = vec![0usize; sessions.len()];
        for &(si, token) in rows {
            ensure!(si < sessions.len(), "row references session index {si} out of range");
            ensure!(
                (0..m.vocab as i32).contains(&token),
                "token {token} outside vocab 0..{}",
                m.vocab
            );
            extra[si] += 1;
        }
        for (si, (sess, &n)) in sessions.iter().zip(&extra).enumerate() {
            ensure!(
                sess.pos + n <= s_max,
                "session slot {si}: context {} + {n} new tokens exceeds the \
                 model window s_max={s_max}",
                sess.pos
            );
        }

        // -- assign cache positions and gather embeddings -----------------
        scratch.ensure(r, d, ff, s_max);
        for (i, &(si, token)) in rows.iter().enumerate() {
            scratch.pos[i] = sessions[si].pos;
            sessions[si].pos += 1;
            let erow = &self.embed[token as usize * d..(token as usize + 1) * d];
            scratch.x[i * d..(i + 1) * d].copy_from_slice(erow);
        }

        for (li, lw) in self.qlayers.iter().enumerate() {
            let koff = li * s_max * d;

            // -- attention sub-layer --------------------------------------
            for (xrow, xnrow) in
                scratch.x[..r * d].chunks_exact(d).zip(scratch.xn[..r * d].chunks_exact_mut(d))
            {
                rmsnorm_into(xrow, &lw.attn_norm, xnrow);
            }
            gemm_q8(&scratch.xn[..r * d], &lw.wq, r, &mut scratch.q[..r * d]);
            gemm_q8(&scratch.xn[..r * d], &lw.wk, r, &mut scratch.k[..r * d]);
            gemm_q8(&scratch.xn[..r * d], &lw.wv, r, &mut scratch.v[..r * d]);

            for (i, &(si, _)) in rows.iter().enumerate() {
                let pos = scratch.pos[i];
                self.rope.apply(&mut scratch.q[i * d..(i + 1) * d], pos, heads, dh);
                self.rope.apply(&mut scratch.k[i * d..(i + 1) * d], pos, heads, dh);
                let sess = &mut sessions[si];
                sess.k[koff + pos * d..koff + (pos + 1) * d]
                    .copy_from_slice(&scratch.k[i * d..(i + 1) * d]);
                sess.v[koff + pos * d..koff + (pos + 1) * d]
                    .copy_from_slice(&scratch.v[i * d..(i + 1) * d]);
            }

            // Causal attention per row: the KV rows for every position of
            // this step are already written, and row i only reads
            // positions 0..=pos[i] of its own session.
            for (i, &(si, _)) in rows.iter().enumerate() {
                let ctx = scratch.pos[i] + 1;
                let sess = &sessions[si];
                attention_row(
                    &scratch.q[i * d..(i + 1) * d],
                    &sess.k[koff..koff + ctx * d],
                    &sess.v[koff..koff + ctx * d],
                    ctx,
                    heads,
                    dh,
                    d,
                    &mut scratch.scores,
                    &mut scratch.o[i * d..(i + 1) * d],
                );
            }
            gemm_q8(&scratch.o[..r * d], &lw.wo, r, &mut scratch.proj[..r * d]);
            for (xv, &pv) in scratch.x[..r * d].iter_mut().zip(&scratch.proj[..r * d]) {
                *xv += pv;
            }

            // -- SwiGLU MLP sub-layer -------------------------------------
            for (xrow, xnrow) in
                scratch.x[..r * d].chunks_exact(d).zip(scratch.xn[..r * d].chunks_exact_mut(d))
            {
                rmsnorm_into(xrow, &lw.mlp_norm, xnrow);
            }
            gemm_q8(&scratch.xn[..r * d], &lw.w_gate, r, &mut scratch.gate[..r * ff]);
            gemm_q8(&scratch.xn[..r * d], &lw.w_up, r, &mut scratch.up[..r * ff]);
            silu_mul(&mut scratch.gate[..r * ff], &scratch.up[..r * ff]);
            gemm_q8(&scratch.gate[..r * ff], &lw.w_down, r, &mut scratch.proj[..r * d]);
            for (xv, &pv) in scratch.x[..r * d].iter_mut().zip(&scratch.proj[..r * d]) {
                *xv += pv;
            }
        }

        // -- tied LM head -------------------------------------------------
        for (xrow, xnrow) in
            scratch.x[..r * d].chunks_exact(d).zip(scratch.xn[..r * d].chunks_exact_mut(d))
        {
            rmsnorm_into(xrow, &self.final_norm, xnrow);
        }
        let mut logits = vec![0f32; r * m.vocab];
        gemm_t(&scratch.xn[..r * d], &self.embed, r, d, m.vocab, &mut logits);
        Ok(logits)
    }

    /// One causal step through the retained naive scalar path (the exact
    /// pre-optimisation algorithm: per-call `Vec`s, zero-skip axpy matvec
    /// over `[k, n]` weights, per-token trig). Parity oracle + bench
    /// baseline; only valid on a `KernelMode::Naive` model.
    fn step_one_naive(&self, sess: &mut RefSession, token: i32) -> anyhow::Result<Vec<f32>> {
        use kernels::naive::{matvec, rmsnorm, rope};
        ensure!(self.mode == KernelMode::Naive, "step_one_naive requires a Naive-mode model");
        let m = &self.meta;
        let (d, ff, heads, s_max) = (m.d_model, m.d_ff, m.n_heads, m.s_max);
        let dh = m.d_head();
        m.check_step(sess.pos, token)?;
        let pos = sess.pos;
        let mut x = self.embed[token as usize * d..(token as usize + 1) * d].to_vec();

        for (li, lw) in self.dlayers.iter().enumerate() {
            let koff = li * s_max * d;
            // -- attention sub-layer --------------------------------------
            let xn = rmsnorm(&x, &lw.attn_norm);
            let mut q = matvec(&xn, &lw.wq, d, d);
            let mut k = matvec(&xn, &lw.wk, d, d);
            let v = matvec(&xn, &lw.wv, d, d);
            rope(&mut q, pos, heads, dh);
            rope(&mut k, pos, heads, dh);
            sess.k[koff + pos * d..koff + (pos + 1) * d].copy_from_slice(&k);
            sess.v[koff + pos * d..koff + (pos + 1) * d].copy_from_slice(&v);

            let ctx = pos + 1;
            let kcache = &sess.k[koff..koff + ctx * d];
            let vcache = &sess.v[koff..koff + ctx * d];
            let scale = 1.0 / (dh as f32).sqrt();
            let mut o = vec![0f32; d];
            let mut scores = vec![0f32; ctx];
            for h in 0..heads {
                let base = h * dh;
                let qh = &q[base..base + dh];
                let mut max = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate() {
                    let krow = &kcache[j * d + base..j * d + base + dh];
                    let mut dot = 0f32;
                    for (a, b) in qh.iter().zip(krow) {
                        dot += a * b;
                    }
                    *sc = dot * scale;
                    max = max.max(*sc);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                }
                let oh = &mut o[base..base + dh];
                for (j, &p) in scores.iter().enumerate() {
                    let vrow = &vcache[j * d + base..j * d + base + dh];
                    for (ov, &vv) in oh.iter_mut().zip(vrow) {
                        *ov += p * vv;
                    }
                }
                for ov in oh.iter_mut() {
                    *ov /= denom;
                }
            }
            let attn_out = matvec(&o, &lw.wo, d, d);
            for (xv, av) in x.iter_mut().zip(&attn_out) {
                *xv += av;
            }

            // -- SwiGLU MLP sub-layer -------------------------------------
            let xn = rmsnorm(&x, &lw.mlp_norm);
            let gate = matvec(&xn, &lw.w_gate, d, ff);
            let up = matvec(&xn, &lw.w_up, d, ff);
            let h: Vec<f32> =
                gate.iter().zip(&up).map(|(&g, &u)| g / (1.0 + (-g).exp()) * u).collect();
            let down = matvec(&h, &lw.w_down, ff, d);
            for (xv, dv) in x.iter_mut().zip(&down) {
                *xv += dv;
            }
        }

        let xf = rmsnorm(&x, &self.final_norm);
        let mut logits = vec![0f32; m.vocab];
        for (t, lv) in logits.iter_mut().enumerate() {
            let erow = &self.embed[t * d..(t + 1) * d];
            let mut dot = 0f32;
            for (a, b) in xf.iter().zip(erow) {
                dot += a * b;
            }
            *lv = dot;
        }
        sess.pos += 1;
        Ok(logits)
    }
}

impl ReferenceBackend {
    /// Load the model from an artifact/fixture directory (fast kernels).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        Self::load_with_mode(dir, KernelMode::Fast)
    }

    /// Load with an explicit kernel mode ([`KernelMode::Naive`] retains the
    /// pre-optimisation scalar path for parity tests and the bench
    /// baseline).
    pub fn load_with_mode(dir: impl AsRef<Path>, mode: KernelMode) -> anyhow::Result<Self> {
        Ok(Self {
            model: ReferenceModel::load_with_mode(dir, mode)?,
            sessions: HashMap::new(),
            scratch: Scratch::new(),
        })
    }

    pub fn model(&self) -> &ReferenceModel {
        &self.model
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.model.meta
    }

    /// Live session count (tests: release bookkeeping).
    pub fn live_sessions(&self) -> usize {
        self.sessions.len()
    }
}

impl NumericsBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        match self.model.mode {
            KernelMode::Fast => "reference-f32",
            KernelMode::Naive => "reference-f32-naive",
        }
    }

    fn vocab(&self) -> usize {
        self.model.meta.vocab
    }

    fn prefill(&mut self, session: SessionId, tokens: &[i32]) -> anyhow::Result<StepOutput> {
        ensure!(!tokens.is_empty(), "empty prompt");
        let m = &self.model.meta;
        // No silent truncation (same contract as the PJRT backend): a
        // prompt the KV window cannot hold in full is rejected.
        ensure!(
            tokens.len() <= m.s_max,
            "prompt of {} tokens exceeds the model window s_max={}",
            tokens.len(),
            m.s_max
        );
        let (l, s_max, d) = (m.n_layers, m.s_max, m.d_model);
        let Self { model, sessions, scratch } = self;
        let mut sess = RefSession::new(l, s_max, d);
        let logits = match model.mode {
            KernelMode::Fast => {
                let rows: Vec<(usize, i32)> = tokens.iter().map(|&t| (0usize, t)).collect();
                model.forward_rows(std::slice::from_mut(&mut sess), &rows, scratch)?
            }
            KernelMode::Naive => {
                let mut logits = Vec::with_capacity(tokens.len() * model.meta.vocab);
                for &t in tokens {
                    logits.extend(model.step_one_naive(&mut sess, t)?);
                }
                logits
            }
        };
        // A resubmitted session id restarts from scratch.
        sessions.insert(session, sess);
        Ok(StepOutput { logits, rows: tokens.len() })
    }

    fn decode_step(&mut self, session: SessionId, token: i32) -> anyhow::Result<StepOutput> {
        let Self { model, sessions, scratch } = self;
        let sess = sessions
            .get_mut(&session)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session} (prefill first)"))?;
        model.meta.check_step(sess.pos, token)?;
        let logits = match model.mode {
            KernelMode::Fast => {
                model.forward_rows(std::slice::from_mut(sess), &[(0, token)], scratch)?
            }
            KernelMode::Naive => model.step_one_naive(sess, token)?,
        };
        Ok(StepOutput { logits, rows: 1 })
    }

    /// Weight-stationary batched decode: every valid step becomes one
    /// activation row of a single [`ReferenceModel::forward_rows`] batch,
    /// so each weight matrix is streamed once per round instead of once
    /// per session. Bit-identical to sequential [`Self::decode_step`]
    /// calls in the same order (each row's arithmetic touches only its own
    /// data); a per-session failure (unknown session, bad token, exhausted
    /// window) occupies its slot as an `Err` without disturbing the rest
    /// of the round.
    fn decode_batch(&mut self, steps: &[(SessionId, i32)]) -> anyhow::Result<BatchResults> {
        // The naive path has no batched kernel; duplicate session ids need
        // earlier steps visible to later ones. Both fall back to the
        // sequential loop (= the trait's default behaviour).
        let mut seen = HashSet::new();
        let has_dup = steps.iter().any(|&(sid, _)| !seen.insert(sid));
        if self.model.mode == KernelMode::Naive || has_dup {
            return Ok(steps.iter().map(|&(sid, t)| self.decode_step(sid, t)).collect());
        }

        let vocab = self.model.meta.vocab;
        let mut results: Vec<Option<anyhow::Result<StepOutput>>> =
            steps.iter().map(|_| None).collect();
        // Move each valid session out of the map for the batch (restored
        // below); invalid steps record their error and stay put. The
        // checks (and error text) are exactly decode_step's, so batched
        // and sequential rounds fail identically.
        let mut batch_sessions: Vec<RefSession> = Vec::with_capacity(steps.len());
        let mut batch_slots: Vec<(usize, SessionId)> = Vec::with_capacity(steps.len());
        let mut rows: Vec<(usize, i32)> = Vec::with_capacity(steps.len());
        for (i, &(sid, token)) in steps.iter().enumerate() {
            let Some(sess) = self.sessions.remove(&sid) else {
                results[i] = Some(Err(anyhow::anyhow!("unknown session {sid} (prefill first)")));
                continue;
            };
            if let Err(err) = self.model.meta.check_step(sess.pos, token) {
                results[i] = Some(Err(err));
                self.sessions.insert(sid, sess);
                continue;
            }
            rows.push((batch_sessions.len(), token));
            batch_sessions.push(sess);
            batch_slots.push((i, sid));
        }

        if !rows.is_empty() {
            let Self { model, sessions, scratch } = self;
            let forward = model.forward_rows(&mut batch_sessions, &rows, scratch);
            // Restore sessions whatever happened (validation precedes any
            // mutation inside forward_rows, so an error leaves them
            // unchanged).
            for ((_, sid), sess) in batch_slots.iter().zip(batch_sessions) {
                sessions.insert(*sid, sess);
            }
            let logits = forward?;
            for (bi, &(slot, _)) in batch_slots.iter().enumerate() {
                let row = logits[bi * vocab..(bi + 1) * vocab].to_vec();
                results[slot] = Some(Ok(StepOutput { logits: row, rows: 1 }));
            }
        }

        Ok(results.into_iter().map(|r| r.expect("every step slot filled")).collect())
    }

    fn release(&mut self, session: SessionId) {
        self.sessions.remove(&session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dequant_per_tile_scales() {
        // 2×2 tiles of xb=1: w[k][n] = q[k][n] * s[k][n]
        let q: Vec<u8> = vec![1, 2, 3u8, 0x80]; // 0x80 = -128
        let s = vec![1.0f32, 10.0, 100.0, 0.5];
        let w = dequant(&q, &s, 2, 2, 2, 1);
        assert_eq!(w, vec![1.0, 20.0, 300.0, -64.0]);
    }

    #[test]
    fn session_layout_flat_per_layer() {
        let sess = RefSession::new(3, 8, 4);
        assert_eq!(sess.k.len(), 3 * 8 * 4);
        assert_eq!(sess.v.len(), 3 * 8 * 4);
        assert_eq!(sess.pos, 0);
    }

    #[test]
    fn kernel_mode_default_is_fast() {
        assert_eq!(KernelMode::default(), KernelMode::Fast);
    }
}
