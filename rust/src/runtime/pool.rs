//! Persistent worker pool: the resident compute fabric of the fast kernel
//! layer.
//!
//! LEAP's throughput rests on *persistent* distributed compute — tiles
//! stream through workers that stay resident, instead of resources being
//! torn down between operations. The software analogue: one [`WorkerPool`]
//! is spawned per backend at load time and every kernel dispatches tile
//! bands onto it through [`WorkerPool::run_tiles`]. Workers spin briefly
//! between dispatches (a decode step issues several per layer) and park on
//! a condvar when the pipeline goes quiet, so the steady-state cost of a
//! dispatch is a couple of atomic transitions — not the thread spawn +
//! join the previous `std::thread::scope` kernels paid on every call.
//!
//! **Determinism contract.** `run_tiles(range, f)` splits `range` into at
//! most `threads()` contiguous bands with *fixed tile ownership*: band `b`
//! always covers tiles `[b·ceil(n/lanes), …)` regardless of scheduling, the
//! dispatching thread always runs band 0, and resident worker `w` always
//! runs band `w`. Combined with the kernels' fixed-order 8-lane reductions
//! (each output element is a pure function of its inputs, never a
//! cross-band combine), results are bitwise identical across pool sizes,
//! across repeated invocations, and against the serial fallback.
//!
//! **Sizing.** The lane count is resolved **once** at pool construction:
//! `LEAP_THREADS` (if set, ≥ 1) overrides, otherwise
//! `available_parallelism()` capped at [`MAX_THREADS`]. Kernels keep the
//! work-threshold fallback — [`WorkerPool::lanes_for`] returns 1 below
//! 2×[`PAR_MIN_WORK`] multiply-accumulates, so tiny models never pay a
//! dispatch.
//!
//! **Not reentrant.** A dispatch mutex serialises concurrent `run_tiles`
//! callers; calling `run_tiles` from *inside* a tile closure deadlocks.
//! Kernels never nest dispatches.
//!
//! **Panic isolation.** A tile closure that panics on a resident worker
//! does not poison the pool or abort the process: the lane is marked
//! *dead* (the worker thread exits), and the dispatcher re-runs the dead
//! lane's band inline after the barrier — tile writes are pure functions
//! of their inputs, so the re-run produces bitwise-identical output and
//! every non-faulted caller is unaffected. Dead lanes stay dead; later
//! dispatches fold their bands onto the dispatching thread up front. A
//! closure that panics *deterministically* panics again on the inline
//! re-run and propagates to the caller — a genuine bug is never silently
//! swallowed. [`WorkerPool::inject_lane_fault`] arms a one-shot
//! [`LaneFault`] (panic or bounded stall) on a lane for the fault-injection
//! harness; injected panics are consumed before the re-run, so a chaos run
//! degrades the pool without corrupting results.

use std::cell::UnsafeCell;
use std::marker::PhantomData;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::obs::{stderr_log, Level};

/// Minimum multiply-accumulate count a tile band should amortise; a kernel
/// stays serial below 2× this. Far lower than the old per-call
/// `std::thread::scope` threshold (1 << 21): waking a resident, spinning
/// worker costs ~µs, not a spawn+join.
pub const PAR_MIN_WORK: usize = 1 << 16;

/// Default cap on pool lanes (an explicit `LEAP_THREADS` may exceed it).
pub const MAX_THREADS: usize = 8;

/// Spin iterations a worker burns between dispatches before parking on the
/// condvar. A decode layer issues dispatches a few µs apart, so workers
/// normally stay in the spin window and a dispatch is just an atomic flip.
const SPIN_ROUNDS: u32 = 1 << 14;

type JobFn = dyn Fn(usize) + Sync;

/// Type-erased pointer to the current dispatch closure. Valid from epoch
/// publication until every *active* worker has incremented `done` —
/// `run_tiles` does not return (or unwind) before that, so the borrow
/// never dangles (inactive lanes never read it at all).
#[derive(Clone, Copy)]
struct Job {
    f: *const JobFn,
}

// SAFETY: the pointer is only dereferenced while `run_tiles` keeps the
// closure alive (see `Job` docs); sending it to worker threads is sound.
unsafe impl Send for Job {}

struct Shared {
    /// Dispatch publication word: `(epoch << 16) | lanes`, stored (release)
    /// after `job` is written. Packing the active lane count with the epoch
    /// lets a worker decide "not my dispatch" from this one atomic — a
    /// worker whose lane is inactive never touches `job` or `done`, so the
    /// dispatcher only ever waits on (and the job cell is only ever read
    /// by) the lanes that compute.
    epoch_lanes: AtomicU64,
    /// Active resident workers finished with the current epoch's job.
    done: AtomicUsize,
    shutdown: AtomicBool,
    /// Written by the dispatching thread only while every *active* worker
    /// of the previous epoch has checked in (inactive workers never read
    /// it); read by active workers only between the epoch publication and
    /// their `done` increment.
    job: UnsafeCell<Option<Job>>,
    /// Lanes whose worker panicked during the *current* epoch (bit = lane).
    /// Set (with the matching `dead_lanes` bit) before the worker's final
    /// `done` increment, so the dispatcher's post-barrier swap observes it;
    /// the dispatcher then re-runs those bands inline.
    panicked_lanes: AtomicU64,
    /// Lanes permanently dead (worker thread exited after a panic). Read
    /// by the dispatcher at the top of every dispatch — the prior
    /// dispatch's `done` barrier orders the relaxed load after the
    /// worker's store — to size the barrier and pre-fold dead bands onto
    /// the dispatching thread.
    dead_lanes: AtomicU64,
    /// One-shot injected-panic arm mask (fault injection): a worker whose
    /// bit is set panics at its next engaged dispatch, consuming the bit.
    armed_panic: AtomicU64,
    /// One-shot injected-stall arm mask: bounded yields, then proceed.
    armed_stall: AtomicU64,
    /// Cumulative lane deaths (counted by the dispatcher, once per lane).
    lane_deaths: AtomicU64,
    /// Bitmask of worker lanes blocked on `wake` (bit = lane index; guards
    /// the condvar handshake). A mask rather than a count so a dispatch
    /// can skip the notify entirely when only lanes it does not engage are
    /// parked — steady-state narrow dispatches never wake the wide lanes.
    parked: Mutex<u64>,
    wake: Condvar,
    // --- counters (relaxed; observability only) -------------------------
    dispatches: AtomicU64,
    parks: AtomicU64,
    wakes: AtomicU64,
    /// Dispatch engagements per lane (lane L was one of the active bands).
    /// 64 slots — the same bound the parked bitmask imposes on lanes. The
    /// dispatcher bumps these, one relaxed add per engaged lane per
    /// dispatch: a handful of uncontended adds per decode layer, invisible
    /// next to the tile work itself.
    lane_dispatches: [AtomicU64; 64],
}

// SAFETY: the `UnsafeCell<Option<Job>>` is the only non-Sync field; its
// single-writer / post-publication-reader protocol is documented on the
// field and enforced by the epoch/done handshake in `run_tiles`.
unsafe impl Sync for Shared {}

/// Observability snapshot of a [`WorkerPool`] (surfaced through
/// `NumericsBackend::worker_pool_stats`, `Metrics`, and the bench record).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerPoolStats {
    /// Total lanes: resident workers + the dispatching thread.
    pub threads: usize,
    /// Resident worker threads (`threads - 1`).
    pub workers: usize,
    /// Parallel tile dispatches since construction (serial fallbacks — work
    /// under the threshold — never dispatch and are not counted).
    pub dispatches: u64,
    /// Park transitions: a worker exhausted its spin budget and blocked.
    pub parks: u64,
    /// Wake transitions: a parked worker resumed for a dispatch/shutdown.
    pub wakes: u64,
    /// Lanes that died to an isolated tile-closure panic (cumulative).
    pub lane_deaths: u64,
    /// Bitmask of currently-dead lanes (bit = lane index).
    pub dead_lanes: u64,
}

/// A one-shot fault to arm on a worker lane (the fault-injection harness's
/// window into the pool). Consumed at the lane's next engaged dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneFault {
    /// The lane panics, dies, and its band re-tiles onto the dispatcher
    /// (isolated — callers still get full, bitwise-identical output).
    Panic,
    /// The lane stalls for a bounded number of yields, then proceeds — a
    /// slow lane, not a dead one. Output is unaffected.
    Stall,
}

/// A persistent, parkable worker pool with fixed tile ownership. Spawned
/// once (per backend); `Drop` shuts the workers down and joins them.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Serialises concurrent dispatchers (kernels dispatch from one thread;
    /// this keeps misuse safe rather than undefined).
    dispatch: Mutex<()>,
}

impl WorkerPool {
    /// Pool sized by the environment: `LEAP_THREADS` override, else
    /// `available_parallelism()` capped at [`MAX_THREADS`]. Resolved once,
    /// here — never re-queried on the hot path.
    pub fn new() -> Self {
        Self::with_threads(Self::default_threads())
    }

    /// The lane count [`WorkerPool::new`] would pick right now.
    /// `LEAP_THREADS=0` means serial (lane count 1, the conventional
    /// "threading off"); an unparseable value warns and falls back to the
    /// hardware default rather than silently meaning something else.
    pub fn default_threads() -> usize {
        if let Ok(v) = std::env::var("LEAP_THREADS") {
            match v.trim().parse::<usize>() {
                Ok(n) => return n.max(1),
                Err(_) => stderr_log(
                    Level::Warn,
                    "pool_threads_env",
                    format_args!(
                        "ignoring unparseable LEAP_THREADS={v:?}; using the hardware default"
                    ),
                ),
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_THREADS)
    }

    /// Pool with an explicit lane count (1 ⇒ no resident workers; every
    /// `run_tiles` runs inline on the caller). Clamped to the 64 lanes the
    /// parked bitmask can track — far beyond any sane machine.
    pub fn with_threads(threads: usize) -> Self {
        let threads = threads.clamp(1, 64);
        let shared = Arc::new(Shared {
            epoch_lanes: AtomicU64::new(0),
            done: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            job: UnsafeCell::new(None),
            panicked_lanes: AtomicU64::new(0),
            dead_lanes: AtomicU64::new(0),
            armed_panic: AtomicU64::new(0),
            armed_stall: AtomicU64::new(0),
            lane_deaths: AtomicU64::new(0),
            parked: Mutex::new(0u64),
            wake: Condvar::new(),
            dispatches: AtomicU64::new(0),
            parks: AtomicU64::new(0),
            wakes: AtomicU64::new(0),
            lane_dispatches: std::array::from_fn(|_| AtomicU64::new(0)),
        });
        let workers = (1..threads)
            .map(|lane| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("leap-pool-{lane}"))
                    .spawn(move || worker_main(&sh, lane))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers, threads, dispatch: Mutex::new(()) }
    }

    /// Lanes this pool dispatches across (resolved at construction).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Lanes worth engaging for a kernel of `work` multiply-accumulates:
    /// 1 under the threshold (serial — no dispatch at all), else enough
    /// lanes to give each at least [`PAR_MIN_WORK`], capped by the pool.
    pub fn lanes_for(&self, work: usize) -> usize {
        if work < 2 * PAR_MIN_WORK {
            return 1;
        }
        self.threads.min(work / PAR_MIN_WORK).max(1)
    }

    /// Counter snapshot.
    pub fn stats(&self) -> WorkerPoolStats {
        WorkerPoolStats {
            threads: self.threads,
            workers: self.workers.len(),
            dispatches: self.shared.dispatches.load(Ordering::Relaxed),
            parks: self.shared.parks.load(Ordering::Relaxed),
            wakes: self.shared.wakes.load(Ordering::Relaxed),
            lane_deaths: self.shared.lane_deaths.load(Ordering::Relaxed),
            dead_lanes: self.shared.dead_lanes.load(Ordering::Relaxed),
        }
    }

    /// Arm a one-shot [`LaneFault`] on worker lane `lane` (clamped into
    /// the pool's worker range; no-op on a serial pool, which has no
    /// worker lanes to fault). Deterministic: the fault fires at the
    /// lane's next engaged dispatch, exactly once.
    pub fn inject_lane_fault(&self, lane: usize, fault: LaneFault) {
        if self.workers.is_empty() {
            return;
        }
        let lane = lane.clamp(1, self.threads - 1);
        let bit = 1u64 << lane;
        match fault {
            LaneFault::Panic => self.shared.armed_panic.fetch_or(bit, Ordering::Relaxed),
            LaneFault::Stall => self.shared.armed_stall.fetch_or(bit, Ordering::Relaxed),
        };
    }

    /// Cumulative dispatch engagements per lane (index = lane; lane 0 is
    /// the dispatching thread's band). Slots past `threads()` stay zero.
    pub fn lane_dispatches(&self) -> [u64; 64] {
        std::array::from_fn(|i| self.shared.lane_dispatches[i].load(Ordering::Relaxed))
    }

    /// Run `f` over `range` split into at most `threads()` contiguous
    /// bands with fixed ownership (see the module docs for the determinism
    /// contract). Blocks until every band has finished; effects of `f` are
    /// visible to the caller afterwards.
    pub fn run_tiles<F>(&self, range: Range<usize>, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        self.run_tiles_bounded(range, usize::MAX, f);
    }

    /// [`WorkerPool::run_tiles`] with an explicit lane cap (kernels pass
    /// [`WorkerPool::lanes_for`] so small calls engage few lanes).
    pub fn run_tiles_bounded<F>(&self, range: Range<usize>, max_lanes: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let n = range.len();
        if n == 0 {
            return;
        }
        let lanes = self.threads.min(max_lanes).min(n).max(1);
        if lanes <= 1 || self.workers.is_empty() {
            f(range);
            return;
        }
        let band = n.div_ceil(lanes);
        let (start, end) = (range.start, range.end);
        // Fixed ownership: lane L covers tiles [start + L·band, …); lanes
        // past the last band (when lanes < threads) see an empty range.
        let run_lane = move |lane: usize| {
            let lo = start + lane * band;
            if lo < end {
                f(lo..(lo + band).min(end));
            }
        };
        let jobref: &(dyn Fn(usize) + Sync) = &run_lane;

        // A poisoned lock here only means an earlier dispatch panicked
        // after its barrier; the critical section protects no data
        // invariant, so recover instead of bricking the backend.
        let _serialised = self.dispatch.lock().unwrap_or_else(|e| e.into_inner());
        // Lanes already dead before this dispatch: the previous dispatch's
        // `done` barrier orders this relaxed load after the dying worker's
        // store. Their bands fold onto the dispatching thread below; band
        // boundaries never move, so output stays bitwise-identical.
        let dead = self.shared.dead_lanes.load(Ordering::Relaxed);
        // A dispatch that unwound from its own band 0 can leave panicked
        // bits unswept; fold them into the death count now so a stale bit
        // never mis-sizes a healthy dispatch.
        let stale = self.shared.panicked_lanes.swap(0, Ordering::Relaxed);
        if stale != 0 {
            self.shared.lane_deaths.fetch_add(u64::from(stale.count_ones()), Ordering::Relaxed);
        }
        self.shared.done.store(0, Ordering::Relaxed);
        // SAFETY: lifetime erasure only. The `WaitGuard` below blocks this
        // frame (even on unwind) until every active worker has run the
        // closure and incremented `done`, so the erased borrow outlives
        // all uses.
        unsafe { *self.shared.job.get() = Some(Job { f: erase(jobref) }) };
        let epoch = self.shared.dispatches.fetch_add(1, Ordering::Relaxed) + 1;
        for c in &self.shared.lane_dispatches[..lanes] {
            c.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.epoch_lanes.store((epoch << 16) | lanes as u64, Ordering::Release);
        // Wake parked workers — but only if one of the lanes THIS dispatch
        // engages is parked. The mask is read under the lock the workers
        // use to register, so either a worker saw the new epoch before
        // parking or it is registered here and gets the notify; lanes the
        // dispatch skips stay parked untouched.
        {
            let lanes_mask =
                if lanes >= 64 { u64::MAX } else { (1u64 << lanes) - 1 };
            let parked = self.shared.parked.lock().unwrap_or_else(|e| e.into_inner());
            if *parked & lanes_mask != 0 {
                self.shared.wake.notify_all();
            }
        }
        // Only the LIVE active lanes are on the barrier: workers with
        // `lane >= lanes` skip the epoch without touching `job` or `done`,
        // and dead lanes have no worker thread to check in at all.
        let live_workers = (1..lanes).filter(|l| dead & (1u64 << l) == 0).count();
        let guard = WaitGuard { shared: &self.shared, active_workers: live_workers };
        run_lane(0);
        // Pre-dead lanes' bands, inline, in lane order — same tile
        // ownership, same writes, so results stay bitwise-identical.
        for lane in 1..lanes {
            if dead & (1u64 << lane) != 0 {
                run_lane(lane);
            }
        }
        drop(guard); // blocks until all live active workers are done
        // Lanes that died THIS dispatch: count them, then re-run their
        // bands inline. Tile writes are pure functions of their inputs, so
        // the re-run is idempotent; a *deterministic* closure panic fires
        // again here and propagates to the caller (never swallowed), while
        // an injected one was consumed and the re-run completes clean.
        let newly = self.shared.panicked_lanes.swap(0, Ordering::Relaxed);
        if newly != 0 {
            self.shared.lane_deaths.fetch_add(u64::from(newly.count_ones()), Ordering::Relaxed);
            for lane in 1..lanes {
                if newly & (1u64 << lane) != 0 {
                    stderr_log(
                        Level::Warn,
                        "pool_lane_dead",
                        format_args!("lane {lane} dead after band panic; band re-tiled inline"),
                    );
                    run_lane(lane);
                }
            }
        }
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _parked = self.shared.parked.lock().unwrap_or_else(|e| e.into_inner());
            self.shared.wake.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Erase the borrow lifetime of a dispatch closure (see the SAFETY note at
/// the call site: the referent outlives every dereference).
fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> *const JobFn {
    // SAFETY: lifetime-only transmute between identically laid out fat
    // references; soundness is the caller's obligation.
    unsafe { std::mem::transmute::<&'a (dyn Fn(usize) + Sync + 'a), &'static JobFn>(f) }
}

/// Blocks (on drop) until every **active** worker finished the current
/// epoch — also on unwind, so a panicking band closure on the dispatching
/// thread cannot free the job while workers still run it.
struct WaitGuard<'a> {
    shared: &'a Shared,
    active_workers: usize,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        let mut spins = 0u32;
        while self.shared.done.load(Ordering::Acquire) != self.active_workers {
            spins = spins.wrapping_add(1);
            if spins > SPIN_ROUNDS {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }
}

fn worker_main(shared: &Shared, lane: usize) {
    let mut seen = 0u64;
    // Spin budget carried ACROSS epochs: an epoch that engages this lane
    // refills it; an epoch that skips this lane does not. A lane the
    // steady-state dispatch width never reaches therefore drains its
    // budget and parks instead of busy-spinning for the backend's
    // lifetime (dispatch notify_all still wakes it should a wider
    // dispatch ever need it).
    let mut spins: u32 = 0;
    loop {
        let Some(now) = wait_for_epoch(shared, seen, lane, &mut spins) else { return };
        seen = now;
        let lanes = (now & 0xFFFF) as usize;
        if lane >= lanes {
            // Not on this dispatch's barrier: must not touch `job` (the
            // dispatcher may overwrite it for the next epoch while we are
            // still here) or `done` (we are not being waited on).
            continue;
        }
        spins = 0;
        let lane_bit = 1u64 << lane;
        // SAFETY: the dispatcher wrote `job` before the (release)
        // publication this thread (acquire-)observed, and overwrites it
        // only after every active worker increments `done` below.
        let job = unsafe { (*shared.job.get()).expect("epoch published without a job") };
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // Injected faults fire inside the unwind boundary, so an armed
            // panic exercises exactly the real band-panic path. Both arms
            // are one-shot: consume our bit before acting.
            if shared.armed_stall.fetch_and(!lane_bit, Ordering::Relaxed) & lane_bit != 0 {
                // bounded slow-lane stall, then proceed normally — the
                // barrier absorbs the delay, output is unaffected
                for _ in 0..64 {
                    std::thread::yield_now();
                }
            }
            if shared.armed_panic.fetch_and(!lane_bit, Ordering::Relaxed) & lane_bit != 0 {
                panic!("injected lane panic (fault plan)");
            }
            // SAFETY: see `Job` — valid until the `done` increment.
            (unsafe { &*job.f })(lane);
        }));
        if run.is_err() {
            // Mark this lane dead and flag the epoch BEFORE the (release)
            // `done` increment, so the dispatcher's post-barrier sweep and
            // every later dispatch observe both. Then exit the thread: a
            // lane that panicked once is retired, its bands fold onto the
            // dispatcher from now on.
            shared.panicked_lanes.fetch_or(lane_bit, Ordering::Relaxed);
            shared.dead_lanes.fetch_or(lane_bit, Ordering::Relaxed);
            stderr_log(
                Level::Error,
                "pool_band_panic",
                format_args!("tile closure panicked on worker pool lane {lane}; lane retired"),
            );
            shared.done.fetch_add(1, Ordering::Release);
            return;
        }
        shared.done.fetch_add(1, Ordering::Release);
    }
}

/// Spin (draining the caller's carried budget), then park, until the
/// publication word advances past `seen` (returns the new word) or
/// shutdown is flagged (returns `None`). The budget is deliberately NOT
/// refilled here — only an epoch that actually engages the calling lane
/// does that (see [`worker_main`]) — so chronically idle lanes park, and
/// dispatches that do not engage them skip the notify entirely.
fn wait_for_epoch(shared: &Shared, seen: u64, lane: usize, spins: &mut u32) -> Option<u64> {
    let lane_bit = 1u64 << lane;
    loop {
        let e = shared.epoch_lanes.load(Ordering::Acquire);
        if e != seen {
            return Some(e);
        }
        if shared.shutdown.load(Ordering::Acquire) {
            return None;
        }
        if *spins < SPIN_ROUNDS {
            *spins += 1;
            std::hint::spin_loop();
            continue;
        }
        // Park. Register under the lock, then re-check: the dispatcher
        // publishes before reading `parked` under this same lock, so
        // either the re-check sees the new epoch or the notify finds us.
        let mut parked = shared.parked.lock().unwrap_or_else(|e| e.into_inner());
        if shared.epoch_lanes.load(Ordering::Acquire) != seen
            || shared.shutdown.load(Ordering::Acquire)
        {
            continue; // guard drops; outer loop re-reads
        }
        *parked |= lane_bit;
        shared.parks.fetch_add(1, Ordering::Relaxed);
        while shared.epoch_lanes.load(Ordering::Acquire) == seen
            && !shared.shutdown.load(Ordering::Acquire)
        {
            parked = shared.wake.wait(parked).unwrap_or_else(|e| e.into_inner());
        }
        *parked &= !lane_bit;
        shared.wakes.fetch_add(1, Ordering::Relaxed);
        drop(parked);
    }
}

/// A `&mut [T]` sharable across tile bands: each band takes a *disjoint*
/// sub-borrow. The only unsafe surface of the kernel layer — every use
/// site owns a distinct index set (output columns, row bands, head
/// slices), which is exactly the fixed-tile-ownership contract.
pub struct SharedSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access discipline (disjoint index sets per band) is the
// documented contract of the unsafe accessors below.
unsafe impl<T: Send> Send for SharedSliceMut<'_, T> {}
unsafe impl<T: Send> Sync for SharedSliceMut<'_, T> {}

impl<'a, T> SharedSliceMut<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable sub-slice `r`.
    ///
    /// # Safety
    /// No two concurrently live borrows (from any band) may overlap, and
    /// `r` must lie within the slice.
    #[allow(clippy::mut_from_ref)] // disjointness is the documented contract
    pub unsafe fn borrow_range(&self, r: Range<usize>) -> &'a mut [T] {
        debug_assert!(r.start <= r.end && r.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }

    /// Write one element.
    ///
    /// # Safety
    /// `idx` must be in bounds and owned exclusively by the calling band.
    pub unsafe fn write(&self, idx: usize, v: T) {
        debug_assert!(idx < self.len);
        *self.ptr.add(idx) = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::with_threads(1);
        let mut hits = vec![0u32; 17];
        {
            let s = SharedSliceMut::new(&mut hits);
            pool.run_tiles(0..17, |r| {
                for i in r {
                    unsafe { s.write(i, 1) };
                }
            });
        }
        assert!(hits.iter().all(|&h| h == 1), "every tile exactly once");
        assert_eq!(pool.stats().dispatches, 0, "single-lane pool never dispatches");
        assert_eq!(pool.stats().workers, 0);
    }

    #[test]
    fn every_tile_runs_exactly_once_parallel() {
        let pool = WorkerPool::with_threads(4);
        for n in [1usize, 2, 3, 4, 5, 63, 64, 65, 1000] {
            let mut hits = vec![0u32; n];
            {
                let s = SharedSliceMut::new(&mut hits);
                pool.run_tiles(0..n, |r| {
                    for i in r {
                        unsafe { s.write(i, hits_plus_one(&s, i)) };
                    }
                });
            }
            assert!(hits.iter().all(|&h| h == 1), "n={n}: every tile exactly once");
        }
        assert!(pool.stats().dispatches >= 1);
    }

    /// Read-modify-write helper for the coverage test (each index is owned
    /// by exactly one band, so the unsafe read is race-free).
    fn hits_plus_one(s: &SharedSliceMut<'_, u32>, i: usize) -> u32 {
        unsafe { s.borrow_range(i..i + 1)[0] + 1 }
    }

    #[test]
    fn fixed_ownership_is_reproducible() {
        // Record the band start each tile was served by; two invocations
        // (and a differently-sized dispatch in between) must agree.
        let pool = WorkerPool::with_threads(3);
        let n = 301;
        let run = || {
            let mut owner = vec![usize::MAX; n];
            {
                let s = SharedSliceMut::new(&mut owner);
                pool.run_tiles(0..n, |r| {
                    let band = unsafe { s.borrow_range(r.clone()) };
                    for o in band.iter_mut() {
                        *o = r.start;
                    }
                });
            }
            owner
        };
        let a = run();
        pool.run_tiles(0..7, |_r| {});
        let b = run();
        assert_eq!(a, b, "tile ownership must be fixed across invocations");
        assert!(a.iter().all(|&o| o != usize::MAX));
    }

    #[test]
    fn results_bitwise_equal_across_pool_sizes() {
        let n = 4096;
        let input: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let run = |threads: usize| {
            let pool = WorkerPool::with_threads(threads);
            let mut out = vec![0f32; n];
            {
                let s = SharedSliceMut::new(&mut out);
                pool.run_tiles(0..n, |r| {
                    let band = unsafe { s.borrow_range(r.clone()) };
                    for (o, i) in band.iter_mut().zip(r) {
                        *o = input[i] * 3.25 + 0.125;
                    }
                });
            }
            out
        };
        let one = run(1);
        let two = run(2);
        let max = run(WorkerPool::default_threads().max(4));
        assert_eq!(one, two);
        assert_eq!(one, max);
    }

    #[test]
    fn lanes_for_respects_threshold() {
        let pool = WorkerPool::with_threads(8);
        assert_eq!(pool.lanes_for(0), 1);
        assert_eq!(pool.lanes_for(2 * PAR_MIN_WORK - 1), 1);
        assert_eq!(pool.lanes_for(2 * PAR_MIN_WORK), 2);
        assert_eq!(pool.lanes_for(64 * PAR_MIN_WORK), 8, "capped by the pool");
        let small = WorkerPool::with_threads(2);
        assert_eq!(small.lanes_for(64 * PAR_MIN_WORK), 2);
    }

    #[test]
    fn parked_workers_wake_for_later_dispatches() {
        let pool = WorkerPool::with_threads(2);
        let mut out = vec![0u8; 64];
        {
            let s = SharedSliceMut::new(&mut out);
            pool.run_tiles(0..64, |r| {
                for i in r {
                    unsafe { s.write(i, 1) };
                }
            });
        }
        // Let the worker exhaust its spin budget and park…
        std::thread::sleep(std::time::Duration::from_millis(30));
        // …then dispatch again: it must wake and serve.
        {
            let s = SharedSliceMut::new(&mut out);
            pool.run_tiles(0..64, |r| {
                for i in r {
                    unsafe { s.write(i, 2) };
                }
            });
        }
        assert!(out.iter().all(|&v| v == 2));
        assert_eq!(pool.stats().dispatches, 2);
    }

    #[test]
    fn stats_snapshot_shape() {
        let pool = WorkerPool::with_threads(3);
        let s = pool.stats();
        assert_eq!(s.threads, 3);
        assert_eq!(s.workers, 2);
        assert_eq!(s.dispatches, 0);
    }

    #[test]
    fn lane_dispatch_counters_track_engagement() {
        let pool = WorkerPool::with_threads(4);
        assert_eq!(pool.lane_dispatches(), [0u64; 64]);
        // width-2 dispatch engages lanes 0 and 1 only
        pool.run_tiles_bounded(0..100, 2, |_r| {});
        // full-width dispatch engages all four lanes
        pool.run_tiles(0..100, |_r| {});
        let lanes = pool.lane_dispatches();
        assert_eq!(&lanes[..4], &[2, 2, 1, 1]);
        assert!(lanes[4..].iter().all(|&c| c == 0), "unengaged lanes stay zero");
        // serial fallback (single tile) never dispatches and never counts
        let serial = WorkerPool::with_threads(1);
        serial.run_tiles(0..100, |_r| {});
        assert_eq!(serial.lane_dispatches(), [0u64; 64]);
    }

    #[test]
    fn worker_panic_is_isolated_band_retiled_lane_dies() {
        // A closure that panics exactly ONCE, on the first touch of band 1
        // (a transient fault): lane 1 dies, the dispatcher re-runs the band
        // inline, and the caller still gets full bitwise-correct coverage.
        let pool = WorkerPool::with_threads(4);
        let n = 1000usize;
        let band = n.div_ceil(4);
        let fired = std::sync::atomic::AtomicBool::new(false);
        let mut out = vec![0u8; n];
        {
            let s = SharedSliceMut::new(&mut out);
            pool.run_tiles(0..n, |r| {
                if r.start == band && !fired.swap(true, Ordering::Relaxed) {
                    panic!("tile boom (once)");
                }
                for i in r {
                    unsafe { s.write(i, 1) };
                }
            });
        }
        assert!(out.iter().all(|&v| v == 1), "dead lane's band re-tiled: full coverage");
        let s = pool.stats();
        assert_eq!(s.lane_deaths, 1);
        assert_eq!(s.dead_lanes, 0b10, "lane 1 retired");
        // the pool stays serviceable, dead band pre-folded onto band 0
        let mut out2 = vec![0u8; 512];
        {
            let s2 = SharedSliceMut::new(&mut out2);
            pool.run_tiles(0..512, |r| {
                for i in r {
                    unsafe { s2.write(i, 2) };
                }
            });
        }
        assert!(out2.iter().all(|&v| v == 2), "pool must keep working after a lane death");
        assert_eq!(pool.stats().lane_deaths, 1, "no double-counting");
    }

    #[test]
    fn deterministic_panic_still_propagates_to_caller() {
        // A closure that ALWAYS panics off band 0 panics again on the
        // inline re-run — a genuine bug is never silently swallowed.
        let pool = WorkerPool::with_threads(4);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_tiles(0..1000, |r| {
                if r.start > 0 {
                    panic!("tile boom");
                }
            });
        }));
        assert!(res.is_err(), "a deterministic band panic must reach the dispatcher");
        // all worker lanes died; the pool degrades to dispatcher-only but
        // still yields full coverage (no poisoned locks, no stuck barrier)
        let mut out = vec![0u8; 512];
        {
            let s = SharedSliceMut::new(&mut out);
            pool.run_tiles(0..512, |r| {
                for i in r {
                    unsafe { s.write(i, 1) };
                }
            });
        }
        assert!(out.iter().all(|&v| v == 1), "pool must keep working after a panic");
        assert_eq!(pool.stats().dead_lanes, 0b1110, "all three worker lanes retired");
    }

    #[test]
    fn injected_lane_panic_is_consumed_and_isolated() {
        let pool = WorkerPool::with_threads(4);
        pool.inject_lane_fault(1, LaneFault::Panic);
        let mut out = vec![0u8; 1000];
        {
            let s = SharedSliceMut::new(&mut out);
            pool.run_tiles(0..1000, |r| {
                for i in r {
                    unsafe { s.write(i, 1) };
                }
            });
        }
        assert!(out.iter().all(|&v| v == 1), "injected panic is invisible in the output");
        let s = pool.stats();
        assert_eq!(s.lane_deaths, 1);
        assert_eq!(s.dead_lanes, 0b10);
    }

    #[test]
    fn injected_stall_is_bitwise_invisible() {
        let pool = WorkerPool::with_threads(2);
        let run = |pool: &WorkerPool| {
            let mut out = vec![0f32; 256];
            {
                let s = SharedSliceMut::new(&mut out);
                pool.run_tiles(0..256, |r| {
                    let band = unsafe { s.borrow_range(r.clone()) };
                    for (o, i) in band.iter_mut().zip(r) {
                        *o = (i as f32).sin() * 1.5;
                    }
                });
            }
            out
        };
        let a = run(&pool);
        pool.inject_lane_fault(1, LaneFault::Stall);
        let b = run(&pool);
        assert_eq!(a, b, "a stalled lane delays, never changes, the output");
        assert_eq!(pool.stats().lane_deaths, 0, "stall is not a death");
        assert_eq!(pool.stats().dead_lanes, 0);
    }

    #[test]
    fn inject_on_serial_pool_is_a_noop() {
        let pool = WorkerPool::with_threads(1);
        pool.inject_lane_fault(0, LaneFault::Panic);
        pool.inject_lane_fault(5, LaneFault::Stall);
        let mut out = vec![0u8; 64];
        {
            let s = SharedSliceMut::new(&mut out);
            pool.run_tiles(0..64, |r| {
                for i in r {
                    unsafe { s.write(i, 1) };
                }
            });
        }
        assert!(out.iter().all(|&v| v == 1));
        assert_eq!(pool.stats().lane_deaths, 0);
    }

    #[test]
    fn bounded_dispatch_waits_only_on_active_lanes() {
        // lanes capped at 2 on a 4-lane pool: the dispatch must complete
        // (and produce full coverage) without lanes 2/3 on the barrier.
        let pool = WorkerPool::with_threads(4);
        let mut out = vec![0u8; 100];
        {
            let s = SharedSliceMut::new(&mut out);
            pool.run_tiles_bounded(0..100, 2, |r| {
                for i in r {
                    unsafe { s.write(i, 1) };
                }
            });
        }
        assert!(out.iter().all(|&v| v == 1));
        assert_eq!(pool.stats().dispatches, 1);
    }
}
