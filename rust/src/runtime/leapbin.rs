//! Reader for the `leapbin` tensor format written by
//! `python/compile/leapbin.py` (see that file for the byte layout).
//! Keep the two implementations in sync.

use std::fs;
use std::path::Path;

use anyhow::{bail, ensure, Context};

/// Element type of a leapbin tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    pub fn bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

/// A host tensor loaded from a leapbin file.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian bytes, C order.
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Interpret the payload as f32 values.
    pub fn as_f32(&self) -> anyhow::Result<Vec<f32>> {
        ensure!(self.dtype == DType::F32, "tensor is {:?}", self.dtype);
        Ok(self.data.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Interpret the payload as i32 values.
    pub fn as_i32(&self) -> anyhow::Result<Vec<i32>> {
        ensure!(self.dtype == DType::I32, "tensor is {:?}", self.dtype);
        Ok(self.data.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Build an XLA literal of the right shape/type (PJRT path only).
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let ty = match self.dtype {
            DType::F32 => xla::ElementType::F32,
            DType::I8 => xla::ElementType::S8,
            DType::I32 => xla::ElementType::S32,
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(ty, &self.dims, &self.data)?)
    }
}

/// Load a leapbin file.
pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Tensor> {
    let path = path.as_ref();
    let blob = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse(&blob).with_context(|| format!("parsing {}", path.display()))
}

/// Parse a leapbin blob.
pub fn parse(blob: &[u8]) -> anyhow::Result<Tensor> {
    ensure!(blob.len() >= 8, "truncated header");
    ensure!(&blob[..4] == b"LEAP", "bad magic");
    let (ver, code, ndim) = (blob[4], blob[5], blob[6] as usize);
    ensure!(ver == 1, "unsupported version {ver}");
    let dtype = match code {
        0 => DType::F32,
        1 => DType::I8,
        2 => DType::I32,
        d => bail!("unknown dtype code {d}"),
    };
    ensure!(blob.len() >= 8 + 4 * ndim, "truncated dims");
    let dims: Vec<usize> = (0..ndim)
        .map(|k| {
            u32::from_le_bytes([
                blob[8 + 4 * k],
                blob[9 + 4 * k],
                blob[10 + 4 * k],
                blob[11 + 4 * k],
            ]) as usize
        })
        .collect();
    let data = blob[8 + 4 * ndim..].to_vec();
    let expect: usize = dims.iter().product::<usize>() * dtype.bytes();
    ensure!(data.len() == expect, "payload {} != expected {}", data.len(), expect);
    Ok(Tensor { dtype, dims, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(dtype_code: u8, dims: &[u32], payload: &[u8]) -> Vec<u8> {
        let mut b = b"LEAP".to_vec();
        b.push(1);
        b.push(dtype_code);
        b.push(dims.len() as u8);
        b.push(0);
        for d in dims {
            b.extend_from_slice(&d.to_le_bytes());
        }
        b.extend_from_slice(payload);
        b
    }

    #[test]
    fn parse_f32() {
        let payload: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let t = parse(&mk(0, &[2, 3], &payload)).unwrap();
        assert_eq!(t.dims, vec![2, 3]);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn parse_i8_and_i32() {
        let t = parse(&mk(1, &[4], &[1, 2, 0xFF, 0x80])).unwrap();
        assert_eq!(t.dtype, DType::I8);
        assert_eq!(t.element_count(), 4);
        let payload: Vec<u8> = [7i32, -9].iter().flat_map(|v| v.to_le_bytes()).collect();
        let t = parse(&mk(2, &[2], &payload)).unwrap();
        assert_eq!(t.as_i32().unwrap(), vec![7, -9]);
    }

    #[test]
    fn rejects_corruption() {
        assert!(parse(b"XXXX\x01\x00\x01\x00\x02\x00\x00\x00").is_err()); // magic
        assert!(parse(&mk(0, &[3], &[0; 8])).is_err()); // size mismatch
        assert!(parse(&mk(9, &[1], &[0; 4])).is_err()); // dtype
        let mut v = mk(0, &[1], &[0; 4]);
        v[4] = 2; // version
        assert!(parse(&v).is_err());
    }

    #[test]
    fn wrong_view_rejected() {
        let t = parse(&mk(1, &[1], &[5])).unwrap();
        assert!(t.as_f32().is_err());
    }
}
