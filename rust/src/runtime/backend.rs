//! The pluggable numerics-backend seam: everything the serving coordinator
//! needs from a functional model implementation, independent of *how* the
//! forward pass is computed.
//!
//! Two implementations exist:
//!
//! - [`crate::runtime::ReferenceBackend`] — pure-Rust naive f32 transformer
//!   (mirrors `python/compile/kernels/ref.py`), loads `leapbin` weights,
//!   zero external dependencies. The default.
//! - `crate::runtime::PjrtBackend` (`--features xla`) — executes the
//!   AOT-lowered HLO artifacts through PJRT.
//!
//! A backend owns per-request KV-cache state keyed by [`SessionId`]; the
//! coordinator uses its `RequestId` as the session id, calls
//! [`NumericsBackend::prefill`] once on admission,
//! [`NumericsBackend::decode_batch`] once per decode round (one entry per
//! live session, so a batching backend can stream each weight matrix once
//! for the whole round), and [`NumericsBackend::release`] at retire.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{ensure, Context};

/// Opaque per-request session key (the coordinator passes its request id).
pub type SessionId = u64;

/// Logits produced by one prefill or decode execution.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOutput {
    /// Row-major `[rows, vocab]` logits.
    pub logits: Vec<f32>,
    pub rows: usize,
}

/// Per-step results of a batched decode round, in step order.
pub type BatchResults = Vec<anyhow::Result<StepOutput>>;

/// A functional numerics implementation behind the serving engine.
pub trait NumericsBackend {
    /// Short human-readable backend name (diagnostics).
    fn name(&self) -> &'static str;

    /// Vocabulary size (logits row width).
    fn vocab(&self) -> usize;

    /// Run the prompt through the model, creating the session's KV cache.
    /// Returns at least `tokens.len()` logits rows (implementations must
    /// reject prompts they cannot represent in full — no silent
    /// truncation); row `tokens.len() - 1` selects the first generated
    /// token.
    fn prefill(&mut self, session: SessionId, tokens: &[i32]) -> anyhow::Result<StepOutput>;

    /// Advance the session by one token; returns a single logits row.
    fn decode_step(&mut self, session: SessionId, token: i32) -> anyhow::Result<StepOutput>;

    /// Whether [`Self::prefill_chunk`] is implemented. Backends that only
    /// support monolithic prefill (the default) are served by the engine
    /// with `chunk = whole prompt` regardless of its chunk setting.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Run one contiguous slice of the prompt through the model,
    /// incrementally extending the session's KV cache. `start` is the
    /// absolute position of `chunk[0]` (the first call has `start == 0`
    /// and creates the session; later calls must resume exactly where the
    /// previous chunk ended). `last` marks the final chunk — after it the
    /// session must be in the same state monolithic
    /// [`Self::prefill`]`(prompt)` would have produced (bitwise-identical
    /// KV, same sealing/sharing), and the returned logits' row
    /// `chunk.len() - 1` selects the first generated token. Returns
    /// `chunk.len()` logits rows.
    ///
    /// The default refuses (see [`Self::supports_chunked_prefill`]).
    fn prefill_chunk(
        &mut self,
        _session: SessionId,
        _chunk: &[i32],
        _start: usize,
        _last: bool,
    ) -> anyhow::Result<StepOutput> {
        anyhow::bail!("backend does not support chunked prefill")
    }

    /// Advance many sessions by one token each — the weight-stationary
    /// entry point: one pass over each weight matrix can serve every step
    /// in the slice. Returns one result per step, in order; a per-session
    /// failure (unknown session, bad token, exhausted context window)
    /// occupies its slot as an `Err` without failing the whole round. The
    /// outer `Err` is reserved for whole-backend failures.
    ///
    /// Implementations must be observably equivalent to calling
    /// [`Self::decode_step`] sequentially in slice order (the reference
    /// backend's batched path is bitwise-identical; see
    /// `tests/prop_backend.rs`). The default does exactly that.
    fn decode_batch(&mut self, steps: &[(SessionId, i32)]) -> anyhow::Result<BatchResults> {
        Ok(steps.iter().map(|&(session, token)| self.decode_step(session, token)).collect())
    }

    /// Drop the session's KV-cache state (idempotent). A pooled backend
    /// returns the session's blocks to the shared pool — this is also the
    /// preemption hook: the coordinator releases a preempted session here
    /// and re-prefills its tokens on readmission.
    fn release(&mut self, session: SessionId);

    // --- pooled-KV admission hooks (defaulted so unpooled backends — the
    // PJRT path, synthetic test doubles — compile and serve unchanged) ---

    /// Model context window in tokens (`s_max`), when the backend knows
    /// it. The engine uses this for typed submit-time validation.
    fn context_window(&self) -> Option<usize> {
        None
    }

    /// Snapshot of the backend's pooled-KV allocator (`None` = this
    /// backend does not pool KV; admission falls back to the
    /// coordinator's capacity accounting alone).
    fn kv_pool_stats(&self) -> Option<crate::kvcache::PoolStats> {
        None
    }

    /// Worst-case free blocks required to decode one more token on
    /// `session` (0 for unpooled backends or unknown sessions). The
    /// engine sums this over a decode round and preempts the youngest
    /// sessions when the pool is short.
    fn kv_append_demand(&self, _session: SessionId) -> usize {
        0
    }

    /// Worst-case blocks needed to admit a new session holding `tokens`
    /// KV positions, ignoring prefix sharing (`None` = unpooled).
    fn kv_admit_demand(&self, _tokens: usize) -> Option<usize> {
        None
    }

    /// Extract the session's stored KV rows as a dtype-preserving
    /// [`crate::kvcache::SpillImage`] (`None` = unpooled backend or
    /// unknown session — the caller falls back to discard + re-prefill).
    /// Called immediately before [`Self::release`] on a preemption with
    /// spill enabled; the session's state afterwards is unchanged.
    fn kv_spill(&mut self, _session: SessionId) -> Option<crate::kvcache::SpillImage> {
        None
    }

    /// Re-create `session` from a spill image without running the model:
    /// rebuild the block table over `tokens` (re-sharing any cached
    /// prefix), replay the image's rows verbatim, and leave the session
    /// exactly as a real prefill of `tokens` would have
    /// (`image.rows == tokens.len()`). On `Err` the backend must hold no
    /// trace of the session — the caller re-prefills instead.
    fn kv_restore(
        &mut self,
        _session: SessionId,
        _tokens: &[i32],
        _image: &crate::kvcache::SpillImage,
    ) -> anyhow::Result<()> {
        anyhow::bail!("backend does not support KV spill/restore")
    }

    /// Snapshot of the backend's resident worker pool (`None` = this
    /// backend computes inline / has no persistent pool). Dispatch and
    /// park/wake counters feed the serving metrics; the dispatch counter
    /// is also the observable witness that the hot path never spawns
    /// threads after load.
    fn worker_pool_stats(&self) -> Option<super::pool::WorkerPoolStats> {
        None
    }

    /// Cumulative dispatch engagements per worker-pool lane (index =
    /// lane; slots past the pool's lane count stay zero). `None` = no
    /// pool. The tracer diffs successive snapshots into per-lane
    /// [`crate::obs::EventKind::PoolLane`] activity, one counter track per
    /// lane in the Chrome trace.
    fn worker_pool_lane_dispatches(&self) -> Option<[u64; 64]> {
        None
    }

    /// Arm a one-shot [`super::pool::LaneFault`] on the backend's worker
    /// pool (the engine's fault-injection hook). No-op for backends
    /// without a resident pool — a fault plan targeting lanes then simply
    /// never fires, which keeps chaos scenarios runnable everywhere.
    fn inject_lane_fault(&mut self, _lane: usize, _fault: super::pool::LaneFault) {}
}

/// Greedy argmax over one `[vocab]`-wide row of a `[rows, vocab]` buffer.
///
/// NaN-safe: `NaN` entries never win (a comparison against the running
/// best is always `false` for `NaN`), so a partly-poisoned row still
/// selects its largest real logit. Ties break to the **lowest index**.
/// An all-`NaN` (or empty-range) row returns index 0.
pub fn argmax_row(logits: &[f32], row: usize, vocab: usize) -> usize {
    let slice = &logits[row * vocab..(row + 1) * vocab];
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in slice.iter().enumerate() {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

/// Model metadata parsed from an artifact directory's `meta.txt`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    /// Crossbar tile size the weights were quantised with.
    pub xb: usize,
    pub s_prefill: usize,
    pub s_max: usize,
    pub param_order: Vec<String>,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> anyhow::Result<usize> {
            kv.get(k).with_context(|| format!("meta missing {k}"))?.parse().context("parse")
        };
        Ok(Self {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            xb: get("xb")?,
            s_prefill: get("s_prefill")?,
            s_max: get("s_max")?,
            param_order: kv
                .get("param_order")
                .context("meta missing param_order")?
                .split(',')
                .map(str::to_string)
                .collect(),
        })
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Validate one decode step against this model: the token must be in
    /// vocab and the session must have a free position in the context
    /// window. The single source of the boundary error messages, shared by
    /// every sequential-step path (fast, naive, batched validation), so
    /// batched and sequential decode fail identically.
    pub fn check_step(&self, pos: usize, token: i32) -> anyhow::Result<()> {
        ensure!(
            (0..self.vocab as i32).contains(&token),
            "token {token} outside vocab 0..{}",
            self.vocab
        );
        ensure!(
            pos < self.s_max,
            "session context {pos} has exhausted the model window s_max={}",
            self.s_max
        );
        Ok(())
    }
}

/// Locate a usable artifact directory (one containing `meta.txt`). An
/// explicit candidate is authoritative: it is the only directory considered
/// (`None` if it lacks `meta.txt` — never silently fall back to a different
/// model's weights). Without one, try the conventional build output
/// locations, then the checked-in reference fixture.
pub fn default_artifacts_dir(explicit: Option<&str>) -> Option<PathBuf> {
    if let Some(dir) = explicit.filter(|d| !d.is_empty()) {
        let dir = PathBuf::from(dir);
        return dir.join("meta.txt").is_file().then_some(dir);
    }
    let mut candidates: Vec<PathBuf> = Vec::new();
    candidates.push(PathBuf::from("artifacts"));
    candidates.push(PathBuf::from("rust/artifacts"));
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    candidates.push(manifest.join("artifacts"));
    candidates.push(manifest.join("tests/fixtures/tiny_ref"));
    candidates.into_iter().find(|d| d.join("meta.txt").is_file())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let text = "vocab=512\nd_model=256\nn_layers=4\nn_heads=4\nn_kv_heads=4\n\
                    d_ff=512\nxb=128\nshard=16\ns_prefill=32\ns_max=128\n\
                    golden_prompt_len=8\ngolden_steps=8\nparam_order=a,b,c\n";
        let m = ArtifactMeta::parse(text).unwrap();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.xb, 128);
        assert_eq!(m.s_max, 128);
        assert_eq!(m.d_head(), 64);
        assert_eq!(m.param_order, vec!["a", "b", "c"]);
    }

    #[test]
    fn meta_parse_rejects_missing() {
        assert!(ArtifactMeta::parse("vocab=1\n").is_err());
    }

    #[test]
    fn argmax_rows() {
        let logits = [0.1, 0.9, 0.0, 7.0, -1.0, 2.0];
        assert_eq!(argmax_row(&logits, 0, 3), 1);
        assert_eq!(argmax_row(&logits, 1, 3), 0);
    }

    #[test]
    fn argmax_skips_nans() {
        // a leading NaN must not shadow the real maximum
        assert_eq!(argmax_row(&[f32::NAN, 0.5, 0.9], 0, 3), 2);
        // NaN in the middle is skipped too
        assert_eq!(argmax_row(&[0.5, f32::NAN, 0.1], 0, 3), 0);
        // an all-NaN row falls back to index 0
        assert_eq!(argmax_row(&[f32::NAN, f32::NAN], 0, 2), 0);
    }

    #[test]
    fn argmax_ties_break_to_lowest_index() {
        assert_eq!(argmax_row(&[3.0, 7.0, 7.0, 7.0], 0, 4), 1);
        // -inf everywhere: lowest index wins
        assert_eq!(argmax_row(&[f32::NEG_INFINITY; 3], 0, 3), 0);
    }

    #[test]
    fn fixture_dir_is_discoverable() {
        // Without an explicit path, discovery finds the checked-in fixture.
        let d = default_artifacts_dir(None).unwrap();
        assert!(d.join("meta.txt").is_file());
    }

    #[test]
    fn explicit_artifacts_path_is_authoritative() {
        // A bad explicit path must NOT fall back to some other model's dir.
        assert_eq!(default_artifacts_dir(Some("/nonexistent/path")), None);
    }
}
