//! PJRT execution engine (`--features xla`): loads the AOT-lowered HLO text
//! artifacts, compiles them once on the CPU PJRT client, and executes the
//! functional model on the request path. [`PjrtBackend`] adapts it to the
//! [`NumericsBackend`] seam so the coordinator is backend-agnostic.
//!
//! HLO *text* is the interchange format — jax ≥ 0.5 emits HloModuleProto
//! with 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The default build ships an API-compatible `xla` stub (rust/xla-stub) so
//! this module always type-checks; executing real artifacts requires
//! pointing the `xla` path dependency at an actual xla-rs checkout.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context};

use super::backend::{ArtifactMeta, NumericsBackend, SessionId, StepOutput};
use super::leapbin::{self, Tensor};

/// The loaded runtime: compiled executables + weight literals.
pub struct Engine {
    pub meta: ArtifactMeta,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    /// Weight literals in meta.param_order.
    params: Vec<xla::Literal>,
    pub artifacts_dir: PathBuf,
}

/// Result of a prefill or decode execution.
pub struct PjrtStepOutput {
    /// Logits, row-major [rows, vocab].
    pub logits: Vec<f32>,
    pub rows: usize,
    /// Updated KV caches (opaque literals fed back on the next step).
    pub kcache: xla::Literal,
    pub vcache: xla::Literal,
}

impl Engine {
    /// Load every artifact from `dir` and compile both entry points.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_text = std::fs::read_to_string(dir.join("meta.txt"))
            .with_context(|| format!("{}/meta.txt (run `make artifacts`)", dir.display()))?;
        let meta = ArtifactMeta::parse(&meta_text)?;

        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> anyhow::Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = compile("tiny_prefill.hlo.txt")?;
        let decode_exe = compile("tiny_decode.hlo.txt")?;

        let mut params = Vec::with_capacity(meta.param_order.len());
        for name in &meta.param_order {
            let t = leapbin::load(dir.join("weights").join(format!("{name}.bin")))?;
            params.push(t.to_literal()?);
        }
        Ok(Self { meta, client, prefill_exe, decode_exe, params, artifacts_dir: dir })
    }

    /// Run the prefill graph on `tokens` (padded/truncated to s_prefill).
    pub fn prefill(&self, tokens: &[i32]) -> anyhow::Result<PjrtStepOutput> {
        ensure!(!tokens.is_empty(), "empty prompt");
        let s = self.meta.s_prefill;
        let mut padded = vec![0i32; s];
        let n = tokens.len().min(s);
        padded[..n].copy_from_slice(&tokens[..n]);
        let tok_lit = xla::Literal::vec1(&padded);

        let mut args: Vec<&xla::Literal> = vec![&tok_lit];
        args.extend(self.params.iter());
        let result = self.prefill_exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 3, "expected (logits, K, V), got {}", outs.len());
        let mut it = outs.into_iter();
        let logits_lit = it.next().unwrap();
        let kcache = it.next().unwrap();
        let vcache = it.next().unwrap();
        Ok(PjrtStepOutput {
            logits: logits_lit.to_vec::<f32>()?,
            rows: s,
            kcache,
            vcache,
        })
    }

    /// Run one decode step.
    pub fn decode(
        &self,
        token: i32,
        pos: i32,
        kcache: &xla::Literal,
        vcache: &xla::Literal,
    ) -> anyhow::Result<PjrtStepOutput> {
        let tok_lit = xla::Literal::vec1(&[token]);
        let pos_lit = xla::Literal::scalar(pos);
        let mut args: Vec<&xla::Literal> = vec![&tok_lit, &pos_lit, kcache, vcache];
        args.extend(self.params.iter());
        let result = self.decode_exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 3, "expected (logits, K, V), got {}", outs.len());
        let mut it = outs.into_iter();
        let logits_lit = it.next().unwrap();
        let kcache = it.next().unwrap();
        let vcache = it.next().unwrap();
        Ok(PjrtStepOutput { logits: logits_lit.to_vec::<f32>()?, rows: 1, kcache, vcache })
    }

    /// Greedy argmax over a logits row.
    pub fn argmax_row(&self, logits: &[f32], row: usize) -> usize {
        super::backend::argmax_row(logits, row, self.meta.vocab)
    }

    /// Golden tensors for self-check (prompt, expected logits, greedy ids).
    pub fn golden(&self) -> anyhow::Result<(Tensor, Tensor, Tensor)> {
        let g = |n: &str| leapbin::load(self.artifacts_dir.join("golden").join(n));
        Ok((g("prompt.bin")?, g("prefill_logits.bin")?, g("greedy_tokens.bin")?))
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Per-session PJRT decode state.
struct PjrtSession {
    kcache: xla::Literal,
    vcache: xla::Literal,
    pos: usize,
}

/// [`NumericsBackend`] adapter over the PJRT [`Engine`]: owns the opaque
/// per-session KV-cache literals the executables thread through each step.
pub struct PjrtBackend {
    engine: Engine,
    sessions: HashMap<SessionId, PjrtSession>,
}

impl PjrtBackend {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        Ok(Self { engine: Engine::load(dir)?, sessions: HashMap::new() })
    }

    pub fn new(engine: Engine) -> Self {
        Self { engine, sessions: HashMap::new() }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl NumericsBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt-xla"
    }

    fn vocab(&self) -> usize {
        self.engine.meta.vocab
    }

    fn prefill(&mut self, session: SessionId, tokens: &[i32]) -> anyhow::Result<StepOutput> {
        // The AOT prefill graph has a fixed window; silently truncating
        // would continue from the wrong context, so reject instead.
        ensure!(
            tokens.len() <= self.engine.meta.s_prefill,
            "prompt of {} tokens exceeds the artifact prefill window {}",
            tokens.len(),
            self.engine.meta.s_prefill
        );
        let out = self.engine.prefill(tokens)?;
        self.sessions.insert(
            session,
            PjrtSession { kcache: out.kcache, vcache: out.vcache, pos: tokens.len() },
        );
        Ok(StepOutput { logits: out.logits, rows: out.rows })
    }

    fn decode_step(&mut self, session: SessionId, token: i32) -> anyhow::Result<StepOutput> {
        let st = self
            .sessions
            .get_mut(&session)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session} (prefill first)"))?;
        // Same boundary contract as the reference backend: decoding past
        // the artifact's KV window would overwrite live cache slots, so
        // reject instead of silently wrapping.
        ensure!(
            st.pos < self.engine.meta.s_max,
            "session context {} has exhausted the model window s_max={}",
            st.pos,
            self.engine.meta.s_max
        );
        let out = self.engine.decode(token, st.pos as i32, &st.kcache, &st.vcache)?;
        st.kcache = out.kcache;
        st.vcache = out.vcache;
        st.pos += 1;
        Ok(StepOutput { logits: out.logits, rows: out.rows })
    }

    fn release(&mut self, session: SessionId) {
        self.sessions.remove(&session);
    }

    fn context_window(&self) -> Option<usize> {
        Some(self.engine.meta.s_max)
    }
}

// ArtifactMeta parsing is covered in runtime/backend.rs; engine execution
// itself is covered by tests/integration_runtime.rs (feature `xla` + the
// artifacts directory built by `make artifacts`).
