//! PJRT execution engine: loads the AOT-lowered HLO text artifacts, compiles
//! them once on the CPU PJRT client, and executes the functional model on
//! the request path (the numerics half of serving; the simulator provides
//! the timing/energy half).
//!
//! HLO *text* is the interchange format — jax ≥ 0.5 emits HloModuleProto
//! with 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context};

use super::leapbin::{self, Tensor};

/// Model metadata parsed from `artifacts/meta.txt`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub s_prefill: usize,
    pub s_max: usize,
    pub param_order: Vec<String>,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> anyhow::Result<usize> {
            kv.get(k).with_context(|| format!("meta missing {k}"))?.parse().context("parse")
        };
        Ok(Self {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            s_prefill: get("s_prefill")?,
            s_max: get("s_max")?,
            param_order: kv
                .get("param_order")
                .context("meta missing param_order")?
                .split(',')
                .map(str::to_string)
                .collect(),
        })
    }
}

/// The loaded runtime: compiled executables + weight literals.
pub struct Engine {
    pub meta: ArtifactMeta,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    /// Weight literals in meta.param_order.
    params: Vec<xla::Literal>,
    pub artifacts_dir: PathBuf,
}

/// Result of a prefill or decode execution.
pub struct StepOutput {
    /// Logits, row-major [rows, vocab].
    pub logits: Vec<f32>,
    pub rows: usize,
    /// Updated KV caches (opaque literals fed back on the next step).
    pub kcache: xla::Literal,
    pub vcache: xla::Literal,
}

impl Engine {
    /// Load every artifact from `dir` and compile both entry points.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_text = std::fs::read_to_string(dir.join("meta.txt"))
            .with_context(|| format!("{}/meta.txt (run `make artifacts`)", dir.display()))?;
        let meta = ArtifactMeta::parse(&meta_text)?;

        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> anyhow::Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = compile("tiny_prefill.hlo.txt")?;
        let decode_exe = compile("tiny_decode.hlo.txt")?;

        let mut params = Vec::with_capacity(meta.param_order.len());
        for name in &meta.param_order {
            let t = leapbin::load(dir.join("weights").join(format!("{name}.bin")))?;
            params.push(t.to_literal()?);
        }
        Ok(Self { meta, client, prefill_exe, decode_exe, params, artifacts_dir: dir })
    }

    /// Run the prefill graph on `tokens` (padded/truncated to s_prefill).
    pub fn prefill(&self, tokens: &[i32]) -> anyhow::Result<StepOutput> {
        ensure!(!tokens.is_empty(), "empty prompt");
        let s = self.meta.s_prefill;
        let mut padded = vec![0i32; s];
        let n = tokens.len().min(s);
        padded[..n].copy_from_slice(&tokens[..n]);
        let tok_lit = xla::Literal::vec1(&padded);

        let mut args: Vec<&xla::Literal> = vec![&tok_lit];
        args.extend(self.params.iter());
        let result = self.prefill_exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 3, "expected (logits, K, V), got {}", outs.len());
        let mut it = outs.into_iter();
        let logits_lit = it.next().unwrap();
        let kcache = it.next().unwrap();
        let vcache = it.next().unwrap();
        Ok(StepOutput {
            logits: logits_lit.to_vec::<f32>()?,
            rows: s,
            kcache,
            vcache,
        })
    }

    /// Run one decode step.
    pub fn decode(
        &self,
        token: i32,
        pos: i32,
        kcache: &xla::Literal,
        vcache: &xla::Literal,
    ) -> anyhow::Result<StepOutput> {
        let tok_lit = xla::Literal::vec1(&[token]);
        let pos_lit = xla::Literal::scalar(pos);
        let mut args: Vec<&xla::Literal> = vec![&tok_lit, &pos_lit, kcache, vcache];
        args.extend(self.params.iter());
        let result = self.decode_exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 3, "expected (logits, K, V), got {}", outs.len());
        let mut it = outs.into_iter();
        let logits_lit = it.next().unwrap();
        let kcache = it.next().unwrap();
        let vcache = it.next().unwrap();
        Ok(StepOutput { logits: logits_lit.to_vec::<f32>()?, rows: 1, kcache, vcache })
    }

    /// Greedy argmax over a logits row.
    pub fn argmax_row(&self, logits: &[f32], row: usize) -> usize {
        let v = self.meta.vocab;
        let slice = &logits[row * v..(row + 1) * v];
        slice
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Golden tensors for self-check (prompt, expected logits, greedy ids).
    pub fn golden(&self) -> anyhow::Result<(Tensor, Tensor, Tensor)> {
        let g = |n: &str| leapbin::load(self.artifacts_dir.join("golden").join(n));
        Ok((g("prompt.bin")?, g("prefill_logits.bin")?, g("greedy_tokens.bin")?))
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let text = "vocab=512\nd_model=256\nn_layers=4\nn_heads=4\nn_kv_heads=4\n\
                    d_ff=512\nxb=128\nshard=16\ns_prefill=32\ns_max=128\n\
                    golden_prompt_len=8\ngolden_steps=8\nparam_order=a,b,c\n";
        let m = ArtifactMeta::parse(text).unwrap();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.s_max, 128);
        assert_eq!(m.param_order, vec!["a", "b", "c"]);
    }

    #[test]
    fn meta_parse_rejects_missing() {
        assert!(ArtifactMeta::parse("vocab=1\n").is_err());
    }
    // Engine execution itself is covered by tests/integration_runtime.rs
    // (needs the artifacts directory built by `make artifacts`).
}
