//! Fast CPU kernels for the reference backend's hot path.
//!
//! The software analogue of LEAP's weight-stationary PIM dataflow: every
//! matmul here streams each weight row through the core exactly once and
//! amortises it over as many activation rows as the caller can batch
//! (whole-prompt prefill, multi-session decode). Design points:
//!
//! - **Transposed weight layout.** Weights are stored `[n, k]` (one
//!   contiguous row per *output* column), so `y[n] = dot(x, wt[n])` is a
//!   pure streaming read with no read-modify-write of `y` — the crossbar
//!   column-read access pattern, and the layout auto-vectorisers like.
//! - **Fixed-order lane accumulation.** [`dot`] accumulates into 8
//!   independent lanes and reduces them in index order, so every call with
//!   the same inputs produces the same bits on every code path — the
//!   bitwise `decode_batch` ≡ sequential `decode_step` contract rests on
//!   this.
//! - **Weight-stationary multi-row GEMM.** [`gemm_t`]/[`gemm_q8`] iterate
//!   weight rows in the *outer* loop: one pass over `W` serves every
//!   activation row, which is what makes batched decode sublinear in batch
//!   size.
//! - **Persistent-pool parallelism, zero deps.** Every parallel kernel
//!   dispatches fixed-ownership tile bands onto the backend's resident
//!   [`WorkerPool`] (`runtime::pool`) — no thread is ever spawned on the
//!   hot path. Small calls stay serial behind the pool's work threshold,
//!   so tiny models never pay a dispatch.
//! - **Fused per-layer pipeline.** [`gemm_q8_qkv`] computes all three
//!   attention projections in one pass over the activations,
//!   [`gemm_q8_swiglu`] streams the gate and up matrices side by side and
//!   applies SiLU in-register, [`add_residual_rmsnorm`] folds the residual
//!   add into the next norm's sweep, and [`attention_rows_paged`] is a
//!   flash-style online-softmax kernel that walks `BlockTable` blocks in
//!   place (no gathered K/V copy, no score buffer). A decode layer is a
//!   handful of pool dispatches instead of a dozen fork-join barriers.
//! - **No per-token tensor allocation.** [`Scratch`] owns every
//!   intermediate tensor buffer and only ever grows; [`RopeTable`]
//!   precomputes the rotary sin/cos so the steady-state decode loop does
//!   no trig.
//!
//! Every fused kernel preserves the per-element expression of its unfused
//! ancestors exactly (same operand order, same reduction order), so row
//! `i` of any multi-row call is bit-identical to a batch containing only
//! row `i` — fusion never moves the numerics. The one deliberate
//! arithmetic change of this layer is [`attention_rows_paged`]'s online
//! softmax (a running max/denominator instead of the two-pass
//! max-subtract): it is deterministic and layout/band invariant, but
//! differs from the two-pass oracle in final-bit rounding, which the
//! parity tests treat as a ≤1e-5 comparison rather than a bitwise one.
//!
//! The [`naive`] submodule retains the pre-optimisation scalar kernels
//! verbatim. They are the parity oracle for the fast path
//! (`tests/integration_kernels.rs`) and the baseline the decode-throughput
//! bench (`benches/bench_hotpath.rs`) measures speedups against.

use std::ops::Range;

use super::pool::{SharedSliceMut, WorkerPool};
use crate::kvcache::store::{f16_to_f32, KvView};

/// RMSNorm epsilon (matches `python/compile/kernels/ref.py`).
pub const RMS_EPS: f32 = 1e-5;
/// Rotary embedding base (matches the python oracle).
pub const ROPE_THETA: f64 = 10000.0;

/// Dot product with 8 fixed accumulator lanes reduced in index order.
///
/// The lane structure gives the auto-vectoriser independent dependency
/// chains; the fixed reduction order makes the result a pure function of
/// the inputs (same bits from `matvec_t`, `gemm_t`, serial or pooled).
/// Dispatches to the explicit SIMD paths in [`crate::runtime::simd`]
/// (AVX2/NEON probe, `LEAP_SIMD=0` forces scalar) — every path reproduces
/// the 8-lane order exactly, so the dispatch level never changes the bits.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    super::simd::dot(a, b)
}

/// `y = x @ W` for one activation row against a *transposed* weight matrix
/// `wt: [n, k]` (row `n` of `wt` is output column `n`). Large calls split
/// the output columns across pool lanes; each column's arithmetic is
/// identical either way.
pub fn matvec_t(pool: &WorkerPool, x: &[f32], wt: &[f32], k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(wt.len(), k * n);
    debug_assert_eq!(y.len(), n);
    let lanes = pool.lanes_for(k * n);
    if lanes <= 1 {
        for (yv, wrow) in y.iter_mut().zip(wt.chunks_exact(k)) {
            *yv = dot(x, wrow);
        }
        return;
    }
    let out = SharedSliceMut::new(y);
    pool.run_tiles_bounded(0..n, lanes, |cols| {
        // SAFETY: tile bands are disjoint column ranges.
        let yb = unsafe { out.borrow_range(cols.clone()) };
        for (yv, nn) in yb.iter_mut().zip(cols) {
            *yv = dot(x, &wt[nn * k..(nn + 1) * k]);
        }
    });
}

/// Weight-stationary multi-row GEMM: `y[rows, n] = x[rows, k] @ W` with
/// `wt: [n, k]` transposed. The weight row is the **outer** loop, so one
/// pass over `W` serves every activation row — batching activation rows
/// (prompt tokens, decode sessions) amortises the whole weight stream.
///
/// Row `r` of the result is bit-identical to `matvec_t` on row `r` alone:
/// each output element is one [`dot`] call either way. Large calls split
/// the output *columns* across pool lanes (every lane keeps the
/// weight-stationary inner structure over its column band, and the full
/// weight stream is paid once across the pool, not once per lane).
pub fn gemm_t(
    pool: &WorkerPool,
    x: &[f32],
    wt: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(wt.len(), k * n);
    debug_assert_eq!(y.len(), rows * n);
    if rows == 1 {
        return matvec_t(pool, x, wt, k, n, y);
    }
    let lanes = pool.lanes_for(rows * k * n);
    if lanes <= 1 {
        for (nn, wrow) in wt.chunks_exact(k).enumerate() {
            for (r, xrow) in x.chunks_exact(k).enumerate() {
                y[r * n + nn] = dot(xrow, wrow);
            }
        }
        return;
    }
    let out = SharedSliceMut::new(y);
    pool.run_tiles_bounded(0..n, lanes, |cols| {
        for nn in cols {
            let wrow = &wt[nn * k..(nn + 1) * k];
            for (r, xrow) in x.chunks_exact(k).enumerate() {
                // SAFETY: column `nn` is owned exclusively by this band.
                unsafe { out.write(r * n + nn, dot(xrow, wrow)) };
            }
        }
    });
}

/// A quantised matrix in fast-kernel layout: the int8 crossbar cells,
/// transposed `[n, k]`, plus the per-tile scales in their original
/// `[k/xb, n/xb]` orientation. The q8 kernels stream the cells directly —
/// 4× less weight traffic than dequantised f32, which is what decode
/// throughput is bound by — and fold the scale in per k-tile:
/// `y[n] = Σ_kt s[kt, n/xb] · Σ_{k∈kt} x[k]·q[k, n]`.
pub struct QMat {
    /// int8 cells, transposed row-major `[n, k]`.
    pub q: Vec<i8>,
    /// per-tile scales, row-major `[k/xb, n/xb]`.
    pub s: Vec<f32>,
    pub k: usize,
    pub n: usize,
    /// crossbar tile edge (tiles are `xb × xb`).
    pub xb: usize,
}

impl QMat {
    /// Build from a row-major `[k, n]` cell blob (raw bytes reinterpreted
    /// as i8, the artifact encoding) and its scale slice.
    pub fn from_cells(cells: &[u8], scales: &[f32], k: usize, n: usize, xb: usize) -> Self {
        // Hard preconditions (not debug-only): the q8 kernels tile both
        // axes by `xb`, so a ragged edge would index scales out of bounds.
        assert!(xb > 0 && k % xb == 0 && n % xb == 0, "k={k}, n={n} must be multiples of xb={xb}");
        assert_eq!(cells.len(), k * n);
        assert_eq!(scales.len(), (k / xb) * (n / xb));
        let mut q = vec![0i8; k * n];
        for (ki, row) in cells.chunks_exact(n).enumerate() {
            for (ni, &c) in row.iter().enumerate() {
                q[ni * k + ki] = c as i8;
            }
        }
        Self { q, s: scales.to_vec(), k, n, xb }
    }

    /// Dense dequantised f32 in the original `[k, n]` layout
    /// (`w[k][n] = q[k][n] * s[k/xb][n/xb]`) — the naive path's view of
    /// this matrix; used by the parity tests.
    pub fn dequant_dense(&self) -> Vec<f32> {
        let nt = self.n / self.xb;
        let mut w = vec![0f32; self.k * self.n];
        for k in 0..self.k {
            for n in 0..self.n {
                let s = self.s[(k / self.xb) * nt + n / self.xb];
                w[k * self.n + n] = self.q[n * self.k + k] as f32 * s;
            }
        }
        w
    }
}

/// Dot product of an f32 activation tile against int8 cells, with the
/// same 8-lane fixed-order accumulation as [`dot`] (the cells are
/// sign-extended to f32 in-register; no dequantised copy ever exists).
/// SIMD-dispatched like [`dot`]; bitwise identical at every level.
#[inline]
pub fn dot_q8(a: &[f32], b: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    super::simd::dot_q8(a, b)
}

/// One output band of [`matvec_q8`]: columns `n0 .. n0 + y.len()`.
fn matvec_q8_band(x: &[f32], m: &QMat, n0: usize, y: &mut [f32]) {
    let (k, xb) = (m.k, m.xb);
    let nt = m.n / xb;
    for (i, yv) in y.iter_mut().enumerate() {
        let n = n0 + i;
        let wrow = &m.q[n * k..(n + 1) * k];
        let mut acc = 0f32;
        for (kt, xtile) in x.chunks(xb).enumerate() {
            let partial = dot_q8(xtile, &wrow[kt * xb..kt * xb + xtile.len()]);
            acc += m.s[kt * nt + n / xb] * partial;
        }
        *yv = acc;
    }
}

/// `y = x @ W` for one activation row against a quantised matrix,
/// streaming the int8 cells directly. Column-banded across pool lanes like
/// [`matvec_t`]; per-column arithmetic is identical on every path.
pub fn matvec_q8(pool: &WorkerPool, x: &[f32], m: &QMat, y: &mut [f32]) {
    debug_assert_eq!(x.len(), m.k);
    debug_assert_eq!(y.len(), m.n);
    let lanes = pool.lanes_for(m.k * m.n);
    if lanes <= 1 {
        return matvec_q8_band(x, m, 0, y);
    }
    let out = SharedSliceMut::new(y);
    pool.run_tiles_bounded(0..m.n, lanes, |cols| {
        // SAFETY: tile bands are disjoint column ranges.
        let yb = unsafe { out.borrow_range(cols.clone()) };
        matvec_q8_band(x, m, cols.start, yb);
    });
}

/// Columns `cols` of the weight-stationary q8 GEMM `y[rows, n] = x @ W`:
/// the column (weight row + scale column) is the outer loop, so the int8
/// stream is paid once for every activation row. Writes only the
/// `(r, nn)` cells with `nn ∈ cols` — the caller hands each band a
/// disjoint column range.
fn gemm_q8_cols(x: &[f32], m: &QMat, rows: usize, cols: Range<usize>, out: &SharedSliceMut<f32>) {
    let (k, n, xb) = (m.k, m.n, m.xb);
    debug_assert_eq!(x.len(), rows * k);
    let nt = n / xb;
    for nn in cols {
        let wrow = &m.q[nn * k..(nn + 1) * k];
        let scol = nn / xb;
        for (r, xrow) in x.chunks_exact(k).enumerate() {
            let mut acc = 0f32;
            for (kt, xtile) in xrow.chunks(xb).enumerate() {
                let partial = dot_q8(xtile, &wrow[kt * xb..kt * xb + xtile.len()]);
                acc += m.s[kt * nt + scol] * partial;
            }
            // SAFETY: column `nn` is owned exclusively by this band.
            unsafe { out.write(r * n + nn, acc) };
        }
    }
}

/// Weight-stationary multi-row GEMM over a quantised matrix:
/// `y[rows, n] = x[rows, k] @ W`. Row `r` is bit-identical to
/// [`matvec_q8`] on row `r` alone (same per-element tile order). Large
/// calls split the output columns across pool lanes.
pub fn gemm_q8(pool: &WorkerPool, x: &[f32], m: &QMat, rows: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * m.k);
    debug_assert_eq!(y.len(), rows * m.n);
    if rows == 1 {
        return matvec_q8(pool, x, m, y);
    }
    let lanes = pool.lanes_for(rows * m.k * m.n);
    let out = SharedSliceMut::new(y);
    if lanes <= 1 {
        return gemm_q8_cols(x, m, rows, 0..m.n, &out);
    }
    pool.run_tiles_bounded(0..m.n, lanes, |cols| gemm_q8_cols(x, m, rows, cols, &out));
}

/// Fused Q/K/V projection: one tile pipeline computes `q = x@Wq`,
/// `k = x@Wk`, `v = x@Wv` (each `[rows, n]`) under a **single** pool
/// dispatch — each column band streams its slice of all three weight
/// matrices while the activation rows are hot. Every output element is
/// exactly the [`matvec_q8`] expression, so the fusion is bit-identical
/// to three separate GEMMs.
#[allow(clippy::too_many_arguments)]
pub fn gemm_q8_qkv(
    pool: &WorkerPool,
    x: &[f32],
    wq: &QMat,
    wk: &QMat,
    wv: &QMat,
    rows: usize,
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
) {
    let n = wq.n;
    debug_assert!(wk.n == n && wv.n == n && wk.k == wq.k && wv.k == wq.k);
    debug_assert_eq!(x.len(), rows * wq.k);
    debug_assert!(q.len() == rows * n && k.len() == rows * n && v.len() == rows * n);
    let lanes = pool.lanes_for(3 * rows * wq.k * n);
    let qo = SharedSliceMut::new(q);
    let ko = SharedSliceMut::new(k);
    let vo = SharedSliceMut::new(v);
    let run = |cols: Range<usize>| {
        gemm_q8_cols(x, wq, rows, cols.clone(), &qo);
        gemm_q8_cols(x, wk, rows, cols.clone(), &ko);
        gemm_q8_cols(x, wv, rows, cols, &vo);
    };
    if lanes <= 1 {
        return run(0..n);
    }
    pool.run_tiles_bounded(0..n, lanes, run);
}

/// Fused SwiGLU: `out[r, j] = silu((x@Wgate)[r, j]) · (x@Wup)[r, j]` in
/// one weight-stationary pass and a single pool dispatch. The gate and up
/// columns stream side by side, and the SiLU·mul combine happens
/// in-register — the unfused pipeline's `up` buffer (written once, read
/// once) never exists. Per element this is exactly
/// `silu_mul(gemm_q8(Wgate), gemm_q8(Wup))`, so the fusion is
/// bit-identical to the unfused pipeline.
pub fn gemm_q8_swiglu(
    pool: &WorkerPool,
    x: &[f32],
    w_gate: &QMat,
    w_up: &QMat,
    rows: usize,
    out: &mut [f32],
) {
    let (k, n, xb) = (w_gate.k, w_gate.n, w_gate.xb);
    debug_assert!(w_up.k == k && w_up.n == n && w_up.xb == xb);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * n);
    let lanes = pool.lanes_for(2 * rows * k * n);
    let o = SharedSliceMut::new(out);
    let nt = n / xb;
    let run = |cols: Range<usize>| {
        for nn in cols {
            let grow = &w_gate.q[nn * k..(nn + 1) * k];
            let urow = &w_up.q[nn * k..(nn + 1) * k];
            let scol = nn / xb;
            for (r, xrow) in x.chunks_exact(k).enumerate() {
                let mut g = 0f32;
                let mut u = 0f32;
                for (kt, xtile) in xrow.chunks(xb).enumerate() {
                    let span = kt * xb..kt * xb + xtile.len();
                    g += w_gate.s[kt * nt + scol] * dot_q8(xtile, &grow[span.clone()]);
                    u += w_up.s[kt * nt + scol] * dot_q8(xtile, &urow[span]);
                }
                // SAFETY: column `nn` is owned exclusively by this band.
                unsafe { o.write(r * n + nn, g / (1.0 + (-g).exp()) * u) };
            }
        }
    };
    if lanes <= 1 {
        return run(0..n);
    }
    pool.run_tiles_bounded(0..n, lanes, run);
}

/// Transpose a row-major `[k, n]` matrix into `[n, k]` (the layout the
/// fast kernels want; done once at weight-load time).
pub fn transpose(w: &[f32], k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), k * n);
    let mut t = vec![0f32; w.len()];
    for (ki, row) in w.chunks_exact(n).enumerate() {
        for (ni, &v) in row.iter().enumerate() {
            t[ni * k + ki] = v;
        }
    }
    t
}

/// RMSNorm into a caller-provided buffer (no allocation on the hot path).
/// Same accumulation order as [`naive::rmsnorm`], so the value is
/// bit-identical.
pub fn rmsnorm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), out.len());
    let mut sq = 0f32;
    for &v in x {
        sq += v * v;
    }
    let inv = 1.0 / (sq / x.len() as f32 + RMS_EPS).sqrt();
    for ((o, &v), &gv) in out.iter_mut().zip(x).zip(g) {
        *o = v * inv * gv;
    }
}

/// Fused residual-add + RMSNorm for one row: `x += res`, then
/// `out = rmsnorm(x) · g`, folding the residual into the norm's sweep over
/// the row. Element order is add-then-square, sequentially — exactly a
/// separate residual loop followed by [`rmsnorm_into`], so the fusion is
/// bit-identical.
pub fn add_residual_rmsnorm(x: &mut [f32], res: &[f32], g: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), res.len());
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), out.len());
    let mut sq = 0f32;
    for (xv, &rv) in x.iter_mut().zip(res) {
        *xv += rv;
        sq += *xv * *xv;
    }
    let inv = 1.0 / (sq / x.len() as f32 + RMS_EPS).sqrt();
    for ((o, &v), &gv) in out.iter_mut().zip(x.iter()).zip(g) {
        *o = v * inv * gv;
    }
}

/// SwiGLU combine in place: `gate[i] = silu(gate[i]) * up[i]` (same
/// expression as the naive path and [`gemm_q8_swiglu`], so bit-identical).
pub fn silu_mul(gate: &mut [f32], up: &[f32]) {
    debug_assert_eq!(gate.len(), up.len());
    for (g, &u) in gate.iter_mut().zip(up) {
        let gv = *g;
        *g = gv / (1.0 + (-gv).exp()) * u;
    }
}

/// Precomputed rotary-embedding tables: `sin/cos[pos * half + j]` for every
/// position below `s_max`, computed with exactly the naive path's
/// arithmetic (f64 `powf`, f32 angle) so table lookups reproduce its bits
/// while eliminating all steady-state trig.
pub struct RopeTable {
    sin: Vec<f32>,
    cos: Vec<f32>,
    half: usize,
}

impl RopeTable {
    pub fn new(s_max: usize, d_head: usize, theta: f64) -> Self {
        let half = d_head / 2;
        let mut sin = vec![0f32; s_max * half];
        let mut cos = vec![0f32; s_max * half];
        for pos in 0..s_max {
            for j in 0..half {
                let freq = (1.0 / theta.powf(j as f64 / half as f64)) as f32;
                let ang = pos as f32 * freq;
                sin[pos * half + j] = ang.sin();
                cos[pos * half + j] = ang.cos();
            }
        }
        Self { sin, cos, half }
    }

    /// Positions this table covers (`s_max` at construction).
    pub fn positions(&self) -> usize {
        if self.half == 0 {
            0
        } else {
            self.sin.len() / self.half
        }
    }

    /// In-place rotary embedding at `pos` over merged heads (half-split
    /// rotation per head, matching [`naive::rope`] bit for bit).
    pub fn apply(&self, x: &mut [f32], pos: usize, n_heads: usize, d_head: usize) {
        debug_assert_eq!(d_head / 2, self.half);
        debug_assert!(pos < self.positions(), "rope table too small for pos {pos}");
        let half = self.half;
        let sin = &self.sin[pos * half..(pos + 1) * half];
        let cos = &self.cos[pos * half..(pos + 1) * half];
        for h in 0..n_heads {
            let base = h * d_head;
            for j in 0..half {
                let (s, c) = (sin[j], cos[j]);
                let (x1, x2) = (x[base + j], x[base + half + j]);
                x[base + j] = x1 * c - x2 * s;
                x[base + half + j] = x1 * s + x2 * c;
            }
        }
    }
}

/// Causal attention for one query row against a `[ctx, d]` KV cache slice
/// (merged-head layout, `d = n_heads * d_head`). `scores` is a scratch
/// buffer of at least `ctx` entries; `o` receives the `[d]` output.
///
/// Serial, two-pass (max-subtracted exp, deferred denominator divide) —
/// the structural oracle the flash kernel [`attention_rows_paged`] is
/// parity-tested against. Not on the hot path.
#[allow(clippy::too_many_arguments)]
pub fn attention_row(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    ctx: usize,
    n_heads: usize,
    d_head: usize,
    d: usize,
    scores: &mut [f32],
    o: &mut [f32],
) {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(o.len(), d);
    debug_assert!(kcache.len() >= ctx * d && vcache.len() >= ctx * d);
    debug_assert!(scores.len() >= ctx);
    for (h, oh) in o.chunks_exact_mut(d_head).enumerate() {
        head_attention(q, kcache, vcache, ctx, h, d_head, d, &mut scores[..ctx], oh);
    }
}

/// One head of [`attention_row`] (softmax(q·Kᵀ)·V over `ctx` positions).
#[allow(clippy::too_many_arguments)]
fn head_attention(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    ctx: usize,
    h: usize,
    d_head: usize,
    d: usize,
    scores: &mut [f32],
    oh: &mut [f32],
) {
    let base = h * d_head;
    let scale = 1.0 / (d_head as f32).sqrt();
    let qh = &q[base..base + d_head];
    let mut max = f32::NEG_INFINITY;
    for (j, sc) in scores[..ctx].iter_mut().enumerate() {
        let krow = &kcache[j * d + base..j * d + base + d_head];
        *sc = dot(qh, krow) * scale;
        max = max.max(*sc);
    }
    let mut denom = 0f32;
    for sc in scores[..ctx].iter_mut() {
        *sc = (*sc - max).exp();
        denom += *sc;
    }
    oh.fill(0.0);
    for (j, &p) in scores[..ctx].iter().enumerate() {
        let vrow = &vcache[j * d + base..j * d + base + d_head];
        for (ov, &vv) in oh.iter_mut().zip(vrow) {
            *ov += p * vv;
        }
    }
    for ov in oh.iter_mut() {
        *ov /= denom;
    }
}

/// Flash-style causal attention for a whole batch of query rows over the
/// *paged* KV cache, in one pool dispatch.
///
/// `q`/`o` are `[rows, d]` (merged heads); `rows_meta[i] = (off, ctx)`
/// gives row `i`'s context length and the offset of its session's
/// block-start table inside `starts_flat` (arena offsets valid for both
/// the K and V arenas, `ceil(ctx / block_size)` entries per row; sessions
/// sharing a table share one entry run). Position `j` of a row lives at
/// arena offset `starts[j / block_size] + (j % block_size) * d`.
///
/// The tile space is `rows × n_heads`; each `(row, head)` tile runs an
/// online-softmax pass that walks the blocks **in place** — no gathered
/// K/V copy, no score buffer, one read of K and V per position. Tiles are
/// mutually independent and each is serial inside, so the output is
/// bitwise invariant across pool sizes and block layouts, and row `i` is
/// bit-identical to a dispatch containing only row `i` (the batched ≡
/// sequential decode contract).
#[allow(clippy::too_many_arguments)]
pub fn attention_rows_paged(
    pool: &WorkerPool,
    q: &[f32],
    karena: &[f32],
    varena: &[f32],
    starts_flat: &[usize],
    rows_meta: &[(usize, usize)],
    block_size: usize,
    n_heads: usize,
    d_head: usize,
    d: usize,
    o: &mut [f32],
) {
    let rows = rows_meta.len();
    debug_assert_eq!(q.len(), rows * d);
    debug_assert_eq!(o.len(), rows * d);
    debug_assert_eq!(n_heads * d_head, d);
    debug_assert!(block_size > 0);
    let total_ctx: usize = rows_meta.iter().map(|&(_, c)| c).sum();
    // ~2·d MACs per cached position (q·K plus p·V across the heads).
    let lanes = pool.lanes_for(2 * total_ctx * d);
    let out = SharedSliceMut::new(o);
    let run = |tiles: Range<usize>| {
        for t in tiles {
            // Row-interleaved tile order (row = t % rows, not t / heads):
            // a prefill batch has ctx ascending 1..s, so contiguous
            // equal-count bands of row-major tiles would hand the last
            // lane ~2× the mean work. Interleaving gives every band a
            // mix of short and long contexts. Still a fixed bijection —
            // ownership and bits are unchanged by the traversal order.
            let (row, h) = (t % rows, t / rows);
            let (off, ctx) = rows_meta[row];
            let starts = &starts_flat[off..off + ctx.div_ceil(block_size)];
            let base = h * d_head;
            let qh = &q[row * d + base..row * d + base + d_head];
            // SAFETY: tile (row, h) exclusively owns this d_head slice.
            let oh = unsafe { out.borrow_range(row * d + base..row * d + base + d_head) };
            head_attention_flash(qh, karena, varena, starts, block_size, ctx, base, d, oh);
        }
    };
    if lanes <= 1 {
        return run(0..rows * n_heads);
    }
    pool.run_tiles_bounded(0..rows * n_heads, lanes, run);
}

/// One `(row, head)` tile of [`attention_rows_paged`]: online softmax with
/// a running max/denominator, walking the context's blocks in place.
#[allow(clippy::too_many_arguments)]
fn head_attention_flash(
    qh: &[f32],
    karena: &[f32],
    varena: &[f32],
    starts: &[usize],
    block_size: usize,
    ctx: usize,
    base: usize,
    d: usize,
    oh: &mut [f32],
) {
    debug_assert!(ctx > 0 && starts.len() * block_size >= ctx);
    let dh = qh.len();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut m = f32::NEG_INFINITY;
    let mut denom = 0f32;
    oh.fill(0.0);
    let mut j = 0usize;
    for &bstart in starts {
        let in_block = block_size.min(ctx - j);
        for row in 0..in_block {
            let at = bstart + row * d + base;
            let s = dot(qh, &karena[at..at + dh]) * scale;
            if s > m {
                // New running max: rescale the accumulated numerator and
                // denominator (first position: m = -inf ⇒ factor 0 on
                // zeroed accumulators).
                let c = (m - s).exp();
                denom *= c;
                for ov in oh.iter_mut() {
                    *ov *= c;
                }
                m = s;
            }
            let p = (s - m).exp();
            denom += p;
            let vrow = &varena[at..at + dh];
            for (ov, &vv) in oh.iter_mut().zip(vrow) {
                *ov += p * vv;
            }
        }
        j += in_block;
        if j >= ctx {
            break;
        }
    }
    for ov in oh.iter_mut() {
        *ov /= denom;
    }
}

/// Widest `d_head` the quantized attention readers support (stack-buffer
/// bound for the f16 dequant tile; 13B-class models use 128).
pub const MAX_D_HEAD: usize = 512;

/// [`attention_rows_paged`] over dtype-tagged KV arenas. The
/// [`KvView::F32`] case routes to the untyped kernel and is bitwise
/// identical to it; f16 dequantizes each K row into a stack tile before
/// the dot, and q8 scores run [`dot_q8`] directly on the stored cells
/// (per-row scale folded into the softmax logit) — quantized attention
/// never materialises a dequantized K/V copy larger than one row.
#[allow(clippy::too_many_arguments)]
pub fn attention_rows_paged_kv(
    pool: &WorkerPool,
    q: &[f32],
    k: KvView<'_>,
    v: KvView<'_>,
    starts_flat: &[usize],
    rows_meta: &[(usize, usize)],
    block_size: usize,
    n_heads: usize,
    d_head: usize,
    d: usize,
    o: &mut [f32],
) {
    if let (KvView::F32(ka), KvView::F32(va)) = (k, v) {
        return attention_rows_paged(
            pool, q, ka, va, starts_flat, rows_meta, block_size, n_heads, d_head, d, o,
        );
    }
    let rows = rows_meta.len();
    debug_assert_eq!(q.len(), rows * d);
    debug_assert_eq!(o.len(), rows * d);
    debug_assert_eq!(n_heads * d_head, d);
    debug_assert!(block_size > 0);
    assert!(d_head <= MAX_D_HEAD, "d_head {d_head} exceeds MAX_D_HEAD");
    let total_ctx: usize = rows_meta.iter().map(|&(_, c)| c).sum();
    let lanes = pool.lanes_for(2 * total_ctx * d);
    let out = SharedSliceMut::new(o);
    // Same row-interleaved tile bijection as the f32 kernel (see there).
    let run = |tiles: Range<usize>| {
        for t in tiles {
            let (row, h) = (t % rows, t / rows);
            let (off, ctx) = rows_meta[row];
            let starts = &starts_flat[off..off + ctx.div_ceil(block_size)];
            let base = h * d_head;
            let qh = &q[row * d + base..row * d + base + d_head];
            // SAFETY: tile (row, h) exclusively owns this d_head slice.
            let oh = unsafe { out.borrow_range(row * d + base..row * d + base + d_head) };
            match (k, v) {
                (KvView::F16(ka), KvView::F16(va)) => {
                    head_attention_flash_f16(qh, ka, va, starts, block_size, ctx, base, d, oh);
                }
                (KvView::Q8 { q: kq, s: ks }, KvView::Q8 { q: vq, s: vs }) => {
                    head_attention_flash_q8(
                        qh, kq, ks, vq, vs, starts, block_size, ctx, base, d, oh,
                    );
                }
                _ => unreachable!("K and V arenas always share one dtype"),
            }
        }
    };
    if lanes <= 1 {
        return run(0..rows * n_heads);
    }
    pool.run_tiles_bounded(0..rows * n_heads, lanes, run);
}

/// [`head_attention_flash`] over f16 arenas: each K row's head slice is
/// dequantized into a stack tile (exact conversion), then the walk is
/// identical to the f32 kernel; V accumulates converted-per-element.
#[allow(clippy::too_many_arguments)]
fn head_attention_flash_f16(
    qh: &[f32],
    karena: &[u16],
    varena: &[u16],
    starts: &[usize],
    block_size: usize,
    ctx: usize,
    base: usize,
    d: usize,
    oh: &mut [f32],
) {
    debug_assert!(ctx > 0 && starts.len() * block_size >= ctx);
    let dh = qh.len();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut kbuf = [0f32; MAX_D_HEAD];
    let mut m = f32::NEG_INFINITY;
    let mut denom = 0f32;
    oh.fill(0.0);
    let mut j = 0usize;
    for &bstart in starts {
        let in_block = block_size.min(ctx - j);
        for row in 0..in_block {
            let at = bstart + row * d + base;
            for (x, &hb) in kbuf[..dh].iter_mut().zip(&karena[at..at + dh]) {
                *x = f16_to_f32(hb);
            }
            let s = dot(qh, &kbuf[..dh]) * scale;
            if s > m {
                let c = (m - s).exp();
                denom *= c;
                for ov in oh.iter_mut() {
                    *ov *= c;
                }
                m = s;
            }
            let p = (s - m).exp();
            denom += p;
            for (ov, &hb) in oh.iter_mut().zip(&varena[at..at + dh]) {
                *ov += p * f16_to_f32(hb);
            }
        }
        j += in_block;
        if j >= ctx {
            break;
        }
    }
    for ov in oh.iter_mut() {
        *ov /= denom;
    }
}

/// [`head_attention_flash`] over q8 arenas: scores are `dot_q8` on the
/// stored int8 K cells with the per-row scale folded into the logit, and
/// the V accumulation folds `p * v_scale` into one factor per position —
/// the attention walk reads one byte per cached element.
#[allow(clippy::too_many_arguments)]
fn head_attention_flash_q8(
    qh: &[f32],
    kq: &[i8],
    ks: &[f32],
    vq: &[i8],
    vs: &[f32],
    starts: &[usize],
    block_size: usize,
    ctx: usize,
    base: usize,
    d: usize,
    oh: &mut [f32],
) {
    debug_assert!(ctx > 0 && starts.len() * block_size >= ctx);
    let dh = qh.len();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut m = f32::NEG_INFINITY;
    let mut denom = 0f32;
    oh.fill(0.0);
    let mut j = 0usize;
    for &bstart in starts {
        let in_block = block_size.min(ctx - j);
        for row in 0..in_block {
            let rowstart = bstart + row * d;
            let at = rowstart + base;
            let s = dot_q8(qh, &kq[at..at + dh]) * ks[rowstart / d] * scale;
            if s > m {
                let c = (m - s).exp();
                denom *= c;
                for ov in oh.iter_mut() {
                    *ov *= c;
                }
                m = s;
            }
            let p = (s - m).exp();
            denom += p;
            let pv = p * vs[rowstart / d];
            for (ov, &qv) in oh.iter_mut().zip(&vq[at..at + dh]) {
                *ov += pv * qv as f32;
            }
        }
        j += in_block;
        if j >= ctx {
            break;
        }
    }
    for ov in oh.iter_mut() {
        *ov /= denom;
    }
}

/// Grow-only scratch arena for the forward pass: one allocation family at
/// the first call of each batch width, no tensor allocations in the
/// steady state. Buffers are sized for `rows` activation rows of a
/// `(d_model, d_ff)` model.
#[derive(Default)]
pub struct Scratch {
    /// Residual stream `[rows, d]`.
    pub x: Vec<f32>,
    /// Normed activations `[rows, d]`.
    pub xn: Vec<f32>,
    /// Attention projections `[rows, d]` each.
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Attention output `[rows, d]`.
    pub o: Vec<f32>,
    /// Output-projection / MLP-down result `[rows, d]` (doubles as the
    /// pending residual folded into the next norm).
    pub proj: Vec<f32>,
    /// Fused SwiGLU result `[rows, ff]` (gate and up never materialise
    /// separately on the fast path).
    pub gate: Vec<f32>,
    /// Per-row cache position assigned this step `[rows]`.
    pub pos: Vec<usize>,
    /// Flat per-layer block-start table for every session in the batch
    /// (cleared and refilled per layer; grow-only capacity).
    pub block_starts: Vec<usize>,
    /// Per batch-session offset into [`Self::block_starts`].
    pub sess_starts: Vec<usize>,
    /// Per row `(starts offset, ctx)` for the fused attention dispatch.
    pub attn_rows: Vec<(usize, usize)>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure capacity for `rows` activation rows (grow-only).
    pub fn ensure(&mut self, rows: usize, d: usize, ff: usize) {
        let grow = |buf: &mut Vec<f32>, len: usize| {
            if buf.len() < len {
                buf.resize(len, 0.0);
            }
        };
        grow(&mut self.x, rows * d);
        grow(&mut self.xn, rows * d);
        grow(&mut self.q, rows * d);
        grow(&mut self.k, rows * d);
        grow(&mut self.v, rows * d);
        grow(&mut self.o, rows * d);
        grow(&mut self.proj, rows * d);
        grow(&mut self.gate, rows * ff);
        if self.pos.len() < rows {
            self.pos.resize(rows, 0);
        }
    }
}

/// The pre-optimisation scalar kernels, retained verbatim: the parity
/// oracle for the fast path and the baseline for the decode-throughput
/// bench. These allocate per call, branch on zero activations, and do trig
/// per token — exactly what the kernel layer exists to remove.
pub mod naive {
    use super::{RMS_EPS, ROPE_THETA};

    /// `y = x @ W` for one activation row: `x: [k]`, `w: [k, n]` row-major
    /// (NOT transposed — the original axpy walk).
    pub fn matvec(x: &[f32], w: &[f32], k: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), k);
        debug_assert_eq!(w.len(), k * n);
        let mut y = vec![0f32; n];
        for (ki, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w[ki * n..(ki + 1) * n];
            for (yv, &wv) in y.iter_mut().zip(row) {
                *yv += xv * wv;
            }
        }
        y
    }

    pub fn rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
        let mut sq = 0f32;
        for &v in x {
            sq += v * v;
        }
        let inv = 1.0 / (sq / x.len() as f32 + RMS_EPS).sqrt();
        x.iter().zip(g).map(|(&v, &gv)| v * inv * gv).collect()
    }

    /// In-place rotary embedding at `pos` over merged heads (half-split
    /// rotation per head, matching `ref.ref_rope`).
    pub fn rope(x: &mut [f32], pos: usize, n_heads: usize, d_head: usize) {
        let half = d_head / 2;
        for h in 0..n_heads {
            let base = h * d_head;
            for j in 0..half {
                let freq = (1.0 / ROPE_THETA.powf(j as f64 / half as f64)) as f32;
                let ang = pos as f32 * freq;
                let (sin, cos) = (ang.sin(), ang.cos());
                let (x1, x2) = (x[base + j], x[base + half + j]);
                x[base + j] = x1 * cos - x2 * sin;
                x[base + half + j] = x1 * sin + x2 * cos;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i % 17) as f32 - 8.0) * scale).collect()
    }

    /// Single-lane pool: kernels run serial (the structural baseline).
    fn pool1() -> WorkerPool {
        WorkerPool::with_threads(1)
    }

    #[test]
    fn dot_matches_sequential_sum() {
        for len in [0, 1, 7, 8, 9, 31, 64, 100] {
            let a = seq(len, 0.25);
            let b = seq(len, -0.5);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - want).abs() <= 1e-5 * (1.0 + want.abs()), "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn matvec_t_matches_naive_matvec() {
        // same matrix in both layouts: w [k,n] row-major, wt = transpose
        let (k, n) = (13, 9);
        let w = seq(k * n, 0.1);
        let wt = transpose(&w, k, n);
        let x = seq(k, 0.3);
        let want = naive::matvec(&x, &w, k, n);
        let mut got = vec![0f32; n];
        matvec_t(&pool1(), &x, &wt, k, n, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn gemm_rows_bitwise_equal_to_matvec() {
        let (rows, k, n) = (4, 24, 10);
        let x = seq(rows * k, 0.2);
        let wt = seq(n * k, -0.15);
        let pool = pool1();
        let mut y = vec![0f32; rows * n];
        gemm_t(&pool, &x, &wt, rows, k, n, &mut y);
        for r in 0..rows {
            let mut solo = vec![0f32; n];
            matvec_t(&pool, &x[r * k..(r + 1) * k], &wt, k, n, &mut solo);
            assert_eq!(&y[r * n..(r + 1) * n], &solo[..], "row {r} must be bit-identical");
        }
    }

    /// Deterministic pseudo-random i8 cells + scales for a [k, n] matrix.
    fn qmat(k: usize, n: usize, xb: usize) -> QMat {
        let cells: Vec<u8> = (0..k * n).map(|i| (i * 31 + 7) as u8).collect();
        let nt = (k / xb) * (n / xb);
        let scales: Vec<f32> = (0..nt).map(|i| 0.01 + 0.003 * (i % 5) as f32).collect();
        QMat::from_cells(&cells, &scales, k, n, xb)
    }

    #[test]
    fn dot_q8_matches_sequential_sum() {
        for len in [1, 7, 8, 9, 31, 64] {
            let a = seq(len, 0.25);
            let b: Vec<i8> = (0..len).map(|i| (i as i8).wrapping_mul(13)).collect();
            let want: f32 = a.iter().zip(&b).map(|(&x, &q)| x * q as f32).sum();
            let got = dot_q8(&a, &b);
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn qmat_transposes_cells() {
        // cells [k=2, n=2] row-major: [1, 2, 3, 0x80]; xb=1 scales per cell
        let m = QMat::from_cells(&[1, 2, 3, 0x80], &[1.0, 10.0, 100.0, 0.5], 2, 2, 1);
        // q is [n, k]: column n=0 holds cells (k=0,n=0)=1 and (k=1,n=0)=3
        assert_eq!(m.q, vec![1, 3, 2, -128]);
        assert_eq!(m.dequant_dense(), vec![1.0, 20.0, 300.0, -64.0]);
    }

    #[test]
    fn matvec_q8_matches_dense_naive_path() {
        let (k, n, xb) = (8, 12, 4);
        let m = qmat(k, n, xb);
        let dense = m.dequant_dense();
        let x = seq(k, 0.3);
        let want = naive::matvec(&x, &dense, k, n);
        let mut got = vec![0f32; n];
        matvec_q8(&pool1(), &x, &m, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn gemm_q8_rows_bitwise_equal_to_matvec_q8() {
        let (rows, k, n, xb) = (3, 8, 8, 4);
        let m = qmat(k, n, xb);
        let x = seq(rows * k, 0.2);
        let pool = pool1();
        let mut y = vec![0f32; rows * n];
        gemm_q8(&pool, &x, &m, rows, &mut y);
        for r in 0..rows {
            let mut solo = vec![0f32; n];
            matvec_q8(&pool, &x[r * k..(r + 1) * k], &m, &mut solo);
            assert_eq!(&y[r * n..(r + 1) * n], &solo[..], "row {r} must be bit-identical");
        }
    }

    /// Like [`qmat`] but seeded, so Q/K/V get distinct cell patterns.
    fn qmat_seeded(k: usize, n: usize, xb: usize, seed: usize) -> QMat {
        let cells: Vec<u8> = (0..k * n).map(|i| (i * 31 + 7 * seed + 3) as u8).collect();
        let nt = (k / xb) * (n / xb);
        let scales: Vec<f32> =
            (0..nt).map(|i| 0.01 + 0.003 * ((i + seed) % 5) as f32).collect();
        QMat::from_cells(&cells, &scales, k, n, xb)
    }

    #[test]
    fn fused_qkv_bitwise_equals_three_gemms() {
        let (rows, k, n, xb) = (3, 8, 8, 4);
        let wq = qmat_seeded(k, n, xb, 1);
        let wk = qmat_seeded(k, n, xb, 2);
        let wv = qmat_seeded(k, n, xb, 3);
        let x = seq(rows * k, 0.2);
        let pool = pool1();
        let (mut q, mut kk, mut v) =
            (vec![0f32; rows * n], vec![0f32; rows * n], vec![0f32; rows * n]);
        gemm_q8_qkv(&pool, &x, &wq, &wk, &wv, rows, &mut q, &mut kk, &mut v);
        let (mut q2, mut k2, mut v2) =
            (vec![0f32; rows * n], vec![0f32; rows * n], vec![0f32; rows * n]);
        gemm_q8(&pool, &x, &wq, rows, &mut q2);
        gemm_q8(&pool, &x, &wk, rows, &mut k2);
        gemm_q8(&pool, &x, &wv, rows, &mut v2);
        assert_eq!(q, q2, "fused Q must be bit-identical");
        assert_eq!(kk, k2, "fused K must be bit-identical");
        assert_eq!(v, v2, "fused V must be bit-identical");
    }

    #[test]
    fn fused_swiglu_bitwise_equals_unfused_pipeline() {
        let (rows, k, n, xb) = (2, 8, 12, 4);
        let w_gate = qmat(k, n, xb);
        let w_up = {
            let cells: Vec<u8> = (0..k * n).map(|i| (i * 13 + 5) as u8).collect();
            let nt = (k / xb) * (n / xb);
            let scales: Vec<f32> = (0..nt).map(|i| 0.02 + 0.001 * (i % 7) as f32).collect();
            QMat::from_cells(&cells, &scales, k, n, xb)
        };
        let x = seq(rows * k, 0.4);
        let pool = pool1();
        let mut fused = vec![0f32; rows * n];
        gemm_q8_swiglu(&pool, &x, &w_gate, &w_up, rows, &mut fused);
        let mut gate = vec![0f32; rows * n];
        let mut up = vec![0f32; rows * n];
        gemm_q8(&pool, &x, &w_gate, rows, &mut gate);
        gemm_q8(&pool, &x, &w_up, rows, &mut up);
        silu_mul(&mut gate, &up);
        assert_eq!(fused, gate, "fused SwiGLU must be bit-identical to gemm+gemm+silu_mul");
    }

    #[test]
    fn transpose_round_trips() {
        let (k, n) = (5, 7);
        let w = seq(k * n, 1.0);
        let wt = transpose(&w, k, n);
        assert_eq!(transpose(&wt, n, k), w);
        // spot-check one element: w[2][3] == wt[3][2]
        assert_eq!(w[2 * n + 3], wt[3 * k + 2]);
    }

    #[test]
    fn rmsnorm_into_bitwise_matches_naive() {
        let x = seq(32, 0.7);
        let g = seq(32, 0.4);
        let want = naive::rmsnorm(&x, &g);
        let mut got = vec![0f32; 32];
        rmsnorm_into(&x, &g, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn add_residual_rmsnorm_bitwise_matches_unfused() {
        let mut x = seq(48, 0.7);
        let res = seq(48, -0.2);
        let g = seq(48, 0.4);
        // unfused: residual loop, then rmsnorm
        let mut x_ref = x.clone();
        for (xv, &rv) in x_ref.iter_mut().zip(&res) {
            *xv += rv;
        }
        let mut want = vec![0f32; 48];
        rmsnorm_into(&x_ref, &g, &mut want);
        // fused
        let mut got = vec![0f32; 48];
        add_residual_rmsnorm(&mut x, &res, &g, &mut got);
        assert_eq!(got, want, "fused norm output must be bit-identical");
        assert_eq!(x, x_ref, "fused residual stream must be bit-identical");
    }

    #[test]
    fn rope_table_bitwise_matches_naive_rope() {
        let (heads, dh, s_max) = (3, 8, 16);
        let table = RopeTable::new(s_max, dh, ROPE_THETA);
        assert_eq!(table.positions(), s_max);
        for pos in [0usize, 1, 7, 15] {
            let mut a = seq(heads * dh, 0.9);
            let mut b = a.clone();
            table.apply(&mut a, pos, heads, dh);
            naive::rope(&mut b, pos, heads, dh);
            assert_eq!(a, b, "pos {pos}");
        }
    }

    #[test]
    fn silu_mul_matches_naive_expression() {
        let gate = seq(20, 0.6);
        let up = seq(20, -0.3);
        let want: Vec<f32> =
            gate.iter().zip(&up).map(|(&g, &u)| g / (1.0 + (-g).exp()) * u).collect();
        let mut got = gate.clone();
        silu_mul(&mut got, &up);
        assert_eq!(got, want);
    }

    #[test]
    fn scratch_grows_and_never_shrinks() {
        let mut s = Scratch::new();
        s.ensure(4, 16, 32);
        assert!(s.x.len() >= 64 && s.gate.len() >= 128);
        let cap = s.gate.len();
        s.ensure(2, 16, 32);
        assert_eq!(s.gate.len(), cap, "ensure with fewer rows must not shrink");
        s.ensure(8, 16, 32);
        assert!(s.gate.len() >= 8 * 32);
    }

    #[test]
    fn attention_row_uniform_values() {
        // uniform K/V: softmax is uniform, output equals the common V row
        let (heads, dh, ctx) = (2, 4, 3);
        let d = heads * dh;
        let q = seq(d, 0.5);
        let kcache = vec![1.0f32; ctx * d];
        let vcache: Vec<f32> = (0..ctx * d).map(|i| (i % d) as f32).collect();
        let mut scores = vec![0f32; ctx];
        let mut o = vec![0f32; d];
        attention_row(&q, &kcache, &vcache, ctx, heads, dh, d, &mut scores, &mut o);
        for (i, &ov) in o.iter().enumerate() {
            assert!((ov - i as f32).abs() < 1e-5, "o[{i}] = {ov}");
        }
    }

    use crate::testutil::scatter_blocks as scatter;

    #[test]
    fn flash_attention_matches_two_pass_oracle() {
        let (heads, dh, ctx, bs) = (3, 8, 11, 4);
        let d = heads * dh;
        let q = seq(d, 0.5);
        let kcache = seq(ctx * d, 0.3);
        let vcache = seq(ctx * d, -0.7);
        let mut scores = vec![0f32; ctx];
        let mut want = vec![0f32; d];
        attention_row(&q, &kcache, &vcache, ctx, heads, dh, d, &mut scores, &mut want);

        let (karena, varena, starts) = scatter(&kcache, &vcache, ctx, d, bs);
        let mut got = vec![0f32; d];
        attention_rows_paged(
            &pool1(),
            &q,
            &karena,
            &varena,
            &starts,
            &[(0, ctx)],
            bs,
            heads,
            dh,
            d,
            &mut got,
        );
        // online softmax vs two-pass: same value, last-bit rounding differs
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "o[{i}]: flash {a} vs two-pass {b}");
        }
    }

    #[test]
    fn flash_attention_is_block_layout_invariant_bitwise() {
        // The paged ≡ flat backend contract rests on this: the same cache
        // content must produce the same bits whether it lives in one big
        // block or many scattered small ones.
        let (heads, dh, ctx) = (2, 8, 13);
        let d = heads * dh;
        let q = seq(d, 0.5);
        let kcache = seq(ctx * d, 0.3);
        let vcache = seq(ctx * d, -0.7);
        let pool = pool1();

        // flat: one block holding the whole context, arena = cache
        let mut flat = vec![0f32; d];
        attention_rows_paged(
            &pool,
            &q,
            &kcache,
            &vcache,
            &[0],
            &[(0, ctx)],
            ctx,
            heads,
            dh,
            d,
            &mut flat,
        );
        for bs in [1usize, 3, 4, 8] {
            let (karena, varena, starts) = scatter(&kcache, &vcache, ctx, d, bs);
            let mut got = vec![0f32; d];
            attention_rows_paged(
                &pool,
                &q,
                &karena,
                &varena,
                &starts,
                &[(0, ctx)],
                bs,
                heads,
                dh,
                d,
                &mut got,
            );
            assert_eq!(got, flat, "bs={bs}: paged attention must be layout invariant");
        }
    }

    #[test]
    fn flash_attention_rows_bitwise_equal_solo_rows() {
        // Row i of a multi-row dispatch == a dispatch of row i alone (the
        // foundation of batched ≡ sequential decode).
        let (heads, dh, bs) = (2, 4, 4);
        let d = heads * dh;
        let rows = 3;
        let ctxs = [5usize, 9, 2];
        let max_ctx = 9;
        let kcache = seq(max_ctx * d, 0.3);
        let vcache = seq(max_ctx * d, -0.6);
        let (karena, varena, starts) = scatter(&kcache, &vcache, max_ctx, d, bs);
        let q = seq(rows * d, 0.5);
        let pool = pool1();

        // all rows share one starts run (same "session"), distinct ctx
        let meta: Vec<(usize, usize)> = ctxs.iter().map(|&c| (0usize, c)).collect();
        let mut batch = vec![0f32; rows * d];
        attention_rows_paged(
            &pool, &q, &karena, &varena, &starts, &meta, bs, heads, dh, d, &mut batch,
        );
        for (r, &ctx) in ctxs.iter().enumerate() {
            let mut solo = vec![0f32; d];
            attention_rows_paged(
                &pool,
                &q[r * d..(r + 1) * d],
                &karena,
                &varena,
                &starts,
                &[(0, ctx)],
                bs,
                heads,
                dh,
                d,
                &mut solo,
            );
            assert_eq!(&batch[r * d..(r + 1) * d], &solo[..], "row {r} must be bit-identical");
        }
    }
}
