//! Fast CPU kernels for the reference backend's hot path.
//!
//! The software analogue of LEAP's weight-stationary PIM dataflow: every
//! matmul here streams each weight row through the core exactly once and
//! amortises it over as many activation rows as the caller can batch
//! (whole-prompt prefill, multi-session decode). Design points:
//!
//! - **Transposed weight layout.** Weights are stored `[n, k]` (one
//!   contiguous row per *output* column), so `y[n] = dot(x, wt[n])` is a
//!   pure streaming read with no read-modify-write of `y` — the crossbar
//!   column-read access pattern, and the layout auto-vectorisers like.
//! - **Fixed-order lane accumulation.** [`dot`] accumulates into 8
//!   independent lanes and reduces them in index order, so every call with
//!   the same inputs produces the same bits on every code path — the
//!   bitwise `decode_batch` ≡ sequential `decode_step` contract rests on
//!   this.
//! - **Weight-stationary multi-row GEMM.** [`gemm_t`] iterates weight rows
//!   in the *outer* loop: one pass over `W` serves every activation row,
//!   which is what makes batched decode sublinear in batch size.
//! - **`std::thread::scope` parallelism, zero deps.** Large matvecs split
//!   the output columns, large GEMMs split the activation rows, and large
//!   attention contexts split the heads — all gated behind a work
//!   threshold so tiny models never pay a spawn.
//! - **No per-token tensor allocation.** [`Scratch`] owns every
//!   intermediate tensor buffer and only ever grows; [`RopeTable`]
//!   precomputes the rotary sin/cos so the steady-state decode loop does
//!   no trig.
//!
//! The [`naive`] submodule retains the pre-optimisation scalar kernels
//! verbatim. They are the parity oracle for the fast path
//! (`tests/integration_kernels.rs`) and the baseline the decode-throughput
//! bench (`benches/bench_hotpath.rs`) measures speedups against.

/// RMSNorm epsilon (matches `python/compile/kernels/ref.py`).
pub const RMS_EPS: f32 = 1e-5;
/// Rotary embedding base (matches the python oracle).
pub const ROPE_THETA: f64 = 10000.0;

/// Minimum multiply-accumulate count before a kernel spawns threads; below
/// this, scoped-thread setup costs more than it saves (a tiny-model decode
/// matvec is ~131K MACs and must stay on one core).
const PAR_MIN_WORK: usize = 1 << 21;
/// Upper bound on worker threads per kernel call.
const MAX_THREADS: usize = 8;

/// Worker-thread count for a kernel invocation of `work` multiply-adds:
/// 1 under the threshold, else enough threads to give each at least
/// `PAR_MIN_WORK`, capped by the machine and [`MAX_THREADS`].
fn threads_for(work: usize) -> usize {
    if work < 2 * PAR_MIN_WORK {
        return 1;
    }
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    avail.min(MAX_THREADS).min(work / PAR_MIN_WORK).max(1)
}

/// Dot product with 8 fixed accumulator lanes reduced in index order.
///
/// The lane structure gives the auto-vectoriser independent dependency
/// chains; the fixed reduction order makes the result a pure function of
/// the inputs (same bits from `matvec_t`, `gemm_t`, serial or threaded).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        for ((lane, &x), &y) in lanes.iter_mut().zip(av).zip(bv) {
            *lane += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    lanes.iter().sum::<f32>() + tail
}

/// `y = x @ W` for one activation row against a *transposed* weight matrix
/// `wt: [n, k]` (row `n` of `wt` is output column `n`). Splits the output
/// columns across scoped threads when the work is large; each column's
/// arithmetic is identical either way.
pub fn matvec_t(x: &[f32], wt: &[f32], k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(wt.len(), k * n);
    debug_assert_eq!(y.len(), n);
    let t = threads_for(k * n);
    if t <= 1 {
        for (yv, wrow) in y.iter_mut().zip(wt.chunks_exact(k)) {
            *yv = dot(x, wrow);
        }
        return;
    }
    let band = n.div_ceil(t);
    std::thread::scope(|s| {
        for (yb, wb) in y.chunks_mut(band).zip(wt.chunks(band * k)) {
            s.spawn(move || {
                for (yv, wrow) in yb.iter_mut().zip(wb.chunks_exact(k)) {
                    *yv = dot(x, wrow);
                }
            });
        }
    });
}

/// Weight-stationary multi-row GEMM: `y[rows, n] = x[rows, k] @ W` with
/// `wt: [n, k]` transposed. The weight row is the **outer** loop, so one
/// pass over `W` serves every activation row — batching activation rows
/// (prompt tokens, decode sessions) amortises the whole weight stream.
///
/// Row `r` of the result is bit-identical to `matvec_t` on row `r` alone:
/// each output element is one [`dot`] call either way. Large calls split
/// the activation rows across scoped threads (each worker keeps the
/// weight-stationary inner structure over its row band).
pub fn gemm_t(x: &[f32], wt: &[f32], rows: usize, k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(wt.len(), k * n);
    debug_assert_eq!(y.len(), rows * n);
    if rows == 1 {
        return matvec_t(x, wt, k, n, y);
    }
    let t = threads_for(rows * k * n).min(rows);
    if t <= 1 {
        for (nn, wrow) in wt.chunks_exact(k).enumerate() {
            for (r, xrow) in x.chunks_exact(k).enumerate() {
                y[r * n + nn] = dot(xrow, wrow);
            }
        }
        return;
    }
    let band = rows.div_ceil(t);
    std::thread::scope(|s| {
        for (yb, xb) in y.chunks_mut(band * n).zip(x.chunks(band * k)) {
            s.spawn(move || {
                for (nn, wrow) in wt.chunks_exact(k).enumerate() {
                    for (r, xrow) in xb.chunks_exact(k).enumerate() {
                        yb[r * n + nn] = dot(xrow, wrow);
                    }
                }
            });
        }
    });
}

/// A quantised matrix in fast-kernel layout: the int8 crossbar cells,
/// transposed `[n, k]`, plus the per-tile scales in their original
/// `[k/xb, n/xb]` orientation. The q8 kernels stream the cells directly —
/// 4× less weight traffic than dequantised f32, which is what decode
/// throughput is bound by — and fold the scale in per k-tile:
/// `y[n] = Σ_kt s[kt, n/xb] · Σ_{k∈kt} x[k]·q[k, n]`.
pub struct QMat {
    /// int8 cells, transposed row-major `[n, k]`.
    pub q: Vec<i8>,
    /// per-tile scales, row-major `[k/xb, n/xb]`.
    pub s: Vec<f32>,
    pub k: usize,
    pub n: usize,
    /// crossbar tile edge (tiles are `xb × xb`).
    pub xb: usize,
}

impl QMat {
    /// Build from a row-major `[k, n]` cell blob (raw bytes reinterpreted
    /// as i8, the artifact encoding) and its scale slice.
    pub fn from_cells(cells: &[u8], scales: &[f32], k: usize, n: usize, xb: usize) -> Self {
        // Hard preconditions (not debug-only): the q8 kernels tile both
        // axes by `xb`, so a ragged edge would index scales out of bounds.
        assert!(xb > 0 && k % xb == 0 && n % xb == 0, "k={k}, n={n} must be multiples of xb={xb}");
        assert_eq!(cells.len(), k * n);
        assert_eq!(scales.len(), (k / xb) * (n / xb));
        let mut q = vec![0i8; k * n];
        for (ki, row) in cells.chunks_exact(n).enumerate() {
            for (ni, &c) in row.iter().enumerate() {
                q[ni * k + ki] = c as i8;
            }
        }
        Self { q, s: scales.to_vec(), k, n, xb }
    }

    /// Dense dequantised f32 in the original `[k, n]` layout
    /// (`w[k][n] = q[k][n] * s[k/xb][n/xb]`) — the naive path's view of
    /// this matrix; used by the parity tests.
    pub fn dequant_dense(&self) -> Vec<f32> {
        let nt = self.n / self.xb;
        let mut w = vec![0f32; self.k * self.n];
        for k in 0..self.k {
            for n in 0..self.n {
                let s = self.s[(k / self.xb) * nt + n / self.xb];
                w[k * self.n + n] = self.q[n * self.k + k] as f32 * s;
            }
        }
        w
    }
}

/// Dot product of an f32 activation tile against int8 cells, with the
/// same 8-lane fixed-order accumulation as [`dot`] (the cells are
/// sign-extended to f32 in-register; no dequantised copy ever exists).
#[inline]
pub fn dot_q8(a: &[f32], b: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        for ((lane, &x), &qv) in lanes.iter_mut().zip(av).zip(bv) {
            *lane += x * qv as f32;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &qv) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * qv as f32;
    }
    lanes.iter().sum::<f32>() + tail
}

/// One output band of [`matvec_q8`]: columns `n0 .. n0 + y.len()`.
fn matvec_q8_band(x: &[f32], m: &QMat, n0: usize, y: &mut [f32]) {
    let (k, xb) = (m.k, m.xb);
    let nt = m.n / xb;
    for (i, yv) in y.iter_mut().enumerate() {
        let n = n0 + i;
        let wrow = &m.q[n * k..(n + 1) * k];
        let mut acc = 0f32;
        for (kt, xtile) in x.chunks(xb).enumerate() {
            let partial = dot_q8(xtile, &wrow[kt * xb..kt * xb + xtile.len()]);
            acc += m.s[kt * nt + n / xb] * partial;
        }
        *yv = acc;
    }
}

/// `y = x @ W` for one activation row against a quantised matrix,
/// streaming the int8 cells directly. Column-band threaded like
/// [`matvec_t`]; per-column arithmetic is identical on every path.
pub fn matvec_q8(x: &[f32], m: &QMat, y: &mut [f32]) {
    debug_assert_eq!(x.len(), m.k);
    debug_assert_eq!(y.len(), m.n);
    let t = threads_for(m.k * m.n);
    if t <= 1 {
        return matvec_q8_band(x, m, 0, y);
    }
    let band = m.n.div_ceil(t);
    std::thread::scope(|s| {
        for (bi, yb) in y.chunks_mut(band).enumerate() {
            s.spawn(move || matvec_q8_band(x, m, bi * band, yb));
        }
    });
}

/// One row band of [`gemm_q8`]: all columns for the rows in `xs`/`yb`.
/// Weight-stationary — the column (weight row + scale column) is the
/// outer loop, so the int8 stream is paid once for every activation row.
fn gemm_q8_rows(xs: &[f32], m: &QMat, yb: &mut [f32]) {
    let (k, n, xb) = (m.k, m.n, m.xb);
    let nt = n / xb;
    for nn in 0..n {
        let wrow = &m.q[nn * k..(nn + 1) * k];
        let scol = nn / xb;
        for (r, xrow) in xs.chunks_exact(k).enumerate() {
            let mut acc = 0f32;
            for (kt, xtile) in xrow.chunks(xb).enumerate() {
                let partial = dot_q8(xtile, &wrow[kt * xb..kt * xb + xtile.len()]);
                acc += m.s[kt * nt + scol] * partial;
            }
            yb[r * n + nn] = acc;
        }
    }
}

/// Weight-stationary multi-row GEMM over a quantised matrix:
/// `y[rows, n] = x[rows, k] @ W`. Row `r` is bit-identical to
/// [`matvec_q8`] on row `r` alone (same per-element tile order). Large
/// calls split the activation rows across scoped threads.
pub fn gemm_q8(x: &[f32], m: &QMat, rows: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * m.k);
    debug_assert_eq!(y.len(), rows * m.n);
    if rows == 1 {
        return matvec_q8(x, m, y);
    }
    let t = threads_for(rows * m.k * m.n).min(rows);
    if t <= 1 {
        return gemm_q8_rows(x, m, y);
    }
    let band = rows.div_ceil(t);
    std::thread::scope(|s| {
        for (yb, xb_rows) in y.chunks_mut(band * m.n).zip(x.chunks(band * m.k)) {
            s.spawn(move || gemm_q8_rows(xb_rows, m, yb));
        }
    });
}

/// Transpose a row-major `[k, n]` matrix into `[n, k]` (the layout the
/// fast kernels want; done once at weight-load time).
pub fn transpose(w: &[f32], k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), k * n);
    let mut t = vec![0f32; w.len()];
    for (ki, row) in w.chunks_exact(n).enumerate() {
        for (ni, &v) in row.iter().enumerate() {
            t[ni * k + ki] = v;
        }
    }
    t
}

/// RMSNorm into a caller-provided buffer (no allocation on the hot path).
/// Same accumulation order as [`naive::rmsnorm`], so the value is
/// bit-identical.
pub fn rmsnorm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), out.len());
    let mut sq = 0f32;
    for &v in x {
        sq += v * v;
    }
    let inv = 1.0 / (sq / x.len() as f32 + RMS_EPS).sqrt();
    for ((o, &v), &gv) in out.iter_mut().zip(x).zip(g) {
        *o = v * inv * gv;
    }
}

/// SwiGLU combine in place: `gate[i] = silu(gate[i]) * up[i]` (same
/// expression as the naive path, so bit-identical).
pub fn silu_mul(gate: &mut [f32], up: &[f32]) {
    debug_assert_eq!(gate.len(), up.len());
    for (g, &u) in gate.iter_mut().zip(up) {
        let gv = *g;
        *g = gv / (1.0 + (-gv).exp()) * u;
    }
}

/// Precomputed rotary-embedding tables: `sin/cos[pos * half + j]` for every
/// position below `s_max`, computed with exactly the naive path's
/// arithmetic (f64 `powf`, f32 angle) so table lookups reproduce its bits
/// while eliminating all steady-state trig.
pub struct RopeTable {
    sin: Vec<f32>,
    cos: Vec<f32>,
    half: usize,
}

impl RopeTable {
    pub fn new(s_max: usize, d_head: usize, theta: f64) -> Self {
        let half = d_head / 2;
        let mut sin = vec![0f32; s_max * half];
        let mut cos = vec![0f32; s_max * half];
        for pos in 0..s_max {
            for j in 0..half {
                let freq = (1.0 / theta.powf(j as f64 / half as f64)) as f32;
                let ang = pos as f32 * freq;
                sin[pos * half + j] = ang.sin();
                cos[pos * half + j] = ang.cos();
            }
        }
        Self { sin, cos, half }
    }

    /// Positions this table covers (`s_max` at construction).
    pub fn positions(&self) -> usize {
        if self.half == 0 {
            0
        } else {
            self.sin.len() / self.half
        }
    }

    /// In-place rotary embedding at `pos` over merged heads (half-split
    /// rotation per head, matching [`naive::rope`] bit for bit).
    pub fn apply(&self, x: &mut [f32], pos: usize, n_heads: usize, d_head: usize) {
        debug_assert_eq!(d_head / 2, self.half);
        debug_assert!(pos < self.positions(), "rope table too small for pos {pos}");
        let half = self.half;
        let sin = &self.sin[pos * half..(pos + 1) * half];
        let cos = &self.cos[pos * half..(pos + 1) * half];
        for h in 0..n_heads {
            let base = h * d_head;
            for j in 0..half {
                let (s, c) = (sin[j], cos[j]);
                let (x1, x2) = (x[base + j], x[base + half + j]);
                x[base + j] = x1 * c - x2 * s;
                x[base + half + j] = x1 * s + x2 * c;
            }
        }
    }
}

/// Causal attention for one query row against a `[ctx, d]` KV cache slice
/// (merged-head layout, `d = n_heads * d_head`). `scores` is a scratch
/// buffer of at least `ctx` entries; `o` receives the `[d]` output.
///
/// Per-head arithmetic matches the naive path's structure (max-subtracted
/// exp, deferred denominator divide); large contexts split the heads
/// across scoped threads with per-thread score buffers — each head's math
/// is identical either way.
#[allow(clippy::too_many_arguments)]
pub fn attention_row(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    ctx: usize,
    n_heads: usize,
    d_head: usize,
    d: usize,
    scores: &mut [f32],
    o: &mut [f32],
) {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(o.len(), d);
    debug_assert!(kcache.len() >= ctx * d && vcache.len() >= ctx * d);
    debug_assert!(scores.len() >= ctx);
    let t = threads_for(n_heads * ctx * d_head).min(n_heads);
    if t <= 1 {
        for (h, oh) in o.chunks_exact_mut(d_head).enumerate() {
            head_attention(q, kcache, vcache, ctx, h, d_head, d, &mut scores[..ctx], oh);
        }
        return;
    }
    let band = n_heads.div_ceil(t);
    std::thread::scope(|s| {
        for (hb, ob) in o.chunks_mut(band * d_head).enumerate() {
            s.spawn(move || {
                let mut local = vec![0f32; ctx];
                for (hi, oh) in ob.chunks_exact_mut(d_head).enumerate() {
                    let h = hb * band + hi;
                    head_attention(q, kcache, vcache, ctx, h, d_head, d, &mut local, oh);
                }
            });
        }
    });
}

/// Causal attention for one query row over a *paged* KV cache: the
/// context's positions live in fixed-size blocks scattered through the
/// shared arenas; `starts[b]` is the offset of block `b`'s
/// `[block_size, d]` slice (valid for both arenas), so position `j` is row
/// `j % block_size` of `starts[j / block_size]`.
///
/// Per-position arithmetic and ordering are exactly
/// [`attention_row`]'s, so the output is **bit-identical** to running the
/// contiguous kernel over a gathered copy of the same cache — the paged
/// backend inherits the batched ≡ sequential decode contract unchanged.
/// Large contexts split the heads across scoped threads like the
/// contiguous path.
#[allow(clippy::too_many_arguments)]
pub fn attention_row_paged(
    q: &[f32],
    karena: &[f32],
    varena: &[f32],
    starts: &[usize],
    block_size: usize,
    ctx: usize,
    n_heads: usize,
    d_head: usize,
    d: usize,
    scores: &mut [f32],
    o: &mut [f32],
) {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(o.len(), d);
    debug_assert!(block_size > 0 && starts.len() * block_size >= ctx);
    debug_assert!(scores.len() >= ctx);
    let t = threads_for(n_heads * ctx * d_head).min(n_heads);
    if t <= 1 {
        for (h, oh) in o.chunks_exact_mut(d_head).enumerate() {
            head_attention_paged(
                q,
                karena,
                varena,
                starts,
                block_size,
                ctx,
                h,
                d_head,
                d,
                &mut scores[..ctx],
                oh,
            );
        }
        return;
    }
    let band = n_heads.div_ceil(t);
    std::thread::scope(|s| {
        for (hb, ob) in o.chunks_mut(band * d_head).enumerate() {
            s.spawn(move || {
                let mut local = vec![0f32; ctx];
                for (hi, oh) in ob.chunks_exact_mut(d_head).enumerate() {
                    let h = hb * band + hi;
                    head_attention_paged(
                        q, karena, varena, starts, block_size, ctx, h, d_head, d, &mut local, oh,
                    );
                }
            });
        }
    });
}

/// One head of [`attention_row_paged`] (same math as [`head_attention`],
/// with the position → `(block, row)` indirection folded into the cache
/// reads).
#[allow(clippy::too_many_arguments)]
fn head_attention_paged(
    q: &[f32],
    karena: &[f32],
    varena: &[f32],
    starts: &[usize],
    block_size: usize,
    ctx: usize,
    h: usize,
    d_head: usize,
    d: usize,
    scores: &mut [f32],
    oh: &mut [f32],
) {
    let base = h * d_head;
    let scale = 1.0 / (d_head as f32).sqrt();
    let qh = &q[base..base + d_head];
    let mut max = f32::NEG_INFINITY;
    for (j, sc) in scores[..ctx].iter_mut().enumerate() {
        let row = starts[j / block_size] + (j % block_size) * d;
        let krow = &karena[row + base..row + base + d_head];
        *sc = dot(qh, krow) * scale;
        max = max.max(*sc);
    }
    let mut denom = 0f32;
    for sc in scores[..ctx].iter_mut() {
        *sc = (*sc - max).exp();
        denom += *sc;
    }
    oh.fill(0.0);
    for (j, &p) in scores[..ctx].iter().enumerate() {
        let row = starts[j / block_size] + (j % block_size) * d;
        let vrow = &varena[row + base..row + base + d_head];
        for (ov, &vv) in oh.iter_mut().zip(vrow) {
            *ov += p * vv;
        }
    }
    for ov in oh.iter_mut() {
        *ov /= denom;
    }
}

/// One head of [`attention_row`] (softmax(q·Kᵀ)·V over `ctx` positions).
#[allow(clippy::too_many_arguments)]
fn head_attention(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    ctx: usize,
    h: usize,
    d_head: usize,
    d: usize,
    scores: &mut [f32],
    oh: &mut [f32],
) {
    let base = h * d_head;
    let scale = 1.0 / (d_head as f32).sqrt();
    let qh = &q[base..base + d_head];
    let mut max = f32::NEG_INFINITY;
    for (j, sc) in scores[..ctx].iter_mut().enumerate() {
        let krow = &kcache[j * d + base..j * d + base + d_head];
        *sc = dot(qh, krow) * scale;
        max = max.max(*sc);
    }
    let mut denom = 0f32;
    for sc in scores[..ctx].iter_mut() {
        *sc = (*sc - max).exp();
        denom += *sc;
    }
    oh.fill(0.0);
    for (j, &p) in scores[..ctx].iter().enumerate() {
        let vrow = &vcache[j * d + base..j * d + base + d_head];
        for (ov, &vv) in oh.iter_mut().zip(vrow) {
            *ov += p * vv;
        }
    }
    for ov in oh.iter_mut() {
        *ov /= denom;
    }
}

/// Grow-only scratch arena for the forward pass: one allocation family at
/// the first call of each batch width, no tensor allocations in the
/// steady state. Buffers are sized for `rows` activation rows of a
/// `(d_model, d_ff)` model with an `s_max` context window.
#[derive(Default)]
pub struct Scratch {
    /// Residual stream `[rows, d]`.
    pub x: Vec<f32>,
    /// Normed activations `[rows, d]`.
    pub xn: Vec<f32>,
    /// Attention projections `[rows, d]` each.
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Attention output `[rows, d]`.
    pub o: Vec<f32>,
    /// Output-projection / MLP-down result `[rows, d]`.
    pub proj: Vec<f32>,
    /// SwiGLU gate and up `[rows, ff]` each.
    pub gate: Vec<f32>,
    pub up: Vec<f32>,
    /// Attention score buffer `[s_max]`.
    pub scores: Vec<f32>,
    /// Per-row cache position assigned this step `[rows]`.
    pub pos: Vec<usize>,
    /// Paged-KV block offsets for the row currently under attention
    /// (refilled per row/layer via `KvStore::fill_starts`; grow-only
    /// capacity like every other scratch buffer).
    pub block_starts: Vec<usize>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure capacity for `rows` activation rows (grow-only).
    pub fn ensure(&mut self, rows: usize, d: usize, ff: usize, s_max: usize) {
        let grow = |buf: &mut Vec<f32>, len: usize| {
            if buf.len() < len {
                buf.resize(len, 0.0);
            }
        };
        grow(&mut self.x, rows * d);
        grow(&mut self.xn, rows * d);
        grow(&mut self.q, rows * d);
        grow(&mut self.k, rows * d);
        grow(&mut self.v, rows * d);
        grow(&mut self.o, rows * d);
        grow(&mut self.proj, rows * d);
        grow(&mut self.gate, rows * ff);
        grow(&mut self.up, rows * ff);
        grow(&mut self.scores, s_max);
        if self.pos.len() < rows {
            self.pos.resize(rows, 0);
        }
    }
}

/// The pre-optimisation scalar kernels, retained verbatim: the parity
/// oracle for the fast path and the baseline for the decode-throughput
/// bench. These allocate per call, branch on zero activations, and do trig
/// per token — exactly what the kernel layer exists to remove.
pub mod naive {
    use super::{RMS_EPS, ROPE_THETA};

    /// `y = x @ W` for one activation row: `x: [k]`, `w: [k, n]` row-major
    /// (NOT transposed — the original axpy walk).
    pub fn matvec(x: &[f32], w: &[f32], k: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), k);
        debug_assert_eq!(w.len(), k * n);
        let mut y = vec![0f32; n];
        for (ki, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w[ki * n..(ki + 1) * n];
            for (yv, &wv) in y.iter_mut().zip(row) {
                *yv += xv * wv;
            }
        }
        y
    }

    pub fn rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
        let mut sq = 0f32;
        for &v in x {
            sq += v * v;
        }
        let inv = 1.0 / (sq / x.len() as f32 + RMS_EPS).sqrt();
        x.iter().zip(g).map(|(&v, &gv)| v * inv * gv).collect()
    }

    /// In-place rotary embedding at `pos` over merged heads (half-split
    /// rotation per head, matching `ref.ref_rope`).
    pub fn rope(x: &mut [f32], pos: usize, n_heads: usize, d_head: usize) {
        let half = d_head / 2;
        for h in 0..n_heads {
            let base = h * d_head;
            for j in 0..half {
                let freq = (1.0 / ROPE_THETA.powf(j as f64 / half as f64)) as f32;
                let ang = pos as f32 * freq;
                let (sin, cos) = (ang.sin(), ang.cos());
                let (x1, x2) = (x[base + j], x[base + half + j]);
                x[base + j] = x1 * cos - x2 * sin;
                x[base + half + j] = x1 * sin + x2 * cos;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i % 17) as f32 - 8.0) * scale).collect()
    }

    #[test]
    fn dot_matches_sequential_sum() {
        for len in [0, 1, 7, 8, 9, 31, 64, 100] {
            let a = seq(len, 0.25);
            let b = seq(len, -0.5);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - want).abs() <= 1e-5 * (1.0 + want.abs()), "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn matvec_t_matches_naive_matvec() {
        // same matrix in both layouts: w [k,n] row-major, wt = transpose
        let (k, n) = (13, 9);
        let w = seq(k * n, 0.1);
        let wt = transpose(&w, k, n);
        let x = seq(k, 0.3);
        let want = naive::matvec(&x, &w, k, n);
        let mut got = vec![0f32; n];
        matvec_t(&x, &wt, k, n, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn gemm_rows_bitwise_equal_to_matvec() {
        let (rows, k, n) = (4, 24, 10);
        let x = seq(rows * k, 0.2);
        let wt = seq(n * k, -0.15);
        let mut y = vec![0f32; rows * n];
        gemm_t(&x, &wt, rows, k, n, &mut y);
        for r in 0..rows {
            let mut solo = vec![0f32; n];
            matvec_t(&x[r * k..(r + 1) * k], &wt, k, n, &mut solo);
            assert_eq!(&y[r * n..(r + 1) * n], &solo[..], "row {r} must be bit-identical");
        }
    }

    /// Deterministic pseudo-random i8 cells + scales for a [k, n] matrix.
    fn qmat(k: usize, n: usize, xb: usize) -> QMat {
        let cells: Vec<u8> = (0..k * n).map(|i| (i * 31 + 7) as u8).collect();
        let nt = (k / xb) * (n / xb);
        let scales: Vec<f32> = (0..nt).map(|i| 0.01 + 0.003 * (i % 5) as f32).collect();
        QMat::from_cells(&cells, &scales, k, n, xb)
    }

    #[test]
    fn dot_q8_matches_sequential_sum() {
        for len in [1, 7, 8, 9, 31, 64] {
            let a = seq(len, 0.25);
            let b: Vec<i8> = (0..len).map(|i| (i as i8).wrapping_mul(13)).collect();
            let want: f32 = a.iter().zip(&b).map(|(&x, &q)| x * q as f32).sum();
            let got = dot_q8(&a, &b);
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn qmat_transposes_cells() {
        // cells [k=2, n=2] row-major: [1, 2, 3, 0x80]; xb=1 scales per cell
        let m = QMat::from_cells(&[1, 2, 3, 0x80], &[1.0, 10.0, 100.0, 0.5], 2, 2, 1);
        // q is [n, k]: column n=0 holds cells (k=0,n=0)=1 and (k=1,n=0)=3
        assert_eq!(m.q, vec![1, 3, 2, -128]);
        assert_eq!(m.dequant_dense(), vec![1.0, 20.0, 300.0, -64.0]);
    }

    #[test]
    fn matvec_q8_matches_dense_naive_path() {
        let (k, n, xb) = (8, 12, 4);
        let m = qmat(k, n, xb);
        let dense = m.dequant_dense();
        let x = seq(k, 0.3);
        let want = naive::matvec(&x, &dense, k, n);
        let mut got = vec![0f32; n];
        matvec_q8(&x, &m, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn gemm_q8_rows_bitwise_equal_to_matvec_q8() {
        let (rows, k, n, xb) = (3, 8, 8, 4);
        let m = qmat(k, n, xb);
        let x = seq(rows * k, 0.2);
        let mut y = vec![0f32; rows * n];
        gemm_q8(&x, &m, rows, &mut y);
        for r in 0..rows {
            let mut solo = vec![0f32; n];
            matvec_q8(&x[r * k..(r + 1) * k], &m, &mut solo);
            assert_eq!(&y[r * n..(r + 1) * n], &solo[..], "row {r} must be bit-identical");
        }
    }

    #[test]
    fn transpose_round_trips() {
        let (k, n) = (5, 7);
        let w = seq(k * n, 1.0);
        let wt = transpose(&w, k, n);
        assert_eq!(transpose(&wt, n, k), w);
        // spot-check one element: w[2][3] == wt[3][2]
        assert_eq!(w[2 * n + 3], wt[3 * k + 2]);
    }

    #[test]
    fn rmsnorm_into_bitwise_matches_naive() {
        let x = seq(32, 0.7);
        let g = seq(32, 0.4);
        let want = naive::rmsnorm(&x, &g);
        let mut got = vec![0f32; 32];
        rmsnorm_into(&x, &g, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn rope_table_bitwise_matches_naive_rope() {
        let (heads, dh, s_max) = (3, 8, 16);
        let table = RopeTable::new(s_max, dh, ROPE_THETA);
        assert_eq!(table.positions(), s_max);
        for pos in [0usize, 1, 7, 15] {
            let mut a = seq(heads * dh, 0.9);
            let mut b = a.clone();
            table.apply(&mut a, pos, heads, dh);
            naive::rope(&mut b, pos, heads, dh);
            assert_eq!(a, b, "pos {pos}");
        }
    }

    #[test]
    fn silu_mul_matches_naive_expression() {
        let gate = seq(20, 0.6);
        let up = seq(20, -0.3);
        let want: Vec<f32> =
            gate.iter().zip(&up).map(|(&g, &u)| g / (1.0 + (-g).exp()) * u).collect();
        let mut got = gate.clone();
        silu_mul(&mut got, &up);
        assert_eq!(got, want);
    }

    #[test]
    fn scratch_grows_and_never_shrinks() {
        let mut s = Scratch::new();
        s.ensure(4, 16, 32, 64);
        assert!(s.x.len() >= 64 && s.gate.len() >= 128 && s.scores.len() >= 64);
        let cap = s.gate.len();
        s.ensure(2, 16, 32, 64);
        assert_eq!(s.gate.len(), cap, "ensure with fewer rows must not shrink");
        s.ensure(8, 16, 32, 64);
        assert!(s.gate.len() >= 8 * 32);
    }

    #[test]
    fn attention_row_uniform_values() {
        // uniform K/V: softmax is uniform, output equals the common V row
        let (heads, dh, ctx) = (2, 4, 3);
        let d = heads * dh;
        let q = seq(d, 0.5);
        let kcache = vec![1.0f32; ctx * d];
        let vcache: Vec<f32> = (0..ctx * d).map(|i| (i % d) as f32).collect();
        let mut scores = vec![0f32; ctx];
        let mut o = vec![0f32; d];
        attention_row(&q, &kcache, &vcache, ctx, heads, dh, d, &mut scores, &mut o);
        for (i, &ov) in o.iter().enumerate() {
            assert!((ov - i as f32).abs() < 1e-5, "o[{i}] = {ov}");
        }
    }

    #[test]
    fn attention_row_paged_bitwise_matches_contiguous() {
        // Scatter a contiguous [ctx, d] cache into out-of-order blocks of a
        // larger arena: the paged kernel must reproduce the contiguous
        // kernel bit for bit.
        let (heads, dh, ctx, bs) = (3, 8, 11, 4);
        let d = heads * dh;
        let q = seq(d, 0.5);
        let kcache = seq(ctx * d, 0.3);
        let vcache = seq(ctx * d, -0.7);

        let n_blocks = ctx.div_ceil(bs);
        // blocks deliberately stored in reverse arena order with a gap
        let mut karena = vec![f32::NAN; (n_blocks + 1) * bs * d];
        let mut varena = vec![f32::NAN; (n_blocks + 1) * bs * d];
        let starts: Vec<usize> = (0..n_blocks).map(|b| (n_blocks - b) * bs * d).collect();
        for j in 0..ctx {
            let at = starts[j / bs] + (j % bs) * d;
            karena[at..at + d].copy_from_slice(&kcache[j * d..(j + 1) * d]);
            varena[at..at + d].copy_from_slice(&vcache[j * d..(j + 1) * d]);
        }

        let mut scores = vec![0f32; ctx];
        let mut want = vec![0f32; d];
        attention_row(&q, &kcache, &vcache, ctx, heads, dh, d, &mut scores, &mut want);
        let mut got = vec![0f32; d];
        attention_row_paged(
            &q, &karena, &varena, &starts, bs, ctx, heads, dh, d, &mut scores, &mut got,
        );
        assert_eq!(got, want, "paged attention must be bit-identical to contiguous");
    }

    #[test]
    fn threads_for_respects_threshold() {
        assert_eq!(threads_for(0), 1);
        assert_eq!(threads_for(PAR_MIN_WORK), 1);
        assert!(threads_for(16 * PAR_MIN_WORK) >= 1);
    }
}
