//! PJRT runtime (the `xla` crate wrapper): loads the AOT-lowered HLO text
//! artifacts built by `python/compile/aot.py`, compiles them once, and
//! executes the functional model from the serving hot path. Python is never
//! invoked here.

pub mod engine;
pub mod leapbin;

pub use engine::{ArtifactMeta, Engine, StepOutput};
pub use leapbin::{DType, Tensor};
