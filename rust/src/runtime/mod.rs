//! Functional runtime: the pluggable numerics backends executed from the
//! serving hot path (the simulator provides the timing/energy half).
//!
//! - [`backend`] — the [`NumericsBackend`] trait the coordinator talks to,
//!   plus artifact metadata and helpers.
//! - [`pool`] — the persistent worker pool: fixed-ownership tile bands
//!   over resident, parkable threads (spawned once per backend; zero
//!   spawns on the request path).
//! - [`simd`] — runtime-dispatched SIMD inner products (AVX2/NEON with the
//!   fixed-order scalar path retained as the bitwise oracle; `LEAP_SIMD=0`
//!   forces scalar).
//! - [`kernels`] — the fast CPU kernel layer (weight-stationary GEMM,
//!   fused QKV/SwiGLU/residual-norm passes, flash paged attention, rope
//!   tables, scratch arena, pool-dispatched parallelism) plus the retained
//!   naive scalar kernels it is parity-tested against.
//! - [`reference`] — pure-Rust f32 transformer over [`kernels`] (default
//!   backend, zero non-std dependencies; mirrors
//!   `python/compile/kernels/ref.py`).
//! - [`engine`] (`--features xla`) — PJRT wrapper that loads the
//!   AOT-lowered HLO text artifacts built by `python/compile/aot.py`.
//! - [`leapbin`] — the tensor interchange format shared with python.
//!
//! Python never runs on the request path in any configuration.

pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
pub mod kernels;
pub mod leapbin;
pub mod pool;
pub mod reference;
pub mod simd;

pub use backend::{
    argmax_row, default_artifacts_dir, ArtifactMeta, BatchResults, NumericsBackend, SessionId,
    StepOutput,
};
#[cfg(feature = "xla")]
pub use engine::{Engine, PjrtBackend};
pub use leapbin::{DType, Tensor};
pub use pool::{LaneFault, WorkerPool, WorkerPoolStats};
pub use reference::{KernelMode, ReferenceBackend, ReferenceModel};
pub use simd::SimdLevel;
