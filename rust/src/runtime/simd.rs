//! Runtime-dispatched SIMD inner products for the kernel layer.
//!
//! Every hot loop in [`crate::runtime::kernels`] bottoms out in one of two
//! inner products: `dot` (f32 · f32) and `dot_q8` (f32 activations · int8
//! crossbar cells). Both accumulate into a **fixed 8-lane order**: lane `j`
//! sums elements `j, j+8, j+16, …`, lanes are reduced in index order, and a
//! scalar tail handles the ragged remainder. That order is the foundation of
//! the repo's bitwise determinism contracts (batched==sequential,
//! paged==flat, pool-size invariance).
//!
//! The vector paths here reproduce that order *exactly*:
//!
//! - **AVX2** — one `__m256` accumulator IS the 8 scalar lanes. Each step is
//!   a separate multiply then add (`_mm256_mul_ps` + `_mm256_add_ps`, never
//!   FMA — fusing changes rounding), so lane `j` of the register performs
//!   the same f32 operations in the same order as scalar lane `j`. The
//!   reduction extracts the lanes and sums them in index order, and the tail
//!   is the identical scalar loop.
//! - **NEON** — two `float32x4` registers hold lanes 0–3 and 4–7; again
//!   separate `vmulq_f32` + `vaddq_f32` (never `vfmaq`), lanes stored out
//!   and summed in index order.
//!
//! IEEE-754 binary ops are deterministic per (inputs, op, rounding mode), so
//! SIMD and scalar produce **bitwise identical** results — the dispatch
//! level is unobservable through any kernel output, and none of the existing
//! contracts needed re-pinning. That equality is itself property-tested
//! (`tests/prop_simd_kv.rs`) including tails shorter than one vector.
//!
//! Dispatch is resolved once per process (`OnceLock`): `LEAP_SIMD=0` (or
//! `off`/`scalar`) forces the portable scalar path, mirroring the
//! `LEAP_THREADS` convention in [`crate::runtime::pool`]; otherwise x86-64
//! probes AVX2 at runtime and AArch64 uses NEON (baseline on that ISA).
//! Benches may additionally force the scalar path *after* the probe via
//! [`force_scalar`] to measure both sides in one process.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Which inner-product implementation the process dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable fixed-order scalar path (the oracle).
    Scalar,
    /// x86-64 AVX2 (8 × f32 per register, one register = the 8 lanes).
    Avx2,
    /// AArch64 NEON (2 × 4 f32 registers covering the 8 lanes).
    Neon,
}

impl SimdLevel {
    /// Stable label for metrics / bench JSON ("avx2" | "neon" | "scalar").
    pub fn as_str(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

static PROBED: OnceLock<SimdLevel> = OnceLock::new();
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

fn probe() -> SimdLevel {
    // LEAP_SIMD=0|off|scalar forces the portable path; unparseable values
    // warn and fall through to the ISA probe (the LEAP_THREADS convention).
    if let Ok(v) = std::env::var("LEAP_SIMD") {
        match v.trim() {
            "0" | "off" | "scalar" => return SimdLevel::Scalar,
            "" | "1" | "on" | "auto" => {}
            other => {
                crate::obs::stderr_log(
                    crate::obs::Level::Warn,
                    "simd_env",
                    format_args!(
                        "ignoring unparseable LEAP_SIMD={other:?} (want 0|off|scalar or 1|on|auto)"
                    ),
                );
            }
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdLevel::Neon;
    }
    #[allow(unreachable_code)]
    SimdLevel::Scalar
}

/// The dispatch level in effect (probe result, or Scalar under
/// [`force_scalar`]). Resolved once per process; cheap to call per kernel.
#[inline]
pub fn level() -> SimdLevel {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        return SimdLevel::Scalar;
    }
    *PROBED.get_or_init(probe)
}

/// The level the ISA probe selected, ignoring any [`force_scalar`] override
/// (what the host *can* do — reported in bench JSON and `leap serve`).
pub fn probed_level() -> SimdLevel {
    *PROBED.get_or_init(probe)
}

/// Force the scalar path (benches/tests only: lets one process measure and
/// compare both sides of the dispatch). `force_scalar(false)` restores the
/// probed level.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Fixed-order f32 dot product, SIMD-dispatched. Bitwise identical to
/// [`dot_scalar`] at every level.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    match level() {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { dot_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { dot_neon(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// Fixed-order f32 · int8 dot product, SIMD-dispatched. Bitwise identical
/// to [`dot_q8_scalar`] at every level (i8→f32 conversion is exact).
#[inline]
pub fn dot_q8(a: &[f32], b: &[i8]) -> f32 {
    match level() {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { dot_q8_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { dot_q8_neon(a, b) },
        _ => dot_q8_scalar(a, b),
    }
}

/// The portable fixed-8-lane scalar dot — the determinism oracle every
/// vector path must match bitwise.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        for ((lane, &x), &y) in lanes.iter_mut().zip(av).zip(bv) {
            *lane += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    lanes.iter().sum::<f32>() + tail
}

/// The portable fixed-8-lane scalar q8 dot — the oracle for [`dot_q8`].
pub fn dot_q8_scalar(a: &[f32], b: &[i8]) -> f32 {
    let mut lanes = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        for ((lane, &x), &qv) in lanes.iter_mut().zip(av).zip(bv) {
            *lane += x * qv as f32;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &qv) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * qv as f32;
    }
    lanes.iter().sum::<f32>() + tail
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    // One __m256 accumulator = the 8 scalar lanes. Separate mul+add (no
    // FMA) keeps per-lane rounding identical to the scalar path.
    let mut acc = _mm256_setzero_ps();
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        let va = _mm256_loadu_ps(av.as_ptr());
        let vb = _mm256_loadu_ps(bv.as_ptr());
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    lanes.iter().sum::<f32>() + tail
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_q8_avx2(a: &[f32], b: &[i8]) -> f32 {
    use std::arch::x86_64::*;
    let mut acc = _mm256_setzero_ps();
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        let va = _mm256_loadu_ps(av.as_ptr());
        // 8 × i8 → 8 × i32 → 8 × f32; integer widening and i8-range
        // int→float conversion are exact, so this matches `qv as f32`.
        let vq = _mm_loadl_epi64(bv.as_ptr() as *const __m128i);
        let vf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(vq));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vf));
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0f32;
    for (&x, &qv) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * qv as f32;
    }
    lanes.iter().sum::<f32>() + tail
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    // Two float32x4 registers hold lanes 0–3 and 4–7. Separate mul+add
    // (never vfmaq) keeps per-lane rounding identical to the scalar path.
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        let a0 = vld1q_f32(av.as_ptr());
        let a1 = vld1q_f32(av.as_ptr().add(4));
        let b0 = vld1q_f32(bv.as_ptr());
        let b1 = vld1q_f32(bv.as_ptr().add(4));
        acc0 = vaddq_f32(acc0, vmulq_f32(a0, b0));
        acc1 = vaddq_f32(acc1, vmulq_f32(a1, b1));
    }
    let mut lanes = [0.0f32; 8];
    vst1q_f32(lanes.as_mut_ptr(), acc0);
    vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    lanes.iter().sum::<f32>() + tail
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_q8_neon(a: &[f32], b: &[i8]) -> f32 {
    use std::arch::aarch64::*;
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        let a0 = vld1q_f32(av.as_ptr());
        let a1 = vld1q_f32(av.as_ptr().add(4));
        // 8 × i8 → widen to i16 → i32 halves → f32 (all exact for i8).
        let q8 = vld1_s8(bv.as_ptr());
        let q16 = vmovl_s8(q8);
        let f0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16)));
        let f1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16)));
        acc0 = vaddq_f32(acc0, vmulq_f32(a0, f0));
        acc1 = vaddq_f32(acc1, vmulq_f32(a1, f1));
    }
    let mut lanes = [0.0f32; 8];
    vst1q_f32(lanes.as_mut_ptr(), acc0);
    vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
    let mut tail = 0.0f32;
    for (&x, &qv) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * qv as f32;
    }
    lanes.iter().sum::<f32>() + tail
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<i8>) {
        let mut rng = crate::testutil::SplitMix64::new(seed);
        let a = rng.normal_vec(n);
        let b = rng.normal_vec(n);
        let q: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        (a, b, q)
    }

    /// The dispatched path matches the scalar oracle bitwise on every
    /// length, including tails shorter than one vector (0..=9) and
    /// non-multiple-of-8 lengths.
    #[test]
    fn dispatched_matches_scalar_bitwise() {
        for n in (0..=9).chain([15, 16, 17, 31, 64, 127, 256, 1000]) {
            let (a, b, q) = vecs(n, 0x5EED + n as u64);
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_scalar(&a, &b).to_bits(),
                "dot diverged from scalar at n={n} (level {:?})",
                level()
            );
            assert_eq!(
                dot_q8(&a, &q).to_bits(),
                dot_q8_scalar(&a, &q).to_bits(),
                "dot_q8 diverged from scalar at n={n} (level {:?})",
                level()
            );
        }
    }

    /// The scalar oracle itself is the documented 8-lane fixed-order sum.
    #[test]
    fn scalar_is_eight_lane_fixed_order() {
        let (a, b, q) = vecs(21, 7);
        let mut lanes = [0.0f32; 8];
        for (i, (&x, &y)) in a.iter().zip(&b).enumerate().take(16) {
            lanes[i % 8] += x * y;
        }
        let mut tail = 0.0f32;
        for (&x, &y) in a[16..].iter().zip(&b[16..]) {
            tail += x * y;
        }
        let want = lanes.iter().sum::<f32>() + tail;
        assert_eq!(dot_scalar(&a, &b).to_bits(), want.to_bits());
        // q8: conversion then identical lane structure
        let qf: Vec<f32> = q.iter().map(|&v| v as f32).collect();
        assert_eq!(dot_q8_scalar(&a, &q).to_bits(), dot_scalar(&a, &qf).to_bits());
    }

    /// `force_scalar` reroutes dispatch without touching the probed level,
    /// and restoring it brings the vector path back.
    #[test]
    fn force_scalar_round_trip() {
        let probed = probed_level();
        force_scalar(true);
        assert_eq!(level(), SimdLevel::Scalar);
        let (a, b, _) = vecs(100, 3);
        let forced = dot(&a, &b);
        force_scalar(false);
        assert_eq!(level(), probed);
        assert_eq!(dot(&a, &b).to_bits(), forced.to_bits(), "levels must agree bitwise");
    }
}
