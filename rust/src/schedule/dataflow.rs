//! Prefill/decode dataflow phase plans (paper §IV-B/C, Fig. 6).
//!
//! A [`Phase`] is one step of the per-shard pipeline with a closed-form
//! cycle/activity model derived from the Fig. 6 timing diagrams. The
//! compiler lowers each phase to NPM instructions whose repeat counts match
//! these formulas exactly, so the analytical simulator (summing phases) and
//! the instruction-level simulator (executing the compiled program) agree
//! by construction — cross-checked in `tests/integration_sim.rs`.
//!
//! Pipeline intuition carried over from Fig. 6:
//!  * streaming a vector of `n` elements over one link costs
//!    `ceil(n / elems_per_packet)` cycles;
//!  * a pipelined reduction/broadcast over `k` hops adds `k` drain cycles;
//!  * a DDMM of an m×d by d×n shard product on an IRCU with `P` MACs costs
//!    `ceil(m·d·n / P)` MAC cycles, overlapped with the operand stream.

use crate::arch::{HwParams, TileGeometry};
use crate::model::ModelShape;

/// Phase kinds of one attention + MLP layer pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PhaseKind {
    /// Broadcast 1: stream input activations into the Q/K/V channels.
    InputBroadcast,
    /// PIM DSMM: in-place projections in the crossbars.
    Projection,
    /// Reduction 1: aggregate projection partials within each RG.
    ProjReduce,
    /// Unicast 1: rotate K shards into the Q channel.
    KShardRotate,
    /// DDMM QKᵀ in the Q-channel IRCUs.
    ScoreDdmm,
    /// Reduction 2: reduce partial scores across Q-channel RGs.
    ScoreReduce,
    /// Online softmax (running max / exp / rescale) on the way to V.
    Softmax,
    /// DDMM S·V in the V-channel IRCUs + Unicast 2 into the O channel.
    ContextDdmm,
    /// Broadcast 2 + Reduction 3: finalise O shards in the O channel.
    OutputReduce,
    /// MLP DSMM passes (gate/up/down) with their broadcasts/reductions.
    Mlp,
}

impl PhaseKind {
    pub const ALL: [PhaseKind; 10] = [
        PhaseKind::InputBroadcast,
        PhaseKind::Projection,
        PhaseKind::ProjReduce,
        PhaseKind::KShardRotate,
        PhaseKind::ScoreDdmm,
        PhaseKind::ScoreReduce,
        PhaseKind::Softmax,
        PhaseKind::ContextDdmm,
        PhaseKind::OutputReduce,
        PhaseKind::Mlp,
    ];
}

/// One dataflow phase with its cycle/activity accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub kind: PhaseKind,
    /// Critical-path cycles of this phase.
    pub cycles: u64,
    /// Router-hop events (for the energy ledger).
    pub hop_events: u64,
    /// IRCU compute cycles (MAC/add/mul/expmax).
    pub ircu_events: u64,
    /// Scratchpad word accesses.
    pub spad_events: u64,
    /// Crossbar MVM events.
    pub pe_events: u64,
    /// Routers active during the phase (for power accounting).
    pub active_routers: u64,
}

/// The complete phase sequence for one decoder layer pass.
#[derive(Debug, Clone, Default)]
pub struct LayerPhases {
    pub phases: Vec<Phase>,
}

impl LayerPhases {
    pub fn total_cycles(&self) -> u64 {
        self.phases.iter().map(|p| p.cycles).sum()
    }

    pub fn cycles_of(&self, kind: PhaseKind) -> u64 {
        self.phases.iter().filter(|p| p.kind == kind).map(|p| p.cycles).sum()
    }
}

/// Prefill phase plan for one layer processing `s` new tokens.
///
/// The inner (Q) loop is spatially unrolled across RPUs and the outer (K/V)
/// loop is the rotational broadcast, so the shard-pair work is charged once
/// per K/V shard rotation step with all Q shards in flight (Fig. 5(d)).
pub fn prefill_phases(shape: &ModelShape, geom: &TileGeometry, hw: &HwParams, s: usize) -> LayerPhases {
    prefill_phases_opts(shape, geom, hw, s, true)
}

/// [`prefill_phases`] with the KV-duplication choice explicit.
///
/// `kv_duplication = true` follows the paper (GQA degraded to MHA by
/// duplication — shards stream at full head width); `false` is the
/// GQA-aware ablation (shards stream at n_kv_heads width), reported in
/// EXPERIMENTS.md.
pub fn prefill_phases_opts(
    shape: &ModelShape,
    geom: &TileGeometry,
    hw: &HwParams,
    s: usize,
    kv_duplication: bool,
) -> LayerPhases {
    let d = shape.d_model;
    let dh = shape.d_head();
    let cs = geom.shard_rows;
    let n_shards = geom.shards_for(s) as u64;
    let epp = hw.elems_per_packet() as u64;
    let dc = geom.dc as u64;
    let nr = geom.n_r as u64;
    let heads = shape.n_heads as u64;
    // The paper degrades GQA to the MHA scheme "by matrix duplication";
    // the duplicated K/V shards stream at full head width. The GQA-aware
    // ablation streams the physically smaller cache instead.
    let kv_heads = if kv_duplication { heads } else { shape.n_kv_heads as u64 };
    let macs = hw.ircu_macs as u64;

    let mut lp = LayerPhases::default();
    let vec_stream = hw.stream_cycles(d); // cycles to stream one token vector

    // -- Broadcast 1: every token's activation enters the west edge and
    //    pipelines across the 2dc-wide tile. Tokens stream back-to-back.
    let tokens = s as u64;
    let b1_cycles = tokens * vec_stream + 2 * dc; // stream + pipeline drain
    lp.phases.push(Phase {
        kind: PhaseKind::InputBroadcast,
        cycles: b1_cycles,
        hop_events: tokens * (d as u64).div_ceil(epp) * 2 * dc,
        ircu_events: 0,
        spad_events: tokens * d as u64 / nr.max(1),
        pe_events: 0,
        active_routers: (geom.side * geom.side) as u64,
    });

    // -- PIM projections: each token triggers one MVM per crossbar; arrays
    //    in a channel work in parallel, MVMs pipeline behind the broadcast.
    let proj_cycles = tokens * hw.pe_mvm_cycles;
    lp.phases.push(Phase {
        kind: PhaseKind::Projection,
        cycles: proj_cycles,
        hop_events: 0,
        ircu_events: 0,
        spad_events: 0,
        pe_events: tokens * 4 * dc * dc, // Q,K,V,O-channel arrays
        active_routers: 0,
    });

    // -- Reduction 1: per token, dc partial vectors (each C wide) reduce
    //    along the RG chain; pipelined: stream + dc drain hops.
    let red1_cycles = tokens * hw.stream_cycles(hw.xb) + dc;
    lp.phases.push(Phase {
        kind: PhaseKind::ProjReduce,
        cycles: red1_cycles,
        hop_events: tokens * 3 * dc * dc * (hw.xb as u64).div_ceil(epp),
        ircu_events: tokens * 3 * dc * (hw.xb as u64).div_ceil(macs),
        spad_events: tokens * 3 * d as u64,
        pe_events: 0,
        active_routers: (3 * geom.macros_per_channel()) as u64,
    });

    // Per-shard-rotation phases: the outer loop runs once per K/V shard;
    // Q-shard RPUs consume the rotating shard in parallel — but the spatial
    // unroll of the inner loop is capped by the 2dc RPU rows of the Q
    // channel, so contexts longer than 2dc·C_S tokens serialise in passes.
    let unroll_passes = (geom.shards_for(s) as u64).div_ceil(2 * dc).max(1);
    let shard_elems = (cs * dh) as u64; // one head's shard slice
    let shard_stream = shard_elems.div_ceil(epp);

    // -- Unicast 1 (K rotation): K shard hops from the K channel across to
    //    the Q channel (≈ dc columns) then rotates vertically RG-to-RG,
    //    once per unroll pass.
    let rot_cycles = n_shards * unroll_passes * (shard_stream * kv_heads + nr + dc);
    lp.phases.push(Phase {
        kind: PhaseKind::KShardRotate,
        cycles: rot_cycles,
        hop_events: n_shards * shard_stream * kv_heads * (dc + nr),
        ircu_events: 0,
        spad_events: n_shards * shard_elems * kv_heads * 2,
        pe_events: 0,
        active_routers: geom.macros_per_channel() as u64 * 2,
    });

    // -- Score DDMM: per rotation, each resident Q-shard RPU computes a
    //    CS×CS score block per head: CS·dh·CS MACs on N_r IRCUs of `macs`
    //    MACs each, serialised over the unroll passes.
    let score_macs = (cs * dh * cs) as u64 * heads;
    let score_cycles = n_shards * unroll_passes * score_macs.div_ceil(macs * nr);
    lp.phases.push(Phase {
        kind: PhaseKind::ScoreDdmm,
        cycles: score_cycles,
        hop_events: 0,
        ircu_events: n_shards * n_shards * score_macs.div_ceil(macs), // all Q shards × rotations
        spad_events: n_shards * shard_elems * heads,
        pe_events: 0,
        active_routers: geom.macros_per_channel() as u64,
    });

    // -- Reduction 2: score partials reduce vertically across dc RGs.
    let score_block = (cs * cs) as u64 * heads;
    let red2_cycles = n_shards * (score_block.div_ceil(epp) + dc);
    lp.phases.push(Phase {
        kind: PhaseKind::ScoreReduce,
        cycles: red2_cycles,
        hop_events: n_shards * n_shards * score_block.div_ceil(epp) * dc,
        ircu_events: n_shards * n_shards * score_block.div_ceil(macs),
        spad_events: 0,
        pe_events: 0,
        active_routers: geom.macros_per_channel() as u64,
    });

    // -- Softmax: running max/exp over each score row (FlashAttention
    //    style), one pass over the block per rotation.
    let sm_cycles = n_shards * score_block.div_ceil(macs);
    lp.phases.push(Phase {
        kind: PhaseKind::Softmax,
        cycles: sm_cycles,
        hop_events: n_shards * score_block.div_ceil(epp),
        ircu_events: n_shards * n_shards * 2 * score_block.div_ceil(macs),
        spad_events: n_shards * score_block,
        pe_events: 0,
        active_routers: geom.macros_per_channel() as u64,
    });

    // -- Context DDMM (S·V) + Unicast 2 into O scratchpads, with the R-Mul
    //    rescale of previously accumulated O shards.
    let ctx_macs = (cs * cs * dh) as u64 * heads;
    let ctx_cycles =
        n_shards * unroll_passes * (ctx_macs.div_ceil(macs * nr) + shard_stream);
    lp.phases.push(Phase {
        kind: PhaseKind::ContextDdmm,
        cycles: ctx_cycles,
        hop_events: n_shards * shard_stream * heads * dc,
        ircu_events: n_shards * n_shards * (ctx_macs.div_ceil(macs) + shard_elems.div_ceil(macs)),
        spad_events: n_shards * shard_elems * heads * 3,
        pe_events: 0,
        active_routers: geom.macros_per_channel() as u64 * 2,
    });

    // -- Output: Broadcast 2 along O rows + Reduction 3 + final O DSMM.
    let out_cycles = n_shards * (shard_stream * heads + 2 * dc) + tokens * hw.pe_mvm_cycles;
    lp.phases.push(Phase {
        kind: PhaseKind::OutputReduce,
        cycles: out_cycles,
        hop_events: n_shards * shard_stream * heads * 2 * dc,
        ircu_events: n_shards * shard_elems.div_ceil(macs) * dc,
        spad_events: n_shards * shard_elems * heads,
        pe_events: tokens * dc * dc,
        active_routers: geom.macros_per_channel() as u64,
    });

    // -- MLP: gate/up (D→F) then down (F→D); DSMM streams like Broadcast1 +
    //    Reduction1 on the MLP tiles (3 passes of vector stream + reduce).
    let f = shape.d_ff as u64;
    let f_stream = f.div_ceil(epp);
    let mlp_cycles = tokens * (2 * vec_stream + f_stream) + 3 * dc;
    let dcf = f.div_ceil(hw.xb as u64); // sub-matrix grid cols for D×F
    lp.phases.push(Phase {
        kind: PhaseKind::Mlp,
        cycles: mlp_cycles,
        hop_events: tokens * (2 * (d as u64).div_ceil(epp) * dcf + f_stream * dc),
        ircu_events: tokens * (2 * f.div_ceil(macs) + (d as u64).div_ceil(macs)),
        spad_events: tokens * (2 * f + d as u64),
        pe_events: tokens * 3 * dc * dcf,
        active_routers: (3 * geom.macros_per_channel()) as u64,
    });

    lp
}

/// Decode phase plan: one new token attending to `ctx_len` cached tokens.
///
/// Differences from prefill (§IV-C): a single Q vector (the Q-channel
/// pipeline is underutilised — only one RPU row of work per rotation), and
/// K/V shards are read from the scratchpad cache rather than produced.
pub fn decode_phases(
    shape: &ModelShape,
    geom: &TileGeometry,
    hw: &HwParams,
    ctx_len: usize,
) -> LayerPhases {
    decode_phases_opts(shape, geom, hw, ctx_len, true)
}

/// [`decode_phases`] with the KV-duplication choice explicit (see
/// [`prefill_phases_opts`]).
pub fn decode_phases_opts(
    shape: &ModelShape,
    geom: &TileGeometry,
    hw: &HwParams,
    ctx_len: usize,
    kv_duplication: bool,
) -> LayerPhases {
    let d = shape.d_model;
    let dh = shape.d_head();
    let cs = geom.shard_rows;
    let n_shards = geom.shards_for(ctx_len.max(1)) as u64;
    let epp = hw.elems_per_packet() as u64;
    let dc = geom.dc as u64;
    let nr = geom.n_r as u64;
    let heads = shape.n_heads as u64;
    // Duplicated-KV streaming, matching the paper's GQA→MHA degradation
    // (see prefill_phases_opts; EXPERIMENTS.md carries the ablation).
    let kv_heads = if kv_duplication { heads } else { shape.n_kv_heads as u64 };
    let macs = hw.ircu_macs as u64;

    let mut lp = LayerPhases::default();
    let vec_stream = hw.stream_cycles(d);

    // One token's broadcast + projection + reduce (same as prefill, s = 1).
    lp.phases.push(Phase {
        kind: PhaseKind::InputBroadcast,
        cycles: vec_stream + 2 * dc,
        hop_events: (d as u64).div_ceil(epp) * 2 * dc,
        ircu_events: 0,
        spad_events: d as u64 / nr.max(1),
        pe_events: 0,
        active_routers: (geom.side * geom.side) as u64,
    });
    lp.phases.push(Phase {
        kind: PhaseKind::Projection,
        cycles: hw.pe_mvm_cycles,
        hop_events: 0,
        ircu_events: 0,
        spad_events: 0,
        pe_events: 4 * dc * dc,
        active_routers: 0,
    });
    lp.phases.push(Phase {
        kind: PhaseKind::ProjReduce,
        cycles: hw.stream_cycles(hw.xb) + dc,
        hop_events: 3 * dc * dc * (hw.xb as u64).div_ceil(epp),
        ircu_events: 3 * dc * (hw.xb as u64).div_ceil(macs),
        spad_events: 3 * d as u64 + 2 * d as u64, // project + KV append
        pe_events: 0,
        active_routers: (3 * geom.macros_per_channel()) as u64,
    });

    // Attention over the cache: rotate every cached K shard past the single
    // Q row (Fig. 5(d) rotational broadcast — the rotation is serial per
    // step, which together with the 1-row Q pipeline underutilisation is
    // the §VI-D decode penalty). Only kv_heads-many slices stream.
    let shard_elems = (cs * dh) as u64;
    let shard_stream = shard_elems.div_ceil(epp);
    let rot_cycles = n_shards * (shard_stream * kv_heads / nr.max(1) + nr + dc);
    lp.phases.push(Phase {
        kind: PhaseKind::KShardRotate,
        cycles: rot_cycles,
        hop_events: n_shards * shard_stream * kv_heads * (dc + nr) / nr.max(1),
        ircu_events: 0,
        spad_events: n_shards * shard_elems * kv_heads,
        pe_events: 0,
        active_routers: geom.macros_per_channel() as u64 * 2,
    });

    // Score DDMM: 1×dh · dh×CS per shard per q-head; q-head pairs sharing a
    // kv group compute on adjacent RPU rows, halving the serial factor.
    let score_macs = (dh * cs) as u64 * heads;
    lp.phases.push(Phase {
        kind: PhaseKind::ScoreDdmm,
        cycles: n_shards * score_macs.div_ceil(macs * nr * 2),
        hop_events: 0,
        ircu_events: n_shards * score_macs.div_ceil(macs),
        spad_events: n_shards * shard_elems * kv_heads,
        pe_events: 0,
        active_routers: geom.n_r as u64 * 2, // two RPU rows — underutilised
    });

    // Reduction 2 across RGs for the 1×CS score slivers; the dc RG columns
    // reduce their slices concurrently.
    let sliver = cs as u64 * heads;
    lp.phases.push(Phase {
        kind: PhaseKind::ScoreReduce,
        cycles: n_shards * (sliver.div_ceil(epp * dc) + dc),
        hop_events: n_shards * sliver.div_ceil(epp) * dc,
        ircu_events: n_shards * sliver.div_ceil(macs),
        spad_events: 0,
        pe_events: 0,
        active_routers: geom.n_r as u64 * dc,
    });

    lp.phases.push(Phase {
        kind: PhaseKind::Softmax,
        cycles: n_shards * sliver.div_ceil(macs) * 2,
        hop_events: n_shards * sliver.div_ceil(epp),
        ircu_events: n_shards * 2 * sliver.div_ceil(macs),
        spad_events: n_shards * sliver,
        pe_events: 0,
        active_routers: geom.n_r as u64,
    });

    // Context DDMM: 1×CS · CS×dh per shard per head; V shards stream with
    // kv_heads width and the O accumulate rescales in-flight.
    let ctx_macs = (cs * dh) as u64 * heads;
    lp.phases.push(Phase {
        kind: PhaseKind::ContextDdmm,
        cycles: n_shards
            * (ctx_macs.div_ceil(macs * nr * 2)
                + shard_stream * kv_heads / (nr.max(1) * 2)
                + (dh as u64).div_ceil(epp)),
        hop_events: n_shards * (dh as u64).div_ceil(epp) * kv_heads * dc,
        ircu_events: n_shards * (ctx_macs.div_ceil(macs) + (dh as u64 * heads).div_ceil(macs)),
        spad_events: n_shards * (dh as u64) * kv_heads * 3,
        pe_events: 0,
        active_routers: geom.macros_per_channel() as u64,
    });

    // Output projection of the single token.
    lp.phases.push(Phase {
        kind: PhaseKind::OutputReduce,
        cycles: vec_stream + 2 * dc + hw.pe_mvm_cycles,
        hop_events: (d as u64).div_ceil(epp) * 2 * dc,
        ircu_events: (d as u64).div_ceil(macs) * dc,
        spad_events: d as u64,
        pe_events: dc * dc,
        active_routers: geom.macros_per_channel() as u64,
    });

    // MLP for one token.
    let f = shape.d_ff as u64;
    let f_stream = f.div_ceil(epp);
    let dcf = f.div_ceil(hw.xb as u64);
    lp.phases.push(Phase {
        kind: PhaseKind::Mlp,
        cycles: 2 * vec_stream + f_stream + 3 * dc,
        hop_events: 2 * (d as u64).div_ceil(epp) * dcf + f_stream * dc,
        ircu_events: 2 * f.div_ceil(macs) + (d as u64).div_ceil(macs),
        spad_events: 2 * f + d as u64,
        pe_events: 3 * dc * dcf,
        active_routers: (3 * geom.macros_per_channel()) as u64,
    });

    lp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelPreset;

    fn setup(preset: ModelPreset) -> (ModelShape, TileGeometry, HwParams) {
        let hw = HwParams::default();
        let shape = preset.shape();
        let geom = TileGeometry::for_model(shape.d_model, &hw);
        (shape, geom, hw)
    }

    #[test]
    fn prefill_covers_all_phases() {
        let (shape, geom, hw) = setup(ModelPreset::Llama1B);
        let lp = prefill_phases(&shape, &geom, &hw, 1024);
        let kinds: std::collections::HashSet<_> = lp.phases.iter().map(|p| p.kind).collect();
        assert_eq!(kinds.len(), PhaseKind::ALL.len());
        assert!(lp.total_cycles() > 0);
    }

    #[test]
    fn prefill_scales_with_sequence() {
        let (shape, geom, hw) = setup(ModelPreset::Llama1B);
        let short = prefill_phases(&shape, &geom, &hw, 128).total_cycles();
        let long = prefill_phases(&shape, &geom, &hw, 1024).total_cycles();
        assert!(long > 4 * short, "prefill must scale with S: {short} vs {long}");
    }

    #[test]
    fn decode_scales_with_context() {
        let (shape, geom, hw) = setup(ModelPreset::Llama1B);
        let early = decode_phases(&shape, &geom, &hw, 64).total_cycles();
        let late = decode_phases(&shape, &geom, &hw, 2048).total_cycles();
        assert!(late > early, "decode must slow as the cache grows");
    }

    #[test]
    fn decode_per_token_cheaper_than_prefill_batch() {
        let (shape, geom, hw) = setup(ModelPreset::Llama1B);
        let prefill = prefill_phases(&shape, &geom, &hw, 1024).total_cycles();
        let decode = decode_phases(&shape, &geom, &hw, 1024).total_cycles();
        assert!(decode < prefill, "one decode step ≪ 1024-token prefill");
    }

    #[test]
    fn decode_throughput_well_below_prefill() {
        // §VI-D direction: per-token decode throughput sits well below
        // prefill (single-Q pipeline underutilisation + serial rotation).
        // The paper reports 4–6×; our model measures ~17–30× because our
        // prefill pipelines tokens more aggressively through the channels —
        // a documented deviation analysed in EXPERIMENTS.md §Fig10.
        let (shape, geom, hw) = setup(ModelPreset::Llama1B);
        let s = 1024;
        let prefill_per_tok = prefill_phases(&shape, &geom, &hw, s).total_cycles() as f64 / s as f64;
        let decode_per_tok = decode_phases(&shape, &geom, &hw, s).total_cycles() as f64;
        let ratio = decode_per_tok / prefill_per_tok;
        assert!((3.0..60.0).contains(&ratio), "decode/prefill per-token ratio {ratio:.1}");
    }

    #[test]
    fn pim_not_on_critical_path() {
        // Fig. 11: PIM operations rarely dominate; movement + IRCU do.
        let (shape, geom, hw) = setup(ModelPreset::Llama1B);
        let lp = prefill_phases(&shape, &geom, &hw, 1024);
        let proj = lp.cycles_of(PhaseKind::Projection);
        assert!(proj * 5 < lp.total_cycles(), "PIM {proj} vs total {}", lp.total_cycles());
    }

    #[test]
    fn larger_models_cost_more() {
        let hw = HwParams::default();
        let mut prev = 0;
        for preset in [ModelPreset::Llama1B, ModelPreset::Llama8B, ModelPreset::Llama13B] {
            let shape = preset.shape();
            let geom = TileGeometry::for_model(shape.d_model, &hw);
            let c = prefill_phases(&shape, &geom, &hw, 512).total_cycles();
            assert!(c > prev, "{preset:?} = {c}");
            prev = c;
        }
    }

    #[test]
    fn gqa_aware_ablation_faster_for_gqa_models() {
        // Llama 1B/8B have 4× fewer KV heads; the GQA-aware dataflow must
        // beat duplicated streaming on decode, and be identical for MHA.
        let (shape, geom, hw) = setup(ModelPreset::Llama8B);
        let dup = decode_phases_opts(&shape, &geom, &hw, 1024, true).total_cycles();
        let gqa = decode_phases_opts(&shape, &geom, &hw, 1024, false).total_cycles();
        assert!(gqa < dup, "gqa {gqa} !< dup {dup}");
        let (mha, mgeom, mhw) = setup(ModelPreset::Llama13B);
        let a = decode_phases_opts(&mha, &mgeom, &mhw, 1024, true).total_cycles();
        let b = decode_phases_opts(&mha, &mgeom, &mhw, 1024, false).total_cycles();
        assert_eq!(a, b, "MHA model unaffected by the flag");
    }

    #[test]
    fn event_counts_positive() {
        let (shape, geom, hw) = setup(ModelPreset::Tiny);
        for lp in [prefill_phases(&shape, &geom, &hw, 32), decode_phases(&shape, &geom, &hw, 32)] {
            let hops: u64 = lp.phases.iter().map(|p| p.hop_events).sum();
            let ircu: u64 = lp.phases.iter().map(|p| p.ircu_events).sum();
            let pe: u64 = lp.phases.iter().map(|p| p.pe_events).sum();
            assert!(hops > 0 && ircu > 0 && pe > 0);
        }
    }
}
