//! Temporal mapping (paper §IV): context-window tiling, scratchpad shard
//! layout, the prefill/decode dataflow phase plans, and KV-cache placement.

pub mod dataflow;
pub mod tiling;

pub use dataflow::{
    decode_phases, decode_phases_opts, prefill_phases, prefill_phases_opts, LayerPhases, Phase,
    PhaseKind,
};
pub use tiling::{KvPlacement, ShardLayout, SlotAddr};
