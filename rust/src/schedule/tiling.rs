//! Context-window tiling and scratchpad layout (Fig. 5).
//!
//! Q/K/V are partitioned into shards of C_S = 2·N_r rows; each shard row is
//! distributed across the N_r routers of an RPU, two rows per router column
//! (Fig. 5(c)). Newly generated K/V vectors in decode append into the same
//! layout (§IV-C), which keeps scratchpad occupancy balanced across routers
//! with no data shifting — the invariant `prop_invariants.rs` checks.

use crate::arch::TileGeometry;

/// Scratchpad slot address for one shard row.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlotAddr {
    /// Router index within the RPU (0..N_r).
    pub router: u16,
    /// Word-depth offset within that router's scratchpad.
    pub depth: u32,
}

/// Shard layout bookkeeping for one RPU's scratchpad bank.
#[derive(Debug, Clone)]
pub struct ShardLayout {
    pub shard_rows: usize,
    pub n_routers: usize,
    /// Scratchpad words available per router.
    pub depth_words: usize,
    /// Words one shard row occupies in a router (the d_head sub-vector the
    /// RPU owns, spread across its routers).
    pub row_words: usize,
}

impl ShardLayout {
    pub fn new(geom: &TileGeometry, d_head: usize) -> Self {
        // Each RPU holds a d_head-wide slice; its N_r routers split the
        // slice, two shard rows interleaved per router (C_S = 2·N_r).
        let row_words = d_head.div_ceil(geom.n_r).max(1);
        Self {
            shard_rows: geom.shard_rows,
            n_routers: geom.n_r,
            depth_words: geom.spad_depth,
            row_words,
        }
    }

    /// Scratchpad slot of global token `t` (Fig. 5(b/c)): token t lives in
    /// shard t / C_S, at row t mod C_S; rows are dealt round-robin across
    /// routers, two per router.
    pub fn slot_for_token(&self, t: usize) -> SlotAddr {
        let shard = t / self.shard_rows;
        let row = t % self.shard_rows;
        let router = (row % self.n_routers) as u16;
        let pass = row / self.n_routers; // 0 or 1 (two rows per router)
        let depth = (shard * 2 + pass) * self.row_words;
        SlotAddr { router, depth: depth as u32 }
    }

    /// Max context length this layout supports before scratchpads overflow.
    pub fn capacity_tokens(&self) -> usize {
        // Each token consumes `row_words` in exactly one router; a router
        // receives 2 tokens per shard.
        let shards = self.depth_words / (2 * self.row_words);
        shards * self.shard_rows
    }

    /// Per-router token occupancy after `n` tokens (for the balance check).
    pub fn occupancy(&self, n_tokens: usize) -> Vec<usize> {
        let mut occ = vec![0usize; self.n_routers];
        for t in 0..n_tokens {
            occ[self.slot_for_token(t).router as usize] += 1;
        }
        occ
    }
}

/// KV-cache placement manager for one attention layer (decode appends).
#[derive(Debug, Clone)]
pub struct KvPlacement {
    pub layout: ShardLayout,
    /// Tokens currently cached.
    pub len: usize,
}

impl KvPlacement {
    pub fn new(layout: ShardLayout) -> Self {
        Self { layout, len: 0 }
    }

    /// Append one newly generated K/V vector; returns its slot.
    /// Errors when the scratchpads are full (context-window limit).
    pub fn append(&mut self) -> anyhow::Result<SlotAddr> {
        anyhow::ensure!(
            self.len < self.layout.capacity_tokens(),
            "KV cache full at {} tokens",
            self.len
        );
        let slot = self.layout.slot_for_token(self.len);
        self.len += 1;
        Ok(slot)
    }

    /// Bulk-install a prefill of `n` tokens.
    pub fn fill_prefill(&mut self, n: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.len == 0, "prefill into a non-empty cache");
        anyhow::ensure!(n <= self.layout.capacity_tokens(), "prefill exceeds capacity");
        self.len = n;
        Ok(())
    }

    /// Imbalance = max − min per-router token count. The Fig. 5 placement
    /// guarantees ≤ 2 at every step (one in-fill shard, two rows/router).
    pub fn imbalance(&self) -> usize {
        let occ = self.layout.occupancy(self.len);
        let max = occ.iter().max().copied().unwrap_or(0);
        let min = occ.iter().min().copied().unwrap_or(0);
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwParams;

    fn layout_1b() -> ShardLayout {
        let hw = HwParams::default();
        let geom = TileGeometry::for_model(2048, &hw);
        ShardLayout::new(&geom, 64)
    }

    #[test]
    fn slots_cycle_through_routers() {
        let l = layout_1b(); // C_S = 16, N_r = 8
        let slots: Vec<_> = (0..16).map(|t| l.slot_for_token(t).router).collect();
        // rows deal round-robin: 0..7 then 0..7 again (second pass)
        assert_eq!(&slots[..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&slots[8..], &[0, 1, 2, 3, 4, 5, 6, 7]);
        // next shard goes deeper, same router pattern
        let s16 = l.slot_for_token(16);
        assert_eq!(s16.router, 0);
        assert!(s16.depth > l.slot_for_token(0).depth);
    }

    #[test]
    fn occupancy_balanced_at_any_length() {
        let l = layout_1b();
        for n in [1usize, 7, 16, 100, 1024, 2048] {
            let occ = l.occupancy(n);
            let max = occ.iter().max().unwrap();
            let min = occ.iter().min().unwrap();
            assert!(max - min <= 2, "imbalance {} at n={n}", max - min);
        }
    }

    #[test]
    fn capacity_matches_geometry() {
        let l = layout_1b();
        // depth 16384 words / (2 rows × 8 words/row) = 1024 shards × 16 rows
        assert_eq!(l.capacity_tokens(), 16384);
    }

    #[test]
    fn append_until_full_then_error() {
        let hw = HwParams::default();
        let geom = TileGeometry::for_model(256, &hw);
        let mut l = ShardLayout::new(&geom, 64);
        l.depth_words = 256; // shrink for the test
        let cap = l.capacity_tokens();
        let mut kv = KvPlacement::new(l);
        for _ in 0..cap {
            kv.append().unwrap();
        }
        assert!(kv.append().is_err());
    }

    #[test]
    fn prefill_then_decode_appends_continue_pattern() {
        let mut kv = KvPlacement::new(layout_1b());
        kv.fill_prefill(1000).unwrap();
        let s = kv.append().unwrap();
        assert_eq!(s, kv.layout.slot_for_token(1000));
        assert!(kv.imbalance() <= 2);
    }

    #[test]
    fn prefill_rejects_refill_and_overflow() {
        let mut kv = KvPlacement::new(layout_1b());
        kv.fill_prefill(10).unwrap();
        assert!(kv.fill_prefill(10).is_err());
        let mut kv2 = KvPlacement::new(layout_1b());
        assert!(kv2.fill_prefill(usize::MAX / 2).is_err());
    }
}
