//! Crossbar PE state machine and cost model.

use crate::arch::HwParams;

/// Lifecycle state of one crossbar array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeState {
    /// No weights programmed; MVMs are invalid.
    Blank,
    /// Holds a weight sub-matrix; identified by an opaque tag
    /// (weight id + grid coordinates, assigned by the compiler).
    Programmed { tag: u32 },
}

/// One PIM PE: state + event counters feeding the energy model.
#[derive(Debug, Clone)]
pub struct PimPe {
    pub state: PeState,
    /// Completed MVM operations.
    pub mvm_count: u64,
    /// Cell-programming passes (each is ~10⁴× an MVM in energy — the
    /// reason DDMMs never map to PIM).
    pub program_count: u64,
}

impl Default for PimPe {
    fn default() -> Self {
        Self { state: PeState::Blank, mvm_count: 0, program_count: 0 }
    }
}

impl PimPe {
    /// Program a weight sub-matrix into the array.
    pub fn program(&mut self, tag: u32) {
        self.state = PeState::Programmed { tag };
        self.program_count += 1;
    }

    /// Execute one in-place MVM; errors if the array is blank.
    pub fn mvm(&mut self) -> anyhow::Result<()> {
        anyhow::ensure!(
            matches!(self.state, PeState::Programmed { .. }),
            "MVM on a blank crossbar"
        );
        self.mvm_count += 1;
        Ok(())
    }

    /// Latency of one crossbar MVM in cycles (DAC settle + analog dot +
    /// ADC readout, pipelined across columns).
    pub fn mvm_cycles(hw: &HwParams) -> u64 {
        hw.pe_mvm_cycles
    }

    /// Latency of programming a full sub-matrix (write-verify per row) —
    /// orders of magnitude above an MVM; the compiler treats it as a
    /// deployment-time cost only.
    pub fn program_cycles(hw: &HwParams) -> u64 {
        // ~100 cycles per row write-verify at 1 GHz ≈ 12.8 µs per array.
        100 * hw.xb as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blank_pe_rejects_mvm() {
        let mut pe = PimPe::default();
        assert!(pe.mvm().is_err());
        pe.program(7);
        assert!(pe.mvm().is_ok());
        assert_eq!(pe.mvm_count, 1);
    }

    #[test]
    fn programming_dwarfs_mvm_latency() {
        let hw = HwParams::default();
        assert!(PimPe::program_cycles(&hw) > 1000 * PimPe::mvm_cycles(&hw));
    }

    #[test]
    fn reprogram_tracks_count() {
        let mut pe = PimPe::default();
        pe.program(1);
        pe.program(2);
        assert_eq!(pe.program_count, 2);
        assert_eq!(pe.state, PeState::Programmed { tag: 2 });
    }
}
