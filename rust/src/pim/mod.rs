//! PIM processing element model: a 128×128 RRAM crossbar performing
//! in-place DSMM (dynamic activation × static 8-bit weights).
//!
//! Timing/energy constants are adopted from the macro of Peng et al. [15]
//! as cited in the paper's Table II (32.37 µW, 0.0864 mm² per PE). The
//! functional path lives in the Pallas `crossbar_mvm` kernel; this module
//! provides the simulator-facing latency/energy/occupancy model plus weight
//! programming state tracking (reprogramming RRAM is the expensive
//! operation that motivates keeping DDMMs out of PIM — Challenge 1).

pub mod pe;

pub use pe::{PeState, PimPe};
