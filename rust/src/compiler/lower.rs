//! Phase → instruction lowering.
//!
//! Each [`Phase`] becomes one or more NPM instructions over the tile's
//! router selection, with CMD pairs chosen so movement and IRCU work
//! co-issue where the dataflow overlaps them (the Fig. 6 pipelines).

use crate::arch::TileGeometry;
use crate::isa::{Cmd, Instruction, Opcode, Program, SelBits};
use crate::schedule::{LayerPhases, Phase, PhaseKind};

/// Clamp a u64 cycle count into the u16 CMD_rep field, splitting into
/// multiple instructions when necessary.
fn push_repeated(prog: &mut Program, make: impl Fn(u16) -> Instruction, mut cycles: u64) {
    const MAX: u64 = u16::MAX as u64;
    while cycles > 0 {
        let rep = cycles.min(MAX) as u16;
        prog.push(make(rep));
        cycles -= rep as u64;
    }
}

/// Lower one phase onto the tile geometry.
fn lower_phase(prog: &mut Program, p: &Phase, geom: &TileGeometry) {
    let side = (2 * geom.dc) as u16;
    let half = geom.n_r as u16;
    // Channel column extents in the Fig. 4 layout (K, Q, V, O strips).
    let (k_lo, q_lo, v_lo, o_lo) = (0, half, 2 * half, 3 * half);
    let all = SelBits::All;
    let q_chan = SelBits::Cols { lo: q_lo, hi: q_lo + half };
    let v_chan = SelBits::Cols { lo: v_lo, hi: v_lo + half };
    let o_chan = SelBits::Cols { lo: o_lo, hi: o_lo + half };
    let kq_chans = SelBits::Cols { lo: k_lo, hi: q_lo + half };
    let qkv = SelBits::Cols { lo: 0, hi: 3 * half };
    let _ = side;

    match p.kind {
        PhaseKind::InputBroadcast => push_repeated(
            prog,
            |rep| Instruction::uni(Cmd::new(Opcode::BcastRow, 4), rep, qkv),
            p.cycles,
        ),
        PhaseKind::Projection => push_repeated(
            prog,
            |rep| Instruction::uni(Cmd::new(Opcode::PeMvm, 0), rep, all),
            p.cycles,
        ),
        PhaseKind::ProjReduce => push_repeated(
            prog,
            // reduce east in K/Q channels while V reduces south — the two
            // non-conflicting paths of a CMD pair (§V-A).
            |rep| {
                Instruction::dual(
                    Cmd::new(Opcode::ReduceE, 5),
                    Cmd::new(Opcode::SpadWr, 5),
                    rep,
                    SelBits::SplitRows { lo: 0, hi: side / 2, lo2: side / 2, hi2: side },
                )
            },
            p.cycles,
        ),
        PhaseKind::KShardRotate => push_repeated(
            prog,
            |rep| {
                Instruction::dual(
                    Cmd::new(Opcode::SpadRd, 0),
                    Cmd::new(Opcode::RouteE, 0),
                    rep,
                    kq_chans,
                )
            },
            p.cycles,
        ),
        PhaseKind::ScoreDdmm => push_repeated(
            prog,
            |rep| Instruction::uni(Cmd::new(Opcode::Mac, 4), rep, q_chan),
            p.cycles,
        ),
        PhaseKind::ScoreReduce => push_repeated(
            prog,
            |rep| Instruction::uni(Cmd::new(Opcode::ReduceS, 1), rep, q_chan),
            p.cycles,
        ),
        PhaseKind::Softmax => push_repeated(
            prog,
            |rep| Instruction::uni(Cmd::new(Opcode::ExpMax, 0), rep, q_chan),
            p.cycles,
        ),
        PhaseKind::ContextDdmm => push_repeated(
            prog,
            |rep| {
                Instruction::dual(
                    Cmd::new(Opcode::Mac, 4),
                    Cmd::new(Opcode::RouteE, 0),
                    rep,
                    v_chan,
                )
            },
            p.cycles,
        ),
        PhaseKind::OutputReduce => push_repeated(
            prog,
            |rep| {
                Instruction::dual(
                    Cmd::new(Opcode::BcastRow, 0),
                    Cmd::new(Opcode::ReduceS, 1),
                    rep,
                    SelBits::SplitRows { lo: 0, hi: side / 2, lo2: side / 2, hi2: side },
                )
            },
            p.cycles,
        ),
        PhaseKind::Mlp => push_repeated(
            prog,
            |rep| {
                Instruction::dual(
                    Cmd::new(Opcode::BcastRow, 4),
                    Cmd::new(Opcode::PeMvm, 0),
                    rep,
                    SelBits::SplitRows { lo: 0, hi: side / 2, lo2: side / 2, hi2: side },
                )
            },
            p.cycles,
        ),
    }
    // one SYNC barrier between phases (the controller's phase boundary)
    prog.push(Instruction::uni(Cmd::new(Opcode::Sync, 0), 1, o_chan));
}

/// Lower a full phase plan into an NPM program.
pub fn lower_phases(label: &str, lp: &LayerPhases, geom: &TileGeometry) -> Program {
    let mut prog = Program::new(label);
    for p in &lp.phases {
        lower_phase(&mut prog, p, geom);
    }
    prog.sealed()
}

/// Controller cycles the lowered program will take (Σ rep + issue), used to
/// cross-check against the analytical phase total.
pub fn lowered_cycles(lp: &LayerPhases) -> u64 {
    lp.total_cycles()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwParams;
    use crate::model::ModelPreset;
    use crate::schedule::prefill_phases;

    fn plan() -> (LayerPhases, TileGeometry) {
        let hw = HwParams::default();
        let shape = ModelPreset::Llama1B.shape();
        let geom = TileGeometry::for_model(shape.d_model, &hw);
        (prefill_phases(&shape, &geom, &hw, 256), geom)
    }

    #[test]
    fn lowered_program_nonempty_and_sealed() {
        let (lp, geom) = plan();
        let p = lower_phases("prefill", &lp, &geom);
        assert!(p.len() > lp.phases.len());
        assert_eq!(p.instrs.last().unwrap().cmd1.op, Opcode::Halt);
    }

    #[test]
    fn rep_cycles_match_phase_cycles() {
        // Σ rep over non-sync instructions == Σ phase cycles: this is the
        // contract that keeps analytical and instruction-level sims aligned.
        let (lp, geom) = plan();
        let p = lower_phases("prefill", &lp, &geom);
        let rep_sum: u64 = p
            .instrs
            .iter()
            .filter(|i| !matches!(i.cmd1.op, Opcode::Sync | Opcode::Halt))
            .map(|i| i.rep as u64)
            .sum();
        assert_eq!(rep_sum, lp.total_cycles());
    }

    #[test]
    fn long_phases_split_across_instructions() {
        let mut prog = Program::new("split");
        push_repeated(
            &mut prog,
            |rep| Instruction::uni(Cmd::new(Opcode::Nop, 0), rep, SelBits::All),
            200_000,
        );
        assert_eq!(prog.len(), 4); // 3×65535 + remainder
        let total: u64 = prog.instrs.iter().map(|i| i.rep as u64).sum();
        assert_eq!(total, 200_000);
    }

    #[test]
    fn no_conflicting_cmd_pairs() {
        let (lp, geom) = plan();
        let p = lower_phases("prefill", &lp, &geom);
        for i in &p.instrs {
            assert!(!i.cmd1.conflicts_with(i.cmd2), "{i:?}");
        }
    }
}
