//! The top-level compiler: preset → mapping → per-layer NPM programs,
//! with a program cache keyed by (phase, context bucket) so serving doesn't
//! recompile every decode step.

use std::collections::HashMap;

use crate::arch::{HwParams, TileGeometry};
use crate::isa::Program;
use crate::mapping::{explore, paper_mapping, Candidate};
use crate::model::{ModelPreset, ModelShape};
use crate::partition::AttentionDag;
use crate::schedule::{decode_phases, prefill_phases};

use super::lower::lower_phases;

/// Programs for one decoder layer (prefill variant + decode variants).
#[derive(Debug, Clone, Default)]
pub struct LayerPrograms {
    pub prefill: Option<Program>,
    /// Decode programs bucketed by context length (power-of-two buckets).
    pub decode: HashMap<usize, Program>,
}

/// A fully compiled model: mapping + geometry + per-layer programs.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    pub shape: ModelShape,
    pub geom: TileGeometry,
    pub hw: HwParams,
    pub mapping: Candidate,
    pub dag: AttentionDag,
    layers: LayerPrograms,
    /// Compile-cache statistics.
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Compiler options.
#[derive(Debug, Clone)]
pub struct Compiler {
    pub hw: HwParams,
    /// Run the full mapping DSE (`true`) or use the paper's Fig. 4 layout
    /// directly (`false`, the fast path — it is near-optimal anyway).
    pub run_dse: bool,
}

impl Default for Compiler {
    fn default() -> Self {
        Self { hw: HwParams::default(), run_dse: false }
    }
}

impl Compiler {
    /// Compile a model preset: partition, map, and prepare program slots.
    pub fn compile(&self, preset: ModelPreset) -> anyhow::Result<CompiledModel> {
        let shape = preset.shape();
        self.hw.validate()?;
        let geom = TileGeometry::for_model(shape.d_model, &self.hw);
        geom.validate()?;
        let mapping = if self.run_dse && geom.dc >= 2 {
            let res = explore(geom.dc, self.hw.xb, self.hw.packet_bits);
            res.candidates[res.best].clone()
        } else {
            paper_mapping(geom.dc)
        };
        let dag = AttentionDag::build(shape.d_model, self.hw.xb);
        anyhow::ensure!(dag.topo_order().is_some(), "partitioned DAG has a cycle");
        Ok(CompiledModel {
            shape,
            geom,
            hw: self.hw.clone(),
            mapping,
            dag,
            layers: LayerPrograms::default(),
            cache_hits: 0,
            cache_misses: 0,
        })
    }
}

/// Bucket a context length to the next power of two (program reuse).
pub fn ctx_bucket(ctx: usize) -> usize {
    ctx.max(1).next_power_of_two()
}

impl CompiledModel {
    /// The prefill program for `s` tokens (compiled on first use).
    pub fn prefill_program(&mut self, s: usize) -> &Program {
        if self.layers.prefill.is_none() {
            self.cache_misses += 1;
            let lp = prefill_phases(&self.shape, &self.geom, &self.hw, s);
            self.layers.prefill = Some(lower_phases(
                &format!("{}-prefill-s{s}", self.shape.name),
                &lp,
                &self.geom,
            ));
        } else {
            self.cache_hits += 1;
        }
        self.layers.prefill.as_ref().unwrap()
    }

    /// The decode program for context length `ctx` (bucketed cache).
    pub fn decode_program(&mut self, ctx: usize) -> &Program {
        let bucket = ctx_bucket(ctx);
        if !self.layers.decode.contains_key(&bucket) {
            self.cache_misses += 1;
            let lp = decode_phases(&self.shape, &self.geom, &self.hw, bucket);
            let prog = lower_phases(
                &format!("{}-decode-ctx{bucket}", self.shape.name),
                &lp,
                &self.geom,
            );
            self.layers.decode.insert(bucket, prog);
        } else {
            self.cache_hits += 1;
        }
        &self.layers.decode[&bucket]
    }

    /// Number of distinct programs currently cached.
    pub fn cached_programs(&self) -> usize {
        self.layers.decode.len() + usize::from(self.layers.prefill.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_all_presets() {
        for p in ModelPreset::ALL {
            let cm = Compiler::default().compile(p).unwrap();
            assert!(cm.dag.nodes.len() > 10, "{p:?}");
        }
    }

    #[test]
    fn ctx_buckets() {
        assert_eq!(ctx_bucket(1), 1);
        assert_eq!(ctx_bucket(100), 128);
        assert_eq!(ctx_bucket(1024), 1024);
        assert_eq!(ctx_bucket(1025), 2048);
    }

    #[test]
    fn program_cache_hits() {
        let mut cm = Compiler::default().compile(ModelPreset::Llama1B).unwrap();
        cm.decode_program(100);
        cm.decode_program(120); // same bucket (128)
        cm.decode_program(200); // new bucket (256)
        assert_eq!(cm.cache_misses, 2);
        assert_eq!(cm.cache_hits, 1);
        assert_eq!(cm.cached_programs(), 2);
    }

    #[test]
    fn prefill_program_compiled_once() {
        let mut cm = Compiler::default().compile(ModelPreset::Tiny).unwrap();
        let n1 = cm.prefill_program(32).len();
        let n2 = cm.prefill_program(32).len();
        assert_eq!(n1, n2);
        assert_eq!(cm.cache_misses, 1);
        assert_eq!(cm.cache_hits, 1);
    }

    #[test]
    fn dse_mode_selects_valid_mapping() {
        let mut c = Compiler::default();
        c.run_dse = true;
        let cm = c.compile(ModelPreset::Tiny).unwrap();
        // mapping regions must tile the square
        let area: usize = cm.mapping.layouts.iter().map(|l| l.region.area()).sum();
        assert_eq!(area, cm.geom.macros_per_tile());
    }
}
