//! End-to-end compilation pipeline: model preset → partition → spatial
//! mapping → temporal schedule → NPM instruction programs.
//!
//! The compiler lowers each dataflow phase (`schedule::dataflow`) into NPM
//! instructions whose repeat counts equal the phase's critical-path cycles,
//! so the instruction-level simulator and the analytical model agree by
//! construction (cross-checked in `tests/integration_sim.rs`).

pub mod lower;
pub mod pipeline;

pub use lower::lower_phases;
pub use pipeline::{ctx_bucket, CompiledModel, Compiler, LayerPrograms};
