//! # LEAP — LLM inference on a scalable PIM-NoC architecture
//!
//! Reproduction of *"LEAP: LLM Inference on Scalable PIM-NoC Architecture
//! with Balanced Dataflow and Fine-Grained Parallelism"* (cs.AR 2025).
//!
//! This crate is the L3 layer of the three-layer stack (see DESIGN.md):
//! it owns the compiler (model partitioning → spatial mapping → temporal
//! scheduling → NoC ISA), the instruction-level PIM-NoC simulator, the
//! energy/area model, the GPU comparison baselines, the pluggable numerics
//! runtime (pure-Rust reference f32 by default; PJRT execution of the
//! AOT-lowered JAX/Pallas artifacts behind `--features xla`), and the
//! serving coordinator. Python never runs on the request path.
//!
//! Module map (one module per subsystem; see DESIGN.md §4):
//!
//! - [`arch`] — hardware description: Table I parameters, mesh topology,
//!   tile/channel/RPU/RG geometry.
//! - [`model`] — Llama-family shape presets and data-stationarity algebra
//!   (paper Eqs. 1–3).
//! - [`partition`] — weight/intermediate partitioning and the attention
//!   DAG of Fig. 3(b).
//! - [`mapping`] — heuristic spatial-mapping design-space exploration
//!   (§III-B, Fig. 8).
//! - [`schedule`] — temporal mapping: context-window tiling (Fig. 5),
//!   prefill/decode dataflows (Fig. 6), KV-cache placement (§IV-C).
//! - [`isa`] — the NoC instruction set: CMD pairs + configuration word,
//!   assembler/disassembler, double-banked program memory (§V-A).
//! - [`kvcache`] — paged KV cache: block-pooled, prefix-shared KV storage
//!   with copy-on-write and preemption-aware admission.
//! - [`noc`] — router mesh: 5-port routers, FIFOs, IRCUs, output crossbar,
//!   multicast, X-Y routing (§V-B).
//! - [`pim`] — crossbar PE timing/energy model (128×128, 8-bit cells).
//! - [`energy`] — per-event energy + area model seeded from Table II,
//!   45 nm → 7 nm scaling.
//! - [`sim`] — instruction-level simulator (cycle accounting, per-opcode
//!   breakdown for Fig. 11) and the fast analytical mode used for the
//!   end-to-end throughput studies (Figs. 10/12, Table III).
//! - [`compiler`] — end-to-end pipeline from a model preset to per-layer
//!   ISA programs.
//! - [`baselines`] — A100/H100 roofline comparators (Table III).
//! - [`runtime`] — pluggable numerics backends behind the
//!   `NumericsBackend` trait: the pure-Rust reference f32 forward (default)
//!   and the PJRT client wrapper (`--features xla`) that loads
//!   `artifacts/*.hlo.txt`.
//! - [`coordinator`] — serving engine: request queue, batcher,
//!   prefill/decode scheduler (chunked prefill), seeded sampler,
//!   KV-shard manager, metrics.
//! - [`obs`] — structured tracing + telemetry: typed event ring buffer,
//!   log2 latency histograms, Chrome-trace/JSONL/Prometheus exporters.
//! - [`faults`] — deterministic fault injection: a seeded, schedule-driven
//!   `FaultPlan` (pure function of seed × site × call count) the engine
//!   consults at every injectable call site — journal/spill I/O, worker
//!   lanes, block allocation — so chaos runs are exactly reproducible.
//! - [`persist`] — durability: append-only session event journal with
//!   checkpoint compaction (crash recovery resumes token streams
//!   bitwise-identically) and per-session KV spill files that let the
//!   pool oversubscribe past its byte budget without re-prefill.
//! - [`scenario`] — declarative e2e scenario harness: scripted serving
//!   traffic (`.scn` files) with per-session JSON results.
//! - [`testutil`] — deterministic PRNG + mini property-testing harness
//!   (the registry is offline: no proptest/criterion/clap/tokio).

pub mod arch;
pub mod baselines;
pub mod bench_util;
pub mod cli;
pub mod compiler;
pub mod coordinator;
pub mod energy;
pub mod faults;
pub mod isa;
pub mod kvcache;
pub mod mapping;
pub mod model;
pub mod noc;
pub mod obs;
pub mod partition;
pub mod persist;
pub mod pim;
pub mod runtime;
pub mod scenario;
pub mod schedule;
pub mod sim;
pub mod testutil;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
