//! `leap` — the coordinator/CLI entry point.
//!
//! See `leap help` for subcommands; each maps to one of the paper's
//! experiments (DESIGN.md §5).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match leap::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
