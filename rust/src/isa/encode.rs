//! Binary/hex encoding of the instruction stream — the format the
//! co-processor writes into the NPM banks (and the "hex file" the paper's
//! Python API emits; mirrored by `python/compile/noc_asm.py`).
//!
//! Wire layout, 16 bytes per instruction, little-endian:
//!   byte 0     cmd1 opcode        byte 1    cmd1 arg
//!   byte 2     cmd2 opcode        byte 3    cmd2 arg
//!   bytes 4-5  CMD_rep (u16)
//!   byte 6     sel kind (0=All 1=Rows 2=Cols 3=Rect 4=SplitRows)
//!   byte 7     reserved (0)
//!   bytes 8-15 four u16 sel operands (unused ones zero)

use anyhow::{bail, Context};

use super::opcodes::{Cmd, Opcode};
use super::program::{Instruction, Program, SelBits};

/// Bytes per encoded instruction.
pub const INSTR_BYTES: usize = 16;

fn encode_one(i: &Instruction, out: &mut Vec<u8>) {
    out.push(i.cmd1.op as u8);
    out.push(i.cmd1.arg);
    out.push(i.cmd2.op as u8);
    out.push(i.cmd2.arg);
    out.extend_from_slice(&i.rep.to_le_bytes());
    let (kind, ops): (u8, [u16; 4]) = match i.sel {
        SelBits::All => (0, [0; 4]),
        SelBits::Rows { lo, hi } => (1, [lo, hi, 0, 0]),
        SelBits::Cols { lo, hi } => (2, [lo, hi, 0, 0]),
        SelBits::Rect { rlo, rhi, clo, chi } => (3, [rlo, rhi, clo, chi]),
        SelBits::SplitRows { lo, hi, lo2, hi2 } => (4, [lo, hi, lo2, hi2]),
    };
    out.push(kind);
    out.push(0);
    for o in ops {
        out.extend_from_slice(&o.to_le_bytes());
    }
}

fn decode_one(b: &[u8]) -> anyhow::Result<Instruction> {
    let cmd1 = Cmd::new(
        Opcode::from_u8(b[0]).with_context(|| format!("bad opcode {:#x}", b[0]))?,
        b[1],
    );
    let cmd2 = Cmd::new(
        Opcode::from_u8(b[2]).with_context(|| format!("bad opcode {:#x}", b[2]))?,
        b[3],
    );
    let rep = u16::from_le_bytes([b[4], b[5]]);
    let o = |k: usize| u16::from_le_bytes([b[8 + 2 * k], b[9 + 2 * k]]);
    let sel = match b[6] {
        0 => SelBits::All,
        1 => SelBits::Rows { lo: o(0), hi: o(1) },
        2 => SelBits::Cols { lo: o(0), hi: o(1) },
        3 => SelBits::Rect { rlo: o(0), rhi: o(1), clo: o(2), chi: o(3) },
        4 => SelBits::SplitRows { lo: o(0), hi: o(1), lo2: o(2), hi2: o(3) },
        k => bail!("bad sel kind {k}"),
    };
    Ok(Instruction { cmd1, cmd2, rep, sel })
}

/// Assemble a program to the NPM hex format: one 32-hex-char line per
/// instruction (16 bytes), comments allowed with `;`.
pub fn assemble(p: &Program) -> String {
    let mut text = format!("; {}\n", p.label);
    let mut buf = Vec::with_capacity(INSTR_BYTES);
    for i in &p.instrs {
        buf.clear();
        encode_one(i, &mut buf);
        for b in &buf {
            text.push_str(&format!("{b:02x}"));
        }
        text.push('\n');
    }
    text
}

/// Parse a hex file back into a program.
pub fn disassemble(text: &str) -> anyhow::Result<Program> {
    let mut p = Program::new("disassembled");
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split(';').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.len() != 2 * INSTR_BYTES {
            bail!("line {}: expected {} hex chars, got {}", lineno + 1, 2 * INSTR_BYTES, line.len());
        }
        let bytes: Vec<u8> = (0..INSTR_BYTES)
            .map(|k| u8::from_str_radix(&line[2 * k..2 * k + 2], 16))
            .collect::<Result<_, _>>()
            .with_context(|| format!("line {}: bad hex", lineno + 1))?;
        p.push(decode_one(&bytes)?);
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_program() -> Program {
        // Keep in sync with python/compile/noc_asm.py::demo_program().
        let mut p = Program::new("demo");
        p.push(Instruction::uni(Cmd::new(Opcode::PeMvm, 0), 4, SelBits::All));
        p.push(Instruction::dual(
            Cmd::new(Opcode::RouteE, 1),
            Cmd::new(Opcode::Mac, 0),
            32,
            SelBits::SplitRows { lo: 0, hi: 2, lo2: 2, hi2: 4 },
        ));
        p.push(Instruction::uni(
            Cmd::new(Opcode::ReduceS, 0),
            16,
            SelBits::Rect { rlo: 0, rhi: 4, clo: 2, chi: 4 },
        ));
        p.push(Instruction::uni(Cmd::new(Opcode::SpadWr, 2), 8, SelBits::Cols { lo: 1, hi: 3 }));
        p.sealed()
    }

    #[test]
    fn roundtrip() {
        let p = demo_program();
        let hex = assemble(&p);
        let q = disassemble(&hex).unwrap();
        assert_eq!(p.instrs, q.instrs);
    }

    #[test]
    fn golden_hex_stable() {
        // Pins the wire format; python noc_asm emits identical bytes.
        let p = demo_program();
        let hex = assemble(&p);
        let lines: Vec<&str> = hex.lines().filter(|l| !l.starts_with(';')).collect();
        assert_eq!(lines[0], "10000000040000000000000000000000");
        assert_eq!(lines[1], "02010a00200004000000020002000400");
        assert_eq!(lines.len(), 5); // 4 + HALT
    }

    #[test]
    fn rejects_bad_hex() {
        assert!(disassemble("zz").is_err());
        assert!(disassemble("ff000000000000000000000000000000").is_err()); // bad opcode
        let short = "0000";
        assert!(disassemble(short).is_err());
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let p = demo_program();
        let hex = format!("; header\n\n{}\n; trailer\n", assemble(&p));
        let q = disassemble(&hex).unwrap();
        assert_eq!(q.instrs.len(), p.instrs.len());
    }

    #[test]
    fn instr_bytes_constant() {
        let mut buf = Vec::new();
        encode_one(&Instruction::halt(), &mut buf);
        assert_eq!(buf.len(), INSTR_BYTES);
    }
}
