//! Opcode set supported by the routers' IRCUs and port crossbars.
//!
//! The set covers everything the prefill/decode dataflows of §IV need:
//! directed forwards (the output crossbar), row/column multicast, pipelined
//! reductions, the IRCU compute ops (MAC for DDMMs, ADD for reductions, MUL
//! for softmax rescale, EXPMAX for the FlashAttention running max/exp),
//! scratchpad access, PE triggering, and control.

use std::fmt;

/// Router/IRCU operation codes. The `u8` discriminants are the wire
/// encoding — keep in sync with `python/compile/noc_asm.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Opcode {
    /// No operation (an IDLE router slot).
    Nop = 0x00,
    /// Forward one packet to the north port.
    RouteN = 0x01,
    /// Forward one packet to the east port.
    RouteE = 0x02,
    /// Forward one packet to the south port.
    RouteS = 0x03,
    /// Forward one packet to the west port.
    RouteW = 0x04,
    /// Forward one packet to the locally attached PE.
    RoutePe = 0x05,
    /// Multicast a packet to every selected router in the same row.
    BcastRow = 0x06,
    /// Multicast a packet to every selected router in the same column.
    BcastCol = 0x07,
    /// Pipelined partial-sum reduction toward the east (Reduction 1 in K/Q).
    ReduceE = 0x08,
    /// Pipelined partial-sum reduction toward the south (Reduction 1 in V,
    /// Reductions 2/3).
    ReduceS = 0x09,
    /// IRCU multiply-accumulate (DDMM inner product step).
    Mac = 0x0A,
    /// IRCU element-wise add (partial-result summation).
    Add = 0x0B,
    /// IRCU element-wise multiply (softmax rescale, R-Mul).
    Mul = 0x0C,
    /// IRCU running max + exponential (FlashAttention online softmax).
    ExpMax = 0x0D,
    /// Read a word burst from the local scratchpad.
    SpadRd = 0x0E,
    /// Write a word burst to the local scratchpad.
    SpadWr = 0x0F,
    /// Trigger the local PE's in-place crossbar MVM (DSMM).
    PeMvm = 0x10,
    /// Barrier: wait until all selected routers reach this instruction.
    Sync = 0x11,
    /// End of program.
    Halt = 0x12,
}

impl Opcode {
    pub const ALL: [Opcode; 19] = [
        Opcode::Nop,
        Opcode::RouteN,
        Opcode::RouteE,
        Opcode::RouteS,
        Opcode::RouteW,
        Opcode::RoutePe,
        Opcode::BcastRow,
        Opcode::BcastCol,
        Opcode::ReduceE,
        Opcode::ReduceS,
        Opcode::Mac,
        Opcode::Add,
        Opcode::Mul,
        Opcode::ExpMax,
        Opcode::SpadRd,
        Opcode::SpadWr,
        Opcode::PeMvm,
        Opcode::Sync,
        Opcode::Halt,
    ];

    pub fn from_u8(v: u8) -> Option<Self> {
        Self::ALL.into_iter().find(|&op| op as u8 == v)
    }

    /// Does this opcode move data over a mesh link?
    pub fn is_movement(self) -> bool {
        matches!(
            self,
            Opcode::RouteN
                | Opcode::RouteE
                | Opcode::RouteS
                | Opcode::RouteW
                | Opcode::RoutePe
                | Opcode::BcastRow
                | Opcode::BcastCol
                | Opcode::ReduceE
                | Opcode::ReduceS
        )
    }

    /// Does this opcode occupy the IRCU datapath?
    pub fn is_compute(self) -> bool {
        matches!(self, Opcode::Mac | Opcode::Add | Opcode::Mul | Opcode::ExpMax)
    }

    /// Does this opcode access the scratchpad?
    pub fn is_spad(self) -> bool {
        matches!(self, Opcode::SpadRd | Opcode::SpadWr)
    }

    /// Instruction class used for the Fig. 11 cycle breakdown.
    pub fn class(self) -> &'static str {
        match self {
            Opcode::Nop | Opcode::Sync | Opcode::Halt => "ctrl",
            Opcode::Mac => "mul",
            Opcode::Add | Opcode::ExpMax => "add",
            Opcode::Mul => "mul",
            Opcode::SpadRd | Opcode::SpadWr => "spad",
            Opcode::PeMvm => "pim",
            _ => "send",
        }
    }
}

impl fmt::Display for Opcode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

/// One command: opcode + 8-bit argument (port select, burst length class,
/// operand bank — opcode-specific).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Cmd {
    pub op: Opcode,
    pub arg: u8,
}

impl Cmd {
    pub const NOP: Cmd = Cmd { op: Opcode::Nop, arg: 0 };

    pub fn new(op: Opcode, arg: u8) -> Self {
        Self { op, arg }
    }

    /// Two commands conflict if they claim the same router resource
    /// (the paper requires CMD1/CMD2 to use distinct, non-conflicting
    /// paths; the assembler enforces it).
    pub fn conflicts_with(self, other: Cmd) -> bool {
        if self.op == Opcode::Nop || other.op == Opcode::Nop {
            return false;
        }
        (self.op.is_compute() && other.op.is_compute())
            || (self.op.is_spad() && other.op.is_spad())
            || (self.op.is_movement() && other.op.is_movement() && self.op == other.op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_opcodes() {
        for op in Opcode::ALL {
            assert_eq!(Opcode::from_u8(op as u8), Some(op));
        }
        assert_eq!(Opcode::from_u8(0xFF), None);
    }

    #[test]
    fn discriminants_dense_and_stable() {
        // wire format compatibility with python/compile/noc_asm.py
        assert_eq!(Opcode::Nop as u8, 0x00);
        assert_eq!(Opcode::Mac as u8, 0x0A);
        assert_eq!(Opcode::Halt as u8, 0x12);
        for (i, op) in Opcode::ALL.iter().enumerate() {
            assert_eq!(*op as u8 as usize, i);
        }
    }

    #[test]
    fn classes_cover_fig11_legend() {
        let classes: std::collections::HashSet<_> =
            Opcode::ALL.iter().map(|o| o.class()).collect();
        for c in ["send", "mul", "add", "spad", "pim", "ctrl"] {
            assert!(classes.contains(c), "missing class {c}");
        }
    }

    #[test]
    fn conflict_rules() {
        let mac = Cmd::new(Opcode::Mac, 0);
        let add = Cmd::new(Opcode::Add, 0);
        let re = Cmd::new(Opcode::RouteE, 0);
        let rw = Cmd::new(Opcode::RouteW, 0);
        assert!(mac.conflicts_with(add), "two IRCU ops conflict");
        assert!(!re.conflicts_with(rw), "distinct ports don't conflict");
        assert!(re.conflicts_with(re), "same port conflicts");
        assert!(!Cmd::NOP.conflicts_with(mac));
        assert!(!re.conflicts_with(mac), "movement + compute co-issue");
    }

    #[test]
    fn predicates_disjoint() {
        for op in Opcode::ALL {
            let n = [op.is_movement(), op.is_compute(), op.is_spad()]
                .iter()
                .filter(|&&b| b)
                .count();
            assert!(n <= 1, "{op:?} claims multiple resource classes");
        }
    }
}
