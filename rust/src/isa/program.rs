//! Instruction and program representation.
//!
//! An [`Instruction`] is a (CMD1, CMD2) pair plus the configuration word:
//! `CMD_rep` (how many cycles each selected router repeats the commands) and
//! [`SelBits`] (which routers participate, and which of the two commands
//! each one executes). The command crossbar is 3-input (CMD1 / CMD2 / IDLE)
//! × N-output (§V-A).

use std::fmt;

use super::opcodes::{Cmd, Opcode};

/// Router-selection bits of the configuration word.
///
/// The hardware uses an N-bit crossbar select; we encode the common cases
/// the dataflow compiler emits — whole-mesh, row ranges, column ranges, and
/// an explicit split between CMD1 and CMD2 subscribers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SelBits {
    /// Every router executes CMD1 (CMD2 unused).
    All,
    /// Rows `[lo, hi)` execute CMD1; all other routers idle.
    Rows { lo: u16, hi: u16 },
    /// Columns `[lo, hi)` execute CMD1; all other routers idle.
    Cols { lo: u16, hi: u16 },
    /// Columns `[lo, hi)` of rows `[rlo, rhi)` execute CMD1.
    Rect { rlo: u16, rhi: u16, clo: u16, chi: u16 },
    /// Rows `[lo, hi)` run CMD1 and rows `[lo2, hi2)` run CMD2 concurrently
    /// (the "two non-conflicting paths" case of §V-A).
    SplitRows { lo: u16, hi: u16, lo2: u16, hi2: u16 },
}

impl SelBits {
    /// Which command (1 or 2) a router at (x, y) executes; `None` = IDLE.
    pub fn command_for(self, x: u16, y: u16) -> Option<u8> {
        match self {
            SelBits::All => Some(1),
            SelBits::Rows { lo, hi } => (y >= lo && y < hi).then_some(1),
            SelBits::Cols { lo, hi } => (x >= lo && x < hi).then_some(1),
            SelBits::Rect { rlo, rhi, clo, chi } => {
                (y >= rlo && y < rhi && x >= clo && x < chi).then_some(1)
            }
            SelBits::SplitRows { lo, hi, lo2, hi2 } => {
                if y >= lo && y < hi {
                    Some(1)
                } else if y >= lo2 && y < hi2 {
                    Some(2)
                } else {
                    None
                }
            }
        }
    }

    /// Number of routers participating on an `w` × `h` mesh.
    pub fn active_count(self, w: u16, h: u16) -> usize {
        let mut n = 0;
        for y in 0..h {
            for x in 0..w {
                if self.command_for(x, y).is_some() {
                    n += 1;
                }
            }
        }
        n
    }
}

/// One NPM instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instruction {
    pub cmd1: Cmd,
    pub cmd2: Cmd,
    /// Repetition count (cycles the command pair is re-issued).
    pub rep: u16,
    pub sel: SelBits,
}

impl Instruction {
    /// Single-command instruction over a selection.
    pub fn uni(cmd: Cmd, rep: u16, sel: SelBits) -> Self {
        Self { cmd1: cmd, cmd2: Cmd::NOP, rep, sel }
    }

    /// Dual-command instruction; panics if the commands conflict (the
    /// compiler must only co-issue non-conflicting paths).
    pub fn dual(cmd1: Cmd, cmd2: Cmd, rep: u16, sel: SelBits) -> Self {
        assert!(!cmd1.conflicts_with(cmd2), "conflicting command pair {cmd1:?}/{cmd2:?}");
        Self { cmd1, cmd2, rep, sel }
    }

    pub fn halt() -> Self {
        Self::uni(Cmd::new(Opcode::Halt, 0), 1, SelBits::All)
    }

    /// Cycles this instruction occupies on the controller (its repeat count;
    /// issue overhead is one cycle, modelled by the simulator).
    pub fn cycles(&self) -> u64 {
        self.rep.max(1) as u64
    }
}

/// A NoC program: the instruction stream one NPM bank holds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Program {
    pub instrs: Vec<Instruction>,
    /// Human-readable provenance (layer / phase), for diagnostics.
    pub label: String,
}

impl Program {
    pub fn new(label: impl Into<String>) -> Self {
        Self { instrs: Vec::new(), label: label.into() }
    }

    pub fn push(&mut self, i: Instruction) -> &mut Self {
        self.instrs.push(i);
        self
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Total controller cycles: Σ rep + one issue cycle per instruction.
    pub fn controller_cycles(&self) -> u64 {
        self.instrs.iter().map(|i| i.cycles() + 1).sum()
    }

    /// Ensure the program terminates with HALT.
    pub fn sealed(mut self) -> Self {
        if !matches!(self.instrs.last(), Some(i) if i.cmd1.op == Opcode::Halt) {
            self.push(Instruction::halt());
        }
        self
    }
}

impl fmt::Display for Program {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "; program {} ({} instrs)", self.label, self.instrs.len())?;
        for (pc, i) in self.instrs.iter().enumerate() {
            writeln!(
                f,
                "{pc:04}: {:>8}/{:<8} rep={:<5} sel={:?}",
                i.cmd1.op.to_string(),
                i.cmd2.op.to_string(),
                i.rep,
                i.sel
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selbits_semantics() {
        let rows = SelBits::Rows { lo: 2, hi: 4 };
        assert_eq!(rows.command_for(0, 2), Some(1));
        assert_eq!(rows.command_for(7, 3), Some(1));
        assert_eq!(rows.command_for(0, 4), None);
        let split = SelBits::SplitRows { lo: 0, hi: 1, lo2: 1, hi2: 2 };
        assert_eq!(split.command_for(5, 0), Some(1));
        assert_eq!(split.command_for(5, 1), Some(2));
        assert_eq!(split.command_for(5, 2), None);
    }

    #[test]
    fn active_count() {
        assert_eq!(SelBits::All.active_count(4, 4), 16);
        assert_eq!(SelBits::Rows { lo: 1, hi: 3 }.active_count(4, 4), 8);
        assert_eq!(
            SelBits::Rect { rlo: 0, rhi: 2, clo: 0, chi: 2 }.active_count(4, 4),
            4
        );
    }

    #[test]
    #[should_panic(expected = "conflicting")]
    fn dual_rejects_conflicts() {
        Instruction::dual(Cmd::new(Opcode::Mac, 0), Cmd::new(Opcode::Add, 0), 1, SelBits::All);
    }

    #[test]
    fn dual_allows_disjoint_paths() {
        // movement east + IRCU MAC in parallel — Fig. 6's overlapped cycle.
        let i = Instruction::dual(
            Cmd::new(Opcode::RouteE, 0),
            Cmd::new(Opcode::Mac, 0),
            8,
            SelBits::All,
        );
        assert_eq!(i.cycles(), 8);
    }

    #[test]
    fn sealing_appends_halt_once() {
        let p = Program::new("t").sealed();
        assert_eq!(p.len(), 1);
        let p2 = p.sealed();
        assert_eq!(p2.len(), 1);
    }

    #[test]
    fn controller_cycles_counts_issue_overhead() {
        let mut p = Program::new("t");
        p.push(Instruction::uni(Cmd::new(Opcode::RouteE, 0), 10, SelBits::All));
        p.push(Instruction::uni(Cmd::new(Opcode::Mac, 0), 5, SelBits::All));
        assert_eq!(p.controller_cycles(), 11 + 6);
    }
}
