//! The NoC instruction set (paper §V-A).
//!
//! Each instruction carries a command pair (CMD1, CMD2) that executes
//! concurrently along two non-conflicting paths, plus a configuration word
//! encoding the repetition count (CMD_rep) and router-selection bits
//! (Sel_bits). The NoC program memory (NPM) is double-banked so the
//! co-processor configures one bank while the controller drains the other.

pub mod encode;
pub mod npm;
pub mod opcodes;
pub mod program;

pub use encode::{assemble, disassemble, INSTR_BYTES};
pub use npm::{Bank, Npm};
pub use opcodes::{Cmd, Opcode};
pub use program::{Instruction, Program, SelBits};
