//! NoC program memory (NPM): two independent banks, each holding a command
//! register file + configuration registers. The co-processor programs one
//! bank while the NoC main controller drains the other (§V-A), hiding
//! program-load latency behind execution.

use super::program::Program;

/// Bank identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bank {
    B1,
    B2,
}

impl Bank {
    pub fn other(self) -> Bank {
        match self {
            Bank::B1 => Bank::B2,
            Bank::B2 => Bank::B1,
        }
    }
}

/// Double-banked NPM state machine.
#[derive(Debug, Default)]
pub struct Npm {
    bank1: Option<Program>,
    bank2: Option<Program>,
    /// Bank the controller currently reads from.
    active: Option<Bank>,
    /// Programs loaded since construction (for diagnostics/metrics).
    pub loads: u64,
    /// Bank swaps performed.
    pub swaps: u64,
}

impl Npm {
    pub fn new() -> Self {
        Self::default()
    }

    /// Co-processor writes `prog` into the inactive bank. Fails if that
    /// bank is the one currently being executed.
    pub fn load(&mut self, prog: Program) -> anyhow::Result<Bank> {
        let target = match self.active {
            Some(b) => b.other(),
            None => Bank::B1,
        };
        match target {
            Bank::B1 => self.bank1 = Some(prog),
            Bank::B2 => self.bank2 = Some(prog),
        }
        self.loads += 1;
        Ok(target)
    }

    /// Controller switches to the most recently loaded bank and returns the
    /// program to execute.
    pub fn swap(&mut self) -> anyhow::Result<&Program> {
        let next = match self.active {
            Some(b) => b.other(),
            None => Bank::B1,
        };
        let prog = match next {
            Bank::B1 => self.bank1.as_ref(),
            Bank::B2 => self.bank2.as_ref(),
        };
        anyhow::ensure!(prog.is_some(), "swap to empty NPM bank {next:?}");
        self.active = Some(next);
        self.swaps += 1;
        Ok(match next {
            Bank::B1 => self.bank1.as_ref().unwrap(),
            Bank::B2 => self.bank2.as_ref().unwrap(),
        })
    }

    /// Currently executing program, if any.
    pub fn active_program(&self) -> Option<&Program> {
        match self.active? {
            Bank::B1 => self.bank1.as_ref(),
            Bank::B2 => self.bank2.as_ref(),
        }
    }

    pub fn active_bank(&self) -> Option<Bank> {
        self.active
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program::Instruction;

    fn prog(label: &str) -> Program {
        let mut p = Program::new(label);
        p.push(Instruction::halt());
        p
    }

    #[test]
    fn alternating_banks() {
        let mut npm = Npm::new();
        assert_eq!(npm.load(prog("a")).unwrap(), Bank::B1);
        assert_eq!(npm.swap().unwrap().label, "a");
        assert_eq!(npm.active_bank(), Some(Bank::B1));
        // while B1 executes, the co-processor fills B2
        assert_eq!(npm.load(prog("b")).unwrap(), Bank::B2);
        assert_eq!(npm.swap().unwrap().label, "b");
        assert_eq!(npm.active_bank(), Some(Bank::B2));
        assert_eq!(npm.load(prog("c")).unwrap(), Bank::B1);
        assert_eq!(npm.swap().unwrap().label, "c");
        assert_eq!((npm.loads, npm.swaps), (3, 3));
    }

    #[test]
    fn swap_without_load_fails() {
        let mut npm = Npm::new();
        assert!(npm.swap().is_err());
    }

    #[test]
    fn double_swap_reuses_stale_bank() {
        let mut npm = Npm::new();
        npm.load(prog("a")).unwrap();
        npm.swap().unwrap();
        // swapping again without a new load lands on the empty B2
        assert!(npm.swap().is_err());
    }

    #[test]
    fn active_program_visible() {
        let mut npm = Npm::new();
        assert!(npm.active_program().is_none());
        npm.load(prog("x")).unwrap();
        npm.swap().unwrap();
        assert_eq!(npm.active_program().unwrap().label, "x");
    }
}
