//! Declarative e2e scenario harness: scripted serving traffic against the
//! [`crate::coordinator::ServingEngine`], with machine-readable results.
//!
//! A scenario is a small text script (`.scn`) describing a traffic shape —
//! per-session arrival times, prompt specs, generation configs, expected
//! outcomes — plus engine knobs (chunked-prefill size, batch policy, KV
//! pool shape) and aggregate expectations (minimum preemptions, minimum
//! prefix-cache hits). The runner drives the engine on the *simulated*
//! clock, collects one [`SessionResult`] per scripted session, checks every
//! expectation, and renders the whole run as JSON for CI artifacts.
//!
//! Script format — `#` comments; global `key value` lines; one
//! `session k=v ...` line per request:
//!
//! ```text
//! scenario mixed_length
//! numerics ref              # ref (tiny artifact model) or synthetic
//! chunk 8                   # chunked prefill; omit (or `off`) = monolithic
//! max_batch 8
//! block_size 4              # KV pool overrides (ref numerics only)
//! blocks 12
//! kv_dtype q8               # KV arena storage: f32 (default) | f16 | q8
//! pool_bytes 8192           # size the pool by bytes (ignored with `blocks`)
//! expect_min_preemptions 1
//! expect_max_preemptions 4  # optional upper bound
//! expect_max_queue_wait_ns 900000   # per-session queue-wait ceiling
//! expect_max_spills 0       # optional KV-spill ceiling
//! expect_recovered 0        # exact sessions_recovered count
//! trace on                  # record a structured trace of the run
//! journal on                # crash-safe session journal (scratch dir)
//! spill on                  # spill preempted KV to disk; spill-aware admission
//! fault site=spill_read at=1 mode=transient times=2   # fault plan (see
//!                           # crate::faults; repeat the directive to add
//!                           # clauses, or join clauses with ';')
//! max_waiting 4             # overload cap: shed the lowest-priority
//!                           # waiters beyond this queue depth
//!
//! session arrive=0 prompt=rand:96:11 gen=8 expect=done
//! session arrive=0 prompt=rand:12:12 gen=8 seed=5 temp=0.8 top_k=40
//! session arrive=0 prompt=prefix:8:21+2:31 gen=6 stop=3,4|9
//! session arrive=0 prompt=rand:8:3 gen=4 deadline_ttft_ns=100 expect=timeout
//! session arrive=0 prompt=rand:8:4 gen=4 priority=1 expect=shed
//! ```
//!
//! Prompt specs: `tokens:1,2,3` (literal ids), `rand:LEN:SEED`
//! (deterministic [`SplitMix64`] tokens), and
//! `prefix:PLEN:PSEED+SLEN:SSEED` (a shared deterministic prefix plus a
//! private suffix — sessions repeating the same `PLEN:PSEED` share KV
//! blocks when prefix sharing is on). Arrivals are simulated nanoseconds;
//! a request arriving mid decode-round is observed at the next round
//! boundary, which is the engine's natural scheduling quantum.

use std::path::{Path, PathBuf};

use crate::arch::HwParams;
use crate::coordinator::{
    BatchPolicy, EngineConfig, FinishReason, GenerationConfig, Metrics, Numerics, RequestId,
    ServingEngine, TimelineSummary,
};
use crate::kvcache::{KvCacheConfig, KvDtype};
use crate::model::ModelPreset;
use crate::obs::{chrome_trace_json, events_jsonl, Tracer, DEFAULT_RING_CAPACITY};
use crate::persist::{FsyncPolicy, Journal, SpillStore, DEFAULT_CHECKPOINT_EVERY};
use crate::runtime::{KernelMode, NumericsBackend, ReferenceBackend};
use crate::testutil::SplitMix64;

/// Which numerics the scenario engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumericsKind {
    /// Synthetic tokens (simulation-only; any model preset).
    Synthetic,
    /// The pure-Rust reference backend over the tiny artifact model.
    Reference,
}

/// How one scripted session's prompt is produced.
#[derive(Debug, Clone, PartialEq)]
pub enum PromptSpec {
    /// Literal token ids.
    Tokens(Vec<i32>),
    /// `len` deterministic tokens from `seed` (uniform over the vocab).
    Random { len: usize, seed: u64 },
    /// A shared deterministic prefix plus a private suffix: sessions with
    /// the same `(prefix_len, prefix_seed)` have identical prefixes.
    SharedPrefix { prefix_len: usize, prefix_seed: u64, suffix_len: usize, suffix_seed: u64 },
}

impl PromptSpec {
    /// Materialise the token ids for a backend with `vocab` entries.
    pub fn materialize(&self, vocab: usize) -> Vec<i32> {
        let v = vocab.max(1) as u64;
        let rand = |len: usize, seed: u64| -> Vec<i32> {
            let mut rng = SplitMix64::new(seed);
            (0..len).map(|_| rng.below(v) as i32).collect()
        };
        match self {
            PromptSpec::Tokens(t) => t.clone(),
            PromptSpec::Random { len, seed } => rand(*len, *seed),
            PromptSpec::SharedPrefix { prefix_len, prefix_seed, suffix_len, suffix_seed } => {
                let mut p = rand(*prefix_len, *prefix_seed);
                p.extend(rand(*suffix_len, *suffix_seed));
                p
            }
        }
    }

    /// Prompt length in tokens (materialisation-free).
    pub fn len(&self) -> usize {
        match self {
            PromptSpec::Tokens(t) => t.len(),
            PromptSpec::Random { len, .. } => *len,
            PromptSpec::SharedPrefix { prefix_len, suffix_len, .. } => prefix_len + suffix_len,
        }
    }

    /// True when the prompt has no tokens.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Expected terminal outcome of one scripted session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Expectation {
    /// Completes with generated tokens.
    Done,
    /// Refused with a typed [`crate::coordinator::SubmitError`] (never
    /// queues).
    Rejected,
    /// Admitted but fails or is dropped by the engine.
    Failed,
    /// Aborted with a typed SLO-deadline timeout.
    Timeout,
    /// Shed by the overload policy (priority-based, at admission).
    Shed,
}

impl Expectation {
    fn as_str(self) -> &'static str {
        match self {
            Expectation::Done => "done",
            Expectation::Rejected => "rejected",
            Expectation::Failed => "failed",
            Expectation::Timeout => "timeout",
            Expectation::Shed => "shed",
        }
    }
}

/// One scripted request.
#[derive(Debug, Clone)]
pub struct SessionSpec {
    /// Simulated arrival time, ns (observed at the next round boundary).
    pub arrive_ns: u64,
    pub prompt: PromptSpec,
    pub gen: GenerationConfig,
    pub expect: Expectation,
}

/// Aggregate expectations checked after the run.
#[derive(Debug, Clone, Copy, Default)]
pub struct Expect {
    pub min_preemptions: u64,
    pub min_prefix_hits: u64,
    /// Upper bound on preemptions (`None` = unchecked). The q8 capacity
    /// scenarios use this to prove a bigger pool stops thrashing.
    pub max_preemptions: Option<u64>,
    /// Upper bound on any completed session's queue wait (arrival →
    /// first admission), simulated ns.
    pub max_queue_wait_ns: Option<u64>,
    /// Upper bound on KV spills (`None` = unchecked). `Some(0)` pins a
    /// scenario that must never touch the spill path.
    pub max_spills: Option<u64>,
    /// Exact expected `sessions_recovered` count (`None` = unchecked).
    pub recovered: Option<u64>,
}

/// A parsed scenario script.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub numerics: NumericsKind,
    /// Engine model preset (defaults: Tiny for reference numerics, 1B for
    /// synthetic).
    pub model: Option<ModelPreset>,
    /// Chunked-prefill size (`None` = monolithic prefill).
    pub chunk: Option<usize>,
    pub max_batch: Option<usize>,
    pub max_total_ctx: Option<usize>,
    /// KV pool overrides (reference numerics only).
    pub block_size: Option<usize>,
    pub blocks: Option<usize>,
    pub prefix_sharing: Option<bool>,
    /// KV arena storage dtype (`f32` / `f16` / `q8`).
    pub kv_dtype: Option<KvDtype>,
    /// Size the pool by a byte budget instead of a block count: the block
    /// count becomes `pool_bytes / bytes_per_block(dtype)`, so the same
    /// budget admits ~2×/~4× more blocks at f16/q8 — the capacity
    /// comparison the `prefix_storm_q8` scenario scripts. Ignored when
    /// `blocks` is set explicitly.
    pub pool_bytes: Option<usize>,
    /// Record a structured trace of the run (`trace on`); the report then
    /// carries [`TraceArtifacts`]. Tracing is bitwise-invisible to the
    /// run itself, so expectations behave identically either way.
    pub trace: bool,
    /// Journal the run (`journal on`): session lifecycle records go to a
    /// per-run scratch directory (wiped after the run). Journaling is
    /// bitwise-invisible to token streams.
    pub journal: bool,
    /// Spill preempted KV to disk (`spill on`): readmissions restore
    /// instead of re-prefilling, and admission runs spill-aware
    /// (watermark waived — the oversubscription mode).
    pub spill: bool,
    /// Raw fault-plan clauses from `fault` directives (joined with `;`
    /// and parsed by [`crate::faults::FaultPlan::parse`] at run time).
    pub fault: Option<String>,
    /// Overload cap on the wait queue (`max_waiting N`): excess waiters
    /// are shed lowest-priority-first with a typed outcome.
    pub max_waiting: Option<usize>,
    pub expect: Expect,
    pub sessions: Vec<SessionSpec>,
}

/// Outcome of one scripted session.
#[derive(Debug, Clone)]
pub struct SessionResult {
    /// Index in script order.
    pub index: usize,
    /// Engine request id (`None` when rejected at submit).
    pub id: Option<RequestId>,
    /// `"done"`, `"rejected"`, `"failed"`, `"timeout"`, or `"shed"`.
    pub outcome: &'static str,
    /// Rendered [`crate::coordinator::SubmitError`] for rejections.
    pub rejected: Option<String>,
    pub prompt_tokens: usize,
    pub output: Vec<i32>,
    pub finish: Option<FinishReason>,
    pub ttft_ns: Option<u64>,
    pub latency_ns: Option<u64>,
    pub preemptions: u32,
    /// Per-phase lifetime breakdown (queue wait / prefill / decode);
    /// all-`None` for rejected sessions.
    pub timeline: TimelineSummary,
    /// Did the outcome match the script's `expect=`?
    pub expect_ok: bool,
}

/// Rendered trace exports of one traced scenario run (`trace on`, or the
/// CLI's `--trace` override). The report JSON carries only the summary
/// counts; the rendered documents are for the CLI to write as artifacts.
#[derive(Debug, Clone)]
pub struct TraceArtifacts {
    /// Chrome trace-event JSON (Perfetto-loadable).
    pub chrome_json: String,
    /// One JSON object per event, newline-delimited.
    pub jsonl: String,
    /// Total events emitted.
    pub recorded: u64,
    /// Events lost to ring wrap-around.
    pub dropped: u64,
}

/// One full scenario run: per-session results + engine metrics +
/// expectation verdicts.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub scenario: String,
    pub numerics: NumericsKind,
    pub chunk: Option<usize>,
    pub sessions: Vec<SessionResult>,
    pub metrics: Metrics,
    /// Rendered trace exports (`None` when tracing was off).
    pub trace: Option<TraceArtifacts>,
    /// Human-readable expectation failures (empty = passed).
    pub expect_failures: Vec<String>,
}

impl ScenarioReport {
    /// True when every per-session and aggregate expectation held.
    pub fn passed(&self) -> bool {
        self.expect_failures.is_empty()
    }

    /// Render the report as a JSON object (hand-rolled — serde is not in
    /// the offline registry; the schema is pinned by
    /// `tests/integration_scenarios.rs`).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push('{');
        push_kv_str(&mut s, "scenario", &self.scenario);
        s.push(',');
        push_kv_str(
            &mut s,
            "numerics",
            match self.numerics {
                NumericsKind::Synthetic => "synthetic",
                NumericsKind::Reference => "ref",
            },
        );
        s.push(',');
        match self.chunk {
            Some(c) => s.push_str(&format!("\"chunk\":{c}")),
            None => s.push_str("\"chunk\":null"),
        }
        s.push_str(&format!(",\"passed\":{}", self.passed()));
        s.push_str(",\"expect_failures\":[");
        for (i, f) in self.expect_failures.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&json_string(f));
        }
        s.push(']');
        let m = &self.metrics;
        let (tp50, tp99) = m.ttft_p50_p99();
        let (lp50, lp99) = m.latency_p50_p99();
        s.push_str(&format!(
            ",\"metrics\":{{\"requests_done\":{},\"requests_failed\":{},\
             \"requests_rejected\":{},\"requests_stopped\":{},\"requests_timeout\":{},\
             \"requests_shed\":{},\"faults_injected\":{},\"persist_retries\":{},\
             \"preemptions\":{},\
             \"prefill_tokens\":{},\"prefill_chunks\":{},\"decode_tokens\":{},\
             \"sim_time_ns\":{},\"kv_prefix_hits\":{},\"kv_cow_copies\":{},\
             \"kv_peak_blocks_used\":{},\"kv_dtype\":\"{}\",\"kv_bytes_per_token\":{},\
             \"kv_spills\":{},\"kv_spilled_blocks\":{},\"spill_bytes_written\":{},\
             \"spill_bytes_read\":{},\"sessions_recovered\":{},\"recovery_replay_events\":{},\
             \"ttft_p50_ns\":{tp50},\"ttft_p99_ns\":{tp99},\
             \"latency_p50_ns\":{lp50},\"latency_p99_ns\":{lp99}}}",
            m.requests_done,
            m.requests_failed,
            m.requests_rejected,
            m.requests_stopped,
            m.requests_timeout,
            m.requests_shed,
            m.faults_injected,
            m.persist_retries,
            m.preemptions,
            m.prefill_tokens,
            m.prefill_chunks,
            m.decode_tokens,
            m.sim_time_ns,
            m.kv_prefix_hits,
            m.kv_cow_copies,
            m.kv_peak_blocks_used,
            m.kv_dtype.as_str(),
            m.kv_bytes_per_token,
            m.kv_spills,
            m.kv_spilled_blocks,
            m.spill_bytes_written,
            m.spill_bytes_read,
            m.sessions_recovered,
            m.recovery_replay_events,
        ));
        s.push_str(",\"sessions\":[");
        for (i, r) in self.sessions.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push('{');
            s.push_str(&format!("\"index\":{}", r.index));
            match r.id {
                Some(id) => s.push_str(&format!(",\"id\":{id}")),
                None => s.push_str(",\"id\":null"),
            }
            s.push(',');
            push_kv_str(&mut s, "outcome", r.outcome);
            match &r.rejected {
                Some(msg) => s.push_str(&format!(",\"rejected\":{}", json_string(msg))),
                None => s.push_str(",\"rejected\":null"),
            }
            s.push_str(&format!(
                ",\"prompt_tokens\":{},\"output_tokens\":{}",
                r.prompt_tokens,
                r.output.len()
            ));
            match r.finish {
                Some(f) => {
                    s.push(',');
                    push_kv_str(&mut s, "finish", f.as_str());
                }
                None => s.push_str(",\"finish\":null"),
            }
            push_kv_opt_u64(&mut s, "ttft_ns", r.ttft_ns);
            push_kv_opt_u64(&mut s, "latency_ns", r.latency_ns);
            push_kv_opt_u64(&mut s, "queue_wait_ns", r.timeline.queue_wait_ns);
            push_kv_opt_u64(&mut s, "prefill_ns", r.timeline.prefill_ns);
            push_kv_opt_u64(&mut s, "decode_ns", r.timeline.decode_ns);
            s.push_str(&format!(",\"restore_ns\":{}", r.timeline.restore_ns));
            s.push_str(&format!(",\"preemptions\":{},\"expect_ok\":{}", r.preemptions, r.expect_ok));
            s.push('}');
        }
        s.push(']');
        match &self.trace {
            Some(t) => s.push_str(&format!(
                ",\"trace\":{{\"recorded\":{},\"dropped\":{}}}",
                t.recorded, t.dropped
            )),
            None => s.push_str(",\"trace\":null"),
        }
        s.push('}');
        s
    }
}

fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn push_kv_str(s: &mut String, key: &str, val: &str) {
    s.push_str(&format!("\"{key}\":{}", json_string(val)));
}

fn push_kv_opt_u64(s: &mut String, key: &str, val: Option<u64>) {
    match val {
        Some(v) => s.push_str(&format!(",\"{key}\":{v}")),
        None => s.push_str(&format!(",\"{key}\":null")),
    }
}

/// A/B report for the chunked-prefill TTFT comparison: the same scenario
/// run with its scripted chunk size and with chunking off. The JSON keeps
/// both full reports plus a per-session TTFT table so CI artifacts show
/// the interleaving win directly.
pub fn chunk_ab_json(on: &ScenarioReport, off: &ScenarioReport) -> String {
    let mut s = String::with_capacity(2048);
    s.push('{');
    push_kv_str(&mut s, "scenario", &on.scenario);
    s.push_str(",\"ttft_ns\":[");
    for (i, (a, b)) in on.sessions.iter().zip(&off.sessions).enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{{\"index\":{},\"prompt_tokens\":{}", a.index, a.prompt_tokens));
        push_kv_opt_u64(&mut s, "chunk_on", a.ttft_ns);
        push_kv_opt_u64(&mut s, "chunk_off", b.ttft_ns);
        let improved = matches!((a.ttft_ns, b.ttft_ns), (Some(x), Some(y)) if x < y);
        s.push_str(&format!(",\"improved\":{improved}}}"));
    }
    s.push_str("],\"chunk_on\":");
    s.push_str(&on.to_json());
    s.push_str(",\"chunk_off\":");
    s.push_str(&off.to_json());
    s.push('}');
    s
}

impl Scenario {
    /// Parse a scenario script (see the module docs for the format).
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut sc = Scenario {
            name: "unnamed".into(),
            numerics: NumericsKind::Synthetic,
            model: None,
            chunk: None,
            max_batch: None,
            max_total_ctx: None,
            block_size: None,
            blocks: None,
            prefix_sharing: None,
            kv_dtype: None,
            pool_bytes: None,
            trace: false,
            journal: false,
            spill: false,
            fault: None,
            max_waiting: None,
            expect: Expect::default(),
            sessions: Vec::new(),
        };
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let ctx = |msg: String| anyhow::anyhow!("line {}: {msg}", ln + 1);
            let (key, rest) = match line.split_once(char::is_whitespace) {
                Some((k, r)) => (k, r.trim()),
                None => (line, ""),
            };
            match key {
                "scenario" => sc.name = rest.to_string(),
                "numerics" => {
                    sc.numerics = match rest {
                        "synthetic" => NumericsKind::Synthetic,
                        "ref" | "reference" => NumericsKind::Reference,
                        other => return Err(ctx(format!("unknown numerics '{other}'"))),
                    }
                }
                "model" => {
                    sc.model = Some(
                        ModelPreset::parse(rest)
                            .ok_or_else(|| ctx(format!("unknown model '{rest}'")))?,
                    )
                }
                "chunk" => {
                    sc.chunk = match rest {
                        "off" | "none" => None,
                        n => Some(parse_num(n).map_err(&ctx)?),
                    }
                }
                "max_batch" => sc.max_batch = Some(parse_num(rest).map_err(&ctx)?),
                "max_total_ctx" => sc.max_total_ctx = Some(parse_num(rest).map_err(&ctx)?),
                "block_size" => sc.block_size = Some(parse_num(rest).map_err(&ctx)?),
                "blocks" => sc.blocks = Some(parse_num(rest).map_err(&ctx)?),
                "prefix_sharing" => {
                    sc.prefix_sharing = Some(match rest {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        other => return Err(ctx(format!("prefix_sharing on|off, got '{other}'"))),
                    })
                }
                "kv_dtype" => {
                    sc.kv_dtype = Some(
                        KvDtype::parse(rest)
                            .ok_or_else(|| ctx(format!("kv_dtype f32|f16|q8, got '{rest}'")))?,
                    )
                }
                "pool_bytes" => sc.pool_bytes = Some(parse_num(rest).map_err(&ctx)?),
                "trace" => {
                    sc.trace = match rest {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        other => return Err(ctx(format!("trace on|off, got '{other}'"))),
                    }
                }
                "journal" => {
                    sc.journal = match rest {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        other => return Err(ctx(format!("journal on|off, got '{other}'"))),
                    }
                }
                "spill" => {
                    sc.spill = match rest {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        other => return Err(ctx(format!("spill on|off, got '{other}'"))),
                    }
                }
                "fault" => {
                    // Validate eagerly for a line-numbered error; the raw
                    // clause text is kept and re-parsed per run (each run
                    // owns its own counting plan state).
                    let joined = match &sc.fault {
                        Some(prev) => format!("{prev}; {rest}"),
                        None => rest.to_string(),
                    };
                    crate::faults::FaultPlan::parse(&joined).map_err(|e| ctx(e.to_string()))?;
                    sc.fault = Some(joined);
                }
                "max_waiting" => sc.max_waiting = Some(parse_num(rest).map_err(&ctx)?),
                "expect_min_preemptions" => {
                    sc.expect.min_preemptions = parse_num(rest).map_err(&ctx)?
                }
                "expect_max_preemptions" => {
                    sc.expect.max_preemptions = Some(parse_num(rest).map_err(&ctx)?)
                }
                "expect_min_prefix_hits" => {
                    sc.expect.min_prefix_hits = parse_num(rest).map_err(&ctx)?
                }
                "expect_max_queue_wait_ns" => {
                    sc.expect.max_queue_wait_ns = Some(parse_num(rest).map_err(&ctx)?)
                }
                "expect_max_spills" => {
                    sc.expect.max_spills = Some(parse_num(rest).map_err(&ctx)?)
                }
                "expect_recovered" => {
                    sc.expect.recovered = Some(parse_num(rest).map_err(&ctx)?)
                }
                "session" => {
                    sc.sessions.push(Self::parse_session(rest).map_err(|e| ctx(e.to_string()))?)
                }
                other => return Err(ctx(format!("unknown directive '{other}'"))),
            }
        }
        anyhow::ensure!(!sc.sessions.is_empty(), "scenario '{}' has no sessions", sc.name);
        Ok(sc)
    }

    fn parse_session(rest: &str) -> anyhow::Result<SessionSpec> {
        let mut spec = SessionSpec {
            arrive_ns: 0,
            prompt: PromptSpec::Tokens(Vec::new()),
            gen: GenerationConfig::default(),
            expect: Expectation::Done,
        };
        for field in rest.split_whitespace() {
            let (k, v) = field
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("session field '{field}' is not key=value"))?;
            match k {
                "arrive" => spec.arrive_ns = parse_num(v).map_err(anyhow::Error::msg)?,
                "prompt" => spec.prompt = Self::parse_prompt(v)?,
                "gen" => spec.gen.max_new_tokens = parse_num(v).map_err(anyhow::Error::msg)?,
                "temp" => spec.gen.temperature = parse_f32(v)?,
                "top_k" => spec.gen.top_k = parse_num(v).map_err(anyhow::Error::msg)?,
                "top_p" => spec.gen.top_p = parse_f32(v)?,
                "rep" => spec.gen.repetition_penalty = parse_f32(v)?,
                "seed" => spec.gen.seed = parse_num(v).map_err(anyhow::Error::msg)?,
                "deadline_ttft_ns" => {
                    spec.gen.ttft_deadline_ns = Some(parse_num(v).map_err(anyhow::Error::msg)?)
                }
                "deadline_total_ns" => {
                    spec.gen.total_deadline_ns = Some(parse_num(v).map_err(anyhow::Error::msg)?)
                }
                "priority" => spec.gen.priority = parse_num(v).map_err(anyhow::Error::msg)?,
                "stop" => {
                    spec.gen.stop = v
                        .split('|')
                        .map(|seq| {
                            seq.split(',')
                                .map(|t| {
                                    t.parse::<i32>().map_err(|_| {
                                        anyhow::anyhow!("bad stop token '{t}' in '{v}'")
                                    })
                                })
                                .collect::<anyhow::Result<Vec<i32>>>()
                        })
                        .collect::<anyhow::Result<Vec<Vec<i32>>>>()?
                }
                "expect" => {
                    spec.expect = match v {
                        "done" => Expectation::Done,
                        "rejected" => Expectation::Rejected,
                        "failed" => Expectation::Failed,
                        "timeout" => Expectation::Timeout,
                        "shed" => Expectation::Shed,
                        other => anyhow::bail!(
                            "expect done|rejected|failed|timeout|shed, got '{other}'"
                        ),
                    }
                }
                other => anyhow::bail!("unknown session field '{other}'"),
            }
        }
        // `gen=0` is a deliberately invalid config scenarios use to script
        // a typed rejection, so it is NOT validated here — the engine's
        // submit path is the thing under test.
        anyhow::ensure!(
            !spec.prompt.is_empty() || matches!(spec.expect, Expectation::Rejected),
            "session needs a prompt= spec (or expect=rejected)"
        );
        Ok(spec)
    }

    fn parse_prompt(v: &str) -> anyhow::Result<PromptSpec> {
        let bad = || anyhow::anyhow!("bad prompt spec '{v}' (tokens:…, rand:LEN:SEED, or prefix:PLEN:PSEED+SLEN:SSEED)");
        let (kind, rest) = v.split_once(':').ok_or_else(bad)?;
        match kind {
            "tokens" => Ok(PromptSpec::Tokens(
                rest.split(',')
                    .map(|t| t.parse::<i32>().map_err(|_| bad()))
                    .collect::<anyhow::Result<Vec<i32>>>()?,
            )),
            "rand" => {
                let (len, seed) = rest.split_once(':').ok_or_else(bad)?;
                Ok(PromptSpec::Random {
                    len: len.parse().map_err(|_| bad())?,
                    seed: seed.parse().map_err(|_| bad())?,
                })
            }
            "prefix" => {
                let (pre, suf) = rest.split_once('+').ok_or_else(bad)?;
                let (plen, pseed) = pre.split_once(':').ok_or_else(bad)?;
                let (slen, sseed) = suf.split_once(':').ok_or_else(bad)?;
                Ok(PromptSpec::SharedPrefix {
                    prefix_len: plen.parse().map_err(|_| bad())?,
                    prefix_seed: pseed.parse().map_err(|_| bad())?,
                    suffix_len: slen.parse().map_err(|_| bad())?,
                    suffix_seed: sseed.parse().map_err(|_| bad())?,
                })
            }
            _ => Err(bad()),
        }
    }

    /// Load and parse a `.scn` script file.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let mut sc = Self::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        if sc.name == "unnamed" {
            if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                sc.name = stem.to_string();
            }
        }
        Ok(sc)
    }

    fn preset(&self) -> ModelPreset {
        self.model.unwrap_or(match self.numerics {
            NumericsKind::Reference => ModelPreset::Tiny,
            NumericsKind::Synthetic => ModelPreset::Llama1B,
        })
    }

    /// Build the scenario's numerics. Reference scenarios resolve the
    /// artifact directory (explicit `artifacts` beats the default search)
    /// and apply the script's KV pool overrides to the backend.
    fn build_numerics(&self, artifacts: Option<&Path>) -> anyhow::Result<Numerics> {
        match self.numerics {
            NumericsKind::Synthetic => Ok(Numerics::synthetic(self.preset().shape().vocab)),
            NumericsKind::Reference => {
                let dir: PathBuf = match artifacts {
                    Some(d) => d.to_path_buf(),
                    None => crate::runtime::default_artifacts_dir(None).ok_or_else(|| {
                        anyhow::anyhow!("reference scenario needs an artifact dir with meta.txt")
                    })?,
                };
                let backend = ReferenceBackend::load(&dir)?;
                let overridden = self.block_size.is_some()
                    || self.blocks.is_some()
                    || self.prefix_sharing.is_some()
                    || self.kv_dtype.is_some()
                    || self.pool_bytes.is_some();
                if !overridden {
                    return Ok(Numerics::Backend(Box::new(backend)));
                }
                let meta = backend.meta();
                let mut cfg = KvCacheConfig::for_model(meta.d_model, meta.s_max);
                if let Some(bs) = self.block_size {
                    cfg.block_size = bs.max(1);
                }
                if let Some(dt) = self.kv_dtype {
                    cfg.dtype = dt;
                }
                if let Some(n) = self.blocks {
                    cfg.n_blocks = n.max(1);
                } else if let Some(bytes) = self.pool_bytes {
                    // dtype is already applied above, so the same byte
                    // budget yields more blocks at f16/q8 than at f32
                    cfg.n_blocks = cfg.blocks_for_bytes(bytes, meta.n_layers, meta.d_model);
                }
                if let Some(ps) = self.prefix_sharing {
                    cfg.prefix_sharing = ps;
                }
                let backend = ReferenceBackend::load_with_opts(&dir, KernelMode::Fast, Some(cfg))?;
                Ok(Numerics::Backend(Box::new(backend)))
            }
        }
    }

    /// Run the scenario with its scripted chunk size.
    pub fn run(&self, artifacts: Option<&Path>) -> anyhow::Result<ScenarioReport> {
        self.run_with_chunk(self.chunk, artifacts)
    }

    /// Run the scenario with an explicit chunked-prefill override (the
    /// chunk-on/off A/B uses this with the scripted size and `None`).
    pub fn run_with_chunk(
        &self,
        chunk: Option<usize>,
        artifacts: Option<&Path>,
    ) -> anyhow::Result<ScenarioReport> {
        self.run_with_opts(chunk, self.trace, artifacts)
    }

    /// Run with explicit chunk and tracing overrides (the CLI's `--trace`
    /// flag forces tracing on for an untraced script).
    pub fn run_with_opts(
        &self,
        chunk: Option<usize>,
        trace: bool,
        artifacts: Option<&Path>,
    ) -> anyhow::Result<ScenarioReport> {
        let numerics = self.build_numerics(artifacts)?;
        let vocab = match &numerics {
            Numerics::Backend(b) => b.vocab(),
            Numerics::Synthetic { vocab } => *vocab,
        };
        let mut policy = BatchPolicy::default();
        if let Some(b) = self.max_batch {
            policy.max_batch = b;
        }
        if let Some(c) = self.max_total_ctx {
            policy.max_total_ctx = c;
        }
        let mut engine = ServingEngine::new(EngineConfig {
            preset: self.preset(),
            hw: HwParams::default(),
            policy,
            numerics,
        })?;
        engine.prefill_chunk = chunk;
        if trace {
            engine.tracer = Tracer::enabled(DEFAULT_RING_CAPACITY);
        }
        if let Some(spec) = &self.fault {
            engine.faults = crate::faults::FaultPlan::parse(spec)?;
        }
        engine.overload.max_waiting = self.max_waiting;
        // Durability knobs live in a per-run scratch directory so parallel
        // test runs never collide; it is wiped once the report is built.
        let mut scratch: Option<PathBuf> = None;
        if self.journal || self.spill {
            static SCRATCH_SEQ: std::sync::atomic::AtomicU64 =
                std::sync::atomic::AtomicU64::new(0);
            let dir = std::env::temp_dir().join(format!(
                "leap_scn_{}_{}",
                std::process::id(),
                SCRATCH_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            ));
            std::fs::create_dir_all(&dir)?;
            if self.journal {
                engine.journal = Some(Journal::create(
                    &dir.join("journal"),
                    FsyncPolicy::Never,
                    DEFAULT_CHECKPOINT_EVERY,
                )?);
            }
            if self.spill {
                engine.spill = Some(SpillStore::create(&dir.join("spill"))?);
                engine.admission.spill_aware = true;
            }
            scratch = Some(dir);
        }

        // submissions in arrival order (stable: ties stay in script order)
        let mut order: Vec<usize> = (0..self.sessions.len()).collect();
        order.sort_by_key(|&i| self.sessions[i].arrive_ns);
        let mut submitted: Vec<(usize, Result<RequestId, String>)> = Vec::new();
        let mut pending = order.into_iter().peekable();
        loop {
            while let Some(&i) = pending.peek() {
                let spec = &self.sessions[i];
                if spec.arrive_ns > engine.now_ns() {
                    break;
                }
                let prompt = spec.prompt.materialize(vocab);
                let res = engine
                    .submit_with(prompt, spec.gen.clone())
                    .map_err(|e| e.to_string());
                submitted.push((i, res));
                pending.next();
            }
            if !engine.step()? {
                match pending.peek() {
                    Some(&i) => engine.advance_clock_to(self.sessions[i].arrive_ns),
                    None => break,
                }
            }
        }

        // collect per-session results in script order
        submitted.sort_by_key(|&(i, _)| i);
        let mut sessions = Vec::with_capacity(submitted.len());
        let mut failures = Vec::new();
        for (i, res) in submitted {
            let spec = &self.sessions[i];
            let r = match res {
                Err(msg) => SessionResult {
                    index: i,
                    id: None,
                    outcome: "rejected",
                    rejected: Some(msg),
                    prompt_tokens: spec.prompt.len(),
                    output: Vec::new(),
                    finish: None,
                    ttft_ns: None,
                    latency_ns: None,
                    preemptions: 0,
                    timeline: TimelineSummary::default(),
                    expect_ok: spec.expect == Expectation::Rejected,
                },
                Ok(id) => match engine.take_finished_request(id) {
                    Some(req) => {
                        let outcome = req.outcome_str();
                        SessionResult {
                            index: i,
                            id: Some(id),
                            outcome,
                            rejected: None,
                            prompt_tokens: req.prompt.len(),
                            ttft_ns: req.ttft_ns(),
                            latency_ns: req.latency_ns(),
                            finish: req.finish,
                            preemptions: req.preemptions,
                            timeline: req.timeline(),
                            output: req.output,
                            expect_ok: outcome == spec.expect.as_str(),
                        }
                    }
                    None => SessionResult {
                        index: i,
                        id: Some(id),
                        outcome: "failed",
                        rejected: None,
                        prompt_tokens: spec.prompt.len(),
                        output: Vec::new(),
                        finish: None,
                        ttft_ns: None,
                        latency_ns: None,
                        preemptions: 0,
                        timeline: TimelineSummary::default(),
                        expect_ok: spec.expect == Expectation::Failed,
                    },
                },
            };
            if !r.expect_ok {
                failures.push(format!(
                    "session {i}: expected {}, got {}{}",
                    spec.expect.as_str(),
                    r.outcome,
                    r.rejected.as_deref().map(|m| format!(" ({m})")).unwrap_or_default()
                ));
            }
            sessions.push(r);
        }
        let m = &engine.metrics;
        if m.preemptions < self.expect.min_preemptions {
            failures.push(format!(
                "expected >= {} preemptions, saw {}",
                self.expect.min_preemptions, m.preemptions
            ));
        }
        if m.kv_prefix_hits < self.expect.min_prefix_hits {
            failures.push(format!(
                "expected >= {} prefix-cache hits, saw {}",
                self.expect.min_prefix_hits, m.kv_prefix_hits
            ));
        }
        if let Some(maxp) = self.expect.max_preemptions {
            if m.preemptions > maxp {
                failures.push(format!(
                    "expected <= {maxp} preemptions, saw {}",
                    m.preemptions
                ));
            }
        }
        if let Some(maxw) = self.expect.max_queue_wait_ns {
            for r in &sessions {
                if let Some(w) = r.timeline.queue_wait_ns {
                    if w > maxw {
                        failures.push(format!(
                            "session {}: queue wait {w} ns exceeds \
                             expect_max_queue_wait_ns {maxw}",
                            r.index
                        ));
                    }
                }
            }
        }
        if let Some(maxs) = self.expect.max_spills {
            if m.kv_spills > maxs {
                failures.push(format!("expected <= {maxs} KV spills, saw {}", m.kv_spills));
            }
        }
        if let Some(rec) = self.expect.recovered {
            if m.sessions_recovered != rec {
                failures.push(format!(
                    "expected exactly {rec} recovered sessions, saw {}",
                    m.sessions_recovered
                ));
            }
        }
        let trace_out = engine.tracer.is_enabled().then(|| TraceArtifacts {
            chrome_json: chrome_trace_json(&engine.tracer),
            jsonl: events_jsonl(&engine.tracer),
            recorded: engine.tracer.recorded(),
            dropped: engine.tracer.dropped(),
        });
        let report = ScenarioReport {
            scenario: self.name.clone(),
            numerics: self.numerics,
            chunk,
            sessions,
            metrics: engine.metrics.clone(),
            trace: trace_out,
            expect_failures: failures,
        };
        // Close the journal/spill files before wiping the scratch dir.
        drop(engine);
        if let Some(dir) = scratch {
            let _ = std::fs::remove_dir_all(&dir);
        }
        Ok(report)
    }

    /// Run the chunk-on/off A/B: the scripted chunk size vs monolithic
    /// prefill. Returns `(on, off)`.
    pub fn run_chunk_ab(
        &self,
        artifacts: Option<&Path>,
    ) -> anyhow::Result<(ScenarioReport, ScenarioReport)> {
        anyhow::ensure!(
            self.chunk.is_some(),
            "scenario '{}' has no chunk size — nothing to A/B",
            self.name
        );
        let on = self.run_with_chunk(self.chunk, artifacts)?;
        let off = self.run_with_chunk(None, artifacts)?;
        Ok((on, off))
    }
}

fn parse_num<T: std::str::FromStr>(s: &str) -> Result<T, String> {
    s.parse().map_err(|_| format!("bad number '{s}'"))
}

fn parse_f32(s: &str) -> anyhow::Result<f32> {
    s.parse().map_err(|_| anyhow::anyhow!("bad float '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SCRIPT: &str = "\
# demo script
scenario demo
numerics synthetic
model 1b
chunk 16
max_batch 4
kv_dtype q8
pool_bytes 65536
trace on
journal on
spill on
expect_min_preemptions 0
expect_max_preemptions 0
expect_max_queue_wait_ns 100000000
expect_max_spills 0
expect_recovered 0

session arrive=0 prompt=rand:40:1 gen=4 expect=done
session arrive=500 prompt=tokens:1,2,3 gen=2 seed=9 temp=0.8 top_k=8 stop=5,6|7
session arrive=0 prompt=rand:4:2 gen=0 expect=rejected
";

    #[test]
    fn parse_roundtrip() {
        let sc = Scenario::parse(SCRIPT).unwrap();
        assert_eq!(sc.name, "demo");
        assert_eq!(sc.numerics, NumericsKind::Synthetic);
        assert_eq!(sc.chunk, Some(16));
        assert_eq!(sc.max_batch, Some(4));
        assert_eq!(sc.kv_dtype, Some(KvDtype::Q8));
        assert_eq!(sc.pool_bytes, Some(65536));
        assert!(sc.trace);
        assert!(sc.journal);
        assert!(sc.spill);
        assert_eq!(sc.expect.max_preemptions, Some(0));
        assert_eq!(sc.expect.max_queue_wait_ns, Some(100_000_000));
        assert_eq!(sc.expect.max_spills, Some(0));
        assert_eq!(sc.expect.recovered, Some(0));
        assert_eq!(sc.sessions.len(), 3);
        assert_eq!(sc.sessions[0].prompt.len(), 40);
        assert_eq!(sc.sessions[1].arrive_ns, 500);
        assert_eq!(sc.sessions[1].gen.stop, vec![vec![5, 6], vec![7]]);
        assert!((sc.sessions[1].gen.temperature - 0.8).abs() < 1e-6);
        assert_eq!(sc.sessions[2].expect, Expectation::Rejected);
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = Scenario::parse("bogus directive\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        let err = Scenario::parse("scenario x\nkv_dtype int4\n").unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("kv_dtype"), "{err}");
        let err = Scenario::parse("scenario x\nsession prompt=nope:1\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        // no sessions at all
        assert!(Scenario::parse("scenario empty\n").is_err());
    }

    #[test]
    fn prompt_specs_are_deterministic_and_share_prefixes() {
        let a = PromptSpec::Random { len: 16, seed: 7 }.materialize(512);
        let b = PromptSpec::Random { len: 16, seed: 7 }.materialize(512);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..512).contains(&t)));
        let p1 = PromptSpec::SharedPrefix {
            prefix_len: 8,
            prefix_seed: 3,
            suffix_len: 2,
            suffix_seed: 10,
        }
        .materialize(512);
        let p2 = PromptSpec::SharedPrefix {
            prefix_len: 8,
            prefix_seed: 3,
            suffix_len: 2,
            suffix_seed: 11,
        }
        .materialize(512);
        assert_eq!(p1[..8], p2[..8], "same prefix seed ⇒ identical prefix");
        assert_ne!(p1[8..], p2[8..], "different suffix seeds ⇒ distinct tails");
    }

    #[test]
    fn synthetic_scenario_runs_and_reports() {
        let sc = Scenario::parse(SCRIPT).unwrap();
        let report = sc.run(None).unwrap();
        assert!(report.passed(), "failures: {:?}", report.expect_failures);
        assert_eq!(report.sessions.len(), 3);
        assert_eq!(report.sessions[0].outcome, "done");
        assert_eq!(report.sessions[0].output.len(), 4);
        assert_eq!(report.sessions[1].outcome, "done");
        assert_eq!(report.sessions[2].outcome, "rejected");
        assert!(report.sessions[2].rejected.as_deref().unwrap().contains("max_new_tokens"));
        // the late arrival was observed at (or after) its scripted time
        assert!(report.metrics.requests_done == 2);
        let json = report.to_json();
        assert!(json.contains("\"scenario\":\"demo\""));
        assert!(json.contains("\"passed\":true"));
        assert!(json.contains("\"outcome\":\"rejected\""));
        // synthetic numerics never pool, so the dtype gauge stays default
        assert!(json.contains("\"kv_dtype\":\"f32\""));
        assert!(json.contains("\"kv_bytes_per_token\":0"));
        // per-session phase breakdowns travel in the session objects
        assert!(json.contains("\"queue_wait_ns\":"));
        assert!(json.contains("\"prefill_ns\":"));
        assert!(json.contains("\"decode_ns\":"));
        assert!(json.contains("\"restore_ns\":0"));
        // durability counters ride in the metrics block (all zero here:
        // synthetic numerics never spill and nothing was recovered)
        assert!(json.contains("\"kv_spills\":0"));
        assert!(json.contains("\"kv_spilled_blocks\":0"));
        assert!(json.contains("\"spill_bytes_written\":0"));
        assert!(json.contains("\"spill_bytes_read\":0"));
        assert!(json.contains("\"sessions_recovered\":0"));
        assert!(json.contains("\"recovery_replay_events\":0"));
        // `trace on` produced artifacts and the summary counts
        let trace = report.trace.as_ref().expect("trace on");
        assert!(trace.recorded > 0);
        assert!(trace.chrome_json.contains("\"traceEvents\""));
        assert!(trace.jsonl.lines().count() > 0);
        assert!(json.contains("\"trace\":{\"recorded\":"));
    }

    #[test]
    fn tracing_is_invisible_to_the_report() {
        let sc = Scenario::parse(SCRIPT).unwrap();
        let traced = sc.run_with_opts(sc.chunk, true, None).unwrap();
        let untraced = sc.run_with_opts(sc.chunk, false, None).unwrap();
        assert!(untraced.trace.is_none());
        for (a, b) in traced.sessions.iter().zip(&untraced.sessions) {
            assert_eq!(a.output, b.output, "tracing must not change tokens");
            assert_eq!(a.ttft_ns, b.ttft_ns);
            assert_eq!(a.latency_ns, b.latency_ns);
            assert_eq!(a.timeline, b.timeline);
        }
        assert_eq!(traced.metrics.sim_time_ns, untraced.metrics.sim_time_ns);
    }

    #[test]
    fn queue_wait_ceiling_failure_is_reported() {
        // max_batch 1 forces session 1 to queue behind session 0's whole
        // generation; a 0 ns ceiling must flag that wait
        let text = "scenario qw\nnumerics synthetic\nmax_batch 1\n\
                    expect_max_queue_wait_ns 0\n\
                    session arrive=0 prompt=rand:8:1 gen=2 expect=done\n\
                    session arrive=0 prompt=rand:8:2 gen=2 expect=done\n";
        let sc = Scenario::parse(text).unwrap();
        let report = sc.run(None).unwrap();
        assert!(
            report.expect_failures.iter().any(|f| f.contains("queue wait")),
            "expected a queue-wait failure, got {:?}",
            report.expect_failures
        );
    }

    #[test]
    fn expectation_mismatch_fails_the_report() {
        let text = "scenario bad\nnumerics synthetic\nsession prompt=rand:8:1 gen=2 expect=rejected\n";
        let sc = Scenario::parse(text).unwrap();
        let report = sc.run(None).unwrap();
        assert!(!report.passed());
        assert!(report.expect_failures[0].contains("session 0"));
        assert!(report.to_json().contains("\"passed\":false"));
    }

    #[test]
    fn fault_directive_joins_clauses_and_errors_carry_lines() {
        let text = "scenario f\nnumerics synthetic\n\
                    fault site=journal_write at=2\n\
                    fault site=spill_read at=1 mode=transient times=1\n\
                    max_waiting 4\n\
                    session prompt=rand:4:1 gen=2 deadline_total_ns=5000 priority=7\n";
        let sc = Scenario::parse(text).unwrap();
        assert_eq!(
            sc.fault.as_deref(),
            Some("site=journal_write at=2; site=spill_read at=1 mode=transient times=1")
        );
        assert_eq!(sc.max_waiting, Some(4));
        assert_eq!(sc.sessions[0].gen.total_deadline_ns, Some(5000));
        assert_eq!(sc.sessions[0].gen.priority, 7);
        let bad = "scenario x\nfault site=warp_core\nsession prompt=rand:4:1 gen=2\n";
        let err = Scenario::parse(bad).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = Scenario::parse("scenario x\nsession prompt=rand:4:1 gen=2 expect=maybe\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("timeout|shed"), "{err}");
    }

    #[test]
    fn chaos_directives_drive_typed_outcomes() {
        // one admission fault + one overload shed + one queue timeout,
        // each landing on the scripted session with a typed outcome
        let text = "scenario chaos\nnumerics synthetic\nmax_batch 1\nmax_waiting 1\n\
                    fault site=block_alloc at=1 mode=transient times=1\n\
                    session arrive=0 prompt=rand:8:1 gen=2 expect=failed\n\
                    session arrive=0 prompt=rand:8:2 gen=2 priority=1 expect=shed\n\
                    session arrive=0 prompt=rand:8:3 gen=2 deadline_ttft_ns=0 expect=timeout\n";
        let sc = Scenario::parse(text).unwrap();
        let report = sc.run(None).unwrap();
        assert!(report.passed(), "failures: {:?}", report.expect_failures);
        assert_eq!(report.sessions[0].outcome, "failed");
        assert_eq!(report.sessions[1].outcome, "shed");
        assert_eq!(report.sessions[2].outcome, "timeout");
        let json = report.to_json();
        assert!(json.contains("\"requests_timeout\":1"), "{json}");
        assert!(json.contains("\"requests_shed\":1"), "{json}");
        assert!(json.contains("\"faults_injected\":1"), "{json}");
    }

    #[test]
    fn chunk_ab_json_shape() {
        let sc = Scenario::parse(SCRIPT).unwrap();
        let (on, off) = sc.run_chunk_ab(None).unwrap();
        assert_eq!(on.chunk, Some(16));
        assert_eq!(off.chunk, None);
        let json = chunk_ab_json(&on, &off);
        assert!(json.contains("\"ttft_ns\":["));
        assert!(json.contains("\"chunk_on\":{"));
        assert!(json.contains("\"chunk_off\":{"));
    }
}
