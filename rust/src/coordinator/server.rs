//! Threaded server wrapper: a worker thread owns the [`ServingEngine`] and
//! drains an mpsc request channel; clients receive completed outputs over
//! per-request response channels. (std threads — tokio is unavailable in
//! this offline environment; the event loop is the engine's decode-round
//! loop, which is the natural scheduling quantum of this architecture.)
//!
//! PJRT handles are not `Send`, so the engine is *constructed inside* the
//! worker thread from a factory closure, and only the (Send) [`Metrics`]
//! travel back at shutdown.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::engine::ServingEngine;
use super::generation::GenerationConfig;
use super::metrics::Metrics;
use super::request::{FinishReason, RequestId, TimelineSummary};

/// A completed request's outputs. A request refused at submit with a typed
/// [`crate::coordinator::SubmitError`] completes immediately with empty
/// `tokens` and the rendered error in `rejected`.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    /// Typed terminal outcome: `"done"`, `"rejected"`, `"failed"`,
    /// `"timeout"` (SLO deadline), or `"shed"` (overload policy). Every
    /// submitted request receives exactly one completion carrying one of
    /// these — including requests still in flight at shutdown.
    pub outcome: &'static str,
    pub tokens: Vec<i32>,
    pub ttft_ns: Option<u64>,
    pub latency_ns: Option<u64>,
    /// Per-phase lifetime breakdown (queue wait / prefill / decode /
    /// preemptions); all-`None` for rejected requests, which never ran.
    pub timeline: TimelineSummary,
    /// Why generation stopped (`None` for rejected/failed requests).
    pub finish: Option<FinishReason>,
    pub rejected: Option<String>,
}

enum Msg {
    Submit { prompt: Vec<i32>, gen: GenerationConfig, reply: Sender<Completion> },
    Shutdown,
}

/// Handle to the serving thread.
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<anyhow::Result<Metrics>>>,
}

impl Server {
    /// Spawn the worker thread; `factory` builds the engine inside it.
    pub fn spawn<F>(factory: F) -> anyhow::Result<Self>
    where
        F: FnOnce() -> anyhow::Result<ServingEngine> + Send + 'static,
    {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let worker = std::thread::Builder::new()
            .name("leap-serving".into())
            .spawn(move || -> anyhow::Result<Metrics> {
                let mut engine = factory()?;
                let mut pending: Vec<(RequestId, Sender<Completion>)> = Vec::new();
                loop {
                    // drain submissions (block only when idle)
                    if engine.batcher.is_idle() {
                        match rx.recv() {
                            Ok(Msg::Submit { prompt, gen, reply }) => {
                                Self::submit_or_reject(&mut engine, prompt, gen, reply, &mut pending);
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    }
                    while let Ok(msg) = rx.try_recv() {
                        match msg {
                            Msg::Submit { prompt, gen, reply } => {
                                Self::submit_or_reject(&mut engine, prompt, gen, reply, &mut pending);
                            }
                            Msg::Shutdown => {
                                engine.run_until_idle()?;
                                Self::flush(&mut engine, &mut pending);
                                return Ok(engine.metrics.clone());
                            }
                        }
                    }
                    engine.step()?;
                    Self::flush(&mut engine, &mut pending);
                }
                engine.run_until_idle()?;
                Self::flush(&mut engine, &mut pending);
                Ok(engine.metrics.clone())
            })?;
        Ok(Self { tx, worker: Some(worker) })
    }

    /// Submit into the engine, or answer a typed rejection immediately —
    /// a refused request never queues, so its client must not wait on it.
    fn submit_or_reject(
        engine: &mut ServingEngine,
        prompt: Vec<i32>,
        gen: GenerationConfig,
        reply: Sender<Completion>,
        pending: &mut Vec<(RequestId, Sender<Completion>)>,
    ) {
        match engine.submit_with(prompt, gen) {
            Ok(id) => pending.push((id, reply)),
            Err(err) => {
                let _ = reply.send(Completion {
                    id: RequestId::MAX,
                    outcome: "rejected",
                    tokens: Vec::new(),
                    ttft_ns: None,
                    latency_ns: None,
                    timeline: TimelineSummary::default(),
                    finish: None,
                    rejected: Some(err.to_string()),
                });
            }
        }
    }

    fn flush(engine: &mut ServingEngine, pending: &mut Vec<(RequestId, Sender<Completion>)>) {
        pending.retain(|(id, reply)| {
            if let Some(c) = engine.take_completion(*id) {
                let _ = reply.send(c);
                false
            } else {
                true
            }
        });
    }

    /// Submit a prompt for greedy generation; returns a receiver for the
    /// completion.
    pub fn submit(&self, prompt: Vec<i32>, max_new: usize) -> Receiver<Completion> {
        self.submit_with(prompt, GenerationConfig::greedy(max_new))
    }

    /// Submit a prompt with a full per-request [`GenerationConfig`];
    /// returns a receiver for the completion.
    pub fn submit_with(&self, prompt: Vec<i32>, gen: GenerationConfig) -> Receiver<Completion> {
        let (reply, rx) = channel();
        let _ = self.tx.send(Msg::Submit { prompt, gen, reply });
        rx
    }

    /// Shut down and return the final serving metrics.
    pub fn shutdown(mut self) -> anyhow::Result<Metrics> {
        let _ = self.tx.send(Msg::Shutdown);
        let worker = self.worker.take().expect("not yet joined");
        worker.join().map_err(|_| anyhow::anyhow!("serving thread panicked"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwParams;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::engine::{EngineConfig, Numerics};
    use crate::model::ModelPreset;

    fn factory() -> impl FnOnce() -> anyhow::Result<ServingEngine> + Send + 'static {
        || {
            ServingEngine::new(EngineConfig {
                preset: ModelPreset::Llama1B,
                hw: HwParams::default(),
                policy: BatchPolicy::default(),
                numerics: Numerics::Synthetic { vocab: 1000 },
            })
        }
    }

    #[test]
    fn threaded_round_trip() {
        let server = Server::spawn(factory()).unwrap();
        let rx1 = server.submit(vec![1; 32], 4);
        let rx2 = server.submit(vec![2; 16], 6);
        let c1 = rx1.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let c2 = rx2.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(c1.tokens.len(), 4);
        assert_eq!(c2.tokens.len(), 6);
        // phase breakdown travels with the completion and sums to latency
        let t = c1.timeline;
        assert_eq!(
            Some(t.queue_wait_ns.unwrap() + t.prefill_ns.unwrap() + t.decode_ns.unwrap()),
            c1.latency_ns
        );
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.requests_done, 2);
    }

    #[test]
    fn typed_rejection_completes_immediately() {
        let server = Server::spawn(factory()).unwrap();
        let rx = server.submit(vec![], 4); // empty prompt: typed reject
        let c = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(c.tokens.is_empty());
        assert_eq!(c.rejected.as_deref(), Some("empty prompt"));
        // the server stays serviceable
        let ok = server.submit(vec![1; 8], 2);
        assert_eq!(ok.recv_timeout(std::time::Duration::from_secs(30)).unwrap().tokens.len(), 2);
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.requests_rejected, 1);
        assert_eq!(metrics.requests_done, 1);
    }

    #[test]
    fn submit_with_config_round_trips_finish_reason() {
        let server = Server::spawn(factory()).unwrap();
        let gen = GenerationConfig { max_new_tokens: 5, seed: 7, ..Default::default() };
        let rx = server.submit_with(vec![1; 8], gen);
        let c = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens.len(), 5);
        assert_eq!(c.finish, Some(FinishReason::Length));
        // an invalid config rejects immediately with the rendered error
        let bad = GenerationConfig { temperature: -1.0, ..Default::default() };
        let rx = server.submit_with(vec![1; 8], bad);
        let c = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(c.rejected.unwrap().contains("temperature"));
        server.shutdown().unwrap();
    }

    #[test]
    fn shutdown_drains_outstanding() {
        let server = Server::spawn(factory()).unwrap();
        let rx = server.submit(vec![3; 64], 8);
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.requests_done, 1);
        let c = rx.try_recv().unwrap();
        assert_eq!(c.tokens.len(), 8);
        assert_eq!(c.outcome, "done");
    }

    /// Shutdown mid-decode under overload + SLO pressure (ISSUE 10):
    /// every receiver gets exactly one typed completion — done, shed, or
    /// timed out — and the per-outcome counts reconcile with the final
    /// metrics. No receiver hangs (the recv timeouts are the bound).
    #[test]
    fn shutdown_under_load_delivers_every_completion_typed() {
        let server = Server::spawn(|| {
            let mut engine = ServingEngine::new(EngineConfig {
                preset: ModelPreset::Llama1B,
                hw: HwParams::default(),
                policy: BatchPolicy { max_batch: 1, ..BatchPolicy::default() },
                numerics: Numerics::Synthetic { vocab: 1000 },
            })?;
            engine.overload.max_waiting = Some(2);
            Ok(engine)
        })
        .unwrap();
        let mut rxs = Vec::new();
        // one long-running request holds the single batch slot...
        rxs.push(server.submit(vec![1; 48], 16));
        // ...an impossible TTFT deadline that must time out in queue...
        rxs.push(server.submit_with(
            vec![2; 16],
            GenerationConfig { ttft_deadline_ns: Some(0), ..GenerationConfig::greedy(4) },
        ));
        // ...and a burst of queued work across two shedding classes
        for i in 0..6u8 {
            rxs.push(server.submit_with(
                vec![3; 8],
                GenerationConfig { priority: 1 + (i % 2), ..GenerationConfig::greedy(2) },
            ));
        }
        // shut down while all of that is still in flight
        let metrics = server.shutdown().unwrap();
        let mut done = 0u64;
        let mut timeout = 0u64;
        let mut shed = 0u64;
        for (i, rx) in rxs.into_iter().enumerate() {
            let c = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("receiver {i} hung at shutdown: {e}"));
            match c.outcome {
                "done" => {
                    done += 1;
                    assert!(!c.tokens.is_empty(), "request {i}: done with no tokens");
                }
                "timeout" => {
                    timeout += 1;
                    assert!(c.tokens.is_empty(), "request {i}: queue timeouts never decode");
                }
                "shed" => {
                    shed += 1;
                    assert!(c.tokens.is_empty(), "request {i}: shed requests never decode");
                }
                other => panic!("request {i}: untyped outcome '{other}'"),
            }
        }
        assert_eq!(done + timeout + shed, 8, "every receiver answered exactly once");
        assert!(timeout >= 1, "the zero-ns TTFT deadline must fire");
        assert_eq!(metrics.requests_done, done);
        assert_eq!(metrics.requests_timeout, timeout);
        assert_eq!(metrics.requests_shed, shed);
    }

    /// Shutdown arriving mid-chunked-prefill drains cleanly: the long
    /// prompt finishes its remaining chunks during the drain and both
    /// clients get full typed completions.
    #[test]
    fn shutdown_mid_chunked_prefill_drains_cleanly() {
        let server = Server::spawn(|| {
            let mut engine = ServingEngine::new(EngineConfig {
                preset: ModelPreset::Llama1B,
                hw: HwParams::default(),
                policy: BatchPolicy::default(),
                numerics: Numerics::Synthetic { vocab: 1000 },
            })?;
            engine.prefill_chunk = Some(16);
            Ok(engine)
        })
        .unwrap();
        let long = server.submit(vec![4; 96], 4);
        let short = server.submit(vec![5; 8], 2);
        let metrics = server.shutdown().unwrap();
        let c_long = long.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let c_short = short.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(c_long.outcome, "done");
        assert_eq!(c_long.tokens.len(), 4);
        assert_eq!(c_short.outcome, "done");
        assert_eq!(c_short.tokens.len(), 2);
        assert_eq!(metrics.requests_done, 2);
        assert_eq!(metrics.prefill_chunks, 7, "ceil(96/16) + ceil(8/16) dispatches");
    }

    /// Shutdown under load with a live journal: the drain retires every
    /// session, and replaying the journal afterwards reconstructs all of
    /// them finished with the exact streams the clients received.
    #[test]
    fn shutdown_with_journal_reconstructs_finished_sessions() {
        let dir = std::env::temp_dir().join(format!("leap_server_journal_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let jdir = dir.clone();
        let server = Server::spawn(move || {
            let mut engine = ServingEngine::new(EngineConfig {
                preset: ModelPreset::Llama1B,
                hw: HwParams::default(),
                policy: BatchPolicy::default(),
                numerics: Numerics::Synthetic { vocab: 1000 },
            })?;
            engine.journal = Some(crate::persist::Journal::create(
                &jdir,
                crate::persist::FsyncPolicy::Never,
                crate::persist::DEFAULT_CHECKPOINT_EVERY,
            )?);
            Ok(engine)
        })
        .unwrap();
        let rxs: Vec<_> = (0..3).map(|i| server.submit(vec![i + 1; 24], 4 + i as usize)).collect();
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.requests_done, 3);
        let tokens: Vec<Vec<i32>> = rxs
            .into_iter()
            .map(|rx| {
                let c = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
                assert_eq!(c.outcome, "done");
                c.tokens
            })
            .collect();
        let state = crate::persist::reconstruct(&dir).unwrap();
        assert!(!state.torn_tail, "clean shutdown leaves no torn tail");
        assert_eq!(state.sessions.len(), 3);
        assert_eq!(state.unfinished().count(), 0, "drained shutdown retires everything");
        let mut sessions = state.sessions.clone();
        sessions.sort_by_key(|s| s.id);
        for (s, t) in sessions.iter().zip(&tokens) {
            assert!(s.finished && !s.failed);
            assert_eq!(&s.output, t, "journal stream diverged from the delivered completion");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
