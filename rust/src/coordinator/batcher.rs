//! Continuous batcher: FCFS admission into a bounded running batch at
//! decode-round boundaries (the scheduling discipline of vLLM-style
//! serving, adapted to the PIM-NoC system where the batch shares the
//! per-tile scratchpad capacity).
//!
//! Admission is two-stage: the batcher enforces its own caps (batch size,
//! aggregate context budget), then defers to a caller-supplied
//! [`AdmissionDecision`] — the engine's block-pool arithmetic — via
//! [`Batcher::admit_with`]. Preempted requests re-enter at the *head* of
//! the wait queue ([`Batcher::preempt`]), preserving FCFS order across
//! preemption cycles.

use std::collections::VecDeque;

use crate::kvcache::AdmissionDecision;

use super::request::{Request, RequestId, RequestState};

/// Admission policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Maximum concurrent decoding requests.
    pub max_batch: usize,
    /// Maximum total context tokens across the batch (KV capacity guard).
    pub max_total_ctx: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, max_total_ctx: 16_384 }
    }
}

/// FCFS queue + running set.
#[derive(Debug, Default)]
pub struct Batcher {
    pub policy: BatchPolicy,
    waiting: VecDeque<Request>,
    running: Vec<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, waiting: VecDeque::new(), running: Vec::new() }
    }

    /// Enqueue a new request.
    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    /// Total context tokens the running batch will hold after admitting a
    /// request of `extra` prompt tokens.
    fn ctx_with(&self, extra: usize) -> usize {
        self.running.iter().map(|r| r.ctx_len() + r.max_new_tokens() - r.output.len()).sum::<usize>()
            + extra
    }

    /// Admit waiting requests while capacity allows. Returns ids admitted
    /// this round (they need prefill).
    pub fn admit(&mut self) -> Vec<RequestId> {
        self.admit_with(|_| AdmissionDecision::Admit).0
    }

    /// FCFS admission with an external per-request decision (the engine's
    /// pool-backed [`crate::kvcache::AdmissionPolicy`]). The batcher's own
    /// caps apply first; then `decide` rules on the head of the queue:
    /// `Admit` pops it into the running batch (a preempted request resumes
    /// with its generated tokens intact), `Queue` stops this round
    /// head-of-line (no FCFS bypass), and `Reject` removes it for the
    /// caller to fail. Returns `(admitted ids, rejected requests)`.
    pub fn admit_with(
        &mut self,
        mut decide: impl FnMut(&Request) -> AdmissionDecision,
    ) -> (Vec<RequestId>, Vec<Request>) {
        let mut admitted = Vec::new();
        let mut rejected = Vec::new();
        while let Some(front) = self.waiting.front() {
            // remaining budget: current context + tokens still to generate
            let need = front.ctx_len() + front.max_new_tokens() - front.output.len();
            if self.running.len() >= self.policy.max_batch
                || self.ctx_with(need) > self.policy.max_total_ctx
            {
                break;
            }
            match decide(front) {
                AdmissionDecision::Admit => {
                    let mut req = self.waiting.pop_front().unwrap();
                    req.state = RequestState::Prefilling;
                    admitted.push(req.id);
                    self.running.push(req);
                }
                AdmissionDecision::Queue => break,
                AdmissionDecision::Reject => {
                    let mut req = self.waiting.pop_front().unwrap();
                    req.state = RequestState::Failed;
                    rejected.push(req);
                }
            }
        }
        (admitted, rejected)
    }

    /// Pull a running request out of the batch back to the **head** of the
    /// wait queue (pool preemption). Generated tokens are kept; the engine
    /// re-prefills `prompt ++ output` on readmission. Preempting youngest
    /// first and pushing to the front restores arrival order in the queue.
    pub fn preempt(&mut self, id: RequestId) -> bool {
        let Some(i) = self.running.iter().position(|r| r.id == id) else {
            return false;
        };
        let mut req = self.running.remove(i);
        req.state = RequestState::Waiting;
        // the engine released this session's KV: readmission re-prefills
        // prompt ++ output from scratch
        req.prefilled = 0;
        req.preemptions += 1;
        self.waiting.push_front(req);
        true
    }

    /// Retire finished requests out of the running set.
    pub fn retire(&mut self) -> Vec<Request> {
        let mut done = Vec::new();
        self.running.retain_mut(|r| {
            if r.is_finished() {
                done.push(r.clone());
                false
            } else {
                true
            }
        });
        done
    }

    pub fn running(&self) -> &[Request] {
        &self.running
    }

    /// The head of the wait queue, mutably — the engine stamps
    /// `t_enqueued_ns` on the request [`Self::preempt`] just pushed there
    /// (the batcher has no clock of its own).
    pub fn waiting_front_mut(&mut self) -> Option<&mut Request> {
        self.waiting.front_mut()
    }

    pub fn running_mut(&mut self) -> &mut [Request] {
        &mut self.running
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// The wait queue in order (head first) — read-only, for the engine's
    /// deadline sweep and overload shedder to pick victims.
    pub fn waiting(&self) -> impl Iterator<Item = &Request> {
        self.waiting.iter()
    }

    /// Remove every waiting request matching `pred`, preserving FCFS order
    /// among the survivors. Returns the extracted requests in queue order.
    /// Deadline timeouts and load shedding abort through this without
    /// disturbing admission order for everyone else.
    pub fn extract_waiting(&mut self, mut pred: impl FnMut(&Request) -> bool) -> Vec<Request> {
        let mut out = Vec::new();
        let mut kept = VecDeque::with_capacity(self.waiting.len());
        for req in self.waiting.drain(..) {
            if pred(&req) {
                out.push(req);
            } else {
                kept.push_back(req);
            }
        }
        self.waiting = kept;
        out
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: RequestId, prompt: usize, max_new: usize) -> Request {
        Request::new(id, vec![1; prompt], max_new, 0)
    }

    #[test]
    fn fcfs_admission_bounded_by_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_total_ctx: 1000 });
        for i in 0..4 {
            b.submit(req(i, 10, 10));
        }
        let adm = b.admit();
        assert_eq!(adm, vec![0, 1]);
        assert_eq!(b.running().len(), 2);
        assert_eq!(b.waiting_len(), 2);
    }

    #[test]
    fn admission_bounded_by_ctx_budget() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_total_ctx: 50 });
        b.submit(req(0, 20, 10)); // needs 30
        b.submit(req(1, 15, 10)); // needs 25 → total 55 > 50
        let adm = b.admit();
        assert_eq!(adm, vec![0]);
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn retire_then_admit_backfills() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 1, max_total_ctx: 1000 });
        b.submit(req(0, 5, 5));
        b.submit(req(1, 5, 5));
        b.admit();
        b.running_mut()[0].state = RequestState::Done;
        let done = b.retire();
        assert_eq!(done.len(), 1);
        let adm = b.admit();
        assert_eq!(adm, vec![1]);
    }

    #[test]
    fn fcfs_order_preserved_no_head_of_line_bypass() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_total_ctx: 40 });
        b.submit(req(0, 38, 1)); // huge: fills the budget
        b.submit(req(1, 2, 2)); // small, but FCFS must not bypass
        b.admit();
        assert_eq!(b.running().len(), 1);
        assert_eq!(b.running()[0].id, 0);
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn admit_with_queue_is_head_of_line() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.submit(req(0, 10, 4));
        b.submit(req(1, 10, 4));
        // queue the head → nothing admitted, FCFS preserved
        let (adm, rej) = b.admit_with(|_| AdmissionDecision::Queue);
        assert!(adm.is_empty() && rej.is_empty());
        assert_eq!(b.waiting_len(), 2);
        // reject the head, admit the next
        let (adm, rej) = b.admit_with(|r| {
            if r.id == 0 {
                AdmissionDecision::Reject
            } else {
                AdmissionDecision::Admit
            }
        });
        assert_eq!(adm, vec![1]);
        assert_eq!(rej.len(), 1);
        assert_eq!(rej[0].id, 0);
        assert_eq!(rej[0].state, RequestState::Failed);
    }

    #[test]
    fn preempt_requeues_at_head_with_output_kept() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.submit(req(0, 4, 8));
        b.submit(req(1, 4, 8));
        b.admit();
        b.running_mut()[1].output.push(42);
        assert!(b.preempt(1));
        assert!(!b.preempt(1), "already preempted");
        assert_eq!(b.running().len(), 1);
        assert_eq!(b.waiting_len(), 1);
        // readmission resumes the same request, generated tokens intact
        let (adm, _) = b.admit_with(|r| {
            assert_eq!(r.output, vec![42]);
            AdmissionDecision::Admit
        });
        assert_eq!(adm, vec![1]);
    }

    #[test]
    fn extract_waiting_preserves_survivor_order() {
        let mut b = Batcher::new(BatchPolicy::default());
        for i in 0..5 {
            b.submit(req(i, 2, 2));
        }
        let out = b.extract_waiting(|r| r.id % 2 == 1);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.waiting().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert!(b.extract_waiting(|_| false).is_empty());
        assert_eq!(b.waiting_len(), 3);
    }

    #[test]
    fn idle_detection() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.is_idle());
        b.submit(req(0, 1, 1));
        assert!(!b.is_idle());
        b.admit();
        b.running_mut()[0].state = RequestState::Done;
        b.retire();
        assert!(b.is_idle());
    }
}
