//! Continuous batcher: FCFS admission into a bounded running batch at
//! decode-round boundaries (the scheduling discipline of vLLM-style
//! serving, adapted to the PIM-NoC system where the batch shares the
//! per-tile scratchpad capacity).

use std::collections::VecDeque;

use super::request::{Request, RequestId, RequestState};

/// Admission policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Maximum concurrent decoding requests.
    pub max_batch: usize,
    /// Maximum total context tokens across the batch (KV capacity guard).
    pub max_total_ctx: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, max_total_ctx: 16_384 }
    }
}

/// FCFS queue + running set.
#[derive(Debug, Default)]
pub struct Batcher {
    pub policy: BatchPolicy,
    waiting: VecDeque<Request>,
    running: Vec<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, waiting: VecDeque::new(), running: Vec::new() }
    }

    /// Enqueue a new request.
    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    /// Total context tokens the running batch will hold after admitting a
    /// request of `extra` prompt tokens.
    fn ctx_with(&self, extra: usize) -> usize {
        self.running.iter().map(|r| r.ctx_len() + r.max_new_tokens - r.output.len()).sum::<usize>()
            + extra
    }

    /// Admit waiting requests while capacity allows. Returns ids admitted
    /// this round (they need prefill).
    pub fn admit(&mut self) -> Vec<RequestId> {
        let mut admitted = Vec::new();
        while let Some(front) = self.waiting.front() {
            let need = front.prompt.len() + front.max_new_tokens;
            if self.running.len() >= self.policy.max_batch
                || self.ctx_with(need) > self.policy.max_total_ctx
            {
                break;
            }
            let mut req = self.waiting.pop_front().unwrap();
            req.state = RequestState::Prefilling;
            admitted.push(req.id);
            self.running.push(req);
        }
        admitted
    }

    /// Retire finished requests out of the running set.
    pub fn retire(&mut self) -> Vec<Request> {
        let mut done = Vec::new();
        self.running.retain_mut(|r| {
            if r.is_finished() {
                done.push(r.clone());
                false
            } else {
                true
            }
        });
        done
    }

    pub fn running(&self) -> &[Request] {
        &self.running
    }

    pub fn running_mut(&mut self) -> &mut [Request] {
        &mut self.running
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: RequestId, prompt: usize, max_new: usize) -> Request {
        Request::new(id, vec![1; prompt], max_new, 0)
    }

    #[test]
    fn fcfs_admission_bounded_by_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_total_ctx: 1000 });
        for i in 0..4 {
            b.submit(req(i, 10, 10));
        }
        let adm = b.admit();
        assert_eq!(adm, vec![0, 1]);
        assert_eq!(b.running().len(), 2);
        assert_eq!(b.waiting_len(), 2);
    }

    #[test]
    fn admission_bounded_by_ctx_budget() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_total_ctx: 50 });
        b.submit(req(0, 20, 10)); // needs 30
        b.submit(req(1, 15, 10)); // needs 25 → total 55 > 50
        let adm = b.admit();
        assert_eq!(adm, vec![0]);
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn retire_then_admit_backfills() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 1, max_total_ctx: 1000 });
        b.submit(req(0, 5, 5));
        b.submit(req(1, 5, 5));
        b.admit();
        b.running_mut()[0].state = RequestState::Done;
        let done = b.retire();
        assert_eq!(done.len(), 1);
        let adm = b.admit();
        assert_eq!(adm, vec![1]);
    }

    #[test]
    fn fcfs_order_preserved_no_head_of_line_bypass() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_total_ctx: 40 });
        b.submit(req(0, 38, 1)); // huge: fills the budget
        b.submit(req(1, 2, 2)); // small, but FCFS must not bypass
        b.admit();
        assert_eq!(b.running().len(), 1);
        assert_eq!(b.running()[0].id, 0);
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn idle_detection() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.is_idle());
        b.submit(req(0, 1, 1));
        assert!(!b.is_idle());
        b.admit();
        b.running_mut()[0].state = RequestState::Done;
        b.retire();
        assert!(b.is_idle());
    }
}
