//! The serving engine: ties the batcher, KV manager, compiler cache, NPM
//! double banking, the timing/energy simulator, and (for the tiny model)
//! a functional numerics backend into a single decode-round loop.
//!
//! Timing model: the engine advances a *simulated* clock by the cycle cost
//! of each program it dispatches (analytical model — identical to what the
//! instruction-level simulator measures, see `tests/integration_sim.rs`).
//! Numerics: with [`Numerics::Backend`], every prefill/decode also runs a
//! real forward pass through the pluggable [`NumericsBackend`] (pure-Rust
//! reference f32 by default, PJRT with `--features xla`), so generated
//! tokens are real model outputs.
//!
//! Admission is **block-pool backed**: requests are admitted against the
//! actual free KV blocks of the backend pool and the simulated scratchpad
//! ledger, not session slots ([`crate::kvcache::AdmissionPolicy`]). When
//! decode growth outruns the pool, the youngest sessions are *preempted* —
//! their blocks are released and they re-enter the head of the wait queue;
//! on readmission their prompt plus already-generated tokens are
//! re-prefilled (the vLLM recompute discipline), which greedy decode makes
//! token-equivalent to never having been preempted.
//!
//! Robustness: the engine consults a seeded [`FaultPlan`] at every
//! persistence/pool call site (deterministic chaos testing), enforces
//! per-request SLO deadlines (`ttft_deadline_ns` / `total_deadline_ns` →
//! typed [`FinishReason::Timeout`]), and sheds the lowest-priority waiters
//! under overload ([`OverloadPolicy`] → typed [`FinishReason::Shed`]).
//! Every faulted or late request ends in a typed outcome; sessions the
//! fault never touched finish bitwise-identically to the fault-free run
//! (`tests/integration_chaos.rs`).

// Typed-error discipline on the serving path: panicking on I/O or lock
// state here would take the whole engine down with every live session.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use std::time::Instant;

use crate::arch::{HwParams, TileGeometry};
use crate::compiler::{Compiler, CompiledModel};
use crate::energy::table2;
use crate::faults::{FaultPlan, FaultSite};
use crate::isa::Npm;
use crate::kvcache::{AdmissionDecision, AdmissionPolicy};
use crate::model::ModelPreset;
use crate::obs::{self, EventKind, Level, Tracer};
use crate::persist::{Journal, JournalRecord, SpillStore};
use crate::runtime::{LaneFault, NumericsBackend, ReferenceBackend};
use crate::sim::analytical::WAVEFRONT_MACROS;
use crate::sim::AnalyticalSim;

use super::batcher::{BatchPolicy, Batcher};
use super::generation::{match_stop, sample, GenerationConfig};
use super::kv::KvManager;
use super::metrics::Metrics;
use super::request::{FinishReason, Request, RequestId, RequestState};

/// Functional-numerics configuration.
pub enum Numerics {
    /// Run a real forward pass through a pluggable backend (tiny model).
    Backend(Box<dyn NumericsBackend>),
    /// Synthetic token generation (big-model simulation-only serving).
    Synthetic { vocab: usize },
}

impl Numerics {
    /// The pure-Rust reference backend over an artifact/fixture directory.
    pub fn reference(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        Ok(Self::Backend(Box::new(ReferenceBackend::load(dir)?)))
    }

    /// The PJRT backend over an AOT artifact directory.
    #[cfg(feature = "xla")]
    pub fn pjrt(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        Ok(Self::Backend(Box::new(crate::runtime::PjrtBackend::load(dir)?)))
    }

    /// Synthetic numerics for simulation-only serving.
    pub fn synthetic(vocab: usize) -> Self {
        Self::Synthetic { vocab }
    }

    /// Backend name for diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Backend(b) => b.name(),
            Self::Synthetic { .. } => "synthetic",
        }
    }
}

/// Engine construction options.
pub struct EngineConfig {
    pub preset: ModelPreset,
    pub hw: HwParams,
    pub policy: BatchPolicy,
    pub numerics: Numerics,
}

/// Typed rejection returned by [`ServingEngine::submit`]: the request can
/// never run, and is refused *before* it queues — not deep inside the
/// backend mid-batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    EmptyPrompt,
    ZeroMaxNewTokens,
    /// The prompt alone exceeds the model context window.
    PromptTooLong { len: usize, s_max: usize },
    /// Prompt + requested generation exceeds the model context window
    /// (`need` counts cached positions: the last token is never fed back).
    ContextTooLong { need: usize, s_max: usize },
    /// The full context needs more KV blocks than the pool contains.
    KvNeverFits { need_blocks: usize, total_blocks: usize },
    /// The generation config is malformed (negative temperature, top_p
    /// outside (0, 1], empty stop sequence, …).
    InvalidConfig { reason: &'static str },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::EmptyPrompt => write!(f, "empty prompt"),
            Self::ZeroMaxNewTokens => write!(f, "max_new_tokens must be at least 1"),
            Self::PromptTooLong { len, s_max } => {
                write!(f, "prompt of {len} tokens exceeds the model window s_max={s_max}")
            }
            Self::ContextTooLong { need, s_max } => write!(
                f,
                "prompt + max_new_tokens needs {need} KV positions but the model \
                 window is s_max={s_max}"
            ),
            Self::KvNeverFits { need_blocks, total_blocks } => write!(
                f,
                "request needs {need_blocks} KV blocks but the pool only has {total_blocks}"
            ),
            Self::InvalidConfig { reason } => write!(f, "invalid generation config: {reason}"),
        }
    }
}

impl SubmitError {
    /// Stable machine code (trace events, log lines).
    pub fn code(&self) -> &'static str {
        match self {
            Self::EmptyPrompt => "empty_prompt",
            Self::ZeroMaxNewTokens => "zero_max_new_tokens",
            Self::PromptTooLong { .. } => "prompt_too_long",
            Self::ContextTooLong { .. } => "context_too_long",
            Self::KvNeverFits { .. } => "kv_never_fits",
            Self::InvalidConfig { .. } => "invalid_config",
        }
    }
}

impl std::error::Error for SubmitError {}

/// What a round's numerics produced for one request: a logits row for the
/// sampler (functional backends) or a token computed directly (synthetic
/// numerics, which has no logits).
enum NextToken {
    Row(Vec<f32>),
    Token(i32),
}

impl NextToken {
    /// Resolve to a token for `req`'s next generation step.
    fn resolve(self, req: &Request) -> i32 {
        match self {
            NextToken::Row(row) => {
                sample(&req.gen, &row, &req.prompt, &req.output, req.output.len()) as i32
            }
            NextToken::Token(t) => t,
        }
    }
}

/// Graceful-overload knobs: shedding from the wait queue by priority
/// class when it grows past a bound. Shedding never touches the running
/// batch and never starves: a waiter aged past `age_exempt_ns` is exempt,
/// so a low-priority request that already waited its share cannot be
/// victimised forever by a stream of high-priority arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OverloadPolicy {
    /// Shed the wait queue down to this depth each step (`None`, the
    /// default, never sheds).
    pub max_waiting: Option<usize>,
    /// Waiters at least this old (simulated ns since last enqueue) are
    /// shed-exempt.
    pub age_exempt_ns: u64,
}

impl Default for OverloadPolicy {
    fn default() -> Self {
        Self { max_waiting: None, age_exempt_ns: 1_000_000 }
    }
}

/// Bounded retries for transient persistence I/O before degrading.
const PERSIST_RETRY_LIMIT: u32 = 3;

/// Append one record to the journal, if journaling is on. Transient write
/// failures (real or injected by the fault plan) are retried up to
/// [`PERSIST_RETRY_LIMIT`] times; a write that still fails degrades
/// durability, not serving — the journal is dropped (read-only degraded
/// mode: no further appends are attempted) and the engine keeps going. A
/// free function so partially-borrowed engine scopes can call it.
fn journal_rec(
    journal: &mut Option<Journal>,
    faults: &mut FaultPlan,
    persist_retries: &mut u64,
    rec: JournalRecord,
) {
    if journal.is_none() {
        return;
    }
    let mut attempt = 0u32;
    loop {
        let res = match faults.check(FaultSite::JournalWrite) {
            Some(_) => Err(anyhow::anyhow!("injected journal-write fault (plan)")),
            None => match journal.as_mut() {
                Some(j) => j.record(&rec),
                None => return,
            },
        };
        match res {
            Ok(()) => return,
            Err(err) if attempt < PERSIST_RETRY_LIMIT => {
                attempt += 1;
                *persist_retries += 1;
                obs::stderr_log(
                    Level::Warn,
                    "journal_write_retry",
                    format_args!("journal append failed (attempt {attempt}): {err:#}"),
                );
            }
            Err(err) => {
                obs::stderr_log(
                    Level::Error,
                    "journal_write_error",
                    format_args!(
                        "journal append still failing after {PERSIST_RETRY_LIMIT} retries; \
                         journaling disabled (read-only degraded mode): {err:#}"
                    ),
                );
                *journal = None;
                return;
            }
        }
    }
}

/// The serving engine.
pub struct ServingEngine {
    pub compiled: CompiledModel,
    pub sim: AnalyticalSim,
    pub batcher: Batcher,
    pub kv: KvManager,
    pub npm: Npm,
    pub metrics: Metrics,
    /// Block-granular admission knobs (watermark, output reservation).
    pub admission: AdmissionPolicy,
    /// Chunked-prefill knob: `Some(c)` splits every prompt into `c`-token
    /// chunks, one chunk per engine step, so decode rounds (and short
    /// requests' first tokens) interleave with a long neighbor's prefill.
    /// `None` (default) prefills each prompt whole in its admission step.
    /// Chunk sizes that are multiples of the backend's KV block size keep
    /// every chunk boundary on a block boundary; any size is correct
    /// (`tests/integration_generation.rs` pins chunked ≡ monolithic).
    /// Backends without [`NumericsBackend::supports_chunked_prefill`] are
    /// served whole regardless.
    pub prefill_chunk: Option<usize>,
    /// Structured tracing ([`crate::obs`]). Disabled by default: every
    /// emit is one predicted branch and the ring owns no memory. Swap in
    /// [`Tracer::enabled`] before serving to record; tracing never feeds
    /// back into scheduling or numerics, so token streams are bitwise
    /// identical either way (`tests/integration_obs.rs`).
    pub tracer: Tracer,
    /// Crash-safe session journal ([`crate::persist`]). `None` (default)
    /// = durability off: no file I/O, no clones on the submit path.
    pub journal: Option<Journal>,
    /// KV spill-to-disk store: preempted sessions write their cached rows
    /// to a per-session file and readmission restores them — zero
    /// re-prefilled tokens. `None` (default) = the recompute discipline.
    pub spill: Option<SpillStore>,
    /// Deterministic fault schedule ([`crate::faults`]). Empty (default)
    /// = every site consult is one `is_empty` branch and nothing injects.
    pub faults: FaultPlan,
    /// Overload shedding policy (default: never shed).
    pub overload: OverloadPolicy,
    numerics: Numerics,
    next_id: RequestId,
    /// Simulated clock, ns.
    now_ns: u64,
    /// Engine iterations taken (trace span labels).
    round: u64,
    /// Finished requests awaiting pickup (server replies).
    completed: Vec<Request>,
    /// Per-site injection counters at the last step's end — the deltas
    /// become [`EventKind::FaultInjected`] trace events.
    last_fault_counts: [u64; 6],
}

impl ServingEngine {
    pub fn new(cfg: EngineConfig) -> anyhow::Result<Self> {
        let compiler = Compiler { hw: cfg.hw.clone(), run_dse: false };
        let compiled = compiler.compile(cfg.preset)?;
        let sim = AnalyticalSim::new(cfg.preset, cfg.hw.clone());
        let geom = TileGeometry::for_model(compiled.shape.d_model, &cfg.hw);
        let kv = KvManager::new(&geom, compiled.shape.d_head(), compiled.shape.n_layers);
        Ok(Self {
            compiled,
            sim,
            batcher: Batcher::new(cfg.policy),
            kv,
            npm: Npm::new(),
            metrics: Metrics::default(),
            admission: AdmissionPolicy::default(),
            prefill_chunk: None,
            tracer: Tracer::disabled(),
            journal: None,
            spill: None,
            faults: FaultPlan::none(),
            overload: OverloadPolicy::default(),
            numerics: cfg.numerics,
            next_id: 0,
            now_ns: 0,
            round: 0,
            completed: Vec::new(),
            last_fault_counts: [0; 6],
        })
    }

    /// Submit a prompt for up to `max_new_tokens` of greedy generation;
    /// returns the request id, or a typed [`SubmitError`] when the request
    /// can never run (bad shape, context window, pool too small). Rejected
    /// requests are counted but never queued.
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<RequestId, SubmitError> {
        self.submit_with(prompt, GenerationConfig::greedy(max_new_tokens))
    }

    /// Submit a prompt with a full per-request [`GenerationConfig`]
    /// (sampling knobs, stop sequences, seed). The config is validated
    /// here — a malformed one is refused before it queues, like every
    /// other [`SubmitError`].
    pub fn submit_with(
        &mut self,
        prompt: Vec<i32>,
        gen: GenerationConfig,
    ) -> Result<RequestId, SubmitError> {
        if let Err(err) =
            gen.validate().and_then(|()| self.validate_submit(&prompt, gen.max_new_tokens))
        {
            self.metrics.requests_rejected += 1;
            self.tracer.emit(self.now_ns, None, EventKind::Reject { reason: err.code() });
            return Err(err);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.tracer.emit(
            self.now_ns,
            Some(id),
            EventKind::Submit {
                prompt_tokens: prompt.len() as u32,
                max_new_tokens: gen.max_new_tokens as u32,
            },
        );
        if self.journal.is_some() {
            journal_rec(
                &mut self.journal,
                &mut self.faults,
                &mut self.metrics.persist_retries,
                JournalRecord::Submit { id, prompt: prompt.clone(), gen: gen.clone() },
            );
        }
        self.batcher.submit(Request::with_gen(id, prompt, gen, self.now_ns));
        Ok(id)
    }

    /// Re-enter one session recovered from a journal
    /// ([`crate::persist::reconstruct`]): validate like a fresh submit,
    /// journal the known history into *this* engine's journal (if any),
    /// and either finish the stream immediately (the crash cut between
    /// the terminal token and its `Finish` record — the termination rules
    /// are re-applied here) or queue it to continue decoding. With the
    /// reference backend the continuation is bitwise-identical to the
    /// uninterrupted run: the sampler is counter-based per `(seed, step)`
    /// and re-prefilling `prompt ++ emitted` reproduces the exact logits
    /// the lost process would have seen next.
    pub fn resubmit_recovered(
        &mut self,
        prompt: Vec<i32>,
        gen: GenerationConfig,
        emitted: Vec<i32>,
    ) -> Result<RequestId, SubmitError> {
        if let Err(err) =
            gen.validate().and_then(|()| self.validate_submit(&prompt, gen.max_new_tokens))
        {
            self.metrics.requests_rejected += 1;
            self.tracer.emit(self.now_ns, None, EventKind::Reject { reason: err.code() });
            return Err(err);
        }
        let id = self.next_id;
        self.next_id += 1;
        let now = self.now_ns;
        self.metrics.sessions_recovered += 1;
        self.tracer.emit(
            now,
            Some(id),
            EventKind::Recovered {
                prompt_tokens: prompt.len() as u32,
                tokens: emitted.len() as u32,
            },
        );
        if self.journal.is_some() {
            journal_rec(
                &mut self.journal,
                &mut self.faults,
                &mut self.metrics.persist_retries,
                JournalRecord::Submit { id, prompt: prompt.clone(), gen: gen.clone() },
            );
            for &t in &emitted {
                journal_rec(
                    &mut self.journal,
                    &mut self.faults,
                    &mut self.metrics.persist_retries,
                    JournalRecord::Token { id, token: t },
                );
            }
        }
        let mut req = Request::with_gen(id, prompt, gen, now);
        req.output = emitted;
        if !req.output.is_empty() {
            req.t_first_token_ns = Some(now);
        }
        if let Some(n) = match_stop(&req.output, &req.gen.stop) {
            req.output.truncate(req.output.len() - n);
            req.finish_with(FinishReason::Stop, now);
        } else if req.output.len() >= req.gen.max_new_tokens {
            req.finish_with(FinishReason::Length, now);
        }
        if req.is_finished() {
            self.metrics.requests_done += 1;
            if req.finish == Some(FinishReason::Stop) {
                self.metrics.requests_stopped += 1;
            }
            journal_rec(
                &mut self.journal,
                &mut self.faults,
                &mut self.metrics.persist_retries,
                JournalRecord::Finish { id, failed: false, output_len: req.output.len() as u64 },
            );
            self.tracer.emit(
                now,
                Some(id),
                EventKind::Finish {
                    outcome: "done",
                    reason: req.finish.map_or("length", FinishReason::as_str),
                    output_tokens: req.output.len() as u32,
                },
            );
            self.completed.push(req);
            return Ok(id);
        }
        self.batcher.submit(req);
        Ok(id)
    }

    fn validate_submit(&self, prompt: &[i32], max_new: usize) -> Result<(), SubmitError> {
        if prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt);
        }
        if max_new == 0 {
            return Err(SubmitError::ZeroMaxNewTokens);
        }
        // Cached positions over the request's life: the prompt plus every
        // generated token except the last (which is never fed back).
        let full_ctx = prompt.len() + max_new - 1;
        if let Numerics::Backend(backend) = &self.numerics {
            if let Some(s_max) = backend.context_window() {
                if prompt.len() > s_max {
                    return Err(SubmitError::PromptTooLong { len: prompt.len(), s_max });
                }
                if full_ctx > s_max {
                    return Err(SubmitError::ContextTooLong { need: full_ctx, s_max });
                }
            }
            if let (Some(need), Some(stats)) =
                (backend.kv_admit_demand(full_ctx), backend.kv_pool_stats())
            {
                if need > stats.blocks_total {
                    return Err(SubmitError::KvNeverFits {
                        need_blocks: need,
                        total_blocks: stats.blocks_total,
                    });
                }
            }
        }
        // Simulated scratchpad ledger: a context that can never fit
        // on-chip (the ledger tracks every generated token, so full usage
        // is prompt + max_new positions).
        let need = self.kv.blocks_for(prompt.len() + max_new);
        if need > self.kv.total_blocks() {
            return Err(SubmitError::KvNeverFits {
                need_blocks: need,
                total_blocks: self.kv.total_blocks(),
            });
        }
        Ok(())
    }

    /// Simulated time now, ns.
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    /// Jump the simulated clock forward to `ns` (no-op if already past).
    /// Scenario drivers use this to model request arrival times: an idle
    /// engine waits at simulated speed, not host speed. Does not count as
    /// simulated *compute* time (`metrics.sim_time_ns` is untouched).
    pub fn advance_clock_to(&mut self, ns: u64) {
        self.now_ns = self.now_ns.max(ns);
    }

    fn advance(&mut self, cycles: u64) {
        let ns = (cycles as f64 / self.sim.hw.freq_ghz) as u64;
        self.now_ns += ns;
        self.metrics.sim_time_ns += ns;
        // Energy: active wavefront draw over the elapsed time.
        let wavefront = self.sim.mapped_macros().min(WAVEFRONT_MACROS);
        self.metrics.energy_j += wavefront as f64 * table2::MACRO_UW * 1e-6 * ns as f64 * 1e-9;
    }

    /// Mark a running request Failed at the current simulated time.
    /// `code` is the stable failure code for the trace (the human-readable
    /// message already went to stderr at the detection site).
    fn fail_request(&mut self, id: RequestId, code: &'static str) {
        let now = self.now_ns;
        if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
            r.state = RequestState::Failed;
            r.t_done_ns = Some(now);
        }
        self.metrics.requests_failed += 1;
        self.tracer.emit(now, Some(id), EventKind::Diag { level: Level::Error, code });
    }

    /// Retire a request aborted while still in the wait queue (deadline
    /// timeout or overload shed): journal the terminal record, emit the
    /// typed event, count it, and surface it to `completed`. Timed-out and
    /// shed requests are *not* counted as `requests_failed` and never
    /// enter the latency/TTFT histograms — they are a separate, typed
    /// population. The request held no KV blocks, so nothing is released;
    /// a pending spill file (preempted then aborted) is discarded.
    fn finish_queued_abort(&mut self, req: Request) {
        let now = self.now_ns;
        journal_rec(
            &mut self.journal,
            &mut self.faults,
            &mut self.metrics.persist_retries,
            JournalRecord::Finish { id: req.id, failed: true, output_len: req.output.len() as u64 },
        );
        if let Some(store) = self.spill.as_mut() {
            store.discard(req.id);
        }
        let waited = now.saturating_sub(req.t_enqueued_ns);
        let (outcome, reason) = match req.finish {
            Some(FinishReason::Timeout) => {
                self.metrics.requests_timeout += 1;
                self.tracer.emit(
                    now,
                    Some(req.id),
                    EventKind::Timeout {
                        waited_ns: waited,
                        output_tokens: req.output.len() as u32,
                    },
                );
                ("timeout", "deadline")
            }
            Some(FinishReason::Shed) => {
                self.metrics.requests_shed += 1;
                self.tracer.emit(
                    now,
                    Some(req.id),
                    EventKind::Shed { priority: req.gen.priority, waited_ns: waited },
                );
                ("shed", "overload")
            }
            _ => ("failed", "error"),
        };
        self.tracer.emit(
            now,
            Some(req.id),
            EventKind::Finish { outcome, reason, output_tokens: req.output.len() as u32 },
        );
        self.completed.push(req);
    }

    /// Load + swap the NPM with the program for this phase (double-banked).
    fn dispatch(&mut self, prog: crate::isa::Program) -> anyhow::Result<u64> {
        let cycles = prog.controller_cycles();
        self.npm.load(prog)?;
        self.npm.swap()?;
        self.metrics.npm_swaps += 1;
        Ok(cycles)
    }

    /// One engine iteration: admit, prefill admitted, one decode round.
    /// Returns false when idle.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        let host_t0 = Instant::now();
        if self.batcher.is_idle() {
            return Ok(false);
        }
        self.round += 1;
        let round_no = self.round;
        let step_t0_sim = self.now_ns;

        // --- SLO deadline sweep ------------------------------------------
        // Waiting requests past a deadline abort in place: a TTFT deadline
        // that elapses in the queue times out *without ever being
        // prefilled* — it never claims a block, never perturbs the batch.
        // Running requests past their total deadline (or still without a
        // first token past their TTFT deadline) are aborted here and
        // collected by the retire loop below, before this step's decode
        // round — they cost no further compute.
        {
            let now = self.now_ns;
            let over = |r: &Request| {
                let ttft_over = r.t_first_token_ns.is_none()
                    && r.gen
                        .ttft_deadline_ns
                        .is_some_and(|d| now >= r.t_arrive_ns.saturating_add(d));
                let total_over = r
                    .gen
                    .total_deadline_ns
                    .is_some_and(|d| now >= r.t_arrive_ns.saturating_add(d));
                ttft_over || total_over
            };
            for mut req in self.batcher.extract_waiting(|r| over(r)) {
                req.abort_with(FinishReason::Timeout, now);
                self.finish_queued_abort(req);
            }
            let late: Vec<RequestId> = self
                .batcher
                .running()
                .iter()
                .filter(|r| !r.is_finished() && over(r))
                .map(|r| r.id)
                .collect();
            for id in late {
                if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
                    r.abort_with(FinishReason::Timeout, now);
                }
            }
        }

        // --- overload shedding -------------------------------------------
        // Trim the wait queue to the policy bound: lowest priority class
        // first, youngest arrival within a class. Aged waiters are exempt
        // (no starvation); when everyone left is exempt, stop shedding.
        if let Some(cap) = self.overload.max_waiting {
            while self.batcher.waiting_len() > cap {
                let now = self.now_ns;
                let exempt_ns = self.overload.age_exempt_ns;
                let victim = self
                    .batcher
                    .waiting()
                    .filter(|r| now.saturating_sub(r.t_enqueued_ns) < exempt_ns)
                    .min_by_key(|r| (r.gen.priority, std::cmp::Reverse(r.id)))
                    .map(|r| r.id);
                let Some(vid) = victim else {
                    break; // every waiter is aged-exempt
                };
                for mut req in self.batcher.extract_waiting(|r| r.id == vid) {
                    req.abort_with(FinishReason::Shed, now);
                    self.finish_queued_abort(req);
                }
            }
        }

        // --- fault plan: arm worker-lane faults for this step ------------
        // Consulted once per step (the plan's `at=` counts engine steps for
        // these sites); the armed lane fires inside its next engagement.
        if !self.faults.is_empty() {
            for (site, kind) in
                [(FaultSite::LanePanic, LaneFault::Panic), (FaultSite::LaneStall, LaneFault::Stall)]
            {
                if let Some(rule) = self.faults.check(site) {
                    if let Numerics::Backend(backend) = &mut self.numerics {
                        backend.inject_lane_fault(rule.lane, kind);
                    }
                }
            }
        }

        // --- admission (block-pool backed) -------------------------------
        // The batcher's caps apply first; then each head-of-queue request
        // is judged against the actual free blocks of the simulated
        // scratchpad ledger and (when the backend pools KV) the functional
        // pool, with running tallies so one round's admissions don't
        // double-spend blocks none of them has claimed yet.
        let (admitted, rejected) = {
            let admission = self.admission;
            let now = self.now_ns;
            let Self { batcher, kv, numerics, tracer, faults, .. } = self;
            let mut sim_pending = 0usize;
            // Blocks the sessions already mid-chunked-prefill will still
            // claim before they produce a token: their future chunks must
            // not be starved by this round's admissions. (Zero when
            // prefill is monolithic — every prefill completes in its
            // admission step.)
            let mut pool_pending = 0usize;
            if let Numerics::Backend(backend) = &*numerics {
                pool_pending = batcher
                    .running()
                    .iter()
                    .filter(|r| r.state == RequestState::Prefilling)
                    .map(|r| {
                        backend
                            .kv_admit_demand(r.ctx_len())
                            .unwrap_or(0)
                            .saturating_sub(backend.kv_admit_demand(r.prefilled).unwrap_or(0))
                    })
                    .sum();
            }
            batcher.admit_with(|req| {
                // injected block-ledger allocation failure: the request is
                // rejected with a typed outcome (bounded — each consult
                // rules on one request, so a permanent fault drains the
                // queue as typed failures, never a livelock)
                if faults.check(FaultSite::BlockAlloc).is_some() {
                    tracer.emit(
                        now,
                        Some(req.id),
                        EventKind::AdmissionDecision {
                            decision: "reject",
                            need_blocks: 0,
                            free_blocks: kv.free_blocks() as u32,
                        },
                    );
                    return AdmissionDecision::Reject;
                }
                let resume_ctx = req.ctx_len(); // prompt + generated (resume)
                let remaining = req.max_new_tokens() - req.output.len();
                // simulated scratchpad: reject what can never fit (the
                // ledger tracks every generated token, so full usage is
                // ctx + remaining), queue until the (re-)prefill AND its
                // immediate first-token append both fit now — the append
                // claims an extra block at a group boundary, and an
                // unreserved claim here would starve a later admission's
                // prefill mid-round
                if kv.blocks_for(resume_ctx + remaining) > kv.total_blocks() {
                    tracer.emit(
                        now,
                        Some(req.id),
                        EventKind::AdmissionDecision {
                            decision: "reject",
                            need_blocks: kv.blocks_for(resume_ctx + remaining) as u32,
                            free_blocks: kv.free_blocks() as u32,
                        },
                    );
                    return AdmissionDecision::Reject;
                }
                let now_need = kv.blocks_for(resume_ctx + 1);
                if now_need + sim_pending > kv.free_blocks() {
                    tracer.emit(
                        now,
                        Some(req.id),
                        EventKind::AdmissionDecision {
                            decision: "queue",
                            need_blocks: (now_need + sim_pending) as u32,
                            free_blocks: kv.free_blocks() as u32,
                        },
                    );
                    return AdmissionDecision::Queue;
                }
                // functional pool: the policy rules on worst-case demand
                // (ignoring prefix sharing — sharing only makes it cheaper)
                if let Numerics::Backend(backend) = numerics {
                    if let (Some(need), Some(stats)) = (
                        backend.kv_admit_demand(admission.reserve_tokens(resume_ctx, remaining)),
                        backend.kv_pool_stats(),
                    ) {
                        let free = stats.blocks_free.saturating_sub(pool_pending);
                        match admission.decide(need, free, stats.blocks_total) {
                            AdmissionDecision::Admit => pool_pending += need,
                            other => {
                                tracer.emit(
                                    now,
                                    Some(req.id),
                                    EventKind::AdmissionDecision {
                                        decision: match other {
                                            AdmissionDecision::Queue => "queue",
                                            _ => "reject",
                                        },
                                        need_blocks: need as u32,
                                        free_blocks: free as u32,
                                    },
                                );
                                return other;
                            }
                        }
                    }
                }
                sim_pending += now_need;
                tracer.emit(
                    now,
                    Some(req.id),
                    EventKind::AdmissionDecision {
                        decision: "admit",
                        need_blocks: now_need as u32,
                        free_blocks: kv.free_blocks() as u32,
                    },
                );
                AdmissionDecision::Admit
            })
        };
        let now = self.now_ns;
        for mut req in rejected {
            req.t_done_ns = Some(now);
            self.metrics.requests_failed += 1;
            journal_rec(
                &mut self.journal,
                &mut self.faults,
                &mut self.metrics.persist_retries,
                JournalRecord::Finish {
                    id: req.id,
                    failed: true,
                    output_len: req.output.len() as u64,
                },
            );
            if let Some(store) = self.spill.as_mut() {
                store.discard(req.id);
            }
            self.tracer.emit(
                now,
                Some(req.id),
                EventKind::Finish {
                    outcome: "failed",
                    reason: "admission_reject",
                    output_tokens: req.output.len() as u32,
                },
            );
            self.completed.push(req);
        }
        // stamp admission times + queue-wait spans for this round's intake
        for r in self.batcher.running_mut().iter_mut() {
            if !admitted.contains(&r.id) {
                continue;
            }
            journal_rec(
                &mut self.journal,
                &mut self.faults,
                &mut self.metrics.persist_retries,
                JournalRecord::Admit { id: r.id },
            );
            let readmission = r.preemptions > 0;
            if r.t_admitted_ns.is_none() {
                r.t_admitted_ns = Some(now);
            }
            let begin = r.t_enqueued_ns;
            self.tracer.emit(
                begin,
                Some(r.id),
                EventKind::Admitted { wait_ns: now.saturating_sub(begin), readmission },
            );
        }

        // --- advance every prefill by one chunk --------------------------
        // With `prefill_chunk = None` (or a backend without chunk support)
        // this is exactly the old monolithic phase: each freshly admitted
        // request prefills whole and produces its first token now. With a
        // chunk size set, every `Prefilling` session — newly admitted or
        // mid-prompt from an earlier step — advances by ONE chunk, then
        // the decode round below runs: a long prompt no longer stalls its
        // neighbors' tokens for its full prefill, only for one chunk.
        //
        // A preempted request resumes here too: its prompt ++ generated
        // tokens re-prefill (recompute). The counter-based sampler makes
        // that lossless beyond greedy: the replayed steps consume the same
        // per-step randomness over bit-identical logits.
        let chunk_cfg = self.prefill_chunk;
        let prefilling: Vec<RequestId> = self
            .batcher
            .running()
            .iter()
            .filter(|r| r.state == RequestState::Prefilling)
            .map(|r| r.id)
            .collect();
        for id in prefilling {
            let (tokens, prefilled) = {
                // a request the deadline sweep aborted between collection
                // and here is simply skipped (the retire loop owns it)
                let Some(r) = self.batcher.running().iter().find(|r| r.id == id) else {
                    continue;
                };
                let mut t = r.prompt.clone();
                t.extend_from_slice(&r.output);
                (t, r.prefilled)
            };
            // admission reserved these blocks (prefill + first append);
            // a ledger refusal is a per-request failure, never an engine
            // crash. The simulated ledger reserves the whole context on
            // the first chunk (it has no chunk granularity).
            if prefilled == 0 {
                if let Err(err) = self.kv.prefill(id, tokens.len()) {
                    obs::stderr_log(
                        Level::Error,
                        "scratchpad_reject",
                        format_args!("request {id} rejected by the scratchpad ledger: {err:#}"),
                    );
                    self.fail_request(id, "scratchpad_reject");
                    continue;
                }
            }
            // --- spill restore ---------------------------------------
            // A readmitted preemption victim whose KV rows went to disk
            // replays the file into the pool instead of re-prefilling.
            // The image holds `prompt ++ output[..len-1]` rows — the last
            // generated token never entered the cache — so a valid image
            // has exactly one row fewer than the resume context. Any
            // failure (corrupt file, pool too tight, shape drift) falls
            // through to the normal re-prefill below: spilling is an
            // optimisation, never a correctness dependency.
            if prefilled == 0 {
                if let Some((img, bytes)) = self.take_spill(id) {
                    let rows = img.rows;
                    let restored = rows + 1 == tokens.len()
                        && match &mut self.numerics {
                            Numerics::Backend(backend) => {
                                match backend.kv_restore(id, &tokens[..rows], &img) {
                                    Ok(()) => true,
                                    Err(err) => {
                                        obs::stderr_log(
                                            Level::Warn,
                                            "spill_restore_error",
                                            format_args!(
                                                "restore of request {id} failed; \
                                                 re-prefilling: {err:#}"
                                            ),
                                        );
                                        false
                                    }
                                }
                            }
                            Numerics::Synthetic { .. } => false,
                        };
                    if restored {
                        // simulated disk-read cost (8 bytes/ns + one seek),
                        // charged to this request's clock like any dispatch
                        let t0 = self.now_ns;
                        let dur = bytes / 8 + 1;
                        self.now_ns += dur;
                        self.metrics.sim_time_ns += dur;
                        self.metrics.spill_bytes_read += bytes;
                        let blocks = match &self.numerics {
                            Numerics::Backend(backend) => {
                                backend.kv_admit_demand(rows).unwrap_or(0)
                            }
                            Numerics::Synthetic { .. } => 0,
                        } as u32;
                        self.tracer.emit(
                            t0,
                            Some(id),
                            EventKind::Restore { blocks, bytes, dur_ns: dur },
                        );
                        if let Some(r) =
                            self.batcher.running_mut().iter_mut().find(|r| r.id == id)
                        {
                            r.prefilled = tokens.len();
                            r.state = RequestState::Decoding;
                            r.restore_ns += dur;
                        }
                        // no token resolves this step — the decode round
                        // below feeds `output.last()` exactly as the
                        // uninterrupted run's next round would have
                        continue;
                    }
                }
            }

            let chunked = chunk_cfg.is_some()
                && match &self.numerics {
                    Numerics::Backend(backend) => backend.supports_chunked_prefill(),
                    Numerics::Synthetic { .. } => true,
                };
            let chunk_len = match chunk_cfg {
                Some(c) if chunked => c.max(1).min(tokens.len() - prefilled),
                _ => tokens.len() - prefilled,
            };
            let chunk = &tokens[prefilled..prefilled + chunk_len];
            let last = prefilled + chunk_len == tokens.len();

            // timing: one program per layer over this chunk's rows
            let chunk_t0_sim = self.now_ns;
            let layers = self.compiled.shape.n_layers as u64;
            let prog = self.compiled.prefill_program(chunk_len.max(1)).clone();
            let per_layer = self.dispatch(prog)?;
            self.advance(per_layer * layers);
            self.metrics.prefill_tokens += chunk_len as u64;
            self.metrics.prefill_chunks += 1;
            self.tracer.emit(
                chunk_t0_sim,
                Some(id),
                EventKind::PrefillChunk {
                    start: prefilled as u32,
                    len: chunk_len as u32,
                    last,
                    dur_ns: self.now_ns - chunk_t0_sim,
                },
            );

            // numerics — a backend error (e.g. out-of-vocab prompt) fails
            // this request only; the engine and its batch keep serving.
            // `first` is the sampler input for the first generated token
            // (only produced by the last chunk).
            let first: Result<Option<NextToken>, &'static str> = match &mut self.numerics {
                Numerics::Backend(backend) => {
                    let vocab = backend.vocab();
                    let out = if prefilled == 0 && last {
                        // whole prompt in one call: the monolithic entry
                        // point, byte-identical to the pre-chunking engine
                        backend.prefill(id, chunk)
                    } else {
                        backend.prefill_chunk(id, chunk, prefilled, last)
                    };
                    match out {
                        // enforce the trait's no-silent-truncation
                        // contract: fewer rows than chunk tokens would
                        // sample the wrong context, so fail the request
                        Ok(out) if out.rows >= chunk_len => Ok(last.then(|| {
                            NextToken::Row(
                                out.logits[(chunk_len - 1) * vocab..chunk_len * vocab].to_vec(),
                            )
                        })),
                        Ok(out) => {
                            obs::stderr_log(
                                Level::Error,
                                "prefill_short_rows",
                                format_args!(
                                    "request {id} rejected: backend returned {} logits rows \
                                     for a {}-token prefill chunk",
                                    out.rows, chunk_len
                                ),
                            );
                            backend.release(id);
                            Err("prefill_short_rows")
                        }
                        Err(err) => {
                            obs::stderr_log(
                                Level::Error,
                                "prefill_backend_error",
                                format_args!("request {id} rejected by numerics prefill: {err:#}"),
                            );
                            backend.release(id);
                            Err("prefill_backend_error")
                        }
                    }
                }
                Numerics::Synthetic { vocab } => Ok(last.then(|| {
                    NextToken::Token(
                        (tokens.iter().map(|&t| t as i64).sum::<i64>() % *vocab as i64) as i32,
                    )
                })),
            };
            let first = match first {
                Ok(first) => first,
                Err(code) => {
                    self.kv.release(id);
                    self.fail_request(id, code);
                    continue;
                }
            };

            let now = self.now_ns;
            let Some(next) = first else {
                // mid-prompt: remember the cursor, stay Prefilling
                if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
                    r.prefilled += chunk_len;
                }
                continue;
            };
            let mut finished = false;
            if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
                r.prefilled = tokens.len();
                r.state = RequestState::Decoding;
                // the prefill's token is generation step `output.len()`
                // (0 for a fresh request, the resume step after preemption)
                let had_first = r.t_first_token_ns.is_some();
                let token = next.resolve(r);
                journal_rec(
                    &mut self.journal,
                    &mut self.faults,
                    &mut self.metrics.persist_retries,
                    JournalRecord::Token { id, token },
                );
                finished = r.accept_token(token, now);
                if !had_first {
                    // saturating: a 1-token stop-sequence match can leave
                    // the output empty after truncation
                    let position = r.output.len().saturating_sub(1) as u32;
                    self.tracer.emit(now, Some(id), EventKind::FirstToken { position });
                }
            }
            if !finished {
                if self.kv.can_append(id) {
                    self.kv.append(id)?;
                } else if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id)
                {
                    // no scratchpad block for the next position: finish here
                    r.finish_with(FinishReason::KvExhausted, now);
                }
            }
            self.metrics.decode_tokens += 1;
        }

        // --- pool-pressure preemption ------------------------------------
        // Worst case, the coming decode round claims `kv_append_demand`
        // blocks per session (a boundary block plus a possible CoW of a
        // shared tail). When the pool cannot cover the sum, the youngest
        // decoding sessions release their blocks and re-enter the head of
        // the wait queue. The demand sum is conservative — two sharers of
        // one tail block each count a CoW — so this preempts a round
        // early at worst, never a round late.
        {
            let now = self.now_ns;
            let Self { batcher, kv, numerics, metrics, tracer, journal, spill, faults, .. } = self;
            if let Numerics::Backend(backend) = numerics {
                if backend.kv_pool_stats().is_some() {
                    loop {
                        let decoding: Vec<RequestId> = batcher
                            .running()
                            .iter()
                            .filter(|r| r.state == RequestState::Decoding)
                            .map(|r| r.id)
                            .collect();
                        let free = backend.kv_pool_stats().map_or(0, |s| s.blocks_free);
                        // sessions still mid-chunked-prefill claim their
                        // next chunk's blocks before the next decode
                        // round — count them, or a starved chunk would
                        // fail its request instead of preempting a decoder
                        let prefill_need: usize = batcher
                            .running()
                            .iter()
                            .filter(|r| r.state == RequestState::Prefilling && r.prefilled > 0)
                            .map(|r| {
                                let total = r.ctx_len();
                                let next_end = match chunk_cfg {
                                    Some(c) => (r.prefilled + c.max(1)).min(total),
                                    None => total,
                                };
                                backend
                                    .kv_admit_demand(next_end)
                                    .unwrap_or(0)
                                    .saturating_sub(
                                        backend.kv_admit_demand(r.prefilled).unwrap_or(0),
                                    )
                            })
                            .sum();
                        let demand: usize = prefill_need
                            + decoding.iter().map(|&id| backend.kv_append_demand(id)).sum::<usize>();
                        if demand <= free {
                            break;
                        }
                        // Preempting even a sole session is lossless: its
                        // prompt ++ output re-prefills once the pool
                        // drains (submit validated the full context
                        // against the pool, and each readmission gains at
                        // least one token), so a transient shortfall
                        // never truncates a generation. Victim = youngest
                        // by ARRIVAL (ids are monotonic), not by
                        // running-batch position — a readmitted old
                        // request sits at the batch tail and must not
                        // become the perpetual victim.
                        let Some(&victim) = decoding.iter().max() else {
                            break;
                        };
                        tracer.emit(
                            now,
                            Some(victim),
                            EventKind::Preempt {
                                demand_blocks: demand as u32,
                                free_blocks: free as u32,
                            },
                        );
                        journal_rec(
                            journal,
                            faults,
                            &mut metrics.persist_retries,
                            JournalRecord::Preempt { id: victim },
                        );
                        // spill the victim's KV rows before releasing them:
                        // readmission then restores from disk instead of
                        // re-prefilling. Transient write failures (real or
                        // injected) retry; a write that still fails just
                        // logs — the recompute path is always there to
                        // fall back on.
                        if let Some(store) = spill.as_mut() {
                            if let Some(img) = backend.kv_spill(victim) {
                                let blocks = backend.kv_admit_demand(img.rows).unwrap_or(0);
                                let mut attempt = 0u32;
                                let wrote = loop {
                                    let res = if faults.check(FaultSite::SpillWrite).is_some() {
                                        Err(anyhow::anyhow!("injected spill-write fault"))
                                    } else {
                                        store.write(victim, &img)
                                    };
                                    match res {
                                        Ok(bytes) => break Some(bytes),
                                        Err(err) if attempt < PERSIST_RETRY_LIMIT => {
                                            attempt += 1;
                                            metrics.persist_retries += 1;
                                            obs::stderr_log(
                                                Level::Warn,
                                                "spill_write_retry",
                                                format_args!(
                                                    "spill of request {victim} failed \
                                                     (attempt {attempt}): {err:#}"
                                                ),
                                            );
                                        }
                                        Err(err) => {
                                            obs::stderr_log(
                                                Level::Warn,
                                                "spill_write_error",
                                                format_args!(
                                                    "spill of request {victim} failed \
                                                     (will re-prefill): {err:#}"
                                                ),
                                            );
                                            break None;
                                        }
                                    }
                                };
                                if let Some(bytes) = wrote {
                                    metrics.kv_spills += 1;
                                    metrics.kv_spilled_blocks += blocks as u64;
                                    metrics.spill_bytes_written += bytes;
                                    tracer.emit(
                                        now,
                                        Some(victim),
                                        EventKind::Spill { blocks: blocks as u32, bytes },
                                    );
                                }
                            }
                        }
                        backend.release(victim);
                        kv.release(victim);
                        batcher.preempt(victim);
                        // the queue-wait span of the eventual readmission
                        // begins at this preemption, not at arrival
                        if let Some(r) = batcher.waiting_front_mut() {
                            r.t_enqueued_ns = now;
                        }
                        metrics.preemptions += 1;
                        if decoding.len() <= 1 {
                            break; // nothing left in the round
                        }
                    }
                }
            }
        }

        // --- one decode round over the running batch ---------------------
        let round: Vec<(RequestId, usize, i32)> = self
            .batcher
            .running()
            .iter()
            .filter(|r| r.state == RequestState::Decoding && !r.is_finished())
            .map(|r| (r.id, r.ctx_len(), *r.output.last().unwrap_or(&0)))
            .collect();

        // timing: one decode program per request per layer (unchanged —
        // the simulated hardware serves requests round-robin). Each
        // request's token lands at the simulated instant its own dispatch
        // completed, same as the pre-batching engine.
        let round_t0_sim = self.now_ns;
        let mut done_at: Vec<u64> = Vec::with_capacity(round.len());
        for &(_, ctx, _) in &round {
            let layers = self.compiled.shape.n_layers as u64;
            let prog = self.compiled.decode_program(ctx).clone();
            let per_layer = self.dispatch(prog)?;
            self.advance(per_layer * layers);
            done_at.push(self.now_ns);
        }

        // numerics: ONE batched call for the whole round — a weight-
        // stationary backend streams each weight matrix once for every
        // live session (LEAP's dataflow, in software). A per-session error
        // fails that request only.
        let next_tokens: Vec<(RequestId, Option<NextToken>)> = match &mut self.numerics {
            Numerics::Backend(backend) => {
                let steps: Vec<(u64, i32)> = round.iter().map(|&(id, _, t)| (id, t)).collect();
                let outs = backend.decode_batch(&steps)?;
                anyhow::ensure!(
                    outs.len() == steps.len(),
                    "backend decode_batch returned {} results for {} steps",
                    outs.len(),
                    steps.len()
                );
                round
                    .iter()
                    .zip(outs)
                    .map(|(&(id, _, _), res)| match res {
                        Ok(out) => (id, Some(NextToken::Row(out.logits))),
                        Err(err) => {
                            obs::stderr_log(
                                Level::Error,
                                "decode_backend_error",
                                format_args!("request {id} failed in numerics decode: {err:#}"),
                            );
                            (id, None)
                        }
                    })
                    .collect()
            }
            Numerics::Synthetic { vocab } => round
                .iter()
                .map(|&(id, ctx, _)| (id, Some(NextToken::Token(((ctx * 2654435761) % *vocab) as i32))))
                .collect(),
        };

        let mut round_tokens = 0u32;
        for ((id, next), now) in next_tokens.into_iter().zip(done_at) {
            let Some(next) = next else {
                self.fail_request(id, "decode_backend_error");
                continue;
            };

            // The logits are already computed (and the position cached by
            // the backend) — sample and keep the token, then reserve the
            // *next* position; exhaustion finishes the request early
            // without dropping this token (same order as the prefill
            // path). A request its stop sequence or length budget just
            // finished needs no next position.
            self.metrics.decode_tokens += 1;
            round_tokens += 1;
            let mut finished = false;
            if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
                let token = next.resolve(r);
                journal_rec(
                    &mut self.journal,
                    &mut self.faults,
                    &mut self.metrics.persist_retries,
                    JournalRecord::Token { id, token },
                );
                finished = r.accept_token(token, now);
            }
            if !finished {
                if self.kv.can_append(id) {
                    self.kv.append(id)?;
                } else if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id)
                {
                    // out of scratchpad blocks: finish at this token
                    r.finish_with(FinishReason::KvExhausted, now);
                }
            }
        }
        if !round.is_empty() {
            self.tracer.emit(
                round_t0_sim,
                None,
                EventKind::DecodeRound {
                    round: round_no,
                    dur_ns: self.now_ns - round_t0_sim,
                    batch: round.len() as u32,
                    tokens: round_tokens,
                },
            );
        }

        // --- retire -------------------------------------------------------
        for done in self.batcher.retire() {
            self.kv.release(done.id);
            if let Numerics::Backend(backend) = &mut self.numerics {
                backend.release(done.id);
            }
            journal_rec(
                &mut self.journal,
                &mut self.faults,
                &mut self.metrics.persist_retries,
                JournalRecord::Finish {
                    id: done.id,
                    failed: done.state != RequestState::Done,
                    output_len: done.output.len() as u64,
                },
            );
            // a session that finished while a spill file was pending (e.g.
            // failed before readmission) must not leave the file behind
            if let Some(store) = self.spill.as_mut() {
                store.discard(done.id);
            }
            let (outcome, reason) = if done.state == RequestState::Done {
                ("done", done.finish.map_or("length", FinishReason::as_str))
            } else {
                match done.finish {
                    // aborted mid-flight by the deadline sweep: a typed
                    // outcome, kept out of requests_failed and the
                    // latency/TTFT histograms
                    Some(FinishReason::Timeout) => {
                        self.metrics.requests_timeout += 1;
                        self.tracer.emit(
                            done.t_done_ns.unwrap_or(self.now_ns),
                            Some(done.id),
                            EventKind::Timeout {
                                waited_ns: done
                                    .t_done_ns
                                    .unwrap_or(self.now_ns)
                                    .saturating_sub(done.t_arrive_ns),
                                output_tokens: done.output.len() as u32,
                            },
                        );
                        ("timeout", "deadline")
                    }
                    Some(FinishReason::Shed) => ("shed", "overload"),
                    // the failure code already went out as a Diag event at
                    // the detection site (fail_request)
                    _ => ("failed", "error"),
                }
            };
            if done.state == RequestState::Done {
                self.metrics.requests_done += 1;
                if done.finish == Some(FinishReason::Stop) {
                    self.metrics.requests_stopped += 1;
                }
                if let Some(l) = done.latency_ns() {
                    self.metrics.latency.record(l);
                }
                if let Some(t) = done.ttft_ns() {
                    self.metrics.ttft.record(t);
                }
            }
            if let (Some(first), Some(end)) = (done.t_first_token_ns, done.t_done_ns) {
                self.tracer.emit(
                    first,
                    Some(done.id),
                    EventKind::DecodePhase {
                        dur_ns: end - first,
                        tokens: done.output.len() as u32,
                    },
                );
            }
            self.tracer.emit(
                done.t_done_ns.unwrap_or(self.now_ns),
                Some(done.id),
                EventKind::Finish { outcome, reason, output_tokens: done.output.len() as u32 },
            );
            self.completed.push(done);
        }

        // --- pool gauges --------------------------------------------------
        if let Numerics::Backend(backend) = &self.numerics {
            if let Some(stats) = backend.kv_pool_stats() {
                self.metrics.observe_kv_pool(&stats);
                self.tracer.observe_kv_pool(self.now_ns, &stats);
            }
            if let Some(stats) = backend.worker_pool_stats() {
                self.metrics.observe_worker_pool(&stats);
                self.tracer.observe_worker_pool(self.now_ns, &stats);
            }
            if let Some(lanes) = backend.worker_pool_lane_dispatches() {
                self.tracer.observe_pool_lanes(self.now_ns, &lanes);
            }
        }

        // --- fault accounting --------------------------------------------
        if !self.faults.is_empty() {
            let counts = self.faults.injected_counts();
            for (i, site) in FaultSite::ALL.iter().enumerate() {
                let delta = counts[i] - self.last_fault_counts[i];
                if delta > 0 {
                    self.tracer.emit(
                        self.now_ns,
                        None,
                        EventKind::FaultInjected { site: site.as_str(), count: delta as u32 },
                    );
                }
            }
            self.last_fault_counts = counts;
            self.metrics.faults_injected = self.faults.injected_total();
        }

        // A step that moved the clock nowhere but still has waiters can
        // only be waiting for a deadline (e.g. an idle engine holding a
        // queued request whose TTFT budget has not elapsed yet): jump the
        // clock to the earliest pending deadline so the sweep fires next
        // step instead of spinning at +0 ns.
        if self.now_ns == step_t0_sim
            && self.batcher.running().is_empty()
            && self.batcher.waiting_len() > 0
        {
            let next_deadline = self
                .batcher
                .waiting()
                .filter_map(|r| {
                    let ttft = r.gen.ttft_deadline_ns.map(|d| r.t_arrive_ns.saturating_add(d));
                    let total = r.gen.total_deadline_ns.map(|d| r.t_arrive_ns.saturating_add(d));
                    [ttft, total].into_iter().flatten().min()
                })
                .min();
            if let Some(ns) = next_deadline {
                self.advance_clock_to(ns);
            }
        }

        self.tracer.emit(
            step_t0_sim,
            None,
            EventKind::EngineStep {
                round: round_no,
                dur_ns: self.now_ns - step_t0_sim,
                running: self.batcher.running().len() as u32,
                waiting: self.batcher.waiting_len() as u32,
            },
        );
        self.metrics.host_time_ns += host_t0.elapsed().as_nanos() as u64;
        Ok(true)
    }

    /// Pop the spill image (and its on-disk byte count) waiting for `id`,
    /// if any. Transient read failures (real or injected by the fault
    /// plan) retry up to [`PERSIST_RETRY_LIMIT`] times; a file that stays
    /// unreadable is logged and dropped — the caller falls back to
    /// re-prefill (spilling is an optimisation, never a correctness
    /// dependency).
    fn take_spill(&mut self, id: RequestId) -> Option<(crate::kvcache::SpillImage, u64)> {
        let Self { spill, faults, metrics, .. } = self;
        let store = spill.as_mut()?;
        let before = store.bytes_read;
        let mut attempt = 0u32;
        loop {
            let res = match faults.check(FaultSite::SpillRead) {
                Some(_) => Err(anyhow::anyhow!("injected spill-read fault (plan)")),
                None => store.take(id),
            };
            match res {
                Ok(Some(img)) => return Some((img, store.bytes_read - before)),
                Ok(None) => return None,
                Err(err) if attempt < PERSIST_RETRY_LIMIT => {
                    attempt += 1;
                    metrics.persist_retries += 1;
                    obs::stderr_log(
                        Level::Warn,
                        "spill_read_retry",
                        format_args!(
                            "spill file of request {id} unreadable (attempt {attempt}): {err:#}"
                        ),
                    );
                }
                Err(err) => {
                    obs::stderr_log(
                        Level::Warn,
                        "spill_read_error",
                        format_args!(
                            "spill file of request {id} unreadable; re-prefilling: {err:#}"
                        ),
                    );
                    return None;
                }
            }
        }
    }

    /// Drive until every request completes; returns completed requests.
    pub fn run_until_idle(&mut self) -> anyhow::Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Finished outputs for a request id (post-retire lookup helper).
    pub fn kv_imbalance(&self) -> usize {
        self.kv.max_imbalance()
    }

    /// Pop a finished request's completion, if it is done.
    pub fn take_completion(&mut self, id: RequestId) -> Option<super::server::Completion> {
        let idx = self.completed.iter().position(|r| r.id == id)?;
        let r = self.completed.swap_remove(idx);
        Some(super::server::Completion {
            id: r.id,
            outcome: r.outcome_str(),
            tokens: r.output.clone(),
            ttft_ns: r.ttft_ns(),
            latency_ns: r.latency_ns(),
            timeline: r.timeline(),
            finish: r.finish,
            rejected: None,
        })
    }

    /// Pop a finished request whole (scenario harness: per-session results
    /// need timings, preemption counts, and the finish reason together).
    pub fn take_finished_request(&mut self, id: RequestId) -> Option<Request> {
        let idx = self.completed.iter().position(|r| r.id == id)?;
        Some(self.completed.swap_remove(idx))
    }

    /// Drain every finished request collected so far.
    pub fn drain_finished(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> ServingEngine {
        ServingEngine::new(EngineConfig {
            preset: ModelPreset::Llama1B,
            hw: HwParams::default(),
            policy: BatchPolicy::default(),
            numerics: Numerics::Synthetic { vocab: 128_256 },
        })
        .unwrap()
    }

    #[test]
    fn serve_synthetic_batch() {
        let mut e = engine();
        for i in 0..4 {
            e.submit(vec![1 + i; 64], 16).expect("submit");
        }
        e.run_until_idle().unwrap();
        assert_eq!(e.metrics.requests_done, 4);
        assert_eq!(e.metrics.decode_tokens, 4 * 16);
        assert_eq!(e.metrics.prefill_tokens, 4 * 64);
        assert!(e.metrics.sim_time_ns > 0);
        assert!(e.metrics.energy_j > 0.0);
        assert!(e.metrics.npm_swaps > 0);
        assert_eq!(e.kv.live_requests(), 0, "all KV released");
    }

    #[test]
    fn latency_metrics_recorded() {
        let mut e = engine();
        e.submit(vec![5; 32], 8).expect("submit");
        e.run_until_idle().unwrap();
        assert_eq!(e.metrics.latency.count(), 1);
        assert_eq!(e.metrics.ttft.count(), 1);
        let (p50, _) = e.metrics.latency_p50_p99();
        assert!(p50 > 0);
        // TTFT ≤ total latency
        assert!(e.metrics.ttft.max() <= e.metrics.latency.max());
    }

    #[test]
    fn oversized_request_rejected_at_submit_typed() {
        let mut e = engine();
        e.kv.set_capacity_tokens(100); // 6 blocks of 16 tokens
        e.batcher.policy.max_total_ctx = 100_000;
        // 90 + 20 = 110 ledger positions = 7 blocks > 6: typed reject
        let err = e.submit(vec![1; 90], 20).unwrap_err();
        assert!(matches!(err, SubmitError::KvNeverFits { .. }), "got {err}");
        assert_eq!(e.metrics.requests_rejected, 1);
        assert!(e.batcher.is_idle(), "rejected requests never queue");
        // a request that fits is still served normally afterwards
        e.submit(vec![1; 40], 2).expect("fits in 3 blocks");
        e.run_until_idle().unwrap();
        assert_eq!(e.metrics.requests_done, 1);
        assert_eq!(e.metrics.requests_failed, 0);
    }

    #[test]
    fn submit_rejections_are_typed() {
        let mut e = engine();
        assert_eq!(e.submit(vec![], 4), Err(SubmitError::EmptyPrompt));
        assert_eq!(e.submit(vec![1], 0), Err(SubmitError::ZeroMaxNewTokens));
        assert_eq!(e.metrics.requests_rejected, 2);

        // window-typed rejections need a backend that knows its s_max
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tiny_ref");
        let mut e = ServingEngine::new(EngineConfig {
            preset: ModelPreset::Tiny,
            hw: HwParams::default(),
            policy: BatchPolicy::default(),
            numerics: Numerics::reference(&dir).unwrap(),
        })
        .unwrap();
        let err = e.submit(vec![1; 129], 1).unwrap_err(); // s_max = 128
        assert!(matches!(err, SubmitError::PromptTooLong { s_max: 128, .. }), "got {err}");
        let err = e.submit(vec![1; 100], 40).unwrap_err(); // 100 + 39 > 128
        assert!(matches!(err, SubmitError::ContextTooLong { .. }), "got {err}");
        assert!(err.to_string().contains("s_max"), "unhelpful rendering: {err}");
        // the boundary itself is accepted
        e.submit(vec![1; 100], 29).expect("100 + 28 = 128 fits exactly");
    }

    #[test]
    fn chunked_prefill_same_tokens_better_neighbor_ttft() {
        // synthetic numerics: outputs must be identical with chunking on or
        // off, while a short request's TTFT improves when its long
        // neighbor's prefill is chunked (the decode/prefill interleave).
        let run = |chunk: Option<usize>| {
            let mut e = engine();
            e.prefill_chunk = chunk;
            let long = e.submit(vec![3; 70], 4).expect("submit");
            let short = e.submit(vec![4; 10], 4).expect("submit");
            e.run_until_idle().unwrap();
            let l = e.take_finished_request(long).unwrap();
            let s = e.take_finished_request(short).unwrap();
            (l.output, s.output, s.ttft_ns().unwrap(), e.metrics.clone())
        };
        let (l_mono, s_mono, ttft_mono, m_mono) = run(None);
        let (l_chunk, s_chunk, ttft_chunk, m_chunk) = run(Some(16));
        assert_eq!(l_mono, l_chunk, "chunking must not change tokens");
        assert_eq!(s_mono, s_chunk);
        assert!(
            ttft_chunk < ttft_mono,
            "short request behind a 70-token prompt: chunked TTFT {ttft_chunk} \
             must beat monolithic {ttft_mono}"
        );
        assert_eq!(m_mono.prefill_chunks, 2, "one dispatch per prompt");
        assert_eq!(m_chunk.prefill_chunks, 6, "ceil(70/16) + ceil(10/16) dispatches");
        assert_eq!(m_mono.prefill_tokens, m_chunk.prefill_tokens);
        assert_eq!(m_mono.decode_tokens, m_chunk.decode_tokens);
    }

    #[test]
    fn stop_sequence_truncates_and_counts() {
        // learn the deterministic synthetic stream, then stop on its third
        // token and expect a truncated output with FinishReason::Stop
        let mut e = engine();
        let id = e.submit(vec![2; 16], 6).expect("submit");
        e.run_until_idle().unwrap();
        let full = e.take_finished_request(id).unwrap().output;
        assert_eq!(full.len(), 6);

        let gen = GenerationConfig {
            max_new_tokens: 6,
            stop: vec![vec![full[2]]],
            ..GenerationConfig::default()
        };
        let mut e = engine();
        let id = e.submit_with(vec![2; 16], gen).expect("submit");
        e.run_until_idle().unwrap();
        let r = e.take_finished_request(id).unwrap();
        assert_eq!(r.output, &full[..2], "matched stop token truncated");
        assert_eq!(r.finish, Some(super::FinishReason::Stop));
        assert_eq!(e.metrics.requests_stopped, 1);
        assert_eq!(e.metrics.requests_done, 1);
    }

    #[test]
    fn invalid_config_rejected_at_submit() {
        let mut e = engine();
        let err = e
            .submit_with(vec![1; 4], GenerationConfig { top_p: 2.0, ..Default::default() })
            .unwrap_err();
        assert!(matches!(err, SubmitError::InvalidConfig { .. }), "got {err}");
        assert!(err.to_string().contains("top_p"), "unhelpful rendering: {err}");
        assert_eq!(e.metrics.requests_rejected, 1);
        assert!(e.batcher.is_idle(), "rejected requests never queue");
    }

    #[test]
    fn tracing_records_lifecycle_and_stays_invisible() {
        let run = |trace: bool| {
            let mut e = engine();
            if trace {
                e.tracer = Tracer::enabled(1 << 12);
            }
            let id = e.submit(vec![2; 32], 6).expect("submit");
            e.run_until_idle().unwrap();
            let out = e.take_finished_request(id).unwrap().output;
            (out, e.metrics.sim_time_ns, e)
        };
        let (out_off, sim_off, e_off) = run(false);
        let (out_on, sim_on, e_on) = run(true);
        assert_eq!(out_off, out_on, "tracing must not change tokens");
        assert_eq!(sim_off, sim_on, "tracing must not change simulated time");
        assert_eq!(e_off.tracer.recorded(), 0);
        assert!(e_on.tracer.recorded() > 0);
        let kinds: std::collections::BTreeSet<&str> =
            e_on.tracer.events().iter().map(|ev| ev.kind.name()).collect();
        for k in [
            "submit",
            "admission",
            "admitted",
            "prefill_chunk",
            "first_token",
            "decode_round",
            "decode_phase",
            "finish",
            "engine_step",
        ] {
            assert!(kinds.contains(k), "missing {k} in {kinds:?}");
        }
    }

    #[test]
    fn decode_slows_with_context_growth() {
        let mut e = engine();
        e.submit(vec![1; 16], 4).expect("submit");
        e.run_until_idle().unwrap();
        let t_short = e.metrics.sim_time_ns;
        let mut e2 = engine();
        e2.submit(vec![1; 2048], 4).expect("submit");
        e2.run_until_idle().unwrap();
        assert!(e2.metrics.sim_time_ns > t_short);
    }

    #[test]
    fn program_cache_reused_across_requests() {
        let mut e = engine();
        for _ in 0..3 {
            e.submit(vec![1; 64], 8).expect("submit");
        }
        e.run_until_idle().unwrap();
        assert!(e.compiled.cache_hits > e.compiled.cache_misses);
    }

    #[test]
    fn ttft_deadline_in_queue_times_out_without_prefill() {
        // max_batch = 0: the request can never be admitted, so its TTFT
        // deadline elapses in the queue. The livelock guard jumps the
        // idle clock to the deadline (run_until_idle must terminate) and
        // the sweep aborts it typed — never prefilled, never counted as
        // failed, absent from the latency/TTFT histograms.
        let mut e = engine();
        e.batcher.policy.max_batch = 0;
        e.tracer = Tracer::enabled(256);
        let gen = GenerationConfig { ttft_deadline_ns: Some(10), ..GenerationConfig::greedy(4) };
        let id = e.submit_with(vec![1; 8], gen).expect("submit");
        e.run_until_idle().unwrap();
        let r = e.take_finished_request(id).unwrap();
        assert_eq!(r.outcome_str(), "timeout");
        assert_eq!(r.finish, Some(FinishReason::Timeout));
        assert!(r.output.is_empty());
        assert_eq!(e.metrics.prefill_tokens, 0, "a queue timeout is never prefilled");
        assert_eq!(e.metrics.requests_timeout, 1);
        assert_eq!(e.metrics.requests_failed, 0, "timeout is typed, not a failure");
        assert_eq!(e.metrics.requests_done, 0);
        assert_eq!(e.metrics.latency.count(), 0);
        assert_eq!(e.metrics.ttft.count(), 0);
        let kinds: Vec<&str> = e.tracer.events().iter().map(|ev| ev.kind.name()).collect();
        assert!(kinds.contains(&"timeout"), "missing timeout event in {kinds:?}");
        assert!(!kinds.contains(&"prefill_chunk"));
    }

    #[test]
    fn total_deadline_aborts_mid_decode_typed() {
        let mut e = engine();
        let gen = GenerationConfig {
            total_deadline_ns: Some(1), // elapses after the first step
            ..GenerationConfig::greedy(1000)
        };
        let id = e.submit_with(vec![1; 16], gen).expect("submit");
        e.run_until_idle().unwrap();
        let r = e.take_finished_request(id).unwrap();
        assert_eq!(r.outcome_str(), "timeout");
        assert!(!r.output.is_empty(), "the pre-deadline tokens are kept");
        assert!(r.output.len() < 1000);
        assert_eq!(e.metrics.requests_timeout, 1);
        assert_eq!(e.metrics.requests_failed, 0);
        assert_eq!(e.kv.live_requests(), 0, "aborted request released its KV");
    }

    #[test]
    fn deadlines_do_not_disturb_on_time_neighbors() {
        let run = |with_deadline: bool| {
            let mut e = engine();
            let a = e.submit(vec![2; 32], 8).expect("submit");
            let gen = GenerationConfig {
                total_deadline_ns: with_deadline.then_some(1),
                ..GenerationConfig::greedy(1000)
            };
            let b = e.submit_with(vec![3; 32], gen).expect("submit");
            e.run_until_idle().unwrap();
            (e.take_finished_request(a).unwrap().output, b)
        };
        let (on_time_base, _) = run(false);
        let (on_time_chaos, _) = run(true);
        assert_eq!(
            on_time_base, on_time_chaos,
            "a neighbor's timeout must be bitwise-invisible to on-time sessions"
        );
    }

    #[test]
    fn overload_sheds_lowest_priority_youngest_first() {
        let mut e = engine();
        e.batcher.policy.max_batch = 1;
        e.overload = OverloadPolicy { max_waiting: Some(1), age_exempt_ns: 1_000_000 };
        e.tracer = Tracer::enabled(256);
        let sub = |e: &mut ServingEngine, priority: u8| {
            e.submit_with(
                vec![1; 8],
                GenerationConfig { priority, ..GenerationConfig::greedy(2) },
            )
            .expect("submit")
        };
        let a = sub(&mut e, 5);
        let b = sub(&mut e, 1);
        let c = sub(&mut e, 9);
        e.run_until_idle().unwrap();
        // step 1 sheds down to one waiter before admission: the lowest
        // class (b, priority 1) goes first, then the lower of the rest (a)
        assert_eq!(e.take_finished_request(b).unwrap().outcome_str(), "shed");
        assert_eq!(e.take_finished_request(a).unwrap().outcome_str(), "shed");
        assert_eq!(e.take_finished_request(c).unwrap().outcome_str(), "done");
        assert_eq!(e.metrics.requests_shed, 2);
        assert_eq!(e.metrics.requests_done, 1);
        assert_eq!(e.metrics.requests_failed, 0);
        let kinds: Vec<&str> = e.tracer.events().iter().map(|ev| ev.kind.name()).collect();
        assert_eq!(kinds.iter().filter(|&&k| k == "shed").count(), 2);
    }

    #[test]
    fn aged_waiters_are_shed_exempt() {
        let mut e = engine();
        e.batcher.policy.max_batch = 0; // nothing ever admits
        e.overload = OverloadPolicy { max_waiting: Some(0), age_exempt_ns: 50 };
        e.submit(vec![1; 4], 2).expect("submit");
        e.advance_clock_to(100); // the waiter is now 100 ns old: exempt
        assert!(e.step().unwrap());
        assert_eq!(e.metrics.requests_shed, 0, "aged waiters are never shed");
        assert_eq!(e.batcher.waiting_len(), 1);
    }

    #[test]
    fn block_alloc_fault_rejects_typed_and_bounded() {
        let mut e = engine();
        e.faults =
            crate::faults::FaultPlan::parse("site=block_alloc at=1 mode=transient times=1")
                .unwrap();
        let a = e.submit(vec![1; 8], 2).expect("submit");
        let b = e.submit(vec![2; 8], 2).expect("submit");
        e.run_until_idle().unwrap();
        assert_eq!(e.take_finished_request(a).unwrap().outcome_str(), "failed");
        assert_eq!(e.take_finished_request(b).unwrap().outcome_str(), "done");
        assert_eq!(e.metrics.requests_failed, 1);
        assert_eq!(e.metrics.requests_done, 1);
        assert_eq!(e.metrics.faults_injected, 1);
    }

    #[test]
    fn permanent_block_alloc_fault_drains_typed_never_hangs() {
        let mut e = engine();
        e.faults = crate::faults::FaultPlan::parse("site=block_alloc at=1").unwrap();
        for i in 0..4 {
            e.submit(vec![1 + i; 8], 2).expect("submit");
        }
        e.run_until_idle().unwrap(); // must terminate
        assert_eq!(e.metrics.requests_failed, 4, "every admission rejected, typed");
        assert_eq!(e.metrics.requests_done, 0);
        assert!(e.batcher.is_idle());
    }
}
