//! The serving engine: ties the batcher, KV manager, compiler cache, NPM
//! double banking, the timing/energy simulator, and (for the tiny model)
//! a functional numerics backend into a single decode-round loop.
//!
//! Timing model: the engine advances a *simulated* clock by the cycle cost
//! of each program it dispatches (analytical model — identical to what the
//! instruction-level simulator measures, see `tests/integration_sim.rs`).
//! Numerics: with [`Numerics::Backend`], every prefill/decode also runs a
//! real forward pass through the pluggable [`NumericsBackend`] (pure-Rust
//! reference f32 by default, PJRT with `--features xla`), so generated
//! tokens are real model outputs.

use std::time::Instant;

use crate::arch::{HwParams, TileGeometry};
use crate::compiler::{Compiler, CompiledModel};
use crate::energy::table2;
use crate::isa::Npm;
use crate::model::ModelPreset;
use crate::runtime::{argmax_row, NumericsBackend, ReferenceBackend};
use crate::sim::analytical::WAVEFRONT_MACROS;
use crate::sim::AnalyticalSim;

use super::batcher::{BatchPolicy, Batcher};
use super::kv::KvManager;
use super::metrics::Metrics;
use super::request::{Request, RequestId, RequestState};

/// Functional-numerics configuration.
pub enum Numerics {
    /// Run a real forward pass through a pluggable backend (tiny model).
    Backend(Box<dyn NumericsBackend>),
    /// Synthetic token generation (big-model simulation-only serving).
    Synthetic { vocab: usize },
}

impl Numerics {
    /// The pure-Rust reference backend over an artifact/fixture directory.
    pub fn reference(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        Ok(Self::Backend(Box::new(ReferenceBackend::load(dir)?)))
    }

    /// The PJRT backend over an AOT artifact directory.
    #[cfg(feature = "xla")]
    pub fn pjrt(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        Ok(Self::Backend(Box::new(crate::runtime::PjrtBackend::load(dir)?)))
    }

    /// Synthetic numerics for simulation-only serving.
    pub fn synthetic(vocab: usize) -> Self {
        Self::Synthetic { vocab }
    }

    /// Backend name for diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Backend(b) => b.name(),
            Self::Synthetic { .. } => "synthetic",
        }
    }
}

/// Engine construction options.
pub struct EngineConfig {
    pub preset: ModelPreset,
    pub hw: HwParams,
    pub policy: BatchPolicy,
    pub numerics: Numerics,
}

/// The serving engine.
pub struct ServingEngine {
    pub compiled: CompiledModel,
    pub sim: AnalyticalSim,
    pub batcher: Batcher,
    pub kv: KvManager,
    pub npm: Npm,
    pub metrics: Metrics,
    numerics: Numerics,
    next_id: RequestId,
    /// Simulated clock, ns.
    now_ns: u64,
    /// Finished requests awaiting pickup (server replies).
    completed: Vec<Request>,
}

impl ServingEngine {
    pub fn new(cfg: EngineConfig) -> anyhow::Result<Self> {
        let compiler = Compiler { hw: cfg.hw.clone(), run_dse: false };
        let compiled = compiler.compile(cfg.preset)?;
        let sim = AnalyticalSim::new(cfg.preset, cfg.hw.clone());
        let geom = TileGeometry::for_model(compiled.shape.d_model, &cfg.hw);
        let kv = KvManager::new(&geom, compiled.shape.d_head(), compiled.shape.n_layers);
        Ok(Self {
            compiled,
            sim,
            batcher: Batcher::new(cfg.policy),
            kv,
            npm: Npm::new(),
            metrics: Metrics::default(),
            numerics: cfg.numerics,
            next_id: 0,
            now_ns: 0,
            completed: Vec::new(),
        })
    }

    /// Submit a prompt; returns the request id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.submit(Request::new(id, prompt, max_new_tokens, self.now_ns));
        id
    }

    /// Simulated time now, ns.
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    fn advance(&mut self, cycles: u64) {
        let ns = (cycles as f64 / self.sim.hw.freq_ghz) as u64;
        self.now_ns += ns;
        self.metrics.sim_time_ns += ns;
        // Energy: active wavefront draw over the elapsed time.
        let wavefront = self.sim.mapped_macros().min(WAVEFRONT_MACROS);
        self.metrics.energy_j += wavefront as f64 * table2::MACRO_UW * 1e-6 * ns as f64 * 1e-9;
    }

    /// Mark a running request Failed at the current simulated time.
    fn fail_request(&mut self, id: RequestId) {
        let now = self.now_ns;
        if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
            r.state = RequestState::Failed;
            r.t_done_ns = Some(now);
        }
        self.metrics.requests_failed += 1;
    }

    /// Load + swap the NPM with the program for this phase (double-banked).
    fn dispatch(&mut self, prog: crate::isa::Program) -> anyhow::Result<u64> {
        let cycles = prog.controller_cycles();
        self.npm.load(prog)?;
        self.npm.swap()?;
        self.metrics.npm_swaps += 1;
        Ok(cycles)
    }

    /// One engine iteration: admit, prefill admitted, one decode round.
    /// Returns false when idle.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        let host_t0 = Instant::now();
        if self.batcher.is_idle() {
            return Ok(false);
        }

        // --- admission + prefill -----------------------------------------
        let admitted = self.batcher.admit();
        for id in admitted {
            let (prompt, max_ctx) = {
                let r = self.batcher.running().iter().find(|r| r.id == id).unwrap();
                (r.prompt.clone(), r.ctx_len() + r.max_new_tokens)
            };
            if !self.kv.has_room(max_ctx) {
                self.fail_request(id);
                continue;
            }
            self.kv.prefill(id, prompt.len())?;

            // timing: one prefill program per layer, layers sequential
            let layers = self.compiled.shape.n_layers as u64;
            let prog = self.compiled.prefill_program(prompt.len().max(1)).clone();
            let per_layer = self.dispatch(prog)?;
            self.advance(per_layer * layers);
            self.metrics.prefill_tokens += prompt.len() as u64;

            // numerics — a backend error (e.g. out-of-vocab prompt) fails
            // this request only; the engine and its batch keep serving
            let first_token = match &mut self.numerics {
                Numerics::Backend(backend) => match backend.prefill(id, &prompt) {
                    // enforce the trait's no-silent-truncation contract:
                    // fewer rows than prompt tokens would argmax the wrong
                    // context, so fail the request instead
                    Ok(out) if out.rows >= prompt.len() => {
                        Some(argmax_row(&out.logits, prompt.len() - 1, backend.vocab()) as i32)
                    }
                    Ok(out) => {
                        eprintln!(
                            "request {id} rejected: backend returned {} logits rows \
                             for a {}-token prompt",
                            out.rows,
                            prompt.len()
                        );
                        backend.release(id);
                        None
                    }
                    Err(err) => {
                        eprintln!("request {id} rejected by numerics prefill: {err:#}");
                        backend.release(id);
                        None
                    }
                },
                Numerics::Synthetic { vocab } => {
                    Some((prompt.iter().map(|&t| t as i64).sum::<i64>() % *vocab as i64) as i32)
                }
            };
            let Some(first_token) = first_token else {
                self.kv.release(id);
                self.fail_request(id);
                continue;
            };

            let now = self.now_ns;
            if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
                r.state = RequestState::Decoding;
                r.output.push(first_token);
                r.t_first_token_ns = Some(now);
                // single-token generations finish at prefill
                if r.output.len() >= r.max_new_tokens {
                    r.state = RequestState::Done;
                    r.t_done_ns = Some(now);
                }
            }
            self.kv.append(id)?;
            self.metrics.decode_tokens += 1;
        }

        // --- one decode round over the running batch ---------------------
        let round: Vec<(RequestId, usize, i32)> = self
            .batcher
            .running()
            .iter()
            .filter(|r| r.state == RequestState::Decoding && !r.is_finished())
            .map(|r| (r.id, r.ctx_len(), *r.output.last().unwrap_or(&0)))
            .collect();

        // timing: one decode program per request per layer (unchanged —
        // the simulated hardware serves requests round-robin). Each
        // request's token lands at the simulated instant its own dispatch
        // completed, same as the pre-batching engine.
        let mut done_at: Vec<u64> = Vec::with_capacity(round.len());
        for &(_, ctx, _) in &round {
            let layers = self.compiled.shape.n_layers as u64;
            let prog = self.compiled.decode_program(ctx).clone();
            let per_layer = self.dispatch(prog)?;
            self.advance(per_layer * layers);
            done_at.push(self.now_ns);
        }

        // numerics: ONE batched call for the whole round — a weight-
        // stationary backend streams each weight matrix once for every
        // live session (LEAP's dataflow, in software). A per-session error
        // fails that request only.
        let next_tokens: Vec<(RequestId, Option<i32>)> = match &mut self.numerics {
            Numerics::Backend(backend) => {
                let steps: Vec<(u64, i32)> = round.iter().map(|&(id, _, t)| (id, t)).collect();
                let outs = backend.decode_batch(&steps)?;
                anyhow::ensure!(
                    outs.len() == steps.len(),
                    "backend decode_batch returned {} results for {} steps",
                    outs.len(),
                    steps.len()
                );
                let vocab = backend.vocab();
                round
                    .iter()
                    .zip(outs)
                    .map(|(&(id, _, _), res)| match res {
                        Ok(out) => (id, Some(argmax_row(&out.logits, 0, vocab) as i32)),
                        Err(err) => {
                            eprintln!("request {id} failed in numerics decode: {err:#}");
                            (id, None)
                        }
                    })
                    .collect()
            }
            Numerics::Synthetic { vocab } => round
                .iter()
                .map(|&(id, ctx, _)| (id, Some(((ctx * 2654435761) % *vocab) as i32)))
                .collect(),
        };

        for ((id, next), now) in next_tokens.into_iter().zip(done_at) {
            let Some(next) = next else {
                self.fail_request(id);
                continue;
            };

            if !self.kv.has_room(1) {
                // out of scratchpad: finish the request early
                if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
                    r.state = RequestState::Done;
                    r.t_done_ns = Some(now);
                }
                continue;
            }
            self.kv.append(id)?;
            self.metrics.decode_tokens += 1;
            if let Some(r) = self.batcher.running_mut().iter_mut().find(|r| r.id == id) {
                r.output.push(next);
                if r.output.len() >= r.max_new_tokens {
                    r.state = RequestState::Done;
                    r.t_done_ns = Some(now);
                }
            }
        }

        // --- retire -------------------------------------------------------
        for done in self.batcher.retire() {
            self.kv.release(done.id);
            if let Numerics::Backend(backend) = &mut self.numerics {
                backend.release(done.id);
            }
            if done.state == RequestState::Done {
                self.metrics.requests_done += 1;
                if let Some(l) = done.latency_ns() {
                    self.metrics.latencies_ns.push(l);
                }
                if let Some(t) = done.ttft_ns() {
                    self.metrics.ttft_ns.push(t);
                }
            }
            self.completed.push(done);
        }

        self.metrics.host_time_ns += host_t0.elapsed().as_nanos() as u64;
        Ok(true)
    }

    /// Drive until every request completes; returns completed requests.
    pub fn run_until_idle(&mut self) -> anyhow::Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Finished outputs for a request id (post-retire lookup helper).
    pub fn kv_imbalance(&self) -> usize {
        self.kv.max_imbalance()
    }

    /// Pop a finished request's completion, if it is done.
    pub fn take_completion(&mut self, id: RequestId) -> Option<super::server::Completion> {
        let idx = self.completed.iter().position(|r| r.id == id)?;
        let r = self.completed.swap_remove(idx);
        Some(super::server::Completion {
            id: r.id,
            tokens: r.output.clone(),
            ttft_ns: r.ttft_ns(),
            latency_ns: r.latency_ns(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> ServingEngine {
        ServingEngine::new(EngineConfig {
            preset: ModelPreset::Llama1B,
            hw: HwParams::default(),
            policy: BatchPolicy::default(),
            numerics: Numerics::Synthetic { vocab: 128_256 },
        })
        .unwrap()
    }

    #[test]
    fn serve_synthetic_batch() {
        let mut e = engine();
        for i in 0..4 {
            e.submit(vec![1 + i; 64], 16);
        }
        e.run_until_idle().unwrap();
        assert_eq!(e.metrics.requests_done, 4);
        assert_eq!(e.metrics.decode_tokens, 4 * 16);
        assert_eq!(e.metrics.prefill_tokens, 4 * 64);
        assert!(e.metrics.sim_time_ns > 0);
        assert!(e.metrics.energy_j > 0.0);
        assert!(e.metrics.npm_swaps > 0);
        assert_eq!(e.kv.live_requests(), 0, "all KV released");
    }

    #[test]
    fn latency_metrics_recorded() {
        let mut e = engine();
        e.submit(vec![5; 32], 8);
        e.run_until_idle().unwrap();
        assert_eq!(e.metrics.latencies_ns.len(), 1);
        assert_eq!(e.metrics.ttft_ns.len(), 1);
        let (p50, _) = e.metrics.latency_p50_p99();
        assert!(p50 > 0);
        // TTFT ≤ total latency
        assert!(e.metrics.ttft_ns[0] <= e.metrics.latencies_ns[0]);
    }

    #[test]
    fn oversized_request_fails_cleanly() {
        let mut e = engine();
        e.kv.capacity_tokens = 100;
        e.batcher.policy.max_total_ctx = 100_000;
        e.submit(vec![1; 90], 20); // 110 total > 100 capacity
        e.run_until_idle().unwrap();
        assert_eq!(e.metrics.requests_failed, 1);
        assert_eq!(e.metrics.requests_done, 0);
    }

    #[test]
    fn decode_slows_with_context_growth() {
        let mut e = engine();
        e.submit(vec![1; 16], 4);
        e.run_until_idle().unwrap();
        let t_short = e.metrics.sim_time_ns;
        let mut e2 = engine();
        e2.submit(vec![1; 2048], 4);
        e2.run_until_idle().unwrap();
        assert!(e2.metrics.sim_time_ns > t_short);
    }

    #[test]
    fn program_cache_reused_across_requests() {
        let mut e = engine();
        for _ in 0..3 {
            e.submit(vec![1; 64], 8);
        }
        e.run_until_idle().unwrap();
        assert!(e.compiled.cache_hits > e.compiled.cache_misses);
    }
}
