//! Per-request generation configuration and the seeded deterministic
//! sampler.
//!
//! The sampler is **counter-based**: the randomness for generation step
//! `n` is derived from `(seed, n)` alone, never from mutable RNG state
//! threaded through the decode loop. That makes sampling compatible with
//! the engine's preemption discipline — a preempted request re-prefills
//! `prompt ++ output` and resumes at the same step index, so the replayed
//! draw consumes exactly the same randomness and the token stream is
//! identical to an uninterrupted run. It also makes the stream independent
//! of batch composition and worker-pool size: the backend's logits are
//! bitwise-identical across pool sizes (see `forward_rows`), and all
//! sampler arithmetic happens in f64 on the coordinator thread.
//!
//! Pipeline per step: repetition penalty (over prompt + generated history)
//! → greedy shortcut at `temperature == 0` → temperature scaling → top-k
//! → softmax → top-p (nucleus) → renormalise → one uniform draw. The
//! penalty is applied *before* filtering, so a token filtered out by
//! top-k/top-p can never be resurrected by any later stage.

use crate::testutil::SplitMix64;

use super::engine::SubmitError;

/// Default priority class: mid-scale, so callers can express both "more
/// important" and "less important" without touching every submit site.
pub const DEFAULT_PRIORITY: u8 = 100;

/// Per-request sampling/termination knobs. [`Default`] is greedy decode
/// with 16 tokens — byte-identical to the pre-sampling engine behaviour.
#[derive(Debug, Clone, PartialEq)]
pub struct GenerationConfig {
    /// Maximum generated tokens (≥ 1).
    pub max_new_tokens: usize,
    /// Softmax temperature; `0.0` selects exact greedy argmax (the
    /// NaN-safe, lowest-index-ties semantics of
    /// [`crate::runtime::argmax_row`]).
    pub temperature: f32,
    /// Keep only the `top_k` highest-probability tokens (`0` = off).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest probability-sorted prefix with
    /// cumulative probability ≥ `top_p` (`1.0` = off).
    pub top_p: f32,
    /// Divide positive / multiply negative logits of tokens already in the
    /// prompt or output by this factor (`1.0` = off; > 1 discourages
    /// repetition — the HF/CTRL convention).
    pub repetition_penalty: f32,
    /// Stop sequences over *generated* tokens. When the output ends with
    /// one, the request finishes and the matched tokens are truncated from
    /// the output.
    pub stop: Vec<Vec<i32>>,
    /// Seed of the counter-based per-step RNG.
    pub seed: u64,
    /// SLO: abort with [`super::request::FinishReason::Timeout`] if the
    /// first token has not been produced within this many simulated ns of
    /// arrival. A queued request whose TTFT deadline elapses is timed out
    /// without ever being prefilled. `None` = no deadline.
    pub ttft_deadline_ns: Option<u64>,
    /// SLO: abort with a typed `Timeout` if the request has not reached a
    /// terminal state within this many simulated ns of arrival.
    pub total_deadline_ns: Option<u64>,
    /// Priority class for overload shedding: higher is more important.
    /// Under queue pressure the engine sheds the *lowest* class first
    /// (ties: youngest first), with aging so no class starves.
    pub priority: u8,
}

impl Default for GenerationConfig {
    fn default() -> Self {
        Self::greedy(16)
    }
}

impl GenerationConfig {
    /// Greedy decode for `max_new_tokens` — what [`super::ServingEngine::submit`]
    /// uses, and exactly the pre-sampling engine behaviour.
    pub fn greedy(max_new_tokens: usize) -> Self {
        Self {
            max_new_tokens,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            stop: Vec::new(),
            seed: 0,
            ttft_deadline_ns: None,
            total_deadline_ns: None,
            priority: DEFAULT_PRIORITY,
        }
    }

    /// True when every step reduces to argmax (no randomness consumed).
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    /// Typed validation, shared by the engine's submit path: a config that
    /// can never run is refused before it queues.
    pub fn validate(&self) -> Result<(), SubmitError> {
        if self.max_new_tokens == 0 {
            return Err(SubmitError::ZeroMaxNewTokens);
        }
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            return Err(SubmitError::InvalidConfig {
                reason: "temperature must be finite and >= 0",
            });
        }
        if !(self.top_p > 0.0 && self.top_p <= 1.0) {
            return Err(SubmitError::InvalidConfig { reason: "top_p must be in (0, 1]" });
        }
        if !self.repetition_penalty.is_finite() || self.repetition_penalty <= 0.0 {
            return Err(SubmitError::InvalidConfig {
                reason: "repetition_penalty must be finite and > 0",
            });
        }
        if self.stop.iter().any(Vec::is_empty) {
            return Err(SubmitError::InvalidConfig {
                reason: "stop sequences must be non-empty",
            });
        }
        Ok(())
    }
}

/// The counter-based RNG for generation step `step`: a fresh SplitMix64
/// whose seed mixes the config seed with the step index, so draw `n` is a
/// pure function of `(seed, n)` (preemption replay consumes identical
/// randomness).
fn step_rng(seed: u64, step: usize) -> SplitMix64 {
    // wyhash-style odd multiplier decorrelates consecutive step indices
    // before SplitMix64's own finaliser mixes them further.
    SplitMix64::new(seed ^ (step as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F))
}

/// Total-order key for sorting logits: NaN sorts like −∞ (it can never
/// win — argmax semantics), ±∞ clamps to the finite range so softmax
/// shifting stays well-defined.
fn sort_key(x: f64) -> f64 {
    if x.is_nan() {
        f64::NEG_INFINITY
    } else {
        x.clamp(f64::MIN, f64::MAX)
    }
}

/// The post-penalty, post-filter, renormalised sampling distribution for
/// one `[vocab]` logits row: `(token, probability)` pairs sorted by
/// probability descending, ties to the lower token id. Greedy
/// (`temperature == 0`) returns the single argmax token with probability 1.
/// Exposed for the property tests — [`sample`] draws from exactly this.
pub fn distribution(
    cfg: &GenerationConfig,
    logits: &[f32],
    prompt: &[i32],
    output: &[i32],
) -> Vec<(usize, f64)> {
    let vocab = logits.len();
    debug_assert!(vocab > 0, "empty logits row");
    let mut adj: Vec<f64> = logits.iter().map(|&v| v as f64).collect();

    // -- repetition penalty over the unique history tokens, BEFORE any
    //    filtering (a penalised token can drop out of the top-k/top-p
    //    support but never re-enter it) --------------------------------
    if cfg.repetition_penalty != 1.0 {
        let p = cfg.repetition_penalty as f64;
        let mut seen = vec![false; vocab];
        for &t in prompt.iter().chain(output.iter()) {
            let Ok(t) = usize::try_from(t) else { continue };
            if t < vocab && !seen[t] {
                seen[t] = true;
                adj[t] = if adj[t] > 0.0 { adj[t] / p } else { adj[t] * p };
            }
        }
    }

    // -- greedy shortcut: exact argmax_row semantics (NaN never wins,
    //    ties break to the lowest index) ------------------------------
    if cfg.temperature == 0.0 {
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &v) in adj.iter().enumerate() {
            if v > best_v {
                best = i;
                best_v = v;
            }
        }
        return vec![(best, 1.0)];
    }

    // -- sort by penalised logit desc (== probability desc), truncate to
    //    top-k --------------------------------------------------------
    let mut idx: Vec<usize> = (0..vocab).collect();
    idx.sort_by(|&a, &b| {
        sort_key(adj[b]).partial_cmp(&sort_key(adj[a])).unwrap().then(a.cmp(&b))
    });
    if cfg.top_k > 0 {
        idx.truncate(cfg.top_k.min(vocab));
    }

    // -- softmax over the kept set (max-shifted; temperature folded into
    //    the exponent) ------------------------------------------------
    let mx = sort_key(adj[idx[0]]);
    if mx == f64::NEG_INFINITY {
        // degenerate row (all −∞/NaN): match argmax's lowest-index rule
        return vec![(idx[0], 1.0)];
    }
    let inv_t = 1.0 / cfg.temperature as f64;
    let mut probs: Vec<f64> = idx.iter().map(|&i| ((sort_key(adj[i]) - mx) * inv_t).exp()).collect();
    let sum: f64 = probs.iter().sum();

    // -- nucleus (top-p): minimal sorted prefix with cumulative
    //    probability ≥ top_p; always keeps at least the argmax ---------
    if cfg.top_p < 1.0 {
        let tp = cfg.top_p as f64;
        let mut cum = 0.0;
        let mut keep = idx.len();
        for (j, &p) in probs.iter().enumerate() {
            cum += p / sum;
            if cum >= tp {
                keep = j + 1;
                break;
            }
        }
        idx.truncate(keep);
        probs.truncate(keep);
    }

    // -- renormalise the surviving support -----------------------------
    let ksum: f64 = probs.iter().sum();
    idx.into_iter().zip(probs).map(|(i, p)| (i, p / ksum)).collect()
}

/// Draw the next token for generation step `step` (`= output.len()` at
/// sampling time). Deterministic: a pure function of the config, the
/// logits row, and the history. Greedy configs consume no randomness.
pub fn sample(
    cfg: &GenerationConfig,
    logits: &[f32],
    prompt: &[i32],
    output: &[i32],
    step: usize,
) -> usize {
    let dist = distribution(cfg, logits, prompt, output);
    if dist.len() == 1 {
        return dist[0].0;
    }
    let u = step_rng(cfg.seed, step).f64();
    let mut cum = 0.0;
    for &(t, p) in &dist {
        cum += p;
        if u < cum {
            return t;
        }
    }
    // fp rounding left cum fractionally below 1: the tail token takes it
    dist.last().expect("non-empty distribution").0
}

/// First stop sequence that is a suffix of `output`; returns its length
/// (the number of tokens to truncate). Sequences are checked in config
/// order.
pub fn match_stop(output: &[i32], stop: &[Vec<i32>]) -> Option<usize> {
    stop.iter()
        .find(|s| !s.is_empty() && output.len() >= s.len() && output[output.len() - s.len()..] == s[..])
        .map(Vec::len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::argmax_row;
    use crate::testutil::{forall, Config};

    #[test]
    fn default_is_greedy() {
        let cfg = GenerationConfig::default();
        assert!(cfg.is_greedy());
        assert_eq!(cfg.max_new_tokens, 16);
        cfg.validate().unwrap();
    }

    #[test]
    fn greedy_matches_argmax_row_exactly() {
        forall(Config::cases(200), |rng| {
            let vocab = rng.range(2, 64);
            let mut logits = rng.normal_vec(vocab);
            if rng.below(4) == 0 {
                logits[rng.below(vocab as u64) as usize] = f32::NAN;
            }
            let cfg = GenerationConfig::greedy(4);
            let got = sample(&cfg, &logits, &[1, 2], &[3], 1);
            let want = argmax_row(&logits, 0, vocab);
            if got != want {
                return Err(format!("greedy {got} != argmax {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn distribution_sums_to_one_and_is_sorted() {
        forall(Config::cases(100), |rng| {
            let vocab = rng.range(4, 128);
            let logits = rng.normal_vec(vocab);
            let cfg = GenerationConfig {
                temperature: 0.9,
                top_k: rng.range(0, vocab),
                top_p: 0.2 + 0.8 * rng.f64() as f32,
                ..GenerationConfig::greedy(4)
            };
            let dist = distribution(&cfg, &logits, &[], &[]);
            let sum: f64 = dist.iter().map(|&(_, p)| p).sum();
            if (sum - 1.0).abs() > 1e-9 {
                return Err(format!("probs sum to {sum}"));
            }
            for w in dist.windows(2) {
                if w[1].1 > w[0].1 + 1e-15 {
                    return Err("distribution not sorted by probability".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sampling_is_deterministic_per_step() {
        let cfg = GenerationConfig {
            temperature: 1.0,
            top_k: 8,
            seed: 0xBEEF,
            ..GenerationConfig::greedy(4)
        };
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let a = sample(&cfg, &logits, &[1], &[2, 3], 2);
        let b = sample(&cfg, &logits, &[1], &[2, 3], 2);
        assert_eq!(a, b);
        // different steps consume different randomness (usually different
        // draws; at minimum the RNG differs — check the distribution is
        // wide enough that some step picks another token)
        let picks: std::collections::HashSet<usize> =
            (0..64).map(|s| sample(&cfg, &logits, &[1], &[2, 3], s)).collect();
        assert!(picks.len() > 1, "64 steps all drew the same token");
    }

    #[test]
    fn stop_suffix_matching() {
        let stop = vec![vec![5, 6], vec![9]];
        assert_eq!(match_stop(&[1, 2, 5, 6], &stop), Some(2));
        assert_eq!(match_stop(&[1, 9], &stop), Some(1));
        assert_eq!(match_stop(&[5, 6, 1], &stop), None);
        assert_eq!(match_stop(&[6], &stop), None);
        assert_eq!(match_stop(&[], &stop), None);
        assert_eq!(match_stop(&[1, 2], &[]), None);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let ok = GenerationConfig::greedy(4);
        ok.validate().unwrap();
        let bad = |f: &dyn Fn(&mut GenerationConfig)| {
            let mut c = GenerationConfig::greedy(4);
            f(&mut c);
            c.validate().unwrap_err()
        };
        assert_eq!(
            bad(&|c| c.max_new_tokens = 0),
            SubmitError::ZeroMaxNewTokens
        );
        assert!(matches!(
            bad(&|c| c.temperature = -1.0),
            SubmitError::InvalidConfig { .. }
        ));
        assert!(matches!(
            bad(&|c| c.temperature = f32::NAN),
            SubmitError::InvalidConfig { .. }
        ));
        assert!(matches!(bad(&|c| c.top_p = 0.0), SubmitError::InvalidConfig { .. }));
        assert!(matches!(bad(&|c| c.top_p = 1.5), SubmitError::InvalidConfig { .. }));
        assert!(matches!(
            bad(&|c| c.repetition_penalty = 0.0),
            SubmitError::InvalidConfig { .. }
        ));
        assert!(matches!(
            bad(&|c| c.stop = vec![vec![]]),
            SubmitError::InvalidConfig { .. }
        ));
    }

    #[test]
    fn top_k_caps_support() {
        let logits = vec![1.0f32, 2.0, 3.0, 4.0];
        let cfg = GenerationConfig { temperature: 1.0, top_k: 2, ..GenerationConfig::greedy(4) };
        let dist = distribution(&cfg, &logits, &[], &[]);
        assert_eq!(dist.len(), 2);
        assert_eq!(dist[0].0, 3);
        assert_eq!(dist[1].0, 2);
    }

    #[test]
    fn penalty_discourages_history_tokens() {
        let logits = vec![2.0f32, 2.0, 2.0];
        let cfg = GenerationConfig {
            temperature: 1.0,
            repetition_penalty: 2.0,
            ..GenerationConfig::greedy(4)
        };
        // token 1 is in the history → its probability must drop below the
        // others'
        let dist = distribution(&cfg, &logits, &[1], &[]);
        let p = |t: usize| dist.iter().find(|&&(tok, _)| tok == t).unwrap().1;
        assert!(p(1) < p(0));
        assert!((p(0) - p(2)).abs() < 1e-12);
    }
}
