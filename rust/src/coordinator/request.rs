//! Request lifecycle types.

use super::generation::{match_stop, GenerationConfig};

/// Monotonic request identifier.
pub type RequestId = u64;

/// Lifecycle state of one inference request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Queued; not yet admitted to the running batch.
    Waiting,
    /// Admitted; prefill pending or in flight (possibly mid-chunk).
    Prefilling,
    /// In the decode batch, generating tokens.
    Decoding,
    /// Finished (max tokens or stop sequence).
    Done,
    /// Rejected/aborted (e.g. KV capacity exhausted).
    Failed,
}

/// Why a request stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// A configured stop sequence matched (and was truncated from the
    /// output).
    Stop,
    /// The paged KV pool could not hold another token and the request was
    /// finished early with what it had.
    KvExhausted,
    /// An SLO deadline (`ttft_deadline_ns` or `total_deadline_ns`) elapsed
    /// before the request finished; it was aborted with a typed outcome.
    Timeout,
    /// Load shedding at admission evicted the request under overload
    /// (lowest priority class first).
    Shed,
}

impl FinishReason {
    /// Stable lowercase name, used in scenario JSON and completions.
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::KvExhausted => "kv_exhausted",
            FinishReason::Timeout => "timeout",
            FinishReason::Shed => "shed",
        }
    }
}

/// One inference request and its progress.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    /// Per-request sampling/termination config (greedy by default).
    pub gen: GenerationConfig,
    pub state: RequestState,
    /// Generated token ids.
    pub output: Vec<i32>,
    /// Prompt+output positions whose KV has been written this admission —
    /// the chunked-prefill cursor. Reset to 0 on preemption (the KV is
    /// released; readmission re-prefills `prompt ++ output`).
    pub prefilled: usize,
    /// How many times this request has been preempted.
    pub preemptions: u32,
    /// Simulated ns spent restoring spilled KV from disk at readmissions
    /// (zero unless the engine runs a spill store). Restores happen after
    /// the first token by construction, so the timeline carves this out
    /// of the decode span.
    pub restore_ns: u64,
    /// Set exactly once, when the request transitions to `Done`.
    pub finish: Option<FinishReason>,
    /// Simulated clock (ns) when the request arrived / prefilled / finished.
    pub t_arrive_ns: u64,
    pub t_first_token_ns: Option<u64>,
    pub t_done_ns: Option<u64>,
    /// When the request was *first* admitted to the running batch
    /// (readmissions after preemption don't move it).
    pub t_admitted_ns: Option<u64>,
    /// When the request last entered the wait queue: arrival, or the most
    /// recent preemption. Queue-wait spans in the trace begin here.
    pub t_enqueued_ns: u64,
}

impl Request {
    /// Greedy request for `max_new_tokens` (the pre-sampling API shape).
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize, now_ns: u64) -> Self {
        Self::with_gen(id, prompt, GenerationConfig::greedy(max_new_tokens), now_ns)
    }

    /// Request with a full per-request generation config.
    pub fn with_gen(id: RequestId, prompt: Vec<i32>, gen: GenerationConfig, now_ns: u64) -> Self {
        Self {
            id,
            prompt,
            gen,
            state: RequestState::Waiting,
            output: Vec::new(),
            prefilled: 0,
            preemptions: 0,
            restore_ns: 0,
            finish: None,
            t_arrive_ns: now_ns,
            t_first_token_ns: None,
            t_done_ns: None,
            t_admitted_ns: None,
            t_enqueued_ns: now_ns,
        }
    }

    /// Generation budget (≥ 1; validated at submit).
    pub fn max_new_tokens(&self) -> usize {
        self.gen.max_new_tokens
    }

    /// Current context length (prompt + generated).
    pub fn ctx_len(&self) -> usize {
        self.prompt.len() + self.output.len()
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, RequestState::Done | RequestState::Failed)
    }

    /// Accept one generated token: record TTFT on the first, then apply
    /// the config's termination rules — stop-sequence suffix match (which
    /// truncates the matched tokens and finishes with
    /// [`FinishReason::Stop`]) before the `max_new_tokens` length check.
    /// Returns `true` when the request just finished.
    pub fn accept_token(&mut self, token: i32, now_ns: u64) -> bool {
        self.output.push(token);
        if self.t_first_token_ns.is_none() {
            self.t_first_token_ns = Some(now_ns);
        }
        if let Some(n) = match_stop(&self.output, &self.gen.stop) {
            self.output.truncate(self.output.len() - n);
            self.finish_with(FinishReason::Stop, now_ns);
            return true;
        }
        if self.output.len() >= self.gen.max_new_tokens {
            self.finish_with(FinishReason::Length, now_ns);
            return true;
        }
        false
    }

    /// Transition to `Done` with a reason (idempotent on the reason).
    pub fn finish_with(&mut self, reason: FinishReason, now_ns: u64) {
        self.state = RequestState::Done;
        self.t_done_ns = Some(now_ns);
        if self.finish.is_none() {
            self.finish = Some(reason);
        }
    }

    /// Transition to `Failed` with a typed abort reason (deadline timeout,
    /// load shed). The reason is set once; the terminal timestamp always.
    pub fn abort_with(&mut self, reason: FinishReason, now_ns: u64) {
        self.state = RequestState::Failed;
        self.t_done_ns = Some(now_ns);
        if self.finish.is_none() {
            self.finish = Some(reason);
        }
    }

    /// Stable outcome string for reports and scenario JSON: `"done"` for a
    /// normally-finished request, the typed abort name (`"timeout"`,
    /// `"shed"`) for SLO/overload aborts, `"failed"` otherwise.
    pub fn outcome_str(&self) -> &'static str {
        match (self.state, self.finish) {
            (RequestState::Done, _) => "done",
            (_, Some(FinishReason::Timeout)) => "timeout",
            (_, Some(FinishReason::Shed)) => "shed",
            _ => "failed",
        }
    }

    /// Time-to-first-token in simulated ns.
    pub fn ttft_ns(&self) -> Option<u64> {
        self.t_first_token_ns.map(|t| t - self.t_arrive_ns)
    }

    /// End-to-end latency in simulated ns.
    pub fn latency_ns(&self) -> Option<u64> {
        self.t_done_ns.map(|t| t - self.t_arrive_ns)
    }

    /// Per-phase breakdown of this request's lifetime (simulated ns).
    pub fn timeline(&self) -> TimelineSummary {
        TimelineSummary {
            queue_wait_ns: self.t_admitted_ns.map(|t| t - self.t_arrive_ns),
            prefill_ns: match (self.t_admitted_ns, self.t_first_token_ns) {
                (Some(a), Some(f)) => Some(f.saturating_sub(a)),
                _ => None,
            },
            decode_ns: match (self.t_first_token_ns, self.t_done_ns) {
                (Some(f), Some(d)) => Some((d - f).saturating_sub(self.restore_ns)),
                _ => None,
            },
            restore_ns: self.restore_ns,
            preemptions: self.preemptions,
        }
    }
}

/// Phase breakdown of one request's lifetime, all in simulated ns.
///
/// `queue_wait_ns` is arrival → **first** admission; `prefill_ns` is
/// first admission → first token; `restore_ns` is the simulated disk
/// time spill-restore readmissions spent replaying KV (zero without a
/// spill store); `decode_ns` is first token → terminal state minus the
/// restores. Preemption/readmission churn after the first token (the
/// blocks released, the queue wait, any re-prefill) all lands in
/// `decode_ns` — the four phases always sum to the end-to-end latency
/// once the request finishes. Optional fields are `None` until the phase
/// boundary exists.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TimelineSummary {
    pub queue_wait_ns: Option<u64>,
    pub prefill_ns: Option<u64>,
    pub decode_ns: Option<u64>,
    pub restore_ns: u64,
    pub preemptions: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accessors() {
        let mut r = Request::new(1, vec![1, 2, 3], 4, 100);
        assert_eq!(r.ctx_len(), 3);
        assert_eq!(r.max_new_tokens(), 4);
        assert!(!r.is_finished());
        r.output.push(7);
        assert_eq!(r.ctx_len(), 4);
        r.t_first_token_ns = Some(150);
        assert_eq!(r.ttft_ns(), Some(50));
        r.state = RequestState::Done;
        r.t_done_ns = Some(400);
        assert_eq!(r.latency_ns(), Some(300));
        assert!(r.is_finished());
    }

    #[test]
    fn accept_token_length_finish() {
        let mut r = Request::new(1, vec![1], 2, 0);
        assert!(!r.accept_token(10, 50));
        assert_eq!(r.ttft_ns(), Some(50));
        assert!(r.accept_token(11, 60));
        assert_eq!(r.finish, Some(FinishReason::Length));
        assert_eq!(r.output, vec![10, 11]);
        assert_eq!(r.latency_ns(), Some(60));
    }

    #[test]
    fn accept_token_stop_truncates() {
        let gen = GenerationConfig {
            stop: vec![vec![8, 9]],
            ..GenerationConfig::greedy(10)
        };
        let mut r = Request::with_gen(2, vec![1], gen, 0);
        assert!(!r.accept_token(7, 10));
        assert!(!r.accept_token(8, 20));
        assert!(r.accept_token(9, 30));
        assert_eq!(r.finish, Some(FinishReason::Stop));
        assert_eq!(r.output, vec![7], "matched stop tokens truncated");
        // TTFT was still recorded on the first (kept) token
        assert_eq!(r.ttft_ns(), Some(10));
    }

    #[test]
    fn timeline_phases_sum_to_latency() {
        let mut r = Request::new(4, vec![1, 2], 3, 100);
        assert_eq!(r.timeline(), TimelineSummary::default());
        r.t_admitted_ns = Some(140);
        assert_eq!(r.timeline().queue_wait_ns, Some(40));
        assert_eq!(r.timeline().prefill_ns, None, "no first token yet");
        r.accept_token(7, 200);
        r.accept_token(8, 260);
        r.preemptions = 1;
        // a spill-restore readmission spent 30 simulated ns on disk I/O:
        // it carves out of the decode span, keeping the sum pinned
        r.restore_ns = 30;
        r.accept_token(9, 400);
        let t = r.timeline();
        assert_eq!(t, TimelineSummary {
            queue_wait_ns: Some(40),
            prefill_ns: Some(60),
            decode_ns: Some(170),
            restore_ns: 30,
            preemptions: 1,
        });
        let sum = t.queue_wait_ns.unwrap()
            + t.prefill_ns.unwrap()
            + t.restore_ns
            + t.decode_ns.unwrap();
        assert_eq!(Some(sum), r.latency_ns());
    }

    #[test]
    fn abort_with_sets_typed_outcome_once() {
        let mut r = Request::new(5, vec![1], 4, 0);
        assert_eq!(r.outcome_str(), "failed", "waiting requests report failed if aborted");
        r.abort_with(FinishReason::Timeout, 90);
        assert_eq!(r.state, RequestState::Failed);
        assert_eq!(r.finish, Some(FinishReason::Timeout));
        assert_eq!(r.outcome_str(), "timeout");
        assert_eq!(r.latency_ns(), Some(90));
        // reason is finish-once — a later abort can't overwrite it
        r.abort_with(FinishReason::Shed, 120);
        assert_eq!(r.finish, Some(FinishReason::Timeout));
        assert_eq!(r.outcome_str(), "timeout");

        let mut s = Request::new(6, vec![1], 4, 0);
        s.abort_with(FinishReason::Shed, 10);
        assert_eq!(s.outcome_str(), "shed");
        let mut d = Request::new(7, vec![1], 1, 0);
        d.accept_token(3, 5);
        assert_eq!(d.outcome_str(), "done");
    }

    #[test]
    fn stop_beats_length_on_final_token() {
        let gen = GenerationConfig { stop: vec![vec![5]], ..GenerationConfig::greedy(1) };
        let mut r = Request::with_gen(3, vec![1], gen, 0);
        assert!(r.accept_token(5, 10));
        assert_eq!(r.finish, Some(FinishReason::Stop));
        assert!(r.output.is_empty());
    }
}
