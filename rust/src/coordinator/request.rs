//! Request lifecycle types.

/// Monotonic request identifier.
pub type RequestId = u64;

/// Lifecycle state of one inference request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Queued; not yet admitted to the running batch.
    Waiting,
    /// Admitted; prefill pending or in flight.
    Prefilling,
    /// In the decode batch, generating tokens.
    Decoding,
    /// Finished (max tokens or EOS).
    Done,
    /// Rejected/aborted (e.g. KV capacity exhausted).
    Failed,
}

/// One inference request and its progress.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub state: RequestState,
    /// Generated token ids.
    pub output: Vec<i32>,
    /// Simulated clock (ns) when the request arrived / prefilled / finished.
    pub t_arrive_ns: u64,
    pub t_first_token_ns: Option<u64>,
    pub t_done_ns: Option<u64>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize, now_ns: u64) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            state: RequestState::Waiting,
            output: Vec::new(),
            t_arrive_ns: now_ns,
            t_first_token_ns: None,
            t_done_ns: None,
        }
    }

    /// Current context length (prompt + generated).
    pub fn ctx_len(&self) -> usize {
        self.prompt.len() + self.output.len()
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, RequestState::Done | RequestState::Failed)
    }

    /// Time-to-first-token in simulated ns.
    pub fn ttft_ns(&self) -> Option<u64> {
        self.t_first_token_ns.map(|t| t - self.t_arrive_ns)
    }

    /// End-to-end latency in simulated ns.
    pub fn latency_ns(&self) -> Option<u64> {
        self.t_done_ns.map(|t| t - self.t_arrive_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accessors() {
        let mut r = Request::new(1, vec![1, 2, 3], 4, 100);
        assert_eq!(r.ctx_len(), 3);
        assert!(!r.is_finished());
        r.output.push(7);
        assert_eq!(r.ctx_len(), 4);
        r.t_first_token_ns = Some(150);
        assert_eq!(r.ttft_ns(), Some(50));
        r.state = RequestState::Done;
        r.t_done_ns = Some(400);
        assert_eq!(r.latency_ns(), Some(300));
        assert!(r.is_finished());
    }
}
