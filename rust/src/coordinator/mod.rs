//! Serving coordinator — the L3 request path.
//!
//! A vLLM-router-style front end scaled to this architecture: requests enter
//! a FCFS queue, a continuous batcher admits them into the running batch at
//! decode-round boundaries against the *actual free KV blocks* of the
//! paged pool (typed rejections at submit, preemption + re-prefill when
//! decode growth outruns the pool), the KV manager tracks per-request shard
//! placement (the balanced layout of §IV-C) over a block ledger, and the
//! engine drives both the functional numerics runtime (tiny model) and the
//! instruction-level/analytical simulators (timing + energy) for every
//! step. The NPM double banking of §V-A is exercised on every program swap.

pub mod batcher;
pub mod engine;
pub mod generation;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{EngineConfig, Numerics, OverloadPolicy, ServingEngine, SubmitError};
pub use generation::GenerationConfig;
pub use kv::KvManager;
pub use metrics::Metrics;
pub use request::{FinishReason, Request, RequestId, RequestState, TimelineSummary};
pub use server::{Completion, Server};
