//! KV-cache manager: per-request, per-layer shard placement bookkeeping on
//! top of `schedule::KvPlacement` (the balanced layout of §IV-C), with
//! global capacity accounting so admission can reject oversubscription.

use std::collections::HashMap;

use crate::arch::TileGeometry;
use crate::schedule::{KvPlacement, ShardLayout};

use super::request::RequestId;

/// Manages KV placements for all live requests.
#[derive(Debug)]
pub struct KvManager {
    layout: ShardLayout,
    /// One placement per request (layers share the pattern; the manager
    /// tracks token counts once and multiplies by layer count for words).
    per_request: HashMap<RequestId, KvPlacement>,
    pub n_layers: usize,
    /// Aggregate capacity in tokens across the batch (scratchpad budget).
    pub capacity_tokens: usize,
}

impl KvManager {
    pub fn new(geom: &TileGeometry, d_head: usize, n_layers: usize) -> Self {
        let layout = ShardLayout::new(geom, d_head);
        let capacity_tokens = layout.capacity_tokens();
        Self { layout, per_request: HashMap::new(), n_layers, capacity_tokens }
    }

    /// Tokens currently cached across all requests.
    pub fn used_tokens(&self) -> usize {
        self.per_request.values().map(|p| p.len).sum()
    }

    /// Can we hold `tokens` more?
    pub fn has_room(&self, tokens: usize) -> bool {
        self.used_tokens() + tokens <= self.capacity_tokens
    }

    /// Install a prefill for a request.
    pub fn prefill(&mut self, id: RequestId, tokens: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.has_room(tokens), "KV capacity exhausted");
        anyhow::ensure!(!self.per_request.contains_key(&id), "request {id} already placed");
        let mut p = KvPlacement::new(self.layout.clone());
        p.fill_prefill(tokens)?;
        self.per_request.insert(id, p);
        Ok(())
    }

    /// Append one decode token for a request.
    pub fn append(&mut self, id: RequestId) -> anyhow::Result<()> {
        anyhow::ensure!(self.has_room(1), "KV capacity exhausted");
        let p = self
            .per_request
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown request {id}"))?;
        p.append()?;
        Ok(())
    }

    /// Release a finished request's cache.
    pub fn release(&mut self, id: RequestId) -> usize {
        self.per_request.remove(&id).map(|p| p.len).unwrap_or(0)
    }

    /// Worst per-request imbalance (must stay ≤ 2 — the §IV-C invariant).
    pub fn max_imbalance(&self) -> usize {
        self.per_request.values().map(|p| p.imbalance()).max().unwrap_or(0)
    }

    pub fn live_requests(&self) -> usize {
        self.per_request.len()
    }

    /// Context length of one request.
    pub fn ctx_of(&self, id: RequestId) -> Option<usize> {
        self.per_request.get(&id).map(|p| p.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwParams;

    fn mgr() -> KvManager {
        let hw = HwParams::default();
        let geom = TileGeometry::for_model(2048, &hw);
        KvManager::new(&geom, 64, 16)
    }

    #[test]
    fn prefill_append_release_cycle() {
        let mut m = mgr();
        m.prefill(1, 100).unwrap();
        assert_eq!(m.used_tokens(), 100);
        m.append(1).unwrap();
        assert_eq!(m.ctx_of(1), Some(101));
        assert_eq!(m.release(1), 101);
        assert_eq!(m.used_tokens(), 0);
        assert_eq!(m.live_requests(), 0);
    }

    #[test]
    fn capacity_rejection() {
        let mut m = mgr();
        m.capacity_tokens = 150;
        m.prefill(1, 100).unwrap();
        assert!(m.prefill(2, 100).is_err());
        assert!(m.has_room(50));
        assert!(!m.has_room(51));
    }

    #[test]
    fn duplicate_prefill_rejected() {
        let mut m = mgr();
        m.prefill(1, 10).unwrap();
        assert!(m.prefill(1, 10).is_err());
    }

    #[test]
    fn append_unknown_request_fails() {
        let mut m = mgr();
        assert!(m.append(42).is_err());
    }

    #[test]
    fn imbalance_invariant_across_many_requests() {
        let mut m = mgr();
        for id in 0..5 {
            m.prefill(id, 97 + id as usize * 13).unwrap();
            for _ in 0..10 {
                m.append(id).unwrap();
            }
        }
        assert!(m.max_imbalance() <= 2, "imbalance {}", m.max_imbalance());
    }
}
