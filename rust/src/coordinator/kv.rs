//! KV-cache manager: per-request, per-layer shard placement bookkeeping on
//! top of `schedule::KvPlacement` (the balanced layout of §IV-C), with the
//! simulated scratchpad capacity now accounted in **pool blocks** through a
//! storage-free [`BlockLedger`] — the same allocator that backs the
//! functional [`crate::kvcache::KvStore`]. A block is one tile row group
//! (`TileGeometry::shard_rows` tokens), so the coordinator's admission
//! arithmetic matches the backend pool's granularity exactly: a request
//! holds `ceil(ctx / block_size)` blocks, appends claim a new block only at
//! a group boundary, and release returns every block to the shared pool.

use std::collections::HashMap;

use crate::arch::TileGeometry;
use crate::kvcache::{BlockId, BlockLedger};
use crate::schedule::{KvPlacement, ShardLayout};

use super::request::RequestId;

/// Manages KV placements + block-granular capacity for all live requests.
#[derive(Debug)]
pub struct KvManager {
    layout: ShardLayout,
    /// One placement per request (layers share the pattern; the manager
    /// tracks token counts once and multiplies by layer count for words).
    per_request: HashMap<RequestId, KvPlacement>,
    /// Simulated-scratchpad blocks held per request (no storage — ids into
    /// `ledger`).
    blocks: HashMap<RequestId, Vec<BlockId>>,
    ledger: BlockLedger,
    /// Tokens per block: one tile row group.
    block_size: usize,
    pub n_layers: usize,
}

impl KvManager {
    pub fn new(geom: &TileGeometry, d_head: usize, n_layers: usize) -> Self {
        let layout = ShardLayout::new(geom, d_head);
        let block_size = geom.shard_rows.max(1);
        let n_blocks = layout.capacity_tokens() / block_size;
        Self {
            layout,
            per_request: HashMap::new(),
            blocks: HashMap::new(),
            ledger: BlockLedger::new(n_blocks),
            block_size,
            n_layers,
        }
    }

    /// Tokens per block (one tile row group).
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Blocks a context of `tokens` occupies.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn total_blocks(&self) -> usize {
        self.ledger.total()
    }

    pub fn free_blocks(&self) -> usize {
        self.ledger.free_blocks()
    }

    /// Aggregate token capacity (block-granular).
    pub fn capacity_tokens(&self) -> usize {
        self.ledger.total() * self.block_size
    }

    /// Shrink/grow the simulated capacity (tests, experiments). Only valid
    /// while no request holds blocks.
    pub fn set_capacity_tokens(&mut self, tokens: usize) {
        assert!(
            self.per_request.is_empty(),
            "cannot resize the KV pool while requests hold blocks"
        );
        self.ledger = BlockLedger::new(tokens / self.block_size);
    }

    /// Tokens currently cached across all requests.
    pub fn used_tokens(&self) -> usize {
        self.per_request.values().map(|p| p.len).sum()
    }

    /// Can a new request of `tokens` context be placed right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.ledger.free_blocks()
    }

    /// Can request `id` append one token (tail-block room or a free block)?
    pub fn can_append(&self, id: RequestId) -> bool {
        match self.per_request.get(&id) {
            Some(p) => p.len % self.block_size != 0 || self.ledger.free_blocks() > 0,
            None => false,
        }
    }

    /// Install a prefill for a request, claiming its blocks.
    pub fn prefill(&mut self, id: RequestId, tokens: usize) -> anyhow::Result<()> {
        anyhow::ensure!(!self.per_request.contains_key(&id), "request {id} already placed");
        let need = self.blocks_for(tokens);
        let mut held = Vec::with_capacity(need);
        for _ in 0..need {
            match self.ledger.alloc() {
                Some(b) => held.push(b),
                None => {
                    for b in held {
                        self.ledger.release(b);
                    }
                    anyhow::bail!("KV capacity exhausted");
                }
            }
        }
        let mut p = KvPlacement::new(self.layout.clone());
        p.fill_prefill(tokens)?;
        self.per_request.insert(id, p);
        self.blocks.insert(id, held);
        Ok(())
    }

    /// Append one decode token for a request (claims a block at group
    /// boundaries).
    pub fn append(&mut self, id: RequestId) -> anyhow::Result<()> {
        let p = self
            .per_request
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown request {id}"))?;
        let held = self.blocks.get_mut(&id).expect("blocks tracked for every placement");
        if p.len % self.block_size == 0 {
            let b = self.ledger.alloc().ok_or_else(|| anyhow::anyhow!("KV capacity exhausted"))?;
            held.push(b);
        }
        p.append()?;
        Ok(())
    }

    /// Release a finished request's cache; returns the token count freed.
    pub fn release(&mut self, id: RequestId) -> usize {
        for b in self.blocks.remove(&id).unwrap_or_default() {
            self.ledger.release(b);
        }
        self.per_request.remove(&id).map(|p| p.len).unwrap_or(0)
    }

    /// Worst per-request imbalance (must stay ≤ 2 — the §IV-C invariant).
    pub fn max_imbalance(&self) -> usize {
        self.per_request.values().map(|p| p.imbalance()).max().unwrap_or(0)
    }

    pub fn live_requests(&self) -> usize {
        self.per_request.len()
    }

    /// Context length of one request.
    pub fn ctx_of(&self, id: RequestId) -> Option<usize> {
        self.per_request.get(&id).map(|p| p.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwParams;

    fn mgr() -> KvManager {
        let hw = HwParams::default();
        let geom = TileGeometry::for_model(2048, &hw);
        KvManager::new(&geom, 64, 16)
    }

    #[test]
    fn prefill_append_release_cycle() {
        let mut m = mgr();
        assert_eq!(m.block_size(), 16);
        m.prefill(1, 100).unwrap();
        assert_eq!(m.used_tokens(), 100);
        assert_eq!(m.total_blocks() - m.free_blocks(), 7, "ceil(100/16) blocks held");
        m.append(1).unwrap();
        assert_eq!(m.ctx_of(1), Some(101));
        assert_eq!(m.release(1), 101);
        assert_eq!(m.used_tokens(), 0);
        assert_eq!(m.live_requests(), 0);
        assert_eq!(m.free_blocks(), m.total_blocks(), "all blocks returned");
    }

    #[test]
    fn capacity_rejection_is_block_granular() {
        let mut m = mgr();
        m.set_capacity_tokens(160); // 10 blocks of 16
        m.prefill(1, 100).unwrap(); // 7 blocks
        assert!(m.prefill(2, 100).is_err(), "7 more blocks don't fit in 3");
        assert_eq!(m.free_blocks(), 3, "failed prefill must roll back fully");
        assert!(m.can_admit(48));
        assert!(!m.can_admit(49), "49 tokens need a 4th block");
    }

    #[test]
    fn append_claims_blocks_at_group_boundaries() {
        let mut m = mgr();
        m.set_capacity_tokens(64); // 4 blocks
        m.prefill(1, 16).unwrap(); // exactly 1 full block
        let free_after_prefill = m.free_blocks();
        assert!(m.can_append(1));
        m.append(1).unwrap(); // token 17 opens block 2
        assert_eq!(m.free_blocks(), free_after_prefill - 1);
        for _ in 0..15 {
            m.append(1).unwrap(); // fills block 2, no new claims
        }
        assert_eq!(m.free_blocks(), free_after_prefill - 1);
    }

    #[test]
    fn append_exhaustion_reported() {
        let mut m = mgr();
        m.set_capacity_tokens(32); // 2 blocks
        m.prefill(1, 32).unwrap();
        assert!(!m.can_append(1));
        assert!(m.append(1).is_err());
        assert!(!m.can_append(42), "unknown request can't append");
    }

    #[test]
    fn duplicate_prefill_rejected() {
        let mut m = mgr();
        m.prefill(1, 10).unwrap();
        assert!(m.prefill(1, 10).is_err());
    }

    #[test]
    fn append_unknown_request_fails() {
        let mut m = mgr();
        assert!(m.append(42).is_err());
    }

    #[test]
    fn imbalance_invariant_across_many_requests() {
        let mut m = mgr();
        for id in 0..5 {
            m.prefill(id, 97 + id as usize * 13).unwrap();
            for _ in 0..10 {
                m.append(id).unwrap();
            }
        }
        assert!(m.max_imbalance() <= 2, "imbalance {}", m.max_imbalance());
    }
}
