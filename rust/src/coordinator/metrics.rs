//! Serving metrics: throughput, latency percentiles, energy, utilisation.
//!
//! Latency and TTFT are recorded into fixed 64-bucket log2
//! [`Histogram`]s, not per-sample vectors: memory stays constant no
//! matter how many requests a run serves, and the percentile queries are
//! nearest-rank over the buckets with no cloning or sorting (the
//! convention is documented on [`crate::obs::histogram`]).

use crate::obs::Histogram;

/// Aggregated serving metrics over one engine run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests_done: u64,
    pub requests_failed: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Simulated time spent, ns.
    pub sim_time_ns: u64,
    /// Simulated energy, J.
    pub energy_j: f64,
    /// Wall-clock time the coordinator itself consumed, ns (host overhead).
    pub host_time_ns: u64,
    /// Per-request end-to-end latencies (simulated ns), log2-bucketed.
    pub latency: Histogram,
    /// Per-request time-to-first-token (simulated ns), log2-bucketed.
    pub ttft: Histogram,
    /// NPM bank swaps performed.
    pub npm_swaps: u64,
    /// Requests rejected with a typed error at submit (never queued).
    pub requests_rejected: u64,
    /// Pool preemptions: a running request released its KV blocks and
    /// re-entered the wait queue.
    pub preemptions: u64,
    /// Prefill program dispatches (one per chunk; equals the number of
    /// prefills when chunking is off).
    pub prefill_chunks: u64,
    /// Requests finished by a stop-sequence match (subset of
    /// `requests_done`).
    pub requests_stopped: u64,

    // --- durability: spill-to-disk + crash recovery ---------------------
    /// Preemptions whose KV rows were written to a spill file (subset of
    /// `preemptions`; the rest were recompute-on-readmit).
    pub kv_spills: u64,
    /// KV blocks spilled to disk, cumulative over all spills.
    pub kv_spilled_blocks: u64,
    /// Bytes written to spill files.
    pub spill_bytes_written: u64,
    /// Bytes read back from spill files at readmission restore.
    pub spill_bytes_read: u64,
    /// Sessions rebuilt from a journal (`Engine::resubmit_recovered`).
    pub sessions_recovered: u64,
    /// Journal records replayed during recovery.
    pub recovery_replay_events: u64,

    // --- robustness: SLOs, overload shedding, fault injection -----------
    /// Requests aborted with a typed `Timeout` (TTFT or total deadline
    /// elapsed). Not counted in `requests_failed` or the latency
    /// histograms — a timed-out stream is an SLO outcome, not a sample.
    pub requests_timeout: u64,
    /// Requests aborted with a typed `Shed` by the overload policy.
    pub requests_shed: u64,
    /// Persist-I/O retries after a transient failure (journal or spill).
    pub persist_retries: u64,
    /// Faults the active `FaultPlan` injected (all sites, cumulative).
    pub faults_injected: u64,
    /// Worker-pool lanes that died to an isolated panic (cumulative).
    pub pool_lane_deaths: u64,

    // --- paged-KV pool gauges (zero when the backend does not pool) -----
    /// Tokens per physical KV block.
    pub kv_block_size: usize,
    /// Storage dtype of the backend KV pool (f32 when the backend does
    /// not pool).
    pub kv_dtype: crate::kvcache::KvDtype,
    /// Bytes one KV token position occupies (both arenas, all layers).
    pub kv_bytes_per_token: usize,
    /// Physical blocks in the backend pool.
    pub kv_blocks_total: usize,
    /// Blocks in use at the last observation.
    pub kv_blocks_used: usize,
    /// High-water mark of blocks in use.
    pub kv_peak_blocks_used: usize,
    /// Blocks currently referenced by more than one session (prefix
    /// sharing) at the last observation.
    pub kv_shared_blocks: usize,
    /// Prefix-cache probes (one per prompt chunk walked at prefill).
    pub kv_prefix_lookups: u64,
    /// Prefix-cache hits (chunks resolved to an already-resident block).
    pub kv_prefix_hits: u64,
    /// Copy-on-write block copies performed.
    pub kv_cow_copies: u64,

    // --- worker-pool gauges (zero when the backend has no resident pool) -
    /// Pool lanes (resident workers + the dispatching thread).
    pub pool_threads: usize,
    /// Parallel tile dispatches since backend load (serial fallbacks never
    /// dispatch). Nonzero with zero thread spawns after load is the
    /// persistent-pool contract.
    pub pool_dispatches: u64,
    /// Worker park transitions (spin budget exhausted → condvar block).
    pub pool_parks: u64,
    /// Parked-worker wake transitions.
    pub pool_wakes: u64,
}

impl Metrics {
    /// Generation throughput in tokens per simulated second.
    pub fn decode_tokens_per_s(&self) -> f64 {
        self.decode_tokens as f64 / (self.sim_time_ns as f64 * 1e-9).max(1e-12)
    }

    /// Total (prefill + decode) tokens per simulated second.
    pub fn total_tokens_per_s(&self) -> f64 {
        (self.prefill_tokens + self.decode_tokens) as f64
            / (self.sim_time_ns as f64 * 1e-9).max(1e-12)
    }

    /// Tokens per joule.
    pub fn tokens_per_j(&self) -> f64 {
        (self.prefill_tokens + self.decode_tokens) as f64 / self.energy_j.max(1e-12)
    }

    /// (p50, p99) end-to-end latency in simulated ns — nearest-rank over
    /// the log2 histogram, O(buckets), no cloning or sorting.
    pub fn latency_p50_p99(&self) -> (u64, u64) {
        (self.latency.percentile(0.5), self.latency.percentile(0.99))
    }

    /// (p50, p99) TTFT in simulated ns (same convention).
    pub fn ttft_p50_p99(&self) -> (u64, u64) {
        (self.ttft.percentile(0.5), self.ttft.percentile(0.99))
    }

    /// Host-overhead fraction: coordinator wall time / simulated time.
    /// (L3 must not be the bottleneck — tracked for the perf pass.)
    pub fn host_overhead(&self) -> f64 {
        self.host_time_ns as f64 / self.sim_time_ns.max(1) as f64
    }

    /// Fold one backend pool snapshot into the gauges (counters are
    /// cumulative in the pool, so overwrite; the peak is kept monotone).
    pub fn observe_kv_pool(&mut self, s: &crate::kvcache::PoolStats) {
        self.kv_block_size = s.block_size;
        self.kv_dtype = s.dtype;
        self.kv_bytes_per_token = s.bytes_per_token;
        self.kv_blocks_total = s.blocks_total;
        self.kv_blocks_used = s.blocks_used;
        self.kv_peak_blocks_used = self.kv_peak_blocks_used.max(s.peak_blocks_used);
        self.kv_shared_blocks = s.shared_blocks;
        self.kv_prefix_lookups = s.prefix_lookups;
        self.kv_prefix_hits = s.prefix_hits;
        self.kv_cow_copies = s.cow_copies;
    }

    /// Fold one worker-pool snapshot into the gauges (counters are
    /// cumulative in the pool, so overwrite).
    pub fn observe_worker_pool(&mut self, s: &crate::runtime::WorkerPoolStats) {
        self.pool_threads = s.threads;
        self.pool_dispatches = s.dispatches;
        self.pool_parks = s.parks;
        self.pool_wakes = s.wakes;
        self.pool_lane_deaths = s.lane_deaths;
    }

    /// Fraction of prefix-cache probes that hit (0 when never probed).
    /// Delegates to [`crate::kvcache::PoolStats::prefix_hit_rate`] so the
    /// convention lives in one place.
    pub fn kv_prefix_hit_rate(&self) -> f64 {
        crate::kvcache::PoolStats {
            prefix_lookups: self.kv_prefix_lookups,
            prefix_hits: self.kv_prefix_hits,
            ..Default::default()
        }
        .prefix_hit_rate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics {
            decode_tokens: 1000,
            prefill_tokens: 1000,
            sim_time_ns: 2_000_000_000,
            energy_j: 4.0,
            ..Default::default()
        };
        assert!((m.decode_tokens_per_s() - 500.0).abs() < 1e-9);
        assert!((m.total_tokens_per_s() - 1000.0).abs() < 1e-9);
        assert!((m.tokens_per_j() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn kv_pool_gauges_fold_snapshots() {
        use crate::kvcache::PoolStats;
        let mut m = Metrics::default();
        assert_eq!(m.kv_prefix_hit_rate(), 0.0);
        m.observe_kv_pool(&PoolStats {
            block_size: 4,
            dtype: crate::kvcache::KvDtype::Q8,
            bytes_per_token: 40,
            blocks_total: 32,
            blocks_free: 20,
            blocks_used: 12,
            peak_blocks_used: 14,
            shared_blocks: 3,
            prefix_lookups: 8,
            prefix_hits: 6,
            cow_copies: 1,
            spilled_blocks: 0,
        });
        // a later, quieter snapshot must not lower the peak
        m.observe_kv_pool(&PoolStats {
            block_size: 4,
            dtype: crate::kvcache::KvDtype::Q8,
            bytes_per_token: 40,
            blocks_total: 32,
            blocks_free: 30,
            blocks_used: 2,
            peak_blocks_used: 14,
            shared_blocks: 0,
            prefix_lookups: 10,
            prefix_hits: 7,
            cow_copies: 2,
            spilled_blocks: 0,
        });
        assert_eq!(m.kv_blocks_used, 2);
        assert_eq!(m.kv_dtype.as_str(), "q8");
        assert_eq!(m.kv_bytes_per_token, 40);
        assert_eq!(m.kv_peak_blocks_used, 14);
        assert_eq!(m.kv_cow_copies, 2);
        assert!((m.kv_prefix_hit_rate() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn worker_pool_gauges_fold_snapshots() {
        use crate::runtime::WorkerPoolStats;
        let mut m = Metrics::default();
        assert_eq!(m.pool_threads, 0);
        m.observe_worker_pool(&WorkerPoolStats {
            threads: 4,
            workers: 3,
            dispatches: 12,
            parks: 2,
            wakes: 2,
            lane_deaths: 0,
            dead_lanes: 0,
        });
        m.observe_worker_pool(&WorkerPoolStats {
            threads: 4,
            workers: 3,
            dispatches: 40,
            parks: 5,
            wakes: 5,
            lane_deaths: 1,
            dead_lanes: 0b100,
        });
        assert_eq!(m.pool_threads, 4);
        assert_eq!(m.pool_dispatches, 40, "cumulative counter: overwrite, not add");
        assert_eq!(m.pool_parks, 5);
        assert_eq!(m.pool_wakes, 5);
        assert_eq!(m.pool_lane_deaths, 1);
    }

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        for v in [50, 10, 30, 20, 40] {
            m.latency.record(v);
        }
        // nearest-rank: rank ceil(0.5·5)=3 → 30, rank ceil(0.99·5)=5 → 50
        let (p50, p99) = m.latency_p50_p99();
        assert_eq!(p50, 30);
        assert_eq!(p99, 50);
        let empty = Metrics::default();
        assert_eq!(empty.latency_p50_p99(), (0, 0));
        assert_eq!(empty.ttft_p50_p99(), (0, 0));
    }
}
