//! Serving metrics: throughput, latency percentiles, energy, utilisation.

/// Aggregated serving metrics over one engine run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests_done: u64,
    pub requests_failed: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Simulated time spent, ns.
    pub sim_time_ns: u64,
    /// Simulated energy, J.
    pub energy_j: f64,
    /// Wall-clock time the coordinator itself consumed, ns (host overhead).
    pub host_time_ns: u64,
    /// Per-request end-to-end latencies (simulated ns).
    pub latencies_ns: Vec<u64>,
    /// Per-request time-to-first-token (simulated ns).
    pub ttft_ns: Vec<u64>,
    /// NPM bank swaps performed.
    pub npm_swaps: u64,
}

impl Metrics {
    /// Generation throughput in tokens per simulated second.
    pub fn decode_tokens_per_s(&self) -> f64 {
        self.decode_tokens as f64 / (self.sim_time_ns as f64 * 1e-9).max(1e-12)
    }

    /// Total (prefill + decode) tokens per simulated second.
    pub fn total_tokens_per_s(&self) -> f64 {
        (self.prefill_tokens + self.decode_tokens) as f64
            / (self.sim_time_ns as f64 * 1e-9).max(1e-12)
    }

    /// Tokens per joule.
    pub fn tokens_per_j(&self) -> f64 {
        (self.prefill_tokens + self.decode_tokens) as f64 / self.energy_j.max(1e-12)
    }

    fn percentile(sorted: &[u64], p: f64) -> u64 {
        if sorted.is_empty() {
            return 0;
        }
        let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
        sorted[idx]
    }

    /// (p50, p99) end-to-end latency in simulated ns.
    pub fn latency_p50_p99(&self) -> (u64, u64) {
        let mut v = self.latencies_ns.clone();
        v.sort_unstable();
        (Self::percentile(&v, 0.5), Self::percentile(&v, 0.99))
    }

    /// (p50, p99) TTFT in simulated ns.
    pub fn ttft_p50_p99(&self) -> (u64, u64) {
        let mut v = self.ttft_ns.clone();
        v.sort_unstable();
        (Self::percentile(&v, 0.5), Self::percentile(&v, 0.99))
    }

    /// Host-overhead fraction: coordinator wall time / simulated time.
    /// (L3 must not be the bottleneck — tracked for the perf pass.)
    pub fn host_overhead(&self) -> f64 {
        self.host_time_ns as f64 / self.sim_time_ns.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics {
            decode_tokens: 1000,
            prefill_tokens: 1000,
            sim_time_ns: 2_000_000_000,
            energy_j: 4.0,
            ..Default::default()
        };
        assert!((m.decode_tokens_per_s() - 500.0).abs() < 1e-9);
        assert!((m.total_tokens_per_s() - 1000.0).abs() < 1e-9);
        assert!((m.tokens_per_j() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let m = Metrics { latencies_ns: vec![50, 10, 30, 20, 40], ..Default::default() };
        let (p50, p99) = m.latency_p50_p99();
        assert_eq!(p50, 30);
        assert_eq!(p99, 50);
        let empty = Metrics::default();
        assert_eq!(empty.latency_p50_p99(), (0, 0));
    }
}
