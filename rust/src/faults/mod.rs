//! Deterministic fault injection: a seeded, schedule-driven [`FaultPlan`]
//! the serving engine consults at every injectable call site.
//!
//! The plan is a list of [`FaultRule`]s, each naming a [`FaultSite`] and
//! the 1-based call count at which it starts firing. Whether a given call
//! injects is a pure function of `(seed, site, count)` — no wall clock, no
//! OS randomness — so a faulted run is exactly reproducible and a chaos
//! test can diff it bitwise against the fault-free baseline. Sites cover
//! the engine's failure surface:
//!
//! - `journal_write` — the WAL append in [`crate::persist::Journal`]
//! - `spill_write` / `spill_read` — KV spill-to-disk I/O
//! - `lane_panic` / `lane_stall` — worker-pool lane faults (armed through
//!   [`crate::runtime::NumericsBackend::inject_lane_fault`], consulted
//!   once per engine step)
//! - `block_alloc` — allocation failure in the KV block ledger at
//!   admission (the faulted request is rejected with a typed outcome)
//!
//! Plan syntax (CLI `serve --fault-plan`, scenario `fault` directive):
//! `;`-separated clauses of whitespace-separated `k=v` fields, e.g.
//!
//! ```text
//! seed=7; site=journal_write at=3 mode=transient times=2; site=lane_panic lane=1
//! ```
//!
//! `mode=permanent` (default) fires from call `at` onward; `transient`
//! fires for `times` calls then recovers. `at=seeded` derives the firing
//! call from the plan seed and the site index — still pure and
//! reproducible, but varied across seeds for fuzz-style chaos sweeps.

use crate::testutil::SplitMix64;

/// An injectable call site in the serving stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// One append to the crash-safe session journal.
    JournalWrite,
    /// One KV image write to the spill store (at preemption).
    SpillWrite,
    /// One KV image read from the spill store (at readmission).
    SpillRead,
    /// Arm a worker-pool lane to panic at its next engagement.
    LanePanic,
    /// Arm a worker-pool lane to stall (bounded busy-wait) once.
    LaneStall,
    /// One KV block-ledger admission decision fails allocation.
    BlockAlloc,
}

impl FaultSite {
    /// Every site, in wire/index order.
    pub const ALL: [FaultSite; 6] = [
        FaultSite::JournalWrite,
        FaultSite::SpillWrite,
        FaultSite::SpillRead,
        FaultSite::LanePanic,
        FaultSite::LaneStall,
        FaultSite::BlockAlloc,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::JournalWrite => "journal_write",
            FaultSite::SpillWrite => "spill_write",
            FaultSite::SpillRead => "spill_read",
            FaultSite::LanePanic => "lane_panic",
            FaultSite::LaneStall => "lane_stall",
            FaultSite::BlockAlloc => "block_alloc",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        FaultSite::ALL.into_iter().find(|site| site.as_str() == s)
    }

    pub(crate) fn index(self) -> usize {
        match self {
            FaultSite::JournalWrite => 0,
            FaultSite::SpillWrite => 1,
            FaultSite::SpillRead => 2,
            FaultSite::LanePanic => 3,
            FaultSite::LaneStall => 4,
            FaultSite::BlockAlloc => 5,
        }
    }
}

/// How long a rule keeps firing once its call count is reached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// Fire for `times` consecutive calls, then recover (the transient
    /// I/O error a bounded retry should ride out).
    Transient { times: u32 },
    /// Fire on every call from `at` onward (the device that stays dead).
    Permanent,
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultRule {
    pub site: FaultSite,
    /// 1-based call count at which the rule starts firing.
    pub at: u64,
    pub mode: FaultMode,
    /// Worker-pool lane for `lane_panic` / `lane_stall` (ignored by the
    /// I/O sites; lane 0 is the dispatching thread and is clamped to 1
    /// by the pool, which cannot kill its caller).
    pub lane: usize,
}

impl FaultRule {
    fn fires(&self, count: u64) -> bool {
        match self.mode {
            FaultMode::Permanent => count >= self.at,
            FaultMode::Transient { times } => {
                count >= self.at && count < self.at + u64::from(times)
            }
        }
    }
}

/// A parsed, counting fault schedule. [`FaultPlan::check`] is the single
/// decision point: it increments the per-site call counter and reports
/// whether this call injects. An empty plan (the default) never injects
/// and costs one `Vec::is_empty` branch per site consult.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Plan seed: folded into `at=seeded` rules; recorded for provenance.
    pub seed: u64,
    rules: Vec<FaultRule>,
    counts: [u64; 6],
    injected: [u64; 6],
}

impl FaultPlan {
    /// A plan that never injects.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    pub fn rules(&self) -> &[FaultRule] {
        &self.rules
    }

    /// Count one call at `site`; return the firing rule if this call
    /// injects. Pure in `(seed, site, count)`: replaying the same call
    /// sequence injects at exactly the same points.
    pub fn check(&mut self, site: FaultSite) -> Option<FaultRule> {
        if self.rules.is_empty() {
            return None;
        }
        let i = site.index();
        self.counts[i] += 1;
        let count = self.counts[i];
        let rule = self.rules.iter().find(|r| r.site == site && r.fires(count)).copied();
        if rule.is_some() {
            self.injected[i] += 1;
        }
        rule
    }

    /// Calls counted at `site` so far.
    pub fn site_count(&self, site: FaultSite) -> u64 {
        self.counts[site.index()]
    }

    /// Injections fired at `site` so far.
    pub fn injected_at(&self, site: FaultSite) -> u64 {
        self.injected[site.index()]
    }

    /// Total injections fired across every site.
    pub fn injected_total(&self) -> u64 {
        self.injected.iter().sum()
    }

    /// Per-site injection counters, indexed like [`FaultSite::ALL`].
    pub fn injected_counts(&self) -> [u64; 6] {
        self.injected
    }

    /// Parse a plan spec (see the module docs for the syntax).
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut plan = FaultPlan::default();
        // Two passes so `seed=` applies to `at=seeded` rules regardless of
        // clause order.
        for clause in spec.split(';') {
            let clause = clause.trim();
            if let Some(v) = clause.strip_prefix("seed=") {
                if !clause.contains(char::is_whitespace) {
                    plan.seed = v
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("fault plan: bad seed '{v}'"))?;
                }
            }
        }
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if clause.starts_with("seed=") && !clause.contains(char::is_whitespace) {
                continue; // consumed by the first pass
            }
            let mut site = None;
            let mut at_raw: Option<String> = None;
            let mut mode_raw: Option<String> = None;
            let mut times: u32 = 1;
            let mut lane: usize = 1;
            for field in clause.split_whitespace() {
                let (k, v) = field.split_once('=').ok_or_else(|| {
                    anyhow::anyhow!("fault plan: field '{field}' is not key=value")
                })?;
                match k {
                    "site" => {
                        site = Some(FaultSite::parse(v).ok_or_else(|| {
                            anyhow::anyhow!(
                                "fault plan: unknown site '{v}' (journal_write, spill_write, \
                                 spill_read, lane_panic, lane_stall, block_alloc)"
                            )
                        })?)
                    }
                    "at" => at_raw = Some(v.to_string()),
                    "mode" => mode_raw = Some(v.to_string()),
                    "times" => {
                        times = v
                            .parse()
                            .map_err(|_| anyhow::anyhow!("fault plan: bad times '{v}'"))?
                    }
                    "lane" => {
                        lane = v
                            .parse()
                            .map_err(|_| anyhow::anyhow!("fault plan: bad lane '{v}'"))?
                    }
                    other => anyhow::bail!("fault plan: unknown field '{other}' in '{clause}'"),
                }
            }
            let site = site
                .ok_or_else(|| anyhow::anyhow!("fault plan: clause '{clause}' needs site="))?;
            let at = match at_raw.as_deref() {
                None => 1,
                Some("seeded") => seeded_at(plan.seed, site),
                Some(v) => v
                    .parse::<u64>()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| anyhow::anyhow!("fault plan: bad at '{v}' (1-based)"))?,
            };
            let mode = match mode_raw.as_deref() {
                None | Some("permanent") => FaultMode::Permanent,
                Some("transient") => FaultMode::Transient { times: times.max(1) },
                Some(other) => {
                    anyhow::bail!("fault plan: mode permanent|transient, got '{other}'")
                }
            };
            plan.rules.push(FaultRule { site, at, mode, lane });
        }
        anyhow::ensure!(!plan.rules.is_empty(), "fault plan '{spec}' has no rules");
        Ok(plan)
    }
}

/// The `at=seeded` schedule: a pure function of (seed, site) landing in
/// call counts 1..=16.
fn seeded_at(seed: u64, site: FaultSite) -> u64 {
    let mut rng = SplitMix64::new(seed ^ ((site.index() as u64 + 1) * 0x9E37_79B9_7F4A_7C15));
    1 + rng.below(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_injects_and_counts_nothing() {
        let mut p = FaultPlan::none();
        for _ in 0..100 {
            assert!(p.check(FaultSite::JournalWrite).is_none());
        }
        assert_eq!(p.injected_total(), 0);
        assert_eq!(p.site_count(FaultSite::JournalWrite), 0, "empty plan skips counting");
    }

    #[test]
    fn parse_roundtrip_and_schedules() {
        let p = FaultPlan::parse(
            "seed=9; site=journal_write at=3 mode=transient times=2; \
             site=lane_panic at=1 lane=2",
        )
        .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.rules().len(), 2);
        assert_eq!(p.rules()[0].site, FaultSite::JournalWrite);
        assert_eq!(p.rules()[0].at, 3);
        assert_eq!(p.rules()[0].mode, FaultMode::Transient { times: 2 });
        assert_eq!(p.rules()[1].site, FaultSite::LanePanic);
        assert_eq!(p.rules()[1].lane, 2);
        assert_eq!(p.rules()[1].mode, FaultMode::Permanent);
    }

    #[test]
    fn transient_fires_exactly_times_then_recovers() {
        let mut p = FaultPlan::parse("site=spill_read at=2 mode=transient times=3").unwrap();
        let fired: Vec<bool> =
            (0..8).map(|_| p.check(FaultSite::SpillRead).is_some()).collect();
        assert_eq!(fired, [false, true, true, true, false, false, false, false]);
        assert_eq!(p.injected_at(FaultSite::SpillRead), 3);
        assert_eq!(p.site_count(FaultSite::SpillRead), 8);
    }

    #[test]
    fn permanent_fires_from_at_onward() {
        let mut p = FaultPlan::parse("site=journal_write at=3").unwrap();
        let fired: Vec<bool> =
            (0..5).map(|_| p.check(FaultSite::JournalWrite).is_some()).collect();
        assert_eq!(fired, [false, false, true, true, true]);
        // other sites are untouched
        assert!(p.check(FaultSite::SpillWrite).is_none());
    }

    #[test]
    fn checks_are_reproducible_across_identical_plans() {
        let spec = "seed=5; site=spill_write at=seeded mode=transient times=1";
        let mut a = FaultPlan::parse(spec).unwrap();
        let mut b = FaultPlan::parse(spec).unwrap();
        let fa: Vec<bool> = (0..32).map(|_| a.check(FaultSite::SpillWrite).is_some()).collect();
        let fb: Vec<bool> = (0..32).map(|_| b.check(FaultSite::SpillWrite).is_some()).collect();
        assert_eq!(fa, fb, "injection is a pure function of (seed, site, count)");
        assert_eq!(fa.iter().filter(|&&x| x).count(), 1, "seeded transient fires once");
    }

    #[test]
    fn seeded_at_varies_with_seed_but_not_call_order() {
        let a = seeded_at(1, FaultSite::LanePanic);
        let b = seeded_at(1, FaultSite::LanePanic);
        assert_eq!(a, b);
        assert!((1..=16).contains(&a));
        let different: Vec<u64> = (0..16).map(|s| seeded_at(s, FaultSite::LanePanic)).collect();
        assert!(different.iter().any(|&x| x != a), "seed must move the schedule");
    }

    #[test]
    fn parse_errors_are_typed() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("site=warp_core").is_err());
        assert!(FaultPlan::parse("site=journal_write at=0").is_err());
        assert!(FaultPlan::parse("site=journal_write mode=flaky").is_err());
        assert!(FaultPlan::parse("site=journal_write bogus=1").is_err());
        assert!(FaultPlan::parse("at=1").is_err(), "clause without a site");
    }
}
