//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `harness = false` bench binaries use [`bench`] for hot-path timing
//! (warmup + N samples, mean/p50/p99) and the table printers for the
//! paper-figure regeneration output.

use std::time::Instant;

/// Timing statistics over a set of samples (nanoseconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub samples: usize,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<u64>) -> Self {
        assert!(!ns.is_empty());
        ns.sort_unstable();
        let n = ns.len();
        Self {
            samples: n,
            mean_ns: ns.iter().sum::<u64>() as f64 / n as f64,
            p50_ns: ns[(n - 1) / 2],
            p99_ns: ns[((n - 1) as f64 * 0.99) as usize],
            min_ns: ns[0],
            max_ns: ns[n - 1],
        }
    }

    /// Human-readable time with unit scaling.
    pub fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    }
}

/// Time `f` with `warmup` unmeasured runs then `samples` measured runs.
/// The closure's return value is black-boxed to prevent dead-code
/// elimination.
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        ns.push(t0.elapsed().as_nanos() as u64);
    }
    let s = Stats::from_samples(ns);
    println!(
        "{name:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  ({} samples)",
        Stats::fmt_ns(s.mean_ns),
        Stats::fmt_ns(s.p50_ns as f64),
        Stats::fmt_ns(s.p99_ns as f64),
        s.samples
    );
    s
}

/// Print a markdown-ish table row.
pub fn row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect::<Vec<_>>()
        .join("  ")
}

/// Render a sparkline-style histogram for terminal output (Fig. 8 and
/// Fig. 10 shapes at a glance).
pub fn ascii_histogram(bins: &[(f64, usize)], width: usize) -> String {
    let max = bins.iter().map(|(_, n)| *n).max().unwrap_or(1).max(1);
    bins.iter()
        .map(|(center, n)| {
            let bar = "#".repeat((n * width).div_ceil(max));
            format!("{center:>12.0} | {bar} {n}")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_samples() {
        let s = Stats::from_samples(vec![10, 20, 30, 40, 100]);
        assert_eq!(s.samples, 5);
        assert_eq!(s.p50_ns, 30);
        assert_eq!(s.min_ns, 10);
        assert_eq!(s.max_ns, 100);
        assert!((s.mean_ns - 40.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(Stats::fmt_ns(500.0), "500 ns");
        assert_eq!(Stats::fmt_ns(1500.0), "1.50 µs");
        assert_eq!(Stats::fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(Stats::fmt_ns(3.2e9), "3.20 s");
    }

    #[test]
    fn bench_runs_and_returns() {
        let mut count = 0;
        let s = bench("test", 2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7);
        assert_eq!(s.samples, 5);
    }

    #[test]
    fn histogram_renders() {
        let h = ascii_histogram(&[(100.0, 5), (200.0, 10)], 20);
        assert!(h.contains('#'));
        assert_eq!(h.lines().count(), 2);
    }
}
