//! Per-session KV spill files: preemption writes the session's cached
//! rows to disk instead of discarding them, readmission restores them
//! into the pool and resumes decode with **zero re-prefilled tokens**.
//!
//! The payload is the pool's stored representation verbatim — f32/f16
//! element bytes, or q8 quantised rows *with their per-row scales* — so a
//! restore is bit-exact for every [`KvDtype`] (re-quantising a
//! dequantised q8 row would not be). Spill files are a cache, not a
//! durability promise: losing one merely costs a re-prefill, so writes
//! are never fsynced and [`SpillStore::create`] wipes leftovers from a
//! previous process.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context};

use crate::kvcache::{KvDtype, SpillImage};
use crate::runtime::SessionId;

use super::eventlog::{fnv1a, Dec, Enc};

const MAGIC: &[u8; 8] = b"LEAPSPL1";

fn dtype_code(dt: KvDtype) -> u8 {
    match dt {
        KvDtype::F32 => 0,
        KvDtype::F16 => 1,
        KvDtype::Q8 => 2,
    }
}

fn dtype_from(code: u8) -> Option<KvDtype> {
    match code {
        0 => Some(KvDtype::F32),
        1 => Some(KvDtype::F16),
        2 => Some(KvDtype::Q8),
        _ => None,
    }
}

/// Directory of `session_<id>.kv` spill files plus transfer counters.
#[derive(Debug)]
pub struct SpillStore {
    dir: PathBuf,
    /// Sessions with a live spill file (in-memory: spills never outlive
    /// the process usefully — the pool they came from is gone).
    live: HashSet<SessionId>,
    pub spills: u64,
    pub restores: u64,
    pub bytes_written: u64,
    pub bytes_read: u64,
}

impl SpillStore {
    /// Create the store, wiping any spill files a dead process left.
    pub fn create(dir: &Path) -> anyhow::Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create spill dir {}", dir.display()))?;
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.extension().is_some_and(|x| x == "kv") {
                let _ = std::fs::remove_file(&path);
            }
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            live: HashSet::new(),
            spills: 0,
            restores: 0,
            bytes_written: 0,
            bytes_read: 0,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_of(&self, id: SessionId) -> PathBuf {
        self.dir.join(format!("session_{id}.kv"))
    }

    /// Does this session have a spill image waiting to restore?
    pub fn has(&self, id: SessionId) -> bool {
        self.live.contains(&id)
    }

    /// Live spill files right now.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Write one session's image; returns the file size in bytes.
    pub fn write(&mut self, id: SessionId, img: &SpillImage) -> anyhow::Result<u64> {
        ensure!(
            img.k_scales.len() == img.v_scales.len(),
            "asymmetric scale arrays ({} k, {} v)",
            img.k_scales.len(),
            img.v_scales.len()
        );
        let mut e = Enc::new();
        e.u8(dtype_code(img.dtype));
        e.u32(img.n_layers as u32);
        e.u32(img.d as u32);
        e.u64(img.rows as u64);
        e.u64(img.k.len() as u64);
        e.u64(img.v.len() as u64);
        e.u32(img.k_scales.len() as u32);
        e.bytes(&img.k);
        e.bytes(&img.v);
        for &s in img.k_scales.iter().chain(img.v_scales.iter()) {
            e.f32(s);
        }
        let payload = e.into_inner();
        let mut frame = Vec::with_capacity(payload.len() + 16);
        frame.extend_from_slice(MAGIC);
        frame.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        frame.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        let path = self.path_of(id);
        std::fs::write(&path, &frame)
            .with_context(|| format!("write spill {}", path.display()))?;
        self.live.insert(id);
        self.spills += 1;
        self.bytes_written += frame.len() as u64;
        Ok(frame.len() as u64)
    }

    /// Read back and delete one session's image. `Ok(None)` when the
    /// session was never spilled; `Err` on a corrupt file (the caller
    /// falls back to re-prefill — the file is deleted either way).
    pub fn take(&mut self, id: SessionId) -> anyhow::Result<Option<SpillImage>> {
        if !self.live.remove(&id) {
            return Ok(None);
        }
        let path = self.path_of(id);
        let result = Self::read_image(&path);
        let _ = std::fs::remove_file(&path);
        let (img, bytes) = result?;
        self.restores += 1;
        self.bytes_read += bytes;
        Ok(Some(img))
    }

    /// Drop a session's spill file without reading it (the session
    /// finished or failed while spilled).
    pub fn discard(&mut self, id: SessionId) {
        if self.live.remove(&id) {
            let _ = std::fs::remove_file(self.path_of(id));
        }
    }

    fn read_image(path: &Path) -> anyhow::Result<(SpillImage, u64)> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read spill {}", path.display()))?;
        ensure!(bytes.len() >= 20 && &bytes[..8] == MAGIC, "bad spill magic/size");
        let mut len8 = [0u8; 8];
        len8.copy_from_slice(&bytes[8..16]);
        let len = u64::from_le_bytes(len8) as usize;
        let mut want4 = [0u8; 4];
        want4.copy_from_slice(&bytes[16..20]);
        let want = u32::from_le_bytes(want4);
        ensure!(bytes.len() == 20 + len, "spill length mismatch");
        let payload = &bytes[20..];
        ensure!(fnv1a(payload) == want, "spill checksum mismatch");
        let mut d = Dec::new(payload);
        let dtype = dtype_from(d.u8()?).context("unknown spill dtype")?;
        let n_layers = d.u32()? as usize;
        let dim = d.u32()? as usize;
        let rows = d.u64()? as usize;
        let k_len = d.u64()? as usize;
        let v_len = d.u64()? as usize;
        let n_scales = d.u32()? as usize;
        let k = d.bytes(k_len)?;
        let v = d.bytes(v_len)?;
        let mut k_scales = Vec::with_capacity(n_scales);
        for _ in 0..n_scales {
            k_scales.push(d.f32()?);
        }
        let mut v_scales = Vec::with_capacity(n_scales);
        for _ in 0..n_scales {
            v_scales.push(d.f32()?);
        }
        d.done()?;
        let img = SpillImage { dtype, n_layers, d: dim, rows, k, v, k_scales, v_scales };
        img.validate()?;
        Ok((img, bytes.len() as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("leap_spill_tests").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample(dtype: KvDtype) -> SpillImage {
        let (n_layers, d, rows) = (2usize, 4usize, 3usize);
        let elems = rows * n_layers * d;
        let elem_bytes = match dtype {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
            KvDtype::Q8 => 1,
        };
        let scales = if dtype == KvDtype::Q8 { rows * n_layers } else { 0 };
        SpillImage {
            dtype,
            n_layers,
            d,
            rows,
            k: (0..elems * elem_bytes).map(|i| i as u8).collect(),
            v: (0..elems * elem_bytes).map(|i| (i * 3) as u8).collect(),
            k_scales: (0..scales).map(|i| i as f32 * 0.5).collect(),
            v_scales: (0..scales).map(|i| i as f32 * 0.25).collect(),
        }
    }

    #[test]
    fn write_take_roundtrip_all_dtypes() {
        let dir = tmp_dir("roundtrip");
        let mut store = SpillStore::create(&dir).unwrap();
        for (i, dtype) in [KvDtype::F32, KvDtype::F16, KvDtype::Q8].into_iter().enumerate() {
            let img = sample(dtype);
            let id = i as SessionId;
            let bytes = store.write(id, &img).unwrap();
            assert!(store.has(id));
            assert!(bytes > 0);
            let back = store.take(id).unwrap().unwrap();
            assert_eq!(back, img, "bitwise roundtrip for {dtype:?}");
            assert!(!store.has(id));
        }
        assert_eq!(store.spills, 3);
        assert_eq!(store.restores, 3);
        assert_eq!(store.bytes_written, store.bytes_read);
    }

    #[test]
    fn take_unspilled_is_none_and_discard_removes_file() {
        let dir = tmp_dir("none");
        let mut store = SpillStore::create(&dir).unwrap();
        assert!(store.take(7).unwrap().is_none());
        store.write(7, &sample(KvDtype::F32)).unwrap();
        let path = store.path_of(7);
        assert!(path.exists());
        store.discard(7);
        assert!(!path.exists());
        assert!(store.take(7).unwrap().is_none());
    }

    #[test]
    fn corrupt_spill_errors_and_is_deleted() {
        let dir = tmp_dir("corrupt");
        let mut store = SpillStore::create(&dir).unwrap();
        store.write(1, &sample(KvDtype::Q8)).unwrap();
        let path = store.path_of(1);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.take(1).unwrap_err().to_string().contains("checksum"));
        assert!(!path.exists(), "corrupt file must not linger");
    }

    #[test]
    fn create_wipes_leftovers() {
        let dir = tmp_dir("wipe");
        let mut store = SpillStore::create(&dir).unwrap();
        store.write(3, &sample(KvDtype::F16)).unwrap();
        let path = store.path_of(3);
        drop(store);
        assert!(path.exists(), "files survive the process (simulated crash)");
        let store = SpillStore::create(&dir).unwrap();
        assert!(!path.exists(), "a fresh store starts clean");
        assert!(!store.has(3));
    }
}
