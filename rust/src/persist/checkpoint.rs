//! Periodic compaction of the event journal into one atomic snapshot.
//!
//! A checkpoint is the full session state the journal's first `covers`
//! records would rebuild: one [`SessionSnapshot`] per session ever
//! submitted (finished sessions included — recovery reports their streams
//! too). Recovery is then *snapshot + tail replay*: load the checkpoint,
//! skip `covers` journal records, apply the rest. The journal itself is
//! never truncated — skipping by count has no crash window, where a
//! truncate racing the checkpoint rename could double-apply or lose
//! records.
//!
//! The file is written tmp-then-rename (atomic on POSIX), checksummed as
//! a whole; a corrupt or missing checkpoint degrades to full journal
//! replay, never to an error.

use std::io::Write;
use std::path::Path;

use anyhow::Context;

use crate::coordinator::{GenerationConfig, RequestId};

use super::eventlog::{fnv1a, get_gen, put_gen, Dec, Enc};

/// Checkpoint filename inside a journal directory.
pub const CHECKPOINT_FILE: &str = "checkpoint.bin";
const TMP_FILE: &str = "checkpoint.tmp";
const MAGIC: &[u8; 8] = b"LEAPCKP1";

/// Everything needed to re-create one session after a crash.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub gen: GenerationConfig,
    /// Tokens emitted (post-truncation once `finished`).
    pub output: Vec<i32>,
    /// Reached a terminal state before the snapshot/crash.
    pub finished: bool,
    /// Terminal state was a failure (admission reject, KV exhaustion).
    pub failed: bool,
}

impl SessionSnapshot {
    fn encode(&self, e: &mut Enc) {
        e.u64(self.id);
        e.tokens(&self.prompt);
        put_gen(e, &self.gen);
        e.tokens(&self.output);
        e.u8(u8::from(self.finished) | (u8::from(self.failed) << 1));
    }

    fn decode(d: &mut Dec<'_>) -> anyhow::Result<Self> {
        let id = d.u64()?;
        let prompt = d.tokens()?;
        let gen = get_gen(d)?;
        let output = d.tokens()?;
        let flags = d.u8()?;
        Ok(Self { id, prompt, gen, output, finished: flags & 1 != 0, failed: flags & 2 != 0 })
    }
}

/// One compacted snapshot of the journal's prefix.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Journal records this snapshot already reflects — replay skips them.
    pub covers: u64,
    pub sessions: Vec<SessionSnapshot>,
}

impl Checkpoint {
    /// Atomically (tmp + fsync + rename) write into `dir`.
    pub fn write(&self, dir: &Path) -> anyhow::Result<()> {
        let mut e = Enc::new();
        e.u64(self.covers);
        e.u32(self.sessions.len() as u32);
        for s in &self.sessions {
            s.encode(&mut e);
        }
        let payload = e.into_inner();
        let tmp = dir.join(TMP_FILE);
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(payload.len() as u32).to_le_bytes())?;
        f.write_all(&fnv1a(&payload).to_le_bytes())?;
        f.write_all(&payload)?;
        // the rename must only ever expose a fully durable file
        f.sync_data().context("checkpoint fsync")?;
        drop(f);
        std::fs::rename(&tmp, dir.join(CHECKPOINT_FILE)).context("checkpoint rename")?;
        Ok(())
    }

    /// Load from `dir`. `None` on missing, short, or corrupt files —
    /// recovery then falls back to full journal replay.
    pub fn load(dir: &Path) -> Option<Checkpoint> {
        let bytes = std::fs::read(dir.join(CHECKPOINT_FILE)).ok()?;
        if bytes.len() < 16 || &bytes[..8] != MAGIC {
            return None;
        }
        let mut len4 = [0u8; 4];
        len4.copy_from_slice(&bytes[8..12]);
        let len = u32::from_le_bytes(len4) as usize;
        let mut want4 = [0u8; 4];
        want4.copy_from_slice(&bytes[12..16]);
        let want = u32::from_le_bytes(want4);
        if bytes.len() != 16 + len {
            return None;
        }
        let payload = &bytes[16..];
        if fnv1a(payload) != want {
            return None;
        }
        let mut d = Dec::new(payload);
        let covers = d.u64().ok()?;
        let n = d.u32().ok()?;
        let mut sessions = Vec::with_capacity(n.min(1 << 20) as usize);
        for _ in 0..n {
            sessions.push(SessionSnapshot::decode(&mut d).ok()?);
        }
        d.done().ok()?;
        Some(Checkpoint { covers, sessions })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            covers: 17,
            sessions: vec![
                SessionSnapshot {
                    id: 0,
                    prompt: vec![1, 2, 3],
                    gen: GenerationConfig::greedy(4),
                    output: vec![7, 8],
                    finished: false,
                    failed: false,
                },
                SessionSnapshot {
                    id: 1,
                    prompt: vec![9],
                    gen: GenerationConfig {
                        temperature: 0.7,
                        seed: 3,
                        stop: vec![vec![2]],
                        ..GenerationConfig::greedy(8)
                    },
                    output: vec![4, 5, 6],
                    finished: true,
                    failed: true,
                },
            ],
        }
    }

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("leap_checkpoint_tests").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_load_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let ck = sample();
        ck.write(&dir).unwrap();
        assert_eq!(Checkpoint::load(&dir), Some(ck));
        // the tmp file never survives a successful write
        assert!(!dir.join(TMP_FILE).exists());
    }

    #[test]
    fn missing_and_corrupt_load_as_none() {
        let dir = tmp_dir("corrupt");
        assert_eq!(Checkpoint::load(&dir), None);
        sample().write(&dir).unwrap();
        let mut bytes = std::fs::read(dir.join(CHECKPOINT_FILE)).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(dir.join(CHECKPOINT_FILE), &bytes).unwrap();
        assert_eq!(Checkpoint::load(&dir), None, "flipped payload bit must fail the checksum");
        // short file
        std::fs::write(dir.join(CHECKPOINT_FILE), b"LEAPCKP1").unwrap();
        assert_eq!(Checkpoint::load(&dir), None);
    }

    #[test]
    fn rewrite_replaces_previous() {
        let dir = tmp_dir("rewrite");
        sample().write(&dir).unwrap();
        let ck2 = Checkpoint { covers: 99, sessions: Vec::new() };
        ck2.write(&dir).unwrap();
        assert_eq!(Checkpoint::load(&dir), Some(ck2));
    }
}
